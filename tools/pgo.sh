#!/usr/bin/env bash
# Profile-guided-optimization lane for the hotpaths bench (opt-in CI job,
# also runnable locally). Classic two-pass cargo PGO:
#
#   1. plain release run of `benches/hotpaths.rs` → baseline numbers;
#   2. `-Cprofile-generate` instrumented build, same bench as the profiling
#      workload (it IS the workload we optimize for);
#   3. `llvm-profdata merge` of the emitted .profraw shards;
#   4. `-Cprofile-use` rebuild, bench again → PGO numbers.
#
# Artifacts:
#   BENCH_hotpaths.json      — plain numbers (regenerated, step 1);
#   BENCH_hotpaths.pgo.json  — per-bench {plain_min_ns, pgo_min_ns, speedup}
#                              plus the geometric-mean speedup, printed too.
#
# Needs the rustup `llvm-tools` component (for llvm-profdata) or an
# llvm-profdata on PATH. No new crates, no cargo plugins.
set -euo pipefail
cd "$(dirname "$0")/.."

PROF_DIR="$(pwd)/target/pgo-profiles"
rm -rf "$PROF_DIR"
mkdir -p "$PROF_DIR"

# Locate llvm-profdata: rustup's llvm-tools ships it inside the sysroot.
HOST="$(rustc -vV | sed -n 's/^host: //p')"
SYSROOT="$(rustc --print sysroot)"
PROFDATA="$SYSROOT/lib/rustlib/$HOST/bin/llvm-profdata"
if [ ! -x "$PROFDATA" ]; then
  PROFDATA="$(command -v llvm-profdata || true)"
fi
if [ -z "$PROFDATA" ]; then
  echo "pgo.sh: llvm-profdata not found (rustup component add llvm-tools)" >&2
  exit 2
fi

echo "== pass 1/3: plain release bench (baseline) =="
cargo bench --bench hotpaths
cp BENCH_hotpaths.json "$PROF_DIR/plain.json"

echo "== pass 2/3: instrumented build + profiling run =="
RUSTFLAGS="-Cprofile-generate=$PROF_DIR" cargo bench --bench hotpaths
"$PROFDATA" merge -o "$PROF_DIR/merged.profdata" "$PROF_DIR"/*.profraw

echo "== pass 3/3: profile-guided rebuild + bench =="
RUSTFLAGS="-Cprofile-use=$PROF_DIR/merged.profdata" cargo bench --bench hotpaths
cp BENCH_hotpaths.json "$PROF_DIR/pgo.json"

# Leave the repo-root file holding the PLAIN numbers (the regression
# baseline other lanes compare against); the PGO comparison goes next to it.
cp "$PROF_DIR/plain.json" BENCH_hotpaths.json

python3 - "$PROF_DIR/plain.json" "$PROF_DIR/pgo.json" BENCH_hotpaths.pgo.json <<'EOF'
import json, math, sys
plain = json.load(open(sys.argv[1]))
pgo = json.load(open(sys.argv[2]))
rows, logs = {}, []
for name, p in plain.items():
    g = pgo.get(name)
    if not g:
        continue
    speedup = p["min_ns"] / max(g["min_ns"], 1e-9)
    rows[name] = {
        "plain_min_ns": p["min_ns"],
        "pgo_min_ns": g["min_ns"],
        "speedup": round(speedup, 4),
    }
    logs.append(math.log(max(speedup, 1e-9)))
    print(f"  {name}: {p['min_ns']:.0f}ns -> {g['min_ns']:.0f}ns ({speedup:.3f}x)")
geomean = math.exp(sum(logs) / len(logs)) if logs else 1.0
rows["_geomean_speedup"] = round(geomean, 4)
json.dump(rows, open(sys.argv[3], "w"), indent=2)
print(f"pgo.sh: geometric-mean speedup {geomean:.3f}x over {len(logs)} benches")
print(f"pgo.sh: wrote {sys.argv[3]}")
EOF
