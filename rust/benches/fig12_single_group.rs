//! Bench: regenerate Figure 12 (single model group saturation multipliers,
//! Puzzle vs Best Mapping vs NPU Only) plus Fig 13's score curves for two
//! scenarios. Use PUZZLE_BENCH_FULL=1 for the full 10-scenario protocol.

use puzzle::experiments::{fig12_single_group, fig13_score_curves, serving, ServingBudget};
use puzzle::perf::PerfModel;

fn main() {
    let pm = PerfModel::paper_calibrated();
    let budget = if std::env::var("PUZZLE_BENCH_FULL").is_ok() {
        ServingBudget::full()
    } else {
        ServingBudget { scenarios: 4, ..ServingBudget::quick() }
    };
    println!("=== Fig 12 reproduction ({} scenarios) ===", budget.scenarios);
    let rows = fig12_single_group(&pm, &budget);
    serving::print_saturation(
        "single model group saturation multipliers (paper: 0.78 / 1.17 / 1.56)",
        &rows,
    );
    println!();
    println!("=== Fig 13 reproduction (score-vs-alpha curves) ===");
    let tight = ServingBudget { scenarios: 2, ..budget };
    for mc in fig13_score_curves(&pm, &tight) {
        println!("scenario {}:", mc.scenario);
        for c in &mc.curves {
            let knee = c
                .alphas
                .iter()
                .zip(&c.scores)
                .find(|(_, (_, med, _))| *med >= 0.995)
                .map(|(a, _)| format!("{a:.1}"))
                .unwrap_or_else(|| ">2.0".into());
            println!("  {:<13} reaches score 1.0 at alpha {}", c.method, knee);
        }
    }
}
