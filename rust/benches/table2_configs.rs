//! Bench: regenerate Table 2 (CPU backend × dtype configuration sweep) and
//! time the perf-model query path that backs it.

use puzzle::experiments::tables;
use puzzle::perf::PerfModel;
use puzzle::util::bench::{bench, black_box};

fn main() {
    let pm = PerfModel::paper_calibrated();
    println!("=== Table 2 reproduction ===");
    tables::print_table2(&pm);
    println!();
    bench("table2/full_config_sweep", 2.0, 10, || {
        black_box(tables::table2_configs(&pm));
    });
}
