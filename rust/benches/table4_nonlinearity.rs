//! Bench: regenerate Table 4 (measured vs layer-sum-estimated execution
//! time — the non-linearity evidence), in both the calibrated model and,
//! when artifacts exist, through *real XLA execution* (fused whole-model
//! HLO vs per-layer chain on the PJRT CPU client).

use std::time::Instant;

use puzzle::experiments::tables;
use puzzle::models::build_model;
use puzzle::perf::PerfModel;
use puzzle::runtime::{layer_artifact, model_artifact, PjrtRuntime};
use puzzle::util::bench::{bench, black_box};

fn main() {
    let pm = PerfModel::paper_calibrated();
    println!("=== Table 4 reproduction (calibrated model) ===");
    tables::print_table4(&pm);
    println!();
    bench("table4/model_sweep", 2.0, 10, || {
        black_box(tables::table4_nonlinearity(&pm));
    });

    // Real-XLA variant: fused whole-model execution vs summed per-layer
    // executions, on the host CPU. XLA's inter-layer fusion is the actual
    // mechanism the paper attributes the non-linearity to.
    if model_artifact("face_det").exists() {
        println!();
        println!("=== real-XLA non-linearity (host CPU, fused vs layer-sum) ===");
        let rt = PjrtRuntime::cpu().expect("client");
        for idx in [0usize, 1, 6] {
            let net = build_model(0, idx);
            let whole = rt.load(&model_artifact(&net.name)).unwrap();
            let input = vec![0.1f32; 32 * 32 * 3];
            let time_it = |f: &mut dyn FnMut()| {
                f(); // warm
                let reps = 20;
                let t0 = Instant::now();
                for _ in 0..reps {
                    f();
                }
                t0.elapsed().as_secs_f64() / reps as f64
            };
            let mut run_whole = || {
                black_box(whole.run_f32(&[(&input, &[1, 32, 32, 3])]).unwrap());
            };
            let fused_t = time_it(&mut run_whole);

            // Sum of isolated per-layer runs (with fresh dummy inputs of the
            // right shapes — the naive estimator's measurement protocol).
            let mut layer_sum = 0.0;
            for l in 0..net.num_layers() {
                let module = rt.load(&layer_artifact(&net.name, l)).unwrap();
                let preds = net.predecessors(puzzle::graph::LayerId(l));
                let shapes: Vec<Vec<usize>> = if preds.is_empty() {
                    vec![vec![1, 32, 32, 3]]
                } else {
                    preds
                        .iter()
                        .map(|p| {
                            let s = net.layer(*p).out_shape;
                            vec![1, s.h, s.w, s.c]
                        })
                        .collect()
                };
                let datas: Vec<Vec<f32>> =
                    shapes.iter().map(|s| vec![0.1f32; s.iter().product()]).collect();
                let mut run_layer = || {
                    let refs: Vec<(&[f32], &[usize])> = datas
                        .iter()
                        .zip(&shapes)
                        .map(|(d, s)| (d.as_slice(), s.as_slice()))
                        .collect();
                    black_box(module.run_f32(&refs).unwrap());
                };
                layer_sum += time_it(&mut run_layer);
            }
            println!(
                "{:<12} fused {:>8.1} us   layer-sum {:>8.1} us   est/meas {:.2}x",
                net.name,
                fused_t * 1e6,
                layer_sum * 1e6,
                layer_sum / fused_t
            );
        }
    } else {
        println!("(artifacts not built; skipping real-XLA variant)");
    }
}
