//! Bench: regenerate Table 3 (best-config execution time per processor) and
//! time the best-config search.

use puzzle::experiments::tables;
use puzzle::graph::LayerId;
use puzzle::models::model_zoo;
use puzzle::perf::PerfModel;
use puzzle::util::bench::{bench, black_box};

fn main() {
    let pm = PerfModel::paper_calibrated();
    println!("=== Table 3 reproduction ===");
    tables::print_table3(&pm);
    println!();
    bench("table3/processor_sweep", 2.0, 10, || {
        black_box(tables::table3_processors(&pm));
    });
    // Hot sub-path: best_config_for over the heaviest model.
    let net = model_zoo().pop().unwrap();
    let all: Vec<LayerId> = (0..net.num_layers()).map(LayerId).collect();
    bench("table3/best_config_fastsam", 2.0, 100, || {
        for p in puzzle::Processor::ALL {
            black_box(pm.best_config_for(&net, &all, p));
        }
    });
}
