//! Bench: regenerate Figure 10 + Table 5 (tensor pool / zero-copy shared
//! buffer ablation) through the real Coordinator/Worker runtime.

use puzzle::experiments::{ablation, fig10_ablation, table5_breakdown};
use puzzle::perf::PerfModel;

fn main() {
    let pm = PerfModel::paper_calibrated();
    println!("=== Fig 10 + Table 5 reproduction (runtime ablation) ===");
    let rows = fig10_ablation(&pm, 4, 10);
    let t5 = table5_breakdown(&pm, 10);
    ablation::print_ablation(&rows, &t5);
}
