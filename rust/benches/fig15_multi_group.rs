//! Bench: regenerate Figures 14/15/16 (multi model group experiments) and
//! the paper's headline request-frequency ratios.

use puzzle::experiments::{
    fig14_makespan_distribution, fig15_multi_group, fig16_multi_score_curves, headline_ratios,
    serving, ServingBudget,
};
use puzzle::perf::PerfModel;

fn main() {
    let pm = PerfModel::paper_calibrated();
    let budget = if std::env::var("PUZZLE_BENCH_FULL").is_ok() {
        ServingBudget::full()
    } else {
        ServingBudget { scenarios: 4, ..ServingBudget::quick() }
    };

    println!("=== Fig 15 reproduction ({} scenarios) ===", budget.scenarios);
    let rows = fig15_multi_group(&pm, &budget);
    serving::print_saturation(
        "multi model group saturation multipliers (paper: 0.95 / 2.24 / 3.45)",
        &rows,
    );
    println!();

    println!("=== Fig 14 reproduction (scenario 10 makespans) ===");
    for (method, alpha, avgs) in fig14_makespan_distribution(&pm, &budget) {
        println!(
            "  {method:<13} alpha={alpha}: group avg makespans {:?}",
            avgs.iter().map(|a| format!("{:.1}ms", a * 1e3)).collect::<Vec<_>>()
        );
    }
    println!();

    println!("=== Fig 16 reproduction (scenarios 6 & 10 score curves) ===");
    let tight = ServingBudget { scenarios: 2, ..budget };
    for mc in fig16_multi_score_curves(&pm, &tight) {
        println!("scenario {}:", mc.scenario);
        for c in &mc.curves {
            let knee = c
                .alphas
                .iter()
                .zip(&c.scores)
                .find(|(_, (_, med, _))| *med >= 0.995)
                .map(|(a, _)| format!("{a:.1}"))
                .unwrap_or_else(|| ">3.0".into());
            println!("  {:<13} reaches score 1.0 at alpha {}", c.method, knee);
        }
    }
    println!();

    println!("=== headline ===");
    let (npu, bm) = headline_ratios(&rows);
    println!(
        "multi-group ratios vs puzzle: NPU Only {npu:.1}x, Best Mapping {bm:.1}x (paper combined: 3.7x / 2.2x)"
    );
}
