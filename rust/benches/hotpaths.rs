//! Micro-benchmarks of the system's hot paths (the §Perf targets):
//! * the discrete-event simulator inner loop (the GA evaluates it ~10^4-10^5
//!   times per search);
//! * genome decode incl. partitioning + profile lookups;
//! * one full GA generation;
//! * NSGA-III selection;
//! * tensor pool acquire/release;
//! * Merkle hashing.

use puzzle::analyzer::{GaConfig, StaticAnalyzer};
use puzzle::comm::CommModel;
use puzzle::ga::{decode, nsga3_select, Genome};
use puzzle::graph::{merkle_hash_subgraph, partition};
use puzzle::mem::TensorPool;
use puzzle::perf::PerfModel;
use puzzle::profiler::Profiler;
use puzzle::scenario::Scenario;
use puzzle::sim::{simulate, GroupSpec, SimOptions};
use puzzle::util::bench::{bench, black_box};
use puzzle::util::rng::Rng;
use puzzle::Processor;

fn main() {
    let pm = PerfModel::paper_calibrated();
    let comm = CommModel::paper_calibrated();
    let scenario = Scenario::from_groups("bench", &[vec![0, 4, 6], vec![1, 5, 8]]);
    let nets = &scenario.networks;
    let mut rng = Rng::seed_from_u64(1);
    let profiler = Profiler::new(&pm);

    // Pre-decode a plan set for the simulator bench.
    let genome = Genome::random(nets, 0.3, &mut rng);
    let plans = decode(nets, &genome, &profiler, &comm);
    let periods = scenario.periods(1.0, &pm);
    let groups: Vec<GroupSpec> = scenario
        .groups
        .iter()
        .zip(&periods)
        .map(|(g, &p)| GroupSpec::periodic(g.members.clone(), p))
        .collect();
    let opts = SimOptions { requests_per_group: 20, ..Default::default() };

    bench("sim/simulate_6models_20req", 3.0, 50, || {
        black_box(simulate(&plans, &groups, &comm, &opts));
    });

    bench("ga/decode_genome(cached profiles)", 3.0, 50, || {
        black_box(decode(nets, &genome, &profiler, &comm));
    });

    bench("ga/decode_fresh_genome", 3.0, 30, || {
        let g = Genome::random(nets, 0.3, &mut rng);
        black_box(decode(nets, &g, &profiler, &comm));
    });

    // Partition alone.
    let net = &nets[5]; // fastsam analog
    let cuts: Vec<bool> = (0..net.num_edges()).map(|i| i % 3 == 0).collect();
    let mapping: Vec<Processor> = (0..net.num_layers())
        .map(|i| Processor::from_index(i % 3))
        .collect();
    bench("graph/partition_17layer", 3.0, 200, || {
        black_box(partition(net, &cuts, &mapping));
    });

    let part = partition(net, &cuts, &mapping);
    bench("graph/merkle_hash", 3.0, 200, || {
        for sg in &part.subgraphs {
            black_box(merkle_hash_subgraph(net, sg));
        }
    });

    // NSGA-III on a realistic pool.
    let objs: Vec<Vec<f64>> = (0..96)
        .map(|_| (0..4).map(|_| rng.gen_f64()).collect())
        .collect();
    bench("ga/nsga3_select_96to48_4obj", 3.0, 100, || {
        black_box(nsga3_select(&objs, 48));
    });

    // Tensor pool.
    let pool = TensorPool::new(true);
    bench("mem/pool_acquire_release_16KiB", 2.0, 500, || {
        let t = pool.acquire(16 * 1024);
        black_box(t.len());
    });

    // One full (tiny) analyzer run for an end-to-end feel.
    let tiny = Scenario::from_groups("tiny", &[vec![0, 1]]);
    let cfg = GaConfig { population: 8, max_generations: 3, sim_requests: 8, measure_reps: 1, ..GaConfig::quick(3) };
    bench("analyzer/tiny_ga_run", 5.0, 3, || {
        black_box(StaticAnalyzer::new(&tiny, &pm, cfg.clone()).run());
    });
}
