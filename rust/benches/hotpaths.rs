//! Micro-benchmarks of the system's hot paths (the §Perf targets):
//! * the discrete-event simulator inner loop (the GA evaluates it ~10^4-10^5
//!   times per search) — fresh-allocation vs reused-workspace;
//! * genome decode incl. partitioning + profile lookups, and the
//!   genome-fingerprint memo hit path;
//! * one full GA generation at population 96, serial vs parallel (the
//!   headline case for the batch evaluation engine);
//! * NSGA-III selection;
//! * tensor pool acquire/release;
//! * Merkle hashing.
//!
//! All stats are also written to `BENCH_hotpaths.json` at the repo root
//! (name → ns/iter) so future PRs can regress against this trajectory.

use puzzle::analyzer::GaConfig;
use puzzle::api::SessionBuilder;
use puzzle::comm::CommModel;
use puzzle::experiments::{run_fuzz_corpus, saturation_protocol, FuzzOptions, ServingBudget};
use puzzle::ga::{decode, nsga3_select, DecodedPlanCache, Genome, SelectionWorkspace};
use puzzle::graph::{merkle_hash_subgraph, partition, PartitionWorkspace};
use puzzle::mem::TensorPool;
use puzzle::perf::PerfModel;
use puzzle::profiler::Profiler;
use puzzle::scenario::fuzz::{corpus as fuzz_corpus_of, FuzzConfig};
use puzzle::scenario::Scenario;
use puzzle::serve::{
    materialize_solutions, probe_seed, saturation_via_runtime, ClockMode, FaultPlan, LoadSpec,
    RuntimeHarness, SaturationOptions,
};
use puzzle::sim::{compile_plans, simulate, ExecutionPlan, GroupSpec, SimOptions, SimWorkspace};
use puzzle::util::bench::{bench, black_box, write_json, BenchStats};
use puzzle::util::rng::Rng;
use puzzle::util::threads::CoreBudget;
use puzzle::Processor;

fn main() {
    let mut all: Vec<BenchStats> = Vec::new();

    let pm = PerfModel::paper_calibrated();
    let comm = CommModel::paper_calibrated();
    let scenario = Scenario::from_groups("bench", &[vec![0, 4, 6], vec![1, 5, 8]]);
    let nets = &scenario.networks;
    let mut rng = Rng::seed_from_u64(1);
    let profiler = Profiler::new(&pm);

    // Pre-decode a plan set for the simulator bench.
    let genome = Genome::random(nets, 0.3, &mut rng);
    let plans = decode(nets, &genome, &profiler, &comm);
    let periods = scenario.periods(1.0, &pm);
    let groups: Vec<GroupSpec> = scenario
        .groups
        .iter()
        .zip(&periods)
        .map(|(g, &p)| GroupSpec::periodic(g.members.clone(), p))
        .collect();
    let opts = SimOptions { requests_per_group: 20, ..Default::default() };

    all.push(bench("sim/simulate_6models_20req", 3.0, 50, || {
        black_box(simulate(&plans, &groups, &comm, &opts));
    }));

    // Same workload, compiled once + workspace reused: the GA's actual
    // steady-state inner loop (zero allocation per call).
    let compiled = compile_plans(&plans);
    let mut ws = SimWorkspace::new();
    all.push(bench("sim/simulate_reused_workspace", 3.0, 50, || {
        ws.run(&plans, &compiled, &groups, &comm, &opts);
        black_box(ws.tasks_run());
    }));

    // Measurement tier at measure_reps = 8, per candidate: the legacy path
    // (clone plans, rewrite every task duration with sample() per rep) vs
    // the vectorized path (flatten nominals once, sample flat factors,
    // replay via run_with_durations). bench_guard asserts vectorized <=
    // naive as a same-run invariant.
    let reps = 8usize;
    let mut mt_rng = Rng::seed_from_u64(77);
    let mut mt_ws = SimWorkspace::new();
    let mut scratch_plans: Vec<ExecutionPlan> = Vec::new();
    all.push(bench("sim/measure_tier_naive_reps8", 3.0, 20, || {
        scratch_plans.clear();
        scratch_plans.extend(plans.iter().cloned());
        for _ in 0..reps {
            for (np, p) in scratch_plans.iter_mut().zip(&plans) {
                for (nt, t) in np.tasks.iter_mut().zip(&p.tasks) {
                    nt.duration = pm.sample(t.duration, t.processor, &mut mt_rng);
                }
            }
            mt_ws.run(&scratch_plans, &compiled, &groups, &comm, &opts);
        }
        black_box(mt_ws.tasks_run());
    }));
    let mut nominal: Vec<f64> = Vec::new();
    let mut procs: Vec<Processor> = Vec::new();
    let mut durs: Vec<f64> = Vec::new();
    all.push(bench("sim/measure_tier_vectorized_reps8", 3.0, 20, || {
        nominal.clear();
        procs.clear();
        for p in &plans {
            for t in &p.tasks {
                nominal.push(t.duration);
                procs.push(t.processor);
            }
        }
        durs.clear();
        durs.resize(nominal.len(), 0.0);
        for _ in 0..reps {
            for i in 0..nominal.len() {
                durs[i] = nominal[i] * pm.sample_factor(procs[i], &mut mt_rng);
            }
            mt_ws.run_with_durations(&plans, &compiled, &durs, &groups, &comm, &opts);
        }
        black_box(mt_ws.tasks_run());
    }));

    all.push(bench("ga/decode_genome(cached profiles)", 3.0, 50, || {
        black_box(decode(nets, &genome, &profiler, &comm));
    }));

    // Memoized decode: the re-evaluated-survivor path (elites, measure-tier
    // reps) that skips partition + profiling entirely.
    let plan_cache = DecodedPlanCache::new();
    let _ = plan_cache.decode(nets, &genome, &profiler, &comm); // prime
    all.push(bench("ga/decode_memoized", 3.0, 200, || {
        black_box(plan_cache.decode(nets, &genome, &profiler, &comm));
    }));

    all.push(bench("ga/decode_fresh_genome", 3.0, 30, || {
        let g = Genome::random(nets, 0.3, &mut rng);
        black_box(decode(nets, &g, &profiler, &comm));
    }));

    // Partition alone.
    let net = &nets[5]; // fastsam analog
    let cuts: Vec<bool> = (0..net.num_edges()).map(|i| i % 3 == 0).collect();
    let mapping: Vec<Processor> = (0..net.num_layers())
        .map(|i| Processor::from_index(i % 3))
        .collect();
    all.push(bench("graph/partition_17layer", 3.0, 200, || {
        black_box(partition(net, &cuts, &mapping));
    }));

    // Same partition through the reusable arena (the decode hot path);
    // bench_guard asserts workspace <= owned as a same-run invariant.
    let mut pws = PartitionWorkspace::new();
    pws.partition_into(net, &cuts, &mapping); // warm to the net's bounds
    all.push(bench("graph/partition_workspace_17layer", 3.0, 200, || {
        pws.partition_into(net, &cuts, &mapping);
        black_box(pws.num_subgraphs());
    }));

    let part = partition(net, &cuts, &mapping);
    all.push(bench("graph/merkle_hash", 3.0, 200, || {
        for sg in &part.subgraphs {
            black_box(merkle_hash_subgraph(net, sg));
        }
    }));

    // NSGA-III on a realistic pool.
    let objs: Vec<Vec<f64>> = (0..96)
        .map(|_| (0..4).map(|_| rng.gen_f64()).collect())
        .collect();
    all.push(bench("ga/nsga3_select_96to48_4obj", 3.0, 100, || {
        black_box(nsga3_select(&objs, 48));
    }));

    // Selection at the target scale: 1024-candidate pool (population 512
    // parents + children), 4 objectives. The O(n²) reference vs the ENS +
    // heap-niching workspace (bit-identical output); bench_guard asserts
    // ENS <= naive as a same-run invariant.
    let big_objs: Vec<Vec<f64>> = (0..1024)
        .map(|_| (0..4).map(|_| rng.gen_f64()).collect())
        .collect();
    let big_flat: Vec<f64> = big_objs.iter().flatten().copied().collect();
    all.push(bench("ga/naive_select_pop512", 5.0, 10, || {
        black_box(nsga3_select(&big_objs, 512));
    }));
    let mut sel_ws = SelectionWorkspace::new();
    let _ = sel_ws.select(&big_flat, 4, 512); // warm: the analyzer's steady state
    all.push(bench("ga/ens_select_pop512", 5.0, 10, || {
        black_box(sel_ws.select(&big_flat, 4, 512).len());
    }));

    // ENS degenerate shape: a 1024-candidate pool where *every* point is
    // mutually nondominated (constant objective sum: any all-≤ relation
    // with one strict < would force a smaller sum), so front sorting
    // collapses to one giant front — the O(n²) comparison worst case
    // late-convergence GA runs actually hit. Trajectory-only: measured so
    // the next selection optimization has its number on record.
    let single_front: Vec<Vec<f64>> = (0..1024)
        .map(|_| {
            let raw: Vec<f64> = (0..4).map(|_| rng.gen_f64() + 0.05).collect();
            let sum: f64 = raw.iter().sum();
            raw.into_iter().map(|x| x / sum).collect()
        })
        .collect();
    let single_flat: Vec<f64> = single_front.iter().flatten().copied().collect();
    let mut sf_ws = SelectionWorkspace::new();
    let _ = sf_ws.select(&single_flat, 4, 512); // warm
    all.push(bench("ga/ens_single_front_pop512", 5.0, 10, || {
        black_box(sf_ws.select(&single_flat, 4, 512).len());
    }));

    // Tensor pool.
    let pool = TensorPool::new(true);
    all.push(bench("mem/pool_acquire_release_16KiB", 2.0, 500, || {
        let t = pool.acquire(16 * 1024);
        black_box(t.len());
    }));

    // One full (tiny) analyzer run for an end-to-end feel (through the api
    // session layer, as external callers run it).
    let tiny = Scenario::from_groups("tiny", &[vec![0, 1]]);
    let cfg = GaConfig { population: 8, max_generations: 3, sim_requests: 8, measure_reps: 1, ..GaConfig::quick(3) };
    let tiny_session = SessionBuilder::for_scenario(tiny)
        .perf_model(pm.clone())
        .config(cfg)
        .build()
        .expect("valid scenario");
    all.push(bench("analyzer/tiny_ga_run", 5.0, 3, || {
        black_box(tiny_session.run());
    }));

    // The headline before/after pair: one full GA generation at population
    // 96 (init evaluation + offspring evaluation + local search + measure
    // tier), serial (threads = 1) vs parallel (threads = cores). The
    // acceptance bar for the batch evaluation engine is >= 2x on a
    // multi-core runner.
    let gen_scenario = Scenario::from_groups("gen96", &[vec![0, 4, 6], vec![1, 5, 8]]);
    let gen_cfg = |threads: usize| GaConfig {
        population: 96,
        max_generations: 1,
        patience: 1,
        sim_requests: 8,
        measure_reps: 1,
        seed: 5,
        threads,
        ..Default::default()
    };
    let gen_session = |threads: usize| {
        SessionBuilder::for_scenario(gen_scenario.clone())
            .perf_model(pm.clone())
            .config(gen_cfg(threads))
            .build()
            .expect("valid scenario")
    };
    let serial_session = gen_session(1);
    let parallel_session = gen_session(0);
    let serial = bench("analyzer/serial_generation", 8.0, 3, || {
        black_box(serial_session.run());
    });
    let parallel = bench("analyzer/parallel_generation", 8.0, 3, || {
        black_box(parallel_session.run());
    });
    println!(
        "analyzer/parallel_generation speedup over serial: {:.2}x ({} logical cores)",
        serial.mean_s / parallel.mean_s,
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
    all.push(serial);
    all.push(parallel);

    // Offspring generation at scale: one full generation at population 256
    // with local search + measurement tier on. Since breeding moved into
    // the fan-out, threads = 0 parallelizes crossover/mutation too;
    // bench_guard asserts fan-out <= serial as a same-run invariant.
    let off_scenario = Scenario::from_groups("off256", &[vec![0, 4, 6], vec![1, 5, 8]]);
    let off_cfg = |threads: usize| GaConfig {
        population: 256,
        max_generations: 1,
        patience: 1,
        sim_requests: 6,
        measure_reps: 1,
        seed: 11,
        threads,
        ..Default::default()
    };
    let off_session = |threads: usize| {
        SessionBuilder::for_scenario(off_scenario.clone())
            .perf_model(pm.clone())
            .config(off_cfg(threads))
            .build()
            .expect("valid scenario")
    };
    let off_serial_session = off_session(1);
    let off_fanout_session = off_session(0);
    let off_serial = bench("analyzer/offspring_serial", 10.0, 2, || {
        black_box(off_serial_session.run());
    });
    let off_fanout = bench("analyzer/offspring_fanout", 10.0, 2, || {
        black_box(off_fanout_session.run());
    });
    println!(
        "analyzer/offspring_fanout speedup over serial: {:.2}x",
        off_serial.mean_s / off_fanout.mean_s
    );
    all.push(off_serial);
    all.push(off_fanout);

    // Arrival-driven load tests through the real Coordinator/Worker stack:
    // the virtual-clock event loop (deterministic, engine never sleeps) vs
    // the wall-clock driver (engine sleeps at time scale 1.0). bench_guard
    // asserts virtual <= wall as a same-run invariant — the virtual clock's
    // whole point is replaying a schedule faster than real time.
    let lt_scenario = puzzle::scenario::Scenario::from_groups("loadtest", &[vec![0, 1]]);
    let lt_genome = puzzle::ga::Genome::all_on(&lt_scenario.networks, Processor::Npu);
    let lt_perf = std::sync::Arc::new(pm.clone());
    let lt_periods = lt_scenario.periods(1.2, &pm);
    let mut lt_virtual = RuntimeHarness::for_genome(&lt_scenario, &lt_genome, &lt_perf, 7);
    lt_virtual.noisy = false;
    let virtual_spec = LoadSpec::periodic(&lt_periods, 10);
    all.push(bench("serve/loadtest_virtual_clock", 3.0, 20, || {
        black_box(lt_virtual.run(&virtual_spec).served);
    }));
    let mut lt_wall = lt_virtual.clone();
    lt_wall.time_scale = 1.0;
    let wall_spec = LoadSpec::periodic(&lt_periods, 10).wall(std::time::Duration::from_secs(10));
    all.push(bench("serve/loadtest_wall_clock", 3.0, 5, || {
        black_box(lt_wall.run(&wall_spec).served);
    }));

    // Zero-overhead contract of the fault-injection layer: the same warm
    // virtual-clock probe through the plain engine vs an empty-plan
    // FaultyEngine with the watchdog/recovery machinery armed. Probes are
    // bit-identical (tested in serve_runtime); bench_guard asserts
    // chaos-off <= plain × 1.05 as a same-run invariant — an empty plan
    // must cost one branch per task, not a measurable slowdown.
    let mut lt_plain_dep = lt_virtual.deploy(ClockMode::Virtual);
    all.push(bench("serve/loadtest_plain", 3.0, 20, || {
        black_box(lt_plain_dep.probe(&virtual_spec, 7).served);
    }));
    lt_plain_dep.shutdown();
    let lt_chaos_off = lt_virtual.clone().with_fault_plan(FaultPlan::default());
    let mut lt_chaos_dep = lt_chaos_off.deploy(ClockMode::Virtual);
    all.push(bench("serve/loadtest_chaos_off", 3.0, 20, || {
        black_box(lt_chaos_dep.probe(&virtual_spec, 7).served);
    }));
    lt_chaos_dep.shutdown();

    // Zero-overhead contract of the telemetry plane: the same warm
    // virtual-clock probe with no subscriber (disarmed — one relaxed atomic
    // load per would-be event) vs an armed subscriber drained after every
    // probe. bench_guard asserts telemetry-off <= plain × 1.05 as a
    // same-run invariant; the subscriber bench is reported for the
    // trajectory but unguarded (publishing real events has a real cost).
    let mut lt_tel_off_dep = lt_virtual.deploy(ClockMode::Virtual);
    all.push(bench("serve/loadtest_telemetry_off", 3.0, 20, || {
        black_box(lt_tel_off_dep.probe(&virtual_spec, 7).served);
    }));
    lt_tel_off_dep.shutdown();
    let mut lt_tel_sub_dep = lt_virtual.deploy(ClockMode::Virtual);
    let mut lt_tel_rx = lt_tel_sub_dep.subscribe();
    all.push(bench("serve/loadtest_telemetry_sub", 3.0, 20, || {
        black_box(lt_tel_sub_dep.probe(&virtual_spec, 7).served);
        black_box(lt_tel_rx.drain().len());
    }));
    drop(lt_tel_rx);
    lt_tel_sub_dep.shutdown();

    // Saturation-probe deployment reuse: the same four α-probes, paying a
    // fresh Coordinator/Worker stack (~6 threads) per probe vs one warm
    // deployment reset between probes. Probes are bit-identical either way
    // (tested in serve_runtime); bench_guard asserts reused <= fresh as a
    // same-run invariant — the whole point of probe reuse.
    let sat_alphas = [2.0, 3.0, 4.0, 5.0];
    let sat_specs: Vec<LoadSpec> = sat_alphas
        .iter()
        .map(|&a| LoadSpec::periodic(&lt_scenario.periods(a, &pm), 8))
        .collect();
    let sat_harness = RuntimeHarness::for_genome(&lt_scenario, &lt_genome, &lt_perf, 7);
    all.push(bench("serve/saturation_fresh_deploys", 3.0, 10, || {
        for (&a, spec) in sat_alphas.iter().zip(&sat_specs) {
            let mut h = sat_harness.clone();
            h.seed = probe_seed(7, 0, a);
            black_box(h.run(spec).served);
        }
    }));
    all.push(bench("serve/saturation_reused_deploy", 3.0, 10, || {
        let mut warm = sat_harness.deploy(ClockMode::Virtual);
        for (&a, spec) in sat_alphas.iter().zip(&sat_specs) {
            black_box(warm.probe(spec, probe_seed(7, 0, a)).served);
        }
        warm.shutdown();
    }));

    // Saturation probe fleet: the full multi-set bisection search, serial
    // (probe_threads = 1) vs the scoped fleet (probe_threads = 0, all
    // cores). Identical probe schedule and bit-identical results either
    // way (tested in serve_runtime); bench_guard asserts fleet <= serial ×
    // 1.05 as a same-run invariant — parallel probing must never cost
    // wall-clock, and on multi-core hosts it should approach a
    // sets-per-core speedup.
    let fleet_sets: Vec<Vec<puzzle::serve::NetworkSolution>> = [
        Processor::Npu,
        Processor::Gpu,
        Processor::Npu,
        Processor::Gpu,
    ]
    .iter()
    .enumerate()
    .map(|(i, &p)| {
        let mut genome = puzzle::ga::Genome::all_on(&lt_scenario.networks, p);
        if i >= 2 {
            genome.priority.reverse();
        }
        materialize_solutions(&lt_scenario.networks, &genome, &lt_perf)
    })
    .collect();
    let fleet_opts = |probe_threads: usize| SaturationOptions {
        requests: 6,
        tolerance: 0.2,
        probe_threads,
        ..Default::default()
    };
    let sat_serial = bench("serve/saturation_serial", 4.0, 3, || {
        black_box(saturation_via_runtime(&fleet_sets, &lt_scenario, &lt_perf, &fleet_opts(1)));
    });
    let sat_fleet = bench("serve/saturation_fleet", 4.0, 3, || {
        black_box(saturation_via_runtime(&fleet_sets, &lt_scenario, &lt_perf, &fleet_opts(0)));
    });
    println!(
        "serve/saturation_fleet speedup over serial: {:.2}x ({} sets, {} logical cores)",
        sat_serial.mean_s / sat_fleet.mean_s,
        fleet_sets.len(),
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
    all.push(sat_serial);
    all.push(sat_fleet);

    // Imbalanced protocol: one giant scenario plus several one-network
    // scenarios. The static two-level rule pins each shard worker's inner
    // fan-out to a single thread, so after the small jobs drain the giant
    // job limps along on one core while the rest idle. The shared
    // CoreBudget lets retiring workers return their slots and the giant
    // job's GA fan-out / probe fleet reclaim them at the next generation
    // or α-probe. Bit-identical rows either way (tested in serving);
    // bench_guard asserts budgeted <= static × 1.05 as a same-run
    // invariant — dynamic reclamation must never cost wall-clock.
    let imbalanced = vec![
        Scenario::from_groups("giant", &[vec![0, 4, 6], vec![1, 5, 8]]),
        Scenario::from_groups("small-a", &[vec![0]]),
        Scenario::from_groups("small-b", &[vec![1]]),
        Scenario::from_groups("small-c", &[vec![2]]),
    ];
    let proto_budget = |threads: usize, core: Option<CoreBudget>| ServingBudget {
        sim_requests: 6,
        scenarios: 4,
        protocol_threads: threads,
        core_budget: core,
        ..ServingBudget::quick()
    };
    let proto_serial = bench("serve/protocol_serial", 10.0, 2, || {
        black_box(saturation_protocol(&imbalanced, &pm, &proto_budget(1, None)).len());
    });
    let proto_static = bench("serve/protocol_static_shard", 10.0, 2, || {
        black_box(saturation_protocol(&imbalanced, &pm, &proto_budget(0, None)).len());
    });
    let proto_budgeted = bench("serve/protocol_budgeted_shard", 10.0, 2, || {
        black_box(
            saturation_protocol(&imbalanced, &pm, &proto_budget(0, Some(CoreBudget::new(0))))
                .len(),
        );
    });
    println!(
        "serve/protocol_budgeted_shard speedup: {:.2}x over serial, {:.2}x over static shard",
        proto_serial.mean_s / proto_budgeted.mean_s,
        proto_static.mean_s / proto_budgeted.mean_s,
    );
    all.push(proto_serial);
    all.push(proto_static);
    all.push(proto_budgeted);

    // Fuzz-corpus runner: 16-group fuzzed scenarios through the warm
    // runtime with envelope checks, serial (probe_threads = 1) vs the
    // scoped case fleet (probe_threads = 0, all cores). Bit-identical
    // outcomes either way (tested in fuzz_envelope); bench_guard asserts
    // fleet <= serial × 1.05 as a same-run invariant.
    let fuzz_perf = std::sync::Arc::new(pm.clone());
    let fuzz_config = FuzzConfig {
        groups: (16, 16),
        members: (1, 1),
        requests: (2, 4),
        generated_prob: 0.0,
        ..FuzzConfig::default()
    };
    let fuzz_corpus = fuzz_corpus_of(13, 6, &fuzz_config, &fuzz_perf);
    let fuzz_opts = |probe_threads: usize| FuzzOptions { probe_threads, ..Default::default() };
    let fuzz_serial = bench("fuzz/corpus_16_groups_serial", 4.0, 2, || {
        black_box(run_fuzz_corpus(&fuzz_corpus, &fuzz_perf, &fuzz_opts(1)).len());
    });
    let fuzz_fleet = bench("fuzz/corpus_16_groups_fleet", 4.0, 2, || {
        black_box(run_fuzz_corpus(&fuzz_corpus, &fuzz_perf, &fuzz_opts(0)).len());
    });
    println!(
        "fuzz/corpus_16_groups_fleet speedup over serial: {:.2}x ({} cases)",
        fuzz_serial.mean_s / fuzz_fleet.mean_s,
        fuzz_corpus.len(),
    );
    all.push(fuzz_serial);
    all.push(fuzz_fleet);

    // Machine-readable trajectory for future PRs.
    let json_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_hotpaths.json");
    match write_json(&json_path, &all) {
        Ok(()) => println!("wrote {}", json_path.display()),
        Err(e) => {
            // A silent write failure would let the CI bench guard compare a
            // stale file against itself — fail loudly instead.
            eprintln!("could not write {}: {e}", json_path.display());
            std::process::exit(1);
        }
    }
}
