//! Bench: regenerate Figure 5 (RPC overhead microbenchmark + piecewise-
//! linear regression) on this host, plus the STREAM bandwidth probe.

use puzzle::comm;
use puzzle::util::bench::bench;

fn main() {
    println!("=== Fig 5 reproduction: RPC overhead microbenchmark ===");
    let sizes = comm::default_size_sweep();
    let samples = comm::rpc_microbenchmark(&sizes, 9);
    let fit = comm::PiecewiseLinear::fit(&samples, comm::KNEE_BYTES);
    println!("{:>12} {:>14} {:>14}", "bytes", "measured (us)", "fit (us)");
    for s in &samples {
        println!(
            "{:>12} {:>14.2} {:>14.2}",
            s.bytes,
            s.seconds * 1e6,
            fit.predict(s.bytes as f64) * 1e6
        );
    }
    println!(
        "fit: below {:.2}us + {:.4}ns/B | above {:.2}us + {:.4}ns/B | r2 {:.4}",
        fit.below_intercept * 1e6,
        fit.below_slope * 1e9,
        fit.above_intercept * 1e6,
        fit.above_slope * 1e9,
        fit.r_squared(&samples)
    );
    let bw = comm::stream_bandwidth(32 << 20, 5);
    println!("STREAM copy bandwidth: {:.1} GB/s (paper device ~40 GB/s)", bw / 1e9);
    println!();
    bench("fig5/microbench_1MiB", 2.0, 20, || {
        let _ = comm::rpc_microbenchmark(&[1 << 20], 3);
    });
}
