//! Integration tests of the `puzzle::api` session layer: the full
//! analyze → deploy → serve flow, observer streaming, plan-set sharing
//! across the Pareto front, and the versioned save/load hand-off.

use std::sync::Arc;
use std::time::Duration;

use puzzle::analyzer::GaConfig;
use puzzle::api::{GenerationProgress, RuntimeOptions, ScenarioSpec, SessionBuilder};

fn quick_session(seed: u64) -> puzzle::api::AnalysisSession {
    SessionBuilder::new(ScenarioSpec::single_group("api", vec![0, 2]))
        .config(GaConfig::quick(seed))
        .build()
        .expect("valid spec")
}

#[test]
fn observer_streams_generation_progress() {
    let session = quick_session(3);
    let mut generations: Vec<usize> = Vec::new();
    let mut evaluations: Vec<usize> = Vec::new();
    let analysis = session.run_observed(&mut |p: &GenerationProgress<'_>| {
        generations.push(p.generation);
        evaluations.push(p.evaluations);
        assert!(!p.best_objectives.is_empty(), "best solution always exists");
        assert!(p.avg_aggregate.is_finite() && p.avg_aggregate > 0.0);
        assert!((0.0..=1.0).contains(&p.plan_cache_hit_rate()));
        assert!((0.0..=1.0).contains(&p.profile_cache_hit_rate()));
    });
    // Generation 0 (initial population) plus one event per GA generation.
    assert_eq!(generations.len(), analysis.generations_run + 1);
    assert_eq!(generations, (0..=analysis.generations_run).collect::<Vec<_>>());
    // Evaluations are cumulative and end at the reported total.
    assert!(evaluations.windows(2).all(|w| w[0] <= w[1]));
    assert_eq!(*evaluations.last().unwrap(), analysis.evaluations);
}

#[test]
fn run_and_run_observed_agree() {
    let a = quick_session(7).run();
    let b = quick_session(7).run_observed(&mut |_: &GenerationProgress<'_>| {});
    let sig = |x: &puzzle::api::Analysis| -> Vec<Vec<f64>> {
        x.pareto.iter().map(|s| s.objectives.clone()).collect()
    };
    assert_eq!(sig(&a), sig(&b), "observation must not perturb the search");
    assert_eq!(a.evaluations, b.evaluations);
}

#[test]
fn pareto_solutions_share_plan_sets() {
    let analysis = quick_session(11).run();
    for sol in &analysis.pareto {
        assert_eq!(
            sol.plans().len(),
            analysis.scenario().networks.len(),
            "one plan per network"
        );
        // Cloning a Solution (the archive/deployment hand-off operation)
        // must share the plan set, not re-wrap or deep-copy it.
        let cloned = sol.clone();
        assert!(
            Arc::ptr_eq(&cloned.plan_set, &sol.plan_set),
            "Solution::clone re-created its plan set"
        );
    }
    // Entries with distinct genomes must not alias each other's plans.
    // (Identical genomes *usually* share one memoized decode, but two
    // threads racing the first decode may legitimately hold separate Arcs,
    // so no assertion in that direction.)
    for a in &analysis.pareto {
        for b in &analysis.pareto {
            if a.genome != b.genome {
                assert!(!Arc::ptr_eq(&a.plan_set, &b.plan_set), "distinct genomes share plans");
            }
        }
    }
}

#[test]
fn save_load_deploy_roundtrip() {
    let session = quick_session(13);
    let analysis = session.run();
    let dir = std::env::temp_dir().join("puzzle_api_roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("pareto.txt");
    analysis.save(&path).unwrap();

    // A fresh session (same spec) loads the file back into a deployable
    // Analysis with identical genomes and objectives.
    let session2 = quick_session(99); // GA seed is irrelevant for loading
    let loaded = session2.load_solutions(&path).unwrap();
    assert_eq!(loaded.pareto.len(), analysis.pareto.len());
    for (a, b) in analysis.pareto.iter().zip(&loaded.pareto) {
        assert_eq!(a.genome, b.genome);
        assert_eq!(a.objectives, b.objectives);
        // Plans re-decoded at load time must match the originals (the
        // profiler is deterministic).
        assert_eq!(a.plans(), b.plans());
    }

    let mut deployment = loaded
        .deploy_sim(loaded.best_index(), RuntimeOptions::default(), 0.0, false, 1)
        .unwrap();
    let served = deployment.serve(0, 4, Duration::from_secs(10));
    assert_eq!(served, 4);
    deployment.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn deploy_rejects_bad_solution_index() {
    let analysis = quick_session(17).run();
    let err = analysis
        .deploy(analysis.pareto.len() + 3, RuntimeOptions::default())
        .unwrap_err();
    assert!(err.to_string().contains("out of range"), "{err}");
}
