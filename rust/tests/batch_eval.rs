//! Equivalence and allocation guarantees of the batch evaluation pipeline
//! (parallel GA scoring + reusable `SimWorkspace` + decode memoization +
//! `Arc<PlanSet>`-shared solutions):
//!
//! 1. parallel batch evaluation is **bit-identical** to the serial path for
//!    several seeds (objectives, Pareto genomes, evaluation counts);
//! 2. a reused workspace reproduces fresh-allocation `simulate()` exactly;
//! 3. steady-state workspace simulation performs **zero** heap allocation
//!    (asserted against the counting global allocator);
//! 4. the genome→plan memo returns plans identical to a fresh decode;
//! 5. the operations Pareto bookkeeping is built from — moving `Solution`s
//!    between buffers and cloning their plan handles — are plan-copy-free:
//!    plans are `Arc`-shared, never deep-cloned. (The replacement step's
//!    selection scratch still allocates per generation; that belongs to the
//!    NSGA-III ROADMAP item.)

use std::sync::Arc;

use puzzle::analyzer::{GaConfig, Solution};
use puzzle::api::{Analysis, SessionBuilder};
use puzzle::comm::CommModel;
use puzzle::ga::{decode, DecodedPlanCache, Genome, PlanSet};
use puzzle::perf::PerfModel;
use puzzle::profiler::Profiler;
use puzzle::scenario::Scenario;
use puzzle::sim::{
    compile_plans, simulate, ArrivalPattern, GroupSpec, SimOptions, SimWorkspace,
};
use puzzle::util::rng::Rng;

fn quick_cfg(seed: u64, threads: usize) -> GaConfig {
    GaConfig {
        population: 16,
        max_generations: 6,
        sim_requests: 8,
        measure_reps: 2,
        threads,
        ..GaConfig::quick(seed)
    }
}

fn run_session(scenario: &Scenario, pm: &PerfModel, cfg: GaConfig) -> Analysis {
    SessionBuilder::for_scenario(scenario.clone())
        .perf_model(pm.clone())
        .config(cfg)
        .build()
        .expect("valid scenario")
        .run()
}

fn pareto_signature(r: &Analysis) -> Vec<(Vec<f64>, Genome)> {
    r.pareto
        .iter()
        .map(|s| (s.objectives.clone(), s.genome.clone()))
        .collect()
}

#[test]
fn deterministic_across_thread_counts() {
    // The tentpole contract: identical results whatever the thread count,
    // including threads = 1 (the serial path). Cache hit/miss *counters*
    // may differ under racing; search output never does.
    let scenario = Scenario::from_groups("par", &[vec![0, 1, 6]]);
    let pm = PerfModel::paper_calibrated();
    for seed in [1u64, 5, 9] {
        let serial = run_session(&scenario, &pm, quick_cfg(seed, 1));
        let par2 = run_session(&scenario, &pm, quick_cfg(seed, 2));
        let par4 = run_session(&scenario, &pm, quick_cfg(seed, 4));
        assert_eq!(serial.generations_run, par4.generations_run, "seed {seed}");
        assert_eq!(serial.evaluations, par2.evaluations, "seed {seed}");
        assert_eq!(serial.evaluations, par4.evaluations, "seed {seed}");
        let sig = pareto_signature(&serial);
        assert_eq!(sig, pareto_signature(&par2), "seed {seed}: 2 threads diverged");
        assert_eq!(sig, pareto_signature(&par4), "seed {seed}: 4 threads diverged");
    }
}

#[test]
fn reused_workspace_matches_fresh_simulate_exactly() {
    // One workspace reused across many different plan sets must reproduce
    // fresh-allocation simulate() bit-for-bit each time.
    let scenario = Scenario::from_groups("ws", &[vec![0, 4], vec![1, 6]]);
    let pm = PerfModel::paper_calibrated();
    let comm = CommModel::paper_calibrated();
    let profiler = Profiler::new(&pm);
    let periods = scenario.periods(1.0, &pm);
    let groups: Vec<GroupSpec> = scenario
        .groups
        .iter()
        .zip(&periods)
        .map(|(g, &p)| GroupSpec::periodic(g.members.clone(), p))
        .collect();
    let opts = SimOptions { requests_per_group: 12, ..Default::default() };

    let mut rng = Rng::seed_from_u64(77);
    let mut ws = SimWorkspace::new();
    for _ in 0..8 {
        let genome = Genome::random(&scenario.networks, 0.4, &mut rng);
        let plans = decode(&scenario.networks, &genome, &profiler, &comm);
        let fresh = simulate(&plans, &groups, &comm, &opts);

        let compiled = compile_plans(&plans);
        ws.run(&plans, &compiled, &groups, &comm, &opts);
        let reused = ws.to_result();

        assert_eq!(fresh.makespans, reused.makespans, "makespans diverged");
        assert_eq!(fresh.busy, reused.busy, "busy time diverged");
        assert_eq!(fresh.span, reused.span, "span diverged");
        assert_eq!(fresh.tasks_run, reused.tasks_run, "task count diverged");
        for g in 0..groups.len() {
            assert_eq!(fresh.avg_makespan(g), ws.avg_makespan(g));
            assert_eq!(fresh.p90_makespan(g), ws.p90_makespan(g));
        }
    }
}

#[test]
fn steady_state_simulation_is_allocation_free() {
    // After one warm-up run, re-running the same workload through the
    // workspace — event loop, Poisson arrival generation, objective
    // extraction — must not allocate at all. Uses the per-thread counter of
    // the crate's counting global allocator, so concurrent test threads
    // cannot flake this.
    let scenario = Scenario::from_groups("alloc", &[vec![0, 1, 6]]);
    let pm = PerfModel::paper_calibrated();
    let comm = CommModel::paper_calibrated();
    let profiler = Profiler::new(&pm);
    let mut rng = Rng::seed_from_u64(3);
    let genome = Genome::random(&scenario.networks, 0.4, &mut rng);
    let plans = decode(&scenario.networks, &genome, &profiler, &comm);
    let compiled = compile_plans(&plans);
    let periods = scenario.periods(1.0, &pm);
    // One periodic group plus a Poisson group exercises both arrival paths.
    let groups = vec![
        GroupSpec::periodic(vec![0, 1], periods[0]),
        GroupSpec {
            networks: vec![2],
            period: periods[0],
            pattern: ArrivalPattern::Poisson { seed: 11 },
        },
    ];
    let opts = SimOptions { requests_per_group: 16, ..Default::default() };

    let mut ws = SimWorkspace::new();
    let mut objectives: Vec<f64> = Vec::new();
    // Warm-up: buffers grow to steady-state capacity.
    ws.run(&plans, &compiled, &groups, &comm, &opts);
    ws.objectives_into(&mut objectives);
    let warm = objectives.clone();

    let before = puzzle::util::alloc::thread_allocations();
    for _ in 0..5 {
        ws.run(&plans, &compiled, &groups, &comm, &opts);
        ws.objectives_into(&mut objectives);
    }
    let after = puzzle::util::alloc::thread_allocations();
    assert_eq!(
        after - before,
        0,
        "steady-state workspace simulation allocated {} times",
        after - before
    );
    assert_eq!(warm, objectives, "steady-state result drifted");
}

#[test]
fn memoized_decode_equals_fresh_decode() {
    let scenario = Scenario::from_groups("memo", &[vec![0, 2, 6]]);
    let pm = PerfModel::paper_calibrated();
    let comm = CommModel::paper_calibrated();
    let profiler = Profiler::new(&pm);
    let cache = DecodedPlanCache::new();
    let mut rng = Rng::seed_from_u64(21);
    for _ in 0..10 {
        let genome = Genome::random(&scenario.networks, 0.3, &mut rng);
        let first = cache.decode(&scenario.networks, &genome, &profiler, &comm);
        let second = cache.decode(&scenario.networks, &genome, &profiler, &comm);
        assert!(
            std::sync::Arc::ptr_eq(&first, &second),
            "re-decode of an identical genome must hit the memo"
        );
        let fresh = decode(&scenario.networks, &genome, &profiler, &comm);
        assert_eq!(first.plans, fresh, "memoized plans diverge from decode()");
    }
    let (hits, misses) = cache.stats();
    assert_eq!((hits, misses), (10, 10));
}

#[test]
fn plan_memo_reports_hits_in_full_search() {
    // End-to-end: a real search re-proposes genomes (elites, crossover
    // clones), so the memo must land hits and the analyzer must report them.
    let scenario = Scenario::from_groups("memo2", &[vec![0, 1]]);
    let pm = PerfModel::paper_calibrated();
    let r = run_session(&scenario, &pm, quick_cfg(4, 1));
    assert!(r.plan_cache_misses > 0);
    assert!(
        r.plan_cache_hits > 0,
        "no memo reuse across {} evaluations",
        r.evaluations
    );
}

/// Build a handful of solutions sharing plan sets, as the analyzer's
/// replacement step sees them.
fn sharing_solutions(n: usize) -> Vec<Solution> {
    let scenario = Scenario::from_groups("share", &[vec![0, 2, 6]]);
    let pm = PerfModel::paper_calibrated();
    let comm = CommModel::paper_calibrated();
    let profiler = Profiler::new(&pm);
    let mut rng = Rng::seed_from_u64(41);
    let genome = Genome::random(&scenario.networks, 0.3, &mut rng);
    let plans = decode(&scenario.networks, &genome, &profiler, &comm);
    let compiled = compile_plans(&plans);
    let set = Arc::new(PlanSet { plans, compiled });
    (0..n)
        .map(|i| Solution {
            genome: genome.clone(),
            objectives: vec![i as f64, (i * 2) as f64],
            plan_set: set.clone(),
        })
        .collect()
}

#[test]
fn solution_clone_never_copies_plans() {
    // Cloning a solution's plan handle is a pure Arc bump: zero heap
    // allocations (the pre-Arc representation deep-cloned every
    // ExecutionPlan here).
    let sols = sharing_solutions(2);
    let before = puzzle::util::alloc::thread_allocations();
    let handle = sols[0].plan_set.clone();
    let after = puzzle::util::alloc::thread_allocations();
    assert_eq!(after - before, 0, "Arc clone of the plan set allocated");
    assert!(Arc::ptr_eq(&handle, &sols[1].plan_set), "clones share one plan set");

    // A full Solution clone pays only for genome + objectives — its cost is
    // independent of the plan set entirely (same genome, plan sets of very
    // different sizes ⇒ identical allocation counts).
    let small = Solution { plan_set: Arc::new(PlanSet { plans: vec![], compiled: vec![] }), ..sols[0].clone() };
    let b1 = puzzle::util::alloc::thread_allocations();
    let _c1 = sols[0].clone();
    let mid = puzzle::util::alloc::thread_allocations();
    let _c2 = small.clone();
    let b2 = puzzle::util::alloc::thread_allocations();
    assert_eq!(mid - b1, b2 - mid, "clone cost depends on plan-set size");
}

#[test]
fn solution_moves_are_allocation_free() {
    // The primitive the replacement step's retention is built on: moving
    // `Solution`s between preallocated buffers allocates nothing, and plan
    // sets stay shared. With the old owned `Vec<ExecutionPlan>`
    // representation, every survivor carried (and on clone, copied) its
    // whole plan vector through this churn.
    let n = 16;
    let mut pool = sharing_solutions(n);
    let mut kept: Vec<Solution> = Vec::with_capacity(n);
    // Warm-up one full cycle so both buffers reach capacity.
    kept.extend(pool.drain(..));
    pool.extend(kept.drain(..));

    let before = puzzle::util::alloc::thread_allocations();
    for _ in 0..100 {
        kept.extend(pool.drain(..));
        pool.extend(kept.drain(..));
    }
    let after = puzzle::util::alloc::thread_allocations();
    assert_eq!(after - before, 0, "survivor retention allocated");
    // Sharing survived the churn.
    assert!(Arc::ptr_eq(&pool[0].plan_set, &pool[n - 1].plan_set));
}
