//! Equivalence and allocation guarantees of the batch evaluation pipeline
//! (parallel GA scoring with offspring-in-fan-out + reusable `SimWorkspace`
//! / `DecodeScratch` / `SelectionWorkspace` + decode memoization +
//! `Arc<PlanSet>`-shared solutions):
//!
//! 1. full searches — offspring breeding included, since reproduction runs
//!    inside the fan-out — are **bit-identical** across thread counts
//!    (1, 2, 4, 8) for several seeds (objectives, Pareto genomes,
//!    evaluation counts);
//! 2. a reused workspace reproduces fresh-allocation `simulate()` exactly;
//! 3. steady-state workspace simulation performs **zero** heap allocation
//!    (asserted against the counting global allocator);
//! 4. the genome→plan memo returns plans identical to a fresh decode, and
//!    its **hit path is allocation-free**;
//! 5. ENS selection at population 512 performs zero steady-state heap
//!    allocation, and memo-miss decode through a warmed `DecodeScratch`
//!    allocates only for its output (strictly less than a cold decode);
//! 6. the vectorized measurement tier (flat noise factors +
//!    `run_with_durations`) is bit-identical to the per-task plan-rewriting
//!    path it replaced;
//! 7. the operations Pareto bookkeeping is built from — moving `Solution`s
//!    between buffers and cloning their plan handles — are plan-copy-free:
//!    plans are `Arc`-shared, never deep-cloned.

use std::sync::Arc;

use puzzle::analyzer::{GaConfig, Solution};
use puzzle::api::{Analysis, SessionBuilder};
use puzzle::comm::CommModel;
use puzzle::ga::{
    decode, decode_with, nsga3_select, DecodeScratch, DecodedPlanCache, Genome, PlanSet,
    SelectionWorkspace,
};
use puzzle::perf::PerfModel;
use puzzle::profiler::Profiler;
use puzzle::scenario::Scenario;
use puzzle::sim::{
    compile_plans, simulate, ArrivalPattern, GroupSpec, SimOptions, SimWorkspace,
};
use puzzle::util::rng::Rng;
use puzzle::Processor;

fn quick_cfg(seed: u64, threads: usize) -> GaConfig {
    GaConfig {
        population: 16,
        max_generations: 6,
        sim_requests: 8,
        measure_reps: 2,
        threads,
        ..GaConfig::quick(seed)
    }
}

fn run_session(scenario: &Scenario, pm: &PerfModel, cfg: GaConfig) -> Analysis {
    SessionBuilder::for_scenario(scenario.clone())
        .perf_model(pm.clone())
        .config(cfg)
        .build()
        .expect("valid scenario")
        .run()
}

fn pareto_signature(r: &Analysis) -> Vec<(Vec<f64>, Genome)> {
    r.pareto
        .iter()
        .map(|s| (s.objectives.clone(), s.genome.clone()))
        .collect()
}

#[test]
fn deterministic_across_thread_counts() {
    // The tentpole contract: identical results whatever the thread count,
    // including threads = 1 (the serial path). Since this PR, *offspring
    // generation* (clone → crossover → mutation) also runs inside the
    // fan-out on per-pair derived seeds, so this covers breeding as well as
    // scoring. Cache hit/miss *counters* may differ under racing; search
    // output never does.
    let scenario = Scenario::from_groups("par", &[vec![0, 1, 6]]);
    let pm = PerfModel::paper_calibrated();
    for seed in [1u64, 5, 9] {
        let serial = run_session(&scenario, &pm, quick_cfg(seed, 1));
        let sig = pareto_signature(&serial);
        for threads in [2usize, 4, 8] {
            let par = run_session(&scenario, &pm, quick_cfg(seed, threads));
            assert_eq!(serial.generations_run, par.generations_run, "seed {seed}");
            assert_eq!(serial.evaluations, par.evaluations, "seed {seed}");
            assert_eq!(
                sig,
                pareto_signature(&par),
                "seed {seed}: {threads} threads diverged"
            );
        }
    }
}

#[test]
fn deterministic_under_core_budget_leases() {
    // The core-budget extension of the thread-invariance contract: a GA
    // whose fan-out leases its width per generation from a shared
    // CoreBudget — any capacity, with the static `threads` knob
    // superseded — reproduces the serial search bit-for-bit. Two
    // sessions sharing ONE budget concurrently also both reproduce it
    // (the semaphore changes scheduling, never results).
    use puzzle::util::threads::CoreBudget;
    let scenario = Scenario::from_groups("budget", &[vec![0, 1, 6]]);
    let pm = PerfModel::paper_calibrated();
    let serial = run_session(&scenario, &pm, quick_cfg(7, 1));
    let sig = pareto_signature(&serial);
    for (capacity, threads) in [(1usize, 8usize), (2, 0), (4, 1), (8, 2)] {
        let mut cfg = quick_cfg(7, threads);
        cfg.core_budget = Some(CoreBudget::new(capacity));
        let par = run_session(&scenario, &pm, cfg);
        assert_eq!(serial.evaluations, par.evaluations, "capacity {capacity}");
        assert_eq!(sig, pareto_signature(&par), "capacity {capacity} diverged");
    }
    // Contention: two concurrent sessions on one 3-slot budget.
    let shared = CoreBudget::new(3);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let shared = shared.clone();
                let (scenario, pm) = (&scenario, &pm);
                scope.spawn(move || {
                    let mut cfg = quick_cfg(7, 0);
                    cfg.core_budget = Some(shared);
                    run_session(scenario, pm, cfg)
                })
            })
            .collect();
        for h in handles {
            let par = h.join().expect("budgeted session panicked");
            assert_eq!(sig, pareto_signature(&par), "shared-budget session diverged");
        }
    });
    assert_eq!(shared.available(), 3, "every generation lease was returned");
}

#[test]
fn offspring_fanout_deterministic_with_odd_population() {
    // An odd population exercises the surplus-child truncation (the last
    // pair emits only one child); results must still be thread-count
    // independent and the population must hold its size.
    let scenario = Scenario::from_groups("odd", &[vec![0, 1]]);
    let pm = PerfModel::paper_calibrated();
    let cfg = |threads| GaConfig {
        population: 13,
        max_generations: 4,
        sim_requests: 6,
        measure_reps: 1,
        threads,
        ..GaConfig::quick(3)
    };
    let serial = run_session(&scenario, &pm, cfg(1));
    let par = run_session(&scenario, &pm, cfg(8));
    assert_eq!(serial.evaluations, par.evaluations);
    assert_eq!(pareto_signature(&serial), pareto_signature(&par));
}

#[test]
fn reused_workspace_matches_fresh_simulate_exactly() {
    // One workspace reused across many different plan sets must reproduce
    // fresh-allocation simulate() bit-for-bit each time.
    let scenario = Scenario::from_groups("ws", &[vec![0, 4], vec![1, 6]]);
    let pm = PerfModel::paper_calibrated();
    let comm = CommModel::paper_calibrated();
    let profiler = Profiler::new(&pm);
    let periods = scenario.periods(1.0, &pm);
    let groups: Vec<GroupSpec> = scenario
        .groups
        .iter()
        .zip(&periods)
        .map(|(g, &p)| GroupSpec::periodic(g.members.clone(), p))
        .collect();
    let opts = SimOptions { requests_per_group: 12, ..Default::default() };

    let mut rng = Rng::seed_from_u64(77);
    let mut ws = SimWorkspace::new();
    for _ in 0..8 {
        let genome = Genome::random(&scenario.networks, 0.4, &mut rng);
        let plans = decode(&scenario.networks, &genome, &profiler, &comm);
        let fresh = simulate(&plans, &groups, &comm, &opts);

        let compiled = compile_plans(&plans);
        ws.run(&plans, &compiled, &groups, &comm, &opts);
        let reused = ws.to_result();

        assert_eq!(fresh.makespans, reused.makespans, "makespans diverged");
        assert_eq!(fresh.busy, reused.busy, "busy time diverged");
        assert_eq!(fresh.span, reused.span, "span diverged");
        assert_eq!(fresh.tasks_run, reused.tasks_run, "task count diverged");
        for g in 0..groups.len() {
            assert_eq!(fresh.avg_makespan(g), ws.avg_makespan(g));
            assert_eq!(fresh.p90_makespan(g), ws.p90_makespan(g));
        }
    }
}

#[test]
fn steady_state_simulation_is_allocation_free() {
    // After one warm-up run, re-running the same workload through the
    // workspace — event loop, Poisson arrival generation, objective
    // extraction — must not allocate at all. Uses the per-thread counter of
    // the crate's counting global allocator, so concurrent test threads
    // cannot flake this.
    let scenario = Scenario::from_groups("alloc", &[vec![0, 1, 6]]);
    let pm = PerfModel::paper_calibrated();
    let comm = CommModel::paper_calibrated();
    let profiler = Profiler::new(&pm);
    let mut rng = Rng::seed_from_u64(3);
    let genome = Genome::random(&scenario.networks, 0.4, &mut rng);
    let plans = decode(&scenario.networks, &genome, &profiler, &comm);
    let compiled = compile_plans(&plans);
    let periods = scenario.periods(1.0, &pm);
    // One periodic group plus a Poisson group exercises both arrival paths.
    let groups = vec![
        GroupSpec::periodic(vec![0, 1], periods[0]),
        GroupSpec {
            networks: vec![2],
            period: periods[0],
            pattern: ArrivalPattern::Poisson { seed: 11 },
        },
    ];
    let opts = SimOptions { requests_per_group: 16, ..Default::default() };

    let mut ws = SimWorkspace::new();
    let mut objectives: Vec<f64> = Vec::new();
    // Warm-up: buffers grow to steady-state capacity.
    ws.run(&plans, &compiled, &groups, &comm, &opts);
    ws.objectives_into(&mut objectives);
    let warm = objectives.clone();

    let before = puzzle::util::alloc::thread_allocations();
    for _ in 0..5 {
        ws.run(&plans, &compiled, &groups, &comm, &opts);
        ws.objectives_into(&mut objectives);
    }
    let after = puzzle::util::alloc::thread_allocations();
    assert_eq!(
        after - before,
        0,
        "steady-state workspace simulation allocated {} times",
        after - before
    );
    assert_eq!(warm, objectives, "steady-state result drifted");
}

#[test]
fn memoized_decode_equals_fresh_decode() {
    let scenario = Scenario::from_groups("memo", &[vec![0, 2, 6]]);
    let pm = PerfModel::paper_calibrated();
    let comm = CommModel::paper_calibrated();
    let profiler = Profiler::new(&pm);
    let cache = DecodedPlanCache::new();
    let mut rng = Rng::seed_from_u64(21);
    for _ in 0..10 {
        let genome = Genome::random(&scenario.networks, 0.3, &mut rng);
        let first = cache.decode(&scenario.networks, &genome, &profiler, &comm);
        let second = cache.decode(&scenario.networks, &genome, &profiler, &comm);
        assert!(
            std::sync::Arc::ptr_eq(&first, &second),
            "re-decode of an identical genome must hit the memo"
        );
        let fresh = decode(&scenario.networks, &genome, &profiler, &comm);
        assert_eq!(first.plans, fresh, "memoized plans diverge from decode()");
    }
    let (hits, misses) = cache.stats();
    assert_eq!((hits, misses), (10, 10));
}

#[test]
fn plan_memo_reports_hits_in_full_search() {
    // End-to-end: a real search re-proposes genomes (elites, crossover
    // clones), so the memo must land hits and the analyzer must report them.
    let scenario = Scenario::from_groups("memo2", &[vec![0, 1]]);
    let pm = PerfModel::paper_calibrated();
    let r = run_session(&scenario, &pm, quick_cfg(4, 1));
    assert!(r.plan_cache_misses > 0);
    assert!(
        r.plan_cache_hits > 0,
        "no memo reuse across {} evaluations",
        r.evaluations
    );
}

/// Build a handful of solutions sharing plan sets, as the analyzer's
/// replacement step sees them.
fn sharing_solutions(n: usize) -> Vec<Solution> {
    let scenario = Scenario::from_groups("share", &[vec![0, 2, 6]]);
    let pm = PerfModel::paper_calibrated();
    let comm = CommModel::paper_calibrated();
    let profiler = Profiler::new(&pm);
    let mut rng = Rng::seed_from_u64(41);
    let genome = Genome::random(&scenario.networks, 0.3, &mut rng);
    let plans = decode(&scenario.networks, &genome, &profiler, &comm);
    let compiled = compile_plans(&plans);
    let set = Arc::new(PlanSet { plans, compiled });
    (0..n)
        .map(|i| Solution {
            genome: genome.clone(),
            objectives: vec![i as f64, (i * 2) as f64],
            plan_set: set.clone(),
        })
        .collect()
}

#[test]
fn solution_clone_never_copies_plans() {
    // Cloning a solution's plan handle is a pure Arc bump: zero heap
    // allocations (the pre-Arc representation deep-cloned every
    // ExecutionPlan here).
    let sols = sharing_solutions(2);
    let before = puzzle::util::alloc::thread_allocations();
    let handle = sols[0].plan_set.clone();
    let after = puzzle::util::alloc::thread_allocations();
    assert_eq!(after - before, 0, "Arc clone of the plan set allocated");
    assert!(Arc::ptr_eq(&handle, &sols[1].plan_set), "clones share one plan set");

    // A full Solution clone pays only for genome + objectives — its cost is
    // independent of the plan set entirely (same genome, plan sets of very
    // different sizes ⇒ identical allocation counts).
    let small = Solution { plan_set: Arc::new(PlanSet { plans: vec![], compiled: vec![] }), ..sols[0].clone() };
    let b1 = puzzle::util::alloc::thread_allocations();
    let _c1 = sols[0].clone();
    let mid = puzzle::util::alloc::thread_allocations();
    let _c2 = small.clone();
    let b2 = puzzle::util::alloc::thread_allocations();
    assert_eq!(mid - b1, b2 - mid, "clone cost depends on plan-set size");
}

#[test]
fn selection_is_allocation_free_at_population_512() {
    // The analyzer's replacement input at population 512: a 1024-candidate
    // pool (parents + children) with 4 objectives. Quantized values create
    // heavy dominance/duplicate ties, stressing the canonical tie-breaks.
    // After one warm pass over six such generations, replaying the same
    // generations must perform zero heap allocation — and the selected
    // indices must match the O(n²) reference selector exactly.
    let mut rng = Rng::seed_from_u64(99);
    let rounds: Vec<Vec<f64>> = (0..6)
        .map(|_| (0..1024 * 4).map(|_| (rng.gen_range(0, 64) as f64) * 0.125).collect())
        .collect();
    let mut ws = SelectionWorkspace::new();
    let mut expect: Vec<Vec<usize>> = Vec::new();
    for r in &rounds {
        expect.push(ws.select(r, 4, 512).to_vec());
    }
    // Cross-check one round against the reference implementation.
    let nested: Vec<Vec<f64>> = rounds[0].chunks(4).map(|c| c.to_vec()).collect();
    assert_eq!(expect[0], nsga3_select(&nested, 512), "ENS path diverged from reference");

    let before = puzzle::util::alloc::thread_allocations();
    for r in &rounds {
        let _ = ws.select(r, 4, 512);
    }
    let after = puzzle::util::alloc::thread_allocations();
    assert_eq!(after - before, 0, "steady-state selection allocated");
    for (r, e) in rounds.iter().zip(&expect) {
        assert_eq!(ws.select(r, 4, 512), e.as_slice(), "replay drifted");
    }
}

#[test]
fn plan_memo_hit_is_allocation_free() {
    // Re-decoding a memoized genome — the dominant decode path in a real
    // search — is a fingerprint + bucket probe + Arc bump: zero heap
    // allocations.
    let scenario = Scenario::from_groups("memo-hit", &[vec![0, 2]]);
    let pm = PerfModel::paper_calibrated();
    let comm = CommModel::paper_calibrated();
    let profiler = Profiler::new(&pm);
    let cache = DecodedPlanCache::new();
    let mut rng = Rng::seed_from_u64(31);
    let genome = Genome::random(&scenario.networks, 0.3, &mut rng);
    let primed = cache.decode(&scenario.networks, &genome, &profiler, &comm);
    let before = puzzle::util::alloc::thread_allocations();
    for _ in 0..10 {
        let hit = cache.decode(&scenario.networks, &genome, &profiler, &comm);
        assert!(Arc::ptr_eq(&hit, &primed));
    }
    let after = puzzle::util::alloc::thread_allocations();
    assert_eq!(after - before, 0, "memo-hit decode allocated");
}

#[test]
fn memo_miss_decode_scratch_removes_transient_allocations() {
    // First-touch decode: with the profiler warm (every subgraph's best
    // config memoized) and a warmed DecodeScratch, a fresh decode allocates
    // only for its output plan vectors — strictly less than the same decode
    // through a cold scratch, whose extra allocations are exactly the
    // transient partition/probe/hashing buffers this PR moved into the
    // workspace.
    let scenario = Scenario::from_groups("miss", &[vec![0, 2, 6]]);
    let pm = PerfModel::paper_calibrated();
    let comm = CommModel::paper_calibrated();
    let profiler = Profiler::new(&pm);
    let mut rng = Rng::seed_from_u64(47);
    let genome = Genome::random(&scenario.networks, 0.35, &mut rng);

    let mut warm = DecodeScratch::new();
    // Warm the profiler (DB + best memo + ordering stats) and the scratch.
    let reference = decode_with(&scenario.networks, &genome, &profiler, &comm, &mut warm);

    let b = puzzle::util::alloc::thread_allocations();
    let warm_plans = decode_with(&scenario.networks, &genome, &profiler, &comm, &mut warm);
    let warm_cost = puzzle::util::alloc::thread_allocations() - b;

    let b = puzzle::util::alloc::thread_allocations();
    let mut cold = DecodeScratch::new();
    let cold_plans = decode_with(&scenario.networks, &genome, &profiler, &comm, &mut cold);
    let cold_cost = puzzle::util::alloc::thread_allocations() - b;

    assert_eq!(warm_plans, reference);
    assert_eq!(cold_plans, reference);
    assert!(
        warm_cost < cold_cost,
        "warmed scratch saved nothing: warm {warm_cost} vs cold {cold_cost}"
    );
    // Output-only budget: one tasks Vec + one (growing) transfers Vec per
    // network, plus the outer collect. 8 covers transfer-vector doubling
    // with room to spare; the pre-workspace decode was far above this.
    let budget = 1 + 8 * scenario.networks.len() as u64;
    assert!(
        warm_cost <= budget,
        "warmed memo-miss decode allocated {warm_cost} times (budget {budget}) — transient \
         scratch is leaking back into the hot path"
    );
}

#[test]
fn vectorized_measurement_noise_matches_per_task_sampling() {
    // The measurement tier now samples multiplicative factors in one flat
    // pass and replays the shared compilation via run_with_durations. This
    // pins its bit-equality to the path it replaced: clone the plans and
    // rewrite every task duration with PerfModel::sample per repetition.
    let scenario = Scenario::from_groups("noise", &[vec![0, 4], vec![1, 6]]);
    let pm = PerfModel::paper_calibrated();
    let comm = CommModel::paper_calibrated();
    let profiler = Profiler::new(&pm);
    let mut rng = Rng::seed_from_u64(61);
    let genome = Genome::random(&scenario.networks, 0.4, &mut rng);
    let plans = decode(&scenario.networks, &genome, &profiler, &comm);
    let compiled = compile_plans(&plans);
    let periods = scenario.periods(1.0, &pm);
    let groups: Vec<GroupSpec> = scenario
        .groups
        .iter()
        .zip(&periods)
        .map(|(g, &p)| GroupSpec::periodic(g.members.clone(), p))
        .collect();
    let opts = SimOptions { requests_per_group: 10, ..Default::default() };
    let reps = 5;

    // Legacy path: per-task sample() into cloned plans.
    let mut rng_old = Rng::seed_from_u64(7);
    let mut noisy = plans.clone();
    let mut ws_old = SimWorkspace::new();
    let mut old_objs: Vec<f64> = Vec::new();
    for _ in 0..reps {
        for (np, p) in noisy.iter_mut().zip(&plans) {
            for (nt, t) in np.tasks.iter_mut().zip(&p.tasks) {
                nt.duration = pm.sample(t.duration, t.processor, &mut rng_old);
            }
        }
        ws_old.run(&noisy, &compiled, &groups, &comm, &opts);
        let mut o = Vec::new();
        ws_old.objectives_into(&mut o);
        old_objs.extend(o);
    }

    // Vectorized path: flat factors over cached nominals + durations
    // override.
    let mut rng_new = Rng::seed_from_u64(7);
    let nominal: Vec<f64> =
        plans.iter().flat_map(|p| p.tasks.iter().map(|t| t.duration)).collect();
    let procs: Vec<Processor> =
        plans.iter().flat_map(|p| p.tasks.iter().map(|t| t.processor)).collect();
    let mut durs = vec![0.0; nominal.len()];
    let mut ws_new = SimWorkspace::new();
    let mut new_objs: Vec<f64> = Vec::new();
    for _ in 0..reps {
        for i in 0..nominal.len() {
            durs[i] = nominal[i] * pm.sample_factor(procs[i], &mut rng_new);
        }
        ws_new.run_with_durations(&plans, &compiled, &durs, &groups, &comm, &opts);
        let mut o = Vec::new();
        ws_new.objectives_into(&mut o);
        new_objs.extend(o);
    }
    assert_eq!(old_objs, new_objs, "vectorized measurement tier diverged bit-wise");
}

#[test]
fn solution_moves_are_allocation_free() {
    // The primitive the replacement step's retention is built on: moving
    // `Solution`s between preallocated buffers allocates nothing, and plan
    // sets stay shared. With the old owned `Vec<ExecutionPlan>`
    // representation, every survivor carried (and on clone, copied) its
    // whole plan vector through this churn.
    let n = 16;
    let mut pool = sharing_solutions(n);
    let mut kept: Vec<Solution> = Vec::with_capacity(n);
    // Warm-up one full cycle so both buffers reach capacity.
    kept.extend(pool.drain(..));
    pool.extend(kept.drain(..));

    let before = puzzle::util::alloc::thread_allocations();
    for _ in 0..100 {
        kept.extend(pool.drain(..));
        pool.extend(kept.drain(..));
    }
    let after = puzzle::util::alloc::thread_allocations();
    assert_eq!(after - before, 0, "survivor retention allocated");
    // Sharing survived the churn.
    assert!(Arc::ptr_eq(&pool[0].plan_set, &pool[n - 1].plan_set));
}
