//! Integration tests over the PJRT bridge: load the AOT HLO artifacts
//! produced by `make artifacts` and execute them through the real runtime.
//!
//! These tests are skipped (with a notice) when `artifacts/` has not been
//! built, so `cargo test` stays green on a fresh checkout; `make test`
//! always builds artifacts first.

use std::sync::Arc;

use puzzle::engine::{Engine, EngineTask, PjrtEngine};
use puzzle::graph::partition;
use puzzle::models::build_model;
use puzzle::runtime::{artifacts_dir, layer_artifact, model_artifact, PjrtRuntime};
use puzzle::{Backend, DataType, ExecConfig, Processor};

fn artifacts_available() -> bool {
    model_artifact("face_det").exists()
}

#[test]
fn load_and_execute_whole_model() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let rt = PjrtRuntime::cpu().expect("pjrt cpu client");
    let module = rt.load(&model_artifact("face_det")).expect("load artifact");
    let input = vec![0.1f32; 32 * 32 * 3];
    let outputs = module
        .run_f32(&[(&input, &[1, 32, 32, 3])])
        .expect("execute face_det");
    // face_det's single output: concat of the two heads, 8x8x12.
    assert_eq!(outputs.len(), 1);
    assert_eq!(outputs[0].len(), 8 * 8 * 12);
    assert!(outputs[0].iter().all(|v| v.is_finite()));
}

#[test]
fn layer_chain_matches_whole_model() {
    // The core numerics check at the rust level: executing the model
    // layer-by-layer through per-layer artifacts must reproduce the fused
    // whole-model artifact's output.
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let rt = PjrtRuntime::cpu().expect("client");
    let net = build_model(0, 0); // face_det
    let input = {
        // Deterministic pseudo-input.
        let mut v = Vec::with_capacity(32 * 32 * 3);
        let mut x = 0.123f32;
        for _ in 0..(32 * 32 * 3) {
            x = (x * 1.7 + 0.31) % 1.0;
            v.push(x - 0.5);
        }
        v
    };

    // Whole model.
    let whole = rt.load(&model_artifact("face_det")).unwrap();
    let whole_out = whole.run_f32(&[(&input, &[1, 32, 32, 3])]).unwrap();

    // Layer chain.
    let mut produced: std::collections::HashMap<usize, Vec<f32>> = Default::default();
    for &l in net.topological_order() {
        let module = rt.load(&layer_artifact("face_det", l.0)).unwrap();
        let preds = net.predecessors(l);
        let out = if preds.is_empty() {
            module.run_f32(&[(&input, &[1, 32, 32, 3])]).unwrap()
        } else {
            let shaped: Vec<(&[f32], Vec<usize>)> = preds
                .iter()
                .map(|p| {
                    let s = net.layer(*p).out_shape;
                    (produced[&p.0].as_slice(), vec![1, s.h, s.w, s.c])
                })
                .collect();
            let refs: Vec<(&[f32], &[usize])> =
                shaped.iter().map(|(d, s)| (*d, s.as_slice())).collect();
            module.run_f32(&refs).unwrap()
        };
        produced.insert(l.0, out.into_iter().next().unwrap());
    }
    let last = net.outputs()[0];
    let chain_out = &produced[&last.0];

    assert_eq!(whole_out[0].len(), chain_out.len());
    for (a, b) in whole_out[0].iter().zip(chain_out) {
        assert!((a - b).abs() < 1e-4, "layer chain diverged: {a} vs {b}");
    }
}

#[test]
fn pjrt_engine_runs_subgraphs() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let rt = PjrtRuntime::cpu().expect("client");
    let engine = PjrtEngine::new(rt);
    let net = build_model(0, 0);
    engine.preload(&net).expect("preload");
    assert_eq!(engine.cached_modules(), net.num_layers());

    // Whole network as one subgraph.
    let part = partition(
        &net,
        &vec![false; net.num_edges()],
        &vec![Processor::Npu; net.num_layers()],
    );
    let task = EngineTask {
        network: &net,
        subgraph: &part.subgraphs[0],
        config: ExecConfig::new(Processor::Npu, Backend::Qnn, DataType::Fp16),
        inputs: vec![vec![0.1f32; 32 * 32 * 3]],
        start: 0.0,
    };
    let out = engine.execute(&task).expect("execute");
    assert_eq!(out.tensors.len(), 1, "one sink tensor");
    assert_eq!(out.tensors[0].len(), 8 * 8 * 12);
    assert!(out.elapsed > 0.0);

    // Split into two subgraphs at the first edge; run both, chaining.
    let mut cuts = vec![false; net.num_edges()];
    cuts[4] = true; // between b2_pw and trunk
    let part2 = partition(&net, &cuts, &vec![Processor::Npu; net.num_layers()]);
    assert!(part2.subgraphs.len() >= 2);
    for sg in &part2.subgraphs {
        let task = EngineTask {
            network: &net,
            subgraph: sg,
            config: ExecConfig::new(Processor::Npu, Backend::Qnn, DataType::Fp16),
            inputs: vec![],
            start: 0.0,
        };
        let out = engine.execute(&task).expect("execute split");
        assert!(!out.tensors.is_empty());
    }
}

#[test]
fn artifact_manifest_is_consistent_with_rust_zoo() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let manifest_path = artifacts_dir().join("manifest.json");
    let text = std::fs::read_to_string(manifest_path).expect("manifest");
    for idx in 0..puzzle::models::MODEL_COUNT {
        let net = build_model(0, idx);
        assert!(
            text.contains(&format!("\"{}\"", net.name)),
            "manifest missing {}",
            net.name
        );
        // Every layer artifact exists.
        for l in 0..net.num_layers() {
            assert!(
                layer_artifact(&net.name, l).exists(),
                "{} layer {} artifact missing",
                net.name,
                l
            );
        }
    }
}

#[test]
fn all_models_whole_artifacts_execute() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let rt = Arc::new(PjrtRuntime::cpu().expect("client"));
    for idx in 0..puzzle::models::MODEL_COUNT {
        let net = build_model(0, idx);
        let module = rt.load(&model_artifact(&net.name)).expect("load");
        let (h, w, c) = {
            let first = net.inputs()[0];
            let layer = net.layer(first);
            let (h, w) = match layer.kind {
                puzzle::graph::LayerKind::Conv { stride, .. } => {
                    (layer.out_shape.h * stride, layer.out_shape.w * stride)
                }
                _ => (layer.out_shape.h, layer.out_shape.w),
            };
            (h, w, layer.in_channels)
        };
        let input = vec![0.05f32; h * w * c];
        let out = module
            .run_f32(&[(&input, &[1, h, w, c])])
            .unwrap_or_else(|e| panic!("{}: {e}", net.name));
        assert!(!out.is_empty(), "{}", net.name);
        for t in &out {
            assert!(t.iter().all(|v| v.is_finite()), "{} non-finite output", net.name);
        }
    }
}
