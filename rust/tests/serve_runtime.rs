//! Integration tests of the arrival-driven serving runtime: virtual-clock
//! determinism, priority-ordered dispatch under contention, deadline
//! accounting above saturation, overload policies, persistent-deployment
//! reuse (warm probes bit-identical to fresh deploys; one deployment per
//! solution set in the saturation search; the ρ-seeded bisection bracket),
//! and the `Deployment::serve_load` api surface.

use std::ops::ControlFlow;
use std::sync::Arc;

use puzzle::analyzer::GaConfig;
use puzzle::api::{LoadSpec, OverloadPolicy, RuntimeOptions, ScenarioSpec, SessionBuilder};
use puzzle::coordinator::ServedRequest;
use puzzle::ga::Genome;
use puzzle::perf::PerfModel;
use puzzle::scenario::Scenario;
use puzzle::serve::{
    self, materialize_solutions, offered_utilization, rho_bracket_floor, ClockMode,
    RuntimeHarness, SaturationOptions, ServeReport,
};
use puzzle::Processor;

/// Bitwise equality of two served logs (every field, every f64 bit).
fn assert_logs_identical(a: &[ServedRequest], b: &[ServedRequest]) {
    assert_eq!(a.len(), b.len(), "log lengths differ");
    for (x, y) in a.iter().zip(b) {
        assert_eq!((x.group, x.request), (y.group, y.request));
        assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
        assert_eq!(x.completion.to_bits(), y.completion.to_bits());
        assert_eq!(x.makespan.to_bits(), y.makespan.to_bits());
        assert_eq!(x.deadline.map(f64::to_bits), y.deadline.map(f64::to_bits));
        assert_eq!(x.violated, y.violated);
    }
}

/// Bitwise equality of the deterministic report fields (wall_seconds is
/// real time and legitimately differs between runs).
fn assert_reports_identical(a: &ServeReport, b: &ServeReport) {
    assert_eq!(a.submitted, b.submitted);
    assert_eq!(a.served, b.served);
    assert_eq!(a.dropped, b.dropped);
    assert_eq!(a.unfinished, b.unfinished);
    assert_eq!(a.violations, b.violations);
    assert_eq!(a.score.to_bits(), b.score.to_bits());
    assert_eq!(a.attainment.to_bits(), b.attainment.to_bits());
    assert_eq!(a.group_makespans.len(), b.group_makespans.len());
    for (ga, gb) in a.group_makespans.iter().zip(&b.group_makespans) {
        assert_eq!(ga.len(), gb.len());
        for (ma, mb) in ga.iter().zip(gb) {
            assert_eq!(ma.to_bits(), mb.to_bits());
        }
    }
    let (ra, rb) = (a.rho.expect("harness logs rho"), b.rho.expect("harness logs rho"));
    for (x, y) in ra.iter().zip(&rb) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}

fn harness_for(scenario: &Scenario, genome: &Genome, seed: u64) -> RuntimeHarness {
    let perf = Arc::new(PerfModel::paper_calibrated());
    RuntimeHarness::for_genome(scenario, genome, &perf, seed)
}

#[test]
fn virtual_clock_logs_bit_identical_across_runs() {
    // Same seed, same (Poisson!) load, fresh runtime each run: the
    // ServedRequest logs must agree to the last f64 bit — arrivals,
    // completions, makespans, verdicts.
    let scenario = Scenario::from_groups("det", &[vec![0, 1]]);
    let genome = Genome::all_on(&scenario.networks, Processor::Npu);
    let harness = harness_for(&scenario, &genome, 11);
    let perf = PerfModel::paper_calibrated();
    let spec = LoadSpec::poisson(&scenario.periods(1.0, &perf), 15, 5);
    let (report_a, log_a) = harness.run_with_log(&spec);
    let (_, log_b) = harness.run_with_log(&spec);
    assert_eq!(report_a.served, 15);
    assert_eq!(log_a.len(), log_b.len());
    for (a, b) in log_a.iter().zip(&log_b) {
        assert_eq!((a.group, a.request), (b.group, b.request));
        assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
        assert_eq!(a.completion.to_bits(), b.completion.to_bits());
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.violated, b.violated);
    }
    // A different noise seed produces a different schedule (the determinism
    // is per seed, not an accident of a noise-free path).
    let (_, log_c) = harness_for(&scenario, &genome, 12).run_with_log(&spec);
    assert!(
        log_a
            .iter()
            .zip(&log_c)
            .any(|(a, c)| a.makespan.to_bits() != c.makespan.to_bits()),
        "noise seed had no effect"
    );
}

#[test]
fn priority_orders_dispatch_under_contention() {
    // Three copies of the same heavy model, all pinned to the NPU, one per
    // group, submitted simultaneously. The ready queue must release them in
    // priority order (0 = highest precedence), not submission order.
    let scenario = Scenario::from_groups("prio", &[vec![8], vec![8], vec![8]]);
    let mut genome = Genome::all_on(&scenario.networks, Processor::Npu);
    genome.priority = vec![1, 2, 0]; // network/group 2 wins, then 0, then 1
    let mut harness = harness_for(&scenario, &genome, 3);
    harness.noisy = false;
    let spec = LoadSpec::periodic(&[1.0, 1.0, 1.0], 1); // one request each at t=0
    let (report, log) = harness.run_with_log(&spec);
    assert_eq!(report.served, 3);
    let completion_order: Vec<usize> = log.iter().map(|s| s.group).collect();
    assert_eq!(completion_order, vec![2, 0, 1], "dispatch ignored priorities");
    // Serialized on one worker: completions strictly increase.
    assert!(log.windows(2).all(|w| w[1].completion > w[0].completion));
}

#[test]
fn deadline_violations_appear_above_saturation() {
    // One NPU-friendly model. At a generous period every deadline holds; at
    // a period far below the service time the backlog grows and the tail of
    // the run violates.
    let scenario = Scenario::from_groups("overload", &[vec![0]]);
    let genome = Genome::all_on(&scenario.networks, Processor::Npu);
    let harness = harness_for(&scenario, &genome, 9);
    let perf = PerfModel::paper_calibrated();

    let relaxed = harness.run(&LoadSpec::for_scenario(&scenario, &perf, 3.0, 12));
    assert_eq!(relaxed.served, 12);
    assert_eq!(relaxed.violations, 0, "{relaxed:?}");
    assert!(relaxed.attainment == 1.0 && relaxed.score > 0.9);

    let overloaded = harness.run(&LoadSpec::for_scenario(&scenario, &perf, 0.05, 12));
    assert_eq!(overloaded.served, 12, "queue policy still serves everything");
    assert!(overloaded.violations > 0, "no violations under overload: {overloaded:?}");
    assert!(overloaded.attainment < 1.0);
    assert!(overloaded.score < relaxed.score);
    // Open-loop backlog: makespans grow toward the tail.
    let ms = &overloaded.group_makespans[0];
    assert!(ms.last().unwrap() > ms.first().unwrap());
}

#[test]
fn drop_policy_bounds_backlog() {
    let scenario = Scenario::from_groups("drops", &[vec![0]]);
    let genome = Genome::all_on(&scenario.networks, Processor::Npu);
    let harness = harness_for(&scenario, &genome, 17);
    let perf = PerfModel::paper_calibrated();
    let overload = LoadSpec::for_scenario(&scenario, &perf, 0.05, 16);

    let queued = harness.run(&overload);
    let dropping =
        harness.run(&overload.with_policy(OverloadPolicy::DropAfter { max_inflight: 2 }));
    assert!(dropping.dropped > 0, "drop policy never engaged");
    assert_eq!(dropping.served + dropping.dropped, dropping.submitted);
    // Admission control bounds the worst makespan the served requests see.
    let worst = |r: &puzzle::serve::ServeReport| {
        r.group_makespans[0].iter().cloned().fold(0.0f64, f64::max)
    };
    assert!(
        worst(&dropping) < worst(&queued),
        "drop policy did not bound the backlog: {} vs {}",
        worst(&dropping),
        worst(&queued)
    );
}

#[test]
fn bursty_load_inflates_tail_latency() {
    // Same long-run rate, clumped arrivals: the p90 makespan under bursts
    // must exceed the periodic p90 (queueing at the worker).
    let scenario = Scenario::from_groups("burst", &[vec![6]]);
    let genome = Genome::all_on(&scenario.networks, Processor::Npu);
    let mut harness = harness_for(&scenario, &genome, 21);
    harness.noisy = false;
    let perf = PerfModel::paper_calibrated();
    let periods = scenario.periods(1.1, &perf);
    let periodic = harness.run(&LoadSpec::periodic(&periods, 24));
    let bursty = harness.run(&LoadSpec::bursty(&periods, 6, 24));
    assert_eq!(periodic.served, 24);
    assert_eq!(bursty.served, 24);
    assert!(
        bursty.percentile(0, 0.9) > periodic.percentile(0, 0.9),
        "bursty p90 {} <= periodic p90 {}",
        bursty.percentile(0, 0.9),
        periodic.percentile(0, 0.9)
    );
}

#[test]
fn wall_clock_load_completes_and_converts_units() {
    // Wall mode on a light group at a compressing time scale: everything
    // serves, and the reported makespans come back in simulated seconds
    // (not wall seconds).
    let scenario = Scenario::from_groups("wall", &[vec![0, 1]]);
    let genome = Genome::all_on(&scenario.networks, Processor::Npu);
    let mut harness = harness_for(&scenario, &genome, 13);
    harness.time_scale = 2.0; // stretch: wall sleeps 2x simulated time
    let perf = PerfModel::paper_calibrated();
    let spec = LoadSpec::for_scenario(&scenario, &perf, 2.0, 5)
        .wall(std::time::Duration::from_secs(20));
    let report = harness.run(&spec);
    assert_eq!(report.served, 5);
    assert_eq!(report.dropped, 0);
    // Simulated makespans stay on the order of the models' service times
    // (sub-5ms), even though wall time was stretched 2x.
    for &m in &report.group_makespans[0] {
        assert!(m > 0.0 && m < 0.05, "makespan {m}s not in simulated units");
    }
}

#[test]
fn deployment_serve_load_end_to_end() {
    // The api surface: session → analysis → deploy (non-sleeping engine) →
    // serve_load under the virtual clock.
    let session = SessionBuilder::new(ScenarioSpec::single_group("api-load", vec![0, 2]))
        .config(GaConfig { population: 10, max_generations: 3, ..GaConfig::quick(7) })
        .build()
        .unwrap();
    let analysis = session.run();
    let mut deployment = analysis
        .deploy_sim(analysis.best_index(), RuntimeOptions::default(), 0.0, true, 7)
        .unwrap();
    let spec = LoadSpec::for_scenario(analysis.scenario(), analysis.perf(), 2.0, 12);
    let report = deployment.serve_load(&spec);
    deployment.shutdown();
    assert_eq!(report.submitted, 12);
    assert_eq!(report.served, 12);
    assert!(report.score > 0.5, "relaxed load should score well: {report:?}");
    assert!(report.group_makespans[0].iter().all(|&m| m > 0.0));
}

#[test]
fn warm_probes_bit_identical_to_fresh_deploys() {
    // The tentpole contract: a reused deployment, reset + re-seeded between
    // loads, replays every probe bit-identically to a fresh
    // Coordinator/Worker stack — across different α loads AND different
    // arrival patterns, including a replay after intervening traffic.
    let scenario = Scenario::from_groups("warm", &[vec![0, 1]]);
    let genome = Genome::all_on(&scenario.networks, Processor::Npu);
    let harness = harness_for(&scenario, &genome, 11);
    let perf = PerfModel::paper_calibrated();
    let periodic = LoadSpec::for_scenario(&scenario, &perf, 1.0, 12);
    let poisson = LoadSpec::poisson(&scenario.periods(1.0, &perf), 12, 5);

    let mut warm = harness.deploy(ClockMode::Virtual);
    let (wr_periodic, wl_periodic) = warm.probe_with_log(&periodic, 41);
    let (wr_poisson, wl_poisson) = warm.probe_with_log(&poisson, 43);
    let (wr_again, wl_again) = warm.probe_with_log(&periodic, 41);
    warm.shutdown();

    let fresh = |seed: u64, spec: &LoadSpec| {
        let mut h = harness.clone();
        h.seed = seed;
        h.run_with_log(spec)
    };
    let (fr_periodic, fl_periodic) = fresh(41, &periodic);
    let (fr_poisson, fl_poisson) = fresh(43, &poisson);

    assert!(!wl_periodic.is_empty() && !wl_poisson.is_empty());
    assert_logs_identical(&wl_periodic, &fl_periodic);
    assert_reports_identical(&wr_periodic, &fr_periodic);
    assert_logs_identical(&wl_poisson, &fl_poisson);
    assert_reports_identical(&wr_poisson, &fr_poisson);
    // Replaying after other traffic leaves no trace: bit-identical again.
    assert_logs_identical(&wl_again, &wl_periodic);
    assert_reports_identical(&wr_again, &wr_periodic);
}

#[test]
fn deployment_reset_leaves_no_stale_state() {
    // api surface: serve_load → reset → the warm runtime looks freshly
    // deployed (no served/dropped/in-flight state, request ids restart).
    let session = SessionBuilder::new(ScenarioSpec::single_group("api-reset", vec![0, 2]))
        .config(GaConfig { population: 10, max_generations: 3, ..GaConfig::quick(7) })
        .build()
        .unwrap();
    let analysis = session.run();
    let mut deployment = analysis
        .deploy_sim(analysis.best_index(), RuntimeOptions::default(), 0.0, true, 7)
        .unwrap();
    let overload = LoadSpec::for_scenario(analysis.scenario(), analysis.perf(), 0.05, 8)
        .with_policy(OverloadPolicy::DropAfter { max_inflight: 2 });
    let first = deployment.serve_load(&overload);
    assert!(first.dropped > 0, "overload with a tight cap must drop");
    assert!(!deployment.coordinator.served().is_empty());
    assert!(!deployment.coordinator.dropped().is_empty());

    deployment.reset_seeded(7);
    assert!(deployment.coordinator.served().is_empty(), "reset left served state");
    assert!(deployment.coordinator.dropped().is_empty(), "reset left dropped state");
    assert_eq!(deployment.coordinator.outstanding(), 0, "reset left in-flight state");

    // The replayed load is bit-identical to the first (same engine seed,
    // same request sequencing from 0).
    let second = deployment.serve_load(&overload);
    let min_id = deployment.coordinator.served().iter().map(|s| s.request).min();
    assert_eq!(min_id, Some(0), "request sequencing did not restart at 0");
    deployment.shutdown();
    assert_eq!(first.served, second.served);
    assert_eq!(first.dropped, second.dropped);
    assert_eq!(first.score.to_bits(), second.score.to_bits());
}

#[test]
fn saturation_deploys_exactly_once_per_solution_set() {
    // The acceptance bar: however many α-probes the bisection takes, the
    // driver spawns one runtime per solution set and reuses it.
    let scenario = Scenario::from_groups("one-deploy", &[vec![0, 1]]);
    let perf = Arc::new(PerfModel::paper_calibrated());
    // Two distinct solution sets, both NPU-mapped (so neither can be
    // certificate-skipped at alpha_max and both must deploy), differing in
    // dispatch priority.
    let genome_a = Genome::all_on(&scenario.networks, Processor::Npu);
    let mut genome_b = genome_a.clone();
    genome_b.priority.reverse();
    let sets = vec![
        materialize_solutions(&scenario.networks, &genome_a, &perf),
        materialize_solutions(&scenario.networks, &genome_b, &perf),
    ];
    let opts = SaturationOptions { requests: 8, tolerance: 0.05, ..Default::default() };
    let mut probes = 0usize;
    let mut deploys = 0usize;
    let _ = serve::saturation_via_runtime_observed(&sets, &scenario, &perf, &opts, &mut |p| {
        probes = p.probes;
        deploys = p.deploys;
        assert!(p.deploys <= sets.len(), "more deployments than solution sets");
        ControlFlow::Continue(())
    });
    assert!(probes >= 3, "bisection should take several probes, took {probes}");
    assert_eq!(
        deploys,
        sets.len(),
        "expected exactly one deployment per solution set over {probes} probes"
    );
}

#[test]
fn rho_seeded_bracket_never_skips_a_feasible_alpha() {
    // Property-style over random solution sets (hence random per-processor
    // rates): every α strictly below `rho_bracket_floor` is certified
    // infeasible for strictly more than half the sets — exactly the
    // driver's certificate on the driver's own ρ computation — so the
    // median score there is 0 and no feasible α is ever excluded from the
    // bisection bracket.
    let scenario = Scenario::from_groups("rho-prop", &[vec![0, 1]]);
    let perf = Arc::new(PerfModel::paper_calibrated());
    let groups: Vec<Vec<usize>> = scenario.groups.iter().map(|g| g.members.clone()).collect();
    puzzle::util::prop::check("rho-seeded bracket", 12, |rng| {
        let n_sets = rng.gen_range(1, 4);
        let sets: Vec<_> = (0..n_sets)
            .map(|_| {
                let genome = Genome::random(&scenario.networks, 0.3, rng);
                materialize_solutions(&scenario.networks, &genome, &perf)
            })
            .collect();
        let floor = rho_bracket_floor(&sets, &scenario, &perf);
        puzzle::prop_assert!(floor > 0.0, "floor must be positive, got {floor}");
        for _ in 0..8 {
            let alpha = floor * rng.gen_f64().max(1e-3) * 0.999;
            let spec = LoadSpec::periodic(&scenario.periods(alpha, &perf), 4);
            let rates = spec.mean_rates();
            let certified = sets
                .iter()
                .filter(|sols| {
                    offered_utilization(sols, &groups, &rates, &perf).iter().any(|&r| r > 1.0)
                })
                .count();
            puzzle::prop_assert!(
                certified > sets.len() / 2,
                "alpha {alpha} below floor {floor} but only {certified}/{} sets certified",
                sets.len()
            );
        }
        Ok(())
    });
}

#[test]
fn little_cap_admission_is_invisible_at_feasible_load() {
    // At comfortably feasible load the Little's-law cap never engages: the
    // capped run is bit-identical to unbounded queueing. (Under certified
    // overload the saturation driver skips the probe before admission
    // control could matter — that pairing is the design.)
    let scenario = Scenario::from_groups("little-feasible", &[vec![0, 1]]);
    let genome = Genome::all_on(&scenario.networks, Processor::Npu);
    let mut harness = harness_for(&scenario, &genome, 19);
    harness.noisy = false;
    let perf = PerfModel::paper_calibrated();
    let spec = LoadSpec::for_scenario(&scenario, &perf, 2.0, 12);
    let cap = serve::little_inflight_cap(
        &harness.solutions,
        &harness.groups,
        &spec.mean_rates(),
        &perf,
        3.0,
    );
    assert!(cap >= 1);
    let (queue_report, queue_log) = harness.run_with_log(&spec);
    let capped_spec = spec.with_policy(OverloadPolicy::DropAfter { max_inflight: cap });
    let (cap_report, cap_log) = harness.run_with_log(&capped_spec);
    assert_eq!(cap_report.dropped, 0, "cap {cap} engaged at feasible load");
    assert_logs_identical(&queue_log, &cap_log);
    assert_eq!(queue_report.score.to_bits(), cap_report.score.to_bits());
}

#[test]
fn materialized_baseline_matches_api_deployment_shape() {
    // materialize_solutions (the baseline entry into the harness) produces
    // the same solution shape as Analysis::runtime_solutions.
    let scenario = Scenario::from_groups("shape", &[vec![0, 4]]);
    let perf = PerfModel::paper_calibrated();
    let genome = Genome::all_on(&scenario.networks, Processor::Gpu);
    let sols = materialize_solutions(&scenario.networks, &genome, &perf);
    assert_eq!(sols.len(), 2);
    for (i, sol) in sols.iter().enumerate() {
        assert_eq!(sol.priority, genome.priority[i]);
        assert_eq!(sol.partition.subgraphs.len(), sol.configs.len());
        for (sg, cfg) in sol.partition.subgraphs.iter().zip(&sol.configs) {
            assert_eq!(cfg.processor, sg.processor);
        }
    }
}
