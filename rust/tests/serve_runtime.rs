//! Integration tests of the arrival-driven serving runtime: virtual-clock
//! determinism, priority-ordered dispatch under contention, deadline
//! accounting above saturation, overload policies, persistent-deployment
//! reuse (warm probes bit-identical to fresh deploys; one deployment per
//! solution set in the saturation search; the ρ-seeded bisection bracket),
//! chaos injection (deterministic fault replay, watchdog/retry/remap
//! recovery, the empty-plan zero-overhead contract, robust-α*), the
//! telemetry plane (bit-identical fresh-vs-warm event streams including
//! chaos recovery, aggregation == ServeReport, the no-subscriber
//! invisibility contract, wall-driver release precision), the
//! `Deployment::serve_load` api surface, and the probe fleet (saturation
//! results bit-identical to serial for any `probe_threads`, including
//! under chaos plans; thread-isolated warm probes across arrival
//! patterns — determinism contract #6).

use std::ops::ControlFlow;
use std::sync::Arc;

use puzzle::analyzer::GaConfig;
use puzzle::api::{LoadSpec, OverloadPolicy, RuntimeOptions, ScenarioSpec, SessionBuilder};
use puzzle::coordinator::ServedRequest;
use puzzle::ga::Genome;
use puzzle::perf::PerfModel;
use puzzle::scenario::Scenario;
use puzzle::serve::{
    self, materialize_solutions, offered_utilization, rho_bracket_floor, ClockMode, FaultPlan,
    RuntimeHarness, SaturationOptions, ServeReport,
};
use puzzle::telemetry::{MetricsAggregator, TelemetryEvent};
use puzzle::Processor;

/// Bitwise equality of one served-log entry (every field, every f64 bit,
/// including the fault-recovery accounting).
fn log_entries_equal(x: &ServedRequest, y: &ServedRequest) -> bool {
    (x.group, x.request) == (y.group, y.request)
        && x.arrival.to_bits() == y.arrival.to_bits()
        && x.completion.to_bits() == y.completion.to_bits()
        && x.makespan.to_bits() == y.makespan.to_bits()
        && x.deadline.map(f64::to_bits) == y.deadline.map(f64::to_bits)
        && x.violated == y.violated
        && (x.retries, x.remaps) == (y.retries, y.remaps)
        && x.degraded.to_bits() == y.degraded.to_bits()
}

/// Bitwise equality of two served logs (every field, every f64 bit).
fn assert_logs_identical(a: &[ServedRequest], b: &[ServedRequest]) {
    assert_eq!(a.len(), b.len(), "log lengths differ");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(log_entries_equal(x, y), "log entry {i} differs: {x:?} vs {y:?}");
    }
}

/// Bitwise equality of the deterministic report fields (wall_seconds is
/// real time, and the `mem` millisecond fields are wall-measured — both
/// legitimately differ between runs; `mem` counts additionally differ
/// between a deployment's cold first probe and warm later ones, so the
/// whole block stays out of the identity contract).
fn assert_reports_identical(a: &ServeReport, b: &ServeReport) {
    assert_eq!(a.submitted, b.submitted);
    assert_eq!(a.served, b.served);
    assert_eq!(a.dropped, b.dropped);
    assert_eq!(a.unfinished, b.unfinished);
    assert_eq!(a.violations, b.violations);
    assert_eq!(a.score.to_bits(), b.score.to_bits());
    assert_eq!(a.attainment.to_bits(), b.attainment.to_bits());
    assert_eq!((a.retries, a.remaps, a.fault_shed), (b.retries, b.remaps, b.fault_shed));
    assert_eq!(a.degraded_time.to_bits(), b.degraded_time.to_bits());
    assert_eq!(a.group_makespans.len(), b.group_makespans.len());
    for (ga, gb) in a.group_makespans.iter().zip(&b.group_makespans) {
        assert_eq!(ga.len(), gb.len());
        for (ma, mb) in ga.iter().zip(gb) {
            assert_eq!(ma.to_bits(), mb.to_bits());
        }
    }
    let (ra, rb) = (a.rho.expect("harness logs rho"), b.rho.expect("harness logs rho"));
    for (x, y) in ra.iter().zip(&rb) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}

fn harness_for(scenario: &Scenario, genome: &Genome, seed: u64) -> RuntimeHarness {
    let perf = Arc::new(PerfModel::paper_calibrated());
    RuntimeHarness::for_genome(scenario, genome, &perf, seed)
}

#[test]
fn virtual_clock_logs_bit_identical_across_runs() {
    // Same seed, same (Poisson!) load, fresh runtime each run: the
    // ServedRequest logs must agree to the last f64 bit — arrivals,
    // completions, makespans, verdicts.
    let scenario = Scenario::from_groups("det", &[vec![0, 1]]);
    let genome = Genome::all_on(&scenario.networks, Processor::Npu);
    let harness = harness_for(&scenario, &genome, 11);
    let perf = PerfModel::paper_calibrated();
    let spec = LoadSpec::poisson(&scenario.periods(1.0, &perf), 15, 5);
    let (report_a, log_a) = harness.run_with_log(&spec);
    let (_, log_b) = harness.run_with_log(&spec);
    assert_eq!(report_a.served, 15);
    assert_eq!(log_a.len(), log_b.len());
    for (a, b) in log_a.iter().zip(&log_b) {
        assert_eq!((a.group, a.request), (b.group, b.request));
        assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
        assert_eq!(a.completion.to_bits(), b.completion.to_bits());
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.violated, b.violated);
    }
    // A different noise seed produces a different schedule (the determinism
    // is per seed, not an accident of a noise-free path).
    let (_, log_c) = harness_for(&scenario, &genome, 12).run_with_log(&spec);
    assert!(
        log_a
            .iter()
            .zip(&log_c)
            .any(|(a, c)| a.makespan.to_bits() != c.makespan.to_bits()),
        "noise seed had no effect"
    );
}

#[test]
fn priority_orders_dispatch_under_contention() {
    // Three copies of the same heavy model, all pinned to the NPU, one per
    // group, submitted simultaneously. The ready queue must release them in
    // priority order (0 = highest precedence), not submission order.
    let scenario = Scenario::from_groups("prio", &[vec![8], vec![8], vec![8]]);
    let mut genome = Genome::all_on(&scenario.networks, Processor::Npu);
    genome.priority = vec![1, 2, 0]; // network/group 2 wins, then 0, then 1
    let mut harness = harness_for(&scenario, &genome, 3);
    harness.noisy = false;
    let spec = LoadSpec::periodic(&[1.0, 1.0, 1.0], 1); // one request each at t=0
    let (report, log) = harness.run_with_log(&spec);
    assert_eq!(report.served, 3);
    let completion_order: Vec<usize> = log.iter().map(|s| s.group).collect();
    assert_eq!(completion_order, vec![2, 0, 1], "dispatch ignored priorities");
    // Serialized on one worker: completions strictly increase.
    assert!(log.windows(2).all(|w| w[1].completion > w[0].completion));
}

#[test]
fn deadline_violations_appear_above_saturation() {
    // One NPU-friendly model. At a generous period every deadline holds; at
    // a period far below the service time the backlog grows and the tail of
    // the run violates.
    let scenario = Scenario::from_groups("overload", &[vec![0]]);
    let genome = Genome::all_on(&scenario.networks, Processor::Npu);
    let harness = harness_for(&scenario, &genome, 9);
    let perf = PerfModel::paper_calibrated();

    let relaxed = harness.run(&LoadSpec::for_scenario(&scenario, &perf, 3.0, 12));
    assert_eq!(relaxed.served, 12);
    assert_eq!(relaxed.violations, 0, "{relaxed:?}");
    assert!(relaxed.attainment == 1.0 && relaxed.score > 0.9);

    let overloaded = harness.run(&LoadSpec::for_scenario(&scenario, &perf, 0.05, 12));
    assert_eq!(overloaded.served, 12, "queue policy still serves everything");
    assert!(overloaded.violations > 0, "no violations under overload: {overloaded:?}");
    assert!(overloaded.attainment < 1.0);
    assert!(overloaded.score < relaxed.score);
    // Open-loop backlog: makespans grow toward the tail.
    let ms = &overloaded.group_makespans[0];
    assert!(ms.last().unwrap() > ms.first().unwrap());
}

#[test]
fn drop_policy_bounds_backlog() {
    let scenario = Scenario::from_groups("drops", &[vec![0]]);
    let genome = Genome::all_on(&scenario.networks, Processor::Npu);
    let harness = harness_for(&scenario, &genome, 17);
    let perf = PerfModel::paper_calibrated();
    let overload = LoadSpec::for_scenario(&scenario, &perf, 0.05, 16);

    let queued = harness.run(&overload);
    let dropping =
        harness.run(&overload.with_policy(OverloadPolicy::DropAfter { max_inflight: 2 }));
    assert!(dropping.dropped > 0, "drop policy never engaged");
    assert_eq!(dropping.served + dropping.dropped, dropping.submitted);
    // Admission control bounds the worst makespan the served requests see.
    let worst = |r: &puzzle::serve::ServeReport| {
        r.group_makespans[0].iter().cloned().fold(0.0f64, f64::max)
    };
    assert!(
        worst(&dropping) < worst(&queued),
        "drop policy did not bound the backlog: {} vs {}",
        worst(&dropping),
        worst(&queued)
    );
}

#[test]
fn bursty_load_inflates_tail_latency() {
    // Same long-run rate, clumped arrivals: the p90 makespan under bursts
    // must exceed the periodic p90 (queueing at the worker).
    let scenario = Scenario::from_groups("burst", &[vec![6]]);
    let genome = Genome::all_on(&scenario.networks, Processor::Npu);
    let mut harness = harness_for(&scenario, &genome, 21);
    harness.noisy = false;
    let perf = PerfModel::paper_calibrated();
    let periods = scenario.periods(1.1, &perf);
    let periodic = harness.run(&LoadSpec::periodic(&periods, 24));
    let bursty = harness.run(&LoadSpec::bursty(&periods, 6, 24));
    assert_eq!(periodic.served, 24);
    assert_eq!(bursty.served, 24);
    assert!(
        bursty.percentile(0, 0.9) > periodic.percentile(0, 0.9),
        "bursty p90 {} <= periodic p90 {}",
        bursty.percentile(0, 0.9),
        periodic.percentile(0, 0.9)
    );
}

#[test]
fn wall_clock_load_completes_and_converts_units() {
    // Wall mode on a light group at a compressing time scale: everything
    // serves, and the reported makespans come back in simulated seconds
    // (not wall seconds).
    let scenario = Scenario::from_groups("wall", &[vec![0, 1]]);
    let genome = Genome::all_on(&scenario.networks, Processor::Npu);
    let mut harness = harness_for(&scenario, &genome, 13);
    harness.time_scale = 2.0; // stretch: wall sleeps 2x simulated time
    let perf = PerfModel::paper_calibrated();
    let spec = LoadSpec::for_scenario(&scenario, &perf, 2.0, 5)
        .wall(std::time::Duration::from_secs(20));
    let report = harness.run(&spec);
    assert_eq!(report.served, 5);
    assert_eq!(report.dropped, 0);
    // Simulated makespans stay on the order of the models' service times
    // (sub-5ms), even though wall time was stretched 2x.
    for &m in &report.group_makespans[0] {
        assert!(m > 0.0 && m < 0.05, "makespan {m}s not in simulated units");
    }
}

#[test]
fn deployment_serve_load_end_to_end() {
    // The api surface: session → analysis → deploy (non-sleeping engine) →
    // serve_load under the virtual clock.
    let session = SessionBuilder::new(ScenarioSpec::single_group("api-load", vec![0, 2]))
        .config(GaConfig { population: 10, max_generations: 3, ..GaConfig::quick(7) })
        .build()
        .unwrap();
    let analysis = session.run();
    let mut deployment = analysis
        .deploy_sim(analysis.best_index(), RuntimeOptions::default(), 0.0, true, 7)
        .unwrap();
    let spec = LoadSpec::for_scenario(analysis.scenario(), analysis.perf(), 2.0, 12);
    let report = deployment.serve_load(&spec);
    deployment.shutdown();
    assert_eq!(report.submitted, 12);
    assert_eq!(report.served, 12);
    assert!(report.score > 0.5, "relaxed load should score well: {report:?}");
    assert!(report.group_makespans[0].iter().all(|&m| m > 0.0));
}

#[test]
fn warm_probes_bit_identical_to_fresh_deploys() {
    // The tentpole contract: a reused deployment, reset + re-seeded between
    // loads, replays every probe bit-identically to a fresh
    // Coordinator/Worker stack — across different α loads AND different
    // arrival patterns, including a replay after intervening traffic.
    let scenario = Scenario::from_groups("warm", &[vec![0, 1]]);
    let genome = Genome::all_on(&scenario.networks, Processor::Npu);
    let harness = harness_for(&scenario, &genome, 11);
    let perf = PerfModel::paper_calibrated();
    let periodic = LoadSpec::for_scenario(&scenario, &perf, 1.0, 12);
    let poisson = LoadSpec::poisson(&scenario.periods(1.0, &perf), 12, 5);

    let mut warm = harness.deploy(ClockMode::Virtual);
    let (wr_periodic, wl_periodic) = warm.probe_with_log(&periodic, 41);
    let (wr_poisson, wl_poisson) = warm.probe_with_log(&poisson, 43);
    let (wr_again, wl_again) = warm.probe_with_log(&periodic, 41);
    warm.shutdown();

    let fresh = |seed: u64, spec: &LoadSpec| {
        let mut h = harness.clone();
        h.seed = seed;
        h.run_with_log(spec)
    };
    let (fr_periodic, fl_periodic) = fresh(41, &periodic);
    let (fr_poisson, fl_poisson) = fresh(43, &poisson);

    assert!(!wl_periodic.is_empty() && !wl_poisson.is_empty());
    assert_logs_identical(&wl_periodic, &fl_periodic);
    assert_reports_identical(&wr_periodic, &fr_periodic);
    assert_logs_identical(&wl_poisson, &fl_poisson);
    assert_reports_identical(&wr_poisson, &fr_poisson);
    // Replaying after other traffic leaves no trace: bit-identical again.
    assert_logs_identical(&wl_again, &wl_periodic);
    assert_reports_identical(&wr_again, &wr_periodic);
}

#[test]
fn deployment_reset_leaves_no_stale_state() {
    // api surface: serve_load → reset → the warm runtime looks freshly
    // deployed (no served/dropped/in-flight state, request ids restart).
    let session = SessionBuilder::new(ScenarioSpec::single_group("api-reset", vec![0, 2]))
        .config(GaConfig { population: 10, max_generations: 3, ..GaConfig::quick(7) })
        .build()
        .unwrap();
    let analysis = session.run();
    let mut deployment = analysis
        .deploy_sim(analysis.best_index(), RuntimeOptions::default(), 0.0, true, 7)
        .unwrap();
    let overload = LoadSpec::for_scenario(analysis.scenario(), analysis.perf(), 0.05, 8)
        .with_policy(OverloadPolicy::DropAfter { max_inflight: 2 });
    let first = deployment.serve_load(&overload);
    assert!(first.dropped > 0, "overload with a tight cap must drop");
    assert!(!deployment.coordinator.served().is_empty());
    assert!(!deployment.coordinator.dropped().is_empty());

    deployment.reset_seeded(7);
    assert!(deployment.coordinator.served().is_empty(), "reset left served state");
    assert!(deployment.coordinator.dropped().is_empty(), "reset left dropped state");
    assert_eq!(deployment.coordinator.outstanding(), 0, "reset left in-flight state");

    // The replayed load is bit-identical to the first (same engine seed,
    // same request sequencing from 0).
    let second = deployment.serve_load(&overload);
    let min_id = deployment.coordinator.served().iter().map(|s| s.request).min();
    assert_eq!(min_id, Some(0), "request sequencing did not restart at 0");
    deployment.shutdown();
    assert_eq!(first.served, second.served);
    assert_eq!(first.dropped, second.dropped);
    assert_eq!(first.score.to_bits(), second.score.to_bits());
}

#[test]
fn saturation_deploys_exactly_once_per_solution_set() {
    // The acceptance bar: however many α-probes the bisection takes, the
    // driver spawns one runtime per solution set and reuses it.
    let scenario = Scenario::from_groups("one-deploy", &[vec![0, 1]]);
    let perf = Arc::new(PerfModel::paper_calibrated());
    // Two distinct solution sets, both NPU-mapped (so neither can be
    // certificate-skipped at alpha_max and both must deploy), differing in
    // dispatch priority.
    let genome_a = Genome::all_on(&scenario.networks, Processor::Npu);
    let mut genome_b = genome_a.clone();
    genome_b.priority.reverse();
    let sets = vec![
        materialize_solutions(&scenario.networks, &genome_a, &perf),
        materialize_solutions(&scenario.networks, &genome_b, &perf),
    ];
    let opts = SaturationOptions { requests: 8, tolerance: 0.05, ..Default::default() };
    let mut probes = 0usize;
    let mut deploys = 0usize;
    let _ = serve::saturation_via_runtime_observed(&sets, &scenario, &perf, &opts, &mut |p| {
        probes = p.probes;
        deploys = p.deploys;
        assert!(p.deploys <= sets.len(), "more deployments than solution sets");
        ControlFlow::Continue(())
    });
    assert!(probes >= 3, "bisection should take several probes, took {probes}");
    assert_eq!(
        deploys,
        sets.len(),
        "expected exactly one deployment per solution set over {probes} probes"
    );
}

#[test]
fn rho_seeded_bracket_never_skips_a_feasible_alpha() {
    // Property-style over random solution sets (hence random per-processor
    // rates): every α strictly below `rho_bracket_floor` is certified
    // infeasible for strictly more than half the sets — exactly the
    // driver's certificate on the driver's own ρ computation — so the
    // median score there is 0 and no feasible α is ever excluded from the
    // bisection bracket.
    let scenario = Scenario::from_groups("rho-prop", &[vec![0, 1]]);
    let perf = Arc::new(PerfModel::paper_calibrated());
    let groups: Vec<Vec<usize>> = scenario.groups.iter().map(|g| g.members.clone()).collect();
    puzzle::util::prop::check("rho-seeded bracket", 12, |rng| {
        let n_sets = rng.gen_range(1, 4);
        let sets: Vec<_> = (0..n_sets)
            .map(|_| {
                let genome = Genome::random(&scenario.networks, 0.3, rng);
                materialize_solutions(&scenario.networks, &genome, &perf)
            })
            .collect();
        let floor = rho_bracket_floor(&sets, &scenario, &perf);
        puzzle::prop_assert!(floor > 0.0, "floor must be positive, got {floor}");
        for _ in 0..8 {
            let alpha = floor * rng.gen_f64().max(1e-3) * 0.999;
            let spec = LoadSpec::periodic(&scenario.periods(alpha, &perf), 4);
            let rates = spec.mean_rates();
            let certified = sets
                .iter()
                .filter(|sols| {
                    offered_utilization(sols, &groups, &rates, &perf).iter().any(|&r| r > 1.0)
                })
                .count();
            puzzle::prop_assert!(
                certified > sets.len() / 2,
                "alpha {alpha} below floor {floor} but only {certified}/{} sets certified",
                sets.len()
            );
        }
        Ok(())
    });
}

#[test]
fn little_cap_admission_is_invisible_at_feasible_load() {
    // At comfortably feasible load the Little's-law cap never engages: the
    // capped run is bit-identical to unbounded queueing. (Under certified
    // overload the saturation driver skips the probe before admission
    // control could matter — that pairing is the design.)
    let scenario = Scenario::from_groups("little-feasible", &[vec![0, 1]]);
    let genome = Genome::all_on(&scenario.networks, Processor::Npu);
    let mut harness = harness_for(&scenario, &genome, 19);
    harness.noisy = false;
    let perf = PerfModel::paper_calibrated();
    let spec = LoadSpec::for_scenario(&scenario, &perf, 2.0, 12);
    let cap = serve::little_inflight_cap(
        &harness.solutions,
        &harness.groups,
        &spec.mean_rates(),
        &perf,
        3.0,
    );
    assert!(cap >= 1);
    let (queue_report, queue_log) = harness.run_with_log(&spec);
    let capped_spec = spec.with_policy(OverloadPolicy::DropAfter { max_inflight: cap });
    let (cap_report, cap_log) = harness.run_with_log(&capped_spec);
    assert_eq!(cap_report.dropped, 0, "cap {cap} engaged at feasible load");
    assert_logs_identical(&queue_log, &cap_log);
    assert_eq!(queue_report.score.to_bits(), cap_report.score.to_bits());
}

#[test]
fn chaos_probes_replay_bit_identically_including_recovery() {
    // The chaos determinism contract: same seed + same FaultPlan ⇒
    // bit-identical served logs and reports — including every retry and
    // every degraded-time bit — on fresh deployments AND on a warm
    // deployment replaying after intervening traffic.
    let scenario = Scenario::from_groups("chaos-replay", &[vec![0], vec![1]]);
    let genome = Genome::all_on(&scenario.networks, Processor::Npu);
    let perf = PerfModel::paper_calibrated();
    let plan = FaultPlan::new(5).slowdown(Processor::Npu, 2.0, 0.0, 1e3).transient(0.25);
    let harness = harness_for(&scenario, &genome, 11).with_fault_plan(plan);
    let spec = LoadSpec::for_scenario(&scenario, &perf, 2.0, 12);

    let (report_a, log_a) = harness.run_with_log(&spec);
    let (report_b, log_b) = harness.run_with_log(&spec);
    assert!(!log_a.is_empty());
    assert_logs_identical(&log_a, &log_b);
    assert_reports_identical(&report_a, &report_b);
    assert!(
        report_a.retries > 0,
        "transient p=0.25 over 24 requests should force retries: {report_a:?}"
    );
    assert!(report_a.degraded_time > 0.0, "retries must book degraded time");

    // Warm replay: reseed re-derives both the execution-noise stream and
    // the fault draw stream, so the chaos scenario replays bit-identically
    // even after the deployment served unrelated traffic.
    let mut warm = harness.deploy(ClockMode::Virtual);
    let _intervening = warm.probe_with_log(&spec, 99);
    let (wr, wl) = warm.probe_with_log(&spec, harness.seed);
    warm.shutdown();
    assert_logs_identical(&wl, &log_a);
    assert_reports_identical(&wr, &report_a);
}

#[test]
fn npu_stall_recovers_via_remap_and_measures_robust_alpha() {
    // Acceptance scenario: a persistent NPU stall on a multi-group,
    // all-NPU-mapped scenario. Every request must discover the stall
    // through the watchdog → retry → remap ladder and still complete (on
    // the next-best processor), and the degradation-aware saturation
    // search must report a positive robust-α* under the same plan.
    let scenario = Scenario::from_groups("chaos-stall", &[vec![0], vec![1]]);
    let genome = Genome::all_on(&scenario.networks, Processor::Npu);
    let perf = Arc::new(PerfModel::paper_calibrated());
    let plan = FaultPlan::new(3).stall(Processor::Npu, 0.0, 1e3);
    let harness = harness_for(&scenario, &genome, 7).with_fault_plan(plan.clone());
    let spec = LoadSpec::for_scenario(&scenario, &perf, 20.0, 6);

    let (report, log) = harness.run_with_log(&spec);
    assert_eq!(
        report.served, report.submitted,
        "every request must complete via remap: {report:?}"
    );
    assert_eq!(report.fault_shed, 0, "remap must succeed, not shed: {report:?}");
    assert!(report.remaps > 0 && report.retries > 0, "{report:?}");
    assert!(report.degraded_time > 0.0, "the discovery ladder must book degraded time");
    // Each group request is one single-subgraph network: the ladder is
    // exactly max_retries failed attempts, then one remap.
    assert!(
        log.iter().all(|s| s.remaps == 1 && s.retries == 2 && s.degraded > 0.0),
        "per-request recovery accounting off: {log:?}"
    );
    // Chaos replay holds for remaps too.
    let (_, log_again) = harness.run_with_log(&spec);
    assert_logs_identical(&log, &log_again);

    // Degradation-aware search: the same plan threaded through the
    // saturation driver yields a positive robust-α*. The stall prices a
    // full discovery ladder into every request, so the SLO threshold and
    // bracket are relaxed relative to the strict nominal defaults.
    let sets = vec![materialize_solutions(&scenario.networks, &genome, &perf)];
    let opts = SaturationOptions {
        requests: 6,
        alpha_max: 40.0,
        tolerance: 0.5,
        threshold: 0.5,
        fault_plan: Some(plan),
        ..Default::default()
    };
    let robust = serve::saturation_via_runtime(&sets, &scenario, &perf, &opts);
    let alpha = robust.expect("a relaxed-load probe under the stall must meet the threshold");
    assert!(alpha > 0.0, "robust alpha* must be positive, got {alpha}");
}

#[test]
fn empty_fault_plan_is_contractually_invisible() {
    // Zero-overhead contract, behavioral half: an empty FaultPlan (which
    // still wraps the engine in FaultyEngine and arms recovery) must be
    // bit-identical to the plain runtime across random genomes, loads, and
    // arrival patterns.
    let scenario = Scenario::from_groups("chaos-empty", &[vec![0, 1]]);
    let perf = PerfModel::paper_calibrated();
    puzzle::util::prop::check("empty fault plan identity", 10, |rng| {
        let genome = Genome::random(&scenario.networks, 0.3, rng);
        let seed = rng.gen_range(1, 1 << 16) as u64;
        let alpha = 0.8 + 1.7 * rng.gen_f64();
        let requests = rng.gen_range(4, 10);
        let periods = scenario.periods(alpha, &perf);
        let spec = match rng.gen_range(0, 3) {
            0 => LoadSpec::periodic(&periods, requests),
            1 => LoadSpec::poisson(&periods, requests, seed ^ 0xA5A5),
            _ => LoadSpec::bursty(&periods, 3, requests),
        };
        let plain = harness_for(&scenario, &genome, seed);
        let chaos = plain.clone().with_fault_plan(FaultPlan::default());
        let (pr, pl) = plain.run_with_log(&spec);
        let (cr, cl) = chaos.run_with_log(&spec);
        puzzle::prop_assert!(
            pl.len() == cl.len() && pl.iter().zip(&cl).all(|(x, y)| log_entries_equal(x, y)),
            "served logs diverged (seed {seed}, alpha {alpha:.3})"
        );
        puzzle::prop_assert!(
            pr.score.to_bits() == cr.score.to_bits()
                && (pr.served, pr.dropped, pr.violations)
                    == (cr.served, cr.dropped, cr.violations),
            "reports diverged (seed {seed}, alpha {alpha:.3}): {pr:?} vs {cr:?}"
        );
        puzzle::prop_assert!(
            (cr.retries, cr.remaps, cr.fault_shed) == (0, 0, 0),
            "an empty plan must never trip recovery: {cr:?}"
        );
        Ok(())
    });
}

#[test]
fn empty_plan_recovery_adds_zero_dispatch_allocations() {
    // Zero-overhead contract, allocation half: a steady-state probe on the
    // coordinator's dispatch thread performs exactly as many heap
    // allocations with an empty-plan FaultyEngine + armed recovery as with
    // the plain engine (the counting allocator is per-thread, so worker
    // threads cannot flake this).
    let scenario = Scenario::from_groups("chaos-alloc", &[vec![0, 1]]);
    let genome = Genome::all_on(&scenario.networks, Processor::Npu);
    let harness = harness_for(&scenario, &genome, 29);
    let perf = PerfModel::paper_calibrated();
    let spec = LoadSpec::for_scenario(&scenario, &perf, 1.2, 10);
    let measure = |h: &RuntimeHarness| -> (u64, ServeReport) {
        let mut d = h.deploy(ClockMode::Virtual);
        let _cold = d.probe(&spec, 41); // warm the pool, maps, and log capacity
        let before = puzzle::util::alloc::thread_allocations();
        let report = d.probe(&spec, 41);
        let delta = puzzle::util::alloc::thread_allocations() - before;
        d.shutdown();
        (delta, report)
    };
    let (plain_allocs, plain_report) = measure(&harness);
    let (chaos_allocs, chaos_report) =
        measure(&harness.clone().with_fault_plan(FaultPlan::default()));
    assert_eq!(
        chaos_allocs, plain_allocs,
        "empty-plan recovery changed the dispatch thread's allocation count"
    );
    assert_reports_identical(&chaos_report, &plain_report);
}

#[test]
fn mem_deltas_attribute_pool_traffic_per_load() {
    // Table 5 satellite: each report's pool counters cover exactly its own
    // load (snapshot deltas around run_load), and the per-load deltas sum
    // back to the warm coordinator's cumulative counters, which
    // Coordinator::reset deliberately leaves untouched.
    let scenario = Scenario::from_groups("mem-snap", &[vec![0, 1]]);
    let genome = Genome::all_on(&scenario.networks, Processor::Npu);
    let harness = harness_for(&scenario, &genome, 31);
    let perf = PerfModel::paper_calibrated();
    let spec = LoadSpec::for_scenario(&scenario, &perf, 1.5, 8);
    let mut d = harness.deploy(ClockMode::Virtual);
    let first = d.probe(&spec, 41);
    let second = d.probe(&spec, 41);
    let cumulative = d.coordinator().pool_stats();
    d.shutdown();
    assert!(first.mem.pool.mallocs > 0, "cold pool staged nothing: {:?}", first.mem);
    assert!(
        second.mem.pool.mallocs <= first.mem.pool.mallocs,
        "a warm pool must not allocate more than a cold one: {:?} then {:?}",
        first.mem,
        second.mem
    );
    assert_eq!(
        first.mem.pool.mallocs + second.mem.pool.mallocs,
        cumulative.1,
        "per-load deltas must sum to the cumulative pool counters"
    );
    // Identical warm probes replay identical pool traffic.
    let mut d2 = harness.deploy(ClockMode::Virtual);
    let _cold = d2.probe(&spec, 41);
    let again = d2.probe(&spec, 41);
    d2.shutdown();
    assert_eq!(again.mem.pool.mallocs, second.mem.pool.mallocs);
}

#[test]
fn telemetry_streams_bit_identical_fresh_vs_warm() {
    // Telemetry determinism contract: under the virtual clock the event
    // stream is part of the replay — a warm deployment re-probing the same
    // (spec, seed), even after intervening traffic, emits a byte-identical
    // JSON-lines stream to a fresh deployment's first probe.
    let scenario = Scenario::from_groups("tel-replay", &[vec![0, 1]]);
    let genome = Genome::all_on(&scenario.networks, Processor::Npu);
    let harness = harness_for(&scenario, &genome, 11);
    let perf = PerfModel::paper_calibrated();
    let spec = LoadSpec::for_scenario(&scenario, &perf, 1.0, 10);

    let mut fresh = harness.deploy(ClockMode::Virtual);
    let mut fresh_rx = fresh.subscribe();
    fresh.probe(&spec, 41);
    let fresh_lines: Vec<String> =
        fresh_rx.drain().iter().map(TelemetryEvent::to_json_line).collect();
    assert_eq!(fresh_rx.dropped(), 0, "ring overflowed");
    fresh.shutdown();

    let mut warm = harness.deploy(ClockMode::Virtual);
    let mut warm_rx = warm.subscribe();
    warm.probe(&spec, 99); // intervening traffic with a different seed
    warm_rx.drain();
    warm.probe(&spec, 41);
    let warm_lines: Vec<String> =
        warm_rx.drain().iter().map(TelemetryEvent::to_json_line).collect();
    warm.shutdown();

    assert!(!fresh_lines.is_empty());
    for kind in ["admitted", "task_dispatch", "task_complete", "served", "heartbeat"] {
        let tag = format!("\"event\":\"{kind}\"");
        assert!(
            fresh_lines.iter().any(|l| l.contains(&tag)),
            "stream is missing {kind} events: {fresh_lines:?}"
        );
    }
    assert_eq!(fresh_lines, warm_lines, "fresh and warm telemetry streams diverged");
}

#[test]
fn chaos_telemetry_streams_replay_bit_identically() {
    // The stream identity contract extends to the recovery machinery: under
    // a fault plan the retry/remap events (and under a flap plan the
    // duty-cycled transient failures they recover from) replay
    // byte-identically for the same seed, and the folded aggregation still
    // reproduces the chaos-accounted report exactly.
    let scenario = Scenario::from_groups("tel-chaos", &[vec![0], vec![1]]);
    let genome = Genome::all_on(&scenario.networks, Processor::Npu);
    let perf = PerfModel::paper_calibrated();
    let spec = LoadSpec::for_scenario(&scenario, &perf, 20.0, 6);
    let run = |plan: FaultPlan, seed: u64| -> (Vec<TelemetryEvent>, ServeReport) {
        let harness = harness_for(&scenario, &genome, seed).with_fault_plan(plan);
        let mut d = harness.deploy(ClockMode::Virtual);
        let mut rx = d.subscribe();
        let report = d.probe(&spec, seed);
        let events = rx.drain();
        assert_eq!(rx.dropped(), 0, "ring overflowed");
        d.shutdown();
        (events, report)
    };
    let lines = |events: &[TelemetryEvent]| -> Vec<String> {
        events.iter().map(TelemetryEvent::to_json_line).collect()
    };

    // Persistent NPU stall: every request walks the watchdog → retry →
    // remap ladder, all of it visible in the stream.
    let stall = FaultPlan::new(3).stall(Processor::Npu, 0.0, 1e3);
    let (ev_a, report_a) = run(stall.clone(), 7);
    let (ev_b, _) = run(stall, 7);
    assert_eq!(lines(&ev_a), lines(&ev_b), "stall streams diverged");
    assert!(report_a.retries > 0 && report_a.remaps > 0, "{report_a:?}");
    assert!(ev_a.iter().any(|e| e.kind() == "retry"), "no retry events in stream");
    assert!(ev_a.iter().any(|e| e.kind() == "remap"), "no remap events in stream");
    let mut agg = MetricsAggregator::new();
    agg.fold_all(&ev_a);
    agg.consistent_with(&report_a).expect("chaos aggregation must match the report");
    // No request is shed in this scenario, so every raw Retry event ends up
    // accounted on a served request: the two counters must agree.
    assert_eq!(agg.retry_events, report_a.retries, "raw retry events vs report");

    // Flap plan: duty-cycled transient windows draw from the same replayed
    // fault stream.
    let flap = FaultPlan::new(11).flap(Processor::Npu, 0.01, 0.4).transient(0.1);
    let (fl_a, fr_a) = run(flap.clone(), 13);
    let (fl_b, _) = run(flap, 13);
    assert_eq!(lines(&fl_a), lines(&fl_b), "flap streams diverged");
    let mut flap_agg = MetricsAggregator::new();
    flap_agg.fold_all(&fl_a);
    flap_agg.consistent_with(&fr_a).expect("flap aggregation must match the report");
}

#[test]
fn telemetry_aggregation_reproduces_serve_reports() {
    // Aggregation consistency, property-style: across random genomes,
    // loads, arrival patterns, and an occasional drop policy, folding the
    // drained event stream reproduces the probe's ServeReport exactly
    // (counts equal, f64 totals bit-equal).
    let scenario = Scenario::from_groups("tel-agg", &[vec![0, 1]]);
    let perf = PerfModel::paper_calibrated();
    puzzle::util::prop::check("telemetry aggregation == report", 10, |rng| {
        let genome = Genome::random(&scenario.networks, 0.3, rng);
        let seed = rng.gen_range(1, 1 << 16) as u64;
        let alpha = 0.6 + 1.9 * rng.gen_f64();
        let requests = rng.gen_range(4, 10);
        let periods = scenario.periods(alpha, &perf);
        let mut spec = match rng.gen_range(0, 3) {
            0 => LoadSpec::periodic(&periods, requests),
            1 => LoadSpec::poisson(&periods, requests, seed ^ 0x5A5A),
            _ => LoadSpec::bursty(&periods, 3, requests),
        };
        if rng.gen_bool(0.3) {
            // Exercise the overload-drop accounting path too.
            spec = spec.with_policy(OverloadPolicy::DropAfter { max_inflight: 2 });
        }
        let harness = harness_for(&scenario, &genome, seed);
        let mut d = harness.deploy(ClockMode::Virtual);
        let mut rx = d.subscribe();
        let report = d.probe(&spec, seed);
        let mut agg = MetricsAggregator::new();
        agg.fold_all(&rx.drain());
        let verdict = agg.consistent_with(&report);
        d.shutdown();
        puzzle::prop_assert!(
            verdict.is_ok(),
            "aggregation mismatch (seed {seed}, alpha {alpha:.3}): {verdict:?}"
        );
        Ok(())
    });
}

#[test]
fn telemetry_no_subscriber_is_invisible_and_armed_publish_is_alloc_free() {
    // The no-subscriber invisibility contract, allocation half: with no
    // subscriber a probe's dispatch-thread allocation count is the
    // steady-state baseline, and because the event ring is pre-allocated
    // and events are Copy, *arming* a subscriber must not change that count
    // either (draining happens outside the measured window). Behavioral
    // half: the armed probe's report is bit-identical to the disarmed one —
    // observation never perturbs the schedule.
    let scenario = Scenario::from_groups("tel-alloc", &[vec![0, 1]]);
    let genome = Genome::all_on(&scenario.networks, Processor::Npu);
    let harness = harness_for(&scenario, &genome, 29);
    let perf = PerfModel::paper_calibrated();
    let spec = LoadSpec::for_scenario(&scenario, &perf, 1.2, 10);

    let mut d = harness.deploy(ClockMode::Virtual);
    let _cold = d.probe(&spec, 41); // warm the pool, maps, and log capacity
    let before = puzzle::util::alloc::thread_allocations();
    let off_report = d.probe(&spec, 41);
    let off_allocs = puzzle::util::alloc::thread_allocations() - before;

    let mut rx = d.subscribe();
    let _warm_armed = d.probe(&spec, 41);
    rx.drain();
    let before = puzzle::util::alloc::thread_allocations();
    let on_report = d.probe(&spec, 41);
    let on_allocs = puzzle::util::alloc::thread_allocations() - before;
    let events = rx.drain();
    d.shutdown();

    assert!(!events.is_empty(), "armed probe emitted nothing");
    assert_eq!(
        on_allocs, off_allocs,
        "an armed subscriber changed the dispatch thread's allocation count"
    );
    assert_reports_identical(&off_report, &on_report);
}

#[test]
fn wall_driver_releases_arrivals_within_tight_error_bounds() {
    // Wall-mode release precision: the park-to-spin-tail sleeper must place
    // each arrival release within a tight error of its schedule. Errors are
    // measured between arrivals (arrival stamps and release targets share
    // the same clock up to a constant offset, which differencing cancels);
    // bounds are loose enough for a shared CI runner.
    let scenario = Scenario::from_groups("wall-precise", &[vec![0]]);
    let genome = Genome::all_on(&scenario.networks, Processor::Npu);
    let mut harness = harness_for(&scenario, &genome, 13);
    harness.noisy = false;
    harness.time_scale = 1.0;
    let period = 0.02;
    let spec = LoadSpec::periodic(&[period], 8).wall(std::time::Duration::from_secs(10));
    let (report, mut log) = harness.run_with_log(&spec);
    assert_eq!(report.served, 8);
    log.sort_by_key(|s| s.request);
    let t0 = log[0].arrival;
    let errors: Vec<f64> = log
        .iter()
        .enumerate()
        .map(|(j, s)| ((s.arrival - t0) - j as f64 * period).abs())
        .collect();
    let mut sorted = errors.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = sorted[sorted.len() / 2];
    let max = *sorted.last().unwrap();
    assert!(median < 1.5e-3, "median release error {median:.6}s too large: {errors:?}");
    assert!(max < 10e-3, "worst release error {max:.6}s too large: {errors:?}");
}

/// Run the fleet saturation search at one width and capture everything the
/// thread-count-invariance contract covers: the full bit-level
/// [`serve::ProbeProgress`] stream and the final α*.
fn fleet_run(
    sets: &[Vec<puzzle::serve::NetworkSolution>],
    scenario: &Scenario,
    perf: &Arc<PerfModel>,
    opts: &SaturationOptions,
    probe_threads: usize,
) -> (Option<u64>, Vec<(u64, u64, usize, usize, usize)>) {
    let opts = SaturationOptions { probe_threads, ..opts.clone() };
    let mut stream: Vec<(u64, u64, usize, usize, usize)> = Vec::new();
    let alpha = serve::saturation_via_runtime_observed(sets, scenario, perf, &opts, &mut |p| {
        stream.push((
            p.alpha.to_bits(),
            p.score.to_bits(),
            p.probes,
            p.certified_infeasible,
            p.deploys,
        ));
        ControlFlow::Continue(())
    });
    (alpha.map(f64::to_bits), stream)
}

#[test]
fn fleet_saturation_bit_identical_across_probe_threads() {
    // Determinism contract #6 (thread-count invariance): the fleet-probed
    // saturation search streams the exact per-probe sequence — every α
    // bit, every median-score bit, every certificate and deploy count —
    // and returns the same α* as the serial path, whatever the width.
    let scenario = Scenario::from_groups("fleet", &[vec![0, 1]]);
    let perf = Arc::new(PerfModel::paper_calibrated());
    let mut rng = puzzle::util::rng::Rng::seed_from_u64(61);
    let mut sets = vec![materialize_solutions(
        &scenario.networks,
        &Genome::all_on(&scenario.networks, Processor::Npu),
        &perf,
    )];
    sets.extend((0..4).map(|_| {
        let genome = Genome::random(&scenario.networks, 0.3, &mut rng);
        materialize_solutions(&scenario.networks, &genome, &perf)
    }));
    let opts = SaturationOptions { requests: 6, tolerance: 0.1, ..Default::default() };
    let serial = fleet_run(&sets, &scenario, &perf, &opts, 1);
    assert!(!serial.1.is_empty(), "search must stream at least one probe");
    for threads in [2, 4, 8] {
        let fleet = fleet_run(&sets, &scenario, &perf, &opts, threads);
        assert_eq!(fleet, serial, "fleet width {threads} diverged from serial");
    }
}

#[test]
fn fleet_chaos_saturation_matches_serial_robust_alpha() {
    // The invariance contract extends to chaos probing: with a FaultPlan
    // threaded through every fleet deployment, the robust-α* search and
    // its probe stream replay bit-identically at every width.
    let scenario = Scenario::from_groups("fleet-chaos", &[vec![0], vec![1]]);
    let perf = Arc::new(PerfModel::paper_calibrated());
    let genome_a = Genome::all_on(&scenario.networks, Processor::Npu);
    let mut genome_b = genome_a.clone();
    genome_b.priority.reverse();
    let sets = vec![
        materialize_solutions(&scenario.networks, &genome_a, &perf),
        materialize_solutions(&scenario.networks, &genome_b, &perf),
    ];
    let opts = SaturationOptions {
        requests: 6,
        alpha_max: 40.0,
        tolerance: 0.5,
        threshold: 0.5,
        fault_plan: Some(FaultPlan::new(3).stall(Processor::Npu, 0.0, 1e3)),
        ..Default::default()
    };
    let serial = fleet_run(&sets, &scenario, &perf, &opts, 1);
    assert!(serial.0.is_some(), "the stall scenario must still yield a robust α*");
    for threads in [2, 4, 8] {
        let fleet = fleet_run(&sets, &scenario, &perf, &opts, threads);
        assert_eq!(fleet, serial, "chaos fleet width {threads} diverged from serial");
    }
}

#[test]
fn budgeted_fleet_saturation_bit_identical_for_any_capacity() {
    // Determinism contract #6 under the dynamic core budget: leasing the
    // probe fleet's width per α-probe from a shared semaphore — whatever
    // its capacity — must replay the serial probe stream bit-for-bit.
    // The `probe_threads` knob is superseded by the lease (no
    // double-clamp), so it is deliberately varied alongside the capacity.
    use puzzle::util::threads::CoreBudget;
    let scenario = Scenario::from_groups("fleet-budget", &[vec![0, 1]]);
    let perf = Arc::new(PerfModel::paper_calibrated());
    let mut rng = puzzle::util::rng::Rng::seed_from_u64(61);
    let mut sets = vec![materialize_solutions(
        &scenario.networks,
        &Genome::all_on(&scenario.networks, Processor::Npu),
        &perf,
    )];
    sets.extend((0..4).map(|_| {
        let genome = Genome::random(&scenario.networks, 0.3, &mut rng);
        materialize_solutions(&scenario.networks, &genome, &perf)
    }));
    let base = SaturationOptions { requests: 6, tolerance: 0.1, ..Default::default() };
    let serial = fleet_run(&sets, &scenario, &perf, &base, 1);
    assert!(!serial.1.is_empty(), "search must stream at least one probe");
    for (capacity, requested) in [(1usize, 0usize), (2, 1), (4, 8), (8, 2)] {
        let opts =
            SaturationOptions { core_budget: Some(CoreBudget::new(capacity)), ..base.clone() };
        let budgeted = fleet_run(&sets, &scenario, &perf, &opts, requested);
        assert_eq!(
            budgeted, serial,
            "core budget {capacity} (requested {requested}) diverged from serial"
        );
    }
}

#[test]
fn budgeted_chaos_fleet_matches_serial_robust_alpha() {
    // The budget-invariance contract extends to chaos probing: the
    // robust-α* search with a FaultPlan on every deployment replays
    // bit-identically for any core-budget capacity.
    use puzzle::util::threads::CoreBudget;
    let scenario = Scenario::from_groups("fleet-chaos-budget", &[vec![0], vec![1]]);
    let perf = Arc::new(PerfModel::paper_calibrated());
    let genome_a = Genome::all_on(&scenario.networks, Processor::Npu);
    let mut genome_b = genome_a.clone();
    genome_b.priority.reverse();
    let sets = vec![
        materialize_solutions(&scenario.networks, &genome_a, &perf),
        materialize_solutions(&scenario.networks, &genome_b, &perf),
    ];
    let base = SaturationOptions {
        requests: 6,
        alpha_max: 40.0,
        tolerance: 0.5,
        threshold: 0.5,
        fault_plan: Some(FaultPlan::new(3).stall(Processor::Npu, 0.0, 1e3)),
        ..Default::default()
    };
    let serial = fleet_run(&sets, &scenario, &perf, &base, 1);
    assert!(serial.0.is_some(), "the stall scenario must still yield a robust α*");
    for capacity in [1usize, 2, 4, 8] {
        let opts =
            SaturationOptions { core_budget: Some(CoreBudget::new(capacity)), ..base.clone() };
        let budgeted = fleet_run(&sets, &scenario, &perf, &opts, 0);
        assert_eq!(budgeted, serial, "chaos core budget {capacity} diverged from serial");
    }
}

#[test]
fn concurrent_warm_probes_bit_identical_to_serial_across_arrival_patterns() {
    // The isolation contract underneath the fleet: deployments probed on
    // scoped worker threads replay bit-identically to the same probes run
    // serially — per-deployment noise and telemetry state never leak
    // across threads — for periodic, Poisson, and bursty load alike.
    let scenario = Scenario::from_groups("fleet-iso", &[vec![0, 1]]);
    let perf = PerfModel::paper_calibrated();
    let periods = scenario.periods(1.0, &perf);
    let specs = [
        LoadSpec::periodic(&periods, 10),
        LoadSpec::poisson(&periods, 10, 5),
        LoadSpec::bursty(&periods, 3, 10),
    ];
    let mut rng = puzzle::util::rng::Rng::seed_from_u64(67);
    let genomes: Vec<Genome> =
        (0..3).map(|_| Genome::random(&scenario.networks, 0.3, &mut rng)).collect();
    let probe_all = |genome: &Genome| -> Vec<(ServeReport, Vec<ServedRequest>)> {
        let mut d = harness_for(&scenario, genome, 11).deploy(ClockMode::Virtual);
        let out = specs
            .iter()
            .enumerate()
            .map(|(k, spec)| d.probe_with_log(spec, serve::probe_seed(11, k, 1.0)))
            .collect();
        d.shutdown();
        out
    };
    let serial: Vec<Vec<(ServeReport, Vec<ServedRequest>)>> =
        genomes.iter().map(probe_all).collect();
    let mut parallel: Vec<Option<Vec<(ServeReport, Vec<ServedRequest>)>>> = Vec::new();
    parallel.resize_with(genomes.len(), || None);
    std::thread::scope(|scope| {
        for (genome, out) in genomes.iter().zip(parallel.iter_mut()) {
            let probe_all = &probe_all;
            scope.spawn(move || *out = Some(probe_all(genome)));
        }
    });
    for (s_runs, p_runs) in serial.iter().zip(&parallel) {
        let p_runs = p_runs.as_ref().expect("every worker finished");
        for ((sr, sl), (pr, pl)) in s_runs.iter().zip(p_runs) {
            assert!(!sl.is_empty());
            assert_logs_identical(sl, pl);
            assert_reports_identical(sr, pr);
        }
    }
}

#[test]
fn dispatch_overhead_zero_is_bit_identical_and_positive_inflates_makespans() {
    // RuntimeOptions::dispatch_overhead: the default 0.0 replays the
    // uncalibrated virtual schedule bit-for-bit, while positive values —
    // priced per task into run_virtual — inflate every makespan
    // monotonically. A single NPU-pinned network keeps the queue FIFO,
    // so per-request monotonicity is exact (no priority overtaking).
    let scenario = Scenario::from_groups("overhead", &[vec![0]]);
    let genome = Genome::all_on(&scenario.networks, Processor::Npu);
    let perf = PerfModel::paper_calibrated();
    let spec = LoadSpec::periodic(&scenario.periods(1.5, &perf), 8);
    let run = |options: RuntimeOptions| -> Vec<ServedRequest> {
        let mut harness = harness_for(&scenario, &genome, 17);
        harness.options = options;
        let (_, mut log) = harness.run_with_log(&spec);
        log.sort_by_key(|s| (s.group, s.request));
        log
    };
    let base = run(RuntimeOptions { dispatch_overhead: 0.0, ..Default::default() });
    assert!(!base.is_empty());
    assert_logs_identical(&base, &run(RuntimeOptions::default()));
    let mut last = base;
    for overhead in [1e-5, 1e-4, 1e-3] {
        let inflated = run(RuntimeOptions { dispatch_overhead: overhead, ..Default::default() });
        assert_eq!(inflated.len(), last.len());
        for (lo, hi) in last.iter().zip(&inflated) {
            assert_eq!((lo.group, lo.request), (hi.group, hi.request));
            assert!(
                hi.makespan > lo.makespan,
                "overhead {overhead}: request {} makespan did not grow ({} vs {})",
                hi.request,
                lo.makespan,
                hi.makespan
            );
        }
        last = inflated;
    }
}

#[test]
fn materialized_baseline_matches_api_deployment_shape() {
    // materialize_solutions (the baseline entry into the harness) produces
    // the same solution shape as Analysis::runtime_solutions.
    let scenario = Scenario::from_groups("shape", &[vec![0, 4]]);
    let perf = PerfModel::paper_calibrated();
    let genome = Genome::all_on(&scenario.networks, Processor::Gpu);
    let sols = materialize_solutions(&scenario.networks, &genome, &perf);
    assert_eq!(sols.len(), 2);
    for (i, sol) in sols.iter().enumerate() {
        assert_eq!(sol.priority, genome.priority[i]);
        assert_eq!(sol.partition.subgraphs.len(), sol.configs.len());
        for (sg, cfg) in sol.partition.subgraphs.iter().zip(&sol.configs) {
            assert_eq!(cfg.processor, sg.processor);
        }
    }
}
