//! Integration tests of the arrival-driven serving runtime: virtual-clock
//! determinism, priority-ordered dispatch under contention, deadline
//! accounting above saturation, overload policies, and the
//! `Deployment::serve_load` api surface.

use std::sync::Arc;

use puzzle::analyzer::GaConfig;
use puzzle::api::{LoadSpec, OverloadPolicy, RuntimeOptions, ScenarioSpec, SessionBuilder};
use puzzle::ga::Genome;
use puzzle::perf::PerfModel;
use puzzle::scenario::Scenario;
use puzzle::serve::{materialize_solutions, RuntimeHarness};
use puzzle::Processor;

fn harness_for(scenario: &Scenario, genome: &Genome, seed: u64) -> RuntimeHarness {
    let perf = Arc::new(PerfModel::paper_calibrated());
    RuntimeHarness::for_genome(scenario, genome, &perf, seed)
}

#[test]
fn virtual_clock_logs_bit_identical_across_runs() {
    // Same seed, same (Poisson!) load, fresh runtime each run: the
    // ServedRequest logs must agree to the last f64 bit — arrivals,
    // completions, makespans, verdicts.
    let scenario = Scenario::from_groups("det", &[vec![0, 1]]);
    let genome = Genome::all_on(&scenario.networks, Processor::Npu);
    let harness = harness_for(&scenario, &genome, 11);
    let perf = PerfModel::paper_calibrated();
    let spec = LoadSpec::poisson(&scenario.periods(1.0, &perf), 15, 5);
    let (report_a, log_a) = harness.run_with_log(&spec);
    let (_, log_b) = harness.run_with_log(&spec);
    assert_eq!(report_a.served, 15);
    assert_eq!(log_a.len(), log_b.len());
    for (a, b) in log_a.iter().zip(&log_b) {
        assert_eq!((a.group, a.request), (b.group, b.request));
        assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
        assert_eq!(a.completion.to_bits(), b.completion.to_bits());
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.violated, b.violated);
    }
    // A different noise seed produces a different schedule (the determinism
    // is per seed, not an accident of a noise-free path).
    let (_, log_c) = harness_for(&scenario, &genome, 12).run_with_log(&spec);
    assert!(
        log_a
            .iter()
            .zip(&log_c)
            .any(|(a, c)| a.makespan.to_bits() != c.makespan.to_bits()),
        "noise seed had no effect"
    );
}

#[test]
fn priority_orders_dispatch_under_contention() {
    // Three copies of the same heavy model, all pinned to the NPU, one per
    // group, submitted simultaneously. The ready queue must release them in
    // priority order (0 = highest precedence), not submission order.
    let scenario = Scenario::from_groups("prio", &[vec![8], vec![8], vec![8]]);
    let mut genome = Genome::all_on(&scenario.networks, Processor::Npu);
    genome.priority = vec![1, 2, 0]; // network/group 2 wins, then 0, then 1
    let mut harness = harness_for(&scenario, &genome, 3);
    harness.noisy = false;
    let spec = LoadSpec::periodic(&[1.0, 1.0, 1.0], 1); // one request each at t=0
    let (report, log) = harness.run_with_log(&spec);
    assert_eq!(report.served, 3);
    let completion_order: Vec<usize> = log.iter().map(|s| s.group).collect();
    assert_eq!(completion_order, vec![2, 0, 1], "dispatch ignored priorities");
    // Serialized on one worker: completions strictly increase.
    assert!(log.windows(2).all(|w| w[1].completion > w[0].completion));
}

#[test]
fn deadline_violations_appear_above_saturation() {
    // One NPU-friendly model. At a generous period every deadline holds; at
    // a period far below the service time the backlog grows and the tail of
    // the run violates.
    let scenario = Scenario::from_groups("overload", &[vec![0]]);
    let genome = Genome::all_on(&scenario.networks, Processor::Npu);
    let harness = harness_for(&scenario, &genome, 9);
    let perf = PerfModel::paper_calibrated();

    let relaxed = harness.run(&LoadSpec::for_scenario(&scenario, &perf, 3.0, 12));
    assert_eq!(relaxed.served, 12);
    assert_eq!(relaxed.violations, 0, "{relaxed:?}");
    assert!(relaxed.attainment == 1.0 && relaxed.score > 0.9);

    let overloaded = harness.run(&LoadSpec::for_scenario(&scenario, &perf, 0.05, 12));
    assert_eq!(overloaded.served, 12, "queue policy still serves everything");
    assert!(overloaded.violations > 0, "no violations under overload: {overloaded:?}");
    assert!(overloaded.attainment < 1.0);
    assert!(overloaded.score < relaxed.score);
    // Open-loop backlog: makespans grow toward the tail.
    let ms = &overloaded.group_makespans[0];
    assert!(ms.last().unwrap() > ms.first().unwrap());
}

#[test]
fn drop_policy_bounds_backlog() {
    let scenario = Scenario::from_groups("drops", &[vec![0]]);
    let genome = Genome::all_on(&scenario.networks, Processor::Npu);
    let harness = harness_for(&scenario, &genome, 17);
    let perf = PerfModel::paper_calibrated();
    let overload = LoadSpec::for_scenario(&scenario, &perf, 0.05, 16);

    let queued = harness.run(&overload);
    let dropping =
        harness.run(&overload.with_policy(OverloadPolicy::DropAfter { max_inflight: 2 }));
    assert!(dropping.dropped > 0, "drop policy never engaged");
    assert_eq!(dropping.served + dropping.dropped, dropping.submitted);
    // Admission control bounds the worst makespan the served requests see.
    let worst = |r: &puzzle::serve::ServeReport| {
        r.group_makespans[0].iter().cloned().fold(0.0f64, f64::max)
    };
    assert!(
        worst(&dropping) < worst(&queued),
        "drop policy did not bound the backlog: {} vs {}",
        worst(&dropping),
        worst(&queued)
    );
}

#[test]
fn bursty_load_inflates_tail_latency() {
    // Same long-run rate, clumped arrivals: the p90 makespan under bursts
    // must exceed the periodic p90 (queueing at the worker).
    let scenario = Scenario::from_groups("burst", &[vec![6]]);
    let genome = Genome::all_on(&scenario.networks, Processor::Npu);
    let mut harness = harness_for(&scenario, &genome, 21);
    harness.noisy = false;
    let perf = PerfModel::paper_calibrated();
    let periods = scenario.periods(1.1, &perf);
    let periodic = harness.run(&LoadSpec::periodic(&periods, 24));
    let bursty = harness.run(&LoadSpec::bursty(&periods, 6, 24));
    assert_eq!(periodic.served, 24);
    assert_eq!(bursty.served, 24);
    assert!(
        bursty.percentile(0, 0.9) > periodic.percentile(0, 0.9),
        "bursty p90 {} <= periodic p90 {}",
        bursty.percentile(0, 0.9),
        periodic.percentile(0, 0.9)
    );
}

#[test]
fn wall_clock_load_completes_and_converts_units() {
    // Wall mode on a light group at a compressing time scale: everything
    // serves, and the reported makespans come back in simulated seconds
    // (not wall seconds).
    let scenario = Scenario::from_groups("wall", &[vec![0, 1]]);
    let genome = Genome::all_on(&scenario.networks, Processor::Npu);
    let mut harness = harness_for(&scenario, &genome, 13);
    harness.time_scale = 2.0; // stretch: wall sleeps 2x simulated time
    let perf = PerfModel::paper_calibrated();
    let spec = LoadSpec::for_scenario(&scenario, &perf, 2.0, 5)
        .wall(std::time::Duration::from_secs(20));
    let report = harness.run(&spec);
    assert_eq!(report.served, 5);
    assert_eq!(report.dropped, 0);
    // Simulated makespans stay on the order of the models' service times
    // (sub-5ms), even though wall time was stretched 2x.
    for &m in &report.group_makespans[0] {
        assert!(m > 0.0 && m < 0.05, "makespan {m}s not in simulated units");
    }
}

#[test]
fn deployment_serve_load_end_to_end() {
    // The api surface: session → analysis → deploy (non-sleeping engine) →
    // serve_load under the virtual clock.
    let session = SessionBuilder::new(ScenarioSpec::single_group("api-load", vec![0, 2]))
        .config(GaConfig { population: 10, max_generations: 3, ..GaConfig::quick(7) })
        .build()
        .unwrap();
    let analysis = session.run();
    let mut deployment = analysis
        .deploy_sim(analysis.best_index(), RuntimeOptions::default(), 0.0, true, 7)
        .unwrap();
    let spec = LoadSpec::for_scenario(analysis.scenario(), analysis.perf(), 2.0, 12);
    let report = deployment.serve_load(&spec);
    deployment.shutdown();
    assert_eq!(report.submitted, 12);
    assert_eq!(report.served, 12);
    assert!(report.score > 0.5, "relaxed load should score well: {report:?}");
    assert!(report.group_makespans[0].iter().all(|&m| m > 0.0));
}

#[test]
fn materialized_baseline_matches_api_deployment_shape() {
    // materialize_solutions (the baseline entry into the harness) produces
    // the same solution shape as Analysis::runtime_solutions.
    let scenario = Scenario::from_groups("shape", &[vec![0, 4]]);
    let perf = PerfModel::paper_calibrated();
    let genome = Genome::all_on(&scenario.networks, Processor::Gpu);
    let sols = materialize_solutions(&scenario.networks, &genome, &perf);
    assert_eq!(sols.len(), 2);
    for (i, sol) in sols.iter().enumerate() {
        assert_eq!(sol.priority, genome.priority[i]);
        assert_eq!(sol.partition.subgraphs.len(), sol.configs.len());
        for (sg, cfg) in sol.partition.subgraphs.iter().zip(&sol.configs) {
            assert_eq!(cfg.processor, sg.processor);
        }
    }
}
