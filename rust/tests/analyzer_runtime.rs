//! Integration tests across the Static Analyzer → Runtime boundary: the GA's
//! chosen solution must register, serve, and produce makespans consistent
//! with what the simulator promised.

use std::sync::Arc;

use puzzle::analyzer::GaConfig;
use puzzle::api::{RuntimeOptions, ScenarioSpec, SessionBuilder};
use puzzle::coordinator::{Coordinator, NetworkSolution};
use puzzle::engine::{Engine, SimEngine};
use puzzle::ga::decode_network;
use puzzle::perf::PerfModel;
use puzzle::scenario::Scenario;

#[test]
fn analyzer_solution_serves_through_runtime() {
    // The full api flow: session → analysis → deployment, with the
    // simulated engine at a time scale that keeps wall time short while
    // still exercising the real threads/queues.
    let session = SessionBuilder::new(ScenarioSpec::single_group("int", vec![0, 2]))
        .config(GaConfig::quick(5))
        .build()
        .unwrap();
    let analysis = session.run();
    let objectives = analysis.best().objectives.clone();
    let mut deployment = analysis
        .deploy_sim(analysis.best_index(), RuntimeOptions::default(), 0.05, false, 9)
        .unwrap();
    let served = deployment.serve(0, 10, std::time::Duration::from_secs(10));
    assert_eq!(served, 10, "all group requests served");
    // Simulated makespans (wall / time-scale) should be within a loose
    // factor of the analyzer's promise (thread scheduling overhead makes
    // the runtime a bit slower, never 10x).
    let sim_promise = objectives[0]; // avg makespan objective
    for simulated in deployment.simulated_makespans() {
        assert!(
            simulated < sim_promise * 10.0 + 0.5,
            "runtime makespan {simulated} vastly exceeds promise {sim_promise}"
        );
    }
    deployment.shutdown();
}

#[test]
fn runtime_ablation_accounting_direction_holds() {
    // Fig 10/Table 5's mechanism, asserted on the runtime's own accounting
    // (wall-clock makespans at this scale are dominated by 1-cpu thread
    // jitter, so we check the allocator/memcpy counters instead): the
    // tensor pool must recycle buffers, and the zero-copy shared buffer
    // must remove arena marshalling copies entirely.
    use puzzle::ga::NetworkGenes;
    use puzzle::models::build_model;
    use puzzle::Processor;

    let pm = PerfModel::paper_calibrated();
    // Force a partitioned, cross-processor solution so the arena actually
    // carries tensors.
    let net = build_model(0, 6); // yolov8n
    let mut genes = NetworkGenes::whole_on(&net, Processor::Npu);
    genes.cuts[7] = true;
    for l in 9..net.num_layers() {
        genes.mapping[l] = Processor::Gpu;
    }
    let part = decode_network(&net, &genes);
    assert!(!part.cut_edges.is_empty());
    let configs = part
        .subgraphs
        .iter()
        .map(|sg| pm.best_config_for(&net, &sg.layers, sg.processor).0)
        .collect();
    let solution = NetworkSolution {
        network: Arc::new(net),
        partition: Arc::new(part),
        configs,
        priority: 0,
    };

    let run = |opts: RuntimeOptions| -> (u64, u64, u64) {
        let engine: Arc<dyn Engine> = Arc::new(SimEngine::new(
            Arc::new(PerfModel::paper_calibrated()),
            0.0,
            false,
            3,
        ));
        let mut coord = Coordinator::new(vec![solution.clone()], engine, opts);
        for _ in 0..10 {
            coord.submit_group(0, &[0]);
            coord.pump(std::time::Duration::from_secs(10));
        }
        assert_eq!(coord.served().len(), 10);
        let (_, malloc_count, _, _) = coord.pool_stats();
        let arena_memcpy = coord
            .arena
            .stats
            .memcpy_bytes
            .load(std::sync::atomic::Ordering::Relaxed);
        let arena_mallocs = coord
            .arena
            .stats
            .malloc_count
            .load(std::sync::atomic::Ordering::Relaxed);
        coord.shutdown();
        (malloc_count, arena_memcpy, arena_mallocs)
    };

    let (_, copy_bytes, copy_allocs) =
        run(RuntimeOptions { tensor_pool: false, zero_copy: false, ..Default::default() });
    let (_, zc_bytes, zc_allocs) =
        run(RuntimeOptions { tensor_pool: true, zero_copy: true, ..Default::default() });
    // Copying mode marshals every cross-processor tensor; zero-copy moves none.
    assert!(copy_bytes > 0, "copying mode recorded no memcpy");
    assert_eq!(zc_bytes, 0, "zero-copy mode still copied {zc_bytes} bytes");
    // Both modes publish the same number of tensors.
    assert_eq!(copy_allocs, zc_allocs);
}

#[test]
fn pareto_solutions_are_mutually_nondominated() {
    let scenario = Scenario::from_groups("pareto", &[vec![0, 4, 6]]);
    let analysis = SessionBuilder::for_scenario(scenario)
        .config(GaConfig::quick(11))
        .build()
        .unwrap()
        .run();
    assert!(!analysis.pareto.is_empty());
    for a in &analysis.pareto {
        for b in &analysis.pareto {
            let dominates = a
                .objectives
                .iter()
                .zip(&b.objectives)
                .all(|(x, y)| x <= y)
                && a.objectives != b.objectives;
            assert!(!dominates, "pareto set contains dominated point");
        }
    }
}

#[test]
fn priorities_respected_under_contention() {
    // Two identical single-subgraph networks pinned to the NPU: the one with
    // better (lower) priority should win the queue when both are submitted.
    use puzzle::models::build_model;
    use puzzle::ga::NetworkGenes;
    use puzzle::Processor;

    let pm = PerfModel::paper_calibrated();
    let mk = |prio: usize| {
        let net = build_model(0, 8); // fastsam (long-running)
        let genes = NetworkGenes::whole_on(&net, Processor::Npu);
        let part = decode_network(&net, &genes);
        let configs = part
            .subgraphs
            .iter()
            .map(|sg| pm.best_config_for(&net, &sg.layers, sg.processor).0)
            .collect();
        NetworkSolution {
            network: Arc::new(net),
            partition: Arc::new(part),
            configs,
            priority: prio,
        }
    };
    let engine: Arc<dyn Engine> = Arc::new(SimEngine::new(
        Arc::new(PerfModel::paper_calibrated()),
        0.02,
        false,
        1,
    ));
    let mut coord = Coordinator::new(vec![mk(1), mk(0)], engine, RuntimeOptions::default());
    coord.submit_group(0, &[0]);
    coord.submit_group(1, &[1]);
    coord.pump(std::time::Duration::from_secs(20));
    assert_eq!(coord.served().len(), 2);
    coord.shutdown();
}
