//! Property-based tests over the system's core invariants, using the
//! in-tree `util::prop` loop (proptest is unavailable offline). Each
//! property runs against randomized networks / genomes / plan sets; failing
//! seeds are reported for exact reproduction.

use puzzle::comm::CommModel;
use puzzle::ga::{
    decode_network, fast_non_dominated_sort, mutate, nsga3_select, one_point_crossover, upmx,
    Genome, NetworkGenes, SelectionWorkspace,
};
use puzzle::graph::{partition, Layer, LayerId, Network};
use puzzle::metrics;
use puzzle::models::{build_model, MODEL_COUNT};
use puzzle::perf::PerfModel;
use puzzle::sim::{simulate, ExecutionPlan, GroupSpec, PlannedTask, PlannedTransfer, SimOptions};
use puzzle::util::prop::check;
use puzzle::util::rng::Rng;
use puzzle::Processor;

/// A random small DAG network (chain + random skip edges).
fn random_network(rng: &mut Rng) -> Network {
    let n_layers = rng.gen_range(2, 12);
    let mut net = Network::new(0, "prop_net");
    let mut ids = Vec::new();
    for i in 0..n_layers {
        ids.push(net.add_layer(Layer::conv(&format!("l{i}"), 16, 8, 8, 3, 1)));
    }
    // Chain backbone guarantees connectivity + acyclicity.
    for w in ids.windows(2) {
        net.connect(w[0], w[1]);
    }
    // Random forward skip edges.
    let extra = rng.gen_range(0, n_layers);
    for _ in 0..extra {
        let a = rng.gen_range(0, n_layers - 1);
        let b = rng.gen_range(a + 1, n_layers);
        if net.edge_between(LayerId(a), LayerId(b)).is_none() {
            net.connect(LayerId(a), LayerId(b));
        }
    }
    net.finalize();
    net
}

fn random_mapping(rng: &mut Rng, n: usize) -> Vec<Processor> {
    (0..n).map(|_| Processor::from_index(rng.gen_range(0, 3))).collect()
}

#[test]
fn prop_partition_covers_every_layer_exactly_once() {
    check("partition covers layers", 200, |rng| {
        let net = random_network(rng);
        let cuts: Vec<bool> = (0..net.num_edges()).map(|_| rng.gen_bool(0.5)).collect();
        let p = partition(&net, &cuts, &random_mapping(rng, net.num_layers()));
        let mut counts = vec![0usize; net.num_layers()];
        for sg in &p.subgraphs {
            for l in &sg.layers {
                counts[l.0] += 1;
            }
        }
        if counts.iter().any(|&c| c != 1) {
            return Err(format!("coverage {counts:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_partition_condensed_graph_is_acyclic() {
    // The convexity repair must always yield a schedulable (acyclic)
    // subgraph DAG, whatever the chromosome says.
    check("partition acyclic", 300, |rng| {
        let net = random_network(rng);
        let cuts: Vec<bool> = (0..net.num_edges()).map(|_| rng.gen_bool(0.5)).collect();
        let p = partition(&net, &cuts, &random_mapping(rng, net.num_layers()));
        // Kahn over subgraph deps.
        let n = p.subgraphs.len();
        let mut indeg = vec![0usize; n];
        for sg in &p.subgraphs {
            indeg[sg.id.0] = sg.deps.len();
        }
        let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut drained = 0;
        while let Some(i) = ready.pop() {
            drained += 1;
            for sg in &p.subgraphs {
                if sg.deps.contains(&puzzle::graph::SubgraphId(i)) {
                    indeg[sg.id.0] -= 1;
                    if indeg[sg.id.0] == 0 {
                        ready.push(sg.id.0);
                    }
                }
            }
        }
        if drained != n {
            return Err(format!("cyclic condensed graph: drained {drained} of {n}"));
        }
        Ok(())
    });
}

#[test]
fn prop_partition_subgraph_layers_internally_connected_or_singleton() {
    // Each subgraph's layers form one weakly-connected component w.r.t.
    // in-subgraph edges (they compile as a unit).
    check("subgraph connectivity", 200, |rng| {
        let net = random_network(rng);
        let cuts: Vec<bool> = (0..net.num_edges()).map(|_| rng.gen_bool(0.4)).collect();
        let p = partition(&net, &cuts, &random_mapping(rng, net.num_layers()));
        for sg in &p.subgraphs {
            if sg.layers.len() == 1 {
                continue;
            }
            // BFS over internal edges.
            let in_sg = |l: LayerId| sg.layers.binary_search(&l).is_ok();
            let mut seen = std::collections::HashSet::new();
            let mut stack = vec![sg.layers[0]];
            while let Some(l) = stack.pop() {
                if !seen.insert(l) {
                    continue;
                }
                for e in net.edges() {
                    if e.src == l && in_sg(e.dst) && p.owner_of(e.dst) == sg.id {
                        stack.push(e.dst);
                    }
                    if e.dst == l && in_sg(e.src) && p.owner_of(e.src) == sg.id {
                        stack.push(e.src);
                    }
                }
            }
            if seen.len() != sg.layers.len() {
                return Err(format!(
                    "subgraph {} disconnected: reached {} of {}",
                    sg.id, seen.len(), sg.layers.len()
                ));
            }
        }
        Ok(())
    });
}

/// Random objective matrix with deliberate ties: quantized values plus
/// occasional duplicated rows (dominance-equal candidates are common in real
/// populations — crossover clones, memoized genomes).
fn random_objectives(rng: &mut Rng, n: usize, m: usize) -> Vec<Vec<f64>> {
    let mut objs: Vec<Vec<f64>> = Vec::with_capacity(n);
    for i in 0..n {
        if i > 0 && rng.gen_bool(0.2) {
            let j = rng.gen_range(0, i);
            objs.push(objs[j].clone());
        } else {
            objs.push((0..m).map(|_| (rng.gen_range(0, 10) as f64) * 0.25).collect());
        }
    }
    objs
}

#[test]
fn prop_ens_fronts_equal_fast_non_dominated_sort() {
    // The ENS-BS front builder must produce exactly the fronts of the O(n²)
    // reference sort (canonical index-ascending order within each front),
    // on any objective set — duplicates, single fronts, one-point sets.
    let mut ws = SelectionWorkspace::new();
    check("ens fronts ≡ naive fronts", 300, |rng| {
        let n = rng.gen_range(1, 64);
        let m = rng.gen_range(1, 6);
        let objs = random_objectives(rng, n, m);
        let mut naive = fast_non_dominated_sort(&objs);
        for f in &mut naive {
            f.sort_unstable();
        }
        let ens = ws.non_dominated_fronts(&objs);
        if ens != naive {
            return Err(format!("ens {ens:?} != naive {naive:?} for {objs:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_selection_workspace_equals_reference_selector() {
    // The full production selection (ENS + binary-heap niching) must return
    // bit-identical indices to nsga3_select for every (objs, k).
    let mut ws = SelectionWorkspace::new();
    check("workspace select ≡ nsga3_select", 250, |rng| {
        let n = rng.gen_range(2, 64);
        let m = rng.gen_range(2, 6);
        let objs = random_objectives(rng, n, m);
        let k = rng.gen_range(1, n + 4); // occasionally k >= n
        let reference = nsga3_select(&objs, k);
        let fast = ws.select_objs(&objs, k);
        if fast != reference {
            return Err(format!(
                "k={k}: workspace {fast:?} != reference {reference:?} for {objs:?}"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_crossover_and_mutation_preserve_validity() {
    check("ga operators validity", 100, |rng| {
        let nets: Vec<Network> = (0..3)
            .map(|i| build_model(i, rng.gen_range(0, MODEL_COUNT)))
            .collect();
        let mut a = Genome::random(&nets, 0.3, rng);
        let mut b = Genome::random(&nets, 0.3, rng);
        one_point_crossover(&mut a, &mut b, rng);
        mutate(&mut a, 0.1, 0.1, 0.5, rng);
        mutate(&mut b, 0.1, 0.1, 0.5, rng);
        if !a.is_valid(&nets) || !b.is_valid(&nets) {
            return Err("invalid genome after operators".into());
        }
        Ok(())
    });
}

#[test]
fn prop_upmx_output_always_permutation() {
    check("upmx permutation", 300, |rng| {
        let n = rng.gen_range(2, 16);
        let mut a: Vec<usize> = (0..n).collect();
        let mut b: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut a);
        rng.shuffle(&mut b);
        let swap_prob = rng.gen_f64();
        upmx(&mut a, &mut b, rng, swap_prob);
        for v in [&a, &b] {
            let mut sorted = v.clone();
            sorted.sort_unstable();
            if sorted != (0..n).collect::<Vec<_>>() {
                return Err(format!("not a permutation: {v:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_simulator_conserves_requests() {
    // Every submitted request produces exactly one makespan, positive and
    // at least the longest member task's duration.
    check("simulator conservation", 100, |rng| {
        let n_nets = rng.gen_range(1, 4);
        let plans: Vec<ExecutionPlan> = (0..n_nets)
            .map(|_| {
                let n_tasks = rng.gen_range(1, 5);
                let tasks: Vec<PlannedTask> = (0..n_tasks)
                    .map(|_| PlannedTask {
                        duration: rng.gen_f64_range(0.001, 0.02),
                        processor: Processor::from_index(rng.gen_range(0, 3)),
                    })
                    .collect();
                // Chain transfers to keep the DAG trivially acyclic.
                let transfers: Vec<PlannedTransfer> = (1..n_tasks)
                    .map(|i| PlannedTransfer { from: i - 1, to: i, bytes: 4096 })
                    .collect();
                ExecutionPlan { tasks, transfers, priority: rng.gen_range(0, 4) }
            })
            .collect();
        let groups = [GroupSpec::periodic((0..n_nets).collect(), 0.05)];
        let reqs = rng.gen_range(1, 8);
        let opts = SimOptions { requests_per_group: reqs, ..Default::default() };
        let r = simulate(&plans, &groups, &CommModel::paper_calibrated(), &opts);
        if r.makespans[0].len() != reqs {
            return Err(format!("{} makespans for {} requests", r.makespans[0].len(), reqs));
        }
        let min_floor = plans
            .iter()
            .map(|p| p.tasks.iter().map(|t| t.duration).sum::<f64>())
            .fold(0.0f64, f64::max);
        for &m in &r.makespans[0] {
            if m <= 0.0 {
                return Err(format!("non-positive makespan {m}"));
            }
            let _ = min_floor; // serial-chain floor; contention may exceed it
        }
        Ok(())
    });
}

#[test]
fn prop_simulator_work_conservation_bounds_busy_time() {
    // Busy time per processor can never exceed the simulated span, and the
    // total busy time equals the sum of executed task durations + overheads.
    check("simulator busy bounds", 100, |rng| {
        let dur = rng.gen_f64_range(0.001, 0.01);
        let plans = vec![ExecutionPlan {
            tasks: vec![PlannedTask { duration: dur, processor: Processor::Npu }],
            transfers: vec![],
            priority: 0,
        }];
        let reqs = rng.gen_range(1, 10);
        let groups = [GroupSpec::periodic(vec![0], dur * rng.gen_f64_range(0.5, 3.0))];
        let opts = SimOptions { requests_per_group: reqs, dispatch_overhead: 0.0, ..Default::default() };
        let r = simulate(&plans, &groups, &CommModel::paper_calibrated(), &opts);
        let busy = r.busy[Processor::Npu.index()];
        let expected = dur * reqs as f64;
        if (busy - expected).abs() > 1e-9 {
            return Err(format!("busy {busy} != expected {expected}"));
        }
        if busy > r.span + 1e-9 {
            return Err(format!("busy {busy} exceeds span {}", r.span));
        }
        Ok(())
    });
}

#[test]
fn prop_comm_model_monotone_and_nonnegative() {
    check("comm monotone", 100, |rng| {
        let m = CommModel::paper_calibrated();
        let a = rng.gen_range(1, 1 << 24);
        let b = a + rng.gen_range(1, 1 << 22);
        for zc in [false, true] {
            let cost = |bytes: usize| {
                if zc {
                    m.transfer_cost_zero_copy(bytes, false)
                } else {
                    m.transfer_cost(bytes, false)
                }
            };
            if cost(a) < 0.0 || cost(b) < cost(a) {
                return Err(format!("not monotone at {a}/{b} zc={zc}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_rt_score_bounded_and_monotone() {
    check("rt score", 200, |rng| {
        let deadline = rng.gen_f64_range(0.001, 1.0);
        let m1 = rng.gen_f64_range(0.0, 2.0) * deadline;
        let m2 = m1 + rng.gen_f64_range(0.0, deadline);
        let s1 = metrics::rt_score(m1, deadline);
        let s2 = metrics::rt_score(m2, deadline);
        if !(0.0..=1.0).contains(&s1) || !(0.0..=1.0).contains(&s2) {
            return Err(format!("score out of range: {s1} {s2}"));
        }
        if s2 > s1 + 1e-12 {
            return Err(format!("not monotone: {s1} -> {s2}"));
        }
        Ok(())
    });
}

#[test]
fn prop_decoded_zoo_genomes_always_schedulable() {
    // End-to-end: random genomes over real zoo models decode to plans the
    // simulator completes (all makespans positive, no deadlock).
    let pm = PerfModel::paper_calibrated();
    check("zoo genomes schedulable", 40, |rng| {
        let idx = rng.gen_range(0, MODEL_COUNT);
        let nets = vec![build_model(0, idx)];
        let genes = NetworkGenes::random(&nets[0], 0.5, rng);
        let genome = Genome { networks: vec![genes], priority: vec![0] };
        let profiler = puzzle::profiler::Profiler::new(&pm);
        let comm = CommModel::paper_calibrated();
        let plans = puzzle::ga::decode(&nets, &genome, &profiler, &comm);
        let part = decode_network(&nets[0], &genome.networks[0]);
        if plans[0].tasks.len() != part.num_subgraphs() {
            return Err("task/subgraph count mismatch".into());
        }
        let groups = [GroupSpec::periodic(vec![0], 1.0)];
        let opts = SimOptions { requests_per_group: 3, ..Default::default() };
        let r = simulate(&plans, &groups, &comm, &opts);
        for &m in &r.makespans[0] {
            if !(m > 0.0 && m.is_finite()) {
                return Err(format!("bad makespan {m} (deadlock?)"));
            }
        }
        Ok(())
    });
}
