//! Fuzz-corpus property tests: every fuzzed scenario's measured report
//! lands inside its analytic queueing envelope with zero false
//! infeasibility certificates; fuzz corpora and their reports replay
//! bit-identically for any fleet width or core budget (determinism
//! contracts #6 and #7); the ρ-seeded bracket floor and the warm-probe
//! bit-identity contract extend to fuzzer-drawn scenarios (generated
//! networks included); the calibrated [`Admission::DEFAULT_SLACK`] keeps
//! the Little's-law cap invisible at feasible load; and a committed
//! fixture corpus anchors golden report hashes across versions.

use std::sync::Arc;

use puzzle::api::OverloadPolicy;
use puzzle::coordinator::ServedRequest;
use puzzle::experiments::{calibrate_slack, report_hash, run_fuzz_corpus, FuzzOptions};
use puzzle::ga::Genome;
use puzzle::perf::PerfModel;
use puzzle::scenario::fuzz::{case_seed, corpus, FuzzConfig, FuzzedScenario};
use puzzle::scenario::Scenario;
use puzzle::serve::{
    self, materialize_solutions, offered_utilization, rho_bracket_floor, Admission, LoadSpec,
    RuntimeHarness, ServeReport,
};
use puzzle::util::prop::effective_cases;
use puzzle::util::rng::Rng;
use puzzle::util::threads::CoreBudget;
use puzzle::Processor;

fn perf() -> Arc<PerfModel> {
    Arc::new(PerfModel::paper_calibrated())
}

/// Bitwise equality of the deterministic report fields (wall time and the
/// wall-measured `mem` block legitimately differ between runs).
fn assert_reports_identical(a: &ServeReport, b: &ServeReport) {
    assert_eq!(a.submitted, b.submitted);
    assert_eq!(a.served, b.served);
    assert_eq!(a.dropped, b.dropped);
    assert_eq!(a.unfinished, b.unfinished);
    assert_eq!(a.violations, b.violations);
    assert_eq!(a.score.to_bits(), b.score.to_bits());
    assert_eq!(a.attainment.to_bits(), b.attainment.to_bits());
    assert_eq!((a.retries, a.remaps, a.fault_shed), (b.retries, b.remaps, b.fault_shed));
    assert_eq!(a.degraded_time.to_bits(), b.degraded_time.to_bits());
    assert_eq!(a.group_makespans.len(), b.group_makespans.len());
    for (ga, gb) in a.group_makespans.iter().zip(&b.group_makespans) {
        assert_eq!(ga.len(), gb.len());
        for (ma, mb) in ga.iter().zip(gb) {
            assert_eq!(ma.to_bits(), mb.to_bits());
        }
    }
}

/// Bitwise equality of two served logs (every field, every f64 bit).
fn assert_logs_identical(a: &[ServedRequest], b: &[ServedRequest]) {
    assert_eq!(a.len(), b.len(), "log lengths differ");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let same = (x.group, x.request) == (y.group, y.request)
            && x.arrival.to_bits() == y.arrival.to_bits()
            && x.completion.to_bits() == y.completion.to_bits()
            && x.makespan.to_bits() == y.makespan.to_bits()
            && x.violated == y.violated;
        assert!(same, "log entry {i} differs: {x:?} vs {y:?}");
    }
}

#[test]
fn fuzzed_reports_stay_inside_their_envelopes() {
    // The tentpole property, at the issue's floor of 64 scenarios (the
    // PUZZLE_PROP_CASES multiplier deepens it in CI's elevated lane):
    // every measured violation fraction lands inside its pre-run analytic
    // band, and every ρ > 1 certificate is corroborated by the arrival
    // schedule it claims to describe — zero breaches, zero false
    // certificates.
    let perf = perf();
    let count = effective_cases(64);
    let cases = corpus(23, count, &FuzzConfig::default(), &perf);
    let outcomes = run_fuzz_corpus(&cases, &perf, &FuzzOptions::default());
    assert_eq!(outcomes.len(), count);
    for outcome in &outcomes {
        assert!(
            outcome.breach.is_none(),
            "case {} (seed {:#x}, {} groups, rho_max {:.3}, peak {:.3}): {}",
            outcome.index,
            outcome.seed,
            outcome.groups,
            outcome.envelope.rho_max,
            outcome.envelope.peak_rho_max,
            outcome.breach.as_deref().unwrap_or("")
        );
        assert!(
            !outcome.false_certificate,
            "case {} (seed {:#x}): certificate fired but the arrival schedule \
             contradicts its rates",
            outcome.index, outcome.seed
        );
    }
    // Non-vacuity: the α range straddles the feasibility boundary, so the
    // corpus must exercise both the certificate path and genuine serving.
    assert!(outcomes.iter().any(|o| o.certified_infeasible), "no case ever certified");
    assert!(outcomes.iter().any(|o| !o.certified_infeasible), "every case certified");
    assert!(outcomes.iter().all(|o| o.report.served > 0), "a case served nothing");
}

#[test]
fn fuzz_corpus_replays_bit_identically_for_any_fleet_width() {
    // Contracts #6 + #7 end to end: regenerating the corpus from the same
    // seed reproduces every arrival bit, and running it at fleet widths
    // 1 and 4 — and at width 4 under a 2-core budget — produces
    // bit-identical reports and hashes in corpus order.
    let perf = perf();
    let config = FuzzConfig::quick();
    let corpus_a = corpus(7, 12, &config, &perf);
    let corpus_b = corpus(7, 12, &config, &perf);
    for (a, b) in corpus_a.iter().zip(&corpus_b) {
        assert_eq!(a.seed, b.seed);
        assert_eq!(a.alpha.to_bits(), b.alpha.to_bits());
        for (x, y) in a.spec.groups.iter().zip(&b.spec.groups) {
            assert_eq!(x.requests, y.requests);
            assert_eq!(x.deadline.map(f64::to_bits), y.deadline.map(f64::to_bits));
            let (tx, ty) = (x.process.times(x.requests), y.process.times(y.requests));
            assert_eq!(
                tx.iter().map(|t| t.to_bits()).collect::<Vec<_>>(),
                ty.iter().map(|t| t.to_bits()).collect::<Vec<_>>(),
                "regenerated corpus drifted"
            );
        }
    }

    let serial =
        run_fuzz_corpus(&corpus_a, &perf, &FuzzOptions { probe_threads: 1, ..Default::default() });
    let wide =
        run_fuzz_corpus(&corpus_b, &perf, &FuzzOptions { probe_threads: 4, ..Default::default() });
    let budgeted = run_fuzz_corpus(
        &corpus_a,
        &perf,
        &FuzzOptions {
            probe_threads: 4,
            core_budget: Some(CoreBudget::new(2)),
            ..Default::default()
        },
    );
    assert_eq!(serial.len(), wide.len());
    assert_eq!(serial.len(), budgeted.len());
    for ((s, w), c) in serial.iter().zip(&wide).zip(&budgeted) {
        assert_eq!(s.index, w.index);
        assert_eq!(s.report_hash, w.report_hash, "case {} differs serial vs wide", s.index);
        assert_eq!(s.report_hash, c.report_hash, "case {} differs serial vs budgeted", s.index);
        assert_reports_identical(&s.report, &w.report);
        assert_reports_identical(&s.report, &c.report);
    }
}

#[test]
fn rho_bracket_floor_extends_to_fuzzed_scenarios() {
    // The ρ-seeded bracket property of the saturation driver, re-proved on
    // fuzzer-drawn scenarios (generated networks included): every α
    // strictly below `rho_bracket_floor` is certified infeasible for
    // strictly more than half the solution sets.
    let perf = perf();
    puzzle::util::prop::check("fuzzed rho bracket", 6, |rng| {
        let index = rng.gen_range(0, 1000);
        let case = FuzzedScenario::generate(0xF10_0D, index, &FuzzConfig::quick(), &perf);
        let scenario = &case.scenario;
        let groups: Vec<Vec<usize>> = scenario.groups.iter().map(|g| g.members.clone()).collect();
        let n_sets = rng.gen_range(1, 4);
        let sets: Vec<_> = (0..n_sets)
            .map(|_| {
                let genome = Genome::random(&scenario.networks, 0.3, rng);
                materialize_solutions(&scenario.networks, &genome, &perf)
            })
            .collect();
        let floor = rho_bracket_floor(&sets, scenario, &perf);
        puzzle::prop_assert!(floor > 0.0, "floor must be positive, got {floor}");
        for _ in 0..4 {
            let alpha = floor * rng.gen_f64().max(1e-3) * 0.999;
            let spec = LoadSpec::periodic(&scenario.periods(alpha, &perf), 4);
            let rates = spec.mean_rates();
            let certified = sets
                .iter()
                .filter(|sols| {
                    offered_utilization(sols, &groups, &rates, &perf).iter().any(|&r| r > 1.0)
                })
                .count();
            puzzle::prop_assert!(
                certified > sets.len() / 2,
                "alpha {alpha} below floor {floor} but only {certified}/{} sets certified",
                sets.len()
            );
        }
        Ok(())
    });
}

#[test]
fn warm_fuzzed_probes_match_fresh_deployments_bit_for_bit() {
    // Contract #3 (warm = fresh) re-proved on fuzzer-drawn loads: a warm
    // deployment replaying a fuzzed spec — before and after intervening
    // traffic — matches a fresh deployment's report and served log to the
    // last bit.
    let perf = perf();
    for index in 0..3 {
        let case = FuzzedScenario::generate(0xAB, index, &FuzzConfig::quick(), &perf);
        let mut rng = Rng::seed_from_u64(case.seed);
        let genome = Genome::random(&case.scenario.networks, 0.3, &mut rng);
        let harness = RuntimeHarness::for_genome(&case.scenario, &genome, &perf, 17);

        let (fresh_report, fresh_log) = harness.run_with_log(&case.spec);
        let mut deployment = harness.deploy(case.spec.mode);
        let (warm_report, warm_log) = deployment.probe_with_log(&case.spec, 17);
        let other = LoadSpec::periodic(&case.scenario.periods(3.0, &perf), 3);
        let _ = deployment.probe(&other, 99);
        let (replay_report, replay_log) = deployment.probe_with_log(&case.spec, 17);
        deployment.shutdown();

        assert_logs_identical(&fresh_log, &warm_log);
        assert_logs_identical(&fresh_log, &replay_log);
        assert_reports_identical(&fresh_report, &warm_report);
        assert_reports_identical(&fresh_report, &replay_report);
    }
}

#[test]
fn default_slack_keeps_the_cap_invisible_at_feasible_load() {
    // The calibration pin: at the calibrated DEFAULT_SLACK the Little's-law
    // cap must be invisible on a feasible periodic load — zero drops and a
    // served log bit-identical to unbounded queueing. Recalibrations must
    // re-justify both the constant and this contract.
    assert_eq!(Admission::DEFAULT_SLACK.to_bits(), 2.0f64.to_bits(), "calibrated value moved");
    let perf = perf();
    let scenario = Scenario::from_groups("slack-pin", &[vec![0], vec![1]]);
    let genome = Genome::all_on(&scenario.networks, Processor::Npu);
    let mut harness = RuntimeHarness::for_genome(&scenario, &genome, &perf, 19);
    harness.noisy = false;
    let spec = LoadSpec::for_scenario(&scenario, &perf, 2.0, 12);
    let cap = serve::little_inflight_cap(
        &harness.solutions,
        &harness.groups,
        &spec.mean_rates(),
        &perf,
        Admission::DEFAULT_SLACK,
    );
    assert!(cap >= scenario.groups.len(), "cap floor must cover the t = 0 herd");
    let (queue_report, queue_log) = harness.run_with_log(&spec);
    let capped = spec.with_policy(OverloadPolicy::DropAfter { max_inflight: cap });
    let (cap_report, cap_log) = harness.run_with_log(&capped);
    assert_eq!(cap_report.dropped, 0, "cap {cap} engaged at feasible load");
    assert_logs_identical(&queue_log, &cap_log);
    assert_eq!(queue_report.score.to_bits(), cap_report.score.to_bits());
}

#[test]
fn slack_sweep_counts_drops_against_the_uncapped_limit() {
    // The calibration sweep itself: rows share the feasibility split
    // (ρ_max is admission-independent), and an effectively infinite slack
    // reproduces queue-all exactly — zero drops anywhere — so the sweep's
    // zero-drop target is reachable and the drop counts measure only the
    // cap, not the load.
    let perf = perf();
    let cases = corpus(31, 10, &FuzzConfig::calibration(), &perf);
    let opts = FuzzOptions { envelope: false, ..Default::default() };
    let slacks = [0.5, 1.0, Admission::DEFAULT_SLACK, 1e6];
    let rows = calibrate_slack(&cases, &perf, &opts, &slacks);
    assert_eq!(rows.len(), slacks.len());
    assert!(rows.iter().all(|r| r.slack > 0.0));
    assert!(
        rows.windows(2).all(|w| w[0].feasible_cases == w[1].feasible_cases),
        "feasibility split must not depend on the swept slack"
    );
    assert!(rows[0].feasible_cases >= 1, "calibration corpus drew no feasible case");
    let limit = rows.last().expect("non-empty");
    assert_eq!(limit.total_drops, 0, "an unreachable cap must reproduce queue-all");
    assert_eq!(limit.feasible_drops, 0);
}

#[test]
fn fixture_corpus_replays_and_matches_golden_hashes() {
    // The committed fixture corpus: seeds must replay exactly (contract
    // #7), and rows carrying a golden report hash must reproduce it bit
    // for bit. Rows marked `pending` only check seed replay — run with
    // PUZZLE_WRITE_FIXTURES=1 to fill them in from a live run and commit
    // the result.
    const BASE_SEED: u64 = 0xF1C;
    let fixture = include_str!("fixtures/fuzz_corpus_v1.txt");
    let rows: Vec<(usize, u64, Option<u64>)> = fixture
        .lines()
        .filter(|l| !l.trim().is_empty() && !l.trim_start().starts_with('#'))
        .map(|l| {
            let mut parts = l.split_whitespace();
            let index: usize = parts.next().expect("index").parse().expect("index");
            let seed = u64::from_str_radix(parts.next().expect("seed"), 16).expect("seed hex");
            let hash = match parts.next().expect("hash") {
                "pending" => None,
                h => Some(u64::from_str_radix(h, 16).expect("hash hex")),
            };
            (index, seed, hash)
        })
        .collect();
    assert!(!rows.is_empty(), "fixture corpus is empty");

    let perf = perf();
    let cases = corpus(BASE_SEED, rows.len(), &FuzzConfig::quick(), &perf);
    let opts = FuzzOptions { probe_threads: 1, seed: BASE_SEED, ..Default::default() };
    let outcomes = run_fuzz_corpus(&cases, &perf, &opts);

    for ((index, seed, golden), outcome) in rows.iter().zip(&outcomes) {
        assert_eq!(*index, outcome.index, "fixture rows must be in corpus order");
        assert_eq!(*seed, case_seed(BASE_SEED, *index), "committed seed no longer replays");
        assert_eq!(*seed, outcome.seed);
        if let Some(golden) = golden {
            assert_eq!(
                *golden, outcome.report_hash,
                "case {index} report hash drifted from the committed golden value"
            );
        }
    }
    // The hash itself is deterministic within a session regardless of the
    // fixture's fill state: recomputing from the report reproduces it.
    for outcome in &outcomes {
        assert_eq!(outcome.report_hash, report_hash(&outcome.report));
    }

    if std::env::var("PUZZLE_WRITE_FIXTURES").is_ok() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/fuzz_corpus_v1.txt");
        let mut out = String::from(
            "# Fuzz fixture corpus v1: `<index> <case seed hex> <golden report hash hex>`.\n\
             # Base seed 0xF1C, FuzzConfig::quick(), FuzzOptions { probe_threads: 1, seed: 0xF1C }.\n\
             # Regenerate with PUZZLE_WRITE_FIXTURES=1 cargo test --test fuzz_envelope fixture.\n",
        );
        for outcome in &outcomes {
            out.push_str(&format!(
                "{} {:016x} {:016x}\n",
                outcome.index, outcome.seed, outcome.report_hash
            ));
        }
        std::fs::write(path, out).expect("write fixture corpus");
    }
}
