//! Device-in-the-loop profiling (paper §4.3).
//!
//! The Static Analyzer never sums layer times; it asks the *device* how long
//! each subgraph takes when compiled as a unit. Results are cached in a
//! profile database keyed by the subgraph's Merkle hash plus the execution
//! config, so structurally identical subgraphs — which the GA re-proposes
//! constantly across generations — hit the cache ("significantly speeding up
//! the profiling process", §4.3).
//!
//! The "device" is abstracted behind [`DeviceProbe`]: the calibrated
//! [`crate::perf::PerfModel`] in analysis mode, or real PJRT execution of the
//! AOT artifacts via [`crate::engine::PjrtEngine`] in hardware mode.

use std::collections::HashMap;

use std::sync::RwLock;

use crate::graph::{merkle_hash_subgraph, LayerId, MerkleHash, Network, Subgraph};
use crate::perf::PerfModel;
use crate::{ExecConfig, Processor};

/// Anything that can measure a subgraph's execution time.
pub trait DeviceProbe: Send + Sync {
    /// Measured execution time (seconds) of `layers` of `net`, compiled as a
    /// unit under `cfg`.
    fn measure(&self, net: &Network, layers: &[LayerId], cfg: ExecConfig) -> f64;
}

/// The calibrated performance model as a probe (analysis mode).
impl DeviceProbe for PerfModel {
    fn measure(&self, net: &Network, layers: &[LayerId], cfg: ExecConfig) -> f64 {
        self.subgraph_time(net, layers, cfg)
    }
}

/// Key of one profile-database entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct ProfileKey {
    merkle: MerkleHash,
    cfg: ExecConfig,
}

/// The profiler with its Merkle-keyed cache.
pub struct Profiler<'d> {
    probe: &'d dyn DeviceProbe,
    db: RwLock<HashMap<ProfileKey, f64>>,
    hits: std::sync::atomic::AtomicU64,
    misses: std::sync::atomic::AtomicU64,
}

impl<'d> Profiler<'d> {
    pub fn new(probe: &'d dyn DeviceProbe) -> Self {
        Profiler {
            probe,
            db: RwLock::new(HashMap::new()),
            hits: std::sync::atomic::AtomicU64::new(0),
            misses: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Profile one subgraph under a config (cached).
    pub fn profile(&self, net: &Network, sg: &Subgraph, cfg: ExecConfig) -> f64 {
        let key = ProfileKey { merkle: merkle_hash_subgraph(net, sg), cfg };
        if let Some(&t) = self.db.read().unwrap().get(&key) {
            self.hits.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            return t;
        }
        let t = self.probe.measure(net, &sg.layers, cfg);
        self.misses.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.db.write().unwrap().insert(key, t);
        t
    }

    /// Profile a subgraph at its mapped processor's best (backend, dtype)
    /// pair — the paper's representative profiling datum ("we identify the
    /// optimal pair for each subgraph", §4).
    pub fn profile_best(&self, net: &Network, sg: &Subgraph) -> (ExecConfig, f64) {
        self.best_on(net, sg, sg.processor)
    }

    /// Best config for a subgraph on an explicit processor.
    pub fn best_on(&self, net: &Network, sg: &Subgraph, p: Processor) -> (ExecConfig, f64) {
        let mut best = (ExecConfig::default_for(p), f64::INFINITY);
        for &b in crate::Backend::for_processor(p) {
            for d in [crate::DataType::Fp32, crate::DataType::Fp16] {
                let cfg = ExecConfig::new(p, b, d);
                let t = self.profile(net, sg, cfg);
                if t < best.1 {
                    best = (cfg, t);
                }
            }
        }
        best
    }

    /// (cache hits, probe measurements).
    pub fn stats(&self) -> (u64, u64) {
        (
            self.hits.load(std::sync::atomic::Ordering::Relaxed),
            self.misses.load(std::sync::atomic::Ordering::Relaxed),
        )
    }

    /// Number of distinct (subgraph, config) profiles stored.
    pub fn db_len(&self) -> usize {
        self.db.read().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::partition;
    use crate::models::build_model;

    #[test]
    fn cache_hits_on_repeat_profile() {
        let pm = PerfModel::paper_calibrated();
        let prof = Profiler::new(&pm);
        let net = build_model(0, 0);
        let p = partition(&net, &vec![false; net.num_edges()], &vec![Processor::Npu; net.num_layers()]);
        let cfg = ExecConfig::default_for(Processor::Npu);
        let t1 = prof.profile(&net, &p.subgraphs[0], cfg);
        let t2 = prof.profile(&net, &p.subgraphs[0], cfg);
        assert_eq!(t1, t2);
        let (hits, misses) = prof.stats();
        assert_eq!(hits, 1);
        assert_eq!(misses, 1);
    }

    #[test]
    fn different_configs_are_distinct_entries() {
        let pm = PerfModel::paper_calibrated();
        let prof = Profiler::new(&pm);
        let net = build_model(0, 1);
        let p = partition(&net, &vec![false; net.num_edges()], &vec![Processor::Cpu; net.num_layers()]);
        let _ = prof.profile(&net, &p.subgraphs[0], ExecConfig::new(Processor::Cpu, crate::Backend::OrtCpu, crate::DataType::Fp32));
        let _ = prof.profile(&net, &p.subgraphs[0], ExecConfig::new(Processor::Cpu, crate::Backend::OrtCpu, crate::DataType::Fp16));
        assert_eq!(prof.db_len(), 2);
    }

    #[test]
    fn best_config_finite_for_all_processors() {
        let pm = PerfModel::paper_calibrated();
        let prof = Profiler::new(&pm);
        for idx in 0..crate::models::MODEL_COUNT {
            let net = build_model(idx, idx);
            let p = partition(&net, &vec![false; net.num_edges()], &vec![Processor::Cpu; net.num_layers()]);
            for proc in Processor::ALL {
                let (_, t) = prof.best_on(&net, &p.subgraphs[0], proc);
                assert!(t.is_finite(), "{} on {}", net.name, proc);
            }
        }
    }

    #[test]
    fn isomorphic_subgraphs_share_profiles_across_networks() {
        // Two copies of the same model share every profile entry.
        let pm = PerfModel::paper_calibrated();
        let prof = Profiler::new(&pm);
        let a = build_model(0, 3);
        let b = build_model(1, 3);
        let pa = partition(&a, &vec![false; a.num_edges()], &vec![Processor::Npu; a.num_layers()]);
        let pb = partition(&b, &vec![false; b.num_edges()], &vec![Processor::Npu; b.num_layers()]);
        let cfg = ExecConfig::default_for(Processor::Npu);
        let _ = prof.profile(&a, &pa.subgraphs[0], cfg);
        let _ = prof.profile(&b, &pb.subgraphs[0], cfg);
        let (hits, misses) = prof.stats();
        assert_eq!((hits, misses), (1, 1), "second profile should hit the cache");
    }
}
