//! Device-in-the-loop profiling (paper §4.3).
//!
//! The Static Analyzer never sums layer times; it asks the *device* how long
//! each subgraph takes when compiled as a unit. Results are cached in a
//! profile database keyed by the subgraph's Merkle hash plus the execution
//! config, so structurally identical subgraphs — which the GA re-proposes
//! constantly across generations — hit the cache ("significantly speeding up
//! the profiling process", §4.3).
//!
//! The "device" is abstracted behind [`DeviceProbe`]: the calibrated
//! [`crate::perf::PerfModel`] in analysis mode, or real PJRT execution of the
//! AOT artifacts via [`crate::engine::PjrtEngine`] in hardware mode.
//!
//! ## Best-first config search (§Perf, this PR)
//!
//! [`Profiler::best_on`] no longer probes every (backend, dtype) pair in a
//! fixed order. Two layers of reuse sit in front of the device:
//!
//! 1. a **best-config memo** keyed by (merkle, processor): a subgraph whose
//!    winner is already known costs one lookup instead of a full config
//!    scan;
//! 2. for new subgraphs, configs are probed in **best-first order** (by the
//!    running mean of each config's time relative to its round's winner,
//!    tracked per (network, processor)), with an **early dominance cutoff**:
//!    after [`MIN_CUTOFF_ROUNDS`] observations, a config whose *minimum*
//!    observed relative time exceeds [`CUTOFF_RATIO`] is skipped outright.
//!
//! Probing runs through a caller-owned [`ProbeScratch`] (hashing buffers,
//! config list, stats snapshot, probe order): a memo hit allocates nothing,
//! a miss only for cache storage — see [`Profiler::best_on_layers`].
//!
//! The cutoff is conservative by construction for the calibrated model:
//! launch overhead and the fusion factor are shared by every config on a
//! processor, so within one (network, processor) the config ordering is
//! subgraph-independent — a config that has lost every round by ≥ 25%
//! cannot win a later round, and the **chosen config and time are identical
//! to an exhaustive scan** (asserted by `best_on_matches_exhaustive_scan`);
//! only the probe *counters* change.
//!
//! Caveats, deliberate: ordering stats pool by **network name** — networks
//! sharing a name are assumed performance-identical (true for the zoo and
//! the name-keyed calibration tables; `ScenarioSpec::Custom` rejects
//! duplicate names for this reason). For *noisy* hardware probes the 25%
//! margin absorbs run-to-run jitter, but a probe whose config ordering
//! genuinely varies per subgraph within one network weakens the guarantee
//! from "exhaustive-identical" to "within the cutoff margin".

use std::collections::HashMap;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::graph::{merkle_hash_layers, LayerId, MerkleHash, MerkleScratch, Network, Subgraph};
use crate::perf::PerfModel;
use crate::{DataType, ExecConfig, Processor};

/// Anything that can measure a subgraph's execution time.
pub trait DeviceProbe: Send + Sync {
    /// Measured execution time (seconds) of `layers` of `net`, compiled as a
    /// unit under `cfg`.
    fn measure(&self, net: &Network, layers: &[LayerId], cfg: ExecConfig) -> f64;
}

/// The calibrated performance model as a probe (analysis mode).
impl DeviceProbe for PerfModel {
    fn measure(&self, net: &Network, layers: &[LayerId], cfg: ExecConfig) -> f64 {
        self.subgraph_time(net, layers, cfg)
    }
}

/// Rounds a config must have been measured (per network × processor) before
/// the dominance cutoff may skip it.
pub const MIN_CUTOFF_ROUNDS: u32 = 4;

/// Dominance margin: a config is skipped only when even its best observed
/// round was ≥ this factor slower than that round's winner.
pub const CUTOFF_RATIO: f64 = 1.25;

/// Key of one profile-database entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct ProfileKey {
    merkle: MerkleHash,
    cfg: ExecConfig,
}

/// Running relative-time statistics for one candidate config of one
/// (network, processor) — the best-first ordering and cutoff signal.
#[derive(Debug, Clone, Copy)]
struct ConfigStat {
    rounds: u32,
    sum_ratio: f64,
    min_ratio: f64,
}

impl ConfigStat {
    const NEW: ConfigStat = ConfigStat { rounds: 0, sum_ratio: 0.0, min_ratio: f64::INFINITY };

    fn mean_ratio(&self) -> f64 {
        if self.rounds == 0 { 0.0 } else { self.sum_ratio / self.rounds as f64 }
    }
}

/// Where the profiler's device probe comes from: borrowed for the duration
/// of one analysis run (the legacy entry points), or shared/owned so a
/// `Profiler<'static>` can outlive the run — the session layer keeps one
/// profiler alive across analyze → deploy, so deployment reuses the
/// best-config memo instead of re-deriving exec configs.
enum ProbeSource<'d> {
    Borrowed(&'d dyn DeviceProbe),
    Shared(Arc<dyn DeviceProbe>),
}

impl<'d> ProbeSource<'d> {
    fn get(&self) -> &dyn DeviceProbe {
        match self {
            ProbeSource::Borrowed(p) => *p,
            ProbeSource::Shared(p) => p.as_ref(),
        }
    }
}

/// Reusable per-thread probing scratch: the merkle hashing buffers, the
/// candidate-config list, a snapshot of the ordering stats, the best-first
/// probe order, and this round's measurements. The seed's `best_on`
/// allocated all five per call (plus a `String` key clone) on the decode
/// hot path; with a scratch, a **memo-hit** [`Profiler::best_on_layers`]
/// performs zero heap allocation, and a miss allocates only for cache
/// storage (the profile DB / memo inserts themselves).
#[derive(Default)]
pub struct ProbeScratch {
    merkle: MerkleScratch,
    configs: Vec<ExecConfig>,
    stats: Vec<ConfigStat>,
    probe_order: Vec<usize>,
    measured: Vec<(usize, f64)>,
}

impl ProbeScratch {
    pub fn new() -> ProbeScratch {
        ProbeScratch::default()
    }
}

/// The profiler with its Merkle-keyed cache.
pub struct Profiler<'d> {
    probe: ProbeSource<'d>,
    db: RwLock<HashMap<ProfileKey, f64>>,
    /// (merkle, processor) → winning (config, time) of a completed scan.
    best: RwLock<HashMap<(MerkleHash, Processor), (ExecConfig, f64)>>,
    /// network name → per-processor per-config ordering stats. Keyed by the
    /// name alone (not `(String, Processor)`) so the hot read path can look
    /// up by `&str` without cloning the name.
    order: RwLock<HashMap<String, [Vec<ConfigStat>; 3]>>,
    hits: AtomicU64,
    misses: AtomicU64,
    probes_skipped: AtomicU64,
    best_memo_hits: AtomicU64,
}

impl<'d> Profiler<'d> {
    pub fn new(probe: &'d dyn DeviceProbe) -> Self {
        Self::with_source(ProbeSource::Borrowed(probe))
    }

    /// A profiler owning its probe: lives as long as needed (the session
    /// layer holds one across analyze → deploy → load-test).
    pub fn shared(probe: Arc<dyn DeviceProbe>) -> Profiler<'static> {
        Profiler::with_source(ProbeSource::Shared(probe))
    }

    fn with_source(probe: ProbeSource<'d>) -> Self {
        Profiler {
            probe,
            db: RwLock::new(HashMap::new()),
            best: RwLock::new(HashMap::new()),
            order: RwLock::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            probes_skipped: AtomicU64::new(0),
            best_memo_hits: AtomicU64::new(0),
        }
    }

    /// Candidate (backend, dtype) pairs for a processor in canonical order —
    /// the legacy scan order, used for deterministic tie-breaks. Written
    /// into a caller-owned buffer (cleared first).
    fn candidate_configs_into(p: Processor, out: &mut Vec<ExecConfig>) {
        out.clear();
        for &b in crate::Backend::for_processor(p) {
            for d in [DataType::Fp32, DataType::Fp16] {
                out.push(ExecConfig::new(p, b, d));
            }
        }
    }

    /// Number of candidate configs for a processor, without materializing
    /// them (the memo-hit fast path only needs the count).
    fn candidate_config_count(p: Processor) -> usize {
        crate::Backend::for_processor(p).len() * 2
    }

    /// Profile one subgraph under a config (cached). Convenience wrapper
    /// over [`Self::profile_hashed`] with a throwaway hashing scratch.
    pub fn profile(&self, net: &Network, sg: &Subgraph, cfg: ExecConfig) -> f64 {
        let merkle = merkle_hash_layers(net, &sg.layers, &mut MerkleScratch::new());
        self.profile_hashed(net, &sg.layers, merkle, cfg)
    }

    /// Profile a layer set whose merkle hash the caller already computed
    /// (the best-first sweep hashes once and probes many configs).
    fn profile_hashed(
        &self,
        net: &Network,
        layers: &[LayerId],
        merkle: MerkleHash,
        cfg: ExecConfig,
    ) -> f64 {
        let key = ProfileKey { merkle, cfg };
        if let Some(&t) = self.db.read().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return t;
        }
        let t = self.probe.get().measure(net, layers, cfg);
        self.misses.fetch_add(1, Ordering::Relaxed);
        self.db.write().unwrap().insert(key, t);
        t
    }

    /// Profile a subgraph at its mapped processor's best (backend, dtype)
    /// pair — the paper's representative profiling datum ("we identify the
    /// optimal pair for each subgraph", §4).
    pub fn profile_best(&self, net: &Network, sg: &Subgraph) -> (ExecConfig, f64) {
        self.best_on(net, sg, sg.processor)
    }

    /// Best config for a subgraph on an explicit processor. Convenience
    /// wrapper over [`Self::best_on_layers`] with a throwaway scratch; hot
    /// loops (the GA decode path) hold a [`ProbeScratch`] per thread.
    pub fn best_on(&self, net: &Network, sg: &Subgraph, p: Processor) -> (ExecConfig, f64) {
        self.best_on_layers(net, &sg.layers, p, &mut ProbeScratch::new())
    }

    /// Best config for a layer set on an explicit processor: best-config
    /// memo, then a best-first probe sweep with the dominance cutoff (module
    /// docs). Equivalent to the exhaustive scan in result; cheaper in
    /// probes. `layers` must be sorted ascending (as [`Subgraph::layers`]
    /// is). A memo hit touches no heap; a miss allocates only for cache
    /// storage.
    pub fn best_on_layers(
        &self,
        net: &Network,
        layers: &[LayerId],
        p: Processor,
        scratch: &mut ProbeScratch,
    ) -> (ExecConfig, f64) {
        let merkle = merkle_hash_layers(net, layers, &mut scratch.merkle);
        if let Some(&(cfg, t)) = self.best.read().unwrap().get(&(merkle, p)) {
            // Account the avoided per-config lookups as hits, keeping the
            // hit/measure ratio comparable with the pre-memo accounting.
            self.best_memo_hits.fetch_add(1, Ordering::Relaxed);
            self.hits.fetch_add(Self::candidate_config_count(p) as u64, Ordering::Relaxed);
            return (cfg, t);
        }
        Self::candidate_configs_into(p, &mut scratch.configs);
        let configs = &scratch.configs;

        // Best-first order: ascending historical mean relative time;
        // unseen configs first (they must be measured); canonical index
        // breaks ties so the order is stable. The stats snapshot is copied
        // out under the read lock, as before.
        {
            let order = self.order.read().unwrap();
            scratch.stats.clear();
            match order.get(net.name.as_str()) {
                Some(per_proc) if !per_proc[p.index()].is_empty() => {
                    scratch.stats.extend_from_slice(&per_proc[p.index()])
                }
                _ => scratch.stats.resize(configs.len(), ConfigStat::NEW),
            }
        }
        let stats = &scratch.stats;
        scratch.probe_order.clear();
        scratch.probe_order.extend(0..configs.len());
        scratch.probe_order.sort_unstable_by(|&a, &b| {
            stats[a]
                .mean_ratio()
                .partial_cmp(&stats[b].mean_ratio())
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });

        let mut best: Option<(usize, f64)> = None;
        scratch.measured.clear();
        for &ci in &scratch.probe_order {
            let st = &stats[ci];
            if st.rounds >= MIN_CUTOFF_ROUNDS && st.min_ratio > CUTOFF_RATIO {
                // Dominated in every observed round by more than the safety
                // margin: cannot win (see module docs).
                self.probes_skipped.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            let t = self.profile_hashed(net, layers, merkle, configs[ci]);
            scratch.measured.push((ci, t));
            best = match best {
                None => Some((ci, t)),
                Some((bi, bt)) if t < bt || (t == bt && ci < bi) => Some((ci, t)),
                keep => keep,
            };
        }
        let (best_ci, best_t) = best.expect("at least one config probed");

        // Fold this round's relative times into the ordering stats. The
        // double lookup (contains_key, then get_mut) avoids cloning the
        // network name on the steady-state path.
        if best_t.is_finite() && best_t > 0.0 {
            let mut order = self.order.write().unwrap();
            if !order.contains_key(net.name.as_str()) {
                order.insert(net.name.clone(), Default::default());
            }
            let entry = &mut order
                .get_mut(net.name.as_str())
                .expect("entry just ensured")[p.index()];
            if entry.is_empty() {
                entry.resize(configs.len(), ConfigStat::NEW);
            }
            for &(ci, t) in &scratch.measured {
                let ratio = t / best_t;
                let st = &mut entry[ci];
                st.rounds += 1;
                st.sum_ratio += ratio;
                st.min_ratio = st.min_ratio.min(ratio);
            }
        }

        let result = (configs[best_ci], best_t);
        self.best.write().unwrap().insert((merkle, p), result);
        result
    }

    /// (cache hits, probe measurements).
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// (config probes skipped by the dominance cutoff, best-config memo
    /// hits) — the §Perf counters of the best-first search.
    pub fn probe_stats(&self) -> (u64, u64) {
        (
            self.probes_skipped.load(Ordering::Relaxed),
            self.best_memo_hits.load(Ordering::Relaxed),
        )
    }

    /// Number of distinct (subgraph, config) profiles stored.
    pub fn db_len(&self) -> usize {
        self.db.read().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::partition;
    use crate::models::build_model;
    use crate::util::rng::Rng;
    use crate::Backend;

    #[test]
    fn cache_hits_on_repeat_profile() {
        let pm = PerfModel::paper_calibrated();
        let prof = Profiler::new(&pm);
        let net = build_model(0, 0);
        let p = partition(&net, &vec![false; net.num_edges()], &vec![Processor::Npu; net.num_layers()]);
        let cfg = ExecConfig::default_for(Processor::Npu);
        let t1 = prof.profile(&net, &p.subgraphs[0], cfg);
        let t2 = prof.profile(&net, &p.subgraphs[0], cfg);
        assert_eq!(t1, t2);
        let (hits, misses) = prof.stats();
        assert_eq!(hits, 1);
        assert_eq!(misses, 1);
    }

    #[test]
    fn different_configs_are_distinct_entries() {
        let pm = PerfModel::paper_calibrated();
        let prof = Profiler::new(&pm);
        let net = build_model(0, 1);
        let p = partition(&net, &vec![false; net.num_edges()], &vec![Processor::Cpu; net.num_layers()]);
        let _ = prof.profile(&net, &p.subgraphs[0], ExecConfig::new(Processor::Cpu, crate::Backend::OrtCpu, crate::DataType::Fp32));
        let _ = prof.profile(&net, &p.subgraphs[0], ExecConfig::new(Processor::Cpu, crate::Backend::OrtCpu, crate::DataType::Fp16));
        assert_eq!(prof.db_len(), 2);
    }

    #[test]
    fn best_config_finite_for_all_processors() {
        let pm = PerfModel::paper_calibrated();
        let prof = Profiler::new(&pm);
        for idx in 0..crate::models::MODEL_COUNT {
            let net = build_model(idx, idx);
            let p = partition(&net, &vec![false; net.num_edges()], &vec![Processor::Cpu; net.num_layers()]);
            for proc in Processor::ALL {
                let (_, t) = prof.best_on(&net, &p.subgraphs[0], proc);
                assert!(t.is_finite(), "{} on {}", net.name, proc);
            }
        }
    }

    #[test]
    fn isomorphic_subgraphs_share_profiles_across_networks() {
        // Two copies of the same model share every profile entry.
        let pm = PerfModel::paper_calibrated();
        let prof = Profiler::new(&pm);
        let a = build_model(0, 3);
        let b = build_model(1, 3);
        let pa = partition(&a, &vec![false; a.num_edges()], &vec![Processor::Npu; a.num_layers()]);
        let pb = partition(&b, &vec![false; b.num_edges()], &vec![Processor::Npu; b.num_layers()]);
        let cfg = ExecConfig::default_for(Processor::Npu);
        let _ = prof.profile(&a, &pa.subgraphs[0], cfg);
        let _ = prof.profile(&b, &pb.subgraphs[0], cfg);
        let (hits, misses) = prof.stats();
        assert_eq!((hits, misses), (1, 1), "second profile should hit the cache");
    }

    /// The legacy exhaustive scan (fixed canonical order, strict `<`),
    /// straight against the device model.
    fn exhaustive(pm: &PerfModel, net: &Network, layers: &[LayerId], p: Processor) -> (ExecConfig, f64) {
        let mut best = (ExecConfig::default_for(p), f64::INFINITY);
        for &b in Backend::for_processor(p) {
            for d in [DataType::Fp32, DataType::Fp16] {
                let cfg = ExecConfig::new(p, b, d);
                let t = pm.subgraph_time(net, layers, cfg);
                if t < best.1 {
                    best = (cfg, t);
                }
            }
        }
        best
    }

    #[test]
    fn best_on_matches_exhaustive_scan() {
        // The satellite contract: best-first order + dominance cutoff must
        // never change the chosen (config, time) — across all zoo models,
        // many random subgraphs, all processors — while actually skipping
        // probes once warmed up.
        let pm = PerfModel::paper_calibrated();
        let prof = Profiler::new(&pm);
        let mut rng = Rng::seed_from_u64(17);
        for zoo in 0..crate::models::MODEL_COUNT {
            let net = build_model(zoo, zoo);
            for round in 0..12 {
                let cuts: Vec<bool> =
                    (0..net.num_edges()).map(|_| rng.gen_bool(0.3)).collect();
                let mapping: Vec<Processor> = (0..net.num_layers())
                    .map(|_| Processor::from_index(rng.gen_range(0, 3)))
                    .collect();
                let part = partition(&net, &cuts, &mapping);
                for sg in &part.subgraphs {
                    for p in Processor::ALL {
                        let (cfg, t) = prof.best_on(&net, sg, p);
                        let (ecfg, et) = exhaustive(&pm, &net, &sg.layers, p);
                        assert_eq!(cfg, ecfg, "{} round {round} on {p}", net.name);
                        assert_eq!(t, et, "{} round {round} on {p}", net.name);
                    }
                }
            }
        }
        let (skipped, memo_hits) = prof.probe_stats();
        assert!(skipped > 0, "dominance cutoff never engaged");
        assert!(memo_hits > 0, "best-config memo never hit");
    }

    #[test]
    fn best_on_memo_hit_is_allocation_free() {
        // The decode hot path re-proposes structurally identical subgraphs
        // constantly; with a per-thread ProbeScratch a best-config memo hit
        // must not touch the heap at all.
        let pm = PerfModel::paper_calibrated();
        let prof = Profiler::new(&pm);
        let net = build_model(0, 6);
        let part = partition(
            &net,
            &vec![false; net.num_edges()],
            &vec![Processor::Gpu; net.num_layers()],
        );
        let sg = &part.subgraphs[0];
        let mut scratch = ProbeScratch::new();
        let first = prof.best_on_layers(&net, &sg.layers, Processor::Gpu, &mut scratch);
        let before = crate::util::alloc::thread_allocations();
        let second = prof.best_on_layers(&net, &sg.layers, Processor::Gpu, &mut scratch);
        let after = crate::util::alloc::thread_allocations();
        assert_eq!(after - before, 0, "memo-hit best_on_layers allocated");
        assert_eq!(first, second);
    }

    #[test]
    fn best_memo_short_circuits_repeat_subgraphs() {
        let pm = PerfModel::paper_calibrated();
        let prof = Profiler::new(&pm);
        let net = build_model(0, 6);
        let part = partition(&net, &vec![false; net.num_edges()], &vec![Processor::Gpu; net.num_layers()]);
        let sg = &part.subgraphs[0];
        let first = prof.best_on(&net, sg, Processor::Gpu);
        let misses_after_first = prof.stats().1;
        let second = prof.best_on(&net, sg, Processor::Gpu);
        assert_eq!(first, second);
        assert_eq!(prof.stats().1, misses_after_first, "memo hit must not probe");
        assert_eq!(prof.probe_stats().1, 1);
    }
}
