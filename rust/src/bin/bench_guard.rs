//! CI bench-smoke guard: compare a freshly regenerated `BENCH_hotpaths.json`
//! against the committed baseline and fail on regressions.
//!
//! ```text
//! usage: bench_guard <baseline.json> <fresh.json> [--threshold 1.25]
//! ```
//!
//! Two layers of checking:
//!
//! 1. **Cross-run comparison** — for every bench name present in both files,
//!    fail if the fresh `min_ns` exceeds `baseline min_ns × threshold`
//!    (default 1.25, i.e. a >25% regression). `min_ns` is the least noisy
//!    of the recorded statistics. A missing/unreadable baseline downgrades
//!    this layer to record-only (first run on a new runner class).
//! 2. **Same-run invariants** — machine-independent relations that must hold
//!    within the fresh numbers alone: the parallel generation bench must not
//!    be slower than the serial one (beyond jitter), the memoized decode
//!    must beat the non-memoized decode, and the reused-workspace simulation
//!    must not lose to fresh-allocation `simulate()`.
//!
//! Exit code 0 = pass, 1 = regression, 2 = usage/IO error on the fresh file.

use puzzle::util::bench::{parse_json, BenchNumbers};

fn load(path: &str) -> Option<Vec<(String, BenchNumbers)>> {
    let text = std::fs::read_to_string(path).ok()?;
    let rows = parse_json(&text);
    if rows.is_empty() { None } else { Some(rows) }
}

fn get<'a>(rows: &'a [(String, BenchNumbers)], name: &str) -> Option<&'a BenchNumbers> {
    rows.iter().find(|(n, _)| n == name).map(|(_, v)| v)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.len() < 2 {
        eprintln!("usage: bench_guard <baseline.json> <fresh.json> [--threshold 1.25]");
        std::process::exit(2);
    }
    let mut threshold = 1.25f64;
    if let Some(pos) = args.iter().position(|a| a == "--threshold") {
        if let Some(v) = args.get(pos + 1).and_then(|v| v.parse().ok()) {
            threshold = v;
        }
    }

    let Some(fresh) = load(&args[1]) else {
        eprintln!("bench_guard: cannot read fresh results from {}", args[1]);
        std::process::exit(2);
    };
    let mut failures: Vec<String> = Vec::new();

    // Layer 1: cross-run comparison against the committed baseline.
    match load(&args[0]) {
        Some(baseline) => {
            let mut compared = 0;
            for (name, base) in &baseline {
                let Some(new) = get(&fresh, name) else {
                    println!("  [gone]    {name} (not in fresh run)");
                    continue;
                };
                compared += 1;
                let ratio = new.min_ns / base.min_ns.max(1e-9);
                let tag = if ratio > threshold {
                    failures.push(format!(
                        "{name}: min {:.0}ns -> {:.0}ns ({ratio:.2}x > {threshold:.2}x)",
                        base.min_ns, new.min_ns
                    ));
                    "REGRESS"
                } else if ratio < 1.0 / threshold {
                    "faster"
                } else {
                    "ok"
                };
                println!("  [{tag:>7}] {name}: {:.0}ns -> {:.0}ns ({ratio:.2}x)", base.min_ns, new.min_ns);
            }
            println!("bench_guard: compared {compared} benches at threshold {threshold:.2}x");
        }
        None => {
            println!(
                "bench_guard: no baseline at {} — record-only run (commit the fresh \
                 BENCH_hotpaths.json to arm cross-run comparison)",
                args[0]
            );
        }
    }

    // Layer 2: same-run invariants (machine-independent).
    let invariants: [(&str, &str, f64); 14] = [
        // Parallel must not lose to serial by more than scheduling jitter
        // (on a single-core runner both take the same path).
        ("analyzer/parallel_generation", "analyzer/serial_generation", 1.10),
        // Offspring-in-fan-out (threads = cores, breeding included) must
        // not lose to the serial generation at population 256.
        ("analyzer/offspring_fanout", "analyzer/offspring_serial", 1.10),
        // The genome->plan memo hit path must beat a full decode.
        ("ga/decode_memoized", "ga/decode_genome(cached profiles)", 1.00),
        // ENS + heap niching must beat the O(n²) reference selector at
        // population 512 (1024-candidate pool).
        ("ga/ens_select_pop512", "ga/naive_select_pop512", 1.00),
        // Reused-workspace simulation must not lose to fresh allocation.
        ("sim/simulate_reused_workspace", "sim/simulate_6models_20req", 1.25),
        // The vectorized measurement tier (flat factors + duration
        // overrides) must not lose to per-candidate plan cloning/rewriting.
        ("sim/measure_tier_vectorized_reps8", "sim/measure_tier_naive_reps8", 1.05),
        // Workspace partitioning must not lose to the owned materializing
        // path it feeds.
        ("graph/partition_workspace_17layer", "graph/partition_17layer", 1.05),
        // The virtual-clock load test replays the same schedule the wall
        // driver sleeps through: it must never be slower.
        ("serve/loadtest_virtual_clock", "serve/loadtest_wall_clock", 1.00),
        // An empty FaultPlan (FaultyEngine wrapper + armed recovery) is one
        // branch per task: the chaos-off probe must track the plain probe
        // to within jitter — the fault layer's zero-overhead contract.
        ("serve/loadtest_chaos_off", "serve/loadtest_plain", 1.05),
        // With no telemetry subscriber the event bus is one relaxed atomic
        // load per would-be event: the telemetry-off probe must track the
        // plain probe to within jitter — the no-subscriber invisibility
        // contract (the armed `loadtest_telemetry_sub` bench is recorded
        // for the trajectory but unguarded: real events have a real cost).
        ("serve/loadtest_telemetry_off", "serve/loadtest_plain", 1.05),
        // Reusing one warm deployment across saturation probes saves the
        // per-probe Coordinator/Worker spawn: it must never lose to fresh
        // deploys running the identical probe sequence.
        ("serve/saturation_reused_deploy", "serve/saturation_fresh_deploys", 1.00),
        // The scoped probe fleet runs the identical multi-set bisection
        // (bit-identical results, determinism contract #6): whatever the
        // core count, going parallel must never cost wall-clock beyond
        // jitter. On a single-core runner both take the serial path.
        ("serve/saturation_fleet", "serve/saturation_serial", 1.05),
        // The shared-CoreBudget shard runs the identical protocol jobs as
        // the static two-level shard (bit-identical rows, contract #6);
        // dynamic core reclamation on the imbalanced workload must never
        // cost wall-clock beyond jitter — and on multi-core hosts it
        // should win, because retiring small-scenario workers hand their
        // slots to the giant scenario's GA/probe fan-outs.
        ("serve/protocol_budgeted_shard", "serve/protocol_static_shard", 1.05),
        // The fuzz-corpus case fleet runs the identical 16-group corpus as
        // the serial runner (bit-identical outcomes, contracts #6/#7):
        // fanning cases across cores must never cost wall-clock beyond
        // jitter. On a single-core runner both take the serial path.
        ("fuzz/corpus_16_groups_fleet", "fuzz/corpus_16_groups_serial", 1.05),
    ];
    for (fast, slow, margin) in invariants {
        match (get(&fresh, fast), get(&fresh, slow)) {
            (Some(f), Some(s)) => {
                if f.min_ns > s.min_ns * margin {
                    failures.push(format!(
                        "invariant: {fast} ({:.0}ns) slower than {slow} ({:.0}ns) x{margin:.2}",
                        f.min_ns, s.min_ns
                    ));
                } else {
                    println!("  [invariant ok] {fast} <= {slow} x{margin:.2}");
                }
            }
            _ => println!("  [invariant skipped] {fast} vs {slow}: bench missing"),
        }
    }

    if failures.is_empty() {
        println!("bench_guard: PASS");
    } else {
        eprintln!("bench_guard: FAIL");
        for f in &failures {
            eprintln!("  {f}");
        }
        std::process::exit(1);
    }
}
