//! Calibration constants transcribed from the paper's measurement tables
//! (Galaxy S23 Ultra, average of 100 runs).
//!
//! These anchor the simulated device so the Static Analyzer explores the same
//! cost landscape the paper's GA did. Entries are indexed by the zoo model
//! name (Table 6 order).

use crate::{Backend, DataType, Processor};

/// Model names in Table 6 order (must match `models::SPECS`).
const NAMES: [&str; 9] = [
    "face_det", "selfie_seg", "hand_det", "pose_det", "tcmonodepth",
    "fast_scnn", "yolov8n", "mosaic", "fastsam",
];

fn index_of(name: &str) -> Option<usize> {
    NAMES.iter().position(|&n| n == name)
}

/// Table 3 — best-config fp16 execution time per processor, **seconds**
/// (paper reports ms): [CPU, GPU, NPU] per model.
pub const TABLE3_MS: [[f64; 3]; 9] = [
    [1.6, 1.9, 0.3],      // face_det
    [3.1, 6.5, 1.0],      // selfie_seg
    [5.8, 4.9, 1.2],      // hand_det
    [6.1, 4.9, 1.1],      // pose_det
    [73.2, 31.7, 32.4],   // tcmonodepth
    [37.3, 12.9, 22.0],   // fast_scnn
    [58.6, 16.0, 5.3],    // yolov8n
    [213.0, 83.8, 163.9], // mosaic
    [192.4, 43.4, 9.1],   // fastsam
];

/// Table 3 anchor for a model, seconds, or None for non-zoo networks.
pub fn table3_anchor(name: &str) -> Option<[f64; 3]> {
    index_of(name).map(|i| {
        let ms = TABLE3_MS[i];
        [ms[0] * 1e-3, ms[1] * 1e-3, ms[2] * 1e-3]
    })
}

/// Table 2 — CPU execution time (ms) per (backend, dtype):
/// columns are [ort fp32, ort fp16, xnnpack fp32, xnnpack fp16,
/// nnapi fp32, nnapi fp16]; `f64::NAN` encodes the paper's N/A cells.
pub const TABLE2_MS: [[f64; 6]; 9] = [
    [2.6, 6.0, 1.6, 5.5, 201.0, 208.5],            // face_det
    [4.3, 3.5, 3.1, 3.6, 106.8, 110.2],            // selfie_seg
    [24.3, 5.8, 8.5, 7.9, 198.5, 205.1],           // hand_det
    [16.3, 6.1, 8.7, 8.0, 286.0, 287.7],           // pose_det
    [93.8, 73.2, f64::NAN, f64::NAN, f64::NAN, f64::NAN], // tcmonodepth
    [73.2, 37.3, f64::NAN, f64::NAN, f64::NAN, f64::NAN], // fast_scnn
    [73.0, 58.6, 74.5, 61.6, 638.7, 642.9],        // yolov8n
    [582.5, 252.6, 373.7, 213.0, 1211.7, 1208.4],  // mosaic
    [314.6, 220.3, 297.4, 192.4, 1255.8, 1256.8],  // fastsam
];

fn table2_column(backend: Backend, dtype: DataType) -> Option<usize> {
    let b = match backend {
        Backend::OrtCpu => 0,
        Backend::Xnnpack => 2,
        Backend::Nnapi => 4,
        Backend::Qnn => return None,
    };
    let d = match dtype {
        DataType::Fp32 => 0,
        DataType::Fp16 => 1,
        DataType::Int8 => return None, // handled by the int8 scaling below
    };
    Some(b + d)
}

/// CPU config multiplier relative to the model's *CPU best* (its Table 3
/// anchor). `f64::INFINITY` for N/A configs. int8 is modeled as 0.9× the
/// backend's fp16 column (not measured in Table 2).
pub fn table2_factor(name: &str, backend: Backend, dtype: DataType) -> f64 {
    let Some(i) = index_of(name) else {
        // Non-zoo networks: neutral backend landscape with NNAPI penalized.
        return match (backend, dtype) {
            (Backend::Nnapi, _) => 30.0,
            (Backend::Qnn, _) => f64::INFINITY,
            (_, DataType::Fp32) => 1.4,
            (_, DataType::Fp16) => 1.0,
            (_, DataType::Int8) => 0.9,
        };
    };
    let row = &TABLE2_MS[i];
    let best = row.iter().copied().filter(|v| !v.is_nan()).fold(f64::INFINITY, f64::min);
    let effective_dtype = if dtype == DataType::Int8 { DataType::Fp16 } else { dtype };
    let col = match table2_column(backend, effective_dtype) {
        Some(c) => c,
        None => return f64::INFINITY,
    };
    let v = row[col];
    if v.is_nan() {
        return f64::INFINITY;
    }
    let scale = if dtype == DataType::Int8 { 0.9 } else { 1.0 };
    v / best * scale
}

/// Table 4 — estimated/measured ratios per processor: [CPU, GPU, NPU].
/// These double as the *isolated-layer* (single-layer subgraph) slowdown
/// factors in the fusion model: profiling a layer alone reproduces the
/// per-layer times the naive estimator sums.
pub const TABLE4_RATIO: [[f64; 3]; 9] = [
    [0.99, 0.68, 1.42], // face_det
    [1.05, 0.85, 2.75], // selfie_seg
    [1.01, 0.83, 1.69], // hand_det
    [1.00, 0.80, 1.97], // pose_det
    [0.99, 0.92, 2.13], // tcmonodepth
    [0.95, 0.84, 2.86], // fast_scnn
    [1.00, 0.88, 2.40], // yolov8n
    [0.97, 0.93, 3.45], // mosaic
    [1.01, 0.90, 1.70], // fastsam
];

/// Per-model isolated-layer factor for a processor (see `TABLE4_RATIO`).
/// CPU factors < 1.0 clamp to 1.0 in the fusion model reading (a lone layer
/// cannot be faster than its fused share) while the raw ratio is still used
/// by the layer-sum estimator.
pub fn isolated_factor(name: &str, p: Processor) -> f64 {
    let raw = match index_of(name) {
        Some(i) => TABLE4_RATIO[i][p.index()],
        None => match p {
            Processor::Cpu => 1.0,
            Processor::Gpu => 0.85,
            Processor::Npu => 2.2,
        },
    };
    match p {
        // The GPU's <1.0 ratio is a profiler artifact (dispatch excluded),
        // not a real speedup; isolated execution still costs ~1.15x.
        Processor::Gpu => 1.15,
        Processor::Cpu => raw.max(1.0),
        Processor::Npu => raw,
    }
}

/// Raw Table 4 ratio for the layer-sum estimator (keeps the GPU's
/// under-estimation artifact).
pub fn estimator_factor(name: &str, p: Processor) -> f64 {
    match index_of(name) {
        Some(i) => TABLE4_RATIO[i][p.index()],
        None => match p {
            Processor::Cpu => 1.0,
            Processor::Gpu => 0.85,
            Processor::Npu => 2.2,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchors_exist_for_all_zoo_models() {
        for name in NAMES {
            let a = table3_anchor(name).unwrap();
            assert!(a.iter().all(|&t| t > 0.0));
        }
    }

    #[test]
    fn xnnpack_fp32_is_face_best() {
        // Table 2: face_det's underlined minimum is XNNPACK fp32 (1.6 ms).
        assert_eq!(table2_factor("face_det", Backend::Xnnpack, DataType::Fp32), 1.0);
        assert!(table2_factor("face_det", Backend::OrtCpu, DataType::Fp16) > 3.0);
    }

    #[test]
    fn na_cells_are_infinite() {
        assert!(table2_factor("tcmonodepth", Backend::Xnnpack, DataType::Fp32).is_infinite());
        assert!(table2_factor("fast_scnn", Backend::Nnapi, DataType::Fp16).is_infinite());
    }

    #[test]
    fn nnapi_factors_match_paper_scale() {
        // face_det NNAPI fp32 = 201.0 / 1.6 = 125.6x.
        let f = table2_factor("face_det", Backend::Nnapi, DataType::Fp32);
        assert!((f - 201.0 / 1.6).abs() < 1e-9);
    }

    #[test]
    fn isolated_factor_clamps() {
        assert_eq!(isolated_factor("face_det", Processor::Cpu), 1.0); // raw 0.99
        assert_eq!(isolated_factor("mosaic", Processor::Npu), 3.45);
        assert_eq!(isolated_factor("anything_else", Processor::Npu), 2.2);
    }

    #[test]
    fn estimator_keeps_gpu_artifact() {
        assert!(estimator_factor("face_det", Processor::Gpu) < 1.0);
    }
}
