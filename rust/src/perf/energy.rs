//! Energy model — the extension the paper defers ("Extending Puzzle to
//! cover energy consumption is left for future work", §6.2).
//!
//! Per-processor power draw is modeled with mobile-SoC-typical figures
//! (active power while executing + idle floor), so every simulated or
//! served schedule can be scored for energy alongside latency. The XRBench
//! energy score the paper omits is implemented in [`energy_score`]:
//! `min(1, budget / consumed)` per group request, the same normalized [0,1]
//! shape as the other XRBench components.

use crate::sim::SimResult;
use crate::Processor;

/// Active power draw while executing, watts (mobile-SoC magnitudes: big-core
/// CPU burst ~3.5 W, Adreno-class GPU ~2.5 W, Hexagon-class NPU ~1.2 W —
/// the NPU's efficiency is why NPU-heavy schedules win on energy even when
/// the GPU wins on latency).
pub fn active_power_w(p: Processor) -> f64 {
    match p {
        Processor::Cpu => 3.5,
        Processor::Gpu => 2.5,
        Processor::Npu => 1.2,
    }
}

/// Idle floor, watts, paid for the whole schedule span per processor.
pub fn idle_power_w(p: Processor) -> f64 {
    match p {
        Processor::Cpu => 0.15,
        Processor::Gpu => 0.08,
        Processor::Npu => 0.05,
    }
}

/// Energy (joules) consumed by a simulated schedule: active power over busy
/// time plus the idle floor over the span.
pub fn schedule_energy(result: &SimResult) -> f64 {
    Processor::ALL
        .iter()
        .map(|&p| {
            let busy = result.busy[p.index()];
            let idle = (result.span - busy).max(0.0);
            active_power_w(p) * busy + idle_power_w(p) * idle
        })
        .sum()
}

/// Average energy per group request, joules.
pub fn energy_per_request(result: &SimResult) -> f64 {
    let requests: usize = result.makespans.iter().map(|m| m.len()).sum();
    if requests == 0 {
        0.0
    } else {
        schedule_energy(result) / requests as f64
    }
}

/// XRBench-style energy score: `min(1, budget / consumed)` — 1.0 while the
/// schedule stays within its energy budget per request, degrading
/// proportionally beyond it.
pub fn energy_score(consumed_j: f64, budget_j: f64) -> f64 {
    if consumed_j <= 0.0 {
        return 1.0;
    }
    (budget_j / consumed_j).min(1.0).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommModel;
    use crate::sim::{simulate, ExecutionPlan, GroupSpec, PlannedTask, SimOptions};

    fn run_on(p: Processor, duration: f64, requests: usize) -> SimResult {
        let plans = [ExecutionPlan {
            tasks: vec![PlannedTask { duration, processor: p }],
            transfers: vec![],
            priority: 0,
        }];
        let groups = [GroupSpec::periodic(vec![0], duration * 2.0)];
        let opts = SimOptions {
            requests_per_group: requests,
            dispatch_overhead: 0.0,
            ..Default::default()
        };
        simulate(&plans, &groups, &CommModel::paper_calibrated(), &opts)
    }

    #[test]
    fn npu_schedule_uses_less_energy_than_cpu() {
        let cpu = schedule_energy(&run_on(Processor::Cpu, 0.01, 10));
        let npu = schedule_energy(&run_on(Processor::Npu, 0.01, 10));
        assert!(npu < cpu, "npu {npu} J >= cpu {cpu} J");
    }

    #[test]
    fn energy_scales_with_work() {
        let little = schedule_energy(&run_on(Processor::Gpu, 0.005, 5));
        let lots = schedule_energy(&run_on(Processor::Gpu, 0.005, 20));
        assert!(lots > little * 2.0, "{lots} vs {little}");
    }

    #[test]
    fn per_request_energy_is_stable_across_request_counts() {
        let a = energy_per_request(&run_on(Processor::Npu, 0.01, 5));
        let b = energy_per_request(&run_on(Processor::Npu, 0.01, 20));
        // Same per-request work → similar per-request energy (idle tail of
        // the last period differs slightly).
        assert!((a / b - 1.0).abs() < 0.5, "{a} vs {b}");
    }

    #[test]
    fn energy_score_shape() {
        assert_eq!(energy_score(0.5, 1.0), 1.0); // under budget
        assert!((energy_score(2.0, 1.0) - 0.5).abs() < 1e-12); // 2x over
        assert_eq!(energy_score(0.0, 1.0), 1.0);
    }

    #[test]
    fn idle_floor_counts() {
        // A mostly-idle schedule still consumes the floor across all three
        // processors over its span.
        let r = run_on(Processor::Npu, 0.001, 2);
        let e = schedule_energy(&r);
        let floor: f64 = Processor::ALL.iter().map(|&p| idle_power_w(p)).sum::<f64>() * r.span;
        assert!(e >= floor * 0.9, "energy {e} below idle floor {floor}");
    }
}
