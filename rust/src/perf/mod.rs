//! Calibrated processor/config performance model.
//!
//! This module is the substitution for the paper's Snapdragon 8 Gen 2
//! testbed (DESIGN.md §3). It is calibrated *directly against the paper's
//! published measurements*:
//!
//! * **Table 3** — whole-model fp16 best-config execution time per processor
//!   (the per-model anchors in [`calib::TABLE3_MS`]);
//! * **Table 2** — CPU backend × dtype configuration matrix
//!   ([`calib::TABLE2_MS`], including the N/A entries), reproducing the
//!   paper's "no dominant configuration" observation;
//! * **Table 4** — the *non-linearity* of execution time: per-model factors
//!   by which a layer-sum estimate mis-predicts the fused measurement
//!   ([`calib::TABLE4_RATIO`]); NPU over-estimates (concurrent op execution),
//!   GPU under-estimates (unaccounted kernel dispatch), CPU is ~linear.
//!
//! The model answers the two questions the Static Analyzer asks of a device:
//! "how long does this *subgraph*, compiled as a unit, take under this
//! config?" ([`PerfModel::subgraph_time`]) and "what would the naive
//! layer-sum estimator have said?" ([`PerfModel::layer_sum_estimate`]).
//! Execution-time *fluctuation* (the paper's CPU contention observation,
//! §6.3) is modeled by [`PerfModel::sample`].

pub mod calib;
pub mod energy;


use crate::util::rng::Rng;
use crate::graph::{LayerId, LayerKind, Network};
use crate::{Backend, DataType, ExecConfig, Processor};

/// Per-(kind, processor) relative *time* multiplier (higher = slower on that
/// processor), shaping where each layer "wants" to run. Normalized away at
/// whole-model level, so anchors still match Table 3 exactly.
fn kind_affinity(kind: LayerKind, p: Processor) -> f64 {
    use LayerKind::*;
    match (kind, p) {
        // Tensor ops saturate the NPU's MAC arrays.
        (Conv { .. } | Pointwise | Dense, Processor::Npu) => 1.0,
        (DepthwiseConv { .. }, Processor::Npu) => 1.8,
        (Add | Concat | Upsample | Pool, Processor::Npu) => 3.0,
        (Conv { .. } | Pointwise | Dense, Processor::Gpu) => 1.0,
        (DepthwiseConv { .. }, Processor::Gpu) => 1.2,
        (Add | Concat | Upsample | Pool, Processor::Gpu) => 1.6,
        (Conv { .. } | Pointwise | Dense, Processor::Cpu) => 1.0,
        (DepthwiseConv { .. }, Processor::Cpu) => 0.8,
        (Add | Concat | Upsample | Pool, Processor::Cpu) => 1.0,
    }
}

/// Per-subgraph compile/launch overhead, seconds. The GPU pays the most per
/// dispatch (paper §2.1.2: "kernel scheduling and other operational costs").
fn launch_overhead(p: Processor) -> f64 {
    match p {
        Processor::Cpu => 15e-6,
        Processor::Gpu => 90e-6,
        Processor::Npu => 40e-6,
    }
}

/// Probability of a CPU background-interference spike per execution
/// (see [`PerfModel::sample`]).
pub const CPU_SPIKE_PROB: f64 = 0.15;

/// Execution-time fluctuation (multiplicative sigma). The paper observes the
/// CPU "experiences significant fluctuations" (scores 0.64–0.9 across runs)
/// while the NPU is stable.
pub fn noise_sigma(p: Processor) -> f64 {
    match p {
        Processor::Cpu => 0.12,
        Processor::Gpu => 0.04,
        Processor::Npu => 0.015,
    }
}

/// Deterministic per-(model, salt) jitter in [lo, hi], for factors the paper
/// reports only as ranges. FNV over the name keeps it stable across runs.
fn jitter(name: &str, salt: u64, lo: f64, hi: f64) -> f64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes().chain(salt.to_le_bytes()) {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    let unit = (h >> 11) as f64 / (1u64 << 53) as f64;
    lo + unit * (hi - lo)
}

/// The calibrated device model.
#[derive(Debug, Clone)]
pub struct PerfModel {
    /// Fallback whole-model throughput (MAC/s) per processor for networks not
    /// in the calibration tables (derived from zoo medians at construction).
    fallback_macs_per_s: [f64; 3],
}

impl Default for PerfModel {
    fn default() -> Self {
        Self::paper_calibrated()
    }
}

impl PerfModel {
    /// Model calibrated to the paper's Tables 2–4 (see module docs).
    pub fn paper_calibrated() -> PerfModel {
        // Median implied throughput over the zoo: analog_macs / anchor_time.
        let zoo = crate::models::model_zoo();
        let mut per_proc: [Vec<f64>; 3] = [Vec::new(), Vec::new(), Vec::new()];
        for net in &zoo {
            if let Some(anchor) = calib::table3_anchor(&net.name) {
                for p in Processor::ALL {
                    per_proc[p.index()].push(net.total_macs() as f64 / anchor[p.index()]);
                }
            }
        }
        let median = |v: &mut Vec<f64>| -> f64 {
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            if v.is_empty() { 1e9 } else { v[v.len() / 2] }
        };
        let fallback = [
            median(&mut per_proc[0]),
            median(&mut per_proc[1]),
            median(&mut per_proc[2]),
        ];
        PerfModel { fallback_macs_per_s: fallback }
    }

    /// Whole-model anchor time (seconds) on processor `p` at the fp16
    /// best-backend config — Table 3 for zoo models, MAC-derived otherwise.
    pub fn anchor_time(&self, net: &Network, p: Processor) -> f64 {
        match calib::table3_anchor(&net.name) {
            Some(a) => a[p.index()],
            None => net.total_macs() as f64 / self.fallback_macs_per_s[p.index()],
        }
    }

    /// Total affinity-weighted MAC mass of a network on a processor — the
    /// normalizer for [`Self::layer_base`]. Hoisted out of per-layer loops
    /// (§Perf L3-1: `subgraph_time` was O(L²) recomputing this per layer).
    fn affinity_total(&self, net: &Network, p: Processor) -> f64 {
        net.layers()
            .iter()
            .map(|ly| ly.macs.max(1) as f64 * kind_affinity(ly.kind, p))
            .sum()
    }

    /// Affinity-weighted share of the model anchor attributed to one layer:
    /// `base_l(p)` with `Σ_l base_l(p) = anchor(p)`.
    fn layer_base_with(&self, net: &Network, l: LayerId, p: Processor, total: f64, anchor: f64) -> f64 {
        let layer = net.layer(l);
        let w = layer.macs.max(1) as f64 * kind_affinity(layer.kind, p) / total;
        anchor * w
    }


    /// Backend × dtype multiplier relative to the processor's fp16
    /// best-backend anchor. `f64::INFINITY` marks unsupported configs
    /// (Table 2's N/A cells). Deterministic per model.
    pub fn config_factor(&self, net: &Network, cfg: ExecConfig) -> f64 {
        match cfg.processor {
            Processor::Cpu => calib::table2_factor(&net.name, cfg.backend, cfg.dtype),
            Processor::Gpu | Processor::Npu => {
                if cfg.backend != Backend::Qnn {
                    return f64::INFINITY; // only the QNN analog drives GPU/NPU
                }
                match cfg.dtype {
                    DataType::Fp16 => 1.0,
                    // fp32 on mobile GPU/NPU roughly halves rate.
                    DataType::Fp32 => jitter(&net.name, 7 + cfg.processor.index() as u64, 1.6, 2.1),
                    // int8 helps, more on the NPU's integer arrays.
                    DataType::Int8 => match cfg.processor {
                        Processor::Npu => jitter(&net.name, 11, 0.55, 0.75),
                        _ => jitter(&net.name, 13, 0.8, 0.95),
                    },
                }
            }
        }
    }

    /// Fusion factor for a subgraph of `n` of the model's `total` layers:
    /// interpolates between the per-model *isolated-layer* factor (n = 1,
    /// from Table 4's estimated/measured ratio) and 1.0 (whole model). This
    /// is the non-linearity knob: compiling more layers together buys
    /// inter-layer optimization and (on the NPU) concurrent op execution.
    fn fusion_factor(&self, net: &Network, n: usize, total: usize, p: Processor) -> f64 {
        let iso = calib::isolated_factor(&net.name, p);
        if total <= 1 {
            return 1.0;
        }
        let frac = (n.saturating_sub(1)) as f64 / (total - 1) as f64; // 0 at n=1, 1 at whole
        // Fusion benefit accrues quickly with subgraph size (most inter-layer
        // optimization is local), hence the sqrt shape.
        iso + (1.0 - iso) * frac.sqrt()
    }

    /// **Measured** execution time (seconds) of a subgraph compiled as a
    /// unit under `cfg`. This is what device-in-the-loop profiling returns
    /// and what the runtime's `SimEngine` replays.
    pub fn subgraph_time(&self, net: &Network, layers: &[LayerId], cfg: ExecConfig) -> f64 {
        let factor = self.config_factor(net, cfg);
        if factor.is_infinite() {
            return f64::INFINITY;
        }
        let total = self.affinity_total(net, cfg.processor);
        let anchor = self.anchor_time(net, cfg.processor);
        let base: f64 = layers
            .iter()
            .map(|&l| self.layer_base_with(net, l, cfg.processor, total, anchor))
            .sum();
        let fusion = self.fusion_factor(net, layers.len(), net.num_layers(), cfg.processor);
        launch_overhead(cfg.processor) + base * factor * fusion
    }

    /// Whole-model measured time under a config.
    pub fn model_time(&self, net: &Network, cfg: ExecConfig) -> f64 {
        let all: Vec<LayerId> = (0..net.num_layers()).map(LayerId).collect();
        self.subgraph_time(net, &all, cfg)
    }

    /// The naive **layer-sum estimate** the paper shows to be wrong
    /// (§2.1.2, Table 4): sum of per-layer profiler times. The per-layer
    /// profiler factor differs per processor: NPU profiler reports serial op
    /// times (over-estimate), GPU profiler omits dispatch (under-estimate),
    /// CPU is nearly linear.
    pub fn layer_sum_estimate(&self, net: &Network, cfg: ExecConfig) -> f64 {
        let factor = self.config_factor(net, cfg);
        if factor.is_infinite() {
            return f64::INFINITY;
        }
        // Calibrated so est/meas reproduces Table 4's ratio exactly: the
        // per-layer profiler is modeled as mis-reporting the *whole measured
        // execution* by the published factor.
        let profiler = calib::estimator_factor(&net.name, cfg.processor);
        self.model_time(net, cfg) * profiler
    }

    /// Best (backend, dtype) pair for a subgraph on a processor — the
    /// "representative profiling data" selection of paper §4 ("we identify
    /// the optimal pair for each subgraph").
    pub fn best_config_for(
        &self,
        net: &Network,
        layers: &[LayerId],
        p: Processor,
    ) -> (ExecConfig, f64) {
        let mut best = (ExecConfig::default_for(p), f64::INFINITY);
        for &b in Backend::for_processor(p) {
            for d in [DataType::Fp32, DataType::Fp16] {
                let cfg = ExecConfig::new(p, b, d);
                let t = self.subgraph_time(net, layers, cfg);
                if t < best.1 {
                    best = (cfg, t);
                }
            }
        }
        best
    }

    /// Draw a noisy observation of a nominal duration on processor `p`
    /// (log-normal-ish multiplicative noise; the CPU fluctuates the most).
    /// GPU/NPU draws use mild log-normal-ish jitter. CPU draws are a
    /// *mixture*: mild jitter most of the time, plus a [`CPU_SPIKE_PROB`]
    /// chance of a 1.5–2.5x slowdown spike from background system work
    /// ("scheduling, job dispatching, and other system operations", §6.3) —
    /// the fluctuation that made the paper's Best Mapping scores swing
    /// between 0.64 and 0.9 across identical runs. Profile-driven mappings
    /// that lean on the CPU are fragile; Puzzle's measurement tier filters
    /// such candidates out.
    pub fn sample(&self, nominal: f64, p: Processor, rng: &mut Rng) -> f64 {
        if p == Processor::Cpu && rng.gen_bool(CPU_SPIKE_PROB) {
            return nominal * rng.gen_f64_range(1.5, 2.5);
        }
        let sigma = noise_sigma(p);
        // Box–Muller from two uniforms; avoids pulling in a distributions dep.
        let z = rng.gen_normal();
        (nominal * (1.0 + sigma * z)).max(nominal * 0.25)
    }

    /// The multiplicative factor of one [`Self::sample`] draw, independent
    /// of the nominal: `nominal * sample_factor(p, rng)` equals
    /// `sample(nominal, p, rng)` **bit-for-bit** for positive nominals and
    /// consumes the same RNG draws — both branches of `sample` scale the
    /// nominal by a nominal-independent factor, and the 0.25 floor commutes
    /// with positive scaling (f64 rounding is monotone, so the max picks the
    /// same side). The measurement tier samples factors in one flat pass
    /// over cached (nominal, processor) arrays instead of rewriting whole
    /// plan clones per repetition; equivalence is asserted in
    /// `rust/tests/batch_eval.rs`.
    pub fn sample_factor(&self, p: Processor, rng: &mut Rng) -> f64 {
        if p == Processor::Cpu && rng.gen_bool(CPU_SPIKE_PROB) {
            return rng.gen_f64_range(1.5, 2.5);
        }
        let sigma = noise_sigma(p);
        let z = rng.gen_normal();
        (1.0 + sigma * z).max(0.25)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::model_zoo;

    #[test]
    fn whole_model_matches_table3_anchor() {
        let pm = PerfModel::paper_calibrated();
        for net in model_zoo() {
            for p in Processor::ALL {
                let cfg = match p {
                    // anchor is "best backend at fp16": pick the best.
                    Processor::Cpu => {
                        let all: Vec<LayerId> = (0..net.num_layers()).map(LayerId).collect();
                        pm.best_config_for(&net, &all, p).0
                    }
                    _ => ExecConfig::new(p, Backend::Qnn, DataType::Fp16),
                };
                let t = pm.model_time(&net, cfg);
                let anchor = pm.anchor_time(&net, p);
                // Whole model: fusion factor = 1, config factor of the best
                // CPU config equals its Table 2 ratio (may be fp16-best).
                assert!(
                    t >= anchor * 0.95 && t <= anchor * 1.3,
                    "{} on {}: {} vs anchor {}",
                    net.name, p, t, anchor
                );
            }
        }
    }

    #[test]
    fn npu_wins_for_six_models_gpu_for_three() {
        // Table 3: NPU best for 6 models; GPU best for TCMonoDepth,
        // Fast-SCNN (as CPU-unfriendly heavies), MOSAIC.
        let pm = PerfModel::paper_calibrated();
        let mut npu_wins = 0;
        let mut gpu_wins = 0;
        for net in model_zoo() {
            let all: Vec<LayerId> = (0..net.num_layers()).map(LayerId).collect();
            let times: Vec<f64> = Processor::ALL
                .iter()
                .map(|&p| pm.best_config_for(&net, &all, p).1)
                .collect();
            let winner = (0..3).min_by(|&a, &b| times[a].partial_cmp(&times[b]).unwrap()).unwrap();
            match winner {
                2 => npu_wins += 1,
                1 => gpu_wins += 1,
                _ => {}
            }
        }
        assert_eq!(npu_wins, 6, "NPU should win 6 of 9");
        assert_eq!(gpu_wins, 3, "GPU should win 3 of 9");
    }

    #[test]
    fn nonlinearity_direction_per_processor() {
        let pm = PerfModel::paper_calibrated();
        for net in model_zoo() {
            // NPU: estimate over-predicts (ratio > 1.4).
            let cfg = ExecConfig::new(Processor::Npu, Backend::Qnn, DataType::Fp16);
            let ratio = pm.layer_sum_estimate(&net, cfg) / pm.model_time(&net, cfg);
            assert!(ratio > 1.3, "{}: NPU est/meas {}", net.name, ratio);
            // GPU: estimate under-predicts (< 1.0).
            let cfg = ExecConfig::new(Processor::Gpu, Backend::Qnn, DataType::Fp16);
            let ratio = pm.layer_sum_estimate(&net, cfg) / pm.model_time(&net, cfg);
            assert!(ratio < 1.0, "{}: GPU est/meas {}", net.name, ratio);
            // CPU: near-linear.
            let all: Vec<LayerId> = (0..net.num_layers()).map(LayerId).collect();
            let cfg = pm.best_config_for(&net, &all, Processor::Cpu).0;
            let ratio = pm.layer_sum_estimate(&net, cfg) / pm.model_time(&net, cfg);
            assert!((0.85..1.15).contains(&ratio), "{}: CPU est/meas {}", net.name, ratio);
        }
    }

    #[test]
    fn partitioning_costs_fusion() {
        // Splitting a model into two halves must not be faster than the
        // fused whole on the same processor (launch + lost fusion).
        let pm = PerfModel::paper_calibrated();
        let net = crate::models::build_model(0, 6); // yolov8n
        let all: Vec<LayerId> = (0..net.num_layers()).map(LayerId).collect();
        let cfg = ExecConfig::new(Processor::Npu, Backend::Qnn, DataType::Fp16);
        let whole = pm.subgraph_time(&net, &all, cfg);
        let (a, b) = all.split_at(all.len() / 2);
        let split = pm.subgraph_time(&net, a, cfg) + pm.subgraph_time(&net, b, cfg);
        assert!(split > whole, "split {split} <= whole {whole}");
    }

    #[test]
    fn no_dominant_cpu_config() {
        // Table 2's headline: across the zoo, at least two distinct CPU
        // (backend, dtype) configs are optimal for some model.
        let pm = PerfModel::paper_calibrated();
        let mut winners = std::collections::HashSet::new();
        for net in model_zoo() {
            let all: Vec<LayerId> = (0..net.num_layers()).map(LayerId).collect();
            let (cfg, _) = pm.best_config_for(&net, &all, Processor::Cpu);
            winners.insert((cfg.backend, cfg.dtype));
        }
        assert!(winners.len() >= 2, "one CPU config dominates: {winners:?}");
    }

    #[test]
    fn nnapi_is_always_terrible() {
        let pm = PerfModel::paper_calibrated();
        for net in model_zoo() {
            let nnapi = pm.model_time(&net, ExecConfig::new(Processor::Cpu, Backend::Nnapi, DataType::Fp32));
            if nnapi.is_infinite() {
                continue; // N/A rows
            }
            let all: Vec<LayerId> = (0..net.num_layers()).map(LayerId).collect();
            let best = pm.best_config_for(&net, &all, Processor::Cpu).1;
            assert!(nnapi / best > 4.0, "{}: nnapi only {}x", net.name, nnapi / best);
        }
    }

    #[test]
    fn sample_noise_is_bounded_and_cpu_noisier() {
                let pm = PerfModel::paper_calibrated();
        let mut rng = crate::util::rng::Rng::seed_from_u64(42);
        let spread = |p: Processor, rng: &mut crate::util::rng::Rng| {
            let xs: Vec<f64> = (0..2000).map(|_| pm.sample(1.0, p, rng)).collect();
            let mean = xs.iter().sum::<f64>() / xs.len() as f64;
            (xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
        };
        let cpu = spread(Processor::Cpu, &mut rng);
        let npu = spread(Processor::Npu, &mut rng);
        assert!(cpu > 3.0 * npu, "cpu sigma {cpu} vs npu {npu}");
    }

    #[test]
    fn unknown_network_uses_fallback() {
        let pm = PerfModel::paper_calibrated();
        let mut n = crate::graph::Network::new(99, "custom_net");
        let a = n.add_layer(crate::graph::Layer::conv("a", 16, 8, 8, 3, 1));
        let b = n.add_layer(crate::graph::Layer::conv("b", 16, 8, 8, 3, 1));
        n.connect(a, b);
        n.finalize();
        for p in Processor::ALL {
            let t = pm.anchor_time(&n, p);
            assert!(t.is_finite() && t > 0.0);
        }
    }
}
