//! Layers: the nodes of the DNN graph IR.
//!
//! Layer kinds cover the operator vocabulary of the nine model analogs
//! (DESIGN.md §4): convolution blocks (stride 1/2), depthwise blocks,
//! pointwise convolutions, joins (add/concat), upsampling, pooling, and dense
//! heads. Every compute-heavy kind lowers (at the python L2 layer) onto the
//! L1 Pallas fused-block kernel.

/// Index of a layer within its [`super::Network`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LayerId(pub usize);

impl std::fmt::Display for LayerId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "L{}", self.0)
    }
}

/// Activation tensor shape (NHWC with N=1, as is standard for mobile
/// single-frame inference).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TensorShape {
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

impl TensorShape {
    pub fn new(h: usize, w: usize, c: usize) -> Self {
        Self { h, w, c }
    }

    pub fn elements(&self) -> usize {
        self.h * self.w * self.c
    }

    /// Bytes at a given kernel precision.
    pub fn bytes(&self, dtype: crate::DataType) -> usize {
        self.elements() * dtype.size()
    }
}

impl std::fmt::Display for TensorShape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}x{}", self.h, self.w, self.c)
    }
}

/// Operator vocabulary of the model zoo.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerKind {
    /// KxK convolution + bias + activation (the Pallas fused block).
    Conv { kernel: usize, stride: usize },
    /// Depthwise KxK convolution + bias + activation.
    DepthwiseConv { kernel: usize, stride: usize },
    /// 1x1 convolution (projection).
    Pointwise,
    /// Elementwise addition of 2+ inputs (residual join).
    Add,
    /// Channel concatenation of 2+ inputs.
    Concat,
    /// Nearest-neighbour 2x upsample.
    Upsample,
    /// 2x2 average pool.
    Pool,
    /// Global-average-pool + dense head.
    Dense,
}

impl LayerKind {
    pub fn name(&self) -> &'static str {
        match self {
            LayerKind::Conv { .. } => "conv",
            LayerKind::DepthwiseConv { .. } => "dwconv",
            LayerKind::Pointwise => "pointwise",
            LayerKind::Add => "add",
            LayerKind::Concat => "concat",
            LayerKind::Upsample => "upsample",
            LayerKind::Pool => "pool",
            LayerKind::Dense => "dense",
        }
    }

    /// Whether the kind is a matmul-shaped op that the NPU's systolic array
    /// (or the paper's Hexagon tensor units) accelerates well. Used by the
    /// performance model to shape per-processor affinity.
    pub fn is_tensor_op(&self) -> bool {
        matches!(
            self,
            LayerKind::Conv { .. } | LayerKind::Pointwise | LayerKind::Dense
        )
    }
}

/// A node in the network DAG.
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    /// Output activation shape.
    pub out_shape: TensorShape,
    /// Input channel count (sum over inputs for Concat).
    pub in_channels: usize,
    /// Multiply-accumulate count for this layer (drives the perf model).
    pub macs: u64,
    /// Parameter count (weights + biases).
    pub params: u64,
}

impl Layer {
    /// KxK conv producing a `size x size x out_c` output from `in_c` channels.
    pub fn conv(name: &str, size: usize, in_c: usize, out_c: usize, kernel: usize, stride: usize) -> Layer {
        let out = TensorShape::new(size / stride, size / stride, out_c);
        let macs = (out.elements() as u64) * (in_c as u64) * (kernel * kernel) as u64;
        let params = (in_c * out_c * kernel * kernel + out_c) as u64;
        Layer {
            name: name.to_string(),
            kind: LayerKind::Conv { kernel, stride },
            out_shape: out,
            in_channels: in_c,
            macs,
            params,
        }
    }

    /// Depthwise KxK conv (channel-preserving).
    pub fn dwconv(name: &str, size: usize, c: usize, kernel: usize, stride: usize) -> Layer {
        let out = TensorShape::new(size / stride, size / stride, c);
        let macs = (out.elements() as u64) * (kernel * kernel) as u64;
        let params = (c * kernel * kernel + c) as u64;
        Layer {
            name: name.to_string(),
            kind: LayerKind::DepthwiseConv { kernel, stride },
            out_shape: out,
            in_channels: c,
            macs,
            params,
        }
    }

    /// 1x1 projection conv.
    pub fn pointwise(name: &str, size: usize, in_c: usize, out_c: usize) -> Layer {
        let out = TensorShape::new(size, size, out_c);
        let macs = (out.elements() as u64) * in_c as u64;
        let params = (in_c * out_c + out_c) as u64;
        Layer {
            name: name.to_string(),
            kind: LayerKind::Pointwise,
            out_shape: out,
            in_channels: in_c,
            macs,
            params,
        }
    }

    /// Residual add join.
    pub fn add(name: &str, size: usize, c: usize) -> Layer {
        let out = TensorShape::new(size, size, c);
        Layer {
            name: name.to_string(),
            kind: LayerKind::Add,
            out_shape: out,
            in_channels: c,
            macs: out.elements() as u64,
            params: 0,
        }
    }

    /// Channel concat join.
    pub fn concat(name: &str, size: usize, total_c: usize) -> Layer {
        let out = TensorShape::new(size, size, total_c);
        Layer {
            name: name.to_string(),
            kind: LayerKind::Concat,
            out_shape: out,
            in_channels: total_c,
            macs: out.elements() as u64,
            params: 0,
        }
    }

    /// 2x nearest-neighbour upsample.
    pub fn upsample(name: &str, in_size: usize, c: usize) -> Layer {
        let out = TensorShape::new(in_size * 2, in_size * 2, c);
        Layer {
            name: name.to_string(),
            kind: LayerKind::Upsample,
            out_shape: out,
            in_channels: c,
            macs: out.elements() as u64,
            params: 0,
        }
    }

    /// 2x2 average pool.
    pub fn pool(name: &str, in_size: usize, c: usize) -> Layer {
        let out = TensorShape::new(in_size / 2, in_size / 2, c);
        Layer {
            name: name.to_string(),
            kind: LayerKind::Pool,
            out_shape: out,
            in_channels: c,
            macs: (in_size * in_size * c) as u64,
            params: 0,
        }
    }

    /// Global-average-pool + dense classification/regression head.
    pub fn dense(name: &str, in_c: usize, out_features: usize) -> Layer {
        let out = TensorShape::new(1, 1, out_features);
        Layer {
            name: name.to_string(),
            kind: LayerKind::Dense,
            out_shape: out,
            in_channels: in_c,
            macs: (in_c * out_features) as u64,
            params: (in_c * out_features + out_features) as u64,
        }
    }

    /// Output tensor bytes at a precision.
    pub fn out_bytes(&self, dtype: crate::DataType) -> usize {
        self.out_shape.bytes(dtype)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_macs_params() {
        // 3x3 conv, 16x16 spatial, 8 -> 16 channels, stride 1.
        let l = Layer::conv("c", 16, 8, 16, 3, 1);
        assert_eq!(l.out_shape, TensorShape::new(16, 16, 16));
        assert_eq!(l.macs, 16 * 16 * 16 * 8 * 9);
        assert_eq!(l.params, (8 * 16 * 9 + 16) as u64);
    }

    #[test]
    fn strided_conv_halves_spatial() {
        let l = Layer::conv("c", 16, 8, 16, 3, 2);
        assert_eq!(l.out_shape, TensorShape::new(8, 8, 16));
    }

    #[test]
    fn dwconv_macs() {
        let l = Layer::dwconv("d", 16, 32, 3, 1);
        assert_eq!(l.macs, 16 * 16 * 32 * 9);
        assert_eq!(l.params, (32 * 9 + 32) as u64);
    }

    #[test]
    fn dense_shape() {
        let l = Layer::dense("h", 64, 10);
        assert_eq!(l.out_shape.elements(), 10);
        assert_eq!(l.macs, 640);
    }

    #[test]
    fn tensor_bytes_by_dtype() {
        let s = TensorShape::new(4, 4, 8);
        assert_eq!(s.bytes(crate::DataType::Fp32), 512);
        assert_eq!(s.bytes(crate::DataType::Fp16), 256);
        assert_eq!(s.bytes(crate::DataType::Int8), 128);
    }

    #[test]
    fn tensor_op_classification() {
        assert!(LayerKind::Conv { kernel: 3, stride: 1 }.is_tensor_op());
        assert!(LayerKind::Pointwise.is_tensor_op());
        assert!(!LayerKind::Add.is_tensor_op());
        assert!(!LayerKind::DepthwiseConv { kernel: 3, stride: 1 }.is_tensor_op());
    }
}
