//! Merkle hashing of subgraphs for the profile database (paper §4.3).
//!
//! The paper caches device-in-the-loop profiling results keyed by a Merkle
//! hash of the subgraph, so structurally identical subgraphs (same layers,
//! same internal wiring, same config) hit the cache across GA generations.
//!
//! We hash each layer's structural description into a leaf, then fold leaves
//! pairwise into a tree root (classic Merkle construction) together with the
//! internal edge list. The hash is position-independent across networks: two
//! subgraphs with isomorphic layer sequences and identical internal edges
//! collide intentionally, which is exactly the reuse the paper exploits.

use super::layer::LayerId;
use super::network::Network;
use super::partition::Subgraph;

/// 64-bit Merkle root (FNV-1a-based; this is a cache key, not a security
/// boundary, and 64 bits keeps the profile DB index compact).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct MerkleHash(pub u64);

impl std::fmt::Display for MerkleHash {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:016x}", self.0)
    }
}

/// FNV-1a offset basis — the shared seed for every structural hash in the
/// crate (subgraph Merkle roots here, genome fingerprints in
/// [`crate::ga::Genome::fingerprint`]).
pub const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// Fold `bytes` into running FNV-1a state `h`.
pub fn fnv1a(bytes: &[u8], mut h: u64) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Fold one `u64` (little-endian bytes) into running FNV-1a state `h`.
pub fn fnv1a_u64(v: u64, h: u64) -> u64 {
    fnv1a(&v.to_le_bytes(), h)
}

fn combine(a: u64, b: u64) -> u64 {
    fnv1a_u64(b, fnv1a_u64(a, FNV_OFFSET))
}

/// Structural leaf hash of a single layer (kind + shapes + MACs; name is
/// deliberately excluded so renames don't bust the cache).
fn leaf(net: &Network, l: LayerId) -> u64 {
    let layer = net.layer(l);
    let mut h = FNV_OFFSET;
    h = fnv1a(layer.kind.name().as_bytes(), h);
    if let super::layer::LayerKind::Conv { kernel, stride }
    | super::layer::LayerKind::DepthwiseConv { kernel, stride } = layer.kind
    {
        h = fnv1a_u64(kernel as u64, h);
        h = fnv1a_u64(stride as u64, h);
    }
    h = fnv1a_u64(layer.out_shape.h as u64, h);
    h = fnv1a_u64(layer.out_shape.w as u64, h);
    h = fnv1a_u64(layer.out_shape.c as u64, h);
    h = fnv1a_u64(layer.in_channels as u64, h);
    h = fnv1a_u64(layer.macs, h);
    h
}

/// Structural Merkle root of a **whole network**: leaf per layer (network
/// order, folded pairwise) plus the full edge list. Unlike
/// [`merkle_hash_subgraph`] this is position-*dependent* — it identifies the
/// network as built, so solution files can carry a per-network fingerprint
/// that validates on load even for custom (non-zoo) models, where the zoo
/// index validates nothing.
pub fn merkle_hash_network(net: &Network) -> MerkleHash {
    let mut level: Vec<u64> = (0..net.num_layers()).map(|l| leaf(net, LayerId(l))).collect();
    if level.is_empty() {
        return MerkleHash(FNV_OFFSET);
    }
    while level.len() > 1 {
        let mut next = Vec::with_capacity(level.len().div_ceil(2));
        for pair in level.chunks(2) {
            next.push(if pair.len() == 2 { combine(pair[0], pair[1]) } else { pair[0] });
        }
        level = next;
    }
    let mut root = level[0];
    for e in net.edges() {
        root = combine(root, combine(e.src.0 as u64, e.dst.0 as u64));
    }
    MerkleHash(root)
}

/// Reusable buffers for subgraph hashing: the leaf/fold level and the
/// local-index internal edge list. The GA's decode path hashes every
/// subgraph of every memo-missed genome, so the per-call `Vec`s the seed
/// allocated here were hot; with a scratch, [`merkle_hash_layers`] performs
/// zero heap allocation once warmed to a network's size.
#[derive(Default)]
pub struct MerkleScratch {
    level: Vec<u64>,
    internal: Vec<(usize, usize)>,
}

impl MerkleScratch {
    pub fn new() -> MerkleScratch {
        MerkleScratch::default()
    }
}

/// Merkle root over a layer set (must be sorted ascending, as
/// [`Subgraph::layers`] is): leaf per layer folded pairwise, plus the
/// internal edges in canonical (local-index) form. Scratch-based workhorse
/// behind [`merkle_hash_subgraph`].
pub fn merkle_hash_layers(
    net: &Network,
    layers: &[LayerId],
    scratch: &mut MerkleScratch,
) -> MerkleHash {
    debug_assert!(layers.windows(2).all(|w| w[0] < w[1]), "layers must be sorted");
    let level = &mut scratch.level;
    level.clear();
    level.extend(layers.iter().map(|&l| leaf(net, l)));
    if level.is_empty() {
        return MerkleHash(FNV_OFFSET);
    }
    // Pairwise fold to the root, in place (same combine order as folding
    // through chunks-of-two levels).
    let mut len = level.len();
    while len > 1 {
        let mut w = 0;
        let mut r = 0;
        while r + 1 < len {
            level[w] = combine(level[r], level[r + 1]);
            w += 1;
            r += 2;
        }
        if r < len {
            level[w] = level[r];
            w += 1;
        }
        len = w;
    }
    let mut root = level[0];

    // Internal edges, re-indexed to subgraph-local positions so the hash is
    // network-position independent.
    let local_index = |l: LayerId| layers.binary_search(&l).ok();
    let internal = &mut scratch.internal;
    internal.clear();
    internal.extend(net.edges().iter().filter_map(|e| {
        match (local_index(e.src), local_index(e.dst)) {
            (Some(a), Some(b)) => Some((a, b)),
            _ => None,
        }
    }));
    internal.sort_unstable();
    for &(a, b) in internal.iter() {
        root = combine(root, combine(a as u64, b as u64));
    }
    MerkleHash(root)
}

/// Merkle root over a subgraph's layers (leaf per layer, folded pairwise)
/// plus its internal edges in canonical (local-index) form.
pub fn merkle_hash_subgraph(net: &Network, sg: &Subgraph) -> MerkleHash {
    merkle_hash_layers(net, &sg.layers, &mut MerkleScratch::new())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::layer::Layer;
    use crate::graph::partition::partition;
    use crate::Processor;

    fn two_chains() -> (Network, Network) {
        let build = |id: usize, prefix: &str| {
            let mut n = Network::new(id, prefix);
            let a = n.add_layer(Layer::conv(&format!("{prefix}a"), 8, 8, 16, 3, 1));
            let b = n.add_layer(Layer::conv(&format!("{prefix}b"), 8, 16, 16, 3, 1));
            let c = n.add_layer(Layer::pointwise(&format!("{prefix}c"), 8, 16, 8));
            n.connect(a, b);
            n.connect(b, c);
            n.finalize();
            n
        };
        (build(0, "x"), build(1, "y"))
    }

    #[test]
    fn isomorphic_subgraphs_collide() {
        let (n1, n2) = two_chains();
        let p1 = partition(&n1, &[false, false], &[Processor::Cpu; 3]);
        let p2 = partition(&n2, &[false, false], &[Processor::Cpu; 3]);
        assert_eq!(
            merkle_hash_subgraph(&n1, &p1.subgraphs[0]),
            merkle_hash_subgraph(&n2, &p2.subgraphs[0]),
            "structurally identical subgraphs must share a cache key"
        );
    }

    #[test]
    fn different_partitions_differ() {
        let (n1, _) = two_chains();
        let whole = partition(&n1, &[false, false], &[Processor::Cpu; 3]);
        let split = partition(&n1, &[true, false], &[Processor::Cpu; 3]);
        assert_ne!(
            merkle_hash_subgraph(&n1, &whole.subgraphs[0]),
            merkle_hash_subgraph(&n1, &split.subgraphs[0]),
        );
    }

    #[test]
    fn network_hash_tracks_structure_not_names() {
        let (n1, n2) = two_chains();
        // Same structure, different names/ids → same fingerprint.
        assert_eq!(merkle_hash_network(&n1), merkle_hash_network(&n2));
        // A structural change (different kernel) changes it.
        let mut n3 = Network::new(2, "z");
        let a = n3.add_layer(Layer::conv("za", 8, 8, 16, 5, 1)); // kernel 5, not 3
        let b = n3.add_layer(Layer::conv("zb", 8, 16, 16, 3, 1));
        let c = n3.add_layer(Layer::pointwise("zc", 8, 16, 8));
        n3.connect(a, b);
        n3.connect(b, c);
        n3.finalize();
        assert_ne!(merkle_hash_network(&n1), merkle_hash_network(&n3));
    }

    #[test]
    fn name_changes_do_not_bust_cache() {
        let mut n1 = Network::new(0, "a");
        let l1 = n1.add_layer(Layer::conv("first", 8, 8, 8, 3, 1));
        let _ = l1;
        n1.finalize();
        let mut n2 = Network::new(1, "b");
        let _ = n2.add_layer(Layer::conv("renamed", 8, 8, 8, 3, 1));
        n2.finalize();
        let p1 = partition(&n1, &[], &[Processor::Cpu]);
        let p2 = partition(&n2, &[], &[Processor::Cpu]);
        assert_eq!(
            merkle_hash_subgraph(&n1, &p1.subgraphs[0]),
            merkle_hash_subgraph(&n2, &p2.subgraphs[0]),
        );
    }
}
