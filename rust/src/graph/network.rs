//! Networks: DAGs of layers with explicitly indexed edges.
//!
//! Edge indices matter: the GA's partition chromosome is a bit-vector over
//! `Network::edges` in insertion order (paper Fig 6/7), so edge ordering must
//! be stable and deterministic.

use super::layer::{Layer, LayerId};

/// Index of a network within a scenario.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NetworkId(pub usize);

impl std::fmt::Display for NetworkId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "N{}", self.0)
    }
}

/// Index of an edge within its network (chromosome position).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct EdgeId(pub usize);

/// A directed data edge `src -> dst`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Edge {
    pub src: LayerId,
    pub dst: LayerId,
}

/// A DNN as a DAG of [`Layer`]s.
#[derive(Debug, Clone)]
pub struct Network {
    pub id: NetworkId,
    pub name: String,
    layers: Vec<Layer>,
    edges: Vec<Edge>,
    /// Adjacency: successors / predecessors per layer (built by `finalize`).
    succs: Vec<Vec<LayerId>>,
    preds: Vec<Vec<LayerId>>,
    inputs: Vec<LayerId>,
    outputs: Vec<LayerId>,
    topo: Vec<LayerId>,
    finalized: bool,
}

impl Network {
    pub fn new(id: usize, name: &str) -> Network {
        Network {
            id: NetworkId(id),
            name: name.to_string(),
            layers: Vec::new(),
            edges: Vec::new(),
            succs: Vec::new(),
            preds: Vec::new(),
            inputs: Vec::new(),
            outputs: Vec::new(),
            topo: Vec::new(),
            finalized: false,
        }
    }

    /// Add a layer, returning its id. Layers must be added before edges that
    /// reference them.
    pub fn add_layer(&mut self, layer: Layer) -> LayerId {
        assert!(!self.finalized, "network already finalized");
        let id = LayerId(self.layers.len());
        self.layers.push(layer);
        self.succs.push(Vec::new());
        self.preds.push(Vec::new());
        id
    }

    /// Connect `src -> dst`. Edges must be added in deterministic order; their
    /// insertion index is the chromosome position.
    pub fn connect(&mut self, src: LayerId, dst: LayerId) -> EdgeId {
        assert!(!self.finalized, "network already finalized");
        assert!(src.0 < self.layers.len() && dst.0 < self.layers.len());
        assert!(src != dst, "self edge");
        let id = EdgeId(self.edges.len());
        self.edges.push(Edge { src, dst });
        self.succs[src.0].push(dst);
        self.preds[dst.0].push(src);
        id
    }

    /// Compute inputs/outputs/topological order; must be called once after
    /// construction. Panics if the graph has a cycle.
    pub fn finalize(&mut self) {
        assert!(!self.finalized);
        self.inputs = (0..self.layers.len())
            .map(LayerId)
            .filter(|l| self.preds[l.0].is_empty())
            .collect();
        self.outputs = (0..self.layers.len())
            .map(LayerId)
            .filter(|l| self.succs[l.0].is_empty())
            .collect();
        // Kahn's algorithm. Ties broken by layer index for determinism.
        let mut indeg: Vec<usize> = self.preds.iter().map(|p| p.len()).collect();
        let mut ready: std::collections::BinaryHeap<std::cmp::Reverse<usize>> = self
            .inputs
            .iter()
            .map(|l| std::cmp::Reverse(l.0))
            .collect();
        let mut topo = Vec::with_capacity(self.layers.len());
        while let Some(std::cmp::Reverse(l)) = ready.pop() {
            topo.push(LayerId(l));
            for &s in &self.succs[l] {
                indeg[s.0] -= 1;
                if indeg[s.0] == 0 {
                    ready.push(std::cmp::Reverse(s.0));
                }
            }
        }
        assert_eq!(topo.len(), self.layers.len(), "network {} has a cycle", self.name);
        self.topo = topo;
        self.finalized = true;
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    pub fn layer(&self, id: LayerId) -> &Layer {
        &self.layers[id.0]
    }

    pub fn layers(&self) -> &[Layer] {
        &self.layers
    }

    pub fn edge(&self, id: EdgeId) -> Edge {
        self.edges[id.0]
    }

    pub fn edges(&self) -> &[Edge] {
        &self.edges
    }

    pub fn successors(&self, id: LayerId) -> &[LayerId] {
        &self.succs[id.0]
    }

    pub fn predecessors(&self, id: LayerId) -> &[LayerId] {
        &self.preds[id.0]
    }

    pub fn inputs(&self) -> &[LayerId] {
        assert!(self.finalized);
        &self.inputs
    }

    pub fn outputs(&self) -> &[LayerId] {
        assert!(self.finalized);
        &self.outputs
    }

    /// Deterministic topological order (Kahn, index-tiebroken).
    pub fn topological_order(&self) -> &[LayerId] {
        assert!(self.finalized);
        &self.topo
    }

    /// Find the edge id connecting `src -> dst`, if any.
    pub fn edge_between(&self, src: LayerId, dst: LayerId) -> Option<EdgeId> {
        self.edges
            .iter()
            .position(|e| e.src == src && e.dst == dst)
            .map(EdgeId)
    }

    /// All edge ids incident (either direction) to a layer.
    pub fn incident_edges(&self, l: LayerId) -> Vec<EdgeId> {
        self.edges
            .iter()
            .enumerate()
            .filter(|(_, e)| e.src == l || e.dst == l)
            .map(|(i, _)| EdgeId(i))
            .collect()
    }

    /// Total multiply-accumulates of the whole network.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs).sum()
    }

    /// Total parameter count of the whole network.
    pub fn total_params(&self) -> u64 {
        self.layers.iter().map(|l| l.params).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain(n: usize) -> Network {
        let mut net = Network::new(0, "chain");
        let ids: Vec<LayerId> = (0..n)
            .map(|i| net.add_layer(Layer::conv(&format!("l{i}"), 8, 8, 8, 3, 1)))
            .collect();
        for w in ids.windows(2) {
            net.connect(w[0], w[1]);
        }
        net.finalize();
        net
    }

    #[test]
    fn chain_topology() {
        let n = chain(5);
        assert_eq!(n.num_edges(), 4);
        assert_eq!(n.inputs(), &[LayerId(0)]);
        assert_eq!(n.outputs(), &[LayerId(4)]);
        let topo: Vec<usize> = n.topological_order().iter().map(|l| l.0).collect();
        assert_eq!(topo, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    #[should_panic(expected = "cycle")]
    fn cycle_detected() {
        let mut net = Network::new(0, "cyclic");
        let a = net.add_layer(Layer::add("a", 4, 4));
        let b = net.add_layer(Layer::add("b", 4, 4));
        net.connect(a, b);
        net.connect(b, a);
        net.finalize();
    }

    #[test]
    fn incident_edges_of_join() {
        let mut net = Network::new(0, "join");
        let a = net.add_layer(Layer::conv("a", 8, 8, 8, 3, 1));
        let b = net.add_layer(Layer::conv("b", 8, 8, 8, 3, 1));
        let c = net.add_layer(Layer::add("c", 8, 8));
        net.connect(a, c);
        net.connect(b, c);
        net.finalize();
        assert_eq!(net.incident_edges(c).len(), 2);
        assert_eq!(net.predecessors(c), &[a, b]);
    }

    #[test]
    fn macs_sum() {
        let n = chain(3);
        assert_eq!(n.total_macs(), 3 * (8 * 8 * 8 * 8 * 9) as u64);
    }
}
