//! Edge-cut partitioning of a network into subgraphs (paper §4.2, Fig 7).
//!
//! Given a bit per edge (cut / keep) and a per-layer processor preference,
//! the connected components of the kept-edge graph become subgraphs. A
//! subgraph's processor is the **majority vote** of its layers' preferences
//! (ties broken by processor index, deterministic). Subgraphs are emitted in
//! topological order of the condensed DAG.
//!
//! **Convexity repair.** Naive undirected components can produce *cyclic*
//! inter-subgraph dependencies: on a diamond `L0→{L1,L2}→L3`, keeping only
//! `L0→L1` and `L1→L3` yields components `{L0,L1,L3}` and `{L2}` that feed
//! each other — an unschedulable partition (each subgraph executes as a
//! unit, so all of its external inputs must exist before it starts). We
//! therefore merge kept edges one at a time in chromosome (edge-index)
//! order, rejecting any merge that would create a cycle in the condensed
//! graph. Rejected kept edges behave as cut — a deterministic genome repair,
//! standard GA practice for infeasible encodings.
//!
//! Invariants (enforced here, property-tested in `rust/tests/`):
//! * every layer belongs to exactly one subgraph;
//! * the condensed subgraph graph is acyclic (by the repair above).

use super::layer::LayerId;
use super::network::{EdgeId, Network, NetworkId};
use crate::Processor;

/// Index of a subgraph within a [`Partition`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SubgraphId(pub usize);

impl std::fmt::Display for SubgraphId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SG{}", self.0)
    }
}

/// A compiled/executable unit: a connected set of layers mapped to one
/// processor.
#[derive(Debug, Clone)]
pub struct Subgraph {
    pub id: SubgraphId,
    pub network: NetworkId,
    /// Member layers in network-topological order.
    pub layers: Vec<LayerId>,
    /// Majority-vote processor assignment.
    pub processor: Processor,
    /// Subgraphs this one consumes tensors from (deduplicated, sorted).
    pub deps: Vec<SubgraphId>,
}

impl Subgraph {
    /// Total MACs of member layers.
    pub fn macs(&self, net: &Network) -> u64 {
        self.layers.iter().map(|&l| net.layer(l).macs).sum()
    }

    /// Bytes of the tensors this subgraph sends across each outgoing cut edge
    /// is computed by [`Partition::cut_bytes`]; here we expose the layer set.
    pub fn contains(&self, l: LayerId) -> bool {
        self.layers.binary_search(&l).is_ok()
    }
}

/// The result of partitioning one network.
#[derive(Debug, Clone)]
pub struct Partition {
    pub network: NetworkId,
    pub subgraphs: Vec<Subgraph>,
    /// For every layer, the subgraph that owns it.
    pub owner: Vec<SubgraphId>,
    /// Cut edges, i.e. cross-subgraph tensor transfers.
    pub cut_edges: Vec<EdgeId>,
}

impl Partition {
    /// Subgraph owning a layer.
    pub fn owner_of(&self, l: LayerId) -> SubgraphId {
        self.owner[l.0]
    }

    /// Total bytes crossing subgraph boundaries at a precision (each cut edge
    /// carries its source layer's output tensor).
    pub fn cut_bytes(&self, net: &Network, dtype: crate::DataType) -> usize {
        self.cut_edges
            .iter()
            .map(|&e| net.layer(net.edge(e).src).out_bytes(dtype))
            .sum()
    }

    pub fn num_subgraphs(&self) -> usize {
        self.subgraphs.len()
    }
}

/// Does merging components `a` and `b` (roots in `uf`) create a cycle in
/// the condensed graph over ALL network edges? True iff some directed path
/// runs b ⇝ a, or a ⇝ b without using a direct a→b edge.
///
/// §Perf L3-3: flat Vec adjacency + bitset visited (component roots are
/// layer indices < n), replacing the HashMap/HashSet version — partition is
/// on the GA decode hot path.
fn merge_creates_cycle(net: &Network, uf: &mut UnionFind, a: usize, b: usize) -> bool {
    let n = net.num_layers();
    // Condensed adjacency under the current union-find, as (head, next)
    // intrusive lists over a flat pool to avoid per-node Vec allocations.
    let mut head = vec![usize::MAX; n];
    let mut pool: Vec<(usize, usize)> = Vec::with_capacity(net.num_edges()); // (target, next)
    for e in net.edges() {
        let (s, d) = (uf.find(e.src.0), uf.find(e.dst.0));
        if s != d {
            pool.push((d, head[s]));
            head[s] = pool.len() - 1;
        }
    }
    let mut seen = vec![false; n];
    let mut stack: Vec<usize> = Vec::with_capacity(n);
    let mut reach = |from: usize, to: usize, seen: &mut Vec<bool>| -> bool {
        seen.iter_mut().for_each(|s| *s = false);
        stack.clear();
        stack.push(from);
        while let Some(x) = stack.pop() {
            if x == to {
                return true;
            }
            if seen[x] {
                continue;
            }
            seen[x] = true;
            let mut cursor = head[x];
            while cursor != usize::MAX {
                let (tgt, next) = pool[cursor];
                stack.push(tgt);
                cursor = next;
            }
        }
        false
    };
    // Path b ⇝ a closes a cycle outright.
    if reach(b, a, &mut seen) {
        return true;
    }
    // A second a ⇝ b path (not the direct edge) would sandwich whatever it
    // passes through between the merged component and itself.
    let mut cursor = head[a];
    while cursor != usize::MAX {
        let (s, next) = pool[cursor];
        if s != b && reach(s, b, &mut seen) {
            return true;
        }
        cursor = next;
    }
    false
}

/// Partition `net` by cutting the edges flagged in `cuts` (one bool per edge,
/// insertion order), assigning each subgraph the majority-vote processor of
/// `mapping` (one preference per layer). Kept edges whose merge would create
/// a cyclic condensed graph are repaired to cut (module docs).
pub fn partition(net: &Network, cuts: &[bool], mapping: &[Processor]) -> Partition {
    assert_eq!(cuts.len(), net.num_edges(), "one cut bit per edge");
    assert_eq!(mapping.len(), net.num_layers(), "one processor per layer");

    // Union-find over layers via kept edges, with convexity repair: merges
    // are applied in edge-index order and skipped if they would close a
    // cycle between components.
    let mut uf = UnionFind::new(net.num_layers());
    for (i, e) in net.edges().iter().enumerate() {
        if !cuts[i] {
            let (a, b) = (uf.find(e.src.0), uf.find(e.dst.0));
            if a != b && !merge_creates_cycle(net, &mut uf, a, b) {
                uf.union(a, b);
            }
        }
    }

    // Group layers by component root, in topological layer order so each
    // subgraph's layer list is executable front-to-back (flat Vec keyed by
    // root index; roots are layer ids).
    let mut comp_layers: Vec<Vec<LayerId>> = vec![Vec::new(); net.num_layers()];
    let mut roots: Vec<usize> = Vec::new();
    for &l in net.topological_order() {
        let r = uf.find(l.0);
        if comp_layers[r].is_empty() {
            roots.push(r); // first touch = earliest topological position
        }
        comp_layers[r].push(l);
    }

    let mut owner = vec![SubgraphId(usize::MAX); net.num_layers()];
    let mut subgraphs = Vec::with_capacity(roots.len());
    for (sg_idx, root) in roots.iter().enumerate() {
        let mut layers = std::mem::take(&mut comp_layers[*root]);
        layers.sort(); // LayerId order; `contains` binary-searches this.
        let id = SubgraphId(sg_idx);
        for &l in &layers {
            owner[l.0] = id;
        }
        let processor = majority_vote(layers.iter().map(|l| mapping[l.0]));
        subgraphs.push(Subgraph {
            id,
            network: net.id,
            layers,
            processor,
            deps: Vec::new(),
        });
    }

    // Dependencies: every cross-component edge (cut by the chromosome or by
    // the convexity repair) makes owner(dst) depend on owner(src).
    let mut cut_edges = Vec::new();
    for (i, e) in net.edges().iter().enumerate() {
        let from = owner[e.src.0];
        let to = owner[e.dst.0];
        if from != to {
            cut_edges.push(EdgeId(i));
            if !subgraphs[to.0].deps.contains(&from) {
                subgraphs[to.0].deps.push(from);
            }
        }
    }
    for sg in &mut subgraphs {
        sg.deps.sort();
    }

    Partition { network: net.id, subgraphs, owner, cut_edges }
}

/// Majority vote with deterministic tie-breaking (lowest processor index).
fn majority_vote(votes: impl Iterator<Item = Processor>) -> Processor {
    let mut counts = [0usize; 3];
    for v in votes {
        counts[v.index()] += 1;
    }
    let best = counts.iter().copied().max().unwrap_or(0);
    Processor::ALL
        .into_iter()
        .find(|p| counts[p.index()] == best)
        .unwrap_or(Processor::Cpu)
}

/// Minimal union-find with path compression + union by size.
struct UnionFind {
    parent: Vec<usize>,
    size: Vec<usize>,
}

impl UnionFind {
    fn new(n: usize) -> Self {
        UnionFind { parent: (0..n).collect(), size: vec![1; n] }
    }

    fn find(&mut self, mut x: usize) -> usize {
        while self.parent[x] != x {
            self.parent[x] = self.parent[self.parent[x]];
            x = self.parent[x];
        }
        x
    }

    fn union(&mut self, a: usize, b: usize) {
        let (mut ra, mut rb) = (self.find(a), self.find(b));
        if ra == rb {
            return;
        }
        if self.size[ra] < self.size[rb] {
            std::mem::swap(&mut ra, &mut rb);
        }
        self.parent[rb] = ra;
        self.size[ra] += self.size[rb];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::layer::Layer;

    #[test]
    fn majority_vote_ties_break_low() {
        let v = majority_vote([Processor::Gpu, Processor::Cpu].into_iter());
        assert_eq!(v, Processor::Cpu);
        let v = majority_vote([Processor::Npu, Processor::Npu, Processor::Cpu].into_iter());
        assert_eq!(v, Processor::Npu);
    }

    #[test]
    fn deps_follow_cut_edges() {
        let mut net = Network::new(0, "chain");
        let a = net.add_layer(Layer::conv("a", 8, 8, 8, 3, 1));
        let b = net.add_layer(Layer::conv("b", 8, 8, 8, 3, 1));
        let c = net.add_layer(Layer::conv("c", 8, 8, 8, 3, 1));
        net.connect(a, b);
        net.connect(b, c);
        net.finalize();
        let p = partition(&net, &[true, false], &[Processor::Cpu, Processor::Gpu, Processor::Gpu]);
        assert_eq!(p.subgraphs.len(), 2);
        assert_eq!(p.subgraphs[1].deps, vec![SubgraphId(0)]);
        assert!(p.subgraphs[0].deps.is_empty());
        assert_eq!(p.cut_edges.len(), 1);
    }

    #[test]
    fn cut_bytes_accounts_src_tensor() {
        let mut net = Network::new(0, "pair");
        let a = net.add_layer(Layer::conv("a", 8, 8, 4, 3, 1)); // out 8x8x4
        let b = net.add_layer(Layer::conv("b", 8, 4, 4, 3, 1));
        net.connect(a, b);
        net.finalize();
        let p = partition(&net, &[true], &[Processor::Cpu, Processor::Cpu]);
        assert_eq!(p.cut_bytes(&net, crate::DataType::Fp32), 8 * 8 * 4 * 4);
    }

    #[test]
    fn owners_total() {
        let net = {
            let mut n = Network::new(0, "d");
            let a = n.add_layer(Layer::conv("a", 8, 8, 8, 3, 1));
            let b = n.add_layer(Layer::conv("b", 8, 8, 8, 3, 1));
            n.connect(a, b);
            n.finalize();
            n
        };
        let p = partition(&net, &[false], &[Processor::Cpu, Processor::Cpu]);
        for l in 0..net.num_layers() {
            assert!(p.owner[l].0 != usize::MAX);
        }
    }
}
