//! Edge-cut partitioning of a network into subgraphs (paper §4.2, Fig 7).
//!
//! Given a bit per edge (cut / keep) and a per-layer processor preference,
//! the connected components of the kept-edge graph become subgraphs. A
//! subgraph's processor is the **majority vote** of its layers' preferences
//! (ties broken by processor index, deterministic). Subgraphs are emitted in
//! topological order of the condensed DAG.
//!
//! **Convexity repair.** Naive undirected components can produce *cyclic*
//! inter-subgraph dependencies: on a diamond `L0→{L1,L2}→L3`, keeping only
//! `L0→L1` and `L1→L3` yields components `{L0,L1,L3}` and `{L2}` that feed
//! each other — an unschedulable partition (each subgraph executes as a
//! unit, so all of its external inputs must exist before it starts). We
//! therefore merge kept edges one at a time in chromosome (edge-index)
//! order, rejecting any merge that would create a cycle in the condensed
//! graph. Rejected kept edges behave as cut — a deterministic genome repair,
//! standard GA practice for infeasible encodings.
//!
//! **Workspace decode (§Perf, this PR).** Partitioning sits on the GA's
//! first-touch decode path (every memo-missed genome partitions every
//! network), and the seed implementation allocated per call: the union-find,
//! the cycle-check adjacency/visited scratch, one `Vec` per component, and
//! the output lists. [`PartitionWorkspace`] owns all of it as flat arenas
//! (layer lists and dependency lists are CSR slices, components are found
//! through the same union-find) — [`PartitionWorkspace::partition_into`]
//! performs **zero heap allocation** once warmed to a network's size
//! (asserted in `rust/tests/batch_eval.rs`). The owned [`partition`] entry
//! point is a thin materialization of the workspace result, so both paths
//! are one algorithm.
//!
//! Invariants (enforced here, property-tested in `rust/tests/`):
//! * every layer belongs to exactly one subgraph;
//! * the condensed subgraph graph is acyclic (by the repair above).

use super::layer::LayerId;
use super::network::{EdgeId, Network, NetworkId};
use crate::Processor;

/// Index of a subgraph within a [`Partition`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SubgraphId(pub usize);

impl std::fmt::Display for SubgraphId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SG{}", self.0)
    }
}

/// A compiled/executable unit: a connected set of layers mapped to one
/// processor.
#[derive(Debug, Clone)]
pub struct Subgraph {
    pub id: SubgraphId,
    pub network: NetworkId,
    /// Member layers in network-topological order.
    pub layers: Vec<LayerId>,
    /// Majority-vote processor assignment.
    pub processor: Processor,
    /// Subgraphs this one consumes tensors from (deduplicated, sorted).
    pub deps: Vec<SubgraphId>,
}

impl Subgraph {
    /// Total MACs of member layers.
    pub fn macs(&self, net: &Network) -> u64 {
        self.layers.iter().map(|&l| net.layer(l).macs).sum()
    }

    /// Bytes of the tensors this subgraph sends across each outgoing cut edge
    /// is computed by [`Partition::cut_bytes`]; here we expose the layer set.
    pub fn contains(&self, l: LayerId) -> bool {
        self.layers.binary_search(&l).is_ok()
    }
}

/// The result of partitioning one network.
#[derive(Debug, Clone)]
pub struct Partition {
    pub network: NetworkId,
    pub subgraphs: Vec<Subgraph>,
    /// For every layer, the subgraph that owns it.
    pub owner: Vec<SubgraphId>,
    /// Cut edges, i.e. cross-subgraph tensor transfers.
    pub cut_edges: Vec<EdgeId>,
}

impl Partition {
    /// Subgraph owning a layer.
    pub fn owner_of(&self, l: LayerId) -> SubgraphId {
        self.owner[l.0]
    }

    /// Total bytes crossing subgraph boundaries at a precision (each cut edge
    /// carries its source layer's output tensor).
    pub fn cut_bytes(&self, net: &Network, dtype: crate::DataType) -> usize {
        self.cut_edges
            .iter()
            .map(|&e| net.layer(net.edge(e).src).out_bytes(dtype))
            .sum()
    }

    pub fn num_subgraphs(&self) -> usize {
        self.subgraphs.len()
    }
}

/// Does merging components `a` and `b` (roots in `uf`) create a cycle in
/// the condensed graph over ALL network edges? True iff some directed path
/// runs b ⇝ a, or a ⇝ b without using a direct a→b edge.
///
/// Scratch (`adj_head`/`adj_pool` intrusive adjacency, `seen` bitset,
/// DFS `stack`) is caller-owned — partition is on the GA decode hot path
/// and this runs once per attempted merge.
#[allow(clippy::too_many_arguments)]
fn merge_creates_cycle(
    net: &Network,
    uf: &mut UnionFind,
    adj_head: &mut Vec<usize>,
    adj_pool: &mut Vec<(usize, usize)>,
    seen: &mut Vec<bool>,
    stack: &mut Vec<usize>,
    a: usize,
    b: usize,
) -> bool {
    let n = net.num_layers();
    // Condensed adjacency under the current union-find, as (target, next)
    // intrusive lists over a flat pool.
    adj_head.clear();
    adj_head.resize(n, usize::MAX);
    adj_pool.clear();
    for e in net.edges() {
        let (s, d) = (uf.find(e.src.0), uf.find(e.dst.0));
        if s != d {
            adj_pool.push((d, adj_head[s]));
            adj_head[s] = adj_pool.len() - 1;
        }
    }
    seen.clear();
    seen.resize(n, false);
    fn reach(
        adj_head: &[usize],
        adj_pool: &[(usize, usize)],
        seen: &mut [bool],
        stack: &mut Vec<usize>,
        from: usize,
        to: usize,
    ) -> bool {
        seen.iter_mut().for_each(|s| *s = false);
        stack.clear();
        stack.push(from);
        while let Some(x) = stack.pop() {
            if x == to {
                return true;
            }
            if seen[x] {
                continue;
            }
            seen[x] = true;
            let mut cursor = adj_head[x];
            while cursor != usize::MAX {
                let (tgt, next) = adj_pool[cursor];
                stack.push(tgt);
                cursor = next;
            }
        }
        false
    }
    // Path b ⇝ a closes a cycle outright.
    if reach(adj_head, adj_pool, seen, stack, b, a) {
        return true;
    }
    // A second a ⇝ b path (not the direct edge) would sandwich whatever it
    // passes through between the merged component and itself.
    let mut cursor = adj_head[a];
    while cursor != usize::MAX {
        let (s, next) = adj_pool[cursor];
        if s != b && reach(adj_head, adj_pool, seen, stack, s, b) {
            return true;
        }
        cursor = next;
    }
    false
}

/// Reusable partitioning arena: union-find, cycle-check scratch, and flat
/// CSR output storage (subgraph layer lists, dependency lists, owners, cut
/// edges). Create one per evaluator thread; [`Self::partition_into`]
/// overwrites the result in place — after the first call at a network's
/// size, partitioning allocates nothing whatever the cut pattern (every
/// buffer is bounded by the layer/edge count).
#[derive(Default)]
pub struct PartitionWorkspace {
    uf: UnionFind,
    adj_head: Vec<usize>,
    adj_pool: Vec<(usize, usize)>,
    seen: Vec<bool>,
    stack: Vec<usize>,
    /// Component root (layer index) → subgraph id, `usize::MAX` = unseen.
    sg_of_root: Vec<usize>,
    sg_count: usize,
    owner: Vec<SubgraphId>,
    /// Per subgraph: offset into `sg_layers` (length `sg_count + 1`).
    sg_starts: Vec<usize>,
    /// All layers grouped by subgraph, ascending `LayerId` within each.
    sg_layers: Vec<LayerId>,
    sg_proc: Vec<Processor>,
    cursor: Vec<usize>,
    cut_edges: Vec<EdgeId>,
    /// (consumer, producer) subgraph pairs, sorted + deduplicated.
    dep_pairs: Vec<(usize, usize)>,
    /// Per subgraph: offset into `deps` (length `sg_count + 1`).
    dep_starts: Vec<usize>,
    deps: Vec<SubgraphId>,
}

impl PartitionWorkspace {
    pub fn new() -> PartitionWorkspace {
        PartitionWorkspace::default()
    }

    /// Partition `net` into the workspace arenas (see [`partition`] for the
    /// semantics — both run this one algorithm). Overwrites the previous
    /// result; read it back through the accessors.
    pub fn partition_into(&mut self, net: &Network, cuts: &[bool], mapping: &[Processor]) {
        assert_eq!(cuts.len(), net.num_edges(), "one cut bit per edge");
        assert_eq!(mapping.len(), net.num_layers(), "one processor per layer");
        let n = net.num_layers();
        let PartitionWorkspace {
            uf,
            adj_head,
            adj_pool,
            seen,
            stack,
            sg_of_root,
            sg_count,
            owner,
            sg_starts,
            sg_layers,
            sg_proc,
            cursor,
            cut_edges,
            dep_pairs,
            dep_starts,
            deps,
        } = self;

        // Pre-size every arena to its bound (layer or edge count) up front,
        // clearing first — `reserve` counts from the current length, so a
        // stale length from the previous call would inflate the request past
        // the warmed capacity and force a realloc. After one call at a
        // network's size, any cut pattern on same-or-smaller networks stays
        // within these capacities: that is the zero-allocation-when-warm
        // contract the replay test asserts.
        let n_edges = net.num_edges();
        adj_pool.clear();
        adj_pool.reserve(n_edges);
        adj_head.clear();
        adj_head.reserve(n);
        seen.clear();
        seen.reserve(n);
        stack.clear();
        stack.reserve(n_edges + 1); // DFS pushes ≤ 1 root + one per condensed edge
        owner.clear();
        owner.reserve(n);
        sg_starts.clear();
        sg_starts.reserve(n + 1);
        dep_starts.clear();
        dep_starts.reserve(n + 1);
        sg_proc.clear();
        sg_proc.reserve(n);
        cursor.clear();
        cursor.reserve(n);
        cut_edges.clear();
        cut_edges.reserve(n_edges);
        dep_pairs.clear();
        dep_pairs.reserve(n_edges);
        deps.clear();
        deps.reserve(n_edges);

        // Union-find over layers via kept edges, with convexity repair:
        // merges are applied in edge-index order and skipped if they would
        // close a cycle between components.
        uf.reset(n);
        for (i, e) in net.edges().iter().enumerate() {
            if !cuts[i] {
                let (a, b) = (uf.find(e.src.0), uf.find(e.dst.0));
                if a != b && !merge_creates_cycle(net, uf, adj_head, adj_pool, seen, stack, a, b)
                {
                    uf.union(a, b);
                }
            }
        }

        // Subgraph ids by first touch in topological order, so the condensed
        // DAG comes out topologically numbered.
        sg_of_root.clear();
        sg_of_root.resize(n, usize::MAX);
        let mut nsg = 0usize;
        for &l in net.topological_order() {
            let r = uf.find(l.0);
            if sg_of_root[r] == usize::MAX {
                sg_of_root[r] = nsg;
                nsg += 1;
            }
        }
        *sg_count = nsg;
        owner.clear();
        for l in 0..n {
            owner.push(SubgraphId(sg_of_root[uf.find(l)]));
        }

        // Layer lists: counting sort by owner over ascending LayerId, so
        // each subgraph's slice is in LayerId order (the canonical order the
        // owned path sorted into).
        sg_starts.clear();
        sg_starts.resize(nsg + 1, 0);
        for o in owner.iter() {
            sg_starts[o.0 + 1] += 1;
        }
        for s in 0..nsg {
            sg_starts[s + 1] += sg_starts[s];
        }
        cursor.clear();
        cursor.extend_from_slice(&sg_starts[..nsg]);
        sg_layers.clear();
        sg_layers.resize(n, LayerId(0));
        for l in 0..n {
            let s = owner[l].0;
            sg_layers[cursor[s]] = LayerId(l);
            cursor[s] += 1;
        }

        // Majority-vote processor per subgraph.
        sg_proc.clear();
        for s in 0..nsg {
            let layers = &sg_layers[sg_starts[s]..sg_starts[s + 1]];
            sg_proc.push(majority_vote(layers.iter().map(|l| mapping[l.0])));
        }

        // Dependencies: every cross-component edge (cut by the chromosome or
        // by the convexity repair) makes owner(dst) depend on owner(src).
        cut_edges.clear();
        dep_pairs.clear();
        for (i, e) in net.edges().iter().enumerate() {
            let from = owner[e.src.0];
            let to = owner[e.dst.0];
            if from != to {
                cut_edges.push(EdgeId(i));
                dep_pairs.push((to.0, from.0));
            }
        }
        dep_pairs.sort_unstable();
        dep_pairs.dedup();
        dep_starts.clear();
        dep_starts.resize(nsg + 1, 0);
        for &(to, _) in dep_pairs.iter() {
            dep_starts[to + 1] += 1;
        }
        for s in 0..nsg {
            dep_starts[s + 1] += dep_starts[s];
        }
        deps.clear();
        deps.extend(dep_pairs.iter().map(|&(_, from)| SubgraphId(from)));
    }

    pub fn num_subgraphs(&self) -> usize {
        self.sg_count
    }

    /// Member layers of subgraph `s`, ascending `LayerId`.
    pub fn subgraph_layers(&self, s: usize) -> &[LayerId] {
        &self.sg_layers[self.sg_starts[s]..self.sg_starts[s + 1]]
    }

    /// Majority-vote processor of subgraph `s`.
    pub fn subgraph_processor(&self, s: usize) -> Processor {
        self.sg_proc[s]
    }

    /// Producers subgraph `s` consumes tensors from (sorted, deduplicated).
    pub fn subgraph_deps(&self, s: usize) -> &[SubgraphId] {
        &self.deps[self.dep_starts[s]..self.dep_starts[s + 1]]
    }

    /// Subgraph owning a layer.
    pub fn owner_of(&self, l: LayerId) -> SubgraphId {
        self.owner[l.0]
    }

    /// Cut edges of the last partitioning, edge-index order.
    pub fn cut_edges(&self) -> &[EdgeId] {
        &self.cut_edges
    }

    /// Materialize the workspace result as an owned [`Partition`].
    pub fn to_partition(&self, network: NetworkId) -> Partition {
        let subgraphs = (0..self.sg_count)
            .map(|s| Subgraph {
                id: SubgraphId(s),
                network,
                layers: self.subgraph_layers(s).to_vec(),
                processor: self.sg_proc[s],
                deps: self.subgraph_deps(s).to_vec(),
            })
            .collect();
        Partition {
            network,
            subgraphs,
            owner: self.owner.clone(),
            cut_edges: self.cut_edges.clone(),
        }
    }
}

/// Partition `net` by cutting the edges flagged in `cuts` (one bool per edge,
/// insertion order), assigning each subgraph the majority-vote processor of
/// `mapping` (one preference per layer). Kept edges whose merge would create
/// a cyclic condensed graph are repaired to cut (module docs).
///
/// Convenience entry point: one throwaway [`PartitionWorkspace`] plus an
/// owned materialization. Hot loops keep a workspace and call
/// [`PartitionWorkspace::partition_into`] directly.
pub fn partition(net: &Network, cuts: &[bool], mapping: &[Processor]) -> Partition {
    let mut ws = PartitionWorkspace::new();
    ws.partition_into(net, cuts, mapping);
    ws.to_partition(net.id)
}

/// Majority vote with deterministic tie-breaking (lowest processor index).
fn majority_vote(votes: impl Iterator<Item = Processor>) -> Processor {
    let mut counts = [0usize; 3];
    for v in votes {
        counts[v.index()] += 1;
    }
    let best = counts.iter().copied().max().unwrap_or(0);
    Processor::ALL
        .into_iter()
        .find(|p| counts[p.index()] == best)
        .unwrap_or(Processor::Cpu)
}

/// Minimal union-find with path compression + union by size.
#[derive(Default)]
struct UnionFind {
    parent: Vec<usize>,
    size: Vec<usize>,
}

impl UnionFind {
    /// Reinitialize for `n` singleton elements, retaining capacity.
    fn reset(&mut self, n: usize) {
        self.parent.clear();
        self.parent.extend(0..n);
        self.size.clear();
        self.size.resize(n, 1);
    }

    fn find(&mut self, mut x: usize) -> usize {
        while self.parent[x] != x {
            self.parent[x] = self.parent[self.parent[x]];
            x = self.parent[x];
        }
        x
    }

    fn union(&mut self, a: usize, b: usize) {
        let (mut ra, mut rb) = (self.find(a), self.find(b));
        if ra == rb {
            return;
        }
        if self.size[ra] < self.size[rb] {
            std::mem::swap(&mut ra, &mut rb);
        }
        self.parent[rb] = ra;
        self.size[ra] += self.size[rb];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::layer::Layer;

    #[test]
    fn majority_vote_ties_break_low() {
        let v = majority_vote([Processor::Gpu, Processor::Cpu].into_iter());
        assert_eq!(v, Processor::Cpu);
        let v = majority_vote([Processor::Npu, Processor::Npu, Processor::Cpu].into_iter());
        assert_eq!(v, Processor::Npu);
    }

    #[test]
    fn deps_follow_cut_edges() {
        let mut net = Network::new(0, "chain");
        let a = net.add_layer(Layer::conv("a", 8, 8, 8, 3, 1));
        let b = net.add_layer(Layer::conv("b", 8, 8, 8, 3, 1));
        let c = net.add_layer(Layer::conv("c", 8, 8, 8, 3, 1));
        net.connect(a, b);
        net.connect(b, c);
        net.finalize();
        let p = partition(&net, &[true, false], &[Processor::Cpu, Processor::Gpu, Processor::Gpu]);
        assert_eq!(p.subgraphs.len(), 2);
        assert_eq!(p.subgraphs[1].deps, vec![SubgraphId(0)]);
        assert!(p.subgraphs[0].deps.is_empty());
        assert_eq!(p.cut_edges.len(), 1);
    }

    #[test]
    fn cut_bytes_accounts_src_tensor() {
        let mut net = Network::new(0, "pair");
        let a = net.add_layer(Layer::conv("a", 8, 8, 4, 3, 1)); // out 8x8x4
        let b = net.add_layer(Layer::conv("b", 8, 4, 4, 3, 1));
        net.connect(a, b);
        net.finalize();
        let p = partition(&net, &[true], &[Processor::Cpu, Processor::Cpu]);
        assert_eq!(p.cut_bytes(&net, crate::DataType::Fp32), 8 * 8 * 4 * 4);
    }

    #[test]
    fn owners_total() {
        let net = {
            let mut n = Network::new(0, "d");
            let a = n.add_layer(Layer::conv("a", 8, 8, 8, 3, 1));
            let b = n.add_layer(Layer::conv("b", 8, 8, 8, 3, 1));
            n.connect(a, b);
            n.finalize();
            n
        };
        let p = partition(&net, &[false], &[Processor::Cpu, Processor::Cpu]);
        for l in 0..net.num_layers() {
            assert!(p.owner[l].0 != usize::MAX);
        }
    }

    #[test]
    fn workspace_view_matches_owned_partition() {
        // One reused workspace across many cut patterns must agree with the
        // owned materialization field for field.
        let net = crate::models::build_model(0, 5);
        let mut ws = PartitionWorkspace::new();
        let mut rng = crate::util::rng::Rng::seed_from_u64(13);
        for _ in 0..40 {
            let cuts: Vec<bool> = (0..net.num_edges()).map(|_| rng.gen_bool(0.4)).collect();
            let mapping: Vec<Processor> = (0..net.num_layers())
                .map(|_| Processor::from_index(rng.gen_range(0, 3)))
                .collect();
            let owned = partition(&net, &cuts, &mapping);
            ws.partition_into(&net, &cuts, &mapping);
            assert_eq!(ws.num_subgraphs(), owned.num_subgraphs());
            for (s, sg) in owned.subgraphs.iter().enumerate() {
                assert_eq!(ws.subgraph_layers(s), sg.layers.as_slice());
                assert_eq!(ws.subgraph_processor(s), sg.processor);
                assert_eq!(ws.subgraph_deps(s), sg.deps.as_slice());
            }
            assert_eq!(ws.cut_edges(), owned.cut_edges.as_slice());
            for l in 0..net.num_layers() {
                assert_eq!(ws.owner_of(LayerId(l)), owned.owner_of(LayerId(l)));
            }
            let rebuilt = ws.to_partition(net.id);
            assert_eq!(rebuilt.owner, owned.owner);
            assert_eq!(rebuilt.cut_edges, owned.cut_edges);
        }
    }

    #[test]
    fn workspace_partition_is_allocation_free_once_warm() {
        // One warm call at a network's size must cover ANY later cut
        // pattern on it: every arena is pre-reserved to its layer/edge
        // bound, not just to the sizes the warm pattern happened to touch.
        let net = crate::models::build_model(0, 5);
        let mut rng = crate::util::rng::Rng::seed_from_u64(29);
        let mut ws = PartitionWorkspace::new();
        // Warm with an all-cut pattern (no merges attempted — the cycle
        // scratch must still be covered for patterns that do merge).
        let all_cut = vec![true; net.num_edges()];
        let all_cpu = vec![Processor::Cpu; net.num_layers()];
        ws.partition_into(&net, &all_cut, &all_cpu);
        let patterns: Vec<(Vec<bool>, Vec<Processor>)> = (0..12)
            .map(|_| {
                (
                    (0..net.num_edges()).map(|_| rng.gen_bool(0.4)).collect(),
                    (0..net.num_layers())
                        .map(|_| Processor::from_index(rng.gen_range(0, 3)))
                        .collect(),
                )
            })
            .collect();
        let before = crate::util::alloc::thread_allocations();
        for (cuts, mapping) in &patterns {
            ws.partition_into(&net, cuts, mapping);
        }
        let after = crate::util::alloc::thread_allocations();
        assert_eq!(after - before, 0, "workspace partitioning allocated");
        assert!(ws.num_subgraphs() >= 1);
    }
}
