//! DNN graph intermediate representation.
//!
//! Networks are DAGs of [`Layer`]s connected by [`Edge`]s carrying tensors.
//! The Static Analyzer partitions a network by *cutting edges* (paper §4.2,
//! Fig 7): the partition chromosome is one bit per edge, and the connected
//! components of the uncut graph become [`Subgraph`]s — the units of
//! compilation, profiling, and execution.

mod layer;
mod merkle;
mod network;
mod partition;

pub use layer::{Layer, LayerId, LayerKind, TensorShape};
pub use merkle::{
    fnv1a, fnv1a_u64, merkle_hash_layers, merkle_hash_network, merkle_hash_subgraph, MerkleHash,
    MerkleScratch, FNV_OFFSET,
};
pub use network::{Edge, EdgeId, Network, NetworkId};
pub use partition::{partition, Partition, PartitionWorkspace, Subgraph, SubgraphId};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::Processor;

    /// The diamond network of paper Fig 3/7: L0 -> {L1, L2} -> L3.
    pub(crate) fn diamond() -> Network {
        let mut n = Network::new(0, "diamond");
        let l0 = n.add_layer(Layer::conv("l0", 8, 8, 16, 3, 1));
        let l1 = n.add_layer(Layer::conv("l1", 8, 16, 16, 3, 1));
        let l2 = n.add_layer(Layer::conv("l2", 8, 16, 16, 3, 1));
        let l3 = n.add_layer(Layer::add("l3", 8, 16));
        n.connect(l0, l1);
        n.connect(l0, l2);
        n.connect(l1, l3);
        n.connect(l2, l3);
        n.finalize();
        n
    }

    #[test]
    fn diamond_structure() {
        let n = diamond();
        assert_eq!(n.num_layers(), 4);
        assert_eq!(n.num_edges(), 4);
        assert_eq!(n.inputs(), &[LayerId(0)]);
        assert_eq!(n.outputs(), &[LayerId(3)]);
        let topo = n.topological_order();
        assert_eq!(topo[0], LayerId(0));
        assert_eq!(topo[3], LayerId(3));
    }

    #[test]
    fn no_cuts_single_subgraph() {
        let n = diamond();
        let cuts = vec![false; n.num_edges()];
        let p = partition(&n, &cuts, &[Processor::Npu; 4]);
        assert_eq!(p.subgraphs.len(), 1);
        assert_eq!(p.subgraphs[0].layers.len(), 4);
        assert_eq!(p.subgraphs[0].processor, Processor::Npu);
    }

    #[test]
    fn all_cuts_per_layer_subgraphs() {
        let n = diamond();
        let cuts = vec![true; n.num_edges()];
        let p = partition(&n, &cuts, &[Processor::Cpu; 4]);
        assert_eq!(p.subgraphs.len(), 4);
        for sg in &p.subgraphs {
            assert_eq!(sg.layers.len(), 1);
        }
    }

    #[test]
    fn paper_fig7_partition() {
        // Fig 7: edges [2],[3] cut on a 5-layer chain-with-branch network
        // gives two subgraphs; mapping majority vote picks the processor.
        let n = diamond();
        // Cut the two edges into l3 => {l0,l1,l2} and {l3}.
        let mut cuts = vec![false; n.num_edges()];
        let e_l1_l3 = n.edge_between(LayerId(1), LayerId(3)).unwrap();
        let e_l2_l3 = n.edge_between(LayerId(2), LayerId(3)).unwrap();
        cuts[e_l1_l3.0] = true;
        cuts[e_l2_l3.0] = true;
        let mapping = [Processor::Npu, Processor::Npu, Processor::Cpu, Processor::Gpu];
        let p = partition(&n, &cuts, &mapping);
        assert_eq!(p.subgraphs.len(), 2);
        // Majority vote of {NPU, NPU, CPU} is NPU.
        let big = p.subgraphs.iter().find(|s| s.layers.len() == 3).unwrap();
        assert_eq!(big.processor, Processor::Npu);
        let small = p.subgraphs.iter().find(|s| s.layers.len() == 1).unwrap();
        assert_eq!(small.processor, Processor::Gpu);
    }
}
