//! # `puzzle::api` — the owned analyze → deploy → serve session layer.
//!
//! Puzzle's pipeline is one conceptual flow (paper Fig 2): describe a
//! scenario, run device-in-the-loop GA analysis, pick a Pareto solution,
//! and hand it to the Runtime. This module exposes that flow behind owned,
//! `Arc`-based types, replacing the borrow-heavy entry points
//! (`StaticAnalyzer<'a>`, hand-wired `NetworkSolution` construction):
//!
//! ```no_run
//! use puzzle::api::{GenerationProgress, RuntimeOptions, ScenarioSpec, SessionBuilder};
//! use puzzle::analyzer::GaConfig;
//!
//! // 1. Describe the workload and budget.
//! let session = SessionBuilder::new(ScenarioSpec::single_group("demo", vec![0, 1, 6]))
//!     .config(GaConfig::quick(23))
//!     .build()
//!     .unwrap();
//!
//! // 2. Analyze, streaming per-generation progress.
//! let analysis = session.run_observed(&mut |p: &GenerationProgress<'_>| {
//!     println!("gen {:>3}: {} evaluations", p.generation, p.evaluations);
//! });
//!
//! // 3. Deploy the chosen Pareto solution to a ready Coordinator.
//! let mut deployment = analysis
//!     .deploy(analysis.best_index(), RuntimeOptions::default())
//!     .unwrap();
//! deployment.serve(0, 10, std::time::Duration::from_secs(10));
//! println!("makespans: {:?}", deployment.simulated_makespans());
//! deployment.shutdown();
//! ```
//!
//! The [`Analysis`] holds `Arc<Scenario>` / `Arc<PerfModel>` and a Pareto
//! front of [`Solution`]s whose decoded plans are shared `Arc<PlanSet>`s, so
//! selection, serialization ([`Analysis::save`]), and deployment never copy
//! plan vectors. New scenario types slot in through [`ScenarioSpec`]
//! (including [`ScenarioSpec::Custom`] for networks outside the zoo); new
//! execution backends through [`Analysis::deploy_with_engine`].
#![warn(missing_docs)]

use std::ops::ControlFlow;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use crate::analyzer::solution_io;
use crate::analyzer::{AnalysisResult, StaticAnalyzer};
use crate::anyhow;
use crate::comm::CommModel;
use crate::coordinator::{Coordinator, NetworkSolution, ServedRequest};
use crate::engine::{Engine, SimEngine};
use crate::ga::{decode, decode_network, PlanSet};
use crate::graph::Network;
use crate::models;
use crate::perf::PerfModel;
use crate::profiler::{DeviceProbe, Profiler};
use crate::scenario::{multi_group_scenarios, single_group_scenarios, Scenario};
use crate::serve;
use crate::sim::compile_plans;
use crate::util::error::Result;

pub use crate::analyzer::{GaConfig, Solution};
pub use crate::coordinator::{OverloadPolicy, RecoveryOptions, RuntimeOptions};
pub use crate::experiments::fuzz::{
    calibrate_slack, run_fuzz_corpus, FuzzCaseOutcome, FuzzOptions, SlackSweepRow,
};
pub use crate::experiments::serving::{
    FigureReport, FigureSelection, Method, ProtocolProgress, ServingBudget,
};
pub use crate::scenario::fuzz::{
    ArrivalKind, ChurnEvent, ChurnKind, FuzzConfig, FuzzedScenario, ScenarioFuzzer,
};
pub use crate::serve::envelope::{certificate_corroborated, Envelope, EnvelopeBreach};
pub use crate::serve::{
    envelope_for, Admission, ArrivalProcess, ClockMode, FaultEvent, FaultPlan, GroupLoad,
    LoadError, LoadSpec, ProbeProgress, RateSegment, SaturationOptions, ServeReport,
};
pub use crate::telemetry::{MetricsAggregator, TelemetryEvent, TelemetryRx};

/// Wall-seconds per simulated second used by [`Analysis::deploy`]'s default
/// simulated engine (1 simulated ms replays in 50 µs).
pub const DEFAULT_TIME_SCALE: f64 = 0.05;

/// Declarative description of the workload a session analyzes.
#[derive(Debug, Clone)]
pub enum ScenarioSpec {
    /// Named model groups drawn from the nine-model zoo: one inner `Vec`
    /// of zoo indices per group.
    ZooGroups {
        /// Scenario name (reports, solution files).
        name: String,
        /// Zoo indices per model group.
        groups: Vec<Vec<usize>>,
    },
    /// Scenario `index` (0..10) of the paper's random single-group
    /// generator (Fig 11 top), deterministic in `seed`.
    GeneratedSingle {
        /// Generator seed.
        seed: u64,
        /// Which of the ten generated scenarios to pick.
        index: usize,
    },
    /// Scenario `index` (0..10) of the random two-group generator (Fig 11
    /// bottom).
    GeneratedMulti {
        /// Generator seed.
        seed: u64,
        /// Which of the ten generated scenarios to pick.
        index: usize,
    },
    /// Caller-provided networks (models outside the zoo). `groups`
    /// partitions the network indices into model groups.
    Custom {
        /// Scenario name (reports, solution files).
        name: String,
        /// The networks themselves (unique names required — the profiler
        /// keys statistics by name).
        networks: Vec<Network>,
        /// Network indices per model group (a partition of `networks`).
        groups: Vec<Vec<usize>>,
    },
    /// An already-built scenario, adopted as-is.
    Prebuilt(Scenario),
}

/// Shared group-shape validation: at least one group, none empty.
fn validate_group_shape(name: &str, groups: &[Vec<usize>]) -> Result<()> {
    if groups.is_empty() || groups.iter().any(|g| g.is_empty()) {
        return Err(anyhow!("scenario {name:?} needs at least one non-empty group"));
    }
    Ok(())
}

/// Pick scenario `index` from a generator's output.
fn pick_generated(mut all: Vec<Scenario>, index: usize) -> Result<Scenario> {
    if index >= all.len() {
        return Err(anyhow!("generated scenario index {index} out of range (0..{})", all.len()));
    }
    Ok(all.swap_remove(index))
}

impl ScenarioSpec {
    /// One model group of zoo models — the common case.
    pub fn single_group(name: &str, zoo_indices: Vec<usize>) -> ScenarioSpec {
        ScenarioSpec::ZooGroups { name: name.to_string(), groups: vec![zoo_indices] }
    }

    /// Validate and materialize the scenario.
    fn build(self) -> Result<Scenario> {
        match self {
            ScenarioSpec::ZooGroups { name, groups } => {
                validate_group_shape(&name, &groups)?;
                for &zoo in groups.iter().flatten() {
                    if zoo >= models::MODEL_COUNT {
                        return Err(anyhow!(
                            "zoo index {zoo} out of range (the zoo has {} models)",
                            models::MODEL_COUNT
                        ));
                    }
                }
                Ok(Scenario::from_groups(&name, &groups))
            }
            ScenarioSpec::GeneratedSingle { seed, index } => {
                pick_generated(single_group_scenarios(seed), index)
            }
            ScenarioSpec::GeneratedMulti { seed, index } => {
                pick_generated(multi_group_scenarios(seed), index)
            }
            ScenarioSpec::Custom { name, networks, groups } => {
                validate_group_shape(&name, &groups)?;
                let mut seen = vec![false; networks.len()];
                for &m in groups.iter().flatten() {
                    if m >= networks.len() {
                        return Err(anyhow!(
                            "group member {m} out of range ({} networks)",
                            networks.len()
                        ));
                    }
                    if seen[m] {
                        return Err(anyhow!("network {m} appears in more than one group"));
                    }
                    seen[m] = true;
                }
                if let Some(missing) = seen.iter().position(|s| !s) {
                    return Err(anyhow!("network {missing} belongs to no group"));
                }
                // The profiler pools calibration and config-ordering stats
                // by network name: two *different* models sharing a name
                // would silently cross-contaminate them.
                let mut names: Vec<&str> = networks.iter().map(|n| n.name.as_str()).collect();
                names.sort_unstable();
                if let Some(dup) = names.windows(2).find(|w| w[0] == w[1]) {
                    return Err(anyhow!(
                        "duplicate network name {:?}: custom networks must have unique names \
                         (the profiler keys performance statistics by name)",
                        dup[0]
                    ));
                }
                Ok(Scenario::from_networks(&name, networks, &groups))
            }
            ScenarioSpec::Prebuilt(s) => Ok(s),
        }
    }
}

/// Where the session's device model comes from.
#[derive(Debug, Clone)]
pub enum PerfSource {
    /// [`PerfModel::paper_calibrated`] — the Snapdragon 8 Gen 2 calibration.
    Calibrated,
    /// A caller-supplied model (re-calibrated tables, hypothetical device).
    Model(PerfModel),
}

/// Per-generation search telemetry streamed through [`Observer`].
/// Generation 0 is the evaluated initial population.
#[derive(Debug)]
pub struct GenerationProgress<'a> {
    /// Generation just evaluated (0 = the initial population).
    pub generation: usize,
    /// Candidate evaluations so far (including local-search probes).
    pub evaluations: usize,
    /// Objectives of the current best solution by the paper's
    /// smallest-maximum-makespan rule.
    pub best_objectives: &'a [f64],
    /// Population-average aggregate objective (the stop-rule signal).
    pub avg_aggregate: f64,
    /// Generations since the average last improved (patience counter).
    pub stale_generations: usize,
    /// Profile-DB lookups answered from the merkle-keyed cache so far.
    pub profile_cache_hits: u64,
    /// Device measurements the profile DB had to perform so far.
    pub profile_measurements: u64,
    /// Genome→plan memo hits so far.
    pub plan_cache_hits: u64,
    /// Genome→plan memo misses (full decodes) so far.
    pub plan_cache_misses: u64,
    /// Config probes skipped so far by the profiler's dominance cutoff
    /// (best-first probing at work during long searches).
    pub probe_skips: u64,
    /// Best-config memo hits so far (whole config scans avoided).
    pub best_memo_hits: u64,
}

impl GenerationProgress<'_> {
    /// Profile-DB hit rate so far (0.0 when nothing was looked up).
    pub fn profile_cache_hit_rate(&self) -> f64 {
        let total = self.profile_cache_hits + self.profile_measurements;
        if total == 0 { 0.0 } else { self.profile_cache_hits as f64 / total as f64 }
    }

    /// Genome→plan memo hit rate so far.
    pub fn plan_cache_hit_rate(&self) -> f64 {
        let total = self.plan_cache_hits + self.plan_cache_misses;
        if total == 0 { 0.0 } else { self.plan_cache_hits as f64 / total as f64 }
    }
}

/// Mid-generation telemetry: one event per evaluated candidate batch (the
/// initial population, then each generation's offspring) — finer-grained
/// than [`GenerationProgress`], and the natural cancellation point for long
/// searches.
#[derive(Debug, Clone)]
pub struct BatchProgress {
    /// Generation the batch belongs to (0 = initial population).
    pub generation: usize,
    /// Candidates in this batch.
    pub batch_size: usize,
    /// Candidate evaluations so far (including local-search probes).
    pub evaluations: usize,
}

/// Receives streamed search progress during
/// [`AnalysisSession::run_observed`]. Implemented for any
/// `FnMut(&GenerationProgress)` closure (which never cancels).
///
/// Returning [`ControlFlow::Break`] from either hook cancels the search:
/// the analyzer finishes the replacement step it is in and returns the
/// current front with [`Analysis::cancelled`] set — long searches stay
/// interruptible from a CLI or serving layer without losing the
/// evaluations already paid for.
pub trait Observer {
    /// Per-generation progress (after each replacement step).
    fn on_generation(&mut self, progress: &GenerationProgress<'_>) -> ControlFlow<()>;

    /// Per-batch (mid-generation) progress. Default: keep running.
    fn on_batch(&mut self, _progress: &BatchProgress) -> ControlFlow<()> {
        ControlFlow::Continue(())
    }
}

impl<F: FnMut(&GenerationProgress<'_>)> Observer for F {
    fn on_generation(&mut self, progress: &GenerationProgress<'_>) -> ControlFlow<()> {
        self(progress);
        ControlFlow::Continue(())
    }
}

/// An observer that discards all progress (the [`AnalysisSession::run`]
/// path). A free function returning a closure — a named unit struct would
/// conflict with the blanket `FnMut` implementation under coherence.
pub fn null_observer() -> impl Observer {
    |_: &GenerationProgress<'_>| {}
}

/// Builder for an [`AnalysisSession`]: pick the workload
/// ([`ScenarioSpec`]), the device model ([`PerfSource`]), the GA budget
/// ([`GaConfig`]), and the communication model, then [`SessionBuilder::build`].
///
/// ```no_run
/// use puzzle::analyzer::GaConfig;
/// use puzzle::api::{RuntimeOptions, ScenarioSpec, SessionBuilder};
///
/// # fn main() -> puzzle::util::error::Result<()> {
/// // A camera-synchronized group of three zoo models, quick search budget.
/// let session = SessionBuilder::new(ScenarioSpec::single_group("demo", vec![0, 1, 6]))
///     .config(GaConfig::quick(42))
///     .build()?;
/// let analysis = session.run();
///
/// // Deploy the best Pareto solution and push an open-loop load through it.
/// let mut deployment = analysis.deploy(analysis.best_index(), RuntimeOptions::default())?;
/// deployment.serve(0, 10, std::time::Duration::from_secs(10));
/// deployment.shutdown();
/// # Ok(())
/// # }
/// ```
pub struct SessionBuilder {
    spec: ScenarioSpec,
    perf: PerfSource,
    config: GaConfig,
    comm: CommModel,
}

impl SessionBuilder {
    /// Start a builder for the given workload, with the calibrated device
    /// model and default GA budget.
    pub fn new(spec: ScenarioSpec) -> SessionBuilder {
        SessionBuilder {
            spec,
            perf: PerfSource::Calibrated,
            config: GaConfig::default(),
            comm: CommModel::paper_calibrated(),
        }
    }

    /// Adopt an already-built [`Scenario`].
    pub fn for_scenario(scenario: Scenario) -> SessionBuilder {
        SessionBuilder::new(ScenarioSpec::Prebuilt(scenario))
    }

    /// Choose where the session's device model comes from.
    pub fn perf(mut self, source: PerfSource) -> SessionBuilder {
        self.perf = source;
        self
    }

    /// Shorthand for [`PerfSource::Model`].
    pub fn perf_model(mut self, model: PerfModel) -> SessionBuilder {
        self.perf = PerfSource::Model(model);
        self
    }

    /// Set the GA search budget and seed.
    pub fn config(mut self, config: GaConfig) -> SessionBuilder {
        self.config = config;
        self
    }

    /// Replace the communication-cost model pricing cross-subgraph
    /// transfers.
    pub fn comm(mut self, comm: CommModel) -> SessionBuilder {
        self.comm = comm;
        self
    }

    /// Validate the spec and assemble the session.
    pub fn build(self) -> Result<AnalysisSession> {
        let scenario = Arc::new(self.spec.build()?);
        let perf = Arc::new(match self.perf {
            PerfSource::Calibrated => PerfModel::paper_calibrated(),
            PerfSource::Model(m) => m,
        });
        // One profiler for the session's lifetime: the search fills its
        // merkle-keyed profile DB and best-config memo, deployment and
        // solution loading reuse them instead of re-deriving exec configs.
        let probe: Arc<dyn DeviceProbe> = perf.clone();
        let profiler = Arc::new(Profiler::shared(probe));
        Ok(AnalysisSession { scenario, perf, profiler, comm: self.comm, config: self.config })
    }
}

/// An owned, ready-to-run analysis: scenario + device model + GA budget,
/// sharing one [`Profiler`] across analyze → deploy.
pub struct AnalysisSession {
    scenario: Arc<Scenario>,
    perf: Arc<PerfModel>,
    profiler: Arc<Profiler<'static>>,
    comm: CommModel,
    config: GaConfig,
}

impl AnalysisSession {
    /// The scenario this session analyzes.
    pub fn scenario(&self) -> &Arc<Scenario> {
        &self.scenario
    }

    /// The session's device model.
    pub fn perf(&self) -> &Arc<PerfModel> {
        &self.perf
    }

    /// The session-shared device profiler (profile DB + best-config memo).
    pub fn profiler(&self) -> &Arc<Profiler<'static>> {
        &self.profiler
    }

    /// The GA budget this session runs with.
    pub fn config(&self) -> &GaConfig {
        &self.config
    }

    /// Run the Static Analyzer search silently.
    pub fn run(&self) -> Analysis {
        self.run_observed(&mut null_observer())
    }

    /// Run the search, streaming per-generation and per-batch progress
    /// through `observer`; a `Break` from either hook cancels the search
    /// (see [`Observer`]).
    pub fn run_observed(&self, observer: &mut dyn Observer) -> Analysis {
        let mut engine = StaticAnalyzer::engine(&self.scenario, &self.perf, self.config.clone());
        engine.comm = self.comm.clone();
        let result = engine.run_observed_with(&self.profiler, observer);
        self.analysis_of(result)
    }

    /// Load previously saved solutions (v1–v3 files; v3 validates
    /// per-network structural hashes) back into a deployable [`Analysis`]:
    /// genomes are validated against this session's scenario and re-decoded
    /// through the session profiler, so the file stays device-independent.
    pub fn load_solutions(&self, path: &Path) -> Result<Analysis> {
        let loaded = solution_io::load_solutions(path, &self.scenario)?;
        if loaded.is_empty() {
            return Err(anyhow!("no solutions in {}", path.display()));
        }
        let pareto = loaded
            .into_iter()
            .map(|ls| {
                let plans =
                    decode(&self.scenario.networks, &ls.genome, &self.profiler, &self.comm);
                let compiled = compile_plans(&plans);
                Solution {
                    genome: ls.genome,
                    objectives: ls.objectives,
                    plan_set: Arc::new(PlanSet { plans, compiled }),
                }
            })
            .collect();
        Ok(Analysis {
            scenario: self.scenario.clone(),
            perf: self.perf.clone(),
            profiler: self.profiler.clone(),
            pareto,
            generations_run: 0,
            evaluations: 0,
            profile_cache_hits: 0,
            profile_measurements: 0,
            plan_cache_hits: 0,
            plan_cache_misses: 0,
            cancelled: false,
        })
    }

    fn analysis_of(&self, result: AnalysisResult) -> Analysis {
        Analysis {
            scenario: self.scenario.clone(),
            perf: self.perf.clone(),
            profiler: self.profiler.clone(),
            pareto: result.pareto,
            generations_run: result.generations_run,
            evaluations: result.evaluations,
            profile_cache_hits: result.profile_cache_hits,
            profile_measurements: result.profile_measurements,
            plan_cache_hits: result.plan_cache_hits,
            plan_cache_misses: result.plan_cache_misses,
            cancelled: result.cancelled,
        }
    }
}

/// Analysis output: the Pareto front (plan sets `Arc`-shared), search
/// telemetry, and the owned context needed to deploy any solution —
/// including the session's profiler, whose best-config memo deployment
/// reuses.
#[derive(Clone)]
pub struct Analysis {
    scenario: Arc<Scenario>,
    perf: Arc<PerfModel>,
    profiler: Arc<Profiler<'static>>,
    /// The Pareto front of the search (plan sets `Arc`-shared).
    pub pareto: Vec<Solution>,
    /// Generations the search ran before converging or being cancelled.
    pub generations_run: usize,
    /// Total candidate evaluations (including local-search probes).
    pub evaluations: usize,
    /// Profile-DB lookups answered from the merkle-keyed cache.
    pub profile_cache_hits: u64,
    /// Device measurements the profile DB had to perform.
    pub profile_measurements: u64,
    /// Genome→plan memo hits.
    pub plan_cache_hits: u64,
    /// Genome→plan memo misses (full decodes).
    pub plan_cache_misses: u64,
    /// True when the search was cancelled through an [`Observer`] hook: the
    /// front reflects the population at cancellation, not convergence.
    pub cancelled: bool,
}

impl Analysis {
    /// The analyzed scenario.
    pub fn scenario(&self) -> &Arc<Scenario> {
        &self.scenario
    }

    /// The device model the analysis ran against.
    pub fn perf(&self) -> &Arc<PerfModel> {
        &self.perf
    }

    /// The session-shared profiler backing this analysis.
    pub fn profiler(&self) -> &Arc<Profiler<'static>> {
        &self.profiler
    }

    /// Index of the solution minimizing the maximum (worst-group) average
    /// makespan — the paper's selection rule for single-number comparisons
    /// (§5.3). Panics on an empty Pareto front.
    pub fn best_index(&self) -> usize {
        self.pareto
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                a.max_objective().partial_cmp(&b.max_objective()).unwrap()
            })
            .map(|(i, _)| i)
            .expect("non-empty pareto front")
    }

    /// The solution chosen by [`Self::best_index`].
    pub fn best(&self) -> &Solution {
        &self.pareto[self.best_index()]
    }

    /// Save the Pareto front in the versioned solution-file format
    /// ([`solution_io`]).
    pub fn save(&self, path: &Path) -> Result<()> {
        solution_io::save_solutions(path, &self.scenario, &self.pareto)
    }

    /// Materialize runtime [`NetworkSolution`]s for one Pareto solution:
    /// partitions from the genome, per-subgraph exec configs from the
    /// session profiler's **best-config memo** (every Pareto genome was
    /// decoded through it during the search, so this is a pure memo read —
    /// no duplicate config scan), priorities from the priority chromosome.
    pub fn runtime_solutions(&self, solution_idx: usize) -> Result<Vec<NetworkSolution>> {
        let sol = self.pareto.get(solution_idx).ok_or_else(|| {
            anyhow!(
                "solution index {solution_idx} out of range ({} pareto solutions)",
                self.pareto.len()
            )
        })?;
        Ok(self
            .scenario
            .networks
            .iter()
            .zip(&sol.genome.networks)
            .enumerate()
            .map(|(i, (net, genes))| {
                let part = decode_network(net, genes);
                let configs = part
                    .subgraphs
                    .iter()
                    .map(|sg| self.profiler.best_on(net, sg, sg.processor).0)
                    .collect();
                NetworkSolution {
                    network: Arc::new(net.clone()),
                    partition: Arc::new(part),
                    configs,
                    priority: sol.genome.priority[i],
                }
            })
            .collect())
    }

    /// Deploy a Pareto solution to a ready [`Coordinator`] backed by the
    /// calibrated simulated engine at [`DEFAULT_TIME_SCALE`] (with execution
    /// noise, as on the real device).
    pub fn deploy(&self, solution_idx: usize, options: RuntimeOptions) -> Result<Deployment> {
        self.deploy_sim(solution_idx, options, DEFAULT_TIME_SCALE, true, 7)
    }

    /// Deploy with full control over the simulated engine (time scale, noise
    /// on/off, noise seed).
    pub fn deploy_sim(
        &self,
        solution_idx: usize,
        options: RuntimeOptions,
        time_scale: f64,
        noisy: bool,
        seed: u64,
    ) -> Result<Deployment> {
        let engine: Arc<dyn Engine> =
            Arc::new(SimEngine::new(self.perf.clone(), time_scale, noisy, seed));
        self.deploy_with_engine(solution_idx, options, engine, time_scale)
    }

    /// Deploy under **chaos testing**: the simulated engine is wrapped in a
    /// [`crate::serve::FaultyEngine`] pricing `plan`'s slowdowns and stalls
    /// into task durations (and injecting transient failures), and the
    /// Coordinator's watchdog/retry/remap recovery is enabled with default
    /// [`RecoveryOptions`]. Same `seed` + same `plan` ⇒ bit-identical
    /// virtual-clock replay, including retries and remaps.
    pub fn deploy_chaos(
        &self,
        solution_idx: usize,
        options: RuntimeOptions,
        time_scale: f64,
        noisy: bool,
        seed: u64,
        plan: FaultPlan,
    ) -> Result<Deployment> {
        let engine: Arc<dyn Engine> = Arc::new(crate::serve::FaultyEngine::new(
            self.perf.clone(),
            time_scale,
            noisy,
            seed,
            plan,
        ));
        let mut deployment = self.deploy_with_engine(solution_idx, options, engine, time_scale)?;
        deployment
            .coordinator
            .enable_recovery(self.perf.clone(), RecoveryOptions::default());
        Ok(deployment)
    }

    /// Deploy onto a caller-provided engine (e.g. the PJRT engine executing
    /// real AOT artifacts). `time_scale` is only used to convert served
    /// wall-clock makespans back to simulated seconds in
    /// [`Deployment::simulated_makespans`]; pass `1.0` for real engines.
    pub fn deploy_with_engine(
        &self,
        solution_idx: usize,
        options: RuntimeOptions,
        engine: Arc<dyn Engine>,
        time_scale: f64,
    ) -> Result<Deployment> {
        let solutions = self.runtime_solutions(solution_idx)?;
        let coordinator = Coordinator::new(solutions, engine, options);
        Ok(Deployment {
            coordinator,
            time_scale,
            groups: self.scenario.groups.iter().map(|g| g.members.clone()).collect(),
            perf: self.perf.clone(),
        })
    }
}

/// A live runtime serving one deployed solution: the [`Coordinator`] plus
/// the scenario's group membership, ready for group submissions.
///
/// Deployments are **persistent**: [`Deployment::serve_load`] can be called
/// any number of times (each report covers only its own load), and
/// [`Deployment::reset`] / [`Deployment::reset_seeded`] return the warm
/// stack to its post-deploy state — with a seeded reset, a replayed
/// virtual-clock load is bit-identical to the same load on a fresh
/// deployment.
pub struct Deployment {
    /// The live Coordinator owning the worker threads.
    pub coordinator: Coordinator,
    /// Wall-seconds per simulated second of the backing engine (1.0 for
    /// real engines).
    pub time_scale: f64,
    groups: Vec<Vec<usize>>,
    perf: Arc<PerfModel>,
}

impl Deployment {
    /// Number of model groups in the deployed scenario.
    pub fn num_groups(&self) -> usize {
        self.groups.len()
    }

    /// Return the warm runtime to its post-deploy state **without tearing
    /// the worker threads down**: drain in-flight work, then clear the
    /// served/dropped logs and request sequencing
    /// ([`Coordinator::reset`]). Returns the completions drained while
    /// settling.
    pub fn reset(&mut self) -> usize {
        self.coordinator.reset()
    }

    /// [`Deployment::reset`], additionally re-seeding the engine's
    /// execution-noise stream: a subsequent virtual-clock
    /// [`Deployment::serve_load`] is bit-identical to the same load on a
    /// fresh deployment seeded with `seed`.
    pub fn reset_seeded(&mut self, seed: u64) -> usize {
        let settled = self.coordinator.reset();
        self.coordinator.engine().reseed(seed);
        settled
    }

    /// Derive a [`OverloadPolicy::DropAfter`] admission cap for `spec` from
    /// Little's law against this deployment's solutions
    /// ([`crate::serve::little_inflight_cap`]): `slack ×` the expected
    /// in-flight population `Σ_g λ_g·W_g`, with a floor of one request per
    /// group. Pass [`Admission::DEFAULT_SLACK`] unless tuning.
    pub fn little_law_policy(&self, spec: &LoadSpec, slack: f64) -> OverloadPolicy {
        OverloadPolicy::DropAfter {
            max_inflight: serve::little_inflight_cap(
                self.coordinator.solutions(),
                &self.groups,
                &spec.mean_rates(),
                &self.perf,
                slack,
            ),
        }
    }

    /// Subscribe to this deployment's telemetry stream: arms the
    /// coordinator's pre-allocated event ring so subsequent loads emit
    /// [`TelemetryEvent`]s, and returns the non-blocking receiver. Drain
    /// with [`TelemetryRx::drain`]; fold into a [`MetricsAggregator`] to
    /// cross-check a [`ServeReport`]. Dropping the receiver disarms the
    /// stream (unsubscribed deployments pay one relaxed atomic load per
    /// would-be event and allocate nothing).
    pub fn subscribe(&self) -> TelemetryRx {
        self.coordinator.subscribe()
    }

    /// Network indices of one model group. Panics on an out-of-range group
    /// (groups are fixed by the scenario at deploy time).
    pub fn group_members(&self, group: usize) -> &[usize] {
        assert!(group < self.groups.len(), "group {group} out of range ({} groups)", self.groups.len());
        &self.groups[group]
    }

    /// Push an **open-loop load** through this deployment's runtime: per-
    /// group arrival processes (periodic / Poisson / bursty), deadline
    /// accounting, and an overload policy, summarized as a [`ServeReport`].
    ///
    /// [`ClockMode::Virtual`] drives the coordinator's deterministic event
    /// loop — deploy with `deploy_sim(.., time_scale = 0.0, ..)` so the
    /// engine never sleeps and the test runs at memo speed.
    /// [`ClockMode::Wall`] schedules arrivals in real time at this
    /// deployment's time scale (spec times are simulated seconds; the
    /// report converts back).
    pub fn serve_load(&mut self, spec: &LoadSpec) -> ServeReport {
        serve::run_load(&mut self.coordinator, &self.groups, spec, self.time_scale)
    }

    /// Submit `requests` synchronized group requests, pumping completions
    /// after each (up to `timeout` per request). Returns how many of *this
    /// group's* requests finished during this call (a straggler from an
    /// earlier timed-out call that completes now is counted — it is still
    /// this group's work — but another group's completions never are).
    /// Panics on an out-of-range group (see [`Self::group_members`]).
    pub fn serve(&mut self, group: usize, requests: usize, timeout: Duration) -> usize {
        let members = self.group_members(group).to_vec();
        let served_in_group =
            |c: &Coordinator| c.served().iter().filter(|s| s.group == group).count();
        let before = served_in_group(&self.coordinator);
        for _ in 0..requests {
            self.coordinator.submit_group(group, &members);
            self.coordinator.pump(timeout);
        }
        served_in_group(&self.coordinator) - before
    }

    /// All served group requests so far (every group).
    pub fn served(&self) -> &[ServedRequest] {
        self.coordinator.served()
    }

    /// Served makespans of **all groups** converted to simulated seconds
    /// (wall makespan ÷ time scale); use
    /// [`Self::simulated_makespans_for`] on multi-group deployments. With
    /// `time_scale ≤ 0` (a non-sleeping engine) there is no simulated-time
    /// conversion: wall-clock makespans are returned unscaled — they
    /// measure runtime overhead only.
    pub fn simulated_makespans(&self) -> Vec<f64> {
        let scale = if self.time_scale > 0.0 { self.time_scale } else { 1.0 };
        self.coordinator.served().iter().map(|s| s.makespan / scale).collect()
    }

    /// [`Self::simulated_makespans`] restricted to one model group.
    pub fn simulated_makespans_for(&self, group: usize) -> Vec<f64> {
        let scale = if self.time_scale > 0.0 { self.time_scale } else { 1.0 };
        self.coordinator
            .served()
            .iter()
            .filter(|s| s.group == group)
            .map(|s| s.makespan / scale)
            .collect()
    }

    /// Shut the runtime's workers down and join their threads.
    pub fn shutdown(self) {
        self.coordinator.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_validation_rejects_bad_inputs() {
        // Out-of-range zoo index.
        let err = SessionBuilder::new(ScenarioSpec::single_group("bad", vec![0, 99]))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("zoo index"), "{err}");
        // Empty group.
        assert!(SessionBuilder::new(ScenarioSpec::ZooGroups {
            name: "empty".into(),
            groups: vec![vec![]],
        })
        .build()
        .is_err());
        // Generated index out of range.
        assert!(SessionBuilder::new(ScenarioSpec::GeneratedSingle { seed: 1, index: 10 })
            .build()
            .is_err());
    }

    #[test]
    fn custom_spec_requires_group_partition() {
        let nets = vec![crate::models::build_model(0, 0), crate::models::build_model(1, 1)];
        // Network 1 missing from all groups.
        let err = SessionBuilder::new(ScenarioSpec::Custom {
            name: "c".into(),
            networks: nets.clone(),
            groups: vec![vec![0]],
        })
        .build()
        .unwrap_err();
        assert!(err.to_string().contains("no group"), "{err}");
        // Duplicate membership.
        assert!(SessionBuilder::new(ScenarioSpec::Custom {
            name: "c".into(),
            networks: nets,
            groups: vec![vec![0, 1], vec![1]],
        })
        .build()
        .is_err());
        // Duplicate network names (would cross-contaminate name-keyed
        // profiler statistics).
        let twins = vec![crate::models::build_model(0, 0), crate::models::build_model(1, 0)];
        let err = SessionBuilder::new(ScenarioSpec::Custom {
            name: "c".into(),
            networks: twins,
            groups: vec![vec![0, 1]],
        })
        .build()
        .unwrap_err();
        assert!(err.to_string().contains("duplicate network name"), "{err}");
    }

    #[test]
    fn generated_spec_matches_generator() {
        let session = SessionBuilder::new(ScenarioSpec::GeneratedSingle { seed: 23, index: 2 })
            .build()
            .unwrap();
        let reference = single_group_scenarios(23);
        assert_eq!(session.scenario().zoo_indices, reference[2].zoo_indices);
    }

    #[test]
    fn observer_cancellation_returns_partial_front() {
        struct StopAfterBatches {
            batches: usize,
        }
        impl Observer for StopAfterBatches {
            fn on_generation(&mut self, _p: &GenerationProgress<'_>) -> ControlFlow<()> {
                ControlFlow::Continue(())
            }
            fn on_batch(&mut self, _p: &BatchProgress) -> ControlFlow<()> {
                self.batches += 1;
                if self.batches >= 2 { ControlFlow::Break(()) } else { ControlFlow::Continue(()) }
            }
        }
        let session = SessionBuilder::new(ScenarioSpec::single_group("cancel", vec![0, 1]))
            .config(GaConfig { max_generations: 30, patience: 30, ..GaConfig::quick(9) })
            .build()
            .unwrap();
        let mut obs = StopAfterBatches { batches: 0 };
        let analysis = session.run_observed(&mut obs);
        assert!(analysis.cancelled, "Break must mark the analysis cancelled");
        assert!(!analysis.pareto.is_empty(), "partial front still usable");
        assert_eq!(analysis.generations_run, 1, "stopped at the first offspring batch");
        // The partial front still deploys.
        let mut dep = analysis
            .deploy_sim(analysis.best_index(), RuntimeOptions::default(), 0.0, false, 3)
            .unwrap();
        assert_eq!(dep.serve(0, 2, Duration::from_secs(10)), 2);
        dep.shutdown();
    }

    #[test]
    fn deploy_reuses_session_profiler_memo() {
        let session = SessionBuilder::new(ScenarioSpec::single_group("memo", vec![0, 2]))
            .config(GaConfig::quick(21))
            .build()
            .unwrap();
        let analysis = session.run();
        // Every Pareto genome was decoded through the session profiler
        // during the search, so materializing runtime solutions is a pure
        // memo read: hits grow, measurements do not.
        let (hits_before, misses_before) = analysis.profiler().stats();
        let sols = analysis.runtime_solutions(analysis.best_index()).unwrap();
        assert_eq!(sols.len(), 2);
        let (hits_after, misses_after) = analysis.profiler().stats();
        assert_eq!(
            misses_after, misses_before,
            "deployment re-measured configs instead of reusing the session memo"
        );
        assert!(hits_after > hits_before, "deployment bypassed the profiler");
        // And the chosen configs match the device model's exhaustive answer.
        for (net, sol) in session.scenario().networks.iter().zip(&sols) {
            for (sg, cfg) in sol.partition.subgraphs.iter().zip(&sol.configs) {
                let expect = session.perf().best_config_for(net, &sg.layers, sg.processor).0;
                assert_eq!(*cfg, expect);
            }
        }
    }

    #[test]
    fn session_runs_and_deploys_custom_networks() {
        let nets = vec![crate::models::build_model(0, 0), crate::models::build_model(1, 2)];
        let session = SessionBuilder::new(ScenarioSpec::Custom {
            name: "custom".into(),
            networks: nets,
            groups: vec![vec![0, 1]],
        })
        .config(GaConfig { population: 12, max_generations: 4, ..GaConfig::quick(3) })
        .build()
        .unwrap();
        let analysis = session.run();
        assert!(!analysis.pareto.is_empty());
        let mut deployment = analysis
            .deploy_sim(analysis.best_index(), RuntimeOptions::default(), 0.0, false, 5)
            .unwrap();
        let served = deployment.serve(0, 3, Duration::from_secs(10));
        assert_eq!(served, 3);
        deployment.shutdown();
    }
}
