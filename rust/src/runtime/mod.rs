//! PJRT runtime bridge: loads AOT-compiled HLO-text artifacts (produced by
//! `python/compile/aot.py`) and executes them from the rust hot path.
//!
//! Interchange is HLO **text**, not serialized `HloModuleProto`: jax ≥ 0.5
//! emits protos with 64-bit instruction ids that xla_extension 0.5.1 rejects
//! (`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
//! cleanly (see /opt/xla-example/README.md).
//!
//! One [`PjrtRuntime`] owns the CPU PJRT client and a cache of compiled
//! executables keyed by artifact path; Python never runs at serving time.
//!
//! The real bridge binds the external `xla` crate, which is not available in
//! the offline build. It is therefore gated behind the off-by-default
//! `pjrt` cargo feature; without it a stub with the same API ships, whose
//! constructor returns an error. Artifact-path plumbing is feature-free, and
//! the integration tests in `rust/tests/pjrt_integration.rs` skip themselves
//! when `artifacts/` has not been built.

use std::path::PathBuf;

#[cfg(feature = "pjrt")]
mod imp {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    use crate::util::error::{Context, Result};
    use std::sync::Mutex;

    /// A loaded, compiled executable plus its I/O metadata.
    pub struct LoadedModule {
        exe: xla::PjRtLoadedExecutable,
        pub path: PathBuf,
    }

    impl LoadedModule {
        /// Execute with f32 input buffers (shape handled by the artifact). The
        /// lowering uses `return_tuple=True`, so outputs come back as a tuple
        /// of however many results the jax function returned.
        pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|(data, shape)| {
                    let lit = xla::Literal::vec1(data);
                    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                    lit.reshape(&dims).map_err(|e| crate::anyhow!("reshape: {e:?}"))
                })
                .collect::<Result<_>>()?;
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| crate::anyhow!("execute: {e:?}"))?;
            let out = result[0][0]
                .to_literal_sync()
                .map_err(|e| crate::anyhow!("to_literal: {e:?}"))?;
            // Outputs are a tuple (return_tuple=True at lowering).
            let elems = out.to_tuple().map_err(|e| crate::anyhow!("decompose: {e:?}"))?;
            elems
                .into_iter()
                .map(|lit| lit.to_vec::<f32>().map_err(|e| crate::anyhow!("to_vec: {e:?}")))
                .collect()
        }
    }

    /// The PJRT client + executable cache.
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
        cache: Mutex<HashMap<PathBuf, std::sync::Arc<LoadedModule>>>,
    }

    impl PjrtRuntime {
        /// Create a CPU PJRT client (the only plugin available in this image;
        /// real NPU/GPU PJRT plugins would slot in here on hardware).
        pub fn cpu() -> Result<PjrtRuntime> {
            let client =
                xla::PjRtClient::cpu().map_err(|e| crate::anyhow!("pjrt cpu client: {e:?}"))?;
            Ok(PjrtRuntime { client, cache: Mutex::new(HashMap::new()) })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO-text artifact (cached by path).
        pub fn load(&self, path: &Path) -> Result<std::sync::Arc<LoadedModule>> {
            if let Some(m) = self.cache.lock().unwrap().get(path) {
                return Ok(m.clone());
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| crate::anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| crate::anyhow!("parse {}: {e:?}", path.display()))
            .context("loading HLO text artifact")?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| crate::anyhow!("compile {}: {e:?}", path.display()))?;
            let module = std::sync::Arc::new(LoadedModule { exe, path: path.to_path_buf() });
            self.cache.lock().unwrap().insert(path.to_path_buf(), module.clone());
            Ok(module)
        }

        /// Number of compiled modules held.
        pub fn cached_modules(&self) -> usize {
            self.cache.lock().unwrap().len()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use std::path::{Path, PathBuf};
    use std::sync::Arc;

    use crate::util::error::Result;

    /// Stub module handle (`pjrt` feature disabled): never constructed,
    /// because [`PjrtRuntime::cpu`] and [`PjrtRuntime::load`] both error.
    pub struct LoadedModule {
        pub path: PathBuf,
    }

    impl LoadedModule {
        pub fn run_f32(&self, _inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
            Err(crate::anyhow!(
                "pjrt feature disabled: cannot execute {}",
                self.path.display()
            ))
        }
    }

    /// Stub runtime (`pjrt` feature disabled).
    pub struct PjrtRuntime {
        _private: (),
    }

    impl PjrtRuntime {
        /// Always errors: build with `--features pjrt` (and a vendored `xla`
        /// bindings crate) for real execution.
        pub fn cpu() -> Result<PjrtRuntime> {
            Err(crate::anyhow!(
                "pjrt feature disabled: rebuild with --features pjrt and a vendored `xla` crate"
            ))
        }

        pub fn platform(&self) -> String {
            "pjrt-disabled".to_string()
        }

        pub fn load(&self, path: &Path) -> Result<Arc<LoadedModule>> {
            Err(crate::anyhow!("pjrt feature disabled: cannot load {}", path.display()))
        }

        pub fn cached_modules(&self) -> usize {
            0
        }
    }
}

pub use imp::{LoadedModule, PjrtRuntime};

/// Locate the artifacts directory: `$PUZZLE_ARTIFACTS`, else `artifacts/`
/// relative to the crate root / current dir.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("PUZZLE_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if manifest.exists() {
        return manifest;
    }
    PathBuf::from("artifacts")
}

/// Artifact path for a model's whole-graph lowering.
pub fn model_artifact(model: &str) -> PathBuf {
    artifacts_dir().join(format!("{model}.hlo.txt"))
}

/// Artifact path for one layer of a model.
pub fn layer_artifact(model: &str, layer: usize) -> PathBuf {
    artifacts_dir().join(format!("{model}.layer{layer:02}.hlo.txt"))
}

#[cfg(test)]
mod tests {
    use super::*;

    // PJRT integration tests live in rust/tests/pjrt_integration.rs (they
    // need artifacts built); here we only check path plumbing.

    #[test]
    fn artifact_paths() {
        std::env::set_var("PUZZLE_ARTIFACTS", "/tmp/zzz");
        assert_eq!(model_artifact("face_det"), PathBuf::from("/tmp/zzz/face_det.hlo.txt"));
        assert_eq!(
            layer_artifact("face_det", 3),
            PathBuf::from("/tmp/zzz/face_det.layer03.hlo.txt")
        );
        std::env::remove_var("PUZZLE_ARTIFACTS");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_errors_cleanly() {
        let err = PjrtRuntime::cpu().err().expect("stub must error");
        assert!(err.to_string().contains("pjrt feature disabled"), "{err}");
    }
}
