//! Memory optimizations of the Puzzle Runtime (paper §5.3, Fig 10, Table 5):
//! the **tensor pool** (chunked buffer reuse) and the **zero-copy shared
//! buffer** (ION/DMA-BUF analog: a reference-counted arena whose slices move
//! between workers without serialization).
//!
//! Both keep the accounting the paper's Table 5 reports — malloc time and
//! count, memcpy time, free time — so the ablation experiment can print the
//! same breakdown.

mod pool;
mod shared;

pub use pool::{PooledTensor, TensorPool, CHUNK_BYTES};
pub use shared::{SharedArena, SharedSlice};

use std::sync::atomic::{AtomicU64, Ordering};

/// Nanosecond-granularity counters for the Table 5 breakdown.
#[derive(Debug, Default)]
pub struct MemStats {
    pub malloc_ns: AtomicU64,
    pub malloc_count: AtomicU64,
    pub memcpy_ns: AtomicU64,
    pub memcpy_bytes: AtomicU64,
    pub free_ns: AtomicU64,
    pub free_count: AtomicU64,
}

impl MemStats {
    pub fn record_malloc(&self, ns: u64) {
        self.malloc_ns.fetch_add(ns, Ordering::Relaxed);
        self.malloc_count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_memcpy(&self, ns: u64, bytes: u64) {
        self.memcpy_ns.fetch_add(ns, Ordering::Relaxed);
        self.memcpy_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn record_free(&self, ns: u64) {
        self.free_ns.fetch_add(ns, Ordering::Relaxed);
        self.free_count.fetch_add(1, Ordering::Relaxed);
    }

    /// (malloc ms, malloc count, memcpy ms, free ms) — Table 5's columns.
    pub fn snapshot(&self) -> (f64, u64, f64, f64) {
        (
            self.malloc_ns.load(Ordering::Relaxed) as f64 / 1e6,
            self.malloc_count.load(Ordering::Relaxed),
            self.memcpy_ns.load(Ordering::Relaxed) as f64 / 1e6,
            self.free_ns.load(Ordering::Relaxed) as f64 / 1e6,
        )
    }

    pub fn reset(&self) {
        self.malloc_ns.store(0, Ordering::Relaxed);
        self.malloc_count.store(0, Ordering::Relaxed);
        self.memcpy_ns.store(0, Ordering::Relaxed);
        self.memcpy_bytes.store(0, Ordering::Relaxed);
        self.free_ns.store(0, Ordering::Relaxed);
        self.free_count.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_accumulate_and_reset() {
        let s = MemStats::default();
        s.record_malloc(1_000_000);
        s.record_malloc(2_000_000);
        s.record_memcpy(500_000, 1024);
        s.record_free(100_000);
        let (m_ms, m_n, c_ms, f_ms) = s.snapshot();
        assert!((m_ms - 3.0).abs() < 1e-9);
        assert_eq!(m_n, 2);
        assert!((c_ms - 0.5).abs() < 1e-9);
        assert!((f_ms - 0.1).abs() < 1e-9);
        s.reset();
        assert_eq!(s.snapshot().1, 0);
    }
}
