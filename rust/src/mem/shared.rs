//! Zero-copy shared buffer (paper §5.3): the ION/DMA-BUF analog.
//!
//! On the paper's device, a shared buffer is allocated once via the Android
//! ION/DMA-BUF allocator and mapped into every processor's address space, so
//! a producing subgraph's output tensor becomes the consuming subgraph's
//! input without marshalling. Our substrate models the same mechanism with a
//! process-wide arena of reference-counted slices: handing a [`SharedSlice`]
//! to another worker transfers *ownership of a view*, never bytes.
//!
//! The non-shared path (ablation baseline) must instead serialize through
//! [`SharedArena::copy_out`] / [`copy_in`], which pays real memcpy time that
//! the stats record — reproducing Table 5's memcpy column.

use std::sync::Arc;
use std::time::Instant;

use super::MemStats;

/// A reference-counted, zero-copy view of tensor bytes.
#[derive(Clone)]
pub struct SharedSlice {
    data: Arc<Vec<u8>>,
}

impl SharedSlice {
    /// Wrap owned bytes without arena accounting (for tensors created
    /// outside the cross-processor path, e.g. network inputs or post-
    /// conversion buffers).
    pub fn from_vec(data: Vec<u8>) -> SharedSlice {
        SharedSlice { data: Arc::new(data) }
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.data
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// How many workers currently hold this buffer.
    pub fn ref_count(&self) -> usize {
        Arc::strong_count(&self.data)
    }
}

/// The shared-buffer arena.
pub struct SharedArena {
    pub stats: MemStats,
    /// Zero-copy enabled? When false, `publish` degrades to a copying path.
    pub zero_copy: bool,
}

impl SharedArena {
    pub fn new(zero_copy: bool) -> SharedArena {
        SharedArena { stats: MemStats::default(), zero_copy }
    }

    /// Publish a produced tensor into the arena. With zero-copy the bytes
    /// are moved (no copy); without it they are copied through a staging
    /// buffer (the RPC marshalling path), which the stats record.
    pub fn publish(&self, bytes: Vec<u8>) -> SharedSlice {
        if self.zero_copy {
            let t0 = Instant::now();
            let s = SharedSlice { data: Arc::new(bytes) };
            // Allocation bookkeeping only (the Arc header); Table 5 shows a
            // slight malloc-time increase from RPC buffer registration.
            self.stats.record_malloc(t0.elapsed().as_nanos() as u64);
            s
        } else {
            let t0 = Instant::now();
            let staged = bytes.clone(); // marshalling copy
            self.stats
                .record_memcpy(t0.elapsed().as_nanos() as u64, staged.len() as u64);
            let t1 = Instant::now();
            let s = SharedSlice { data: Arc::new(staged) };
            self.stats.record_malloc(t1.elapsed().as_nanos() as u64);
            drop(bytes);
            s
        }
    }

    /// Consume a shared slice on another worker. Zero-copy: borrow the view.
    /// Copying mode: unmarshal into a fresh buffer (recorded memcpy).
    pub fn consume(&self, slice: &SharedSlice) -> Vec<u8> {
        if self.zero_copy {
            // A real engine would read through the mapping; we hand back a
            // clone of the Arc'd bytes only when an owned Vec is demanded.
            // The hot path uses `consume_view` below instead.
            slice.as_slice().to_vec()
        } else {
            let t0 = Instant::now();
            let v = slice.as_slice().to_vec();
            self.stats.record_memcpy(t0.elapsed().as_nanos() as u64, v.len() as u64);
            v
        }
    }

    /// Zero-copy read path: a borrowed view, no bytes moved.
    pub fn consume_view<'a>(&self, slice: &'a SharedSlice) -> &'a [u8] {
        slice.as_slice()
    }

    /// Copy tensor bytes out of a worker buffer (non-zero-copy send path).
    pub fn copy_out(&self, src: &[u8]) -> Vec<u8> {
        let t0 = Instant::now();
        let v = src.to_vec();
        self.stats.record_memcpy(t0.elapsed().as_nanos() as u64, v.len() as u64);
        v
    }

    /// Copy tensor bytes into a worker buffer (non-zero-copy receive path).
    pub fn copy_in(&self, dst: &mut [u8], src: &[u8]) {
        let t0 = Instant::now();
        dst[..src.len()].copy_from_slice(src);
        self.stats
            .record_memcpy(t0.elapsed().as_nanos() as u64, src.len() as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    #[test]
    fn zero_copy_moves_no_bytes() {
        let arena = SharedArena::new(true);
        let slice = arena.publish(vec![1, 2, 3, 4]);
        let view = arena.consume_view(&slice);
        assert_eq!(view, &[1, 2, 3, 4]);
        assert_eq!(arena.stats.memcpy_bytes.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn copying_mode_records_marshalling() {
        let arena = SharedArena::new(false);
        let slice = arena.publish(vec![0u8; 1024]);
        let _ = arena.consume(&slice);
        // publish copies once, consume copies once.
        assert_eq!(arena.stats.memcpy_bytes.load(Ordering::Relaxed), 2048);
    }

    #[test]
    fn slices_are_shareable_across_threads() {
        let arena = SharedArena::new(true);
        let slice = arena.publish((0..=255u8).collect());
        let clones: Vec<SharedSlice> = (0..4).map(|_| slice.clone()).collect();
        assert_eq!(slice.ref_count(), 5);
        let handles: Vec<_> = clones
            .into_iter()
            .map(|s| std::thread::spawn(move || s.as_slice().iter().map(|&b| b as u64).sum::<u64>()))
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), (0..=255u64).sum::<u64>());
        }
    }

    #[test]
    fn copy_in_out_account() {
        let arena = SharedArena::new(false);
        let staged = arena.copy_out(&[9u8; 100]);
        let mut dst = vec![0u8; 100];
        arena.copy_in(&mut dst, &staged);
        assert_eq!(dst, vec![9u8; 100]);
        assert_eq!(arena.stats.memcpy_bytes.load(Ordering::Relaxed), 200);
    }
}
