//! The tensor pool (paper §5.3): buffers are allocated in 2048-byte chunks
//! and recycled, so one memory buffer serves many tensors of different
//! sizes across requests. Table 5 attributes a 76.8% malloc-time and 99.4%
//! free-time reduction to this reuse, plus a 65.9% memcpy reduction from
//! already-faulted pages.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use std::sync::Mutex;

use super::MemStats;

/// Pool chunk granularity (paper: 2048 B).
pub const CHUNK_BYTES: usize = 2048;

/// Round a size up to whole chunks.
fn chunks_for(bytes: usize) -> usize {
    bytes.div_ceil(CHUNK_BYTES).max(1)
}

/// A tensor buffer lent out by the pool. Returned on drop.
pub struct PooledTensor {
    buf: Option<Vec<u8>>,
    len: usize,
    pool: Arc<PoolInner>,
}

impl PooledTensor {
    pub fn as_slice(&self) -> &[u8] {
        &self.buf.as_ref().unwrap()[..self.len]
    }

    pub fn as_mut_slice(&mut self) -> &mut [u8] {
        let len = self.len;
        &mut self.buf.as_mut().unwrap()[..len]
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Capacity in bytes (whole chunks).
    pub fn capacity(&self) -> usize {
        self.buf.as_ref().unwrap().len()
    }

    /// Copy data in, with memcpy accounting.
    pub fn fill_from(&mut self, src: &[u8]) {
        assert!(src.len() <= self.len, "fill over tensor length");
        let t0 = Instant::now();
        self.as_mut_slice()[..src.len()].copy_from_slice(src);
        self.pool.stats.record_memcpy(t0.elapsed().as_nanos() as u64, src.len() as u64);
    }
}

impl Drop for PooledTensor {
    fn drop(&mut self) {
        if let Some(buf) = self.buf.take() {
            let t0 = Instant::now();
            if self.pool.enabled {
                self.pool.free_lists.lock().unwrap().entry(buf.len()).or_default().push(buf);
            } else {
                drop(buf); // real free
            }
            self.pool.stats.record_free(t0.elapsed().as_nanos() as u64);
        }
    }
}

struct PoolInner {
    enabled: bool,
    /// Free buffers bucketed by capacity (chunk-rounded).
    free_lists: Mutex<HashMap<usize, Vec<Vec<u8>>>>,
    stats: MemStats,
}

/// The tensor pool. With `enabled = false` it degrades to plain
/// malloc/free (the ablation baseline) while keeping identical accounting.
#[derive(Clone)]
pub struct TensorPool {
    inner: Arc<PoolInner>,
}

impl TensorPool {
    pub fn new(enabled: bool) -> TensorPool {
        TensorPool {
            inner: Arc::new(PoolInner {
                enabled,
                free_lists: Mutex::new(HashMap::new()),
                stats: MemStats::default(),
            }),
        }
    }

    /// Pre-allocate `count` buffers of `bytes` each (paper: "initially
    /// pre-allocate buffers"). No-op when pooling is disabled.
    pub fn preallocate(&self, bytes: usize, count: usize) {
        if !self.inner.enabled {
            return;
        }
        let cap = chunks_for(bytes) * CHUNK_BYTES;
        let mut lists = self.inner.free_lists.lock().unwrap();
        let list = lists.entry(cap).or_default();
        for _ in 0..count {
            let mut b = vec![0u8; cap];
            // Touch pages so later use doesn't fault.
            for i in (0..cap).step_by(4096) {
                b[i] = 0;
            }
            list.push(b);
        }
    }

    /// Acquire a tensor buffer of at least `bytes`.
    pub fn acquire(&self, bytes: usize) -> PooledTensor {
        let cap = chunks_for(bytes) * CHUNK_BYTES;
        let t0 = Instant::now();
        let buf = if self.inner.enabled {
            self.inner
                .free_lists
                .lock().unwrap()
                .get_mut(&cap)
                .and_then(|l| l.pop())
                .unwrap_or_else(|| vec![0u8; cap])
        } else {
            vec![0u8; cap]
        };
        self.inner.stats.record_malloc(t0.elapsed().as_nanos() as u64);
        PooledTensor { buf: Some(buf), len: bytes, pool: self.inner.clone() }
    }

    /// Distinct *fresh* allocations made so far (Table 5's "# of Alloc"
    /// equivalent is malloc_count; fresh-vs-recycled is observable through
    /// the free-list length before/after).
    pub fn stats(&self) -> &MemStats {
        &self.inner.stats
    }

    /// Total buffers currently idle in the pool.
    pub fn idle_buffers(&self) -> usize {
        self.inner.free_lists.lock().unwrap().values().map(|v| v.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_rounding() {
        assert_eq!(chunks_for(1), 1);
        assert_eq!(chunks_for(2048), 1);
        assert_eq!(chunks_for(2049), 2);
        assert_eq!(chunks_for(10_000), 5);
    }

    #[test]
    fn buffers_are_recycled() {
        let pool = TensorPool::new(true);
        {
            let t = pool.acquire(1000);
            assert_eq!(t.capacity(), CHUNK_BYTES);
        } // drop returns it
        assert_eq!(pool.idle_buffers(), 1);
        let _t2 = pool.acquire(2000); // same 1-chunk bucket
        assert_eq!(pool.idle_buffers(), 0, "buffer not reused");
    }

    #[test]
    fn different_sizes_share_chunked_buckets() {
        let pool = TensorPool::new(true);
        {
            let _a = pool.acquire(100);
        }
        {
            // 100 B and 1.9 KiB round to the same single chunk.
            let _b = pool.acquire(1900);
        }
        let (_, malloc_count, _, _) = pool.stats().snapshot();
        assert_eq!(malloc_count, 2);
        assert_eq!(pool.idle_buffers(), 1, "single buffer should serve both");
    }

    #[test]
    fn disabled_pool_never_retains() {
        let pool = TensorPool::new(false);
        {
            let _t = pool.acquire(4096);
        }
        assert_eq!(pool.idle_buffers(), 0);
    }

    #[test]
    fn preallocation_avoids_fresh_allocs() {
        let pool = TensorPool::new(true);
        pool.preallocate(8192, 4);
        assert_eq!(pool.idle_buffers(), 4);
        let a = pool.acquire(8192);
        let b = pool.acquire(8000);
        assert_eq!(pool.idle_buffers(), 2);
        drop(a);
        drop(b);
        assert_eq!(pool.idle_buffers(), 4);
    }

    #[test]
    fn fill_accounts_memcpy() {
        let pool = TensorPool::new(true);
        let mut t = pool.acquire(64);
        t.fill_from(&[7u8; 64]);
        assert_eq!(t.as_slice(), &[7u8; 64]);
        assert_eq!(pool.stats().memcpy_bytes.load(std::sync::atomic::Ordering::Relaxed), 64);
    }

    #[test]
    fn pool_is_thread_safe() {
        let pool = TensorPool::new(true);
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let p = pool.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        let mut t = p.acquire(3000);
                        t.as_mut_slice()[0] = 1;
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let (_, count, _, _) = pool.stats().snapshot();
        assert_eq!(count, 800);
        assert!(pool.idle_buffers() <= 8);
    }
}
