//! Workers (paper §5.1): one per processor, executing that processor's
//! subgraph tasks serially, with a *separate* (de)quantization thread so
//! conversion overlaps execution ("To run task execution and
//! (de-)quantization in parallel, we use two separate threads, each polling
//! items from its dedicated queue").

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use std::sync::mpsc::{Receiver, Sender};

use crate::coordinator::{CompletionMsg, TaskMsg};
use crate::engine::{Engine, EngineTask};
use crate::mem::TensorPool;
use crate::quant;
use crate::Processor;

/// A running worker: the quant thread feeds the exec thread.
pub struct Worker {
    pub processor: Processor,
    quant_tx: Sender<TaskMsg>,
    /// Tasks submitted but not yet finished executing (monitoring gauge).
    depth: Arc<AtomicUsize>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Worker {
    /// Spawn the two worker threads. `completion_tx` reports finished tasks
    /// back to the coordinator.
    pub fn spawn(
        processor: Processor,
        engine: Arc<dyn Engine>,
        pool: TensorPool,
        completion_tx: Sender<CompletionMsg>,
    ) -> Worker {
        let (quant_tx, quant_rx) = std::sync::mpsc::channel::<TaskMsg>();
        let (exec_tx, exec_rx) = std::sync::mpsc::channel::<TaskMsg>();
        let depth = Arc::new(AtomicUsize::new(0));
        let exec_depth = depth.clone();

        // Dequantization thread: convert inputs whose dtype mismatches the
        // task's config, then forward to the execution queue.
        let quant_handle = {
            std::thread::Builder::new()
                .name(format!("{}-quant", processor.name().to_lowercase()))
                .spawn(move || {
                    while let Ok(mut task) = quant_rx.recv() {
                        for input in &mut task.inputs {
                            if quant::needs_conversion(input.dtype, task.config.dtype) {
                                // Convert through f32 (engines consume f32).
                                let f32s = quant::dequantize(
                                    input.slice.as_slice(), input.dtype, input.scale,
                                );
                                let (bytes, scale) = quant::quantize(&f32s, task.config.dtype);
                                input.slice = crate::mem::SharedSlice::from_vec(bytes);
                                input.scale = scale;
                                input.dtype = task.config.dtype;
                            }
                        }
                        if exec_tx.send(task).is_err() {
                            break;
                        }
                    }
                })
                .expect("spawn quant thread")
        };

        // Execution thread: run tasks serially on the engine.
        let exec_handle = {
            let completion_tx = completion_tx.clone();
            std::thread::Builder::new()
                .name(format!("{}-exec", processor.name().to_lowercase()))
                .spawn(move || {
                    while let Ok(task) = exec_rx.recv() {
                        // Stage inputs through the tensor pool (the pool's
                        // accounting is what Table 5 reports).
                        let staged: Vec<Vec<f32>> = task
                            .inputs
                            .iter()
                            .map(|i| {
                                let bytes = i.slice.as_slice();
                                let mut t = pool.acquire(bytes.len());
                                t.fill_from(bytes);
                                quant::dequantize(t.as_slice(), i.dtype, i.scale)
                            })
                            .collect();
                        let engine_task = EngineTask {
                            network: &task.network,
                            subgraph: &task.subgraph,
                            config: task.config,
                            inputs: staged,
                            start: task.start,
                        };
                        let result = engine.execute(&engine_task);
                        let msg = match result {
                            // A task-level fault (out.error set) keeps the
                            // engine-priced elapsed: the failed attempt
                            // consumed that time on the processor.
                            Ok(out) => CompletionMsg {
                                request: task.request,
                                network: task.network_idx,
                                subgraph: task.subgraph.id,
                                elapsed: out.elapsed,
                                processor,
                                outputs: out.tensors,
                                error: out.error,
                            },
                            Err(e) => CompletionMsg {
                                request: task.request,
                                network: task.network_idx,
                                subgraph: task.subgraph.id,
                                elapsed: 0.0,
                                processor,
                                outputs: Vec::new(),
                                error: Some(e.to_string()),
                            },
                        };
                        exec_depth.fetch_sub(1, Ordering::Relaxed);
                        if completion_tx.send(msg).is_err() {
                            break;
                        }
                    }
                })
                .expect("spawn exec thread")
        };

        Worker {
            processor,
            quant_tx,
            depth,
            handles: vec![quant_handle, exec_handle],
        }
    }

    /// Queue a task on this worker (enters via the quant thread).
    pub fn submit(&self, task: TaskMsg) {
        self.depth.fetch_add(1, Ordering::Relaxed);
        let _ = self.quant_tx.send(task);
    }

    /// Tasks submitted to this worker and not yet executed — a **racy
    /// monitoring gauge** (the worker threads decrement it asynchronously),
    /// for dashboards and debugging only. The coordinator's own dispatch
    /// state, not this gauge, feeds the deterministic telemetry heartbeats.
    pub fn pending(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// Close the queues and join both threads.
    pub fn shutdown(self) {
        drop(self.quant_tx);
        for h in self.handles {
            let _ = h.join();
        }
    }
}

/// Convenience: spawn one worker per processor with a shared engine.
pub fn spawn_all(
    engine: &Arc<dyn Engine>,
    pool: &TensorPool,
    completion_tx: &Sender<CompletionMsg>,
) -> Vec<Worker> {
    Processor::ALL
        .into_iter()
        .map(|p| Worker::spawn(p, engine.clone(), pool.clone(), completion_tx.clone()))
        .collect()
}

/// Receiver side for tests: drain completions with a deadline.
pub fn drain_completions(
    rx: &Receiver<CompletionMsg>,
    n: usize,
    timeout: std::time::Duration,
) -> Vec<CompletionMsg> {
    let deadline = std::time::Instant::now() + timeout;
    let mut out = Vec::with_capacity(n);
    while out.len() < n && std::time::Instant::now() < deadline {
        if let Ok(msg) = rx.recv_timeout(std::time::Duration::from_millis(50)) {
            out.push(msg);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::TensorInput;
    use crate::engine::SimEngine;
    use crate::graph::partition;
    use crate::models::build_model;
    use crate::perf::PerfModel;
    use crate::{Backend, DataType, ExecConfig};
    use std::sync::Arc;

    fn mk_task(net: Arc<crate::graph::Network>, idx: usize, request: u64) -> TaskMsg {
        let part = partition(
            &net,
            &vec![false; net.num_edges()],
            &vec![Processor::Npu; net.num_layers()],
        );
        TaskMsg {
            request,
            network: net.clone(),
            network_idx: idx,
            subgraph: Arc::new(part.subgraphs[0].clone()),
            config: ExecConfig::new(Processor::Npu, Backend::Qnn, DataType::Fp16),
            inputs: vec![],
            start: 0.0,
        }
    }

    #[test]
    fn worker_executes_and_reports() {
        let pm = Arc::new(PerfModel::paper_calibrated());
        let engine: Arc<dyn Engine> = Arc::new(SimEngine::new(pm, 0.0, false, 1));
        let pool = TensorPool::new(true);
        let (tx, rx) = std::sync::mpsc::channel();
        let worker = Worker::spawn(Processor::Npu, engine, pool, tx);
        let net = Arc::new(build_model(0, 0));
        worker.submit(mk_task(net, 0, 42));
        let done = drain_completions(&rx, 1, std::time::Duration::from_secs(5));
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].request, 42);
        assert!(done[0].error.is_none());
        assert!(done[0].elapsed > 0.0);
        worker.shutdown();
    }

    #[test]
    fn worker_serializes_tasks_in_order() {
        let pm = Arc::new(PerfModel::paper_calibrated());
        let engine: Arc<dyn Engine> = Arc::new(SimEngine::new(pm, 0.0, false, 2));
        let pool = TensorPool::new(true);
        let (tx, rx) = std::sync::mpsc::channel();
        let worker = Worker::spawn(Processor::Npu, engine, pool, tx);
        let net = Arc::new(build_model(0, 0));
        for i in 0..5 {
            worker.submit(mk_task(net.clone(), 0, i));
        }
        let done = drain_completions(&rx, 5, std::time::Duration::from_secs(5));
        let ids: Vec<u64> = done.iter().map(|d| d.request).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4], "FIFO violated");
        worker.shutdown();
    }

    #[test]
    fn pending_gauge_tracks_submissions_and_drains_to_zero() {
        let pm = Arc::new(PerfModel::paper_calibrated());
        let engine: Arc<dyn Engine> = Arc::new(SimEngine::new(pm, 0.0, false, 4));
        let pool = TensorPool::new(true);
        let (tx, rx) = std::sync::mpsc::channel();
        let worker = Worker::spawn(Processor::Npu, engine, pool, tx);
        assert_eq!(worker.pending(), 0);
        let net = Arc::new(build_model(0, 0));
        for i in 0..4 {
            worker.submit(mk_task(net.clone(), 0, i));
        }
        // The gauge is racy (threads drain it concurrently) but bounded by
        // what was submitted, and it reaches zero once everything reported.
        assert!(worker.pending() <= 4);
        let done = drain_completions(&rx, 4, std::time::Duration::from_secs(5));
        assert_eq!(done.len(), 4);
        assert_eq!(worker.pending(), 0);
        worker.shutdown();
    }

    #[test]
    fn quant_thread_converts_dtypes() {
        let pm = Arc::new(PerfModel::paper_calibrated());
        let engine: Arc<dyn Engine> = Arc::new(SimEngine::new(pm, 0.0, false, 3));
        let pool = TensorPool::new(true);
        let (tx, rx) = std::sync::mpsc::channel();
        let worker = Worker::spawn(Processor::Npu, engine, pool, tx);
        let net = Arc::new(build_model(0, 0));
        let mut task = mk_task(net, 0, 1);
        // fp32 input into an fp16 task: the quant thread must convert.
        let (bytes, scale) = quant::quantize(&[1.0f32, 2.0, 3.0], DataType::Fp32);
        task.inputs.push(TensorInput::from_vec(bytes, DataType::Fp32, scale));
        worker.submit(task);
        let done = drain_completions(&rx, 1, std::time::Duration::from_secs(5));
        assert_eq!(done.len(), 1);
        assert!(done[0].error.is_none());
        worker.shutdown();
    }
}
