//! Puzzle CLI — the leader entrypoint.
//!
//! Subcommands:
//! * `analyze`     — run the Static Analyzer on a scenario, print the Pareto set
//! * `serve`       — serve a scenario through the runtime (simulated engine)
//! * `loadtest`    — open-loop load test through the runtime (periodic /
//!   poisson / bursty arrivals, deadline accounting, runtime-measured
//!   saturation search)
//! * `profile`     — profile the model zoo on the simulated device
//! * `comm-bench`  — run the RPC/STREAM microbenchmarks and print the fit
//! * `scenario-gen`— print the random scenario configurations (Fig 11)
//! * `experiment`  — regenerate a paper table/figure (`all` for everything)
//! * `figures`     — the serving figures (12–16) as one work-stealing
//!   queue of (scenario, method) jobs (`--threads N`, 0 = cores)
//! * `fuzz`        — run a seeded corpus of fuzzed scenarios (group/SLA/
//!   arrival mixes far beyond the nine-model zoo) through the
//!   warm-deployment fleet and cross-check every measured report against
//!   its analytic queueing envelope
//!
//! Argument parsing is hand-rolled (`--key value` / `--flag`): the build
//! environment is offline and clap is not vendored.

use puzzle::util::error::Result;

use puzzle::analyzer::GaConfig;
use puzzle::api::{
    Analysis, GenerationProgress, RuntimeOptions, ScenarioSpec, SessionBuilder,
};
use puzzle::experiments::{self, ServingBudget};
use puzzle::graph::LayerId;
use puzzle::models;
use puzzle::perf::PerfModel;
use puzzle::scenario::{multi_group_scenarios, single_group_scenarios};
use puzzle::Processor;

/// Parsed `--key value` options and `--flag` switches.
struct Args {
    positional: Vec<String>,
    options: std::collections::HashMap<String, String>,
    flags: std::collections::HashSet<String>,
}

fn parse_args(argv: &[String]) -> Args {
    let mut args = Args {
        positional: Vec::new(),
        options: std::collections::HashMap::new(),
        flags: std::collections::HashSet::new(),
    };
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(key) = a.strip_prefix("--") {
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                args.options.insert(key.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                args.flags.insert(key.to_string());
                i += 1;
            }
        } else {
            args.positional.push(a.clone());
            i += 1;
        }
    }
    args
}

impl Args {
    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.options
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    fn get_str(&self, key: &str, default: &str) -> String {
        self.options.get(key).cloned().unwrap_or_else(|| default.to_string())
    }
}

const USAGE: &str = "usage: puzzle <analyze|serve|loadtest|profile|comm-bench|scenario-gen|experiment|figures> [options]
  analyze      --models 0,1,6 --population 48 --generations 40 --seed 23 [--save sol.txt] [--quiet]
  serve        --models 0,1,6 --requests 30 --time-scale 0.05 [--solution sol.txt]
  loadtest     --models 0,1,6 --alpha 1.0 --requests 40 --pattern periodic|poisson|bursty
               [--burst 4] [--max-inflight N] [--admission queue|little] [--all-patterns]
               [--wall] [--time-scale 0.05] [--quick] [--no-saturation] [--seed 23]
               [--probe-threads N] [--core-budget N]
               [--chaos slowdown:npu:2.0:0:0.5,stall:gpu:0.1:0.05,transient:0.02]
               [--monitor] [--monitor-json FILE]
  profile
  comm-bench
  scenario-gen --seed 23
  experiment   <table2|table3|table4|table5|fig5|fig10|fig12|fig13|fig14|fig15|fig16|headline|all> [--full]
  figures      [--threads N] [--core-budget N] [--alpha-chunk W] [--only fig12,fig14]
               [--scenarios N] [--requests N] [--full]
  fuzz         --seed 23 --count 16 [--quick] [--stress] [--envelope]
               [--probe-threads N] [--core-budget N] [--calibrate]";

fn parse_models(s: &str) -> Vec<usize> {
    s.split(',')
        .filter_map(|t| t.trim().parse::<usize>().ok())
        .filter(|&i| i < models::MODEL_COUNT)
        .collect()
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        println!("{USAGE}");
        return Ok(());
    };
    let args = parse_args(&argv[1..]);
    let pm = PerfModel::paper_calibrated();
    match cmd.as_str() {
        "analyze" => {
            let idx = parse_models(&args.get_str("models", "0,1,6"));
            let config = GaConfig {
                population: args.get("population", 48),
                max_generations: args.get("generations", 40),
                seed: args.get("seed", 23),
                ..Default::default()
            };
            let session = SessionBuilder::new(ScenarioSpec::single_group("cli", idx))
                .perf_model(pm.clone())
                .config(config)
                .build()?;
            let quiet = args.flags.contains("quiet");
            let analysis = session.run_observed(&mut |p: &GenerationProgress<'_>| {
                if !quiet {
                    println!(
                        "gen {:>3}: {:>5} evals, best {:?}, plan memo {:.0}%, profile cache {:.0}%",
                        p.generation,
                        p.evaluations,
                        p.best_objectives
                            .iter()
                            .map(|o| format!("{:.2}ms", o * 1e3))
                            .collect::<Vec<_>>(),
                        p.plan_cache_hit_rate() * 100.0,
                        p.profile_cache_hit_rate() * 100.0,
                    );
                }
            });
            if let Some(path) = args.options.get("save") {
                analysis.save(std::path::Path::new(path))?;
                println!("saved {} solutions to {path}", analysis.pareto.len());
            }
            println!(
                "analyzer: {} generations, {} evaluations, profile cache {} hits / {} measures",
                analysis.generations_run, analysis.evaluations,
                analysis.profile_cache_hits, analysis.profile_measurements
            );
            println!("pareto solutions: {}", analysis.pareto.len());
            for (i, sol) in analysis.pareto.iter().enumerate() {
                let subgraphs: usize = sol.plans().iter().map(|p| p.tasks.len()).sum();
                println!(
                    "  #{i}: objectives {:?} ({} subgraphs total)",
                    sol.objectives.iter().map(|o| format!("{:.2}ms", o * 1e3)).collect::<Vec<_>>(),
                    subgraphs
                );
            }
        }
        "serve" => {
            let idx = parse_models(&args.get_str("models", "0,1,6"));
            let solution_file = args.options.get("solution").cloned();
            serve_cmd(
                &pm, &idx, args.get("requests", 30), args.get("time-scale", 0.05),
                solution_file.as_deref(),
            )?;
        }
        "loadtest" => loadtest_cmd(&pm, &args)?,
        "profile" => profile_zoo(&pm),
        "comm-bench" => {
            let (samples, fit, bw) = experiments::fig5_rpc_regression();
            println!("STREAM bandwidth: {:.1} GB/s (paper device: ~40 GB/s)", bw / 1e9);
            println!("piecewise-linear RPC fit (knee at 1 MiB):");
            println!(
                "  below: {:.1}us + {:.3}ns/B   above: {:.1}us + {:.3}ns/B   r2={:.4}",
                fit.below_intercept * 1e6, fit.below_slope * 1e9,
                fit.above_intercept * 1e6, fit.above_slope * 1e9,
                fit.r_squared(&samples)
            );
            for s in &samples {
                println!("  {:>10} B  {:>10.2} us", s.bytes, s.seconds * 1e6);
            }
        }
        "scenario-gen" => {
            let seed = args.get("seed", 23u64);
            println!("single model group scenarios (Fig 11 top):");
            for s in single_group_scenarios(seed) {
                println!("  {:<10} models {:?}", s.name, s.zoo_indices);
            }
            println!("multi model group scenarios (Fig 11 bottom):");
            for s in multi_group_scenarios(seed) {
                let g1: Vec<usize> = s.groups[0].members.iter().map(|&m| s.zoo_indices[m]).collect();
                let g2: Vec<usize> = s.groups[1].members.iter().map(|&m| s.zoo_indices[m]).collect();
                println!("  {:<10} group1 {:?} group2 {:?}", s.name, g1, g2);
            }
        }
        "experiment" => {
            let id = args.positional.first().cloned().unwrap_or_else(|| "all".into());
            let budget = if args.flags.contains("full") {
                ServingBudget::full()
            } else {
                ServingBudget::quick()
            };
            run_experiment(&pm, &id, &budget)?;
        }
        "figures" => {
            let mut budget = if args.flags.contains("full") {
                ServingBudget::full()
            } else {
                ServingBudget::quick()
            };
            budget.protocol_threads = args.get("threads", 0usize);
            budget.scenarios = args.get("scenarios", budget.scenarios);
            budget.sim_requests = args.get("requests", budget.sim_requests);
            budget.alpha_chunk = args.get("alpha-chunk", budget.alpha_chunk);
            // `--core-budget N` replaces the static two-level thread rule
            // with one shared N-slot semaphore (0 = machine cores); see
            // ServingBudget::core_budget. Scheduling only — the report
            // stays bit-identical.
            budget.core_budget = args
                .options
                .get("core-budget")
                .and_then(|v| v.parse::<usize>().ok())
                .map(puzzle::util::threads::CoreBudget::new);
            let select = match args.options.get("only") {
                Some(spec) => match experiments::serving::FigureSelection::parse(spec) {
                    Ok(sel) => sel,
                    Err(e) => puzzle::bail!("--only: {e}"),
                },
                None => experiments::serving::FigureSelection::all(),
            };
            figures_cmd(&pm, &budget, select)?;
        }
        "fuzz" => fuzz_cmd(&pm, &args)?,
        other => {
            println!("unknown command: {other}\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}

fn serve_cmd(
    pm: &PerfModel,
    idx: &[usize],
    requests: usize,
    time_scale: f64,
    solution_file: Option<&str>,
) -> Result<()> {
    let session = SessionBuilder::new(ScenarioSpec::single_group("serve", idx.to_vec()))
        .perf_model(pm.clone())
        .config(GaConfig::quick(23))
        .build()?;
    // Either load a saved Static-Analyzer solution (the paper's Fig 2
    // hand-off) or run a fresh quick analysis.
    let analysis: Analysis = match solution_file {
        Some(path) => {
            let loaded = session.load_solutions(std::path::Path::new(path))?;
            println!("loaded {} solutions from {path}", loaded.pareto.len());
            loaded
        }
        None => session.run(),
    };
    let mut deployment = analysis.deploy_sim(
        analysis.best_index(),
        RuntimeOptions::default(),
        time_scale,
        true,
        7,
    )?;
    let t0 = std::time::Instant::now();
    let served = deployment.serve(0, requests, std::time::Duration::from_secs(30));
    let wall = t0.elapsed().as_secs_f64();
    let makespans = deployment.simulated_makespans();
    let (avg, sd) = puzzle::metrics::mean_sd(&makespans);
    println!(
        "served {} requests in {:.2}s wall; simulated makespan avg {:.2}ms ± {:.2}ms, p90 {:.2}ms",
        served, wall,
        avg * 1e3, sd * 1e3,
        puzzle::sim::percentile(&makespans, 0.9) * 1e3
    );
    deployment.shutdown();
    Ok(())
}

/// Seeded scenario-fuzzer corpus through the warm-deployment fleet: draw
/// `--count` scenarios (group counts, model mixes including generated
/// networks, SLA classes, periodic/Poisson/bursty/diurnal/flash-crowd
/// arrivals, optional churn) from `--seed`, serve each on a per-case
/// random solution, and — with `--envelope` — cross-check every measured
/// report against its analytic queueing envelope, failing on any breach
/// or false infeasibility certificate. `--calibrate` additionally sweeps
/// the `Admission::LittleCap` slack over the corpus.
fn fuzz_cmd(pm: &PerfModel, args: &Args) -> Result<()> {
    use puzzle::api::{calibrate_slack, run_fuzz_corpus, FuzzConfig, FuzzOptions};
    use puzzle::scenario::fuzz::corpus;
    use std::sync::Arc;

    let seed = args.get("seed", 23u64);
    let quick = args.flags.contains("quick");
    let stress = args.flags.contains("stress");
    let count = args.get("count", if quick { 8 } else { 16 });
    let config = if stress {
        FuzzConfig::stress()
    } else if quick {
        FuzzConfig::quick()
    } else {
        FuzzConfig::default()
    };
    let cases = corpus(seed, count, &config, pm);
    let opts = FuzzOptions {
        probe_threads: args.get("probe-threads", 0usize),
        core_budget: args
            .options
            .get("core-budget")
            .and_then(|v| v.parse::<usize>().ok())
            .map(puzzle::util::threads::CoreBudget::new),
        envelope: args.flags.contains("envelope"),
        seed,
        ..Default::default()
    };
    let perf = Arc::new(pm.clone());
    let t0 = std::time::Instant::now();
    let outcomes = run_fuzz_corpus(&cases, &perf, &opts);
    println!(
        "{:>4} {:>18} {:>6} {:>6} {:>8} {:>8} {:>9}  verdict",
        "case", "seed", "groups", "rho", "served", "violate", "band"
    );
    let mut breaches = 0usize;
    let mut false_certs = 0usize;
    let mut certified = 0usize;
    for o in &outcomes {
        certified += usize::from(o.certified_infeasible);
        false_certs += usize::from(o.false_certificate);
        breaches += usize::from(o.breach.is_some());
        let verdict = if o.false_certificate {
            "FALSE-CERT".to_string()
        } else if let Some(b) = &o.breach {
            format!("BREACH: {b}")
        } else if o.certified_infeasible {
            "certified ρ>1".to_string()
        } else {
            "in envelope".to_string()
        };
        println!(
            "{:>4} {:>18x} {:>6} {:>6.2} {:>8} {:>8} [{:.2},{:.2}]  {verdict}",
            o.index,
            o.seed,
            o.groups,
            o.envelope.rho_max,
            o.report.served,
            o.report.violations,
            o.envelope.band.0,
            o.envelope.band.1,
        );
    }
    println!(
        "{} cases in {:.2}s: {certified} certified infeasible, {breaches} envelope \
         breach(es), {false_certs} false certificate(s)",
        outcomes.len(),
        t0.elapsed().as_secs_f64()
    );
    if args.flags.contains("calibrate") {
        println!("LittleCap slack sweep (feasible-load drops must be zero):");
        for row in calibrate_slack(&cases, &perf, &opts, &[1.0, 1.5, 2.0, 2.5, 3.0, 4.0]) {
            println!(
                "  slack {:>4.1}: {:>2} feasible cases, {:>3} feasible-load drops, {:>3} total",
                row.slack, row.feasible_cases, row.feasible_drops, row.total_drops
            );
        }
    }
    if opts.envelope && (breaches > 0 || false_certs > 0) {
        puzzle::bail!("{breaches} envelope breach(es), {false_certs} false certificate(s)");
    }
    Ok(())
}

/// Open-loop load test through the arrival-driven runtime: analyze a model
/// group, deploy the best Pareto solution **once**, push an arrival process
/// through it (virtual clock by default — deterministic and fast; `--wall`
/// for real time), report deadline attainment, optionally replay the other
/// arrival patterns against the same warm deployment (`--all-patterns`),
/// then binary-search the runtime-measured saturation multiplier (one
/// persistent deployment reused across every α-probe). `--admission little`
/// swaps the unbounded queue for a Little's-law derived in-flight cap.
fn loadtest_cmd(pm: &PerfModel, args: &Args) -> Result<()> {
    use puzzle::api::{Admission, LoadSpec, MetricsAggregator, OverloadPolicy, TelemetryEvent};
    use std::ops::ControlFlow;

    let idx = parse_models(&args.get_str("models", "0,1,6"));
    let quick = args.flags.contains("quick");
    let seed = args.get("seed", 23u64);
    let config = if quick {
        GaConfig {
            population: 12,
            max_generations: 4,
            sim_requests: 8,
            measure_reps: 1,
            ..GaConfig::quick(seed)
        }
    } else {
        GaConfig::quick(seed)
    };
    let session = SessionBuilder::new(ScenarioSpec::single_group("loadtest", idx))
        .perf_model(pm.clone())
        .config(config)
        .build()?;
    let scenario = session.scenario().clone();
    println!(
        "analyzing {} models ({})...",
        scenario.networks.len(),
        scenario.networks.iter().map(|n| n.name.as_str()).collect::<Vec<_>>().join(", ")
    );
    let analysis = session.run();
    let best = analysis.best_index();
    println!(
        "analysis: {} generations, {} evaluations, deploying pareto solution #{best}",
        analysis.generations_run, analysis.evaluations
    );

    let alpha = args.get("alpha", 1.0f64);
    let requests: usize = args.get("requests", if quick { 10 } else { 40 });
    let periods = scenario.periods(alpha, pm);
    // Resolve the pattern name up front: an unrecognized value falls back
    // to periodic, and every later use (labels, --all-patterns skip) must
    // agree with what actually ran.
    let pattern = match args.get_str("pattern", "periodic").as_str() {
        "poisson" => "poisson",
        "bursty" => "bursty",
        _ => "periodic",
    };
    let mut spec = match pattern {
        "poisson" => LoadSpec::poisson(&periods, requests, seed),
        "bursty" => LoadSpec::bursty(&periods, args.get("burst", 4usize), requests),
        _ => LoadSpec::periodic(&periods, requests),
    };
    let wall = args.flags.contains("wall");
    let time_scale = args.get("time-scale", 0.05);
    if wall {
        spec = spec.wall(std::time::Duration::from_secs(60));
    }
    // `--chaos <spec>` injects a deterministic fault scenario (and enables
    // the coordinator's watchdog/retry/remap recovery) into the main load
    // and the saturation search, which then also reports robust-α*.
    let chaos: Option<puzzle::serve::FaultPlan> = match args.options.get("chaos") {
        Some(s) => Some(puzzle::serve::FaultPlan::parse(s, seed)?),
        None => None,
    };
    let engine_scale = if wall { time_scale } else { 0.0 };
    let mut deployment = match &chaos {
        Some(plan) => analysis.deploy_chaos(
            best,
            RuntimeOptions::default(),
            engine_scale,
            true,
            seed,
            plan.clone(),
        )?,
        None => analysis.deploy_sim(best, RuntimeOptions::default(), engine_scale, true, seed)?,
    };
    let admission = match args.get_str("admission", "queue").as_str() {
        "little" => Admission::little(),
        _ => Admission::Queue,
    };
    if let Some(max_inflight) = args.options.get("max-inflight").and_then(|v| v.parse().ok()) {
        spec = spec.with_policy(OverloadPolicy::DropAfter { max_inflight });
    } else if let Admission::LittleCap { slack } = admission {
        // Derive the in-flight cap from Little's law instead of a fixed
        // constant: slack x (mean rate x profiled service time).
        let policy = deployment.little_law_policy(&spec, slack);
        if let OverloadPolicy::DropAfter { max_inflight } = policy {
            println!("admission: Little's-law cap of {max_inflight} in-flight group requests");
        }
        spec = spec.with_policy(policy);
    }
    // `--monitor` / `--monitor-json` subscribe to the deployment's
    // telemetry stream for the primary load: a background thread drains the
    // event ring while the load runs (live heartbeat lines on the TTY with
    // `--monitor`), and the folded totals are cross-checked against the
    // ServeReport after the run. The subscription is dropped before the
    // warm replays and the saturation search, so those run disarmed.
    let monitor_json = args.options.get("monitor-json").cloned();
    let monitor = args.flags.contains("monitor") || monitor_json.is_some();
    let monitor_thread = if monitor {
        let mut rx = deployment.subscribe();
        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let stop_signal = stop.clone();
        let live = args.flags.contains("monitor");
        let handle = std::thread::spawn(move || {
            let mut events: Vec<TelemetryEvent> = Vec::new();
            loop {
                let done = stop_signal.load(std::sync::atomic::Ordering::Acquire);
                for ev in rx.drain() {
                    if live {
                        if let TelemetryEvent::Heartbeat { time, rho, queue, busy, in_flight } = ev
                        {
                            println!(
                                "[monitor] t={time:9.4}s rho cpu/gpu/npu {:.2}/{:.2}/{:.2} queue {:?} busy {busy} in-flight {in_flight}",
                                rho[0], rho[1], rho[2], queue
                            );
                        }
                    }
                    events.push(ev);
                }
                if done {
                    break;
                }
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
            let dropped = rx.dropped();
            (events, dropped)
        });
        Some((handle, stop))
    } else {
        None
    };

    let report = deployment.serve_load(&spec);

    if let Some((handle, stop)) = monitor_thread {
        stop.store(true, std::sync::atomic::Ordering::Release);
        let (events, ring_dropped) = handle.join().expect("monitor thread panicked");
        let mut agg = MetricsAggregator::new();
        agg.fold_all(&events);
        println!("telemetry: {} events ({} lost to ring overflow)", events.len(), ring_dropped);
        println!("  {}", agg.summary_line());
        match agg.consistent_with(&report) {
            Ok(()) => println!("  aggregator totals match the serve report"),
            Err(e) => println!("  WARNING: aggregator/report mismatch: {e}"),
        }
        if let Some(path) = &monitor_json {
            use std::io::Write;
            let mut f = std::fs::File::create(path)?;
            for ev in &events {
                writeln!(f, "{}", ev.to_json_line())?;
            }
            println!("  wrote {} JSON-lines telemetry events to {path}", events.len());
        }
    }

    println!(
        "loadtest: pattern {pattern}, alpha {alpha:.2}, {} clock",
        if wall { "wall" } else { "virtual" }
    );
    println!(
        "  submitted {} served {} dropped {} unfinished {} violations {} | attainment {:.1}%, score {:.3}, {:.2}s wall",
        report.submitted,
        report.served,
        report.dropped,
        report.unfinished,
        report.violations,
        report.attainment * 100.0,
        report.score,
        report.wall_seconds
    );
    if chaos.is_some() {
        println!(
            "  chaos: {} retries, {} remaps, {} fault-shed, degraded time {:.2}ms",
            report.retries,
            report.remaps,
            report.fault_shed,
            report.degraded_time * 1e3
        );
    }
    for g in 0..report.group_makespans.len() {
        println!(
            "  group {g}: avg {:.2}ms p50 {:.2}ms p90 {:.2}ms over {} served (deadline {:.2}ms)",
            report.avg_makespan(g) * 1e3,
            report.percentile(g, 0.5) * 1e3,
            report.percentile(g, 0.9) * 1e3,
            report.group_makespans[g].len(),
            periods[g] * 1e3
        );
    }

    if args.flags.contains("all-patterns") {
        // Replay the remaining arrival patterns against the SAME warm
        // deployment: reset + re-seed between loads, no re-deploy.
        for (name, alt) in [
            ("periodic", LoadSpec::periodic(&periods, requests)),
            ("poisson", LoadSpec::poisson(&periods, requests, seed)),
            ("bursty", LoadSpec::bursty(&periods, args.get("burst", 4usize), requests)),
        ] {
            if name == pattern {
                continue;
            }
            let mut alt = alt.with_policy(spec.policy);
            if wall {
                alt = alt.wall(std::time::Duration::from_secs(60));
            }
            deployment.reset_seeded(seed);
            let r = deployment.serve_load(&alt);
            println!(
                "  [warm replay] {name:<8}: served {} dropped {} violations {} | score {:.3}",
                r.served, r.dropped, r.violations, r.score
            );
        }
    }
    deployment.shutdown();

    if !args.flags.contains("no-saturation") {
        println!("saturation search (runtime-measured, one warm deployment per solution set):");
        let sets = vec![analysis.runtime_solutions(best)?];
        let opts = puzzle::serve::SaturationOptions {
            requests,
            tolerance: if quick { 0.05 } else { 0.01 },
            seed,
            admission,
            probe_threads: args.get("probe-threads", 0usize),
            // `--core-budget N` leases the probe fleet's width per α from
            // a shared N-slot semaphore instead of the fixed
            // `--probe-threads` count (0 = machine cores).
            core_budget: args
                .options
                .get("core-budget")
                .and_then(|v| v.parse::<usize>().ok())
                .map(puzzle::util::threads::CoreBudget::new),
            ..Default::default()
        };
        let sat = puzzle::serve::saturation_via_runtime_observed(
            &sets,
            &scenario,
            session.perf(),
            &opts,
            &mut |p| {
                println!(
                    "  probe {:>2}: alpha {:.3} -> score {:.3} ({} deploys, {} certified)",
                    p.probes, p.alpha, p.score, p.deploys, p.certified_infeasible
                );
                ControlFlow::Continue(())
            },
        );
        match sat {
            Some(a) => println!("saturation multiplier alpha* = {a:.3}"),
            None => println!("no saturation within alpha <= {:.1}", opts.alpha_max),
        }
        if let Some(plan) = &chaos {
            // Same search with the fault plan attached to every probe
            // deployment: the rate sustainable *under* the chaos scenario.
            let robust_opts = puzzle::serve::SaturationOptions {
                fault_plan: Some(plan.clone()),
                ..opts
            };
            let robust = puzzle::serve::saturation_via_runtime(
                &sets,
                &scenario,
                session.perf(),
                &robust_opts,
            );
            match robust {
                Some(a) => {
                    println!("robust saturation multiplier alpha* = {a:.3} (under --chaos)")
                }
                None => {
                    println!("no robust saturation within alpha <= {:.1}", robust_opts.alpha_max)
                }
            }
        }
    }
    Ok(())
}

fn run_experiment(pm: &PerfModel, id: &str, budget: &ServingBudget) -> Result<()> {
    match id {
        "table2" => experiments::tables::print_table2(pm),
        "table3" => experiments::tables::print_table3(pm),
        "table4" => experiments::tables::print_table4(pm),
        "fig5" => {
            let (samples, fit, bw) = experiments::fig5_rpc_regression();
            println!("bandwidth {:.1} GB/s, r2 {:.4}", bw / 1e9, fit.r_squared(&samples));
            println!(
                "below-knee: {:.1}us + {:.3}ns/B; above: {:.1}us + {:.3}ns/B",
                fit.below_intercept * 1e6, fit.below_slope * 1e9,
                fit.above_intercept * 1e6, fit.above_slope * 1e9
            );
        }
        "energy" => {
            // The paper's deferred extension: energy per group request for
            // each method on the scenario-10 analog.
            use puzzle::perf::energy;
            use puzzle::sim::{simulate, GroupSpec, SimOptions};
            let scenario = puzzle::scenario::scenario10_analog();
            let (pz, bm, npu) =
                puzzle::experiments::solve_scenario_budgeted(&scenario, pm, budget.sim_requests, 210);
            let comm = puzzle::comm::CommModel::paper_calibrated();
            let periods = scenario.periods(1.2, pm);
            let groups: Vec<GroupSpec> = scenario
                .groups
                .iter()
                .zip(&periods)
                .map(|(g, &p)| GroupSpec::periodic(g.members.clone(), p))
                .collect();
            let opts = SimOptions { requests_per_group: 30, ..Default::default() };
            println!("energy per group request at alpha=1.2 (scenario-10 analog):");
            for (name, sols) in [("puzzle", &pz), ("best_mapping", &bm), ("npu_only", &npu)] {
                if let Some(plans) = sols.first() {
                    let r = simulate(plans, &groups, &comm, &opts);
                    println!(
                        "  {:<13} {:.1} mJ/request ({:.2} J total, busy CPU/GPU/NPU = {:.0}/{:.0}/{:.0} ms)",
                        name,
                        energy::energy_per_request(&r) * 1e3,
                        energy::schedule_energy(&r),
                        r.busy[0] * 1e3, r.busy[1] * 1e3, r.busy[2] * 1e3
                    );
                }
            }
        }
        "ablation-ga" => {
            println!("GA design-choice ablation (scenario-10 analog):");
            println!("{:<18} {:>18} {:>8}", "variant", "worst avg (ms)", "alpha*");
            for (name, worst, sat) in
                puzzle::experiments::ga_ablation(&puzzle::scenario::scenario10_analog(), pm, 7)
            {
                println!(
                    "{:<18} {:>18.2} {:>8}",
                    name,
                    worst * 1e3,
                    sat.map(|a| format!("{a:.2}")).unwrap_or_else(|| ">6".into())
                );
            }
        }
        "fig10" | "table5" => {
            let rows = experiments::fig10_ablation(pm, budget.scenarios.min(5), 12);
            let t5 = experiments::table5_breakdown(pm, 12);
            experiments::ablation::print_ablation(&rows, &t5);
        }
        "fig12" => {
            let rows = experiments::fig12_single_group(pm, budget);
            experiments::serving::print_saturation("Fig 12 — single model group saturation multipliers", &rows);
        }
        "fig13" => {
            for mc in experiments::fig13_score_curves(pm, budget) {
                print_curves(&mc);
            }
        }
        "fig14" => {
            for (method, alpha, avgs) in experiments::fig14_makespan_distribution(pm, budget) {
                println!(
                    "{method:<13} α={alpha}: group makespans {:?}",
                    avgs.iter().map(|a| format!("{:.1}ms", a * 1e3)).collect::<Vec<_>>()
                );
            }
        }
        "fig15" => {
            let rows = experiments::fig15_multi_group(pm, budget);
            experiments::serving::print_saturation("Fig 15 — multi model group saturation multipliers", &rows);
        }
        "fig16" => {
            for mc in experiments::fig16_multi_score_curves(pm, budget) {
                print_curves(&mc);
            }
        }
        "headline" => {
            let mut rows = experiments::fig12_single_group(pm, budget);
            rows.extend(experiments::fig15_multi_group(pm, budget));
            let (npu, bm) = experiments::headline_ratios(&rows);
            println!("headline: NPU Only {npu:.1}x (paper 3.7x), Best Mapping {bm:.1}x (paper 2.2x)");
        }
        "all" => {
            for id in [
                "table2", "table3", "table4", "fig5", "fig10", "ablation-ga", "fig12",
                "fig13", "fig14", "fig15", "fig16", "headline", "energy",
            ] {
                println!("==== {id} ====");
                run_experiment(pm, id, budget)?;
                println!();
            }
        }
        other => puzzle::bail!("unknown experiment id: {other}"),
    }
    Ok(())
}

/// The serving figures as one flattened work-stealing queue of
/// `(scenario, method)` jobs ([`experiments::serving::figure_protocol`]):
/// wall-clock is bounded by the slowest single scenario rather than the
/// slowest figure, and the merged report is bit-identical to the serial
/// per-figure drivers for any `--threads`.
fn figures_cmd(
    pm: &PerfModel,
    budget: &ServingBudget,
    select: experiments::serving::FigureSelection,
) -> Result<()> {
    use experiments::serving::{figure_protocol_observed, print_saturation};
    let t0 = std::time::Instant::now();
    let report = figure_protocol_observed(pm, budget, select, &mut |p| {
        println!("[{:>3}/{}] {}", p.done, p.total, p.label);
    });
    if let Some(rows) = &report.fig12 {
        print_saturation("Fig 12 — single model group saturation multipliers", rows);
    }
    if let Some(curves) = &report.fig13 {
        for mc in curves {
            print_curves(mc);
        }
    }
    if let Some(rows) = &report.fig14 {
        for (method, alpha, avgs) in rows {
            println!(
                "{method:<13} α={alpha}: group makespans {:?}",
                avgs.iter().map(|a| format!("{:.1}ms", a * 1e3)).collect::<Vec<_>>()
            );
        }
    }
    if let Some(rows) = &report.fig15 {
        print_saturation("Fig 15 — multi model group saturation multipliers", rows);
    }
    if let Some(curves) = &report.fig16 {
        for mc in curves {
            print_curves(mc);
        }
    }
    if let Some((npu, bm)) = report.headline {
        println!("headline: NPU Only {npu:.1}x (paper 3.7x), Best Mapping {bm:.1}x (paper 2.2x)");
    }
    println!("figure protocol finished in {:.2}s wall", t0.elapsed().as_secs_f64());
    Ok(())
}

fn print_curves(mc: &puzzle::experiments::MethodCurve) {
    println!("scenario {}", mc.scenario);
    for c in &mc.curves {
        let pts: Vec<String> = c
            .alphas
            .iter()
            .zip(&c.scores)
            .map(|(a, (lo, med, hi))| format!("{a:.1}:{lo:.2}/{med:.2}/{hi:.2}"))
            .collect();
        println!("  {:<13} {}", c.method, pts.join(" "));
    }
}

fn profile_zoo(pm: &PerfModel) {
    for net in models::model_zoo() {
        let all: Vec<LayerId> = (0..net.num_layers()).map(LayerId).collect();
        let times: Vec<String> = Processor::ALL
            .iter()
            .map(|&p| {
                let (cfg, t) = pm.best_config_for(&net, &all, p);
                format!("{}: {:.2}ms ({})", p, t * 1e3, cfg)
            })
            .collect();
        println!("{:<14} {}", net.name, times.join("  "));
    }
}
