//! Figure 10 + Table 5: the runtime-optimization ablation (tensor pool /
//! zero-copy shared buffer), run through the *real* Coordinator/Worker stack
//! with the simulated engine, so malloc/memcpy/free accounting is genuine.

use std::sync::Arc;

use crate::analyzer::GaConfig;
use crate::api::SessionBuilder;
use crate::coordinator::{Coordinator, NetworkSolution, RuntimeOptions};
use crate::engine::{Engine, SimEngine};
use crate::perf::PerfModel;
use crate::scenario::{single_group_scenarios, Scenario};

/// One ablation data point.
#[derive(Debug, Clone)]
pub struct AblationRow {
    pub scenario: String,
    /// Average makespan with (pool=off, shared=off).
    pub baseline: f64,
    /// Average makespan with pool on.
    pub pool: f64,
    /// Average makespan with pool + shared buffer.
    pub pool_shared: f64,
}

impl AblationRow {
    /// Relative makespans normalized to the no-optimization baseline
    /// (Fig 10's y-axis).
    pub fn normalized(&self) -> (f64, f64) {
        (self.pool / self.baseline, self.pool_shared / self.baseline)
    }
}

/// Table 5's breakdown columns for one optimization setting.
#[derive(Debug, Clone)]
pub struct Table5Row {
    pub tensor_pool: bool,
    pub shared_buffer: bool,
    pub malloc_ms: f64,
    pub malloc_count: u64,
    pub memcpy_ms: f64,
    pub engine_ms: f64,
    pub free_ms: f64,
}

/// Build runtime solutions from a Puzzle analysis of a scenario (the api's
/// analyze → deploy materialization).
fn puzzle_solutions(scenario: &Scenario, pm: &PerfModel, seed: u64) -> Vec<NetworkSolution> {
    let session = SessionBuilder::for_scenario(scenario.clone())
        .perf_model(pm.clone())
        .config(GaConfig::quick(seed))
        .build()
        .expect("prebuilt scenario is always valid");
    let analysis = session.run();
    analysis
        .runtime_solutions(analysis.best_index())
        .expect("best pareto solution deploys")
}

/// Serve `requests` group-requests through the real runtime under given
/// options; returns (avg makespan seconds, Table 5 row).
pub fn serve_with_options(
    solutions: Vec<NetworkSolution>,
    members: &[usize],
    requests: usize,
    options: RuntimeOptions,
    time_scale: f64,
) -> (f64, Table5Row) {
    let pm = Arc::new(PerfModel::paper_calibrated());
    let engine_impl = Arc::new(SimEngine::new(pm, time_scale, false, 11));
    let engine: Arc<dyn Engine> = engine_impl.clone();
    let tensor_pool = options.tensor_pool;
    let shared_buffer = options.zero_copy;
    let mut coord = Coordinator::new(solutions, engine, options);
    for _ in 0..requests {
        coord.submit_group(0, members);
        coord.pump(std::time::Duration::from_secs(20));
    }
    let served = coord.served().to_vec();
    let (malloc_ms, malloc_count, memcpy_ms, free_ms) = coord.pool_stats();
    let arena = &coord.arena;
    let arena_memcpy_ms =
        arena.stats.memcpy_ns.load(std::sync::atomic::Ordering::Relaxed) as f64 / 1e6;
    let arena_malloc_ms =
        arena.stats.malloc_ns.load(std::sync::atomic::Ordering::Relaxed) as f64 / 1e6;
    let engine_ms = engine_impl.simulated_busy() * 1e3;
    coord.shutdown();
    let avg = if served.is_empty() {
        f64::INFINITY
    } else {
        served.iter().map(|s| s.makespan).sum::<f64>() / served.len() as f64
    };
    (
        avg,
        Table5Row {
            tensor_pool,
            shared_buffer,
            malloc_ms: malloc_ms + arena_malloc_ms,
            malloc_count,
            memcpy_ms: memcpy_ms + arena_memcpy_ms,
            engine_ms,
            free_ms,
        },
    )
}

/// Figure 10 — relative makespan across single-group scenarios with the two
/// optimizations toggled. `n_scenarios` trims the sweep for benches.
pub fn fig10_ablation(pm: &PerfModel, n_scenarios: usize, requests: usize) -> Vec<AblationRow> {
    let scenarios = single_group_scenarios(23);
    scenarios
        .iter()
        .take(n_scenarios)
        .enumerate()
        .map(|(i, s)| {
            let members: Vec<usize> = s.groups[0].members.clone();
            let sols = puzzle_solutions(s, pm, 40 + i as u64);
            // Overhead-dominated measurement (time_scale = 0): the engines
            // return instantly, so the makespan is exactly the runtime's
            // tensor-management + dispatch overhead — the quantity the two
            // optimizations attack. At full engine-time scale our analog
            // tensors (~1000x smaller than the paper's) make that share
            // invisible; see EXPERIMENTS.md for the scale discussion.
            let scale = 0.0;
            let (baseline, _) = serve_with_options(
                sols.clone(),
                &members,
                requests,
                RuntimeOptions { tensor_pool: false, zero_copy: false, ..Default::default() },
                scale,
            );
            let (pool, _) = serve_with_options(
                sols.clone(),
                &members,
                requests,
                RuntimeOptions { tensor_pool: true, zero_copy: false, ..Default::default() },
                scale,
            );
            let (pool_shared, _) = serve_with_options(
                sols,
                &members,
                requests,
                RuntimeOptions { tensor_pool: true, zero_copy: true, ..Default::default() },
                scale,
            );
            AblationRow { scenario: s.name.clone(), baseline, pool, pool_shared }
        })
        .collect()
}

/// Table 5 — malloc/memcpy/engine/free breakdown for one scenario under the
/// three optimization settings.
pub fn table5_breakdown(pm: &PerfModel, requests: usize) -> Vec<Table5Row> {
    // Paper uses Scenario 5 of the single-group set.
    let scenarios = single_group_scenarios(23);
    let s = &scenarios[4];
    let members: Vec<usize> = s.groups[0].members.clone();
    let settings = [
        RuntimeOptions { tensor_pool: false, zero_copy: false, ..Default::default() },
        RuntimeOptions { tensor_pool: true, zero_copy: false, ..Default::default() },
        RuntimeOptions { tensor_pool: true, zero_copy: true, ..Default::default() },
    ];
    settings
        .into_iter()
        .map(|opt| {
            let sols = puzzle_solutions(s, pm, 44);
            serve_with_options(sols, &members, requests, opt, 0.02).1
        })
        .collect()
}

/// Pretty-print the ablation results (Fig 10 + Table 5 format).
pub fn print_ablation(rows: &[AblationRow], table5: &[Table5Row]) {
    println!("Fig 10 — relative makespan (1.0 = no optimizations)");
    println!("{:<12} {:>10} {:>14}", "scenario", "pool", "pool+shared");
    let mut pools = Vec::new();
    let mut shareds = Vec::new();
    for r in rows {
        let (p, s) = r.normalized();
        pools.push(1.0 - p);
        shareds.push(1.0 - s);
        println!("{:<12} {:>10.3} {:>14.3}", r.scenario, p, s);
    }
    let (pm_, _) = crate::metrics::mean_sd(&pools);
    let (sm, _) = crate::metrics::mean_sd(&shareds);
    println!("avg improvement: pool {:.1}% (paper 14.2%), +shared {:.1}% (paper 18.9%)", pm_ * 100.0, sm * 100.0);
    println!();
    println!("Table 5 — breakdown (ms)");
    println!(
        "{:<6} {:<7} {:>10} {:>8} {:>10} {:>10} {:>8}",
        "pool", "shared", "malloc", "#alloc", "memcpy", "engine", "free"
    );
    for r in table5 {
        println!(
            "{:<6} {:<7} {:>10.2} {:>8} {:>10.2} {:>10.1} {:>8.3}",
            r.tensor_pool, r.shared_buffer, r.malloc_ms, r.malloc_count,
            r.memcpy_ms, r.engine_ms, r.free_ms
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_pool_reduces_malloc_and_free() {
        let pm = PerfModel::paper_calibrated();
        let rows = table5_breakdown(&pm, 6);
        assert_eq!(rows.len(), 3);
        let base = &rows[0];
        let pool = &rows[1];
        // Pool reuse must not increase malloc count, and free time should
        // not blow up (freelist push vs real deallocation). Timing at this
        // granularity jitters in debug builds, so allow generous slack
        // plus an absolute floor.
        assert!(pool.malloc_count <= base.malloc_count);
        assert!(
            pool.free_ms <= base.free_ms * 2.0 + 0.05,
            "pool free {} vs base {}",
            pool.free_ms, base.free_ms
        );
    }

    #[test]
    fn shared_buffer_cuts_arena_memcpy() {
        let pm = PerfModel::paper_calibrated();
        let rows = table5_breakdown(&pm, 6);
        let pool_only = &rows[1];
        let pool_shared = &rows[2];
        assert!(
            pool_shared.memcpy_ms <= pool_only.memcpy_ms + 0.01,
            "zero-copy memcpy {} > copying {}",
            pool_shared.memcpy_ms, pool_only.memcpy_ms
        );
    }
}

/// GA design-choice ablation (DESIGN.md §6 "ablation benches"): disable one
/// exploration dimension at a time and compare the chosen solution's
/// worst-group average makespan plus the scenario's saturation multiplier.
/// Variants: full / no-partition / no-priority / no-local-search /
/// no-measurement-tier.
pub fn ga_ablation(
    scenario: &Scenario,
    pm: &PerfModel,
    seed: u64,
) -> Vec<(String, f64, Option<f64>)> {
    let base = GaConfig::quick(seed);
    let variants: Vec<(&str, GaConfig)> = vec![
        ("full", base.clone()),
        ("no-partition", GaConfig { explore_partition: false, ..base.clone() }),
        ("no-priority", GaConfig { explore_priority: false, ..base.clone() }),
        ("no-local-search", GaConfig { p_local_search: 0.0, ..base.clone() }),
        ("no-measure-tier", GaConfig { measure_reps: 0, ..base.clone() }),
    ];
    variants
        .into_iter()
        .map(|(name, cfg)| {
            let session = SessionBuilder::for_scenario(scenario.clone())
                .perf_model(pm.clone())
                .config(cfg)
                .build()
                .expect("prebuilt scenario is always valid");
            let analysis = session.run();
            let sols: Vec<Vec<crate::sim::ExecutionPlan>> =
                analysis.pareto.iter().map(|s| s.plans().to_vec()).collect();
            let best = analysis.best();
            let worst_obj = best.objectives.iter().cloned().fold(0.0, f64::max);
            let sat = super::saturation_of(&sols, scenario, pm, 12);
            (name.to_string(), worst_obj, sat)
        })
        .collect()
}

#[cfg(test)]
mod ga_ablation_tests {
    use super::*;
    use crate::scenario::scenario10_analog;

    #[test]
    fn ablation_variants_all_produce_solutions() {
        let pm = PerfModel::paper_calibrated();
        let rows = ga_ablation(&scenario10_analog(), &pm, 3);
        assert_eq!(rows.len(), 5);
        for (name, worst, _sat) in &rows {
            assert!(worst.is_finite() && *worst > 0.0, "{name}: {worst}");
        }
        // The full search space should not be meaningfully worse than any
        // ablated variant on the primary objective (same budget/seed).
        let full = rows[0].1;
        for (name, worst, _) in &rows[1..] {
            assert!(
                full <= worst * 1.25,
                "full GA ({full}) much worse than {name} ({worst})"
            );
        }
    }
}
