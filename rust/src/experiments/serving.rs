//! Serving experiments: Figures 12–16 and the headline request-frequency
//! ratios (paper §6.3–6.4).
//!
//! Since the arrival-driven serving PR these figures are measured **through
//! the runtime**: every method's solutions (Puzzle's Pareto genomes, Best
//! Mapping's front, NPU Only) are materialized into runtime
//! [`NetworkSolution`]s and pushed through the same open-loop virtual-clock
//! harness ([`crate::serve`]) — saturation multipliers come from
//! [`crate::serve::saturation_via_runtime`], scores from the Coordinator's
//! deadline-accounted [`crate::coordinator::ServedRequest`] log. The
//! analytic simulator path ([`super::saturation_of`] /
//! [`super::score_at_alpha`]) remains available for the ablation drivers
//! and quick estimates, but the figures no longer use it.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock};

use crate::analyzer::GaConfig;
use crate::api::SessionBuilder;
use crate::baselines;
use crate::coordinator::NetworkSolution;
use crate::metrics::mean_sd;
use crate::perf::PerfModel;
use crate::scenario::{multi_group_scenarios, scenario10_analog, single_group_scenarios, Scenario};
use crate::serve::{self, Admission, ClockMode, LoadSpec, RuntimeHarness, SaturationOptions};
use crate::sim::ExecutionPlan;
use crate::util::threads::{leased_threads, CoreBudget, CoreLease};

/// Per-scenario saturation multipliers for the three methods.
#[derive(Debug, Clone)]
pub struct SaturationRow {
    pub scenario: String,
    pub puzzle: Option<f64>,
    pub best_mapping: Option<f64>,
    pub npu_only: Option<f64>,
}

/// Budget knobs for the serving experiments (the full paper protocol is
/// expensive; benches use the reduced budget).
#[derive(Debug, Clone)]
pub struct ServingBudget {
    pub ga: GaSize,
    pub sim_requests: usize,
    pub scenarios: usize,
    /// Probe admission policy of the saturation searches
    /// ([`Admission::Queue`] reproduces the paper's unbounded-queue
    /// protocol; [`Admission::LittleCap`] bounds probe backlog with a
    /// Little's-law in-flight cap).
    pub admission: Admission,
    /// Width of the figure-protocol work-stealing shard: how many
    /// protocol jobs run concurrently (`0` = all cores, clamped to the
    /// job count). `1` — the default — runs the protocol serially with
    /// the per-set probe fleet inside each saturation search instead;
    /// above 1, each job's inner fleet drops to one thread so the two
    /// layers never oversubscribe (unless a [`ServingBudget::core_budget`]
    /// replaces that static rule). Either way the report is bit-identical:
    /// thread counts change scheduling only.
    pub protocol_threads: usize,
    /// Shared [`CoreBudget`] replacing the static two-level thread rule.
    /// When set, the protocol shard, each job's inner GA fan-out, and
    /// each saturation search's probe fleet all lease their widths from
    /// this one semaphore (`protocol_threads` and the forced inner
    /// `threads = 1` are superseded): a retiring protocol worker releases
    /// its slot, and still-running jobs' inner fan-outs reclaim it at
    /// their next generation or α-probe. Scheduling only — the report is
    /// bit-identical for any capacity (contract #6, property-tested).
    pub core_budget: Option<CoreBudget>,
    /// α-sweep chunk width of the score-curve protocol jobs (fig13 /
    /// fig16): each `(scenario, method)` sweep is split into
    /// independently stealable `(scenario, method, α-chunk)` jobs of this
    /// many grid points, merged back by job index. `0` — the default —
    /// picks automatically: the whole sweep as one job when the protocol
    /// runs serially without a core budget (one warm deployment per set
    /// across the whole grid), chunks of [`ServingBudget::AUTO_ALPHA_CHUNK`]
    /// otherwise. Any width yields a bit-identical report: probes are
    /// reset + re-seeded per (set, α), so a chunk-boundary re-deploy
    /// replays the exact fresh-deployment schedule (the warm-probe
    /// identity contract).
    pub alpha_chunk: usize,
}

#[derive(Debug, Clone, Copy)]
pub enum GaSize {
    Quick,
    Full,
}

impl ServingBudget {
    /// Auto α-chunk width of the score-curve jobs when the protocol runs
    /// parallel (see [`ServingBudget::alpha_chunk`]): small enough that
    /// one giant scenario's sweep splits across several stealable jobs,
    /// large enough that each job amortizes its per-set deployments.
    pub const AUTO_ALPHA_CHUNK: usize = 8;

    pub fn full() -> Self {
        ServingBudget {
            ga: GaSize::Full,
            sim_requests: 30,
            scenarios: 10,
            admission: Admission::Queue,
            protocol_threads: 1,
            core_budget: None,
            alpha_chunk: 0,
        }
    }

    pub fn quick() -> Self {
        ServingBudget {
            ga: GaSize::Quick,
            sim_requests: 12,
            scenarios: 3,
            admission: Admission::Queue,
            protocol_threads: 1,
            core_budget: None,
            alpha_chunk: 0,
        }
    }

    fn ga_config(&self, seed: u64) -> GaConfig {
        let mut config = match self.ga {
            GaSize::Quick => GaConfig::quick(seed),
            GaSize::Full => GaConfig { seed, ..Default::default() },
        };
        if let Some(core) = &self.core_budget {
            // Dynamic rule: the GA fan-out leases from the shared budget
            // every generation, reclaiming cores as sibling protocol jobs
            // retire (bit-identical for any width by contract).
            config.core_budget = Some(core.clone());
        } else if self.protocol_threads > 1 {
            // Static rule: the protocol shard already fans out across
            // jobs; one GA worker per job avoids nested oversubscription
            // (GA results are thread-count invariant, so this changes
            // nothing else).
            config.threads = 1;
        }
        config
    }

    /// Resolved α-chunk width for a sweep of `n_alphas` grid points (see
    /// [`ServingBudget::alpha_chunk`]).
    fn alpha_chunk_width(&self, n_alphas: usize) -> usize {
        match self.alpha_chunk {
            0 if self.protocol_threads == 1 && self.core_budget.is_none() => n_alphas.max(1),
            0 => Self::AUTO_ALPHA_CHUNK,
            w => w,
        }
    }
}

/// Convenience wrapper for examples: solve with a quick budget at a given
/// sim-request count and seed (analytic plan sets — see
/// [`solve_scenario`]).
pub fn solve_scenario_budgeted(
    scenario: &Scenario,
    pm: &PerfModel,
    sim_requests: usize,
    seed: u64,
) -> (Vec<Vec<ExecutionPlan>>, Vec<Vec<ExecutionPlan>>, Vec<Vec<ExecutionPlan>>) {
    let budget = ServingBudget { sim_requests, ..ServingBudget::quick() };
    solve_scenario(scenario, pm, &budget, seed)
}

/// Run the three methods on one scenario; return their Pareto **plan sets**
/// (the analytic-simulator representation, kept for the examples and the
/// energy estimate; the serving figures use [`solve_scenario_runtime`]).
pub fn solve_scenario(
    scenario: &Scenario,
    pm: &PerfModel,
    budget: &ServingBudget,
    seed: u64,
) -> (Vec<Vec<ExecutionPlan>>, Vec<Vec<ExecutionPlan>>, Vec<Vec<ExecutionPlan>>) {
    let session = SessionBuilder::for_scenario(scenario.clone())
        .perf_model(pm.clone())
        .config(budget.ga_config(seed))
        .build()
        .expect("prebuilt scenario is always valid");
    let analysis = session.run();
    let puzzle: Vec<Vec<ExecutionPlan>> =
        analysis.pareto.iter().map(|s| s.plans().to_vec()).collect();
    let bm: Vec<Vec<ExecutionPlan>> = baselines::best_mapping(scenario, pm, budget.sim_requests)
        .into_iter()
        .map(|s| s.plans)
        .collect();
    let npu = vec![baselines::npu_only(scenario, pm, budget.sim_requests).plans];
    (puzzle, bm, npu)
}

/// Runtime solution sets of the three methods on one scenario — the input
/// to the single serving harness every method goes through (identical
/// measurement for Puzzle and both baselines).
pub struct ScenarioMethods {
    pub puzzle: Vec<Vec<NetworkSolution>>,
    pub best_mapping: Vec<Vec<NetworkSolution>>,
    pub npu_only: Vec<Vec<NetworkSolution>>,
}

/// Solve one scenario with all three methods and materialize each
/// candidate solution for the runtime.
pub fn solve_scenario_runtime(
    scenario: &Scenario,
    pm: &PerfModel,
    budget: &ServingBudget,
    seed: u64,
) -> ScenarioMethods {
    let session = SessionBuilder::for_scenario(scenario.clone())
        .perf_model(pm.clone())
        .config(budget.ga_config(seed))
        .build()
        .expect("prebuilt scenario is always valid");
    let analysis = session.run();
    let puzzle = (0..analysis.pareto.len())
        .map(|i| analysis.runtime_solutions(i).expect("pareto index in range"))
        .collect();
    let best_mapping = baselines::best_mapping(scenario, pm, budget.sim_requests)
        .iter()
        .map(|s| s.runtime_solutions(scenario, pm))
        .collect();
    let npu = baselines::npu_only(scenario, pm, budget.sim_requests);
    let npu_only = vec![npu.runtime_solutions(scenario, pm)];
    ScenarioMethods { puzzle, best_mapping, npu_only }
}

/// Inner-fleet width under one protocol job: all cores when the protocol
/// layer itself is serial, one thread once the protocol shard is fanned
/// out — nested oversubscription changes scheduling only (results are
/// thread-count invariant by contract) but wastes context switches.
fn inner_threads(budget: &ServingBudget) -> usize {
    if budget.protocol_threads > 1 {
        1
    } else {
        0
    }
}

fn sat_opts(budget: &ServingBudget, seed: u64) -> SaturationOptions {
    SaturationOptions {
        requests: budget.sim_requests,
        seed,
        admission: budget.admission,
        probe_threads: inner_threads(budget),
        // With a shared core budget the probe fleet leases its width per
        // α-probe (superseding probe_threads) — late-phase reclamation.
        core_budget: budget.core_budget.clone(),
        ..Default::default()
    }
}

/// The three measured methods of the paper's serving protocol. A
/// `(scenario, method)` pair is the unit of work the protocol shard
/// steals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// Puzzle's Pareto solution sets.
    Puzzle,
    /// The best-static-mapping baseline's front.
    BestMapping,
    /// The all-on-NPU baseline.
    NpuOnly,
}

impl Method {
    /// All methods, in the fixed protocol (and report) order.
    pub const ALL: [Method; 3] = [Method::Puzzle, Method::BestMapping, Method::NpuOnly];

    /// The method's report label.
    pub fn name(self) -> &'static str {
        match self {
            Method::Puzzle => "puzzle",
            Method::BestMapping => "best_mapping",
            Method::NpuOnly => "npu_only",
        }
    }

    fn pick(self, methods: &ScenarioMethods) -> &Vec<Vec<NetworkSolution>> {
        match self {
            Method::Puzzle => &methods.puzzle,
            Method::BestMapping => &methods.best_mapping,
            Method::NpuOnly => &methods.npu_only,
        }
    }

    fn set(self, row: &mut SaturationRow, alpha: Option<f64>) {
        match self {
            Method::Puzzle => row.puzzle = alpha,
            Method::BestMapping => row.best_mapping = alpha,
            Method::NpuOnly => row.npu_only = alpha,
        }
    }
}

/// One scenario's lazily-shared GA solve: the first protocol job needing
/// its methods runs the solve, concurrent jobs of the same scenario block
/// on the [`OnceLock`] instead of re-solving. The GA seed is part of the
/// cell, so a shared cell always reproduces the serial protocol's solve.
struct SolveCell {
    scenario: Scenario,
    ga_seed: u64,
    methods: OnceLock<ScenarioMethods>,
}

impl SolveCell {
    fn new(scenario: Scenario, ga_seed: u64) -> SolveCell {
        SolveCell { scenario, ga_seed, methods: OnceLock::new() }
    }

    fn methods(&self, pm: &PerfModel, budget: &ServingBudget) -> &ScenarioMethods {
        self.methods.get_or_init(|| solve_scenario_runtime(&self.scenario, pm, budget, self.ga_seed))
    }
}

/// Work-stealing shard over an indexed job list, with a completion
/// fan-in. Workers pull the next job off a shared atomic cursor (no
/// per-thread chunking: one slow scenario cannot strand the rest of its
/// chunk), push `(index, result)` under a lock, and send the finished
/// index through an [`mpsc`] channel; the *calling* thread drains that
/// channel while the workers run, so `on_done` — the protocol's streaming
/// observer — needs neither `Send` nor `Sync`. Results are merged **by
/// job index, never completion order**, which is what keeps the folded
/// report bit-identical to a serial run of the same jobs.
fn shard_observed<J: Sync, R: Send>(
    jobs: &[J],
    requested: usize,
    core: Option<&CoreBudget>,
    run: &(impl Fn(usize, &J) -> R + Sync),
    on_done: &mut dyn FnMut(usize),
) -> Vec<R> {
    let (threads, lease) = leased_threads(core, requested, jobs.len());
    if threads <= 1 || jobs.len() <= 1 {
        // Serial path. Keep the (≤ 1-slot) lease for its duration: the
        // calling thread is charged to the budget like any worker, so
        // nested fan-outs below see an honestly-decremented pool.
        let _lease = lease;
        return jobs
            .iter()
            .enumerate()
            .map(|(i, job)| {
                let r = run(i, job);
                on_done(i);
                r
            })
            .collect();
    }
    let cursor = AtomicUsize::new(0);
    let done: Mutex<Vec<(usize, R)>> = Mutex::new(Vec::with_capacity(jobs.len()));
    let (tx, rx) = mpsc::channel::<usize>();
    // One single-slot token per worker (when leased from a core budget):
    // a worker that finds the cursor exhausted drops its token as it
    // exits, releasing its core *while its siblings still run* — the
    // late-phase reclamation that lets a surviving giant job's inner
    // fan-outs widen as the queue drains.
    let mut tokens: Vec<Option<CoreLease>> = match lease {
        Some(lease) => lease.split().into_iter().map(Some).collect(),
        None => (0..threads).map(|_| None).collect(),
    };
    std::thread::scope(|scope| {
        for token in tokens.drain(..) {
            let tx = tx.clone();
            let (cursor, done) = (&cursor, &done);
            scope.spawn(move || {
                let _token = token;
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= jobs.len() {
                        break;
                    }
                    let r = run(i, &jobs[i]);
                    done.lock().expect("shard worker panicked").push((i, r));
                    let _ = tx.send(i);
                }
            });
        }
        // The workers hold the remaining senders; iteration ends when the
        // last worker finishes and drops its clone.
        drop(tx);
        for i in rx {
            on_done(i);
        }
    });
    let mut done = done.into_inner().expect("shard worker panicked");
    done.sort_unstable_by_key(|&(i, _)| i);
    done.into_iter().map(|(_, r)| r).collect()
}

/// [`shard_observed`] without a completion observer.
fn shard<J: Sync, R: Send>(
    jobs: &[J],
    requested: usize,
    core: Option<&CoreBudget>,
    run: &(impl Fn(usize, &J) -> R + Sync),
) -> Vec<R> {
    shard_observed(jobs, requested, core, run, &mut |_| {})
}

/// Figure 12 / 15 core: runtime-measured saturation multiplier per scenario
/// per method (the [`crate::serve::saturation_via_runtime`] driver), run as
/// a work-stealing shard of `(scenario, method)` jobs at
/// [`ServingBudget::protocol_threads`] width (or leased from
/// [`ServingBudget::core_budget`] when set). Jobs of one scenario share
/// the GA solve through a [`SolveCell`]; rows are folded by scenario
/// index, so the table is identical to the serial sweep for any width.
/// Public as the imbalanced-protocol bench surface: callers hand it any
/// scenario list (e.g. one giant + several small) and any budget.
pub fn saturation_protocol(
    scenarios: &[Scenario],
    pm: &PerfModel,
    budget: &ServingBudget,
) -> Vec<SaturationRow> {
    let perf = Arc::new(pm.clone());
    let cells: Vec<SolveCell> = scenarios
        .iter()
        .take(budget.scenarios)
        .enumerate()
        .map(|(i, s)| SolveCell::new(s.clone(), 23 + i as u64))
        .collect();
    let jobs: Vec<(usize, Method)> =
        (0..cells.len()).flat_map(|i| Method::ALL.map(|m| (i, m))).collect();
    let alphas =
        shard(&jobs, budget.protocol_threads, budget.core_budget.as_ref(), &|_, &(i, m)| {
            let methods = cells[i].methods(pm, budget);
            let opts = sat_opts(budget, 29 + i as u64);
            serve::saturation_via_runtime(m.pick(methods), &cells[i].scenario, &perf, &opts)
        });
    let mut rows: Vec<SaturationRow> = cells
        .iter()
        .map(|c| SaturationRow {
            scenario: c.scenario.name.clone(),
            puzzle: None,
            best_mapping: None,
            npu_only: None,
        })
        .collect();
    for (&(i, m), alpha) in jobs.iter().zip(alphas) {
        m.set(&mut rows[i], alpha);
    }
    rows
}

/// Figure 12 — single model group saturation multipliers
/// (paper: Puzzle 0.78±0.08, Best Mapping 1.17±0.27, NPU Only 1.56±0.35).
pub fn fig12_single_group(pm: &PerfModel, budget: &ServingBudget) -> Vec<SaturationRow> {
    saturation_protocol(&single_group_scenarios(23), pm, budget)
}

/// Figure 15 — multi model group saturation multipliers
/// (paper: 0.95±0.27 / 2.24±1.90 / 3.45±2.12).
pub fn fig15_multi_group(pm: &PerfModel, budget: &ServingBudget) -> Vec<SaturationRow> {
    saturation_protocol(&multi_group_scenarios(23), pm, budget)
}

/// XRBench score as a function of the period multiplier for one method.
#[derive(Debug, Clone)]
pub struct ScoreCurve {
    pub method: String,
    pub alphas: Vec<f64>,
    /// (min, median, max) score across the method's solutions at each α.
    pub scores: Vec<(f64, f64, f64)>,
}

/// Curves for the three methods on one scenario (Figures 13 & 16).
#[derive(Debug, Clone)]
pub struct MethodCurve {
    pub scenario: String,
    pub curves: Vec<ScoreCurve>,
}

/// Runtime-measured score bands of a set of candidate solutions over a
/// whole α grid: periodic open-loop load at Φ(α) through **one warm
/// virtual-clock deployment per solution** (reset + re-seeded between
/// probes — bit-identical to fresh deployments, at one deploy per set
/// instead of one per (set, α) pair). The sets ride the same per-set
/// fleet as the saturation driver — one [`shard`] job per set, each
/// owning its deployment (and its whole α loop) for the job's lifetime —
/// and the solutions are `Arc`-shared into each harness rather than
/// cloned per deployment. Deterministic per seed, for any fleet width —
/// static ([`inner_threads`]) or leased from the budget's [`CoreBudget`].
fn runtime_score_bands(
    sets: &[Vec<NetworkSolution>],
    scenario: &Scenario,
    alphas: &[f64],
    perf: &Arc<PerfModel>,
    seed: u64,
    budget: &ServingBudget,
) -> Vec<(f64, f64, f64)> {
    if sets.is_empty() {
        return alphas.iter().map(|_| (0.0, 0.0, 0.0)).collect();
    }
    let groups: Arc<Vec<Vec<usize>>> =
        Arc::new(scenario.groups.iter().map(|g| g.members.clone()).collect());
    let jobs: Vec<usize> = (0..sets.len()).collect();
    // per_set[i][k] = score of set i at alphas[k].
    let per_set: Vec<Vec<f64>> = shard(
        &jobs,
        inner_threads(budget),
        budget.core_budget.as_ref(),
        &|_, &i| {
            let harness = RuntimeHarness::for_shared(
                Arc::new(sets[i].clone()),
                groups.clone(),
                perf.clone(),
                seed,
            );
            let mut deployment = harness.deploy(ClockMode::Virtual);
            let scores: Vec<f64> = alphas
                .iter()
                .map(|&alpha| {
                    let spec =
                        LoadSpec::for_scenario(scenario, perf, alpha, budget.sim_requests);
                    deployment.probe(&spec, serve::probe_seed(seed, i, alpha)).score
                })
                .collect();
            deployment.shutdown();
            scores
        },
    );
    alphas
        .iter()
        .enumerate()
        .map(|(k, _)| {
            let mut scores: Vec<f64> = per_set.iter().map(|s| s[k]).collect();
            scores.sort_by(|a, b| a.partial_cmp(b).unwrap());
            (scores[0], scores[scores.len() / 2], scores[scores.len() - 1])
        })
        .collect()
}

/// Score-vs-α curves for a scenario (Figure 13 for single-group scenarios,
/// Figure 16 for multi-group), measured through the runtime. The per-set
/// band sweeps run on the probe fleet — all cores when the protocol layer
/// is serial, one thread per protocol job otherwise.
pub fn score_curves(
    scenario: &Scenario,
    pm: &PerfModel,
    budget: &ServingBudget,
    alphas: &[f64],
    seed: u64,
) -> MethodCurve {
    let methods = solve_scenario_runtime(scenario, pm, budget, seed);
    let perf = Arc::new(pm.clone());
    MethodCurve {
        scenario: scenario.name.clone(),
        curves: Method::ALL
            .iter()
            .map(|m| ScoreCurve {
                method: m.name().to_string(),
                alphas: alphas.to_vec(),
                scores: runtime_score_bands(
                    m.pick(&methods),
                    scenario,
                    alphas,
                    &perf,
                    seed,
                    budget,
                ),
            })
            .collect(),
    }
}

/// Figure 13 — two single-group scenarios' score curves (trimmed to
/// [`ServingBudget::scenarios`], floor 1, like the saturation sweeps).
pub fn fig13_score_curves(pm: &PerfModel, budget: &ServingBudget) -> Vec<MethodCurve> {
    let scenarios = single_group_scenarios(23);
    let alphas = fig13_alphas();
    [(0usize, 101u64), (7, 108)]
        .into_iter()
        .take(budget.scenarios.max(1))
        .map(|(idx, seed)| score_curves(&scenarios[idx], pm, budget, &alphas, seed))
        .collect()
}

/// Figure 16 — scenarios 6 & 10 analogs' score curves (multi-group,
/// trimmed to [`ServingBudget::scenarios`], floor 1).
pub fn fig16_multi_score_curves(pm: &PerfModel, budget: &ServingBudget) -> Vec<MethodCurve> {
    let alphas = fig16_alphas();
    [(crate::scenario::scenario6_analog(), 206u64), (scenario10_analog(), 210)]
        .into_iter()
        .take(budget.scenarios.max(1))
        .map(|(s, seed)| score_curves(&s, pm, budget, &alphas, seed))
        .collect()
}

/// Figure 13's α grid (0.2..=2.0, step 0.1) — one definition shared by
/// the serial driver and the chunked protocol builder, so their merged
/// curves always carry the same axis.
fn fig13_alphas() -> Vec<f64> {
    (2..=20).map(|i| i as f64 * 0.1).collect()
}

/// Figure 16's α grid (0.2..=3.0, step 0.1); see [`fig13_alphas`].
fn fig16_alphas() -> Vec<f64> {
    (2..=30).map(|i| i as f64 * 0.1).collect()
}

/// Figure 14 — per-group average makespan of scenario 10's solutions at a
/// lenient (α=1.4) and tight (α=0.9) period, measured through the runtime's
/// served-request log. Returns `(method, alpha, [group avg makespans])`
/// rows.
pub fn fig14_makespan_distribution(
    pm: &PerfModel,
    budget: &ServingBudget,
) -> Vec<(String, f64, Vec<f64>)> {
    let scenario = scenario10_analog();
    let methods = solve_scenario_runtime(&scenario, pm, budget, 210);
    let perf = Arc::new(pm.clone());
    Method::ALL
        .iter()
        .flat_map(|m| fig14_method_rows(&scenario, m.name(), m.pick(&methods).first(), &perf, budget))
        .collect()
}

/// One method's Figure-14 rows — the unit the protocol shard steals. The
/// deployment, its telemetry subscription, and the aggregation
/// cross-check all live on the calling (worker) thread: per-deployment
/// subscribers stay isolated per job, so sharded methods never share a
/// telemetry ring.
fn fig14_method_rows(
    scenario: &Scenario,
    name: &str,
    sols: Option<&Vec<NetworkSolution>>,
    perf: &Arc<PerfModel>,
    budget: &ServingBudget,
) -> Vec<(String, f64, Vec<f64>)> {
    let Some(sols) = sols else { return Vec::new() };
    let groups: Arc<Vec<Vec<usize>>> =
        Arc::new(scenario.groups.iter().map(|g| g.members.clone()).collect());
    // One warm deployment per method, probed at every α: reset +
    // re-seeded between probes, so each row is bit-identical to the
    // fresh-deployment-per-(method, α) protocol at half the deploys.
    let mut deployment =
        RuntimeHarness::for_shared(Arc::new(sols.clone()), groups.clone(), perf.clone(), 41)
            .deploy(ClockMode::Virtual);
    // Telemetry cross-check: one subscription across every probe of
    // this deployment; each probe's drained events, folded on their
    // own, must reproduce that probe's ServeReport exactly (the
    // aggregation-consistency contract, exercised here on a production
    // figure path rather than only in tests).
    let mut telemetry = deployment.subscribe();
    let mut rows = Vec::new();
    for &alpha in &[1.4, 0.9] {
        // Paper omits NPU Only at tight periods (system failure from
        // accumulated tasks); we keep it at the lenient period only.
        if name == "npu_only" && alpha < 1.0 {
            continue;
        }
        let spec = LoadSpec::for_scenario(scenario, perf, alpha, budget.sim_requests);
        let report = deployment.probe(&spec, serve::probe_seed(41, 0, alpha));
        let mut agg = crate::telemetry::MetricsAggregator::new();
        agg.fold_all(&telemetry.drain());
        agg.consistent_with(&report)
            .expect("fig14 telemetry aggregation must match the probe's serve report");
        let avgs: Vec<f64> = (0..groups.len()).map(|g| report.avg_makespan(g)).collect();
        rows.push((name.to_string(), alpha, avgs));
    }
    drop(telemetry);
    deployment.shutdown();
    rows
}

/// Which figures the protocol queue should produce.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[allow(missing_docs)]
pub struct FigureSelection {
    pub fig12: bool,
    pub fig13: bool,
    pub fig14: bool,
    pub fig15: bool,
    pub fig16: bool,
}

impl FigureSelection {
    /// Every figure (the paper's full evaluation protocol).
    pub fn all() -> FigureSelection {
        FigureSelection { fig12: true, fig13: true, fig14: true, fig15: true, fig16: true }
    }

    /// No figures — the starting point for [`FigureSelection::parse`].
    pub fn none() -> FigureSelection {
        FigureSelection { fig12: false, fig13: false, fig14: false, fig15: false, fig16: false }
    }

    /// Parse a comma-separated list like `"fig12,fig14"` (bare numbers
    /// accepted: `"12,14"`).
    pub fn parse(spec: &str) -> Result<FigureSelection, String> {
        let mut sel = FigureSelection::none();
        for tok in spec.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            match tok {
                "fig12" | "12" => sel.fig12 = true,
                "fig13" | "13" => sel.fig13 = true,
                "fig14" | "14" => sel.fig14 = true,
                "fig15" | "15" => sel.fig15 = true,
                "fig16" | "16" => sel.fig16 = true,
                other => return Err(format!("unknown figure {other:?} (expected fig12..fig16)")),
            }
        }
        Ok(sel)
    }
}

/// The merged output of [`figure_protocol`]: one field per selected
/// figure (`None` = not selected), plus the headline ratios when both
/// saturation tables were produced.
#[derive(Debug, Clone, Default)]
pub struct FigureReport {
    /// Figure 12 — single-group saturation multipliers.
    pub fig12: Option<Vec<SaturationRow>>,
    /// Figure 13 — single-group score-vs-α curves.
    pub fig13: Option<Vec<MethodCurve>>,
    /// Figure 14 — per-group average makespans.
    pub fig14: Option<Vec<(String, f64, Vec<f64>)>>,
    /// Figure 15 — multi-group saturation multipliers.
    pub fig15: Option<Vec<SaturationRow>>,
    /// Figure 16 — multi-group score-vs-α curves.
    pub fig16: Option<Vec<MethodCurve>>,
    /// `(npu_only, best_mapping)` mean saturation ratios vs Puzzle over
    /// fig12 + fig15 combined ([`headline_ratios`]); requires both.
    pub headline: Option<(f64, f64)>,
}

/// One finished protocol job, streamed to the [`figure_protocol_observed`]
/// observer **in completion order** (the report itself is merged by job
/// index, so completion order never leaks into the output).
#[derive(Debug, Clone)]
pub struct ProtocolProgress {
    /// Jobs finished so far, including this one.
    pub done: usize,
    /// Total jobs in the queue.
    pub total: usize,
    /// Human label of the finished job (`"fig12 scenario3 puzzle"`).
    pub label: String,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Fig {
    F12,
    F13,
    F15,
    F16,
}

impl Fig {
    fn name(self) -> &'static str {
        match self {
            Fig::F12 => "fig12",
            Fig::F13 => "fig13",
            Fig::F15 => "fig15",
            Fig::F16 => "fig16",
        }
    }
}

/// One unit of the figure protocol: a `(scenario, method)` pair — or,
/// for the score-curve figures, a `(scenario, method, α-chunk)` triple
/// (see [`ServingBudget::alpha_chunk`]) — plus where its output lands in
/// the report. Jobs reference their scenario's [`SolveCell`] by index, so
/// two jobs (even across figures — fig16's scenario-10 curves and fig14
/// share one solve) never duplicate a GA run.
enum ProtocolJob {
    Sat { fig: Fig, row: usize, cell: usize, method: Method, sat_seed: u64 },
    /// `alphas` holds only this chunk's grid points; `lo` is the chunk's
    /// offset into the figure's full α grid (0 = the curve's first
    /// chunk), which is all the merge needs to stitch curves back in
    /// grid order.
    Curve {
        fig: Fig,
        row: usize,
        cell: usize,
        method: Method,
        seed: u64,
        lo: usize,
        alphas: Vec<f64>,
    },
    Makespan { cell: usize, method: Method },
}

enum ProtocolOut {
    Sat(Option<f64>),
    /// One α-chunk's `(min, median, max)` score bands, in grid order.
    Curve(Vec<(f64, f64, f64)>),
    Makespan(Vec<(String, f64, Vec<f64>)>),
}

impl ProtocolJob {
    fn label(&self, cells: &[SolveCell]) -> String {
        match self {
            ProtocolJob::Sat { fig, cell, method, .. } => {
                format!("{} {} {}", fig.name(), cells[*cell].scenario.name, method.name())
            }
            ProtocolJob::Curve { fig, cell, method, lo, alphas, .. } => {
                let name = cells[*cell].scenario.name.as_str();
                if *lo == 0 {
                    format!("{} {} {}", fig.name(), name, method.name())
                } else {
                    // Non-leading chunks carry their α window so progress
                    // lines distinguish the stolen pieces of one sweep.
                    format!(
                        "{} {} {} α[{}..{}]",
                        fig.name(),
                        name,
                        method.name(),
                        lo,
                        lo + alphas.len()
                    )
                }
            }
            ProtocolJob::Makespan { cell, method } => {
                format!("fig14 {} {}", cells[*cell].scenario.name, method.name())
            }
        }
    }

    fn run(
        &self,
        cells: &[SolveCell],
        perf: &Arc<PerfModel>,
        pm: &PerfModel,
        budget: &ServingBudget,
    ) -> ProtocolOut {
        match self {
            ProtocolJob::Sat { cell, method, sat_seed, .. } => {
                let methods = cells[*cell].methods(pm, budget);
                let opts = sat_opts(budget, *sat_seed);
                ProtocolOut::Sat(serve::saturation_via_runtime(
                    method.pick(methods),
                    &cells[*cell].scenario,
                    perf,
                    &opts,
                ))
            }
            ProtocolJob::Curve { cell, method, seed, alphas, .. } => {
                let methods = cells[*cell].methods(pm, budget);
                ProtocolOut::Curve(runtime_score_bands(
                    method.pick(methods),
                    &cells[*cell].scenario,
                    alphas,
                    perf,
                    *seed,
                    budget,
                ))
            }
            ProtocolJob::Makespan { cell, method } => {
                let methods = cells[*cell].methods(pm, budget);
                ProtocolOut::Makespan(fig14_method_rows(
                    &cells[*cell].scenario,
                    method.name(),
                    method.pick(methods).first(),
                    perf,
                    budget,
                ))
            }
        }
    }
}

/// The whole figure protocol (Figs 12–16 + headline) as **one flattened
/// work-stealing queue** of `(scenario, method)` jobs at
/// [`ServingBudget::protocol_threads`] width — full-protocol wall-clock
/// is bounded by the slowest single scenario, not the slowest figure.
/// Seeds, job bodies, and fold order are exactly the serial per-figure
/// drivers' ([`fig12_single_group`], [`fig13_score_curves`],
/// [`fig14_makespan_distribution`], [`fig15_multi_group`],
/// [`fig16_multi_score_curves`]), so the merged report is bit-identical
/// to running those five in sequence, for any thread count.
pub fn figure_protocol(
    pm: &PerfModel,
    budget: &ServingBudget,
    select: FigureSelection,
) -> FigureReport {
    figure_protocol_observed(pm, budget, select, &mut |_| {})
}

/// [`figure_protocol`] with a per-job completion observer (CLI progress).
/// The observer runs on the calling thread — job completions fan in over
/// a channel — so it needs neither `Send` nor `Sync`.
pub fn figure_protocol_observed(
    pm: &PerfModel,
    budget: &ServingBudget,
    select: FigureSelection,
    on_job: &mut dyn FnMut(&ProtocolProgress),
) -> FigureReport {
    let mut cells: Vec<SolveCell> = Vec::new();
    let mut jobs: Vec<ProtocolJob> = Vec::new();

    // Saturation tables (fig12 single-group, fig15 multi-group): GA seed
    // 23+i, saturation seed 29+i per scenario — the serial sweep's seeds.
    let mut fig12_rows: Vec<SaturationRow> = Vec::new();
    let mut fig15_rows: Vec<SaturationRow> = Vec::new();
    for (fig, on, scenarios, rows) in [
        (Fig::F12, select.fig12, single_group_scenarios(23), &mut fig12_rows),
        (Fig::F15, select.fig15, multi_group_scenarios(23), &mut fig15_rows),
    ] {
        if !on {
            continue;
        }
        for (i, s) in scenarios.into_iter().take(budget.scenarios).enumerate() {
            let cell = cells.len();
            rows.push(SaturationRow {
                scenario: s.name.clone(),
                puzzle: None,
                best_mapping: None,
                npu_only: None,
            });
            cells.push(SolveCell::new(s, 23 + i as u64));
            for method in Method::ALL {
                jobs.push(ProtocolJob::Sat {
                    fig,
                    row: i,
                    cell,
                    method,
                    sat_seed: 29 + i as u64,
                });
            }
        }
    }

    // Score curves (fig13 single-group scenarios 1 & 8, fig16 multi-group
    // analogs): per-scenario GA/probe seeds as in the serial drivers. Each
    // `(scenario, method)` sweep is cut into α-chunk jobs of
    // `alpha_chunk_width` grid points — chunk-minor within method-major
    // order, so the index-merge below can push a curve at its first chunk
    // and extend it with the rest. Probes are reset + re-seeded per
    // `(set, α)`, so the chunk boundaries never show in the scores.
    let fig13_grid = fig13_alphas();
    let fig16_grid = fig16_alphas();
    let mut fig13_rows: Vec<MethodCurve> = Vec::new();
    let mut fig16_rows: Vec<MethodCurve> = Vec::new();
    let mut s10_cell: Option<usize> = None;
    if select.fig13 {
        let single = single_group_scenarios(23);
        let chunk = budget.alpha_chunk_width(fig13_grid.len());
        for (row, (idx, seed)) in [(0usize, 101u64), (7, 108)]
            .into_iter()
            .take(budget.scenarios.max(1))
            .enumerate()
        {
            let s = single[idx].clone();
            let cell = cells.len();
            fig13_rows.push(MethodCurve { scenario: s.name.clone(), curves: Vec::new() });
            cells.push(SolveCell::new(s, seed));
            for method in Method::ALL {
                for lo in (0..fig13_grid.len()).step_by(chunk) {
                    let hi = (lo + chunk).min(fig13_grid.len());
                    jobs.push(ProtocolJob::Curve {
                        fig: Fig::F13,
                        row,
                        cell,
                        method,
                        seed,
                        lo,
                        alphas: fig13_grid[lo..hi].to_vec(),
                    });
                }
            }
        }
    }
    if select.fig16 {
        let chunk = budget.alpha_chunk_width(fig16_grid.len());
        for (row, (s, seed)) in
            [(crate::scenario::scenario6_analog(), 206u64), (scenario10_analog(), 210)]
                .into_iter()
                .take(budget.scenarios.max(1))
                .enumerate()
        {
            let cell = cells.len();
            if seed == 210 {
                s10_cell = Some(cell);
            }
            fig16_rows.push(MethodCurve { scenario: s.name.clone(), curves: Vec::new() });
            cells.push(SolveCell::new(s, seed));
            for method in Method::ALL {
                for lo in (0..fig16_grid.len()).step_by(chunk) {
                    let hi = (lo + chunk).min(fig16_grid.len());
                    jobs.push(ProtocolJob::Curve {
                        fig: Fig::F16,
                        row,
                        cell,
                        method,
                        seed,
                        lo,
                        alphas: fig16_grid[lo..hi].to_vec(),
                    });
                }
            }
        }
    }

    // Fig 14 rides fig16's scenario-10 solve when both are selected (same
    // scenario, same GA seed 210 — the solve is deterministic, so sharing
    // the cell cannot change either figure).
    if select.fig14 {
        let cell = s10_cell.unwrap_or_else(|| {
            let cell = cells.len();
            cells.push(SolveCell::new(scenario10_analog(), 210));
            cell
        });
        for method in Method::ALL {
            jobs.push(ProtocolJob::Makespan { cell, method });
        }
    }

    let perf = Arc::new(pm.clone());
    let labels: Vec<String> = jobs.iter().map(|j| j.label(&cells)).collect();
    let total = jobs.len();
    let mut completed = 0usize;
    let results = shard_observed(
        &jobs,
        budget.protocol_threads,
        budget.core_budget.as_ref(),
        &|_, job: &ProtocolJob| job.run(&cells, &perf, pm, budget),
        &mut |i| {
            completed += 1;
            on_job(&ProtocolProgress { done: completed, total, label: labels[i].clone() });
        },
    );

    // Merge by job index: `results` is already in job order, and jobs are
    // generated figure-major / scenario-major / method-major / α-chunk-
    // minor, so pushing a curve at its `lo == 0` chunk (with the figure's
    // full α grid), extending it with the following chunks, and extending
    // fig14 rows reproduces the serial drivers' output exactly.
    let mut fig14_rows: Vec<(String, f64, Vec<f64>)> = Vec::new();
    for (job, out) in jobs.iter().zip(results) {
        match (job, out) {
            (ProtocolJob::Sat { fig, row, method, .. }, ProtocolOut::Sat(alpha)) => {
                let rows = match fig {
                    Fig::F12 => &mut fig12_rows,
                    Fig::F15 => &mut fig15_rows,
                    _ => unreachable!("saturation jobs belong to fig12/fig15"),
                };
                method.set(&mut rows[*row], alpha);
            }
            (ProtocolJob::Curve { fig, row, method, lo, .. }, ProtocolOut::Curve(scores)) => {
                let (rows, grid) = match fig {
                    Fig::F13 => (&mut fig13_rows, &fig13_grid),
                    Fig::F16 => (&mut fig16_rows, &fig16_grid),
                    _ => unreachable!("curve jobs belong to fig13/fig16"),
                };
                if *lo == 0 {
                    rows[*row].curves.push(ScoreCurve {
                        method: method.name().to_string(),
                        alphas: grid.clone(),
                        scores: Vec::new(),
                    });
                }
                rows[*row]
                    .curves
                    .last_mut()
                    .expect("the lo == 0 chunk pushed this method's curve")
                    .scores
                    .extend(scores);
            }
            (ProtocolJob::Makespan { .. }, ProtocolOut::Makespan(rows)) => {
                fig14_rows.extend(rows);
            }
            _ => unreachable!("job and output kinds are produced 1:1"),
        }
    }

    let headline = (select.fig12 && select.fig15).then(|| {
        let mut all = fig12_rows.clone();
        all.extend(fig15_rows.iter().cloned());
        headline_ratios(&all)
    });
    FigureReport {
        fig12: select.fig12.then_some(fig12_rows),
        fig13: select.fig13.then_some(fig13_rows),
        fig14: select.fig14.then_some(fig14_rows),
        fig15: select.fig15.then_some(fig15_rows),
        fig16: select.fig16.then_some(fig16_rows),
        headline,
    }
}

/// Headline: mean saturation-multiplier ratios vs Puzzle
/// (paper: NPU Only 3.7×, Best Mapping 2.2× over single+multi combined).
pub fn headline_ratios(rows: &[SaturationRow]) -> (f64, f64) {
    let ratios = |get: fn(&SaturationRow) -> Option<f64>| -> Vec<f64> {
        rows.iter()
            .filter_map(|r| match (get(r), r.puzzle) {
                (Some(x), Some(p)) if p > 0.0 => Some(x / p),
                _ => None,
            })
            .collect()
    };
    let npu = ratios(|r| r.npu_only);
    let bm = ratios(|r| r.best_mapping);
    (mean_sd(&npu).0, mean_sd(&bm).0)
}

/// Pretty-print a saturation table with mean ± SD.
pub fn print_saturation(title: &str, rows: &[SaturationRow]) {
    println!("{title}");
    println!("{:<12} {:>8} {:>13} {:>9}", "scenario", "puzzle", "best_mapping", "npu_only");
    let fmt = |v: Option<f64>| v.map(|x| format!("{x:.2}")).unwrap_or_else(|| ">6".into());
    for r in rows {
        println!(
            "{:<12} {:>8} {:>13} {:>9}",
            r.scenario, fmt(r.puzzle), fmt(r.best_mapping), fmt(r.npu_only)
        );
    }
    let collect = |get: fn(&SaturationRow) -> Option<f64>| -> Vec<f64> {
        rows.iter().filter_map(get).collect()
    };
    let (pm_, ps) = mean_sd(&collect(|r| r.puzzle));
    let (bm, bs) = mean_sd(&collect(|r| r.best_mapping));
    let (nm, ns) = mean_sd(&collect(|r| r.npu_only));
    println!(
        "{:<12} {:>5.2}±{:.2} {:>9.2}±{:.2} {:>6.2}±{:.2}",
        "mean±sd", pm_, ps, bm, bs, nm, ns
    );
    let (r_npu, r_bm) = headline_ratios(rows);
    println!("headline ratios vs puzzle: npu_only {r_npu:.1}x, best_mapping {r_bm:.1}x");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_single_group_puzzle_wins() {
        // The acceptance bar of the arrival-driven serving PR: Fig 12's
        // quick budget, saturation measured through the runtime driver,
        // Puzzle at least as good (≤, lower α* = more sustainable load) as
        // both baselines.
        let pm = PerfModel::paper_calibrated();
        let budget = ServingBudget { scenarios: 2, ..ServingBudget::quick() };
        let rows = fig12_single_group(&pm, &budget);
        assert_eq!(rows.len(), 2);
        for r in &rows {
            let p = r.puzzle.expect("puzzle saturates");
            if let Some(n) = r.npu_only {
                assert!(p <= n + 0.05, "{}: puzzle {p} vs npu {n}", r.scenario);
            }
            if let Some(b) = r.best_mapping {
                assert!(p <= b + 0.05, "{}: puzzle {p} vs bm {b}", r.scenario);
            }
        }
    }

    #[test]
    fn runtime_serving_logs_bit_identical_for_seed() {
        // The virtual-clock determinism contract on the fig-12 path: same
        // seed, same load ⇒ bit-identical ServedRequest logs.
        let pm = PerfModel::paper_calibrated();
        let budget = ServingBudget { scenarios: 1, ..ServingBudget::quick() };
        let scenarios = single_group_scenarios(23);
        let scenario = &scenarios[0];
        let methods = solve_scenario_runtime(scenario, &pm, &budget, 23);
        let perf = Arc::new(pm.clone());
        let harness = RuntimeHarness::for_solutions(
            methods.puzzle[0].clone(),
            scenario.groups.iter().map(|g| g.members.clone()).collect(),
            perf.clone(),
            7,
        );
        let spec = LoadSpec::for_scenario(scenario, &pm, 1.0, budget.sim_requests);
        let (_, log_a) = harness.run_with_log(&spec);
        let (_, log_b) = harness.run_with_log(&spec);
        assert_eq!(log_a.len(), log_b.len());
        assert!(!log_a.is_empty());
        for (a, b) in log_a.iter().zip(&log_b) {
            assert_eq!((a.group, a.request), (b.group, b.request));
            assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
            assert_eq!(a.completion.to_bits(), b.completion.to_bits());
            assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
            assert_eq!(a.deadline.map(f64::to_bits), b.deadline.map(f64::to_bits));
            assert_eq!(a.violated, b.violated);
        }
    }

    #[test]
    fn fig14_rows_have_two_groups() {
        let pm = PerfModel::paper_calibrated();
        let budget = ServingBudget::quick();
        let rows = fig14_makespan_distribution(&pm, &budget);
        assert!(rows.len() >= 4);
        for (_m, _a, avgs) in &rows {
            assert_eq!(avgs.len(), 2);
            assert!(avgs.iter().all(|&x| x > 0.0));
        }
        // NPU-only row exists at 1.4 but not at 0.9.
        assert!(rows.iter().any(|(m, a, _)| m == "npu_only" && *a == 1.4));
        assert!(!rows.iter().any(|(m, a, _)| m == "npu_only" && *a == 0.9));
    }

    #[test]
    fn shard_merges_by_index_for_any_width() {
        let jobs: Vec<usize> = (0..23).collect();
        for threads in [1, 2, 4, 8] {
            let mut done: Vec<usize> = Vec::new();
            let out = shard_observed(
                &jobs,
                threads,
                None,
                &|i, &j| {
                    assert_eq!(i, j, "jobs are dispatched with their own index");
                    j * 10
                },
                &mut |i| done.push(i),
            );
            // Results in job order regardless of completion order…
            assert_eq!(out, (0..23).map(|j| j * 10).collect::<Vec<_>>(), "threads={threads}");
            // …and the fan-in reported every job exactly once.
            done.sort_unstable();
            assert_eq!(done, jobs, "threads={threads}");
        }
    }

    #[test]
    fn sharded_protocol_matches_serial_sweep() {
        // The protocol-shard determinism contract: the same budget at
        // protocol_threads 1 vs 2 yields bit-identical saturation rows,
        // both through the figure driver and the flattened protocol queue.
        let pm = PerfModel::paper_calibrated();
        let serial_budget = ServingBudget { scenarios: 1, ..ServingBudget::quick() };
        let sharded_budget = ServingBudget { protocol_threads: 2, ..serial_budget.clone() };
        let serial = fig12_single_group(&pm, &serial_budget);
        let sharded = fig12_single_group(&pm, &sharded_budget);
        let assert_rows_eq = |a: &[SaturationRow], b: &[SaturationRow]| {
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.scenario, y.scenario);
                assert_eq!(x.puzzle.map(f64::to_bits), y.puzzle.map(f64::to_bits));
                assert_eq!(
                    x.best_mapping.map(f64::to_bits),
                    y.best_mapping.map(f64::to_bits)
                );
                assert_eq!(x.npu_only.map(f64::to_bits), y.npu_only.map(f64::to_bits));
            }
        };
        assert_rows_eq(&serial, &sharded);

        let select = FigureSelection::parse("fig12").expect("valid selection");
        let report = figure_protocol(&pm, &sharded_budget, select);
        assert_rows_eq(&serial, report.fig12.as_deref().expect("fig12 selected"));
        assert!(report.fig13.is_none() && report.fig14.is_none());
        assert!(report.fig15.is_none() && report.fig16.is_none());
        assert!(report.headline.is_none(), "headline needs fig12 AND fig15");
        assert!(FigureSelection::parse("fig12,bogus").is_err());
    }

    #[test]
    fn shard_respects_core_budget_capacity() {
        // The shard leases its width from the budget — the `requested`
        // knob is superseded (no double-clamp): asking for 8 workers on
        // a 2-core budget runs exactly 2 at a time.
        use std::sync::atomic::AtomicIsize;
        let jobs: Vec<usize> = (0..16).collect();
        let budget = CoreBudget::new(2);
        let live = AtomicIsize::new(0);
        let peak = AtomicIsize::new(0);
        let out = shard(&jobs, 8, Some(&budget), &|_, &j| {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(2));
            live.fetch_sub(1, Ordering::SeqCst);
            j
        });
        assert_eq!(out, jobs);
        let peak = peak.load(Ordering::SeqCst);
        assert!((1..=2).contains(&peak), "peak concurrency {peak} vs 2-core budget");
        assert_eq!(budget.available(), 2, "shard returned every leased slot");
    }

    #[test]
    fn chunked_budgeted_protocol_matches_serial_curves() {
        // Contract #6 on the score-curve (bands) path: any core-budget
        // capacity × α-chunk width reproduces the serial fig13 curves
        // bit-for-bit. Chunk boundaries re-deploy, but probes are reset +
        // re-seeded per (set, α), so the schedule replays exactly; the
        // budget changes worker counts only.
        let pm = PerfModel::paper_calibrated();
        let serial_budget = ServingBudget { scenarios: 1, ..ServingBudget::quick() };
        let select = FigureSelection::parse("fig13").expect("valid selection");
        let serial =
            figure_protocol(&pm, &serial_budget, select).fig13.expect("fig13 selected");
        assert_eq!(serial.len(), 1, "scenarios: 1 trims fig13 to one scenario");
        // Protocol ≡ serial per-figure driver at the same budget.
        let driver = fig13_score_curves(&pm, &serial_budget);
        assert_curves_eq(&driver, &serial, "serial driver");
        for (capacity, chunk) in [(1usize, 4usize), (2, 19), (4, 7), (8, 4)] {
            let budget = ServingBudget {
                core_budget: Some(CoreBudget::new(capacity)),
                alpha_chunk: chunk,
                ..serial_budget.clone()
            };
            let curves = figure_protocol(&pm, &budget, select).fig13.expect("fig13 selected");
            assert_curves_eq(&serial, &curves, &format!("capacity={capacity} chunk={chunk}"));
        }
    }

    fn assert_curves_eq(a: &[MethodCurve], b: &[MethodCurve], what: &str) {
        let bits = |s: &[(f64, f64, f64)]| -> Vec<(u64, u64, u64)> {
            s.iter().map(|&(l, m, h)| (l.to_bits(), m.to_bits(), h.to_bits())).collect()
        };
        assert_eq!(a.len(), b.len(), "{what}");
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.scenario, y.scenario, "{what}");
            assert_eq!(x.curves.len(), y.curves.len(), "{what}");
            for (cx, cy) in x.curves.iter().zip(&y.curves) {
                assert_eq!(cx.method, cy.method, "{what}");
                assert_eq!(
                    cx.alphas.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    cy.alphas.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "{what} {}",
                    cx.method
                );
                assert_eq!(bits(&cx.scores), bits(&cy.scores), "{what} {}", cx.method);
            }
        }
    }

    #[test]
    fn score_curves_are_monotone_ish() {
        // Median score should not decrease significantly as alpha grows.
        let pm = PerfModel::paper_calibrated();
        let budget = ServingBudget::quick();
        let scenario = crate::scenario::scenario6_analog();
        let alphas = [0.5, 1.0, 2.0, 3.0];
        let mc = score_curves(&scenario, &pm, &budget, &alphas, 5);
        for curve in &mc.curves {
            let med: Vec<f64> = curve.scores.iter().map(|s| s.1).collect();
            for w in med.windows(2) {
                assert!(w[1] >= w[0] - 0.1, "{}: {med:?}", curve.method);
            }
        }
    }
}
