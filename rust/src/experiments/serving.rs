//! Serving experiments: Figures 12–16 and the headline request-frequency
//! ratios (paper §6.3–6.4).
//!
//! Since the arrival-driven serving PR these figures are measured **through
//! the runtime**: every method's solutions (Puzzle's Pareto genomes, Best
//! Mapping's front, NPU Only) are materialized into runtime
//! [`NetworkSolution`]s and pushed through the same open-loop virtual-clock
//! harness ([`crate::serve`]) — saturation multipliers come from
//! [`crate::serve::saturation_via_runtime`], scores from the Coordinator's
//! deadline-accounted [`crate::coordinator::ServedRequest`] log. The
//! analytic simulator path ([`super::saturation_of`] /
//! [`super::score_at_alpha`]) remains available for the ablation drivers
//! and quick estimates, but the figures no longer use it.

use std::sync::Arc;

use crate::analyzer::GaConfig;
use crate::api::SessionBuilder;
use crate::baselines;
use crate::coordinator::NetworkSolution;
use crate::metrics::mean_sd;
use crate::perf::PerfModel;
use crate::scenario::{multi_group_scenarios, scenario10_analog, single_group_scenarios, Scenario};
use crate::serve::{self, Admission, ClockMode, LoadSpec, RuntimeHarness, SaturationOptions};
use crate::sim::ExecutionPlan;

/// Per-scenario saturation multipliers for the three methods.
#[derive(Debug, Clone)]
pub struct SaturationRow {
    pub scenario: String,
    pub puzzle: Option<f64>,
    pub best_mapping: Option<f64>,
    pub npu_only: Option<f64>,
}

/// Budget knobs for the serving experiments (the full paper protocol is
/// expensive; benches use the reduced budget).
#[derive(Debug, Clone, Copy)]
pub struct ServingBudget {
    pub ga: GaSize,
    pub sim_requests: usize,
    pub scenarios: usize,
    /// Probe admission policy of the saturation searches
    /// ([`Admission::Queue`] reproduces the paper's unbounded-queue
    /// protocol; [`Admission::LittleCap`] bounds probe backlog with a
    /// Little's-law in-flight cap).
    pub admission: Admission,
}

#[derive(Debug, Clone, Copy)]
pub enum GaSize {
    Quick,
    Full,
}

impl ServingBudget {
    pub fn full() -> Self {
        ServingBudget {
            ga: GaSize::Full,
            sim_requests: 30,
            scenarios: 10,
            admission: Admission::Queue,
        }
    }

    pub fn quick() -> Self {
        ServingBudget {
            ga: GaSize::Quick,
            sim_requests: 12,
            scenarios: 3,
            admission: Admission::Queue,
        }
    }

    fn ga_config(&self, seed: u64) -> GaConfig {
        match self.ga {
            GaSize::Quick => GaConfig::quick(seed),
            GaSize::Full => GaConfig { seed, ..Default::default() },
        }
    }
}

/// Convenience wrapper for examples: solve with a quick budget at a given
/// sim-request count and seed (analytic plan sets — see
/// [`solve_scenario`]).
pub fn solve_scenario_budgeted(
    scenario: &Scenario,
    pm: &PerfModel,
    sim_requests: usize,
    seed: u64,
) -> (Vec<Vec<ExecutionPlan>>, Vec<Vec<ExecutionPlan>>, Vec<Vec<ExecutionPlan>>) {
    let budget = ServingBudget { sim_requests, ..ServingBudget::quick() };
    solve_scenario(scenario, pm, &budget, seed)
}

/// Run the three methods on one scenario; return their Pareto **plan sets**
/// (the analytic-simulator representation, kept for the examples and the
/// energy estimate; the serving figures use [`solve_scenario_runtime`]).
pub fn solve_scenario(
    scenario: &Scenario,
    pm: &PerfModel,
    budget: &ServingBudget,
    seed: u64,
) -> (Vec<Vec<ExecutionPlan>>, Vec<Vec<ExecutionPlan>>, Vec<Vec<ExecutionPlan>>) {
    let session = SessionBuilder::for_scenario(scenario.clone())
        .perf_model(pm.clone())
        .config(budget.ga_config(seed))
        .build()
        .expect("prebuilt scenario is always valid");
    let analysis = session.run();
    let puzzle: Vec<Vec<ExecutionPlan>> =
        analysis.pareto.iter().map(|s| s.plans().to_vec()).collect();
    let bm: Vec<Vec<ExecutionPlan>> = baselines::best_mapping(scenario, pm, budget.sim_requests)
        .into_iter()
        .map(|s| s.plans)
        .collect();
    let npu = vec![baselines::npu_only(scenario, pm, budget.sim_requests).plans];
    (puzzle, bm, npu)
}

/// Runtime solution sets of the three methods on one scenario — the input
/// to the single serving harness every method goes through (identical
/// measurement for Puzzle and both baselines).
pub struct ScenarioMethods {
    pub puzzle: Vec<Vec<NetworkSolution>>,
    pub best_mapping: Vec<Vec<NetworkSolution>>,
    pub npu_only: Vec<Vec<NetworkSolution>>,
}

/// Solve one scenario with all three methods and materialize each
/// candidate solution for the runtime.
pub fn solve_scenario_runtime(
    scenario: &Scenario,
    pm: &PerfModel,
    budget: &ServingBudget,
    seed: u64,
) -> ScenarioMethods {
    let session = SessionBuilder::for_scenario(scenario.clone())
        .perf_model(pm.clone())
        .config(budget.ga_config(seed))
        .build()
        .expect("prebuilt scenario is always valid");
    let analysis = session.run();
    let puzzle = (0..analysis.pareto.len())
        .map(|i| analysis.runtime_solutions(i).expect("pareto index in range"))
        .collect();
    let best_mapping = baselines::best_mapping(scenario, pm, budget.sim_requests)
        .iter()
        .map(|s| s.runtime_solutions(scenario, pm))
        .collect();
    let npu = baselines::npu_only(scenario, pm, budget.sim_requests);
    let npu_only = vec![npu.runtime_solutions(scenario, pm)];
    ScenarioMethods { puzzle, best_mapping, npu_only }
}

fn sat_opts(budget: &ServingBudget, seed: u64) -> SaturationOptions {
    SaturationOptions {
        requests: budget.sim_requests,
        seed,
        admission: budget.admission,
        ..Default::default()
    }
}

/// Figure 12 / 15 core: runtime-measured saturation multiplier per scenario
/// per method (the [`crate::serve::saturation_via_runtime`] driver).
fn saturation_sweep(
    scenarios: &[Scenario],
    pm: &PerfModel,
    budget: &ServingBudget,
) -> Vec<SaturationRow> {
    let perf = Arc::new(pm.clone());
    scenarios
        .iter()
        .take(budget.scenarios)
        .enumerate()
        .map(|(i, s)| {
            let methods = solve_scenario_runtime(s, pm, budget, 23 + i as u64);
            let opts = sat_opts(budget, 29 + i as u64);
            SaturationRow {
                scenario: s.name.clone(),
                puzzle: serve::saturation_via_runtime(&methods.puzzle, s, &perf, &opts),
                best_mapping: serve::saturation_via_runtime(&methods.best_mapping, s, &perf, &opts),
                npu_only: serve::saturation_via_runtime(&methods.npu_only, s, &perf, &opts),
            }
        })
        .collect()
}

/// Figure 12 — single model group saturation multipliers
/// (paper: Puzzle 0.78±0.08, Best Mapping 1.17±0.27, NPU Only 1.56±0.35).
pub fn fig12_single_group(pm: &PerfModel, budget: &ServingBudget) -> Vec<SaturationRow> {
    saturation_sweep(&single_group_scenarios(23), pm, budget)
}

/// Figure 15 — multi model group saturation multipliers
/// (paper: 0.95±0.27 / 2.24±1.90 / 3.45±2.12).
pub fn fig15_multi_group(pm: &PerfModel, budget: &ServingBudget) -> Vec<SaturationRow> {
    saturation_sweep(&multi_group_scenarios(23), pm, budget)
}

/// XRBench score as a function of the period multiplier for one method.
#[derive(Debug, Clone)]
pub struct ScoreCurve {
    pub method: String,
    pub alphas: Vec<f64>,
    /// (min, median, max) score across the method's solutions at each α.
    pub scores: Vec<(f64, f64, f64)>,
}

/// Curves for the three methods on one scenario (Figures 13 & 16).
#[derive(Debug, Clone)]
pub struct MethodCurve {
    pub scenario: String,
    pub curves: Vec<ScoreCurve>,
}

/// Runtime-measured score bands of a set of candidate solutions over a
/// whole α grid: periodic open-loop load at Φ(α) through **one warm
/// virtual-clock deployment per solution** (reset + re-seeded between
/// probes — bit-identical to fresh deployments, at one deploy per set
/// instead of one per (set, α) pair). Deterministic per seed.
fn runtime_score_bands(
    sets: &[Vec<NetworkSolution>],
    scenario: &Scenario,
    alphas: &[f64],
    perf: &Arc<PerfModel>,
    requests: usize,
    seed: u64,
) -> Vec<(f64, f64, f64)> {
    if sets.is_empty() {
        return alphas.iter().map(|_| (0.0, 0.0, 0.0)).collect();
    }
    let groups: Vec<Vec<usize>> = scenario.groups.iter().map(|g| g.members.clone()).collect();
    // per_alpha[k][i] = score of set i at alphas[k].
    let mut per_alpha: Vec<Vec<f64>> = vec![Vec::with_capacity(sets.len()); alphas.len()];
    for (i, sols) in sets.iter().enumerate() {
        let harness =
            RuntimeHarness::for_solutions(sols.clone(), groups.clone(), perf.clone(), seed);
        let mut deployment = harness.deploy(ClockMode::Virtual);
        for (k, &alpha) in alphas.iter().enumerate() {
            let spec = LoadSpec::for_scenario(scenario, perf, alpha, requests);
            per_alpha[k].push(deployment.probe(&spec, serve::probe_seed(seed, i, alpha)).score);
        }
        deployment.shutdown();
    }
    per_alpha
        .into_iter()
        .map(|mut scores| {
            scores.sort_by(|a, b| a.partial_cmp(b).unwrap());
            (scores[0], scores[scores.len() / 2], scores[scores.len() - 1])
        })
        .collect()
}

/// Score-vs-α curves for a scenario (Figure 13 for single-group scenarios,
/// Figure 16 for multi-group), measured through the runtime.
pub fn score_curves(
    scenario: &Scenario,
    pm: &PerfModel,
    budget: &ServingBudget,
    alphas: &[f64],
    seed: u64,
) -> MethodCurve {
    let methods = solve_scenario_runtime(scenario, pm, budget, seed);
    let perf = Arc::new(pm.clone());
    let make = |name: &str, sets: &[Vec<NetworkSolution>]| ScoreCurve {
        method: name.to_string(),
        alphas: alphas.to_vec(),
        scores: runtime_score_bands(sets, scenario, alphas, &perf, budget.sim_requests, seed),
    };
    MethodCurve {
        scenario: scenario.name.clone(),
        curves: vec![
            make("puzzle", &methods.puzzle),
            make("best_mapping", &methods.best_mapping),
            make("npu_only", &methods.npu_only),
        ],
    }
}

/// Figure 13 — two single-group scenarios' score curves.
pub fn fig13_score_curves(pm: &PerfModel, budget: &ServingBudget) -> Vec<MethodCurve> {
    let scenarios = single_group_scenarios(23);
    let alphas: Vec<f64> = (2..=20).map(|i| i as f64 * 0.1).collect();
    vec![
        score_curves(&scenarios[0], pm, budget, &alphas, 101),
        score_curves(&scenarios[7], pm, budget, &alphas, 108),
    ]
}

/// Figure 16 — scenarios 6 & 10 analogs' score curves (multi-group).
pub fn fig16_multi_score_curves(pm: &PerfModel, budget: &ServingBudget) -> Vec<MethodCurve> {
    let alphas: Vec<f64> = (2..=30).map(|i| i as f64 * 0.1).collect();
    vec![
        score_curves(&crate::scenario::scenario6_analog(), pm, budget, &alphas, 206),
        score_curves(&scenario10_analog(), pm, budget, &alphas, 210),
    ]
}

/// Figure 14 — per-group average makespan of scenario 10's solutions at a
/// lenient (α=1.4) and tight (α=0.9) period, measured through the runtime's
/// served-request log. Returns `(method, alpha, [group avg makespans])`
/// rows.
pub fn fig14_makespan_distribution(
    pm: &PerfModel,
    budget: &ServingBudget,
) -> Vec<(String, f64, Vec<f64>)> {
    let scenario = scenario10_analog();
    let methods = solve_scenario_runtime(&scenario, pm, budget, 210);
    let perf = Arc::new(pm.clone());
    let groups: Vec<Vec<usize>> = scenario.groups.iter().map(|g| g.members.clone()).collect();
    let named: Vec<(&str, Option<&Vec<NetworkSolution>>)> = vec![
        ("puzzle", methods.puzzle.first()),
        ("best_mapping", methods.best_mapping.first()),
        ("npu_only", methods.npu_only.first()),
    ];
    let mut rows = Vec::new();
    for (name, sols) in named {
        let Some(sols) = sols else { continue };
        // One warm deployment per method, probed at every α: reset +
        // re-seeded between probes, so each row is bit-identical to the
        // fresh-deployment-per-(method, α) protocol at half the deploys.
        let mut deployment =
            RuntimeHarness::for_solutions(sols.clone(), groups.clone(), perf.clone(), 41)
                .deploy(ClockMode::Virtual);
        // Telemetry cross-check: one subscription across every probe of
        // this deployment; each probe's drained events, folded on their
        // own, must reproduce that probe's ServeReport exactly (the
        // aggregation-consistency contract, exercised here on a production
        // figure path rather than only in tests).
        let mut telemetry = deployment.subscribe();
        for &alpha in &[1.4, 0.9] {
            // Paper omits NPU Only at tight periods (system failure from
            // accumulated tasks); we keep it at the lenient period only.
            if name == "npu_only" && alpha < 1.0 {
                continue;
            }
            let spec = LoadSpec::for_scenario(&scenario, pm, alpha, budget.sim_requests);
            let report = deployment.probe(&spec, serve::probe_seed(41, 0, alpha));
            let mut agg = crate::telemetry::MetricsAggregator::new();
            agg.fold_all(&telemetry.drain());
            agg.consistent_with(&report)
                .expect("fig14 telemetry aggregation must match the probe's serve report");
            let avgs: Vec<f64> = (0..groups.len()).map(|g| report.avg_makespan(g)).collect();
            rows.push((name.to_string(), alpha, avgs));
        }
        drop(telemetry);
        deployment.shutdown();
    }
    rows
}

/// Headline: mean saturation-multiplier ratios vs Puzzle
/// (paper: NPU Only 3.7×, Best Mapping 2.2× over single+multi combined).
pub fn headline_ratios(rows: &[SaturationRow]) -> (f64, f64) {
    let ratios = |get: fn(&SaturationRow) -> Option<f64>| -> Vec<f64> {
        rows.iter()
            .filter_map(|r| match (get(r), r.puzzle) {
                (Some(x), Some(p)) if p > 0.0 => Some(x / p),
                _ => None,
            })
            .collect()
    };
    let npu = ratios(|r| r.npu_only);
    let bm = ratios(|r| r.best_mapping);
    (mean_sd(&npu).0, mean_sd(&bm).0)
}

/// Pretty-print a saturation table with mean ± SD.
pub fn print_saturation(title: &str, rows: &[SaturationRow]) {
    println!("{title}");
    println!("{:<12} {:>8} {:>13} {:>9}", "scenario", "puzzle", "best_mapping", "npu_only");
    let fmt = |v: Option<f64>| v.map(|x| format!("{x:.2}")).unwrap_or_else(|| ">6".into());
    for r in rows {
        println!(
            "{:<12} {:>8} {:>13} {:>9}",
            r.scenario, fmt(r.puzzle), fmt(r.best_mapping), fmt(r.npu_only)
        );
    }
    let collect = |get: fn(&SaturationRow) -> Option<f64>| -> Vec<f64> {
        rows.iter().filter_map(get).collect()
    };
    let (pm_, ps) = mean_sd(&collect(|r| r.puzzle));
    let (bm, bs) = mean_sd(&collect(|r| r.best_mapping));
    let (nm, ns) = mean_sd(&collect(|r| r.npu_only));
    println!(
        "{:<12} {:>5.2}±{:.2} {:>9.2}±{:.2} {:>6.2}±{:.2}",
        "mean±sd", pm_, ps, bm, bs, nm, ns
    );
    let (r_npu, r_bm) = headline_ratios(rows);
    println!("headline ratios vs puzzle: npu_only {r_npu:.1}x, best_mapping {r_bm:.1}x");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_single_group_puzzle_wins() {
        // The acceptance bar of the arrival-driven serving PR: Fig 12's
        // quick budget, saturation measured through the runtime driver,
        // Puzzle at least as good (≤, lower α* = more sustainable load) as
        // both baselines.
        let pm = PerfModel::paper_calibrated();
        let budget = ServingBudget { scenarios: 2, ..ServingBudget::quick() };
        let rows = fig12_single_group(&pm, &budget);
        assert_eq!(rows.len(), 2);
        for r in &rows {
            let p = r.puzzle.expect("puzzle saturates");
            if let Some(n) = r.npu_only {
                assert!(p <= n + 0.05, "{}: puzzle {p} vs npu {n}", r.scenario);
            }
            if let Some(b) = r.best_mapping {
                assert!(p <= b + 0.05, "{}: puzzle {p} vs bm {b}", r.scenario);
            }
        }
    }

    #[test]
    fn runtime_serving_logs_bit_identical_for_seed() {
        // The virtual-clock determinism contract on the fig-12 path: same
        // seed, same load ⇒ bit-identical ServedRequest logs.
        let pm = PerfModel::paper_calibrated();
        let budget = ServingBudget { scenarios: 1, ..ServingBudget::quick() };
        let scenarios = single_group_scenarios(23);
        let scenario = &scenarios[0];
        let methods = solve_scenario_runtime(scenario, &pm, &budget, 23);
        let perf = Arc::new(pm.clone());
        let harness = RuntimeHarness::for_solutions(
            methods.puzzle[0].clone(),
            scenario.groups.iter().map(|g| g.members.clone()).collect(),
            perf.clone(),
            7,
        );
        let spec = LoadSpec::for_scenario(scenario, &pm, 1.0, budget.sim_requests);
        let (_, log_a) = harness.run_with_log(&spec);
        let (_, log_b) = harness.run_with_log(&spec);
        assert_eq!(log_a.len(), log_b.len());
        assert!(!log_a.is_empty());
        for (a, b) in log_a.iter().zip(&log_b) {
            assert_eq!((a.group, a.request), (b.group, b.request));
            assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
            assert_eq!(a.completion.to_bits(), b.completion.to_bits());
            assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
            assert_eq!(a.deadline.map(f64::to_bits), b.deadline.map(f64::to_bits));
            assert_eq!(a.violated, b.violated);
        }
    }

    #[test]
    fn fig14_rows_have_two_groups() {
        let pm = PerfModel::paper_calibrated();
        let budget = ServingBudget::quick();
        let rows = fig14_makespan_distribution(&pm, &budget);
        assert!(rows.len() >= 4);
        for (_m, _a, avgs) in &rows {
            assert_eq!(avgs.len(), 2);
            assert!(avgs.iter().all(|&x| x > 0.0));
        }
        // NPU-only row exists at 1.4 but not at 0.9.
        assert!(rows.iter().any(|(m, a, _)| m == "npu_only" && *a == 1.4));
        assert!(!rows.iter().any(|(m, a, _)| m == "npu_only" && *a == 0.9));
    }

    #[test]
    fn score_curves_are_monotone_ish() {
        // Median score should not decrease significantly as alpha grows.
        let pm = PerfModel::paper_calibrated();
        let budget = ServingBudget::quick();
        let scenario = crate::scenario::scenario6_analog();
        let alphas = [0.5, 1.0, 2.0, 3.0];
        let mc = score_curves(&scenario, &pm, &budget, &alphas, 5);
        for curve in &mc.curves {
            let med: Vec<f64> = curve.scores.iter().map(|s| s.1).collect();
            for w in med.windows(2) {
                assert!(w[1] >= w[0] - 0.1, "{}: {med:?}", curve.method);
            }
        }
    }
}
