//! Experiment drivers: one per table/figure of the paper's evaluation
//! (DESIGN.md §6 maps each to its source section). Every driver returns a
//! structured result and can print the paper-formatted table; EXPERIMENTS.md
//! records paper-vs-measured for each.

pub mod ablation;
pub mod fuzz;
pub mod serving;
pub mod tables;

pub use ablation::{fig10_ablation, ga_ablation, table5_breakdown, AblationRow, Table5Row};
pub use fuzz::{
    calibrate_slack, report_hash, run_fuzz_corpus, FuzzCaseOutcome, FuzzOptions, SlackSweepRow,
};
pub use serving::{
    fig12_single_group, fig13_score_curves, fig14_makespan_distribution, fig15_multi_group,
    fig16_multi_score_curves, figure_protocol, figure_protocol_observed, headline_ratios,
    saturation_protocol, solve_scenario, solve_scenario_budgeted, solve_scenario_runtime,
    FigureReport,
    FigureSelection, GaSize, Method, MethodCurve, ProtocolProgress, SaturationRow,
    ScenarioMethods, ScoreCurve, ServingBudget,
};
pub use tables::{fig5_rpc_regression, table2_configs, table3_processors, table4_nonlinearity};

use crate::comm::CommModel;
use crate::metrics;
use crate::perf::PerfModel;
use crate::scenario::Scenario;
use crate::sim::{simulate, ExecutionPlan, GroupSpec, SimOptions};

/// Number of noisy repetitions per score evaluation (the analog of running
/// the solution on the real device, where execution times fluctuate —
/// especially on the CPU, paper §6.3).
pub const SCORE_NOISE_REPS: usize = 3;

/// Simulate a plan set on a scenario at period multiplier `alpha` and return
/// the XRBench score, averaged over noisy repetitions. This is the
/// "measured on device" evaluation every method is subjected to: methods
/// whose solutions depend on fluctuating processors (Best Mapping's
/// CPU-heavy mappings) pay for it here, exactly as in the paper's testbed.
pub fn score_at_alpha(
    plans: &[ExecutionPlan],
    scenario: &Scenario,
    alpha: f64,
    pm: &PerfModel,
    requests: usize,
) -> f64 {
    let periods = scenario.periods(alpha, pm);
    let groups: Vec<GroupSpec> = scenario
        .groups
        .iter()
        .zip(&periods)
        .map(|(g, &p)| GroupSpec::periodic(g.members.clone(), p))
        .collect();
    let comm = CommModel::paper_calibrated();
    let opts = SimOptions { requests_per_group: requests, ..Default::default() };
    // Deterministic seed per (alpha, plan-set shape) keeps runs reproducible.
    let seed = 0x5c0e ^ (alpha * 1000.0) as u64 ^ ((plans.len() as u64) << 32);
    let mut rng = crate::util::rng::Rng::seed_from_u64(seed);
    let mut total = 0.0;
    for _ in 0..SCORE_NOISE_REPS {
        let noisy: Vec<ExecutionPlan> = plans
            .iter()
            .map(|p| {
                let mut p2 = p.clone();
                for t in &mut p2.tasks {
                    t.duration = pm.sample(t.duration, t.processor, &mut rng);
                }
                p2
            })
            .collect();
        let result = simulate(&noisy, &groups, &comm, &opts);
        total += metrics::scenario_score(&result.makespans, &periods);
    }
    total / SCORE_NOISE_REPS as f64
}

/// Median score over a set of Pareto solutions at a multiplier (the paper's
/// rule when multiple solutions emerge, §6.2).
pub fn median_score_at_alpha(
    solutions: &[Vec<ExecutionPlan>],
    scenario: &Scenario,
    alpha: f64,
    pm: &PerfModel,
    requests: usize,
) -> f64 {
    let mut scores: Vec<f64> = solutions
        .iter()
        .map(|p| score_at_alpha(p, scenario, alpha, pm, requests))
        .collect();
    scores.sort_by(|a, b| a.partial_cmp(b).unwrap());
    if scores.is_empty() {
        0.0
    } else {
        scores[scores.len() / 2]
    }
}

/// Saturation multiplier α* of a solution set on a scenario — the
/// **analytic** (simulator-only) estimate, kept for the ablation drivers
/// and examples. The serving figures (12–16) measure saturation through
/// the runtime instead: [`crate::serve::saturation_via_runtime`].
pub fn saturation_of(
    solutions: &[Vec<ExecutionPlan>],
    scenario: &Scenario,
    pm: &PerfModel,
    requests: usize,
) -> Option<f64> {
    metrics::saturation_multiplier(
        |alpha| median_score_at_alpha(solutions, scenario, alpha, pm, requests),
        0.2,
        6.0,
        0.01,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines;

    #[test]
    fn score_increases_with_alpha() {
        // Longer periods (larger alpha) can only help the score.
        let pm = PerfModel::paper_calibrated();
        let scenario = Scenario::from_groups("t", &[vec![0, 6, 8]]);
        let sol = baselines::npu_only(&scenario, &pm, 10);
        let s_tight = score_at_alpha(&sol.plans, &scenario, 0.3, &pm, 15);
        let s_loose = score_at_alpha(&sol.plans, &scenario, 4.0, &pm, 15);
        assert!(s_loose >= s_tight, "{s_loose} < {s_tight}");
        assert!(s_loose > 0.9, "loose score {s_loose}");
    }

    #[test]
    fn saturation_exists_for_relaxed_system() {
        let pm = PerfModel::paper_calibrated();
        let scenario = Scenario::from_groups("t", &[vec![0, 1]]);
        let sol = baselines::npu_only(&scenario, &pm, 10);
        let alpha = saturation_of(&[sol.plans], &scenario, &pm, 15);
        assert!(alpha.is_some());
        assert!(alpha.unwrap() < 6.0);
    }
}
