//! Tables 2–4 and Figure 5 reproduction drivers.

use crate::comm::{self, PiecewiseLinear};
use crate::graph::LayerId;
use crate::models::{model_zoo, SPECS};
use crate::perf::PerfModel;
use crate::{Backend, DataType, ExecConfig, Processor};

/// Table 2 — CPU execution time (ms) across backend × dtype configurations.
/// Returns rows of `(model, [ort32, ort16, xnn32, xnn16, nnapi32, nnapi16])`
/// with `None` for unsupported configs.
pub fn table2_configs(pm: &PerfModel) -> Vec<(String, Vec<Option<f64>>)> {
    let combos = [
        (Backend::OrtCpu, DataType::Fp32),
        (Backend::OrtCpu, DataType::Fp16),
        (Backend::Xnnpack, DataType::Fp32),
        (Backend::Xnnpack, DataType::Fp16),
        (Backend::Nnapi, DataType::Fp32),
        (Backend::Nnapi, DataType::Fp16),
    ];
    model_zoo()
        .iter()
        .map(|net| {
            let row = combos
                .iter()
                .map(|&(b, d)| {
                    let t = pm.model_time(net, ExecConfig::new(Processor::Cpu, b, d));
                    if t.is_finite() { Some(t * 1e3) } else { None }
                })
                .collect();
            (net.name.clone(), row)
        })
        .collect()
}

/// Table 3 — best-config execution time (ms) per processor.
pub fn table3_processors(pm: &PerfModel) -> Vec<(String, [f64; 3])> {
    model_zoo()
        .iter()
        .map(|net| {
            let all: Vec<LayerId> = (0..net.num_layers()).map(LayerId).collect();
            let mut times = [0.0f64; 3];
            for p in Processor::ALL {
                times[p.index()] = pm.best_config_for(net, &all, p).1 * 1e3;
            }
            (net.name.clone(), times)
        })
        .collect()
}

/// Table 4 — measured vs layer-sum-estimated execution time (µs) per
/// processor. Returns `(model, [(measured, estimated); 3])`.
pub fn table4_nonlinearity(pm: &PerfModel) -> Vec<(String, [(f64, f64); 3])> {
    model_zoo()
        .iter()
        .map(|net| {
            let all: Vec<LayerId> = (0..net.num_layers()).map(LayerId).collect();
            let mut rows = [(0.0f64, 0.0f64); 3];
            for p in Processor::ALL {
                let cfg = match p {
                    Processor::Cpu => pm.best_config_for(net, &all, p).0,
                    _ => ExecConfig::new(p, Backend::Qnn, DataType::Fp16),
                };
                let measured = pm.model_time(net, cfg) * 1e6;
                let estimated = pm.layer_sum_estimate(net, cfg) * 1e6;
                rows[p.index()] = (measured, estimated);
            }
            (net.name.clone(), rows)
        })
        .collect()
}

/// Figure 5 — run the RPC microbenchmark on this host, fit the two-segment
/// regression, and return (samples, fit, measured STREAM bandwidth).
pub fn fig5_rpc_regression() -> (Vec<comm::RpcSample>, PiecewiseLinear, f64) {
    let sizes = comm::microbench::default_size_sweep();
    let samples = comm::rpc_microbenchmark(&sizes, 7);
    let fit = PiecewiseLinear::fit(&samples, comm::KNEE_BYTES);
    let bw = comm::stream_bandwidth(32 << 20, 3);
    (samples, fit, bw)
}

/// Pretty-print Table 2 next to the paper's numbers.
pub fn print_table2(pm: &PerfModel) {
    println!("Table 2 — CPU config sweep (ms). Paper values in parentheses.");
    println!(
        "{:<14} {:>9} {:>9} {:>9} {:>9} {:>10} {:>10}",
        "model", "ort/32", "ort/16", "xnn/32", "xnn/16", "nnapi/32", "nnapi/16"
    );
    let rows = table2_configs(pm);
    for (i, (name, row)) in rows.iter().enumerate() {
        let paper = crate::perf::calib::TABLE2_MS[i];
        let cell = |j: usize| match row[j] {
            Some(v) => format!("{v:>6.1}({:.1})", paper[j]),
            None => "      N/A".to_string(),
        };
        println!(
            "{:<14} {} {} {} {} {} {}",
            name, cell(0), cell(1), cell(2), cell(3), cell(4), cell(5)
        );
    }
}

/// Pretty-print Table 3 with winners marked.
pub fn print_table3(pm: &PerfModel) {
    println!("Table 3 — best-config time per processor (ms). Paper in parens.");
    println!("{:<14} {:>14} {:>14} {:>14} {:>7}", "model", "CPU", "GPU", "NPU", "winner");
    for (i, (name, t)) in table3_processors(pm).iter().enumerate() {
        let paper = crate::perf::calib::TABLE3_MS[i];
        let w = (0..3).min_by(|&a, &b| t[a].partial_cmp(&t[b]).unwrap()).unwrap();
        println!(
            "{:<14} {:>7.1}({:>5.1}) {:>7.1}({:>5.1}) {:>7.1}({:>5.1}) {:>7}",
            name, t[0], paper[0], t[1], paper[1], t[2], paper[2],
            Processor::from_index(w).name()
        );
    }
    let _ = SPECS;
}

/// Pretty-print Table 4 ratios.
pub fn print_table4(pm: &PerfModel) {
    println!("Table 4 — measured vs estimated (µs); ratio est/meas. Paper ratio in parens.");
    println!(
        "{:<14} {:>22} {:>22} {:>22}",
        "model", "CPU meas/est(ratio)", "GPU meas/est(ratio)", "NPU meas/est(ratio)"
    );
    for (i, (name, rows)) in table4_nonlinearity(pm).iter().enumerate() {
        let paper = crate::perf::calib::TABLE4_RATIO[i];
        let fmt = |p: usize| {
            let (m, e) = rows[p];
            format!("{:>7.0}/{:>7.0} {:.2}({:.2})", m, e, e / m, paper[p])
        };
        println!("{:<14} {:>20} {:>20} {:>20}", name, fmt(0), fmt(1), fmt(2));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_matches_paper_within_tolerance() {
        let pm = PerfModel::paper_calibrated();
        let rows = table2_configs(&pm);
        assert_eq!(rows.len(), 9);
        for (i, (_name, row)) in rows.iter().enumerate() {
            for (j, cell) in row.iter().enumerate() {
                let paper = crate::perf::calib::TABLE2_MS[i][j];
                match cell {
                    Some(v) => {
                        assert!(!paper.is_nan());
                        // Whole-model config times should track the paper's
                        // table within 35% (fusion bookkeeping shifts a bit).
                        assert!(
                            (v / paper - 1.0).abs() < 0.35,
                            "row {i} col {j}: {v} vs paper {paper}"
                        );
                    }
                    None => assert!(paper.is_nan(), "row {i} col {j} should be N/A"),
                }
            }
        }
    }

    #[test]
    fn table3_winner_pattern_matches_paper() {
        // Paper: NPU wins rows 0-3, 6, 8; GPU wins rows 4, 5, 7.
        let pm = PerfModel::paper_calibrated();
        let rows = table3_processors(&pm);
        let gpu_rows = [4usize, 5, 7];
        for (i, (_n, t)) in rows.iter().enumerate() {
            let w = (0..3).min_by(|&a, &b| t[a].partial_cmp(&t[b]).unwrap()).unwrap();
            if gpu_rows.contains(&i) {
                assert_eq!(w, 1, "row {i} should be GPU-won");
            } else {
                assert_eq!(w, 2, "row {i} should be NPU-won");
            }
        }
    }

    #[test]
    fn table4_ratios_match_paper() {
        let pm = PerfModel::paper_calibrated();
        for (i, (_n, rows)) in table4_nonlinearity(&pm).iter().enumerate() {
            for p in 0..3 {
                let (m, e) = rows[p];
                let ratio = e / m;
                let paper = crate::perf::calib::TABLE4_RATIO[i][p];
                assert!(
                    (ratio / paper - 1.0).abs() < 0.30,
                    "row {i} proc {p}: ratio {ratio} vs paper {paper}"
                );
            }
        }
    }

    #[test]
    fn fig5_fit_has_positive_slopes() {
        let (samples, fit, bw) = fig5_rpc_regression();
        assert!(samples.len() > 10);
        assert!(fit.below_slope > 0.0, "below slope {}", fit.below_slope);
        assert!(fit.above_slope > 0.0, "above slope {}", fit.above_slope);
        assert!(bw > 1e9);
        // Fit quality on its own samples.
        assert!(fit.r_squared(&samples) > 0.8, "r2 {}", fit.r_squared(&samples));
    }

    #[test]
    fn comm_model_fits_from_fig5_bench() {
        let (samples, _fit, bw) = fig5_rpc_regression();
        let m = crate::comm::CommModel::fit(&samples, bw);
        // Fitted model predicts monotone costs.
        assert!(m.transfer_cost(1 << 22, false) > m.transfer_cost(1 << 12, false));
    }
}
