//! Fuzz-corpus serving driver: push a seeded corpus of fuzzed scenarios
//! ([`crate::scenario::fuzz`]) through the warm-deployment runtime and
//! cross-check every measured [`ServeReport`] against its analytic
//! envelope ([`crate::serve::envelope`]).
//!
//! The fan-out reuses the probe fleet's machinery: cases are chunked
//! across scoped threads at a width resolved by
//! [`crate::util::threads::leased_threads`] (the `probe_threads` knob, or
//! a [`CoreBudget`] lease), results land by case index, and every
//! deployment's noise seed derives positionally from `(seed, index, α)` —
//! so the outcome vector is **bit-identical for any thread count or core
//! budget** (determinism contract #6) and replayable from the corpus seed
//! alone (contract #7).

use std::sync::Arc;

use crate::coordinator::OverloadPolicy;
use crate::ga::Genome;
use crate::perf::PerfModel;
use crate::scenario::fuzz::FuzzedScenario;
use crate::serve::envelope::{certificate_corroborated, envelope_for, Envelope};
use crate::serve::{little_inflight_cap, probe_seed, Admission, RuntimeHarness, ServeReport};
use crate::util::rng::Rng;
use crate::util::threads::{leased_threads, CoreBudget};

/// Genome cut probability of the per-case random solution draw.
const FUZZ_CUT_PROB: f64 = 0.3;

/// ρ_max at or below which a case counts as *feasible load* for the
/// [`calibrate_slack`] sweep (comfortably inside the stationary regime,
/// where the Little's-law cap must never engage).
pub const FEASIBLE_RHO: f64 = 0.85;

/// Knobs of the corpus runner.
#[derive(Debug, Clone)]
pub struct FuzzOptions {
    /// Fleet width: concurrent cases (`0` = all cores, clamped to the
    /// corpus size). Scheduling only — outcomes are bit-identical for any
    /// value.
    pub probe_threads: usize,
    /// Shared core budget: when set, the fleet width is leased from it
    /// instead of `probe_threads` (scheduling only, like the probe fleet).
    pub core_budget: Option<CoreBudget>,
    /// Check each measured report against its analytic envelope.
    pub envelope: bool,
    /// Base seed of the per-case engine-noise schedule.
    pub seed: u64,
    /// Admission applied to every case's load (the envelope band assumes
    /// [`Admission::Queue`]; capped runs skip the breach check).
    pub admission: Admission,
}

impl Default for FuzzOptions {
    fn default() -> FuzzOptions {
        FuzzOptions {
            probe_threads: 0,
            core_budget: None,
            envelope: true,
            seed: 23,
            admission: Admission::Queue,
        }
    }
}

/// Outcome of one corpus case.
#[derive(Debug, Clone)]
pub struct FuzzCaseOutcome {
    /// Case position in the corpus.
    pub index: usize,
    /// The case's derived seed (replay anchor).
    pub seed: u64,
    /// Scenario name.
    pub name: String,
    /// Model-group count.
    pub groups: usize,
    /// The case's analytic envelope.
    pub envelope: Envelope,
    /// The certificate fired (ρ > 1 from long-run mean rates).
    pub certified_infeasible: bool,
    /// The certificate fired but its rates are contradicted by the
    /// generated arrival schedule — a queueing-model bug
    /// ([`certificate_corroborated`]).
    pub false_certificate: bool,
    /// Envelope breach, if the measured report landed outside its band.
    pub breach: Option<String>,
    /// FNV-1a hash of the report's deterministic fields ([`report_hash`]).
    pub report_hash: u64,
    /// The measured report.
    pub report: ServeReport,
}

/// FNV-1a over the deterministic fields of a report — every count and
/// every f64 bit that the bit-identity contracts cover (wall time and the
/// wall-measured `mem` block stay out). Golden values of this hash anchor
/// the committed fixture corpus.
pub fn report_hash(report: &ServeReport) -> u64 {
    let mut hash = 0xcbf2_9ce4_8422_2325u64;
    let mut put = |value: u64| {
        for byte in value.to_le_bytes() {
            hash ^= byte as u64;
            hash = hash.wrapping_mul(0x100_0000_01b3);
        }
    };
    put(report.submitted as u64);
    put(report.served as u64);
    put(report.dropped as u64);
    put(report.unfinished as u64);
    put(report.violations as u64);
    put(report.retries);
    put(report.remaps);
    put(report.fault_shed as u64);
    put(report.score.to_bits());
    put(report.attainment.to_bits());
    put(report.degraded_time.to_bits());
    for group in &report.group_makespans {
        put(group.len() as u64);
        for makespan in group {
            put(makespan.to_bits());
        }
    }
    if let Some(rho) = report.rho {
        for r in rho {
            put(r.to_bits());
        }
    }
    hash
}

/// Run one case: draw its solution genome from the case seed, deploy,
/// serve the fuzzed load, and envelope-check the measured report.
fn run_case(
    index: usize,
    case: &FuzzedScenario,
    perf: &Arc<PerfModel>,
    opts: &FuzzOptions,
) -> FuzzCaseOutcome {
    let mut rng = Rng::seed_from_u64(case.seed ^ 0xA55A_5AA5_A55A_5AA5);
    let genome = Genome::random(&case.scenario.networks, FUZZ_CUT_PROB, &mut rng);
    let noise_seed = probe_seed(opts.seed, index, case.alpha);
    let harness = RuntimeHarness::for_genome(&case.scenario, &genome, perf, noise_seed);

    let envelope = envelope_for(&harness.solutions, &harness.groups, &case.spec, perf)
        .expect("fuzzer corpora validate by construction");

    let spec = match opts.admission {
        Admission::Queue => case.spec.clone(),
        Admission::LittleCap { slack } => {
            let cap = little_inflight_cap(
                &harness.solutions,
                &harness.groups,
                &case.spec.mean_rates(),
                perf,
                slack,
            );
            case.spec.clone().with_policy(OverloadPolicy::DropAfter { max_inflight: cap })
        }
    };
    let report = harness.run(&spec);

    let queue_admission = matches!(opts.admission, Admission::Queue);
    let breach = if opts.envelope && queue_admission {
        envelope.check(&report).err().map(|b| b.to_string())
    } else {
        None
    };
    let certified_infeasible = envelope.certified_infeasible;
    let false_certificate = certified_infeasible && !certificate_corroborated(&case.spec);

    FuzzCaseOutcome {
        index,
        seed: case.seed,
        name: case.scenario.name.clone(),
        groups: case.scenario.groups.len(),
        envelope,
        certified_infeasible,
        false_certificate,
        breach,
        report_hash: report_hash(&report),
        report,
    }
}

/// Run a whole corpus through the fleet. Outcomes are ordered by case
/// index and bit-identical for any `probe_threads` / core budget.
pub fn run_fuzz_corpus(
    corpus: &[FuzzedScenario],
    perf: &Arc<PerfModel>,
    opts: &FuzzOptions,
) -> Vec<FuzzCaseOutcome> {
    let jobs = corpus.len();
    if jobs == 0 {
        return Vec::new();
    }
    let (threads, _lease) = leased_threads(opts.core_budget.as_ref(), opts.probe_threads, jobs);
    let mut results: Vec<Option<FuzzCaseOutcome>> = (0..jobs).map(|_| None).collect();
    if threads <= 1 {
        for (i, case) in corpus.iter().enumerate() {
            results[i] = Some(run_case(i, case, perf, opts));
        }
    } else {
        let chunk = jobs.div_ceil(threads);
        std::thread::scope(|scope| {
            for (chunk_idx, (cases, slots)) in
                corpus.chunks(chunk).zip(results.chunks_mut(chunk)).enumerate()
            {
                let base = chunk_idx * chunk;
                scope.spawn(move || {
                    for (j, (case, slot)) in cases.iter().zip(slots.iter_mut()).enumerate() {
                        *slot = Some(run_case(base + j, case, perf, opts));
                    }
                });
            }
        });
    }
    results.into_iter().map(|r| r.expect("every case ran")).collect()
}

/// One row of the [`calibrate_slack`] sweep.
#[derive(Debug, Clone, Copy)]
pub struct SlackSweepRow {
    /// The [`Admission::LittleCap`] slack swept.
    pub slack: f64,
    /// Corpus cases at feasible load (ρ_max ≤ [`FEASIBLE_RHO`]).
    pub feasible_cases: usize,
    /// Requests dropped across those feasible cases — the calibration
    /// target is the smallest slack where this is zero.
    pub feasible_drops: usize,
    /// Requests dropped across the whole corpus (overload cases included;
    /// informational — dropping there is the cap doing its job).
    pub total_drops: usize,
}

/// Sweep [`Admission::LittleCap`] slacks over a corpus: for each slack,
/// run every case under the cap and count drops at feasible load. The
/// calibrated `DEFAULT_SLACK` is the smallest swept slack whose
/// `feasible_drops` is zero (pinned by a regression test).
pub fn calibrate_slack(
    corpus: &[FuzzedScenario],
    perf: &Arc<PerfModel>,
    opts: &FuzzOptions,
    slacks: &[f64],
) -> Vec<SlackSweepRow> {
    slacks
        .iter()
        .map(|&slack| {
            let capped = FuzzOptions {
                admission: Admission::LittleCap { slack },
                envelope: false,
                ..opts.clone()
            };
            let outcomes = run_fuzz_corpus(corpus, perf, &capped);
            let mut row = SlackSweepRow {
                slack,
                feasible_cases: 0,
                feasible_drops: 0,
                total_drops: 0,
            };
            for outcome in &outcomes {
                row.total_drops += outcome.report.dropped;
                if outcome.envelope.rho_max <= FEASIBLE_RHO {
                    row.feasible_cases += 1;
                    row.feasible_drops += outcome.report.dropped;
                }
            }
            row
        })
        .collect()
}
