//! Genetic-algorithm optimizer of the Static Analyzer (paper §4.2–4.3).
//!
//! Three chromosome types are explored simultaneously (Fig 6):
//!
//! * **partition** — per network, one bit per edge (cut / keep);
//! * **mapping** — per network, one processor preference per layer, resolved
//!   to subgraph processors by majority vote;
//! * **priority** — a permutation over networks giving dispatch precedence.
//!
//! Operators (Fig 8): one-point crossover on partition and mapping, Uniform
//! Partially-Matched Crossover (UPMX) on priority, bit/gene mutation, two
//! local-search moves (merge neighbouring subgraphs; reposition adjacent
//! layers), NSGA-III replacement, and a stop rule of 3 generations without
//! average-score improvement. All parents reproduce (no elite selection) to
//! avoid premature convergence, as in the paper.

mod chromosome;
mod local_search;
mod nsga3;
mod operators;

pub use chromosome::{
    decode, decode_network, decode_with, DecodeScratch, DecodedPlanCache, Genome, NetworkGenes,
    PlanSet,
};
pub use local_search::{
    debug_check, merge_neighbors, merge_neighbors_into, reposition_adjacent,
    reposition_adjacent_into,
};
pub use nsga3::{
    fast_non_dominated_sort, nsga3_select, reference_points, Dominance, SelectionWorkspace,
};
pub use operators::{
    breed_pair, breed_pair_into, breed_pair_with, mutate, one_point_crossover,
    one_point_crossover_with, upmx, upmx_with, MutationRates, UpmxScratch,
};
