//! Chromosome encoding and decoding (paper §4.2, Figs 6–7), plus the
//! genome-fingerprint decode memo ([`DecodedPlanCache`]) that lets
//! re-evaluated survivors — elites carried across generations, local-search
//! revisits, measurement-tier repetitions — skip partitioning and profiling
//! entirely.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::util::rng::Rng;
use crate::comm::CommModel;
use crate::graph::{
    fnv1a, fnv1a_u64, partition, Network, Partition, PartitionWorkspace, FNV_OFFSET,
};
use crate::profiler::{ProbeScratch, Profiler};
use crate::sim::{compile_plans, CompiledPlan, ExecutionPlan, PlannedTask, PlannedTransfer};
use crate::{DataType, Processor};

/// Genes for one network: the partition bit-vector (one per edge) and the
/// mapping vector (one processor per layer).
///
/// `Clone` is implemented by hand so that `clone_from` reuses the target's
/// buffers — local-search candidate generation clones a genome per attempted
/// move, and with `clone_from` into a per-thread scratch genome those
/// attempts stop allocating.
#[derive(Debug, PartialEq)]
pub struct NetworkGenes {
    pub cuts: Vec<bool>,
    pub mapping: Vec<Processor>,
}

impl Clone for NetworkGenes {
    fn clone(&self) -> NetworkGenes {
        NetworkGenes { cuts: self.cuts.clone(), mapping: self.mapping.clone() }
    }

    fn clone_from(&mut self, source: &NetworkGenes) {
        self.cuts.clone_from(&source.cuts);
        self.mapping.clone_from(&source.mapping);
    }
}

impl NetworkGenes {
    /// Random genes for a network: each edge cut with probability
    /// `cut_prob`, each layer mapped uniformly.
    pub fn random(net: &Network, cut_prob: f64, rng: &mut Rng) -> NetworkGenes {
        NetworkGenes {
            cuts: (0..net.num_edges()).map(|_| rng.gen_bool(cut_prob)).collect(),
            mapping: (0..net.num_layers())
                .map(|_| Processor::from_index(rng.gen_range(0, 3)))
                .collect(),
        }
    }

    /// Uncut genes pinned to one processor (seeds; also the baselines'
    /// representation).
    pub fn whole_on(net: &Network, p: Processor) -> NetworkGenes {
        NetworkGenes {
            cuts: vec![false; net.num_edges()],
            mapping: vec![p; net.num_layers()],
        }
    }
}

/// A complete GA individual: per-network genes + the priority permutation.
///
/// `Clone` is hand-written for a buffer-reusing `clone_from` (see
/// [`NetworkGenes`]); `Default` is the empty genome, useful as the initial
/// state of a reusable clone-target scratch.
#[derive(Debug, Default, PartialEq)]
pub struct Genome {
    pub networks: Vec<NetworkGenes>,
    /// `priority[i]` = dispatch precedence of network `i` (0 = highest).
    pub priority: Vec<usize>,
}

impl Clone for Genome {
    fn clone(&self) -> Genome {
        Genome { networks: self.networks.clone(), priority: self.priority.clone() }
    }

    fn clone_from(&mut self, source: &Genome) {
        // Vec::clone_from reuses capacity and calls clone_from element-wise,
        // which NetworkGenes implements buffer-reusingly.
        self.networks.clone_from(&source.networks);
        self.priority.clone_from(&source.priority);
    }
}

impl Genome {
    pub fn random(nets: &[Network], cut_prob: f64, rng: &mut Rng) -> Genome {
        let mut priority: Vec<usize> = (0..nets.len()).collect();
        // Fisher–Yates.
        for i in (1..priority.len()).rev() {
            let j = rng.gen_range_inclusive(0, i);
            priority.swap(i, j);
        }
        Genome {
            networks: nets.iter().map(|n| NetworkGenes::random(n, cut_prob, rng)).collect(),
            priority,
        }
    }

    /// Seed individual: every network whole on a single processor.
    pub fn all_on(nets: &[Network], p: Processor) -> Genome {
        Genome {
            networks: nets.iter().map(|n| NetworkGenes::whole_on(n, p)).collect(),
            priority: (0..nets.len()).collect(),
        }
    }

    /// Structural 64-bit fingerprint of the full chromosome (FNV-1a over
    /// cuts, mapping, and priority — the same hash family as the profile
    /// DB's Merkle keys). Used as the [`DecodedPlanCache`] index; collisions
    /// are disambiguated by full [`PartialEq`] comparison, so a collision
    /// costs a decode, never a wrong plan.
    pub fn fingerprint(&self) -> u64 {
        let mut h = FNV_OFFSET;
        for genes in &self.networks {
            h = fnv1a_u64(genes.cuts.len() as u64, h);
            for &cut in &genes.cuts {
                h = fnv1a(&[cut as u8], h);
            }
            h = fnv1a_u64(genes.mapping.len() as u64, h);
            for &p in &genes.mapping {
                h = fnv1a(&[p.index() as u8], h);
            }
        }
        for &p in &self.priority {
            h = fnv1a_u64(p as u64, h);
        }
        h
    }

    /// Validity: gene lengths match, priority is a permutation.
    pub fn is_valid(&self, nets: &[Network]) -> bool {
        if self.networks.len() != nets.len() || self.priority.len() != nets.len() {
            return false;
        }
        let mut seen = vec![false; nets.len()];
        for &p in &self.priority {
            if p >= nets.len() || seen[p] {
                return false;
            }
            seen[p] = true;
        }
        self.networks
            .iter()
            .zip(nets)
            .all(|(g, n)| g.cuts.len() == n.num_edges() && g.mapping.len() == n.num_layers())
    }
}

/// Decode one network's genes into a [`Partition`].
pub fn decode_network(net: &Network, genes: &NetworkGenes) -> Partition {
    partition(net, &genes.cuts, &genes.mapping)
}

/// Reusable first-touch decode scratch: the partitioning arenas plus the
/// profiler probing buffers. One per evaluator thread; with it, a memo-miss
/// decode allocates only for its *output* (the plan vectors the memo then
/// owns) — every transient of partitioning, hashing, and config probing
/// lives here.
#[derive(Default)]
pub struct DecodeScratch {
    pub partition: PartitionWorkspace,
    pub probe: ProbeScratch,
}

impl DecodeScratch {
    pub fn new() -> DecodeScratch {
        DecodeScratch::default()
    }
}

/// Decode a genome into simulator-ready [`ExecutionPlan`]s, profiling each
/// subgraph at its mapped processor's best (backend, dtype) via the
/// device-in-the-loop profiler. Transfer bytes use the producing subgraph's
/// chosen dtype (fp16 default for tensors in flight). Partitioning and
/// probing scratch comes from `scratch`; only the returned plans allocate.
pub fn decode_with(
    nets: &[Network],
    genome: &Genome,
    profiler: &Profiler<'_>,
    _comm: &CommModel,
    scratch: &mut DecodeScratch,
) -> Vec<ExecutionPlan> {
    nets.iter()
        .zip(&genome.networks)
        .enumerate()
        .map(|(i, (net, genes))| {
            scratch.partition.partition_into(net, &genes.cuts, &genes.mapping);
            let n_sg = scratch.partition.num_subgraphs();
            let mut tasks: Vec<PlannedTask> = Vec::with_capacity(n_sg);
            for s in 0..n_sg {
                let proc = scratch.partition.subgraph_processor(s);
                let (_cfg, t) = profiler.best_on_layers(
                    net,
                    scratch.partition.subgraph_layers(s),
                    proc,
                    &mut scratch.probe,
                );
                tasks.push(PlannedTask { duration: t, processor: proc });
            }
            // Cross-subgraph transfers from cut edges; bytes at fp16 (the
            // in-flight representation of activations on the device).
            let mut transfers = Vec::new();
            for &e in scratch.partition.cut_edges() {
                let edge = net.edge(e);
                let from = scratch.partition.owner_of(edge.src);
                let to = scratch.partition.owner_of(edge.dst);
                if from != to {
                    transfers.push(PlannedTransfer {
                        from: from.0,
                        to: to.0,
                        bytes: net.layer(edge.src).out_bytes(DataType::Fp16),
                    });
                }
            }
            ExecutionPlan { tasks, transfers, priority: genome.priority[i] }
        })
        .collect()
}

/// [`decode_with`] through a throwaway [`DecodeScratch`] — the convenience
/// path for tests, benches, and one-off decodes.
pub fn decode(
    nets: &[Network],
    genome: &Genome,
    profiler: &Profiler<'_>,
    comm: &CommModel,
) -> Vec<ExecutionPlan> {
    decode_with(nets, genome, profiler, comm, &mut DecodeScratch::new())
}

/// A decoded genome ready for simulation: the executable plans plus their
/// one-time structural compilation (CSR dependency metadata). Shared via
/// `Arc` so survivors re-evaluated across generations, local-search
/// revisits, and the measurement tier's noisy repetitions all reuse one
/// decode + compile.
#[derive(Debug)]
pub struct PlanSet {
    pub plans: Vec<ExecutionPlan>,
    pub compiled: Vec<CompiledPlan>,
}

struct CacheEntry {
    genome: Genome,
    set: Arc<PlanSet>,
}

/// Genome-fingerprint → decoded-plan memo, the decode-level sibling of the
/// profiler's merkle cache: where the profile DB dedups *subgraph
/// measurements* across genomes, this dedups whole *decodes* across
/// re-evaluations of the same genome (elites, crossover clones,
/// measure-tier reps). Thread-safe: the batch evaluator's worker threads
/// share one cache. Values are pure functions of the genome (the profiler
/// probe is deterministic), so concurrent misses on the same genome insert
/// identical plans and determinism is preserved regardless of interleaving.
pub struct DecodedPlanCache {
    /// fingerprint → entries (a bucket list disambiguates hash collisions
    /// by full genome equality).
    map: RwLock<HashMap<u64, Vec<CacheEntry>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl Default for DecodedPlanCache {
    fn default() -> Self {
        Self::new()
    }
}

impl DecodedPlanCache {
    /// Soft cap on memoized genomes; beyond it new decodes are returned
    /// uncached (a search rarely exceeds a few thousand distinct genomes).
    const MAX_GENOMES: usize = 1 << 15;

    pub fn new() -> DecodedPlanCache {
        DecodedPlanCache {
            map: RwLock::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Decode a genome, reusing the memoized plan set when this exact genome
    /// has been decoded before. The **hit path performs zero heap
    /// allocation** (fingerprint, bucket probe, `Arc` bump — asserted in
    /// `rust/tests/batch_eval.rs`); a miss decodes through `scratch` so its
    /// only allocations are the memoized output itself.
    pub fn decode_scratch(
        &self,
        nets: &[Network],
        genome: &Genome,
        profiler: &Profiler<'_>,
        comm: &CommModel,
        scratch: &mut DecodeScratch,
    ) -> Arc<PlanSet> {
        let fp = genome.fingerprint();
        {
            let map = self.map.read().unwrap();
            if let Some(bucket) = map.get(&fp) {
                if let Some(entry) = bucket.iter().find(|e| &e.genome == genome) {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return entry.set.clone();
                }
            }
        }
        let plans = decode_with(nets, genome, profiler, comm, scratch);
        let compiled = compile_plans(&plans);
        let set = Arc::new(PlanSet { plans, compiled });
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut map = self.map.write().unwrap();
        if map.len() < Self::MAX_GENOMES {
            let bucket = map.entry(fp).or_default();
            // Another thread may have raced the same genome in; both decoded
            // identical values, keep one.
            if !bucket.iter().any(|e| e.genome == *genome) {
                bucket.push(CacheEntry { genome: genome.clone(), set: set.clone() });
            }
        }
        set
    }

    /// [`Self::decode_scratch`] with a throwaway scratch (tests, benches).
    pub fn decode(
        &self,
        nets: &[Network],
        genome: &Genome,
        profiler: &Profiler<'_>,
        comm: &CommModel,
    ) -> Arc<PlanSet> {
        self.decode_scratch(nets, genome, profiler, comm, &mut DecodeScratch::new())
    }

    /// (memo hits, decode misses).
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Number of distinct fingerprints memoized.
    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::build_model;
    use crate::perf::PerfModel;
    
    fn nets() -> Vec<Network> {
        vec![build_model(0, 0), build_model(1, 2)]
    }

    #[test]
    fn random_genomes_are_valid() {
        let nets = nets();
        let mut rng = Rng::seed_from_u64(1);
        for _ in 0..50 {
            let g = Genome::random(&nets, 0.2, &mut rng);
            assert!(g.is_valid(&nets));
        }
    }

    #[test]
    fn all_on_is_single_subgraph_each() {
        let nets = nets();
        let g = Genome::all_on(&nets, Processor::Npu);
        for (net, genes) in nets.iter().zip(&g.networks) {
            let p = decode_network(net, genes);
            assert_eq!(p.num_subgraphs(), 1);
            assert_eq!(p.subgraphs[0].processor, Processor::Npu);
        }
    }

    #[test]
    fn decode_produces_acyclic_plans() {
        // The transfer graph must be a DAG (the convexity repair in
        // `partition` guarantees it) with positive finite durations.
        let nets = nets();
        let pm = PerfModel::paper_calibrated();
        let prof = Profiler::new(&pm);
        let comm = CommModel::paper_calibrated();
        let mut rng = Rng::seed_from_u64(5);
        for _ in 0..20 {
            let g = Genome::random(&nets, 0.4, &mut rng);
            let plans = decode(&nets, &g, &prof, &comm);
            for plan in &plans {
                // Kahn: all tasks must drain if acyclic.
                let n = plan.tasks.len();
                let mut indeg = vec![0usize; n];
                for tr in &plan.transfers {
                    assert!(tr.bytes > 0);
                    indeg[tr.to] += 1;
                }
                let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
                let mut seen = 0;
                while let Some(i) = ready.pop() {
                    seen += 1;
                    for tr in plan.transfers.iter().filter(|t| t.from == i) {
                        indeg[tr.to] -= 1;
                        if indeg[tr.to] == 0 {
                            ready.push(tr.to);
                        }
                    }
                }
                assert_eq!(seen, n, "cyclic transfer graph");
                for t in &plan.tasks {
                    assert!(t.duration.is_finite() && t.duration > 0.0);
                }
            }
        }
    }

    #[test]
    fn fingerprint_tracks_genome_content() {
        let nets = nets();
        let mut rng = Rng::seed_from_u64(9);
        let g = Genome::random(&nets, 0.3, &mut rng);
        assert_eq!(g.fingerprint(), g.clone().fingerprint(), "fingerprint not pure");
        let mut h = g.clone();
        h.priority.swap(0, 1);
        assert_ne!(g.fingerprint(), h.fingerprint(), "priority ignored");
        let mut k = g.clone();
        k.networks[0].cuts[0] = !k.networks[0].cuts[0];
        assert_ne!(g.fingerprint(), k.fingerprint(), "cuts ignored");
        let mut m = g.clone();
        m.networks[1].mapping[0] = match m.networks[1].mapping[0] {
            Processor::Cpu => Processor::Gpu,
            _ => Processor::Cpu,
        };
        assert_ne!(g.fingerprint(), m.fingerprint(), "mapping ignored");
    }

    #[test]
    fn decoded_plan_cache_memoizes() {
        let nets = nets();
        let pm = PerfModel::paper_calibrated();
        let prof = Profiler::new(&pm);
        let comm = CommModel::paper_calibrated();
        let mut rng = Rng::seed_from_u64(11);
        let g = Genome::random(&nets, 0.3, &mut rng);
        let cache = DecodedPlanCache::new();
        let a = cache.decode(&nets, &g, &prof, &comm);
        let b = cache.decode(&nets, &g, &prof, &comm);
        assert!(std::sync::Arc::ptr_eq(&a, &b), "second decode must be a memo hit");
        assert_eq!(cache.stats(), (1, 1));
        // Memoized plans equal a fresh decode exactly.
        let fresh = decode(&nets, &g, &prof, &comm);
        assert_eq!(a.plans, fresh);
        assert_eq!(a.compiled.len(), fresh.len());
        // A different genome is a distinct entry.
        let g2 = Genome::random(&nets, 0.3, &mut rng);
        let _ = cache.decode(&nets, &g2, &prof, &comm);
        assert_eq!(cache.stats(), (1, 2));
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn invalid_priority_detected() {
        let nets = nets();
        let mut g = Genome::all_on(&nets, Processor::Cpu);
        g.priority = vec![0, 0];
        assert!(!g.is_valid(&nets));
        g.priority = vec![0, 5];
        assert!(!g.is_valid(&nets));
    }
}
