//! Chromosome encoding and decoding (paper §4.2, Figs 6–7).

use crate::util::rng::Rng;
use crate::comm::CommModel;
use crate::graph::{partition, Network, Partition};
use crate::profiler::Profiler;
use crate::sim::{ExecutionPlan, PlannedTask, PlannedTransfer};
use crate::{DataType, Processor};

/// Genes for one network: the partition bit-vector (one per edge) and the
/// mapping vector (one processor per layer).
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkGenes {
    pub cuts: Vec<bool>,
    pub mapping: Vec<Processor>,
}

impl NetworkGenes {
    /// Random genes for a network: each edge cut with probability
    /// `cut_prob`, each layer mapped uniformly.
    pub fn random(net: &Network, cut_prob: f64, rng: &mut Rng) -> NetworkGenes {
        NetworkGenes {
            cuts: (0..net.num_edges()).map(|_| rng.gen_bool(cut_prob)).collect(),
            mapping: (0..net.num_layers())
                .map(|_| Processor::from_index(rng.gen_range(0, 3)))
                .collect(),
        }
    }

    /// Uncut genes pinned to one processor (seeds; also the baselines'
    /// representation).
    pub fn whole_on(net: &Network, p: Processor) -> NetworkGenes {
        NetworkGenes {
            cuts: vec![false; net.num_edges()],
            mapping: vec![p; net.num_layers()],
        }
    }
}

/// A complete GA individual: per-network genes + the priority permutation.
#[derive(Debug, Clone, PartialEq)]
pub struct Genome {
    pub networks: Vec<NetworkGenes>,
    /// `priority[i]` = dispatch precedence of network `i` (0 = highest).
    pub priority: Vec<usize>,
}

impl Genome {
    pub fn random(nets: &[Network], cut_prob: f64, rng: &mut Rng) -> Genome {
        let mut priority: Vec<usize> = (0..nets.len()).collect();
        // Fisher–Yates.
        for i in (1..priority.len()).rev() {
            let j = rng.gen_range_inclusive(0, i);
            priority.swap(i, j);
        }
        Genome {
            networks: nets.iter().map(|n| NetworkGenes::random(n, cut_prob, rng)).collect(),
            priority,
        }
    }

    /// Seed individual: every network whole on a single processor.
    pub fn all_on(nets: &[Network], p: Processor) -> Genome {
        Genome {
            networks: nets.iter().map(|n| NetworkGenes::whole_on(n, p)).collect(),
            priority: (0..nets.len()).collect(),
        }
    }

    /// Validity: gene lengths match, priority is a permutation.
    pub fn is_valid(&self, nets: &[Network]) -> bool {
        if self.networks.len() != nets.len() || self.priority.len() != nets.len() {
            return false;
        }
        let mut seen = vec![false; nets.len()];
        for &p in &self.priority {
            if p >= nets.len() || seen[p] {
                return false;
            }
            seen[p] = true;
        }
        self.networks
            .iter()
            .zip(nets)
            .all(|(g, n)| g.cuts.len() == n.num_edges() && g.mapping.len() == n.num_layers())
    }
}

/// Decode one network's genes into a [`Partition`].
pub fn decode_network(net: &Network, genes: &NetworkGenes) -> Partition {
    partition(net, &genes.cuts, &genes.mapping)
}

/// Decode a genome into simulator-ready [`ExecutionPlan`]s, profiling each
/// subgraph at its mapped processor's best (backend, dtype) via the
/// device-in-the-loop profiler. Transfer bytes use the producing subgraph's
/// chosen dtype (fp16 default for tensors in flight).
pub fn decode(
    nets: &[Network],
    genome: &Genome,
    profiler: &Profiler<'_>,
    _comm: &CommModel,
) -> Vec<ExecutionPlan> {
    nets.iter()
        .zip(&genome.networks)
        .enumerate()
        .map(|(i, (net, genes))| {
            let part = decode_network(net, genes);
            let tasks: Vec<PlannedTask> = part
                .subgraphs
                .iter()
                .map(|sg| {
                    let (_cfg, t) = profiler.profile_best(net, sg);
                    PlannedTask { duration: t, processor: sg.processor }
                })
                .collect();
            // Cross-subgraph transfers from cut edges; bytes at fp16 (the
            // in-flight representation of activations on the device).
            let mut transfers = Vec::new();
            for &e in &part.cut_edges {
                let edge = net.edge(e);
                let from = part.owner_of(edge.src);
                let to = part.owner_of(edge.dst);
                if from != to {
                    transfers.push(PlannedTransfer {
                        from: from.0,
                        to: to.0,
                        bytes: net.layer(edge.src).out_bytes(DataType::Fp16),
                    });
                }
            }
            ExecutionPlan { tasks, transfers, priority: genome.priority[i] }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::build_model;
    use crate::perf::PerfModel;
    
    fn nets() -> Vec<Network> {
        vec![build_model(0, 0), build_model(1, 2)]
    }

    #[test]
    fn random_genomes_are_valid() {
        let nets = nets();
        let mut rng = Rng::seed_from_u64(1);
        for _ in 0..50 {
            let g = Genome::random(&nets, 0.2, &mut rng);
            assert!(g.is_valid(&nets));
        }
    }

    #[test]
    fn all_on_is_single_subgraph_each() {
        let nets = nets();
        let g = Genome::all_on(&nets, Processor::Npu);
        for (net, genes) in nets.iter().zip(&g.networks) {
            let p = decode_network(net, genes);
            assert_eq!(p.num_subgraphs(), 1);
            assert_eq!(p.subgraphs[0].processor, Processor::Npu);
        }
    }

    #[test]
    fn decode_produces_acyclic_plans() {
        // The transfer graph must be a DAG (the convexity repair in
        // `partition` guarantees it) with positive finite durations.
        let nets = nets();
        let pm = PerfModel::paper_calibrated();
        let prof = Profiler::new(&pm);
        let comm = CommModel::paper_calibrated();
        let mut rng = Rng::seed_from_u64(5);
        for _ in 0..20 {
            let g = Genome::random(&nets, 0.4, &mut rng);
            let plans = decode(&nets, &g, &prof, &comm);
            for plan in &plans {
                // Kahn: all tasks must drain if acyclic.
                let n = plan.tasks.len();
                let mut indeg = vec![0usize; n];
                for tr in &plan.transfers {
                    assert!(tr.bytes > 0);
                    indeg[tr.to] += 1;
                }
                let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
                let mut seen = 0;
                while let Some(i) = ready.pop() {
                    seen += 1;
                    for tr in plan.transfers.iter().filter(|t| t.from == i) {
                        indeg[tr.to] -= 1;
                        if indeg[tr.to] == 0 {
                            ready.push(tr.to);
                        }
                    }
                }
                assert_eq!(seen, n, "cyclic transfer graph");
                for t in &plan.tasks {
                    assert!(t.duration.is_finite() && t.duration > 0.0);
                }
            }
        }
    }

    #[test]
    fn invalid_priority_detected() {
        let nets = nets();
        let mut g = Genome::all_on(&nets, Processor::Cpu);
        g.priority = vec![0, 0];
        assert!(!g.is_valid(&nets));
        g.priority = vec![0, 5];
        assert!(!g.is_valid(&nets));
    }
}
