//! Local-search moves (paper §4.3): applied with some probability to newly
//! generated individuals, accepted only if they improve *all* objectives.
//!
//! 1. **Merge neighbouring subgraphs** — pick a cut edge, uncut it (the two
//!    subgraphs compile together, regaining fusion).
//! 2. **Reposition adjacent layers** — move a layer at a subgraph boundary
//!    across it: flip the boundary edge's cut state pattern so the layer
//!    changes sides, and adopt the neighbour subgraph's processor
//!    preference for that layer.


use crate::util::rng::Rng;
use super::chromosome::{decode_network, Genome};
use crate::graph::Network;

/// Number of cut edges across the whole genome.
fn count_cut(genome: &Genome) -> usize {
    genome
        .networks
        .iter()
        .map(|g| g.cuts.iter().filter(|&&c| c).count())
        .sum()
}

/// The `k`-th cut edge in (network, edge-index) scan order.
fn nth_cut(genome: &Genome, mut k: usize) -> (usize, usize) {
    for (n, genes) in genome.networks.iter().enumerate() {
        for (e, &cut) in genes.cuts.iter().enumerate() {
            if cut {
                if k == 0 {
                    return (n, e);
                }
                k -= 1;
            }
        }
    }
    unreachable!("nth_cut called with k >= count_cut")
}

/// Merge move into a reusable child buffer: uncut one randomly chosen cut
/// edge. Returns false (child untouched, no RNG draw) when nothing is cut.
/// `clone_from` reuses the child's buffers, so a warmed child makes this
/// move allocation-free — the local-search tier attempts two moves per
/// candidate, almost all rejected, and the seed cloned a fresh genome for
/// every attempt.
pub fn merge_neighbors_into(genome: &Genome, child: &mut Genome, rng: &mut Rng) -> bool {
    let total = count_cut(genome);
    if total == 0 {
        return false;
    }
    let (n, e) = nth_cut(genome, rng.gen_range(0, total));
    child.clone_from(genome);
    child.networks[n].cuts[e] = false;
    true
}

/// Merge move: uncut one randomly chosen cut edge. Returns the mutated
/// clone, or `None` if nothing is cut.
pub fn merge_neighbors(genome: &Genome, rng: &mut Rng) -> Option<Genome> {
    let mut child = Genome::default();
    merge_neighbors_into(genome, &mut child, rng).then_some(child)
}

/// Reposition move into a reusable child buffer (see
/// [`reposition_adjacent`] for the move semantics). Returns false (no RNG
/// draw) when nothing is cut.
pub fn reposition_adjacent_into(
    nets: &[Network],
    genome: &Genome,
    child: &mut Genome,
    rng: &mut Rng,
) -> bool {
    let total = count_cut(genome);
    if total == 0 {
        return false;
    }
    let (n, e) = nth_cut(genome, rng.gen_range(0, total));
    let net = &nets[n];
    let edge = net.edge(crate::graph::EdgeId(e));
    child.clone_from(genome);
    let genes = &mut child.networks[n];

    if rng.gen_bool(0.5) {
        // Pull dst back: attach dst to src's subgraph, detach it from its
        // current one by cutting dst's other incident edges.
        genes.cuts[e] = false;
        for eid in net.incident_edges(edge.dst) {
            if eid.0 != e {
                genes.cuts[eid.0] = true;
            }
        }
        genes.mapping[edge.dst.0] = genes.mapping[edge.src.0];
    } else {
        // Push src forward: attach src to dst's subgraph.
        genes.cuts[e] = false;
        for eid in net.incident_edges(edge.src) {
            if eid.0 != e {
                genes.cuts[eid.0] = true;
            }
        }
        genes.mapping[edge.src.0] = genes.mapping[edge.dst.0];
    }
    true
}

/// Reposition move: pick a cut edge `src -> dst`; pull `dst`'s layer into
/// `src`'s side by uncutting that edge and cutting `dst`'s outgoing edges
/// instead (or symmetrically push `src` forward). The moved layer adopts
/// the processor preference of the side it joins, so the majority vote
/// follows the move.
pub fn reposition_adjacent(nets: &[Network], genome: &Genome, rng: &mut Rng) -> Option<Genome> {
    let mut child = Genome::default();
    reposition_adjacent_into(nets, genome, &mut child, rng).then_some(child)
}

/// Sanity helper used by the analyzer: a local-search child must still
/// decode (always true by construction, asserted in debug builds).
pub fn debug_check(nets: &[Network], genome: &Genome) {
    debug_assert!(genome.is_valid(nets));
    if cfg!(debug_assertions) {
        for (net, genes) in nets.iter().zip(&genome.networks) {
            let p = decode_network(net, genes);
            debug_assert!(p.num_subgraphs() >= 1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ga::chromosome::decode_network;
    use crate::models::build_model;
        

    fn nets() -> Vec<Network> {
        vec![build_model(0, 4), build_model(1, 6)]
    }

    #[test]
    fn merge_reduces_subgraph_count_or_keeps() {
        let nets = nets();
        let mut rng = Rng::seed_from_u64(21);
        for _ in 0..50 {
            let g = Genome::random(&nets, 0.5, &mut rng);
            let before: usize = nets
                .iter()
                .zip(&g.networks)
                .map(|(n, ge)| decode_network(n, ge).num_subgraphs())
                .sum();
            if let Some(child) = merge_neighbors(&g, &mut rng) {
                assert!(child.is_valid(&nets));
                let after: usize = nets
                    .iter()
                    .zip(&child.networks)
                    .map(|(n, ge)| decode_network(n, ge).num_subgraphs())
                    .sum();
                assert!(after <= before, "merge grew partition: {before} -> {after}");
            }
        }
    }

    #[test]
    fn merge_none_when_uncut() {
        let nets = nets();
        let g = Genome::all_on(&nets, crate::Processor::Npu);
        let mut rng = Rng::seed_from_u64(1);
        assert!(merge_neighbors(&g, &mut rng).is_none());
    }

    #[test]
    fn into_variants_match_owning_variants() {
        let nets = nets();
        let mut rng = Rng::seed_from_u64(77);
        let mut child = Genome::default();
        for i in 0..50u64 {
            let g = Genome::random(&nets, 0.4, &mut rng);
            let owned = merge_neighbors(&g, &mut Rng::seed_from_u64(i));
            let got = merge_neighbors_into(&g, &mut child, &mut Rng::seed_from_u64(i));
            assert_eq!(owned.is_some(), got);
            if let Some(o) = owned {
                assert_eq!(o, child);
            }
            let seed = i * 31 + 1;
            let owned = reposition_adjacent(&nets, &g, &mut Rng::seed_from_u64(seed));
            let got =
                reposition_adjacent_into(&nets, &g, &mut child, &mut Rng::seed_from_u64(seed));
            assert_eq!(owned.is_some(), got);
            if let Some(o) = owned {
                assert_eq!(o, child);
            }
        }
    }

    #[test]
    fn into_moves_are_allocation_free_when_warm() {
        let nets = nets();
        let mut rng = Rng::seed_from_u64(5);
        let mut g = Genome::random(&nets, 0.5, &mut rng);
        g.networks[0].cuts[0] = true; // ensure at least one move exists
        let mut child = Genome::default();
        child.clone_from(&g); // warm the clone target to the genome's shape
        let before = crate::util::alloc::thread_allocations();
        for _ in 0..20 {
            assert!(merge_neighbors_into(&g, &mut child, &mut rng));
            assert!(reposition_adjacent_into(&nets, &g, &mut child, &mut rng));
        }
        let after = crate::util::alloc::thread_allocations();
        assert_eq!(after - before, 0, "warm local-search moves allocated");
    }

    #[test]
    fn reposition_keeps_validity() {
        let nets = nets();
        let mut rng = Rng::seed_from_u64(33);
        for _ in 0..100 {
            let g = Genome::random(&nets, 0.4, &mut rng);
            if let Some(child) = reposition_adjacent(&nets, &g, &mut rng) {
                assert!(child.is_valid(&nets));
                debug_check(&nets, &child);
            }
        }
    }

    #[test]
    fn reposition_changes_partition() {
        let nets = nets();
        let mut rng = Rng::seed_from_u64(55);
        let mut changed = false;
        for _ in 0..50 {
            let g = Genome::random(&nets, 0.4, &mut rng);
            if let Some(child) = reposition_adjacent(&nets, &g, &mut rng) {
                if child != g {
                    changed = true;
                    break;
                }
            }
        }
        assert!(changed);
    }
}
