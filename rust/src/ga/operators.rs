//! Crossover and mutation operators (paper §4.3, Fig 8):
//! one-point crossover for partition/mapping genes, UPMX for priority,
//! per-gene mutation.


use super::chromosome::{Genome, NetworkGenes};
use crate::util::rng::Rng;

/// One-point crossover on two equal-length gene slices. Returns the cut
/// point used (for tests).
fn one_point_slice<T: Clone>(a: &mut [T], b: &mut [T], rng: &mut Rng) -> usize {
    if a.len() < 2 {
        return 0;
    }
    let cut = rng.gen_range(1, a.len());
    for i in cut..a.len() {
        std::mem::swap(&mut a[i], &mut b[i]);
    }
    cut
}

/// One-point crossover applied per network to both the partition bits and
/// the mapping genes of two genomes, in place (paper: "one-point crossover
/// is applied to the partition and mapping chromosomes").
pub fn one_point_crossover(a: &mut Genome, b: &mut Genome, rng: &mut Rng) {
    one_point_crossover_with(a, b, rng, &mut UpmxScratch::default());
}

/// [`one_point_crossover`] through a caller-owned [`UpmxScratch`] (the
/// allocation-free offspring fan-out path).
pub fn one_point_crossover_with(
    a: &mut Genome,
    b: &mut Genome,
    rng: &mut Rng,
    scratch: &mut UpmxScratch,
) {
    for (ga, gb) in a.networks.iter_mut().zip(b.networks.iter_mut()) {
        one_point_slice(&mut ga.cuts, &mut gb.cuts, rng);
        one_point_slice(&mut ga.mapping, &mut gb.mapping, rng);
    }
    upmx_with(&mut a.priority, &mut b.priority, rng, 0.5, scratch);
}

/// Reusable position-of-value index buffers for [`upmx`]: one per worker
/// thread removes the last two per-pair allocations of the offspring
/// fan-out (visible at population 4096+). Scratch reuse cannot affect
/// results — both buffers are fully overwritten before they are read, and
/// no randomness is consumed by the buffers themselves.
#[derive(Debug, Default, Clone)]
pub struct UpmxScratch {
    pos_a: Vec<usize>,
    pos_b: Vec<usize>,
}

/// Uniform Partially-Matched Crossover on two permutations, in place.
///
/// For each position, with probability `swap_prob`, the values at that
/// position are exchanged *within each parent* via the partial-matching
/// repair (swap the value with wherever the partner's value currently sits),
/// preserving permutation validity — the standard UPMX of DEAP's
/// `cxUniformPartialyMatched`.
pub fn upmx(a: &mut [usize], b: &mut [usize], rng: &mut Rng, swap_prob: f64) {
    upmx_with(a, b, rng, swap_prob, &mut UpmxScratch::default());
}

/// [`upmx`] through a caller-owned [`UpmxScratch`]: identical RNG draws and
/// output (tested), zero allocation once the scratch is warm.
pub fn upmx_with(
    a: &mut [usize],
    b: &mut [usize],
    rng: &mut Rng,
    swap_prob: f64,
    scratch: &mut UpmxScratch,
) {
    let n = a.len();
    if n < 2 {
        return;
    }
    // Position-of-value indices for O(1) repair (fully overwritten below).
    scratch.pos_a.resize(n, 0);
    scratch.pos_b.resize(n, 0);
    let (pos_a, pos_b) = (&mut scratch.pos_a, &mut scratch.pos_b);
    for i in 0..n {
        pos_a[a[i]] = i;
        pos_b[b[i]] = i;
    }
    for i in 0..n {
        if rng.gen_bool(swap_prob) {
            let va = a[i];
            let vb = b[i];
            // In `a`, swap value va (at i) with value vb (at pos_a[vb]).
            let j = pos_a[vb];
            a.swap(i, j);
            pos_a[va] = j;
            pos_a[vb] = i;
            // Mirror in `b`.
            let k = pos_b[va];
            b.swap(i, k);
            pos_b[vb] = k;
            pos_b[va] = i;
        }
    }
}

/// Per-chromosome mutation probabilities, bundled so offspring jobs carry
/// one value across the fan-out.
#[derive(Debug, Clone, Copy)]
pub struct MutationRates {
    pub cut: f64,
    pub map: f64,
    pub prio: f64,
}

/// Breed one parent pair into two children: clone both parents, apply
/// one-point crossover, then mutate each child — the per-pair work unit the
/// analyzer's offspring fan-out ships to worker threads. All randomness
/// comes from `rng`; seed it from a per-pair derived seed and the children
/// are a pure function of `(parents, seed)`, independent of which thread
/// breeds them.
pub fn breed_pair(a: &Genome, b: &Genome, rates: MutationRates, rng: &mut Rng) -> (Genome, Genome) {
    breed_pair_with(a, b, rates, rng, &mut UpmxScratch::default())
}

/// [`breed_pair`] through a per-thread [`UpmxScratch`]: bit-identical
/// children (tested), with the children's own buffers as the only
/// allocations once the scratch is warm.
pub fn breed_pair_with(
    a: &Genome,
    b: &Genome,
    rates: MutationRates,
    rng: &mut Rng,
    scratch: &mut UpmxScratch,
) -> (Genome, Genome) {
    let mut ca = a.clone();
    let mut cb = b.clone();
    one_point_crossover_with(&mut ca, &mut cb, rng, scratch);
    mutate(&mut ca, rates.cut, rates.map, rates.prio, rng);
    mutate(&mut cb, rates.cut, rates.map, rates.prio, rng);
    (ca, cb)
}

/// [`breed_pair_with`] writing the children into caller-owned genome
/// buffers (`Genome::clone_from` reuses their chromosome `Vec`s): identical
/// RNG draws and bit-identical children (tested). With output genomes
/// recycled from replaced survivors of the same scenario — every genome of
/// one search has the same shape — a warm breed performs zero heap
/// allocation, which is what lets the analyzer's steady-state reproduction
/// run out of its free-list slab.
#[allow(clippy::too_many_arguments)]
pub fn breed_pair_into(
    a: &Genome,
    b: &Genome,
    rates: MutationRates,
    rng: &mut Rng,
    scratch: &mut UpmxScratch,
    out_a: &mut Genome,
    out_b: &mut Genome,
) {
    out_a.clone_from(a);
    out_b.clone_from(b);
    one_point_crossover_with(out_a, out_b, rng, scratch);
    mutate(out_a, rates.cut, rates.map, rates.prio, rng);
    mutate(out_b, rates.cut, rates.map, rates.prio, rng);
}

/// Mutation: each partition bit flips with `p_cut`, each mapping gene
/// re-draws with `p_map`, and the priority permutation swaps a random pair
/// with `p_prio`.
pub fn mutate(g: &mut Genome, p_cut: f64, p_map: f64, p_prio: f64, rng: &mut Rng) {
    for genes in &mut g.networks {
        mutate_network(genes, p_cut, p_map, rng);
    }
    if g.priority.len() >= 2 && rng.gen_bool(p_prio) {
        let i = rng.gen_range(0, g.priority.len());
        let j = rng.gen_range(0, g.priority.len());
        g.priority.swap(i, j);
    }
}

fn mutate_network(genes: &mut NetworkGenes, p_cut: f64, p_map: f64, rng: &mut Rng) {
    for c in &mut genes.cuts {
        if rng.gen_bool(p_cut) {
            *c = !*c;
        }
    }
    for m in &mut genes.mapping {
        if rng.gen_bool(p_map) {
            *m = crate::Processor::from_index(rng.gen_range(0, 3));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::build_model;
    use crate::Processor;
    
    fn is_permutation(p: &[usize]) -> bool {
        let mut seen = vec![false; p.len()];
        p.iter().all(|&v| {
            if v >= seen.len() || seen[v] {
                false
            } else {
                seen[v] = true;
                true
            }
        })
    }

    #[test]
    fn upmx_preserves_permutations() {
        let mut rng = Rng::seed_from_u64(3);
        for _ in 0..200 {
            let n = rng.gen_range(2, 12);
            let mut a: Vec<usize> = (0..n).collect();
            let mut b: Vec<usize> = (0..n).rev().collect();
            upmx(&mut a, &mut b, &mut rng, 0.5);
            assert!(is_permutation(&a), "{a:?}");
            assert!(is_permutation(&b), "{b:?}");
        }
    }

    #[test]
    fn upmx_actually_mixes() {
        let mut rng = Rng::seed_from_u64(4);
        let orig: Vec<usize> = (0..8).collect();
        let mut mixed = false;
        for _ in 0..20 {
            let mut a = orig.clone();
            let mut b: Vec<usize> = (0..8).rev().collect();
            upmx(&mut a, &mut b, &mut rng, 0.5);
            if a != orig {
                mixed = true;
            }
        }
        assert!(mixed);
    }

    #[test]
    fn crossover_keeps_genomes_valid() {
        let nets = vec![build_model(0, 1), build_model(1, 6), build_model(2, 4)];
        let mut rng = Rng::seed_from_u64(9);
        for _ in 0..100 {
            let mut a = Genome::random(&nets, 0.3, &mut rng);
            let mut b = Genome::random(&nets, 0.3, &mut rng);
            one_point_crossover(&mut a, &mut b, &mut rng);
            assert!(a.is_valid(&nets));
            assert!(b.is_valid(&nets));
        }
    }

    #[test]
    fn crossover_exchanges_tails() {
        // With a fixed seed, children must contain genes from both parents.
        let nets = vec![build_model(0, 8)];
        let mut rng = Rng::seed_from_u64(2);
        let mut a = Genome::all_on(&nets, Processor::Cpu);
        let mut b = Genome::all_on(&nets, Processor::Npu);
        one_point_crossover(&mut a, &mut b, &mut rng);
        let cpus = a.networks[0].mapping.iter().filter(|&&p| p == Processor::Cpu).count();
        assert!(cpus > 0 && cpus < a.networks[0].mapping.len(), "no tail exchanged");
    }

    #[test]
    fn mutation_keeps_validity_and_perturbs() {
        let nets = vec![build_model(0, 3), build_model(1, 5)];
        let mut rng = Rng::seed_from_u64(11);
        let mut any_changed = false;
        for _ in 0..50 {
            let mut g = Genome::random(&nets, 0.2, &mut rng);
            let before = g.clone();
            mutate(&mut g, 0.1, 0.1, 0.5, &mut rng);
            assert!(g.is_valid(&nets));
            if g != before {
                any_changed = true;
            }
        }
        assert!(any_changed);
    }

    #[test]
    fn breed_pair_is_pure_in_parents_and_seed() {
        // The offspring fan-out contract: children depend only on the
        // parent pair and the derived seed, never on scheduling.
        let nets = vec![build_model(0, 1), build_model(1, 6)];
        let mut rng = Rng::seed_from_u64(7);
        let a = Genome::random(&nets, 0.3, &mut rng);
        let b = Genome::random(&nets, 0.3, &mut rng);
        let rates = MutationRates { cut: 0.05, map: 0.05, prio: 0.3 };
        let c1 = breed_pair(&a, &b, rates, &mut Rng::seed_from_u64(99));
        let c2 = breed_pair(&a, &b, rates, &mut Rng::seed_from_u64(99));
        assert_eq!(c1, c2);
        assert!(c1.0.is_valid(&nets) && c1.1.is_valid(&nets));
        // And it matches the inline clone → crossover → mutate sequence.
        let mut rng2 = Rng::seed_from_u64(99);
        let (mut ma, mut mb) = (a.clone(), b.clone());
        one_point_crossover(&mut ma, &mut mb, &mut rng2);
        mutate(&mut ma, rates.cut, rates.map, rates.prio, &mut rng2);
        mutate(&mut mb, rates.cut, rates.map, rates.prio, &mut rng2);
        assert_eq!((ma, mb), c1);
    }

    #[test]
    fn upmx_with_scratch_matches_owned_and_is_allocation_free() {
        // Identical RNG stream + identical output, across reused scratch of
        // varying sizes; once warm, the scratch path performs zero heap
        // allocation.
        let mut scratch = UpmxScratch::default();
        for case in 0..50u64 {
            let mut size_rng = Rng::seed_from_u64(1000 + case);
            let n = size_rng.gen_range(2, 16);
            let mut a1: Vec<usize> = (0..n).collect();
            let mut b1: Vec<usize> = (0..n).rev().collect();
            let (mut a2, mut b2) = (a1.clone(), b1.clone());
            upmx(&mut a1, &mut b1, &mut Rng::seed_from_u64(case), 0.5);
            upmx_with(&mut a2, &mut b2, &mut Rng::seed_from_u64(case), 0.5, &mut scratch);
            assert_eq!(a1, a2);
            assert_eq!(b1, b2);
        }
        // Warm scratch at a fixed size, then count allocations.
        let n = 12;
        let mut a: Vec<usize> = (0..n).collect();
        let mut b: Vec<usize> = (0..n).rev().collect();
        upmx_with(&mut a, &mut b, &mut Rng::seed_from_u64(9), 0.5, &mut scratch);
        let before = crate::util::alloc::thread_allocations();
        upmx_with(&mut a, &mut b, &mut Rng::seed_from_u64(10), 0.5, &mut scratch);
        let allocs = crate::util::alloc::thread_allocations() - before;
        assert_eq!(allocs, 0, "warm upmx scratch must not allocate");
    }

    #[test]
    fn breed_pair_with_scratch_is_bit_identical() {
        let nets = vec![build_model(0, 1), build_model(1, 6), build_model(2, 3)];
        let mut rng = Rng::seed_from_u64(8);
        let a = Genome::random(&nets, 0.3, &mut rng);
        let b = Genome::random(&nets, 0.3, &mut rng);
        let rates = MutationRates { cut: 0.05, map: 0.05, prio: 0.3 };
        let owned = breed_pair(&a, &b, rates, &mut Rng::seed_from_u64(55));
        let mut scratch = UpmxScratch::default();
        let scratched = breed_pair_with(&a, &b, rates, &mut Rng::seed_from_u64(55), &mut scratch);
        assert_eq!(owned, scratched);
        // Reuse across pairs keeps the purity contract.
        let again = breed_pair_with(&a, &b, rates, &mut Rng::seed_from_u64(55), &mut scratch);
        assert_eq!(owned, again);
    }

    #[test]
    fn breed_pair_into_is_bit_identical_and_allocation_free() {
        let nets = vec![build_model(0, 1), build_model(1, 6), build_model(2, 3)];
        let mut rng = Rng::seed_from_u64(8);
        let a = Genome::random(&nets, 0.3, &mut rng);
        let b = Genome::random(&nets, 0.3, &mut rng);
        let rates = MutationRates { cut: 0.05, map: 0.05, prio: 0.3 };
        let owned = breed_pair(&a, &b, rates, &mut Rng::seed_from_u64(55));
        let mut scratch = UpmxScratch::default();
        let (mut ca, mut cb) = (Genome::default(), Genome::default());
        let mut rng55 = Rng::seed_from_u64(55);
        breed_pair_into(&a, &b, rates, &mut rng55, &mut scratch, &mut ca, &mut cb);
        assert_eq!(owned, (ca.clone(), cb.clone()));
        // Recycled same-shape outputs + warm scratch: zero heap allocation.
        let mut rng56 = Rng::seed_from_u64(56);
        let before = crate::util::alloc::thread_allocations();
        breed_pair_into(&a, &b, rates, &mut rng56, &mut scratch, &mut ca, &mut cb);
        let allocs = crate::util::alloc::thread_allocations() - before;
        assert_eq!(allocs, 0, "warm breed_pair_into must not allocate");
        // And the recycled outputs still match a fresh owned breed.
        assert_eq!(breed_pair(&a, &b, rates, &mut Rng::seed_from_u64(56)), (ca, cb));
    }

    #[test]
    fn zero_rate_mutation_is_identity() {
        let nets = vec![build_model(0, 2)];
        let mut rng = Rng::seed_from_u64(12);
        let mut g = Genome::random(&nets, 0.2, &mut rng);
        let before = g.clone();
        mutate(&mut g, 0.0, 0.0, 0.0, &mut rng);
        assert_eq!(g, before);
    }
}
