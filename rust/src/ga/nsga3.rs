//! NSGA-III environmental selection (Deb & Jain 2013), the population
//! replacement the paper uses ("the population is updated using the NSGA3
//! algorithm", §4.3).
//!
//! Pipeline: non-dominated sort → fill whole fronts while they fit → for the
//! splitting front, normalize objectives, associate individuals with
//! Das–Dennis reference directions, and fill by niche count (preferring
//! under-represented directions, closest-distance first).
//!
//! ## Two implementations, one contract (§Perf, this PR)
//!
//! * [`nsga3_select`] — the straightforward reference: `fast_non_dominated_sort`
//!   (O(n²) dominance matrix + BFS peeling) and linear-scan niching. Kept as
//!   the executable specification.
//! * [`SelectionWorkspace`] — the production path the analyzer runs every
//!   generation: an **ENS-BS** front builder (lexicographic presort + binary
//!   search over fronts, checking only already-placed members) and
//!   **binary-heap niching** (one live heap entry per niche keyed by
//!   `(niche count, earliest remaining candidate position)`), all scratch
//!   owned by the workspace so steady-state selection performs **zero heap
//!   allocation** (asserted in `rust/tests/batch_eval.rs`).
//!
//! Both paths emit fronts in **canonical order** — each front's indices
//! ascending — and the heap keys reproduce the reference's tie-breaking
//! exactly (least niche count, then earliest remaining split-front position;
//! within a niche, closest distance, then earliest position), so
//! [`SelectionWorkspace::select`] returns **bit-identical indices** to
//! [`nsga3_select`] on every input (property-tested in
//! `rust/tests/proptests.rs`).

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::{Arc, Mutex};

/// Process-wide Das–Dennis memo keyed by `(m, divisions)`: every
/// [`SelectionWorkspace`] across every concurrent GA search shares the same
/// `Arc`'d flat point sets instead of regenerating them per workspace. The
/// key space is tiny (`divisions` is capped at 32 by [`divisions_for`]), so
/// a linear scan under the lock beats hashing.
static REF_CACHE: Mutex<Vec<(usize, usize, Arc<Vec<f64>>)>> = Mutex::new(Vec::new());

/// Shared flat Das–Dennis rows for `(m, divisions)` from the process-wide
/// memo, generating (once, process-lifetime) on first use.
fn shared_reference_points(m: usize, divisions: usize) -> Arc<Vec<f64>> {
    let mut cache = REF_CACHE.lock().expect("ref cache poisoned");
    if let Some((_, _, flat)) = cache.iter().find(|&&(cm, cd, _)| cm == m && cd == divisions) {
        return flat.clone();
    }
    let mut flat = Vec::new();
    reference_points_into(m, divisions, &mut flat);
    let flat = Arc::new(flat);
    cache.push((m, divisions, flat.clone()));
    flat
}

/// Pareto dominance for minimization objectives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dominance {
    Dominates,
    DominatedBy,
    Incomparable,
}

/// Compare two objective vectors (all objectives minimized).
pub fn dominance(a: &[f64], b: &[f64]) -> Dominance {
    let mut a_better = false;
    let mut b_better = false;
    for (&x, &y) in a.iter().zip(b) {
        if x < y {
            a_better = true;
        } else if y < x {
            b_better = true;
        }
    }
    match (a_better, b_better) {
        (true, false) => Dominance::Dominates,
        (false, true) => Dominance::DominatedBy,
        _ => Dominance::Incomparable,
    }
}

/// `a` strictly dominates `b` (≤ everywhere, < somewhere). Early-exits on
/// the first losing objective; boolean-equivalent to
/// `dominance(a, b) == Dominance::Dominates`.
#[inline]
fn dominates(a: &[f64], b: &[f64]) -> bool {
    let mut strict = false;
    for (&x, &y) in a.iter().zip(b) {
        if x > y {
            return false;
        }
        if x < y {
            strict = true;
        }
    }
    strict
}

/// Fast non-dominated sort: returns fronts (vectors of indices), best first.
/// Front 0 is ascending by construction; deeper fronts come out in BFS
/// order — callers needing the canonical (ascending) order sort each front,
/// as [`nsga3_select`] does.
pub fn fast_non_dominated_sort(objs: &[Vec<f64>]) -> Vec<Vec<usize>> {
    let n = objs.len();
    let mut dominated_by: Vec<Vec<usize>> = vec![Vec::new(); n]; // i dominates these
    let mut dom_count = vec![0usize; n];
    for i in 0..n {
        for j in (i + 1)..n {
            match dominance(&objs[i], &objs[j]) {
                Dominance::Dominates => {
                    dominated_by[i].push(j);
                    dom_count[j] += 1;
                }
                Dominance::DominatedBy => {
                    dominated_by[j].push(i);
                    dom_count[i] += 1;
                }
                Dominance::Incomparable => {}
            }
        }
    }
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    let mut current: Vec<usize> = (0..n).filter(|&i| dom_count[i] == 0).collect();
    while !current.is_empty() {
        let mut next = Vec::new();
        for &i in &current {
            for &j in &dominated_by[i] {
                dom_count[j] -= 1;
                if dom_count[j] == 0 {
                    next.push(j);
                }
            }
        }
        fronts.push(std::mem::take(&mut current));
        current = next;
    }
    fronts
}

/// Das–Dennis reference directions on the unit simplex with `divisions`
/// gaps per objective (`m` objectives).
pub fn reference_points(m: usize, divisions: usize) -> Vec<Vec<f64>> {
    let mut out = Vec::new();
    let mut point = vec![0usize; m];
    fn recurse(m: usize, left: usize, dim: usize, point: &mut Vec<usize>, out: &mut Vec<Vec<f64>>, divisions: usize) {
        if dim == m - 1 {
            point[dim] = left;
            out.push(point.iter().map(|&x| x as f64 / divisions as f64).collect());
            return;
        }
        for v in 0..=left {
            point[dim] = v;
            recurse(m, left - v, dim + 1, point, out, divisions);
        }
    }
    recurse(m, divisions, 0, &mut point, &mut out, divisions);
    out
}

/// `reference_points(m, divisions).len()` without materializing the points:
/// the number of compositions of `divisions` into `m` parts,
/// C(divisions + m - 1, m - 1). Computed incrementally so every
/// intermediate is itself an exact binomial; if one overflows `u128` the
/// true count is astronomically larger than any population, so saturate —
/// callers only compare it against a population size.
fn das_dennis_count(m: usize, divisions: usize) -> u128 {
    let k = m.saturating_sub(1) as u128;
    let n = divisions as u128 + k;
    let mut res: u128 = 1;
    for i in 1..=k {
        // res = C(n - k + i - 1, i - 1) entering the step; the identity
        // C(a, i) = C(a - 1, i - 1) · a / i keeps the division exact.
        res = match res.checked_mul(n - k + i) {
            Some(v) => v / i,
            None => return u128::MAX,
        };
    }
    res
}

/// Append the Das–Dennis directions as flat rows to `out` — identical values
/// in identical order to [`reference_points`], without the nested `Vec`s.
fn reference_points_into(m: usize, divisions: usize, out: &mut Vec<f64>) {
    out.clear();
    let mut point = vec![0usize; m];
    fn recurse(m: usize, left: usize, dim: usize, point: &mut [usize], out: &mut Vec<f64>, divisions: usize) {
        if dim == m - 1 {
            point[dim] = left;
            out.extend(point.iter().map(|&x| x as f64 / divisions as f64));
            return;
        }
        for v in 0..=left {
            point[dim] = v;
            recurse(m, left - v, dim + 1, point, out, divisions);
        }
    }
    recurse(m, divisions, 0, &mut point, out, divisions);
}

/// Perpendicular distance from (normalized) objective vector `f` to the ray
/// through reference direction `w`.
fn perpendicular_distance(f: &[f64], w: &[f64]) -> f64 {
    let wdotf: f64 = w.iter().zip(f).map(|(a, b)| a * b).sum();
    let wnorm2: f64 = w.iter().map(|a| a * a).sum();
    if wnorm2 <= 0.0 {
        return f.iter().map(|a| a * a).sum::<f64>().sqrt();
    }
    let t = wdotf / wnorm2;
    f.iter()
        .zip(w)
        .map(|(fi, wi)| (fi - t * wi).powi(2))
        .sum::<f64>()
        .sqrt()
}

/// The smallest `divisions` whose Das–Dennis set offers at least
/// `need.max(4)` directions (capped at 32) — shared by both selection paths.
fn divisions_for(m: usize, need: usize) -> usize {
    let mut divisions = 4;
    while das_dennis_count(m, divisions) < need.max(4) as u128 && divisions < 32 {
        divisions += 1;
    }
    divisions
}

/// Normalize solution `i`'s objectives into `row` (ideal/nadir min-max, same
/// arithmetic in both selection paths).
fn normalize_into(objs: &[f64], i: usize, m: usize, ideal: &[f64], nadir: &[f64], row: &mut Vec<f64>) {
    row.clear();
    for d in 0..m {
        let range = (nadir[d] - ideal[d]).max(1e-12);
        row.push((objs[i * m + d] - ideal[d]) / range);
    }
}

/// Closest reference direction for a normalized row: (ref index, distance),
/// ties broken by the lower index (strict `<` while scanning in order).
fn associate(row: &[f64], refs: &[f64], m: usize) -> (usize, f64) {
    let mut best = (0usize, f64::INFINITY);
    for (r, w) in refs.chunks_exact(m).enumerate() {
        let d = perpendicular_distance(row, w);
        if d < best.1 {
            best = (r, d);
        }
    }
    best
}

/// NSGA-III environmental selection: choose `k` survivors from `objs`
/// (minimization). Deterministic given input order: fronts are used in
/// canonical (index-ascending) order, ties in niching break toward the
/// earliest remaining candidate, and the niching pick is the closest
/// individual rather than a random one — a common deterministic variant.
///
/// This is the O(n²) reference implementation; the search itself runs
/// [`SelectionWorkspace::select`], which returns identical indices.
pub fn nsga3_select(objs: &[Vec<f64>], k: usize) -> Vec<usize> {
    let n = objs.len();
    if k >= n {
        return (0..n).collect();
    }
    let m = objs.first().map(|o| o.len()).unwrap_or(0);
    let mut fronts = fast_non_dominated_sort(objs);
    // Canonical front order (shared contract with SelectionWorkspace): the
    // BFS peel emits deeper fronts in discovery order, which is an artifact
    // of the dominance structure; selection tie-breaking is defined over
    // index-ascending fronts instead.
    for f in &mut fronts {
        f.sort_unstable();
    }

    let mut chosen: Vec<usize> = Vec::with_capacity(k);
    let mut split_front: Option<Vec<usize>> = None;
    for front in &fronts {
        if chosen.len() + front.len() <= k {
            chosen.extend_from_slice(front);
        } else {
            split_front = Some(front.clone());
            break;
        }
    }
    let Some(split) = split_front else {
        return chosen;
    };
    let need = k - chosen.len();

    // Normalize over the union of chosen + split using ideal/nadir estimates.
    let pool: Vec<usize> = chosen.iter().chain(&split).copied().collect();
    let mut ideal = vec![f64::INFINITY; m];
    let mut nadir = vec![f64::NEG_INFINITY; m];
    for &i in &pool {
        for d in 0..m {
            ideal[d] = ideal[d].min(objs[i][d]);
            nadir[d] = nadir[d].max(objs[i][d]);
        }
    }
    let normalize = |i: usize| -> Vec<f64> {
        (0..m)
            .map(|d| {
                let range = (nadir[d] - ideal[d]).max(1e-12);
                (objs[i][d] - ideal[d]) / range
            })
            .collect()
    };

    // Das–Dennis directions sized to the population (>= need niches).
    let divisions = divisions_for(m, need);
    let refs = reference_points(m, divisions);

    // Associate: everyone already chosen contributes to niche counts.
    let associate = |i: usize| -> (usize, f64) {
        let f = normalize(i);
        let mut best = (0usize, f64::INFINITY);
        for (r, w) in refs.iter().enumerate() {
            let d = perpendicular_distance(&f, w);
            if d < best.1 {
                best = (r, d);
            }
        }
        best
    };
    let mut niche_count = vec![0usize; refs.len()];
    for &i in &chosen {
        let (r, _) = associate(i);
        niche_count[r] += 1;
    }
    // Candidates from the split front with their (ref, dist).
    let cands: Vec<(usize, usize, f64)> = split.iter().map(|&i| {
        let (r, d) = associate(i);
        (i, r, d)
    }).collect();

    // Niching: repeatedly take from the least-crowded niche.
    let mut taken = vec![false; cands.len()];
    for _ in 0..need {
        // Find the niche with minimal count that still has candidates.
        let mut best_niche: Option<usize> = None;
        for (ci, &(_, r, _)) in cands.iter().enumerate() {
            if taken[ci] {
                continue;
            }
            match best_niche {
                None => best_niche = Some(r),
                Some(bn) => {
                    if niche_count[r] < niche_count[bn] {
                        best_niche = Some(r);
                    }
                }
            }
        }
        let Some(niche) = best_niche else { break };
        // Closest candidate in that niche.
        let mut pick: Option<(usize, f64)> = None;
        for (ci, &(_, r, d)) in cands.iter().enumerate() {
            if taken[ci] || r != niche {
                continue;
            }
            if pick.map(|(_, pd)| d < pd).unwrap_or(true) {
                pick = Some((ci, d));
            }
        }
        let (ci, _) = pick.expect("niche had a candidate");
        taken[ci] = true;
        niche_count[cands[ci].1] += 1;
        chosen.push(cands[ci].0);
    }
    chosen
}

/// Reusable scratch for the production selection path: ENS-BS non-dominated
/// sorting plus binary-heap niching. Create once (per analyzer run), call
/// [`SelectionWorkspace::select`] per generation; after the first call at a
/// given population shape, selection performs zero heap allocation.
///
/// Results are **bit-identical** to [`nsga3_select`] for every input (see
/// module docs for the shared tie-break contract).
#[derive(Default)]
pub struct SelectionWorkspace {
    // --- ENS front builder ---
    /// Indices sorted lexicographically by objective vector (tie: index).
    lex: Vec<usize>,
    /// Per front: most recently placed member (intrusive list head).
    head: Vec<usize>,
    /// Per solution: previously placed member of its front.
    next_in: Vec<usize>,
    /// Per solution: assigned front.
    front_of: Vec<usize>,
    /// Per front: member count / placement cursor (counting sort scratch).
    counts: Vec<usize>,
    /// All indices grouped by front, ascending within each front.
    sorted: Vec<usize>,
    /// Per front: offset into `sorted` (length `fronts + 1`).
    starts: Vec<usize>,
    // --- niching ---
    ideal: Vec<f64>,
    nadir: Vec<f64>,
    norm_row: Vec<f64>,
    /// Memoized flat Das–Dennis sets: (m, divisions, rows), `Arc`-shared
    /// with the process-wide [`REF_CACHE`]. Bounded — divisions is capped
    /// at 32 — so steady state never regenerates, and a fresh workspace
    /// never recomputes a set any workspace in the process has built.
    refs_cache: Vec<(usize, usize, Arc<Vec<f64>>)>,
    niche_count: Vec<usize>,
    cand_niche: Vec<usize>,
    cand_dist: Vec<f64>,
    /// Split-front candidates grouped by niche, each group sorted by
    /// (distance, split position): (distance, position, solution index).
    grouped: Vec<(f64, usize, usize)>,
    /// Per niche: offset into `grouped` (length `refs + 1`).
    g_start: Vec<usize>,
    bucket_cursor: Vec<usize>,
    /// Per grouped entry: min split position over the remaining suffix of
    /// its niche group — the "earliest remaining candidate" key in O(1).
    suffix_min_pos: Vec<usize>,
    /// Per niche: candidates already taken (prefix of its sorted group).
    taken: Vec<usize>,
    /// One live entry per niche with remaining candidates:
    /// (niche count, earliest remaining position, niche).
    heap: BinaryHeap<Reverse<(usize, usize, usize)>>,
    /// Selected indices of the last [`SelectionWorkspace::select`] call.
    out: Vec<usize>,
}

impl SelectionWorkspace {
    pub fn new() -> SelectionWorkspace {
        SelectionWorkspace::default()
    }

    /// Select `k` survivors from `objs` — a flat row-major `n × m` matrix of
    /// minimized objectives (`m ≥ 1`). Returns the selected indices in the
    /// same order as [`nsga3_select`]: whole fronts ascending, then niched
    /// picks. The slice borrows workspace storage; copy it out before the
    /// next call.
    pub fn select(&mut self, objs: &[f64], m: usize, k: usize) -> &[usize] {
        assert!(m > 0, "need at least one objective");
        assert_eq!(objs.len() % m, 0, "flat objective matrix must be n × m");
        self.select_inner(objs, m, k);
        &self.out
    }

    /// [`SelectionWorkspace::select`] over nested rows (tests, benches);
    /// allocates the flattened copy and the returned vector.
    pub fn select_objs(&mut self, objs: &[Vec<f64>], k: usize) -> Vec<usize> {
        let n = objs.len();
        if k >= n {
            return (0..n).collect();
        }
        let m = objs.first().map(|o| o.len()).unwrap_or(0);
        let flat: Vec<f64> = objs.iter().flat_map(|o| o.iter().copied()).collect();
        self.select(&flat, m, k).to_vec()
    }

    /// Non-dominated fronts (canonical ascending order within each front)
    /// via the ENS builder — the testable surface for equivalence with
    /// [`fast_non_dominated_sort`]. Allocates the returned nesting.
    pub fn non_dominated_fronts(&mut self, objs: &[Vec<f64>]) -> Vec<Vec<usize>> {
        let n = objs.len();
        if n == 0 {
            return Vec::new();
        }
        let m = objs.first().map(|o| o.len()).unwrap_or(0);
        if m == 0 {
            // Degenerate: nothing dominates anything.
            return vec![(0..n).collect()];
        }
        let flat: Vec<f64> = objs.iter().flat_map(|o| o.iter().copied()).collect();
        self.build_fronts(&flat, n, m);
        (0..self.num_fronts())
            .map(|f| self.front(f).to_vec())
            .collect()
    }

    /// Number of fronts built by the last sort.
    pub fn num_fronts(&self) -> usize {
        self.starts.len().saturating_sub(1)
    }

    /// Members of front `f` (ascending indices) from the last sort.
    pub fn front(&self, f: usize) -> &[usize] {
        &self.sorted[self.starts[f]..self.starts[f + 1]]
    }

    /// ENS-BS: lexicographic presort, then place each solution into the
    /// first front none of whose already-placed members dominates it (binary
    /// search over fronts — validity follows from dominance transitivity: a
    /// solution dominated by a member of front j is dominated by a member of
    /// every earlier front). Any dominator of `s` precedes `s`
    /// lexicographically, so checking placed members suffices.
    fn build_fronts(&mut self, objs: &[f64], n: usize, m: usize) {
        let SelectionWorkspace { lex, head, next_in, front_of, counts, sorted, starts, .. } =
            self;
        lex.clear();
        lex.extend(0..n);
        lex.sort_unstable_by(|&a, &b| {
            let ra = &objs[a * m..a * m + m];
            let rb = &objs[b * m..b * m + m];
            for (x, y) in ra.iter().zip(rb) {
                match x.partial_cmp(y).expect("comparable objective") {
                    std::cmp::Ordering::Equal => continue,
                    o => return o,
                }
            }
            a.cmp(&b)
        });

        head.clear();
        next_in.clear();
        next_in.resize(n, usize::MAX);
        front_of.clear();
        front_of.resize(n, 0);
        let front_has_dominator = |head: &[usize], next_in: &[usize], f: usize, s: usize| {
            let srow = &objs[s * m..s * m + m];
            let mut cur = head[f];
            while cur != usize::MAX {
                if dominates(&objs[cur * m..cur * m + m], srow) {
                    return true;
                }
                cur = next_in[cur];
            }
            false
        };
        for &s in lex.iter() {
            let (mut lo, mut hi) = (0usize, head.len());
            while lo < hi {
                let mid = (lo + hi) / 2;
                if front_has_dominator(head, next_in, mid, s) {
                    lo = mid + 1;
                } else {
                    hi = mid;
                }
            }
            if lo == head.len() {
                head.push(usize::MAX);
            }
            front_of[s] = lo;
            next_in[s] = head[lo];
            head[lo] = s;
        }

        // Counting sort by front: ascending indices within each front.
        let nf = head.len();
        counts.clear();
        counts.resize(nf, 0);
        for &f in front_of.iter() {
            counts[f] += 1;
        }
        starts.clear();
        starts.resize(nf + 1, 0);
        for f in 0..nf {
            starts[f + 1] = starts[f] + counts[f];
        }
        counts.copy_from_slice(&starts[..nf]);
        sorted.clear();
        sorted.resize(n, 0);
        for i in 0..n {
            let f = front_of[i];
            sorted[counts[f]] = i;
            counts[f] += 1;
        }
    }

    /// Index of the (m, divisions) entry in the workspace refs cache; on a
    /// workspace miss the `Arc` is fetched from (or built into) the
    /// process-wide [`REF_CACHE`], so generation happens once per process.
    fn ensure_refs(&mut self, m: usize, divisions: usize) -> usize {
        if let Some(pos) = self
            .refs_cache
            .iter()
            .position(|&(cm, cd, _)| cm == m && cd == divisions)
        {
            return pos;
        }
        let flat = shared_reference_points(m, divisions);
        self.refs_cache.push((m, divisions, flat));
        self.refs_cache.len() - 1
    }

    fn select_inner(&mut self, objs: &[f64], m: usize, k: usize) {
        let n = objs.len() / m;
        self.out.clear();
        if k >= n {
            self.out.extend(0..n);
            return;
        }
        self.build_fronts(objs, n, m);

        // Fill whole fronts while they fit; the first that does not is the
        // splitting front.
        let nf = self.num_fronts();
        let mut split_f = None;
        for f in 0..nf {
            let fr = &self.sorted[self.starts[f]..self.starts[f + 1]];
            if self.out.len() + fr.len() <= k {
                self.out.extend_from_slice(fr);
            } else {
                split_f = Some(f);
                break;
            }
        }
        let Some(sf) = split_f else { return };
        let need = k - self.out.len();
        if need == 0 {
            return;
        }
        let divisions = divisions_for(m, need);
        let cache_pos = self.ensure_refs(m, divisions);

        let SelectionWorkspace {
            ideal,
            nadir,
            norm_row,
            refs_cache,
            niche_count,
            cand_niche,
            cand_dist,
            grouped,
            g_start,
            bucket_cursor,
            suffix_min_pos,
            taken,
            heap,
            out,
            sorted,
            starts,
            ..
        } = self;
        let split = &sorted[starts[sf]..starts[sf + 1]];
        let refs = refs_cache[cache_pos].2.as_slice();
        let nrefs = refs.len() / m;

        // Ideal/nadir over chosen ∪ split.
        ideal.clear();
        ideal.resize(m, f64::INFINITY);
        nadir.clear();
        nadir.resize(m, f64::NEG_INFINITY);
        for &i in out.iter().chain(split) {
            for d in 0..m {
                ideal[d] = ideal[d].min(objs[i * m + d]);
                nadir[d] = nadir[d].max(objs[i * m + d]);
            }
        }

        // Niche counts from the already-chosen members.
        niche_count.clear();
        niche_count.resize(nrefs, 0);
        for &i in out.iter() {
            normalize_into(objs, i, m, ideal, nadir, norm_row);
            let (r, _) = associate(norm_row, refs, m);
            niche_count[r] += 1;
        }
        // Candidate association (split-front position order).
        cand_niche.clear();
        cand_dist.clear();
        for &i in split {
            normalize_into(objs, i, m, ideal, nadir, norm_row);
            let (r, d) = associate(norm_row, refs, m);
            cand_niche.push(r);
            cand_dist.push(d);
        }

        // Group candidates by niche (counting sort), then order each group
        // by (distance, position) — the within-niche pick order.
        let sl = split.len();
        g_start.clear();
        g_start.resize(nrefs + 1, 0);
        for &r in cand_niche.iter() {
            g_start[r + 1] += 1;
        }
        for r in 0..nrefs {
            g_start[r + 1] += g_start[r];
        }
        bucket_cursor.clear();
        bucket_cursor.extend_from_slice(&g_start[..nrefs]);
        grouped.clear();
        grouped.resize(sl, (0.0, 0, 0));
        for pos in 0..sl {
            let r = cand_niche[pos];
            grouped[bucket_cursor[r]] = (cand_dist[pos], pos, split[pos]);
            bucket_cursor[r] += 1;
        }
        for r in 0..nrefs {
            let g = &mut grouped[g_start[r]..g_start[r + 1]];
            if g.len() > 1 {
                g.sort_unstable_by(|a, b| {
                    a.0.partial_cmp(&b.0)
                        .expect("comparable niche distance")
                        .then(a.1.cmp(&b.1))
                });
            }
        }
        // Suffix-min of positions within each group: after taking a group's
        // first t entries, its earliest remaining position is O(1).
        suffix_min_pos.clear();
        suffix_min_pos.resize(sl, usize::MAX);
        for r in 0..nrefs {
            let (lo, hi) = (g_start[r], g_start[r + 1]);
            let mut min_pos = usize::MAX;
            for j in (lo..hi).rev() {
                min_pos = min_pos.min(grouped[j].1);
                suffix_min_pos[j] = min_pos;
            }
        }

        // Heap niching: one live entry per niche with remaining candidates,
        // keyed (count, earliest remaining position, niche). Popping the
        // minimum reproduces the reference scan: least-crowded niche first,
        // ties to the niche whose remaining candidate appears earliest in
        // the split front.
        taken.clear();
        taken.resize(nrefs, 0);
        heap.clear();
        for r in 0..nrefs {
            if g_start[r] < g_start[r + 1] {
                heap.push(Reverse((niche_count[r], suffix_min_pos[g_start[r]], r)));
            }
        }
        for _ in 0..need {
            let Some(Reverse((cnt, _pos, r))) = heap.pop() else { break };
            debug_assert_eq!(cnt, niche_count[r], "stale niche heap entry");
            let gi = g_start[r] + taken[r];
            out.push(grouped[gi].2);
            niche_count[r] += 1;
            taken[r] += 1;
            let next = g_start[r] + taken[r];
            if next < g_start[r + 1] {
                heap.push(Reverse((niche_count[r], suffix_min_pos[next], r)));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn dominance_basics() {
        assert_eq!(dominance(&[1.0, 1.0], &[2.0, 2.0]), Dominance::Dominates);
        assert_eq!(dominance(&[2.0, 2.0], &[1.0, 1.0]), Dominance::DominatedBy);
        assert_eq!(dominance(&[1.0, 2.0], &[2.0, 1.0]), Dominance::Incomparable);
        assert_eq!(dominance(&[1.0, 1.0], &[1.0, 1.0]), Dominance::Incomparable);
    }

    #[test]
    fn sort_layers_fronts() {
        let objs = vec![
            vec![1.0, 1.0], // front 0
            vec![2.0, 2.0], // front 1 (dominated by 0)
            vec![0.5, 3.0], // front 0 (incomparable with 0)
            vec![3.0, 3.0], // front 2
        ];
        let fronts = fast_non_dominated_sort(&objs);
        assert_eq!(fronts[0], vec![0, 2]);
        assert_eq!(fronts[1], vec![1]);
        assert_eq!(fronts[2], vec![3]);
    }

    #[test]
    fn reference_points_simplex() {
        let refs = reference_points(2, 4);
        assert_eq!(refs.len(), 5); // C(4+1, 1)
        for r in &refs {
            let s: f64 = r.iter().sum();
            assert!((s - 1.0).abs() < 1e-12);
        }
        let refs3 = reference_points(3, 4);
        assert_eq!(refs3.len(), 15); // C(6,2)
    }

    #[test]
    fn das_dennis_cache_is_process_wide() {
        // Two independent lookups share one Arc'd point set, and the cached
        // rows are exactly what direct generation produces.
        let a = shared_reference_points(3, 4);
        let b = shared_reference_points(3, 4);
        assert!(Arc::ptr_eq(&a, &b), "second lookup regenerated the set");
        let mut reference = Vec::new();
        reference_points_into(3, 4, &mut reference);
        assert_eq!(*a, reference);
    }

    #[test]
    fn das_dennis_count_matches_materialized() {
        for m in 1..=5 {
            for d in 1..=8 {
                assert_eq!(
                    das_dennis_count(m, d),
                    reference_points(m, d).len() as u128,
                    "m={m} d={d}"
                );
            }
        }
    }

    #[test]
    fn flat_reference_points_match_nested() {
        for (m, d) in [(2, 4), (3, 5), (4, 6)] {
            let nested = reference_points(m, d);
            let mut flat = Vec::new();
            reference_points_into(m, d, &mut flat);
            let reflat: Vec<f64> = nested.into_iter().flatten().collect();
            assert_eq!(flat, reflat, "m={m} d={d}");
        }
    }

    #[test]
    fn select_never_drops_first_front_when_it_fits() {
        let objs = vec![
            vec![1.0, 5.0],
            vec![5.0, 1.0],
            vec![3.0, 3.0],
            vec![6.0, 6.0], // dominated
            vec![7.0, 7.0], // dominated
        ];
        let sel = nsga3_select(&objs, 3);
        assert!(sel.contains(&0) && sel.contains(&1) && sel.contains(&2), "{sel:?}");
    }

    #[test]
    fn select_respects_k() {
        let objs: Vec<Vec<f64>> = (0..20).map(|i| vec![i as f64, (20 - i) as f64]).collect();
        let sel = nsga3_select(&objs, 7);
        assert_eq!(sel.len(), 7);
        // All on one front; niching must spread across the extremes.
        assert!(sel.contains(&0) || sel.contains(&1));
        assert!(sel.contains(&19) || sel.contains(&18));
    }

    #[test]
    fn select_everything_when_k_ge_n() {
        let objs = vec![vec![1.0], vec![2.0]];
        assert_eq!(nsga3_select(&objs, 5), vec![0, 1]);
        let mut ws = SelectionWorkspace::new();
        assert_eq!(ws.select_objs(&objs, 5), vec![0, 1]);
    }

    #[test]
    fn split_front_prefers_diversity() {
        // Front 0: one point. Front 1: a cluster near (1,10) and one
        // outlier near (10,1); selecting 2 from front 1 must include the
        // outlier for spread.
        let objs = vec![
            vec![0.5, 0.5],   // front 0
            vec![1.0, 10.0],  // cluster
            vec![1.1, 10.1],  // cluster
            vec![1.2, 10.2],  // cluster
            vec![10.0, 1.0],  // outlier
        ];
        let sel = nsga3_select(&objs, 3);
        assert!(sel.contains(&0));
        assert!(sel.contains(&4), "outlier dropped: {sel:?}");
    }

    fn random_objs(rng: &mut Rng, n: usize, m: usize, dup_prob: f64) -> Vec<Vec<f64>> {
        let mut objs: Vec<Vec<f64>> = Vec::with_capacity(n);
        for i in 0..n {
            if i > 0 && rng.gen_bool(dup_prob) {
                // Duplicate an earlier row to exercise tie handling.
                let j = rng.gen_range(0, i);
                objs.push(objs[j].clone());
            } else {
                objs.push((0..m).map(|_| (rng.gen_range(0, 12) as f64) * 0.5).collect());
            }
        }
        objs
    }

    #[test]
    fn ens_fronts_match_naive_sort() {
        let mut ws = SelectionWorkspace::new();
        let mut rng = Rng::seed_from_u64(71);
        for _ in 0..80 {
            let n = rng.gen_range(1, 40);
            let m = rng.gen_range(1, 5);
            let objs = random_objs(&mut rng, n, m, 0.2);
            let mut naive = fast_non_dominated_sort(&objs);
            for f in &mut naive {
                f.sort_unstable();
            }
            let ens = ws.non_dominated_fronts(&objs);
            assert_eq!(ens, naive, "objs {objs:?}");
        }
    }

    #[test]
    fn workspace_select_matches_reference() {
        let mut ws = SelectionWorkspace::new();
        let mut rng = Rng::seed_from_u64(72);
        for _ in 0..80 {
            let n = rng.gen_range(2, 40);
            let m = rng.gen_range(2, 5);
            let objs = random_objs(&mut rng, n, m, 0.2);
            let k = rng.gen_range(1, n);
            let reference = nsga3_select(&objs, k);
            let fast = ws.select_objs(&objs, k);
            assert_eq!(fast, reference, "n={n} m={m} k={k} objs {objs:?}");
        }
    }

    #[test]
    fn workspace_select_replay_is_allocation_free() {
        // Replaying the same input after a warm-up call must allocate
        // nothing: every scratch buffer retains capacity and the refs cache
        // hits. (The population-512 version lives in tests/batch_eval.rs.)
        let mut rng = Rng::seed_from_u64(9);
        let objs: Vec<Vec<f64>> = (0..64)
            .map(|_| (0..4).map(|_| rng.gen_f64()).collect())
            .collect();
        let flat: Vec<f64> = objs.iter().flatten().copied().collect();
        let mut ws = SelectionWorkspace::new();
        let expect = ws.select(&flat, 4, 24).to_vec();
        let before = crate::util::alloc::thread_allocations();
        let got_len = ws.select(&flat, 4, 24).len();
        let after = crate::util::alloc::thread_allocations();
        assert_eq!(after - before, 0, "steady-state selection allocated");
        assert_eq!(got_len, expect.len());
        assert_eq!(ws.select(&flat, 4, 24), expect.as_slice());
    }
}
