//! NSGA-III environmental selection (Deb & Jain 2013), the population
//! replacement the paper uses ("the population is updated using the NSGA3
//! algorithm", §4.3).
//!
//! Pipeline: fast non-dominated sort → fill whole fronts while they fit →
//! for the splitting front, normalize objectives, associate individuals with
//! Das–Dennis reference directions, and fill by niche count (preferring
//! under-represented directions, closest-distance first).

/// Pareto dominance for minimization objectives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dominance {
    Dominates,
    DominatedBy,
    Incomparable,
}

/// Compare two objective vectors (all objectives minimized).
pub fn dominance(a: &[f64], b: &[f64]) -> Dominance {
    let mut a_better = false;
    let mut b_better = false;
    for (&x, &y) in a.iter().zip(b) {
        if x < y {
            a_better = true;
        } else if y < x {
            b_better = true;
        }
    }
    match (a_better, b_better) {
        (true, false) => Dominance::Dominates,
        (false, true) => Dominance::DominatedBy,
        _ => Dominance::Incomparable,
    }
}

/// Fast non-dominated sort: returns fronts (vectors of indices), best first.
pub fn fast_non_dominated_sort(objs: &[Vec<f64>]) -> Vec<Vec<usize>> {
    let n = objs.len();
    let mut dominated_by: Vec<Vec<usize>> = vec![Vec::new(); n]; // i dominates these
    let mut dom_count = vec![0usize; n];
    for i in 0..n {
        for j in (i + 1)..n {
            match dominance(&objs[i], &objs[j]) {
                Dominance::Dominates => {
                    dominated_by[i].push(j);
                    dom_count[j] += 1;
                }
                Dominance::DominatedBy => {
                    dominated_by[j].push(i);
                    dom_count[i] += 1;
                }
                Dominance::Incomparable => {}
            }
        }
    }
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    let mut current: Vec<usize> = (0..n).filter(|&i| dom_count[i] == 0).collect();
    while !current.is_empty() {
        let mut next = Vec::new();
        for &i in &current {
            for &j in &dominated_by[i] {
                dom_count[j] -= 1;
                if dom_count[j] == 0 {
                    next.push(j);
                }
            }
        }
        fronts.push(std::mem::take(&mut current));
        current = next;
    }
    fronts
}

/// Das–Dennis reference directions on the unit simplex with `divisions`
/// gaps per objective (`m` objectives).
pub fn reference_points(m: usize, divisions: usize) -> Vec<Vec<f64>> {
    let mut out = Vec::new();
    let mut point = vec![0usize; m];
    fn recurse(m: usize, left: usize, dim: usize, point: &mut Vec<usize>, out: &mut Vec<Vec<f64>>, divisions: usize) {
        if dim == m - 1 {
            point[dim] = left;
            out.push(point.iter().map(|&x| x as f64 / divisions as f64).collect());
            return;
        }
        for v in 0..=left {
            point[dim] = v;
            recurse(m, left - v, dim + 1, point, out, divisions);
        }
    }
    recurse(m, divisions, 0, &mut point, &mut out, divisions);
    out
}

/// Perpendicular distance from (normalized) objective vector `f` to the ray
/// through reference direction `w`.
fn perpendicular_distance(f: &[f64], w: &[f64]) -> f64 {
    let wdotf: f64 = w.iter().zip(f).map(|(a, b)| a * b).sum();
    let wnorm2: f64 = w.iter().map(|a| a * a).sum();
    if wnorm2 <= 0.0 {
        return f.iter().map(|a| a * a).sum::<f64>().sqrt();
    }
    let t = wdotf / wnorm2;
    f.iter()
        .zip(w)
        .map(|(fi, wi)| (fi - t * wi).powi(2))
        .sum::<f64>()
        .sqrt()
}

/// NSGA-III environmental selection: choose `k` survivors from `objs`
/// (minimization). Deterministic given input order (ties broken by index;
/// niching picks the closest individual rather than a random one — a common
/// deterministic variant).
pub fn nsga3_select(objs: &[Vec<f64>], k: usize) -> Vec<usize> {
    let n = objs.len();
    if k >= n {
        return (0..n).collect();
    }
    let m = objs.first().map(|o| o.len()).unwrap_or(0);
    let fronts = fast_non_dominated_sort(objs);

    let mut chosen: Vec<usize> = Vec::with_capacity(k);
    let mut split_front: Option<Vec<usize>> = None;
    for front in &fronts {
        if chosen.len() + front.len() <= k {
            chosen.extend_from_slice(front);
        } else {
            split_front = Some(front.clone());
            break;
        }
    }
    let Some(split) = split_front else {
        return chosen;
    };
    let need = k - chosen.len();

    // Normalize over the union of chosen + split using ideal/nadir estimates.
    let pool: Vec<usize> = chosen.iter().chain(&split).copied().collect();
    let mut ideal = vec![f64::INFINITY; m];
    let mut nadir = vec![f64::NEG_INFINITY; m];
    for &i in &pool {
        for d in 0..m {
            ideal[d] = ideal[d].min(objs[i][d]);
            nadir[d] = nadir[d].max(objs[i][d]);
        }
    }
    let normalize = |i: usize| -> Vec<f64> {
        (0..m)
            .map(|d| {
                let range = (nadir[d] - ideal[d]).max(1e-12);
                (objs[i][d] - ideal[d]) / range
            })
            .collect()
    };

    // Das–Dennis directions sized to the population (>= need niches).
    let mut divisions = 4;
    while reference_points(m, divisions).len() < need.max(4) && divisions < 32 {
        divisions += 1;
    }
    let refs = reference_points(m, divisions);

    // Associate: everyone already chosen contributes to niche counts.
    let associate = |i: usize| -> (usize, f64) {
        let f = normalize(i);
        let mut best = (0usize, f64::INFINITY);
        for (r, w) in refs.iter().enumerate() {
            let d = perpendicular_distance(&f, w);
            if d < best.1 {
                best = (r, d);
            }
        }
        best
    };
    let mut niche_count = vec![0usize; refs.len()];
    for &i in &chosen {
        let (r, _) = associate(i);
        niche_count[r] += 1;
    }
    // Candidates from the split front with their (ref, dist).
    let cands: Vec<(usize, usize, f64)> = split.iter().map(|&i| {
        let (r, d) = associate(i);
        (i, r, d)
    }).collect();

    // Niching: repeatedly take from the least-crowded niche.
    let mut taken = vec![false; cands.len()];
    for _ in 0..need {
        // Find the niche with minimal count that still has candidates.
        let mut best_niche: Option<usize> = None;
        for (ci, &(_, r, _)) in cands.iter().enumerate() {
            if taken[ci] {
                continue;
            }
            match best_niche {
                None => best_niche = Some(r),
                Some(bn) => {
                    if niche_count[r] < niche_count[bn] {
                        best_niche = Some(r);
                    }
                }
            }
        }
        let Some(niche) = best_niche else { break };
        // Closest candidate in that niche.
        let mut pick: Option<(usize, f64)> = None;
        for (ci, &(_, r, d)) in cands.iter().enumerate() {
            if taken[ci] || r != niche {
                continue;
            }
            if pick.map(|(_, pd)| d < pd).unwrap_or(true) {
                pick = Some((ci, d));
            }
        }
        let (ci, _) = pick.expect("niche had a candidate");
        taken[ci] = true;
        niche_count[cands[ci].1] += 1;
        chosen.push(cands[ci].0);
    }
    chosen
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominance_basics() {
        assert_eq!(dominance(&[1.0, 1.0], &[2.0, 2.0]), Dominance::Dominates);
        assert_eq!(dominance(&[2.0, 2.0], &[1.0, 1.0]), Dominance::DominatedBy);
        assert_eq!(dominance(&[1.0, 2.0], &[2.0, 1.0]), Dominance::Incomparable);
        assert_eq!(dominance(&[1.0, 1.0], &[1.0, 1.0]), Dominance::Incomparable);
    }

    #[test]
    fn sort_layers_fronts() {
        let objs = vec![
            vec![1.0, 1.0], // front 0
            vec![2.0, 2.0], // front 1 (dominated by 0)
            vec![0.5, 3.0], // front 0 (incomparable with 0)
            vec![3.0, 3.0], // front 2
        ];
        let fronts = fast_non_dominated_sort(&objs);
        assert_eq!(fronts[0], vec![0, 2]);
        assert_eq!(fronts[1], vec![1]);
        assert_eq!(fronts[2], vec![3]);
    }

    #[test]
    fn reference_points_simplex() {
        let refs = reference_points(2, 4);
        assert_eq!(refs.len(), 5); // C(4+1, 1)
        for r in &refs {
            let s: f64 = r.iter().sum();
            assert!((s - 1.0).abs() < 1e-12);
        }
        let refs3 = reference_points(3, 4);
        assert_eq!(refs3.len(), 15); // C(6,2)
    }

    #[test]
    fn select_never_drops_first_front_when_it_fits() {
        let objs = vec![
            vec![1.0, 5.0],
            vec![5.0, 1.0],
            vec![3.0, 3.0],
            vec![6.0, 6.0], // dominated
            vec![7.0, 7.0], // dominated
        ];
        let sel = nsga3_select(&objs, 3);
        assert!(sel.contains(&0) && sel.contains(&1) && sel.contains(&2), "{sel:?}");
    }

    #[test]
    fn select_respects_k() {
        let objs: Vec<Vec<f64>> = (0..20).map(|i| vec![i as f64, (20 - i) as f64]).collect();
        let sel = nsga3_select(&objs, 7);
        assert_eq!(sel.len(), 7);
        // All on one front; niching must spread across the extremes.
        assert!(sel.contains(&0) || sel.contains(&1));
        assert!(sel.contains(&19) || sel.contains(&18));
    }

    #[test]
    fn select_everything_when_k_ge_n() {
        let objs = vec![vec![1.0], vec![2.0]];
        assert_eq!(nsga3_select(&objs, 5), vec![0, 1]);
    }

    #[test]
    fn split_front_prefers_diversity() {
        // Front 0: one point. Front 1: a cluster near (1,10) and one
        // outlier near (10,1); selecting 2 from front 1 must include the
        // outlier for spread.
        let objs = vec![
            vec![0.5, 0.5],   // front 0
            vec![1.0, 10.0],  // cluster
            vec![1.1, 10.1],  // cluster
            vec![1.2, 10.2],  // cluster
            vec![10.0, 1.0],  // outlier
        ];
        let sel = nsga3_select(&objs, 3);
        assert!(sel.contains(&0));
        assert!(sel.contains(&4), "outlier dropped: {sel:?}");
    }
}
