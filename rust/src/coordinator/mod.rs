//! The Coordinator (paper §5.1–5.2, Fig 9): external interface of the
//! Runtime. It queues client inference requests, finds schedulable subgraphs
//! whose data dependencies are resolved, dispatches tasks to the per-
//! processor Workers (in priority order — the pseudo-preemption mechanism),
//! collects completions, and returns results when every subgraph of a
//! request has finished.

mod request;

pub use request::{CompletionMsg, GroupRequest, RequestId, TaskMsg, TensorInput};

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use std::sync::mpsc::{Receiver, Sender};

use crate::engine::Engine;
use crate::graph::{Network, Partition, Subgraph, SubgraphId};
use crate::mem::{SharedArena, TensorPool};
use crate::worker::Worker;
use crate::{DataType, ExecConfig};

/// A registered solution for one network: its partition and per-subgraph
/// exec configs (from the Static Analyzer).
#[derive(Clone)]
pub struct NetworkSolution {
    pub network: Arc<Network>,
    pub partition: Arc<Partition>,
    pub configs: Vec<ExecConfig>,
    pub priority: usize,
}

impl NetworkSolution {
    pub fn subgraph(&self, id: SubgraphId) -> &Subgraph {
        &self.partition.subgraphs[id.0]
    }
}

/// Options mirroring the runtime ablation (paper §5.3).
#[derive(Debug, Clone)]
pub struct RuntimeOptions {
    pub tensor_pool: bool,
    pub zero_copy: bool,
}

impl Default for RuntimeOptions {
    fn default() -> Self {
        RuntimeOptions { tensor_pool: true, zero_copy: true }
    }
}

/// Per-request live state.
struct LiveRequest {
    /// Remaining dependency count per subgraph.
    pending_deps: Vec<usize>,
    /// Completed subgraphs.
    done: Vec<bool>,
    remaining: usize,
}

/// Record of one served group request (all member networks done).
#[derive(Debug, Clone)]
pub struct ServedRequest {
    pub group: usize,
    pub request: u64,
    /// Makespan: max finish over member networks − submission, seconds.
    pub makespan: f64,
}

/// The Coordinator. Owns the workers and the dispatch loop state.
pub struct Coordinator {
    solutions: Vec<NetworkSolution>,
    workers: Vec<Worker>,
    completion_rx: Receiver<CompletionMsg>,
    completion_tx: Sender<CompletionMsg>,
    pool: TensorPool,
    pub arena: SharedArena,
    options: RuntimeOptions,
    /// request key = (group, request_seq, network) -> live state.
    live: HashMap<(usize, u64, usize), LiveRequest>,
    /// group request -> (outstanding networks, submit instant, last finish).
    group_progress: HashMap<(usize, u64), (usize, Instant, Option<Instant>)>,
    /// Cross-subgraph tensors in flight: (group, seq, network, src layer) ->
    /// published slice. Entries are dropped when the request completes.
    tensors: HashMap<(usize, u64, usize, usize), crate::mem::SharedSlice>,
    served: Vec<ServedRequest>,
    next_request: u64,
}

impl Coordinator {
    /// Initialize the runtime: register solutions, spawn workers
    /// (paper §5.2 "Initialization").
    pub fn new(
        solutions: Vec<NetworkSolution>,
        engine: Arc<dyn Engine>,
        options: RuntimeOptions,
    ) -> Coordinator {
        let (completion_tx, completion_rx) = std::sync::mpsc::channel();
        let pool = TensorPool::new(options.tensor_pool);
        // Pre-allocate pool buffers for every cut-edge tensor (paper:
        // "initially pre-allocate buffers").
        if options.tensor_pool {
            for sol in &solutions {
                for &e in &sol.partition.cut_edges {
                    let edge = sol.network.edge(e);
                    let bytes = sol.network.layer(edge.src).out_bytes(DataType::Fp16);
                    pool.preallocate(bytes, 2);
                }
            }
        }
        let workers = crate::worker::spawn_all(&engine, &pool, &completion_tx);
        let arena = SharedArena::new(options.zero_copy);
        Coordinator {
            solutions,
            workers,
            completion_rx,
            completion_tx,
            pool,
            arena,
            options,
            live: HashMap::new(),
            group_progress: HashMap::new(),
            tensors: HashMap::new(),
            served: Vec::new(),
            next_request: 0,
        }
    }

    /// Submit one synchronized group request: every network in `members`
    /// gets an inference request with the same input timestamp (paper's
    /// model-group semantics). Returns the request sequence number.
    pub fn submit_group(&mut self, group: usize, members: &[usize]) -> u64 {
        let seq = self.next_request;
        self.next_request += 1;
        let now = Instant::now();
        self.group_progress.insert((group, seq), (members.len(), now, None));
        for &net_idx in members {
            let sol = self.solutions[net_idx].clone();
            let n_sg = sol.partition.subgraphs.len();
            let mut pending: Vec<usize> = vec![0; n_sg];
            for sg in &sol.partition.subgraphs {
                pending[sg.id.0] = sg.deps.len();
            }
            let live = LiveRequest {
                pending_deps: pending,
                done: vec![false; n_sg],
                remaining: n_sg,
            };
            self.live.insert((group, seq, net_idx), live);
            // Dispatch all root subgraphs immediately (paper Fig 9 step ③).
            for sg in &sol.partition.subgraphs {
                if sg.deps.is_empty() {
                    self.dispatch(&sol, group, seq, net_idx, sg.id);
                }
            }
        }
        seq
    }

    fn dispatch(&self, sol: &NetworkSolution, group: usize, seq: u64, net_idx: usize, sg: SubgraphId) {
        let subgraph = Arc::new(sol.subgraph(sg).clone());
        let config = sol.configs[sg.0];
        // Gather input tensors in the engine's consumption order: for each
        // member layer (subgraph order), each predecessor outside the
        // subgraph contributes one external input; root layers with no
        // predecessors consume the network input.
        let net = &sol.network;
        let mut inputs: Vec<TensorInput> = Vec::new();
        for &l in &subgraph.layers {
            let preds = net.predecessors(l);
            if preds.is_empty() {
                // Synthesize the network input (a camera frame stand-in).
                let shape = crate::engine::input_shape(net, l, None);
                let elements: usize = shape.iter().product();
                let (bytes, scale) =
                    crate::quant::quantize(&vec![0.1f32; elements], DataType::Fp16);
                inputs.push(TensorInput::from_vec(bytes, DataType::Fp16, scale));
                continue;
            }
            for &pred in preds {
                if subgraph.contains(pred) {
                    continue; // internal edge; the engine chains it itself
                }
                let key = (group, seq, net_idx, pred.0);
                let slice = match self.tensors.get(&key) {
                    Some(s) => {
                        if self.options.zero_copy {
                            s.clone() // view moves, no bytes
                        } else {
                            // Unmarshal: a real copy through the arena.
                            crate::mem::SharedSlice::from_vec(self.arena.consume(s))
                        }
                    }
                    None => {
                        // Producer output unavailable (time-only engine that
                        // reported no tensors): synthesize a zero buffer of
                        // the right size so staging costs stay faithful.
                        let bytes = net.layer(pred).out_bytes(DataType::Fp16);
                        crate::mem::SharedSlice::from_vec(vec![0u8; bytes])
                    }
                };
                inputs.push(TensorInput { slice, dtype: DataType::Fp16, scale: 1.0 });
            }
        }
        let task = TaskMsg {
            request: pack_request(group, seq, net_idx),
            network: sol.network.clone(),
            network_idx: net_idx,
            subgraph,
            config,
            inputs,
        };
        self.workers[config.processor.index()].submit(task);
    }

    /// Pump completions until all outstanding requests are served or the
    /// timeout elapses. Returns the number of completions processed.
    pub fn pump(&mut self, timeout: std::time::Duration) -> usize {
        let deadline = Instant::now() + timeout;
        let mut processed = 0;
        while !self.live.is_empty() && Instant::now() < deadline {
            match self.completion_rx.recv_timeout(std::time::Duration::from_millis(20)) {
                Ok(msg) => {
                    self.handle_completion(msg);
                    processed += 1;
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        processed
    }

    fn handle_completion(&mut self, msg: CompletionMsg) {
        let (group, seq, net_idx) = unpack_request(msg.request);
        let now = Instant::now();
        let Some(live) = self.live.get_mut(&(group, seq, net_idx)) else {
            return;
        };
        if live.done[msg.subgraph.0] {
            return; // duplicate (should not happen; defensive)
        }
        live.done[msg.subgraph.0] = true;
        live.remaining -= 1;

        let sol = self.solutions[net_idx].clone();

        // Publish this subgraph's boundary tensors into the shared arena
        // (Fig 9 ⑤): real engine outputs when available (PjrtEngine), or
        // synthesized buffers of the correct size (SimEngine). Zero-copy
        // publishes views; copying mode pays real marshalling memcpy.
        {
            let completed = sol.subgraph(msg.subgraph);
            // Engine outputs come in subgraph-layer order for boundary
            // layers (network outputs or layers with external consumers) —
            // this filter must match PjrtEngine's is_boundary rule.
            let sink_layers: Vec<usize> = completed
                .layers
                .iter()
                .filter(|l| {
                    let succs = sol.network.successors(**l);
                    succs.is_empty() || succs.iter().any(|s| !completed.contains(*s))
                })
                .map(|l| l.0)
                .collect();
            for (i, &layer) in sink_layers.iter().enumerate() {
                // Only keep tensors some other subgraph will consume.
                let consumed_elsewhere = sol
                    .network
                    .successors(crate::graph::LayerId(layer))
                    .iter()
                    .any(|s| sol.partition.owner_of(*s) != msg.subgraph);
                if !consumed_elsewhere {
                    continue;
                }
                let payload = match msg.outputs.get(i) {
                    Some(t) if !t.is_empty() => crate::quant::quantize(t, DataType::Fp16).0,
                    _ => vec![0u8; sol.network.layer(crate::graph::LayerId(layer)).out_bytes(DataType::Fp16)],
                };
                let slice = self.arena.publish(payload);
                self.tensors.insert((group, seq, net_idx, layer), slice);
            }
        }

        // Resolve dependents; dispatch the newly schedulable (Fig 9 ② → ③).
        let mut to_dispatch: Vec<SubgraphId> = Vec::new();
        for sg in &sol.partition.subgraphs {
            if sg.deps.contains(&msg.subgraph) {
                let live = self.live.get_mut(&(group, seq, net_idx)).unwrap();
                live.pending_deps[sg.id.0] -= 1;
                if live.pending_deps[sg.id.0] == 0 {
                    to_dispatch.push(sg.id);
                }
            }
        }
        for &sg in &to_dispatch {
            self.dispatch(&sol, group, seq, net_idx, sg);
        }

        let live = self.live.get_mut(&(group, seq, net_idx)).unwrap();
        if live.remaining == 0 {
            self.live.remove(&(group, seq, net_idx));
            // Return this request's in-flight tensors (pool/arena reuse).
            self.tensors.retain(|k, _| !(k.0 == group && k.1 == seq && k.2 == net_idx));
            // Group bookkeeping: when the last member network finishes,
            // record the group makespan (paper §6.2: max Tf − min Ts).
            let entry = self.group_progress.get_mut(&(group, seq)).unwrap();
            entry.0 -= 1;
            entry.2 = Some(entry.2.map_or(now, |f| f.max(now)));
            if entry.0 == 0 {
                let (_, start, finish) = self.group_progress.remove(&(group, seq)).unwrap();
                self.served.push(ServedRequest {
                    group,
                    request: seq,
                    makespan: finish.unwrap().duration_since(start).as_secs_f64(),
                });
            }
        }
    }

    /// The registered per-network solutions.
    pub fn solutions(&self) -> &[NetworkSolution] {
        &self.solutions
    }

    /// Served request records so far.
    pub fn served(&self) -> &[ServedRequest] {
        &self.served
    }

    /// Outstanding (unfinished) network-requests.
    pub fn outstanding(&self) -> usize {
        self.live.len()
    }

    /// Tensor-pool statistics (Table 5 columns).
    pub fn pool_stats(&self) -> (f64, u64, f64, f64) {
        self.pool.stats().snapshot()
    }

    /// Shut workers down and join their threads.
    pub fn shutdown(self) {
        for w in self.workers {
            w.shutdown();
        }
        drop(self.completion_tx);
    }
}

/// Pack (group, seq, network) into the u64 request tag carried by tasks.
fn pack_request(group: usize, seq: u64, network: usize) -> u64 {
    ((group as u64) << 48) | ((network as u64) << 40) | (seq & 0xff_ffff_ffff)
}

fn unpack_request(tag: u64) -> (usize, u64, usize) {
    (
        (tag >> 48) as usize,
        tag & 0xff_ffff_ffff,
        ((tag >> 40) & 0xff) as usize,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::SimEngine;
    use crate::ga::decode_network;
    use crate::graph::Network;
    use crate::models::build_model;
    use crate::perf::PerfModel;
    use crate::Processor;

    fn solution_for(net: Network, priority: usize, cuts: Option<Vec<bool>>) -> NetworkSolution {
        let cuts = cuts.unwrap_or_else(|| vec![false; net.num_edges()]);
        let genes = crate::ga::NetworkGenes {
            cuts,
            mapping: vec![Processor::Npu; net.num_layers()],
        };
        let part = decode_network(&net, &genes);
        let configs = part
            .subgraphs
            .iter()
            .map(|sg| ExecConfig::default_for(sg.processor))
            .collect();
        NetworkSolution {
            network: Arc::new(net),
            partition: Arc::new(part),
            configs,
            priority,
        }
    }

    fn sim_coordinator(solutions: Vec<NetworkSolution>, opts: RuntimeOptions) -> Coordinator {
        let pm = Arc::new(PerfModel::paper_calibrated());
        let engine: Arc<dyn Engine> = Arc::new(SimEngine::new(pm, 0.0, false, 7));
        Coordinator::new(solutions, engine, opts)
    }

    #[test]
    fn single_request_completes() {
        let sol = solution_for(build_model(0, 0), 0, None);
        let mut coord = sim_coordinator(vec![sol], RuntimeOptions::default());
        coord.submit_group(0, &[0]);
        coord.pump(std::time::Duration::from_secs(5));
        assert_eq!(coord.served().len(), 1);
        assert_eq!(coord.outstanding(), 0);
        assert!(coord.served()[0].makespan > 0.0);
        coord.shutdown();
    }

    #[test]
    fn partitioned_request_respects_dependencies() {
        // Cut the first edge: at least two subgraphs in sequence.
        let net = build_model(0, 1);
        let mut cuts = vec![false; net.num_edges()];
        cuts[0] = true;
        let sol = solution_for(net, 0, Some(cuts));
        assert!(sol.partition.subgraphs.len() >= 2);
        let mut coord = sim_coordinator(vec![sol], RuntimeOptions::default());
        coord.submit_group(0, &[0]);
        coord.pump(std::time::Duration::from_secs(5));
        assert_eq!(coord.served().len(), 1);
        coord.shutdown();
    }

    #[test]
    fn group_makespan_spans_all_members() {
        let sols = vec![
            solution_for(build_model(0, 0), 0, None),
            solution_for(build_model(1, 6), 1, None), // heavier
        ];
        let mut coord = sim_coordinator(sols, RuntimeOptions::default());
        coord.submit_group(0, &[0, 1]);
        coord.pump(std::time::Duration::from_secs(10));
        assert_eq!(coord.served().len(), 1);
        coord.shutdown();
    }

    #[test]
    fn multiple_requests_all_served() {
        let sol = solution_for(build_model(0, 0), 0, None);
        let mut coord = sim_coordinator(vec![sol], RuntimeOptions::default());
        for _ in 0..5 {
            coord.submit_group(0, &[0]);
        }
        coord.pump(std::time::Duration::from_secs(10));
        assert_eq!(coord.served().len(), 5);
        coord.shutdown();
    }

    #[test]
    fn request_tag_roundtrip() {
        for (g, s, n) in [(0usize, 0u64, 0usize), (1, 12345, 5), (3, 999_999, 8)] {
            assert_eq!(unpack_request(pack_request(g, s, n)), (g, s, n));
        }
    }
}
