//! The Coordinator (paper §5.1–5.2, Fig 9): external interface of the
//! Runtime. It admits client inference requests (open-loop arrivals with
//! optional SLO deadlines), holds schedulable subgraphs in per-processor
//! **priority-ordered ready queues**, dispatches one in-flight task per
//! Worker (the pseudo-preemption mechanism: the next subgraph is chosen from
//! the heap at completion time, so a high-priority subgraph never waits
//! behind queued low-priority work), collects completions, and records a
//! [`ServedRequest`] — with deadline/violation accounting — when every
//! subgraph of a group request has finished.
//!
//! ## Event-driven serving (this PR)
//!
//! The former submit-then-pump loop (submit everything, then drain) became an
//! event-driven core with two drivers:
//!
//! * **wall clock** — [`Coordinator::pump`]/[`Coordinator::poll`] dispatch
//!   ready work to idle workers and drain completions; timestamps come from
//!   the pluggable [`crate::serve::Clock`].
//! * **virtual clock** — [`Coordinator::run_virtual`] runs a deterministic
//!   discrete-event schedule *through the real Coordinator/Worker/Engine
//!   stack*: arrivals release requests at their virtual timestamps, each
//!   dispatched task executes immediately on its worker (one task in flight
//!   system-wide, so engine noise draws are sequential and seed-
//!   deterministic) and its reported duration schedules the completion
//!   event. Same seed ⇒ bit-identical [`ServedRequest`] logs.
//!
//! Overload is governed by [`OverloadPolicy`]: queue everything (the paper's
//! implicit behavior) or drop arrivals past an in-flight cap (admission
//! control for sustained-overload scenarios).

mod request;

pub use request::{CompletionMsg, GroupRequest, RequestId, TaskMsg, TensorInput};

use std::cmp::Ordering as CmpOrdering;
use std::collections::{BinaryHeap, HashMap};
use std::sync::Arc;
use std::time::Instant;

use std::sync::mpsc::{Receiver, Sender};

use crate::comm::CommModel;
use crate::engine::Engine;
use crate::graph::{Network, Partition, Subgraph, SubgraphId};
use crate::mem::{SharedArena, TensorPool};
use crate::perf::PerfModel;
use crate::serve::{Arrival, Clock, VirtualClock, WallClock};
use crate::telemetry::{Telemetry, TelemetryEvent, TelemetryRx};
use crate::worker::Worker;
use crate::{DataType, ExecConfig, Processor};

/// A registered solution for one network: its partition and per-subgraph
/// exec configs (from the Static Analyzer).
#[derive(Clone)]
pub struct NetworkSolution {
    pub network: Arc<Network>,
    pub partition: Arc<Partition>,
    pub configs: Vec<ExecConfig>,
    pub priority: usize,
}

impl NetworkSolution {
    pub fn subgraph(&self, id: SubgraphId) -> &Subgraph {
        &self.partition.subgraphs[id.0]
    }
}

/// Options mirroring the runtime ablation (paper §5.3).
#[derive(Debug, Clone)]
pub struct RuntimeOptions {
    pub tensor_pool: bool,
    pub zero_copy: bool,
    /// Virtual-clock dispatch-overhead calibration: seconds of coordinator
    /// cost charged to every task start in [`Coordinator::run_virtual`]
    /// (the analytic simulator prices ~10 µs/task; `1e-5` reproduces it).
    /// The default `0.0` is bit-identical to the uncalibrated virtual
    /// path; any positive value inflates makespans monotonically. Wall
    /// runs ignore it — they pay the real dispatch cost in real time.
    pub dispatch_overhead: f64,
}

impl Default for RuntimeOptions {
    fn default() -> Self {
        RuntimeOptions { tensor_pool: true, zero_copy: true, dispatch_overhead: 0.0 }
    }
}

/// What to do with an arriving group request when the runtime is loaded.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OverloadPolicy {
    /// Admit every arrival; the backlog may grow without bound (the paper's
    /// implicit closed-world behavior, and the default).
    Queue,
    /// Drop an arriving group request outright when `max_inflight` group
    /// requests are already admitted and unfinished (admission control).
    DropAfter { max_inflight: usize },
}

/// Tunables of the self-healing machinery
/// ([`Coordinator::enable_recovery`]). A failed task attempt is retried
/// with exponential backoff up to `max_retries` times; exhausting the
/// budget remaps the subgraph onto the next-best processor (fresh budget);
/// a failure *after* a remap sheds the whole group request. The watchdog
/// aborts any task running longer than `watchdog_factor ×` its profiled
/// duration — the factor must clear the noise model's worst case (CPU
/// spikes top out at 2.5×) so healthy tasks never trip it.
#[derive(Debug, Clone)]
pub struct RecoveryOptions {
    /// Failed attempts tolerated per (task, processor) before remapping.
    pub max_retries: u32,
    /// First backoff = `backoff_factor ×` the task's profiled duration;
    /// doubles per subsequent attempt.
    pub backoff_factor: f64,
    /// Watchdog deadline as a multiple of the profiled duration.
    pub watchdog_factor: f64,
}

impl Default for RecoveryOptions {
    fn default() -> Self {
        RecoveryOptions { max_retries: 2, backoff_factor: 0.5, watchdog_factor: 4.0 }
    }
}

/// Per-group-request fault accounting, folded into [`ServedRequest`] when
/// the request completes.
#[derive(Debug, Clone, Copy, Default)]
struct RequestFaults {
    retries: u32,
    remaps: u32,
    degraded: f64,
}

/// Self-healing state (present only when recovery is enabled; `None` keeps
/// the dispatch path bit-identical to the recovery-less runtime).
struct Recovery {
    perf: Arc<PerfModel>,
    opts: RecoveryOptions,
    /// Profiled nominal duration per `[net_idx][subgraph]` under the
    /// solution-assigned config — the watchdog/backoff baseline.
    profiled: Vec<Vec<f64>>,
    /// Failed attempts per (group, seq, net_idx, subgraph).
    attempts: HashMap<(usize, u64, usize, usize), u32>,
    /// Remap overrides per (group, seq, net_idx, subgraph).
    remapped: HashMap<(usize, u64, usize, usize), ExecConfig>,
    /// Accumulated fault record per (group, seq).
    request_faults: HashMap<(usize, u64), RequestFaults>,
}

/// What a retry/remap/shed decision resolved to (borrow-scoped helper).
enum FaultAction {
    Retry { backoff: f64 },
    Remap,
    Shed,
}

/// Per-request live state.
struct LiveRequest {
    /// Remaining dependency count per subgraph.
    pending_deps: Vec<usize>,
    /// Completed subgraphs.
    done: Vec<bool>,
    /// Earliest time each subgraph's cross-subgraph inputs are fully
    /// transferred (virtual-clock runs; stays 0 under the wall clock, where
    /// staging costs are paid in real time).
    data_at: Vec<f64>,
    remaining: usize,
}

/// Progress of one admitted group request.
struct GroupProgress {
    outstanding: usize,
    arrival: f64,
    deadline: Option<f64>,
}

/// Record of one served group request (all member networks done). All
/// timestamps are clock seconds (wall seconds under the wall clock,
/// simulated seconds under the virtual clock).
#[derive(Debug, Clone)]
pub struct ServedRequest {
    pub group: usize,
    pub request: u64,
    /// Open-loop arrival timestamp of the request.
    pub arrival: f64,
    /// Timestamp of the last member network finishing.
    pub completion: f64,
    /// Makespan: max finish over member networks − arrival, seconds.
    pub makespan: f64,
    /// Relative SLO deadline (= the group's period in the paper's protocol),
    /// when the load declared one.
    pub deadline: Option<f64>,
    /// `makespan > deadline` (always false for deadline-less requests).
    pub violated: bool,
    /// Failed attempts re-tried in place (recovery enabled; else 0).
    pub retries: u32,
    /// Subgraph tasks remapped to another processor (recovery enabled).
    pub remaps: u32,
    /// Processor-seconds lost to failed attempts and retry backoff.
    pub degraded: f64,
}

/// Why a group request was dropped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DropReason {
    /// Rejected at admission by [`OverloadPolicy::DropAfter`].
    Overload,
    /// Shed by recovery: a subgraph task kept failing after retry and
    /// remap, so the whole request was abandoned.
    FaultShed,
}

/// Record of a group request rejected at admission
/// ([`OverloadPolicy::DropAfter`]) or shed by recovery.
#[derive(Debug, Clone)]
pub struct DroppedRequest {
    pub group: usize,
    pub request: u64,
    pub arrival: f64,
    pub reason: DropReason,
}

/// A schedulable subgraph waiting for its processor's worker. Max-heap
/// order = dispatch precedence: lowest solution priority value first, FIFO
/// (insertion order) among equals.
struct ReadyTask {
    precedence: usize,
    order: u64,
    group: usize,
    seq: u64,
    net_idx: usize,
    sg: SubgraphId,
}

impl PartialEq for ReadyTask {
    fn eq(&self, other: &Self) -> bool {
        self.precedence == other.precedence && self.order == other.order
    }
}
impl Eq for ReadyTask {}
impl PartialOrd for ReadyTask {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}
impl Ord for ReadyTask {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        // Reversed: BinaryHeap pops the max, we want the smallest
        // (precedence, insertion order).
        other
            .precedence
            .cmp(&self.precedence)
            .then_with(|| other.order.cmp(&self.order))
    }
}

/// A subgraph made schedulable by a completion, with the time its inputs
/// are fully transferred (≥ the completion time under the virtual clock).
struct ReadySub {
    group: usize,
    seq: u64,
    net_idx: usize,
    sg: SubgraphId,
    ready_at: f64,
}

/// Virtual-run event: arrival, data-ready, or task completion.
struct VEvent {
    time: f64,
    order: u64,
    kind: VEventKind,
}

enum VEventKind {
    Arrival { group: usize, deadline: Option<f64> },
    Ready { group: usize, seq: u64, net_idx: usize, sg: SubgraphId },
    Completion { msg: CompletionMsg },
}

impl PartialEq for VEvent {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.order == other.order
    }
}
impl Eq for VEvent {}
impl PartialOrd for VEvent {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}
impl Ord for VEvent {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        // Reversed (min-heap on (time, insertion order)); event times are
        // always finite.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(CmpOrdering::Equal)
            .then_with(|| other.order.cmp(&self.order))
    }
}

/// The Coordinator. Owns the workers and the event-driven dispatch state.
pub struct Coordinator {
    solutions: Vec<NetworkSolution>,
    workers: Vec<Worker>,
    engine: Arc<dyn Engine>,
    completion_rx: Receiver<CompletionMsg>,
    completion_tx: Sender<CompletionMsg>,
    pool: TensorPool,
    pub arena: SharedArena,
    options: RuntimeOptions,
    clock: Arc<dyn Clock>,
    policy: OverloadPolicy,
    /// request key = (group, request_seq, network) -> live state.
    live: HashMap<(usize, u64, usize), LiveRequest>,
    /// group request -> admission bookkeeping.
    group_progress: HashMap<(usize, u64), GroupProgress>,
    /// Cross-subgraph tensors in flight: (group, seq, network, src layer) ->
    /// published slice. Entries are dropped when the request completes.
    tensors: HashMap<(usize, u64, usize, usize), crate::mem::SharedSlice>,
    /// Per-processor priority-ordered ready queues.
    ready: Vec<BinaryHeap<ReadyTask>>,
    /// One in-flight task per worker (pseudo-preemption granularity).
    busy: Vec<bool>,
    ready_order: u64,
    served: Vec<ServedRequest>,
    dropped: Vec<DroppedRequest>,
    next_request: u64,
    /// Watchdog/retry/remap state; `None` (the default) keeps the dispatch
    /// and completion paths bit-identical to the recovery-less runtime.
    recovery: Option<Recovery>,
    /// The telemetry plane ([`crate::telemetry`]): disarmed (no subscriber)
    /// every emission site is one relaxed atomic load and a branch, so the
    /// dispatch path stays allocation-free and bit-identical.
    telemetry: Telemetry,
}

impl Coordinator {
    /// Initialize the runtime: register solutions, spawn workers
    /// (paper §5.2 "Initialization"). The clock defaults to wall time;
    /// [`Coordinator::run_virtual`] swaps in a virtual clock for the
    /// duration of a deterministic run.
    pub fn new(
        solutions: Vec<NetworkSolution>,
        engine: Arc<dyn Engine>,
        options: RuntimeOptions,
    ) -> Coordinator {
        let (completion_tx, completion_rx) = std::sync::mpsc::channel();
        let pool = TensorPool::new(options.tensor_pool);
        // Pre-allocate pool buffers for every cut-edge tensor (paper:
        // "initially pre-allocate buffers").
        if options.tensor_pool {
            for sol in &solutions {
                for &e in &sol.partition.cut_edges {
                    let edge = sol.network.edge(e);
                    let bytes = sol.network.layer(edge.src).out_bytes(DataType::Fp16);
                    pool.preallocate(bytes, 2);
                }
            }
        }
        let workers = crate::worker::spawn_all(&engine, &pool, &completion_tx);
        let arena = SharedArena::new(options.zero_copy);
        let n_workers = workers.len();
        Coordinator {
            solutions,
            workers,
            engine,
            completion_rx,
            completion_tx,
            pool,
            arena,
            options,
            clock: Arc::new(WallClock::new()),
            policy: OverloadPolicy::Queue,
            live: HashMap::new(),
            group_progress: HashMap::new(),
            tensors: HashMap::new(),
            ready: (0..n_workers).map(|_| BinaryHeap::new()).collect(),
            busy: vec![false; n_workers],
            ready_order: 0,
            served: Vec::new(),
            dropped: Vec::new(),
            next_request: 0,
            recovery: None,
            telemetry: Telemetry::new(),
        }
    }

    /// Attach a telemetry subscriber: subsequent serving activity is
    /// published to the returned [`TelemetryRx`] as [`TelemetryEvent`]s
    /// (non-blocking drain, counted drop-on-full). While no subscriber is
    /// attached the telemetry plane is contractually invisible — see
    /// [`crate::telemetry`].
    pub fn subscribe(&self) -> TelemetryRx {
        self.telemetry.subscribe()
    }

    /// Change the telemetry heartbeat period (clock seconds; default
    /// [`crate::telemetry::DEFAULT_HEARTBEAT_PERIOD`]). Takes effect at the
    /// next load window.
    pub fn set_telemetry_heartbeat(&mut self, period: f64) {
        self.telemetry.set_heartbeat_period(period);
    }

    /// Start a new telemetry load window: heartbeat schedule and ρ
    /// accumulators rewind to t = 0 so warm replays emit the same stream
    /// as fresh deployments. Load drivers call this at load start.
    pub(crate) fn begin_telemetry_window(&mut self) {
        self.telemetry.begin_window();
    }

    /// Emit every telemetry heartbeat due at clock time `now`, carrying the
    /// coordinator-side gauges (ready-queue depths, busy workers, in-flight
    /// group requests). One load + branch when disarmed or not yet due.
    fn telemetry_heartbeat(&mut self, now: f64) {
        if !self.telemetry.heartbeat_due(now) {
            return;
        }
        let mut queue = [0u32; 3];
        for (q, r) in queue.iter_mut().zip(self.ready.iter()) {
            *q = r.len() as u32;
        }
        let busy = self.busy.iter().filter(|&&b| b).count() as u32;
        let in_flight = self.group_progress.len() as u32;
        self.telemetry.emit_heartbeats(now, queue, busy, in_flight);
    }

    /// Turn on the self-healing machinery: per-task watchdog deadlines,
    /// bounded retry with exponential backoff on task failure, and
    /// remap-on-persistent-fault onto the next-best processor (chosen via
    /// `perf`'s per-(subgraph, processor) best-config memo). Profiled
    /// durations are snapshotted per registered subgraph now, so the
    /// completion path never re-profiles. Without this call the runtime
    /// treats task errors exactly as before (logged into the completion,
    /// otherwise ignored).
    pub fn enable_recovery(&mut self, perf: Arc<PerfModel>, opts: RecoveryOptions) {
        let profiled = self
            .solutions
            .iter()
            .map(|sol| {
                sol.partition
                    .subgraphs
                    .iter()
                    .map(|sg| perf.subgraph_time(&sol.network, &sg.layers, sol.configs[sg.id.0]))
                    .collect()
            })
            .collect();
        self.recovery = Some(Recovery {
            perf,
            opts,
            profiled,
            attempts: HashMap::new(),
            remapped: HashMap::new(),
            request_faults: HashMap::new(),
        });
    }

    /// Replace the runtime clock (timestamps of subsequent admissions and
    /// completions).
    pub fn set_clock(&mut self, clock: Arc<dyn Clock>) {
        self.clock = clock;
    }

    /// Current clock reading, seconds.
    pub fn now(&self) -> f64 {
        self.clock.now()
    }

    /// Set the admission policy for subsequent arrivals.
    pub fn set_overload_policy(&mut self, policy: OverloadPolicy) {
        self.policy = policy;
    }

    /// The current admission policy.
    pub fn overload_policy(&self) -> OverloadPolicy {
        self.policy
    }

    /// Submit one synchronized group request *now* with no deadline: every
    /// network in `members` gets an inference request with the same input
    /// timestamp (paper's model-group semantics). Returns the request
    /// sequence number (the request may still be dropped under
    /// [`OverloadPolicy::DropAfter`]).
    pub fn submit_group(&mut self, group: usize, members: &[usize]) -> u64 {
        let now = self.clock.now();
        let seq = self.next_request;
        self.submit_group_at(group, members, now, None);
        seq
    }

    /// Admission (Fig 9 step ①, open-loop): a group request arriving at
    /// `arrival` (clock seconds) with an optional relative SLO deadline.
    /// Under [`OverloadPolicy::DropAfter`] an arrival past the in-flight cap
    /// is recorded in [`Coordinator::dropped`] and rejected. Returns the
    /// sequence number of an admitted request.
    pub fn submit_group_at(
        &mut self,
        group: usize,
        members: &[usize],
        arrival: f64,
        deadline: Option<f64>,
    ) -> Option<u64> {
        let seq = self.next_request;
        self.next_request += 1;
        if let OverloadPolicy::DropAfter { max_inflight } = self.policy {
            if self.group_progress.len() >= max_inflight {
                self.dropped.push(DroppedRequest {
                    group,
                    request: seq,
                    arrival,
                    reason: DropReason::Overload,
                });
                self.telemetry.emit(TelemetryEvent::Dropped {
                    time: arrival,
                    group,
                    request: seq,
                    reason: DropReason::Overload,
                });
                return None;
            }
        }
        self.group_progress.insert(
            (group, seq),
            GroupProgress { outstanding: members.len(), arrival, deadline },
        );
        self.telemetry.emit(TelemetryEvent::Admitted { time: arrival, group, request: seq });
        for &net_idx in members {
            let n_sg = self.solutions[net_idx].partition.subgraphs.len();
            let mut pending: Vec<usize> = vec![0; n_sg];
            for sg in &self.solutions[net_idx].partition.subgraphs {
                pending[sg.id.0] = sg.deps.len();
            }
            let live = LiveRequest {
                pending_deps: pending,
                done: vec![false; n_sg],
                data_at: vec![0.0; n_sg],
                remaining: n_sg,
            };
            self.live.insert((group, seq, net_idx), live);
            // Root subgraphs are schedulable immediately (Fig 9 step ②);
            // they wait in the priority queues for an idle worker.
            let roots: Vec<SubgraphId> = self.solutions[net_idx]
                .partition
                .subgraphs
                .iter()
                .filter(|sg| sg.deps.is_empty())
                .map(|sg| sg.id)
                .collect();
            for sg in roots {
                self.enqueue_ready(group, seq, net_idx, sg);
            }
        }
        Some(seq)
    }

    /// The exec config this task actually runs under: the solution's
    /// assignment, unless recovery has remapped it. The remap lookup is
    /// double-gated (recovery enabled *and* at least one remap recorded) so
    /// the nominal path costs one branch and never hashes.
    fn effective_config(
        &self,
        group: usize,
        seq: u64,
        net_idx: usize,
        sg: SubgraphId,
    ) -> ExecConfig {
        if let Some(rec) = &self.recovery {
            if !rec.remapped.is_empty() {
                if let Some(cfg) = rec.remapped.get(&(group, seq, net_idx, sg.0)) {
                    return *cfg;
                }
            }
        }
        self.solutions[net_idx].configs[sg.0]
    }

    /// Put a schedulable subgraph into its processor's ready queue.
    fn enqueue_ready(&mut self, group: usize, seq: u64, net_idx: usize, sg: SubgraphId) {
        let p = self.effective_config(group, seq, net_idx, sg).processor.index();
        let order = self.ready_order;
        self.ready_order += 1;
        self.ready[p].push(ReadyTask {
            precedence: self.solutions[net_idx].priority,
            order,
            group,
            seq,
            net_idx,
            sg,
        });
    }

    /// Pop the next dispatchable task for processor `p`, skipping tasks
    /// whose request was shed by recovery after they were enqueued. Without
    /// recovery this is a plain pop.
    fn pop_ready(&mut self, p: usize) -> Option<ReadyTask> {
        loop {
            let t = self.ready[p].pop()?;
            if self.recovery.is_none() || self.live.contains_key(&(t.group, t.seq, t.net_idx)) {
                return Some(t);
            }
        }
    }

    /// Dispatch ready subgraphs to idle workers, highest priority first
    /// (Fig 9 step ③). One task in flight per worker: the next choice is
    /// made at completion time, which is what makes the priority order a
    /// pseudo-preemption mechanism. Returns the number dispatched.
    pub fn dispatch_ready(&mut self) -> usize {
        let mut dispatched = 0;
        for p in 0..self.workers.len() {
            if self.busy[p] {
                continue;
            }
            if let Some(t) = self.pop_ready(p) {
                let sol = self.solutions[t.net_idx].clone();
                self.dispatch(&sol, t.group, t.seq, t.net_idx, t.sg);
                self.busy[p] = true;
                dispatched += 1;
            }
        }
        dispatched
    }

    fn dispatch(&self, sol: &NetworkSolution, group: usize, seq: u64, net_idx: usize, sg: SubgraphId) {
        let subgraph = Arc::new(sol.subgraph(sg).clone());
        let config = self.effective_config(group, seq, net_idx, sg);
        // Gather input tensors in the engine's consumption order: for each
        // member layer (subgraph order), each predecessor outside the
        // subgraph contributes one external input; root layers with no
        // predecessors consume the network input.
        let net = &sol.network;
        let mut inputs: Vec<TensorInput> = Vec::new();
        for &l in &subgraph.layers {
            let preds = net.predecessors(l);
            if preds.is_empty() {
                // Synthesize the network input (a camera frame stand-in).
                let shape = crate::engine::input_shape(net, l, None);
                let elements: usize = shape.iter().product();
                let (bytes, scale) =
                    crate::quant::quantize(&vec![0.1f32; elements], DataType::Fp16);
                inputs.push(TensorInput::from_vec(bytes, DataType::Fp16, scale));
                continue;
            }
            for &pred in preds {
                if subgraph.contains(pred) {
                    continue; // internal edge; the engine chains it itself
                }
                let key = (group, seq, net_idx, pred.0);
                let slice = match self.tensors.get(&key) {
                    Some(s) => {
                        if self.options.zero_copy {
                            s.clone() // view moves, no bytes
                        } else {
                            // Unmarshal: a real copy through the arena.
                            crate::mem::SharedSlice::from_vec(self.arena.consume(s))
                        }
                    }
                    None => {
                        // Producer output unavailable (time-only engine that
                        // reported no tensors): synthesize a zero buffer of
                        // the right size so staging costs stay faithful.
                        let bytes = net.layer(pred).out_bytes(DataType::Fp16);
                        crate::mem::SharedSlice::from_vec(vec![0u8; bytes])
                    }
                };
                inputs.push(TensorInput { slice, dtype: DataType::Fp16, scale: 1.0 });
            }
        }
        let task = TaskMsg {
            request: pack_request(group, seq, net_idx),
            network: sol.network.clone(),
            network_idx: net_idx,
            subgraph,
            config,
            inputs,
            start: self.clock.now(),
        };
        self.telemetry.emit(TelemetryEvent::TaskDispatch {
            time: task.start,
            group,
            request: seq,
            network: net_idx,
            subgraph: sg.0,
            processor: config.processor,
        });
        self.workers[config.processor.index()].submit(task);
    }

    /// Wall-clock driver: dispatch and pump completions until all admitted
    /// requests are served or the timeout elapses. Returns the number of
    /// completions processed.
    pub fn pump(&mut self, timeout: std::time::Duration) -> usize {
        let deadline = Instant::now() + timeout;
        let mut processed = 0;
        self.dispatch_ready();
        while !self.live.is_empty() && Instant::now() < deadline {
            if self.telemetry.armed() {
                let now = self.clock.now();
                self.telemetry_heartbeat(now);
            }
            match self.completion_rx.recv_timeout(std::time::Duration::from_millis(20)) {
                Ok(msg) => {
                    let now = self.clock.now();
                    for r in self.handle_completion(msg, now, None) {
                        self.enqueue_ready(r.group, r.seq, r.net_idx, r.sg);
                    }
                    self.dispatch_ready();
                    processed += 1;
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        processed
    }

    /// Finish any outstanding work (dispatch + drain under `timeout`) so
    /// the runtime is idle: no live requests, no busy workers, no pending
    /// completions in the channel. Load drivers call this before taking a
    /// served-log snapshot, so stragglers from earlier traffic are never
    /// attributed to a new load's report. Returns completions processed.
    pub fn settle(&mut self, timeout: std::time::Duration) -> usize {
        if self.live.is_empty() && !self.busy.iter().any(|&b| b) {
            return 0;
        }
        self.pump(timeout)
    }

    /// Return the runtime to its post-construction state **without tearing
    /// the worker threads down**: finish any in-flight work
    /// ([`Coordinator::settle`]), drop straggler completions, then clear the
    /// served/dropped logs, per-request bookkeeping, ready queues, and the
    /// request/dispatch sequence counters. After a reset (plus
    /// [`Engine::reseed`] on stochastic engines) a warm coordinator replays
    /// a load **bit-identically** to a freshly constructed one — the
    /// contract behind probe reuse in
    /// [`crate::serve::saturation_via_runtime`]. The admission policy and
    /// the pool/arena accounting are left as set: loads manage the policy
    /// themselves ([`crate::serve::run_load`] saves/restores it), and the
    /// Table-5 memory statistics deliberately accumulate across loads.
    /// Returns the completions drained while settling.
    pub fn reset(&mut self) -> usize {
        let settled = self.settle(std::time::Duration::from_secs(30));
        // A timed-out settle (wall mode only — virtual runs settle exactly)
        // can leave workers mid-task. Because reset restarts request
        // sequencing at 0, a completion surfacing *after* the clear could
        // alias a post-reset request carrying the same (group, seq,
        // network) tag — so block until every busy worker has reported (or
        // is provably gone) before clearing. Newly-ready dependents are
        // deliberately dropped: the request state they belong to is about
        // to be cleared.
        while self.busy.iter().any(|&b| b) {
            match self.completion_rx.recv_timeout(std::time::Duration::from_secs(30)) {
                Ok(msg) => {
                    let now = self.clock.now();
                    let _ = self.handle_completion(msg, now, None);
                }
                Err(_) => break, // worker dead/hung: nothing more will arrive
            }
        }
        // Drain any completions that raced the settle.
        while self.completion_rx.try_recv().is_ok() {}
        self.live.clear();
        self.group_progress.clear();
        self.tensors.clear();
        for q in &mut self.ready {
            q.clear();
        }
        for b in &mut self.busy {
            *b = false;
        }
        self.ready_order = 0;
        self.served.clear();
        self.dropped.clear();
        self.next_request = 0;
        if let Some(rec) = self.recovery.as_mut() {
            rec.attempts.clear();
            rec.remapped.clear();
            rec.request_faults.clear();
        }
        self.telemetry.begin_window();
        settled
    }

    /// The engine backing this runtime's workers (e.g. to
    /// [`Engine::reseed`] noise between reused-deployment probes).
    pub fn engine(&self) -> &Arc<dyn Engine> {
        &self.engine
    }

    /// Non-blocking wall-clock step: dispatch ready work, drain any
    /// already-available completions. Returns completions processed.
    pub fn poll(&mut self) -> usize {
        let mut processed = 0;
        if self.telemetry.armed() {
            let now = self.clock.now();
            self.telemetry_heartbeat(now);
        }
        loop {
            self.dispatch_ready();
            match self.completion_rx.try_recv() {
                Ok(msg) => {
                    let now = self.clock.now();
                    for r in self.handle_completion(msg, now, None) {
                        self.enqueue_ready(r.group, r.seq, r.net_idx, r.sg);
                    }
                    processed += 1;
                }
                Err(_) => break,
            }
        }
        processed
    }

    /// Deterministic virtual-clock run: an event-driven schedule of
    /// open-loop `arrivals` through the real Coordinator/Worker/Engine
    /// stack. `groups[g]` are the member network indices of group `g`;
    /// `comm` prices cross-subgraph tensor transfers into dependent ready
    /// times (the wall path pays them as real staging time instead).
    ///
    /// The backing engine must not sleep (`SimEngine` time scale 0) for the
    /// run to be fast; correctness only needs the engine's reported
    /// durations. Exactly one task is in flight at any instant, so engine
    /// noise draws happen in a deterministic order: same seed ⇒
    /// bit-identical [`ServedRequest`] logs. Returns the number of group
    /// requests completed during the run.
    pub fn run_virtual(
        &mut self,
        arrivals: &[Arrival],
        groups: &[Vec<usize>],
        comm: &CommModel,
    ) -> usize {
        // Settle any in-flight work from earlier (e.g. a timed-out wall
        // pump): a stale completion in the channel must not be paired with
        // a virtual dispatch, or every subsequent event carries the wrong
        // request's timing.
        self.settle(std::time::Duration::from_secs(30));
        let vclock = Arc::new(VirtualClock::new());
        let vdyn: Arc<dyn Clock> = vclock.clone();
        let prev_clock = std::mem::replace(&mut self.clock, vdyn);
        let served_before = self.served.len();
        // Telemetry heartbeats derive from the virtual event times, so the
        // emitted stream is part of the deterministic-replay contract.
        self.telemetry.begin_window();

        let mut events: BinaryHeap<VEvent> = BinaryHeap::new();
        let mut order: u64 = 0;
        for a in arrivals {
            events.push(VEvent {
                time: a.time,
                order,
                kind: VEventKind::Arrival { group: a.group, deadline: a.deadline },
            });
            order += 1;
        }

        while let Some(ev) = events.pop() {
            let now = ev.time;
            vclock.advance_to(now);
            // Heartbeats due before this event fire first, stamped with
            // their schedule times (deterministic: derived from event times,
            // not the OS clock).
            self.telemetry_heartbeat(now);
            self.process_virtual_event(ev, now, comm, groups, &mut events, &mut order);
            // Drain co-temporal events before dispatching, so a completion
            // and a ready edge at the same instant cannot race the priority
            // decision.
            while events.peek().is_some_and(|e| e.time == now) {
                let ev = events.pop().expect("peeked event");
                self.process_virtual_event(ev, now, comm, groups, &mut events, &mut order);
            }
            // Dispatch phase: fill every idle worker, one task at a time,
            // awaiting each completion immediately (the engine does not
            // sleep) and scheduling it as a future event.
            for p in 0..self.workers.len() {
                if self.busy[p] {
                    continue;
                }
                if let Some(t) = self.pop_ready(p) {
                    let sol = self.solutions[t.net_idx].clone();
                    self.dispatch(&sol, t.group, t.seq, t.net_idx, t.sg);
                    self.busy[p] = true;
                    match self
                        .completion_rx
                        .recv_timeout(std::time::Duration::from_secs(30))
                    {
                        Ok(mut msg) => {
                            // Apply the watchdog *before* scheduling so an
                            // aborted task's completion event lands at its
                            // watchdog deadline, not the stalled finish.
                            self.watchdog_abort(&mut msg);
                            // Dispatch-overhead calibration: charge the
                            // coordinator's per-task dispatch cost to the
                            // task's virtual start, pushing its completion
                            // out by the same amount. Gated so the default
                            // 0.0 replays the uncalibrated schedule
                            // bit-identically.
                            let overhead = self.options.dispatch_overhead;
                            let finish = if overhead > 0.0 {
                                now + overhead + msg.elapsed.max(0.0)
                            } else {
                                now + msg.elapsed.max(0.0)
                            };
                            events.push(VEvent {
                                time: finish,
                                order,
                                kind: VEventKind::Completion { msg },
                            });
                            order += 1;
                        }
                        Err(_) => {
                            // Worker died or stalled: abandon the run with
                            // whatever completed so far.
                            self.busy[p] = false;
                            self.clock = prev_clock;
                            return self.served.len() - served_before;
                        }
                    }
                }
            }
        }

        self.clock = prev_clock;
        self.served.len() - served_before
    }

    fn process_virtual_event(
        &mut self,
        ev: VEvent,
        now: f64,
        comm: &CommModel,
        groups: &[Vec<usize>],
        events: &mut BinaryHeap<VEvent>,
        order: &mut u64,
    ) {
        match ev.kind {
            VEventKind::Arrival { group, deadline } => {
                self.submit_group_at(group, &groups[group], now, deadline);
            }
            VEventKind::Ready { group, seq, net_idx, sg } => {
                self.enqueue_ready(group, seq, net_idx, sg);
            }
            VEventKind::Completion { msg } => {
                for r in self.handle_completion(msg, now, Some(comm)) {
                    if r.ready_at > now {
                        events.push(VEvent {
                            time: r.ready_at,
                            order: *order,
                            kind: VEventKind::Ready {
                                group: r.group,
                                seq: r.seq,
                                net_idx: r.net_idx,
                                sg: r.sg,
                            },
                        });
                        *order += 1;
                    } else {
                        self.enqueue_ready(r.group, r.seq, r.net_idx, r.sg);
                    }
                }
            }
        }
    }

    /// Cost of moving the tensors crossing `from → to` of one network
    /// (virtual-clock runs; the wall path stages them in real time).
    fn transfer_delay(
        &self,
        sol: &NetworkSolution,
        from: SubgraphId,
        to: SubgraphId,
        comm: &CommModel,
    ) -> f64 {
        let mut total = 0.0;
        for &e in &sol.partition.cut_edges {
            let edge = sol.network.edge(e);
            if sol.partition.owner_of(edge.src) == from && sol.partition.owner_of(edge.dst) == to {
                let bytes = sol.network.layer(edge.src).out_bytes(DataType::Fp16);
                let same = sol.configs[from.0].processor == sol.configs[to.0].processor;
                total += if self.options.zero_copy {
                    comm.transfer_cost_zero_copy(bytes, same)
                } else {
                    comm.transfer_cost(bytes, same)
                };
            }
        }
        total
    }

    /// Profiled duration of one live task under its *effective* config —
    /// the solution snapshot normally, recomputed when recovery remapped it.
    /// Recovery must be enabled.
    fn profiled_duration(&self, group: usize, seq: u64, net_idx: usize, sg: SubgraphId) -> f64 {
        let rec = self.recovery.as_ref().expect("recovery enabled");
        if !rec.remapped.is_empty() {
            if let Some(cfg) = rec.remapped.get(&(group, seq, net_idx, sg.0)) {
                let sol = &self.solutions[net_idx];
                return rec.perf.subgraph_time(&sol.network, &sol.subgraph(sg).layers, *cfg);
            }
        }
        rec.profiled[net_idx][sg.0]
    }

    /// Watchdog (recovery only): a completion whose duration exceeds
    /// `watchdog_factor ×` the profiled duration is rewritten into a
    /// failure that consumed exactly the watchdog deadline — as if the
    /// coordinator had aborted the task at its deadline. Idempotent (a
    /// message already marked failed is left alone), one branch when
    /// recovery is off.
    fn watchdog_abort(&self, msg: &mut CompletionMsg) {
        let Some(rec) = &self.recovery else { return };
        if msg.error.is_some() {
            return;
        }
        let (group, seq, net_idx) = unpack_request(msg.request);
        if !self.live.contains_key(&(group, seq, net_idx)) {
            return; // request already gone; nothing to abort against
        }
        let deadline =
            rec.opts.watchdog_factor * self.profiled_duration(group, seq, net_idx, msg.subgraph);
        if msg.elapsed > deadline {
            let ran = msg.elapsed;
            msg.elapsed = deadline;
            msg.outputs.clear();
            msg.error = Some(format!(
                "watchdog: ran {:.3} ms, deadline {:.3} ms",
                ran * 1e3,
                deadline * 1e3
            ));
        }
    }

    /// React to a failed task attempt (recovery only): retry with
    /// exponential backoff while the budget lasts, then remap to the
    /// next-best processor with a fresh budget, then shed the whole group
    /// request. Returns the re-enqueued task (empty on shed). Under the
    /// virtual clock the backoff delays the task's ready event; the wall
    /// drivers re-enqueue immediately (their completions already arrive
    /// late, so the backoff would double-count).
    fn handle_failure(&mut self, msg: &CompletionMsg, now: f64) -> Vec<ReadySub> {
        let (group, seq, net_idx) = unpack_request(msg.request);
        let sg = msg.subgraph;
        if !self.live.contains_key(&(group, seq, net_idx)) {
            return Vec::new(); // already shed or completed
        }
        let profiled = self.profiled_duration(group, seq, net_idx, sg);
        let key = (group, seq, net_idx, sg.0);
        let (action, attempt) = {
            let rec = self.recovery.as_mut().expect("recovery enabled");
            let attempts = rec.attempts.entry(key).or_insert(0);
            *attempts += 1;
            let attempt = *attempts;
            let faults = rec.request_faults.entry((group, seq)).or_default();
            faults.degraded += msg.elapsed.max(0.0);
            let action = if attempt <= rec.opts.max_retries {
                let backoff =
                    rec.opts.backoff_factor * profiled * (1u64 << (attempt - 1)) as f64;
                faults.retries += 1;
                faults.degraded += backoff;
                FaultAction::Retry { backoff }
            } else if !rec.remapped.contains_key(&key) {
                FaultAction::Remap
            } else {
                FaultAction::Shed
            };
            (action, attempt)
        };
        match action {
            FaultAction::Retry { backoff } => {
                self.telemetry.emit(TelemetryEvent::Retry {
                    time: now,
                    group,
                    request: seq,
                    network: net_idx,
                    subgraph: sg.0,
                    attempt,
                    backoff,
                });
                vec![ReadySub { group, seq, net_idx, sg, ready_at: now + backoff }]
            }
            FaultAction::Remap => {
                // Next-best processor by the perf model's best-config memo,
                // excluding the one that keeps failing.
                let perf = self.recovery.as_ref().expect("recovery enabled").perf.clone();
                let current = self.effective_config(group, seq, net_idx, sg).processor;
                let sol = &self.solutions[net_idx];
                let mut best_cfg = None;
                let mut best_t = f64::INFINITY;
                for p in Processor::ALL {
                    if p == current {
                        continue;
                    }
                    let (cfg, t) = perf.best_config_for(&sol.network, &sol.subgraph(sg).layers, p);
                    if t < best_t {
                        best_t = t;
                        best_cfg = Some(cfg);
                    }
                }
                let Some(cfg) = best_cfg else {
                    // No alternative processor can run this subgraph.
                    self.shed_request(group, seq, now);
                    return Vec::new();
                };
                let rec = self.recovery.as_mut().expect("recovery enabled");
                rec.remapped.insert(key, cfg);
                rec.attempts.insert(key, 0);
                rec.request_faults.entry((group, seq)).or_default().remaps += 1;
                self.telemetry.emit(TelemetryEvent::Remap {
                    time: now,
                    group,
                    request: seq,
                    network: net_idx,
                    subgraph: sg.0,
                    from: current,
                    to: cfg.processor,
                });
                vec![ReadySub { group, seq, net_idx, sg, ready_at: now }]
            }
            FaultAction::Shed => {
                self.shed_request(group, seq, now);
                Vec::new()
            }
        }
    }

    /// Abandon a group request that recovery could not heal: drop all its
    /// live state and record it as [`DropReason::FaultShed`]. Tasks of the
    /// request already sitting in ready queues are skipped at pop time.
    /// `now` stamps the shed decision in the telemetry stream (the record
    /// itself keeps the arrival timestamp, as admission drops do).
    fn shed_request(&mut self, group: usize, seq: u64, now: f64) {
        let Some(progress) = self.group_progress.remove(&(group, seq)) else {
            return;
        };
        self.live.retain(|k, _| !(k.0 == group && k.1 == seq));
        self.tensors.retain(|k, _| !(k.0 == group && k.1 == seq));
        self.dropped.push(DroppedRequest {
            group,
            request: seq,
            arrival: progress.arrival,
            reason: DropReason::FaultShed,
        });
        self.telemetry.emit(TelemetryEvent::Dropped {
            time: now,
            group,
            request: seq,
            reason: DropReason::FaultShed,
        });
        if let Some(rec) = self.recovery.as_mut() {
            rec.request_faults.remove(&(group, seq));
            if !rec.attempts.is_empty() {
                rec.attempts.retain(|k, _| !(k.0 == group && k.1 == seq));
            }
            if !rec.remapped.is_empty() {
                rec.remapped.retain(|k, _| !(k.0 == group && k.1 == seq));
            }
        }
    }

    /// Process one completion at clock time `now` (Fig 9 steps ④–⑥): free
    /// the worker, publish boundary tensors, resolve dependents, and record
    /// the [`ServedRequest`] when the group's last network finishes. Returns
    /// the dependents that became schedulable (with their data-ready times
    /// when `comm` prices transfers — virtual mode).
    ///
    /// With recovery enabled, a failed completion (task error or watchdog
    /// abort) is routed to [`Coordinator::handle_failure`] instead. Without
    /// it, errors keep their historical treatment: the completion counts,
    /// outputs are simply absent.
    fn handle_completion(
        &mut self,
        mut msg: CompletionMsg,
        now: f64,
        comm: Option<&CommModel>,
    ) -> Vec<ReadySub> {
        // Wall drivers reach here without the virtual pre-schedule hook, so
        // apply the watchdog now (idempotent for the virtual path).
        self.watchdog_abort(&mut msg);
        let (group, seq, net_idx) = unpack_request(msg.request);
        // The worker that ran this subgraph is idle again, whether or not
        // the request is still live. Keyed on the *reporting* worker:
        // recovery can run a subgraph away from its solution-assigned
        // processor.
        self.busy[msg.processor.index()] = false;
        self.telemetry.on_busy(msg.processor, msg.elapsed.max(0.0));

        if self.recovery.is_some() && msg.error.is_some() {
            return self.handle_failure(&msg, now);
        }

        self.telemetry.emit(TelemetryEvent::TaskComplete {
            time: now,
            group,
            request: seq,
            network: net_idx,
            subgraph: msg.subgraph.0,
            processor: msg.processor,
            elapsed: msg.elapsed,
        });

        let mut newly_ready = Vec::new();
        let Some(live) = self.live.get_mut(&(group, seq, net_idx)) else {
            return newly_ready;
        };
        if live.done[msg.subgraph.0] {
            return newly_ready; // duplicate (should not happen; defensive)
        }
        live.done[msg.subgraph.0] = true;
        live.remaining -= 1;

        let sol = self.solutions[net_idx].clone();

        // Publish this subgraph's boundary tensors into the shared arena
        // (Fig 9 ⑤): real engine outputs when available (PjrtEngine), or
        // synthesized buffers of the correct size (SimEngine). Zero-copy
        // publishes views; copying mode pays real marshalling memcpy.
        {
            let completed = sol.subgraph(msg.subgraph);
            // Engine outputs come in subgraph-layer order for boundary
            // layers (network outputs or layers with external consumers) —
            // this filter must match PjrtEngine's is_boundary rule.
            let sink_layers: Vec<usize> = completed
                .layers
                .iter()
                .filter(|l| {
                    let succs = sol.network.successors(**l);
                    succs.is_empty() || succs.iter().any(|s| !completed.contains(*s))
                })
                .map(|l| l.0)
                .collect();
            for (i, &layer) in sink_layers.iter().enumerate() {
                // Only keep tensors some other subgraph will consume.
                let consumed_elsewhere = sol
                    .network
                    .successors(crate::graph::LayerId(layer))
                    .iter()
                    .any(|s| sol.partition.owner_of(*s) != msg.subgraph);
                if !consumed_elsewhere {
                    continue;
                }
                let payload = match msg.outputs.get(i) {
                    Some(t) if !t.is_empty() => crate::quant::quantize(t, DataType::Fp16).0,
                    _ => vec![0u8; sol.network.layer(crate::graph::LayerId(layer)).out_bytes(DataType::Fp16)],
                };
                let slice = self.arena.publish(payload);
                self.tensors.insert((group, seq, net_idx, layer), slice);
            }
        }

        // Resolve dependents (Fig 9 ② → ③): account when their inputs land,
        // collect the newly schedulable.
        for sg in &sol.partition.subgraphs {
            if sg.deps.contains(&msg.subgraph) {
                let data_at = comm
                    .map(|c| now + self.transfer_delay(&sol, msg.subgraph, sg.id, c))
                    .unwrap_or(now);
                let live = self.live.get_mut(&(group, seq, net_idx)).unwrap();
                live.data_at[sg.id.0] = live.data_at[sg.id.0].max(data_at);
                live.pending_deps[sg.id.0] -= 1;
                if live.pending_deps[sg.id.0] == 0 {
                    let ready_at = live.data_at[sg.id.0].max(now);
                    newly_ready.push(ReadySub {
                        group,
                        seq,
                        net_idx,
                        sg: sg.id,
                        ready_at,
                    });
                }
            }
        }

        let live = self.live.get_mut(&(group, seq, net_idx)).unwrap();
        if live.remaining == 0 {
            self.live.remove(&(group, seq, net_idx));
            // Return this request's in-flight tensors (pool/arena reuse).
            self.tensors.retain(|k, _| !(k.0 == group && k.1 == seq && k.2 == net_idx));
            // Group bookkeeping: when the last member network finishes,
            // record the group makespan (paper §6.2: max Tf − min Ts) and
            // the deadline verdict.
            let entry = self.group_progress.get_mut(&(group, seq)).unwrap();
            entry.outstanding -= 1;
            if entry.outstanding == 0 {
                let GroupProgress { arrival, deadline, .. } =
                    self.group_progress.remove(&(group, seq)).unwrap();
                let makespan = (now - arrival).max(0.0);
                // Fold in (and release) the request's fault accounting;
                // (0, 0, 0.0) without recovery or without faults.
                let (retries, remaps, degraded) = match self.recovery.as_mut() {
                    Some(rec) => {
                        let faults =
                            rec.request_faults.remove(&(group, seq)).unwrap_or_default();
                        if !rec.attempts.is_empty() {
                            rec.attempts.retain(|k, _| !(k.0 == group && k.1 == seq));
                        }
                        if !rec.remapped.is_empty() {
                            rec.remapped.retain(|k, _| !(k.0 == group && k.1 == seq));
                        }
                        (faults.retries, faults.remaps, faults.degraded)
                    }
                    None => (0, 0, 0.0),
                };
                let violated = deadline.is_some_and(|d| makespan > d);
                self.served.push(ServedRequest {
                    group,
                    request: seq,
                    arrival,
                    completion: now,
                    makespan,
                    deadline,
                    violated,
                    retries,
                    remaps,
                    degraded,
                });
                self.telemetry.emit(TelemetryEvent::Served {
                    time: now,
                    group,
                    request: seq,
                    arrival,
                    makespan,
                    deadline,
                    violated,
                    retries,
                    remaps,
                    degraded,
                });
                if violated {
                    self.telemetry.emit(TelemetryEvent::DeadlineViolation {
                        time: now,
                        group,
                        request: seq,
                        makespan,
                        deadline: deadline.expect("violated implies a deadline"),
                    });
                }
            }
        }
        newly_ready
    }

    /// The registered per-network solutions.
    pub fn solutions(&self) -> &[NetworkSolution] {
        &self.solutions
    }

    /// Served request records so far.
    pub fn served(&self) -> &[ServedRequest] {
        &self.served
    }

    /// Group requests rejected by the admission policy so far.
    pub fn dropped(&self) -> &[DroppedRequest] {
        &self.dropped
    }

    /// Outstanding (unfinished) network-requests.
    pub fn outstanding(&self) -> usize {
        self.live.len()
    }

    /// Tensor-pool statistics (Table 5 columns).
    pub fn pool_stats(&self) -> (f64, u64, f64, f64) {
        self.pool.stats().snapshot()
    }

    /// Shut workers down and join their threads.
    pub fn shutdown(self) {
        for w in self.workers {
            w.shutdown();
        }
        drop(self.completion_tx);
    }
}

/// Pack (group, seq, network) into the u64 request tag carried by tasks.
fn pack_request(group: usize, seq: u64, network: usize) -> u64 {
    ((group as u64) << 48) | ((network as u64) << 40) | (seq & 0xff_ffff_ffff)
}

fn unpack_request(tag: u64) -> (usize, u64, usize) {
    (
        (tag >> 48) as usize,
        tag & 0xff_ffff_ffff,
        ((tag >> 40) & 0xff) as usize,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::SimEngine;
    use crate::ga::decode_network;
    use crate::graph::Network;
    use crate::models::build_model;
    use crate::perf::PerfModel;
    use crate::Processor;

    fn solution_for(net: Network, priority: usize, cuts: Option<Vec<bool>>) -> NetworkSolution {
        let cuts = cuts.unwrap_or_else(|| vec![false; net.num_edges()]);
        let genes = crate::ga::NetworkGenes {
            cuts,
            mapping: vec![Processor::Npu; net.num_layers()],
        };
        let part = decode_network(&net, &genes);
        let configs = part
            .subgraphs
            .iter()
            .map(|sg| ExecConfig::default_for(sg.processor))
            .collect();
        NetworkSolution {
            network: Arc::new(net),
            partition: Arc::new(part),
            configs,
            priority,
        }
    }

    fn sim_coordinator(solutions: Vec<NetworkSolution>, opts: RuntimeOptions) -> Coordinator {
        let pm = Arc::new(PerfModel::paper_calibrated());
        let engine: Arc<dyn Engine> = Arc::new(SimEngine::new(pm, 0.0, false, 7));
        Coordinator::new(solutions, engine, opts)
    }

    #[test]
    fn single_request_completes() {
        let sol = solution_for(build_model(0, 0), 0, None);
        let mut coord = sim_coordinator(vec![sol], RuntimeOptions::default());
        coord.submit_group(0, &[0]);
        coord.pump(std::time::Duration::from_secs(5));
        assert_eq!(coord.served().len(), 1);
        assert_eq!(coord.outstanding(), 0);
        let s = &coord.served()[0];
        assert!(s.makespan > 0.0);
        assert!(s.completion >= s.arrival);
        assert!(s.deadline.is_none() && !s.violated);
        coord.shutdown();
    }

    #[test]
    fn partitioned_request_respects_dependencies() {
        // Cut the first edge: at least two subgraphs in sequence.
        let net = build_model(0, 1);
        let mut cuts = vec![false; net.num_edges()];
        cuts[0] = true;
        let sol = solution_for(net, 0, Some(cuts));
        assert!(sol.partition.subgraphs.len() >= 2);
        let mut coord = sim_coordinator(vec![sol], RuntimeOptions::default());
        coord.submit_group(0, &[0]);
        coord.pump(std::time::Duration::from_secs(5));
        assert_eq!(coord.served().len(), 1);
        coord.shutdown();
    }

    #[test]
    fn group_makespan_spans_all_members() {
        let sols = vec![
            solution_for(build_model(0, 0), 0, None),
            solution_for(build_model(1, 6), 1, None), // heavier
        ];
        let mut coord = sim_coordinator(sols, RuntimeOptions::default());
        coord.submit_group(0, &[0, 1]);
        coord.pump(std::time::Duration::from_secs(10));
        assert_eq!(coord.served().len(), 1);
        coord.shutdown();
    }

    #[test]
    fn multiple_requests_all_served() {
        let sol = solution_for(build_model(0, 0), 0, None);
        let mut coord = sim_coordinator(vec![sol], RuntimeOptions::default());
        for _ in 0..5 {
            coord.submit_group(0, &[0]);
        }
        coord.pump(std::time::Duration::from_secs(10));
        assert_eq!(coord.served().len(), 5);
        coord.shutdown();
    }

    #[test]
    fn drop_policy_bounds_inflight_requests() {
        let sol = solution_for(build_model(0, 0), 0, None);
        let mut coord = sim_coordinator(vec![sol], RuntimeOptions::default());
        coord.set_overload_policy(OverloadPolicy::DropAfter { max_inflight: 2 });
        // Five back-to-back arrivals with no pumping in between: only the
        // first two are admitted.
        for _ in 0..5 {
            coord.submit_group(0, &[0]);
        }
        assert_eq!(coord.dropped().len(), 3);
        coord.pump(std::time::Duration::from_secs(5));
        assert_eq!(coord.served().len(), 2);
        coord.shutdown();
    }

    #[test]
    fn virtual_run_serves_and_accounts_deadlines() {
        let sol = solution_for(build_model(0, 0), 0, None);
        let mut coord = sim_coordinator(vec![sol], RuntimeOptions::default());
        let arrivals: Vec<Arrival> = (0..4)
            .map(|j| Arrival { time: j as f64 * 0.01, group: 0, deadline: Some(0.01) })
            .collect();
        let groups = vec![vec![0usize]];
        let served = coord.run_virtual(&arrivals, &groups, &CommModel::paper_calibrated());
        assert_eq!(served, 4);
        for (j, s) in coord.served().iter().enumerate() {
            // Virtual timestamps follow the arrival schedule exactly.
            assert_eq!(s.arrival, j as f64 * 0.01);
            assert_eq!(s.deadline, Some(0.01));
            // face_det on the NPU is ~0.3 ms: a 10 ms period never violates.
            assert!(!s.violated, "request {j} violated: {s:?}");
            assert!((s.completion - s.arrival - s.makespan).abs() < 1e-12);
        }
        coord.shutdown();
    }

    #[test]
    fn virtual_run_detects_overload_violations() {
        let sol = solution_for(build_model(0, 8), 0, None); // fastsam: heavy
        let mut coord = sim_coordinator(vec![sol], RuntimeOptions::default());
        // Period far below the model's NPU service time: backlog grows and
        // later requests blow their deadlines.
        let arrivals: Vec<Arrival> = (0..6)
            .map(|j| Arrival { time: j as f64 * 1e-4, group: 0, deadline: Some(1e-4) })
            .collect();
        let groups = vec![vec![0usize]];
        coord.run_virtual(&arrivals, &groups, &CommModel::paper_calibrated());
        assert_eq!(coord.served().len(), 6);
        assert!(coord.served().iter().any(|s| s.violated));
        // Makespans grow monotonically under backlog.
        let ms: Vec<f64> = coord.served().iter().map(|s| s.makespan).collect();
        assert!(ms.windows(2).all(|w| w[1] >= w[0] - 1e-12), "{ms:?}");
        coord.shutdown();
    }

    #[test]
    fn reset_clears_logs_and_restarts_sequencing() {
        let sol = solution_for(build_model(0, 0), 0, None);
        let mut coord = sim_coordinator(vec![sol], RuntimeOptions::default());
        coord.set_overload_policy(OverloadPolicy::DropAfter { max_inflight: 1 });
        coord.submit_group(0, &[0]);
        coord.submit_group(0, &[0]); // cap 1, no pump in between: dropped
        coord.pump(std::time::Duration::from_secs(5));
        assert_eq!(coord.served().len(), 1);
        assert_eq!(coord.dropped().len(), 1);
        coord.reset();
        assert!(coord.served().is_empty(), "reset left served records");
        assert!(coord.dropped().is_empty(), "reset left dropped records");
        assert_eq!(coord.outstanding(), 0);
        // Sequencing restarts: the next admission is request 0 again, and
        // the workers are still alive to serve it.
        coord.set_overload_policy(OverloadPolicy::Queue);
        assert_eq!(coord.submit_group(0, &[0]), 0);
        coord.pump(std::time::Duration::from_secs(5));
        assert_eq!(coord.served().len(), 1);
        assert_eq!(coord.served()[0].request, 0);
        coord.shutdown();
    }

    #[test]
    fn request_tag_roundtrip() {
        for (g, s, n) in [(0usize, 0u64, 0usize), (1, 12345, 5), (3, 999_999, 8)] {
            assert_eq!(unpack_request(pack_request(g, s, n)), (g, s, n));
        }
    }
}
