//! Request/task/completion message types flowing between the Coordinator
//! and the Workers (paper Fig 9).

use std::sync::Arc;

use crate::graph::{Network, Subgraph, SubgraphId};
use crate::{DataType, ExecConfig, Processor};

/// Identifies one network's inference inside a group request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct RequestId {
    pub group: usize,
    pub seq: u64,
    pub network: usize,
}

/// A client-visible group request (all networks fed by the same input).
#[derive(Debug, Clone)]
pub struct GroupRequest {
    pub group: usize,
    pub members: Vec<usize>,
}

/// An input tensor handed to a worker (possibly needing dtype conversion on
/// the worker's quant thread). Carried as a [`SharedSlice`] so the zero-copy
/// path moves a view, never bytes.
#[derive(Clone)]
pub struct TensorInput {
    pub slice: crate::mem::SharedSlice,
    pub dtype: DataType,
    pub scale: f32,
}

impl TensorInput {
    pub fn from_vec(bytes: Vec<u8>, dtype: DataType, scale: f32) -> TensorInput {
        TensorInput { slice: crate::mem::SharedSlice::from_vec(bytes), dtype, scale }
    }
}

/// A subgraph execution task dispatched to a worker queue.
pub struct TaskMsg {
    /// Packed (group, seq, network) tag.
    pub request: u64,
    pub network: Arc<Network>,
    pub network_idx: usize,
    pub subgraph: Arc<Subgraph>,
    pub config: ExecConfig,
    pub inputs: Vec<TensorInput>,
    /// Coordinator clock at dispatch, seconds. Fault-injecting engines key
    /// their timelines on it; plain engines ignore it.
    pub start: f64,
}

/// Worker → coordinator completion notification.
pub struct CompletionMsg {
    pub request: u64,
    pub network: usize,
    pub subgraph: SubgraphId,
    /// Engine-reported execution duration, seconds.
    pub elapsed: f64,
    /// The worker (= processor) that executed the task. The coordinator
    /// frees this processor's busy slot — load-bearing once recovery can
    /// remap a task away from its solution-assigned processor.
    pub processor: Processor,
    pub outputs: Vec<Vec<f32>>,
    pub error: Option<String>,
}
