//! Data-type conversion ((de)quantization) between subgraphs (paper §5.1:
//! "Before executing tasks, (de-)quantization may be required if the data
//! type of subgraph's input does not match the output of the preceding
//! subgraph").
//!
//! fp16 here is IEEE 754 binary16, converted manually (no external dep);
//! int8 uses symmetric per-tensor scaling.

use crate::DataType;

/// f32 -> f16 bit conversion (round-to-nearest-even).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x7f_ffff;

    if exp == 255 {
        // Inf / NaN.
        let m = if mant != 0 { 0x200 } else { 0 };
        return sign | 0x7c00 | m as u16;
    }
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7c00; // overflow -> inf
    }
    if unbiased >= -14 {
        // Normal.
        let half_exp = ((unbiased + 15) as u32) << 10;
        let half_mant = mant >> 13;
        // Round to nearest even.
        let round_bit = (mant >> 12) & 1;
        let sticky = mant & 0xfff;
        let mut h = half_exp | half_mant;
        if round_bit == 1 && (sticky != 0 || (half_mant & 1) == 1) {
            h += 1;
        }
        return sign | h as u16;
    }
    if unbiased >= -24 {
        // Subnormal.
        let shift = (-unbiased - 14 + 13) as u32 + 1;
        let full_mant = mant | 0x80_0000;
        let half_mant = full_mant >> shift;
        let round_bit = (full_mant >> (shift - 1)) & 1;
        let mut h = half_mant;
        if round_bit == 1 {
            h += 1;
        }
        return sign | h as u16;
    }
    sign // underflow -> zero
}

/// f16 bits -> f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;
    let bits = match (exp, mant) {
        (0, 0) => sign,
        (0, m) => {
            // Subnormal: normalize.
            let mut e = -14i32;
            let mut m = m;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x3ff;
            sign | (((e + 127) as u32) << 23) | (m << 13)
        }
        (0x1f, 0) => sign | 0x7f80_0000,
        (0x1f, m) => sign | 0x7f80_0000 | (m << 13),
        (e, m) => sign | ((e + 127 - 15) << 23) | (m << 13),
    };
    f32::from_bits(bits)
}

/// Quantize an f32 tensor to a target dtype's byte representation.
/// Returns (bytes, scale); scale is 1.0 except for int8.
pub fn quantize(data: &[f32], dtype: DataType) -> (Vec<u8>, f32) {
    match dtype {
        DataType::Fp32 => {
            let mut out = Vec::with_capacity(data.len() * 4);
            for &x in data {
                out.extend_from_slice(&x.to_le_bytes());
            }
            (out, 1.0)
        }
        DataType::Fp16 => {
            let mut out = Vec::with_capacity(data.len() * 2);
            for &x in data {
                out.extend_from_slice(&f32_to_f16_bits(x).to_le_bytes());
            }
            (out, 1.0)
        }
        DataType::Int8 => {
            let max_abs = data.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            let scale = if max_abs > 0.0 { max_abs / 127.0 } else { 1.0 };
            let out = data
                .iter()
                .map(|&x| (x / scale).round().clamp(-127.0, 127.0) as i8 as u8)
                .collect();
            (out, scale)
        }
    }
}

/// Dequantize back to f32.
pub fn dequantize(bytes: &[u8], dtype: DataType, scale: f32) -> Vec<f32> {
    match dtype {
        DataType::Fp32 => bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect(),
        DataType::Fp16 => bytes
            .chunks_exact(2)
            .map(|c| f16_bits_to_f32(u16::from_le_bytes(c.try_into().unwrap())))
            .collect(),
        DataType::Int8 => bytes.iter().map(|&b| (b as i8) as f32 * scale).collect(),
    }
}

/// Whether a dtype boundary requires conversion work on the worker's
/// dequant thread.
pub fn needs_conversion(from: DataType, to: DataType) -> bool {
    from != to
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_roundtrip_exact_values() {
        for &x in &[0.0f32, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0, 0.000061035156] {
            let h = f32_to_f16_bits(x);
            assert_eq!(f16_bits_to_f32(h), x, "roundtrip failed for {x}");
        }
    }

    #[test]
    fn f16_overflow_to_inf() {
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e6)), f32::INFINITY);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(-1e6)), f32::NEG_INFINITY);
    }

    #[test]
    fn f16_nan_preserved() {
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
    }

    #[test]
    fn f16_precision_bound() {
        // Relative error of normal-range f16 is <= 2^-11.
        for i in 1..1000 {
            let x = i as f32 * 0.37;
            let y = f16_bits_to_f32(f32_to_f16_bits(x));
            assert!(((y - x) / x).abs() <= 1.0 / 2048.0, "{x} -> {y}");
        }
    }

    #[test]
    fn fp32_quantize_is_identity() {
        let data = vec![1.5f32, -2.25, 0.0, 3.75];
        let (bytes, scale) = quantize(&data, DataType::Fp32);
        assert_eq!(dequantize(&bytes, DataType::Fp32, scale), data);
    }

    #[test]
    fn int8_quantize_bounded_error() {
        let data: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) * 0.1).collect();
        let (bytes, scale) = quantize(&data, DataType::Int8);
        let back = dequantize(&bytes, DataType::Int8, scale);
        let max_abs = data.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        for (a, b) in data.iter().zip(&back) {
            assert!((a - b).abs() <= scale * 0.5 + 1e-6, "{a} vs {b} (scale {scale}, max {max_abs})");
        }
    }

    #[test]
    fn int8_all_zero_tensor() {
        let (bytes, scale) = quantize(&[0.0; 8], DataType::Int8);
        assert_eq!(scale, 1.0);
        assert!(dequantize(&bytes, DataType::Int8, scale).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn conversion_predicate() {
        assert!(!needs_conversion(DataType::Fp16, DataType::Fp16));
        assert!(needs_conversion(DataType::Fp16, DataType::Fp32));
    }
}
