//! # Puzzle — multi-model scheduling on heterogeneous processors
//!
//! A reproduction of *"Puzzle: Scheduling Multiple Deep Learning Models on
//! Mobile Device with Heterogeneous Processors"* (Kang, Lee, Kim — Qualcomm AI
//! Research, 2025) as a three-layer rust + JAX + Pallas stack.
//!
//! The crate contains both halves of the paper's system:
//!
//! * the **Static Analyzer** ([`analyzer`], [`ga`], [`sim`], [`profiler`],
//!   [`comm`]) — a genetic algorithm that jointly explores graph partitioning,
//!   processor mapping, and network priority, evaluated through a
//!   discrete-event simulator fed by device-in-the-loop profiling and a
//!   piecewise-linear communication-cost model; and
//! * the **Runtime** ([`coordinator`], [`worker`], [`engine`], [`mem`]) — a
//!   Coordinator/Worker/Engine serving stack with tensor-pool and zero-copy
//!   shared-buffer optimizations, executing AOT-compiled XLA artifacts through
//!   the PJRT C API ([`runtime`]).
//!
//! Substrates the paper relied on (DEAP, SimPy, the Snapdragon 8 Gen 2's
//! CPU/GPU/NPU and their SDKs) are rebuilt from scratch: see `DESIGN.md` for
//! the substitution table.
//!
//! The two halves meet in [`api`] — the owned analyze → deploy → serve
//! session layer ([`api::SessionBuilder`] → [`api::AnalysisSession`] →
//! [`api::Analysis::deploy`]), which is the supported entry point for
//! external callers. [`serve`] drives deployments under **open-loop load**:
//! pluggable wall/virtual clocks, periodic/Poisson/bursty arrival
//! processes, deadline accounting, and the runtime-measured saturation
//! driver behind the serving figures.

/// Counting allocator (see [`util::alloc`]): lets tests assert that the
/// simulator's steady state performs zero heap allocation. One relaxed
/// atomic add per allocation; active in every binary linking this crate.
#[global_allocator]
static GLOBAL_ALLOCATOR: util::alloc::CountingAllocator = util::alloc::CountingAllocator;

pub mod analyzer;
pub mod api;
pub mod baselines;
pub mod comm;
pub mod coordinator;
pub mod engine;
pub mod experiments;
pub mod ga;
pub mod graph;
pub mod mem;
pub mod metrics;
pub mod models;
pub mod perf;
pub mod profiler;
pub mod quant;
pub mod runtime;
pub mod scenario;
pub mod serve;
pub mod sim;
pub mod telemetry;
pub mod util;
pub mod worker;

/// The three logical processors of the simulated mobile SoC.
///
/// The paper's testbed is a Snapdragon 8 Gen 2 (8-core CPU, Adreno GPU,
/// Hexagon NPU). Our substrate keeps the same three-way split; per-processor
/// cost comes from [`perf::PerfModel`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Processor {
    Cpu,
    Gpu,
    Npu,
}

impl Processor {
    pub const ALL: [Processor; 3] = [Processor::Cpu, Processor::Gpu, Processor::Npu];

    pub fn index(self) -> usize {
        match self {
            Processor::Cpu => 0,
            Processor::Gpu => 1,
            Processor::Npu => 2,
        }
    }

    pub fn from_index(i: usize) -> Processor {
        Self::ALL[i % 3]
    }

    pub fn name(self) -> &'static str {
        match self {
            Processor::Cpu => "CPU",
            Processor::Gpu => "GPU",
            Processor::Npu => "NPU",
        }
    }
}

impl std::fmt::Display for Processor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Kernel data types available per backend (paper §2.1.1, Table 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DataType {
    Fp32,
    Fp16,
    Int8,
}

impl DataType {
    pub const ALL: [DataType; 3] = [DataType::Fp32, DataType::Fp16, DataType::Int8];

    /// Bytes per element.
    pub fn size(self) -> usize {
        match self {
            DataType::Fp32 => 4,
            DataType::Fp16 => 2,
            DataType::Int8 => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DataType::Fp32 => "fp32",
            DataType::Fp16 => "fp16",
            DataType::Int8 => "int8",
        }
    }
}

impl std::fmt::Display for DataType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Backend kernel implementations (paper Table 2: ORT default CPU, XNNPACK,
/// NNAPI for the CPU; QNN-CPU/GPU/HTP analogs for GPU/NPU).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Backend {
    /// ORT default CPU execution provider analog.
    OrtCpu,
    /// XNNPACK execution provider analog.
    Xnnpack,
    /// NNAPI execution provider analog (consistently worst in the paper).
    Nnapi,
    /// Qualcomm AI Engine Direct analog (GPU / NPU backends).
    Qnn,
}

impl Backend {
    pub const ALL: [Backend; 4] = [Backend::OrtCpu, Backend::Xnnpack, Backend::Nnapi, Backend::Qnn];

    /// Backends selectable for a given processor.
    pub fn for_processor(p: Processor) -> &'static [Backend] {
        match p {
            Processor::Cpu => &[Backend::OrtCpu, Backend::Xnnpack, Backend::Nnapi],
            Processor::Gpu | Processor::Npu => &[Backend::Qnn],
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Backend::OrtCpu => "ort-cpu",
            Backend::Xnnpack => "xnnpack",
            Backend::Nnapi => "nnapi",
            Backend::Qnn => "qnn",
        }
    }
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A full execution configuration for a subgraph: where it runs, with which
/// kernel library, at which precision (paper's `M × T × BE` search space).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ExecConfig {
    pub processor: Processor,
    pub backend: Backend,
    pub dtype: DataType,
}

impl ExecConfig {
    pub fn new(processor: Processor, backend: Backend, dtype: DataType) -> Self {
        Self { processor, backend, dtype }
    }

    /// Every valid (processor, backend, dtype) combination.
    pub fn enumerate() -> Vec<ExecConfig> {
        let mut out = Vec::new();
        for p in Processor::ALL {
            for &b in Backend::for_processor(p) {
                for d in DataType::ALL {
                    out.push(ExecConfig::new(p, b, d));
                }
            }
        }
        out
    }

    /// Default best-effort config for a processor (fp16 on the native backend,
    /// matching the paper's Table 3 methodology: "all models are run in fp16").
    pub fn default_for(p: Processor) -> ExecConfig {
        let backend = match p {
            Processor::Cpu => Backend::Xnnpack,
            _ => Backend::Qnn,
        };
        ExecConfig::new(p, backend, DataType::Fp16)
    }
}

impl std::fmt::Display for ExecConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{}/{}", self.processor, self.backend, self.dtype)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn processor_roundtrip() {
        for p in Processor::ALL {
            assert_eq!(Processor::from_index(p.index()), p);
        }
    }

    #[test]
    fn backend_sets_are_valid() {
        assert_eq!(Backend::for_processor(Processor::Cpu).len(), 3);
        assert_eq!(Backend::for_processor(Processor::Gpu), &[Backend::Qnn]);
        assert_eq!(Backend::for_processor(Processor::Npu), &[Backend::Qnn]);
    }

    #[test]
    fn enumerate_configs_counts() {
        // CPU: 3 backends x 3 dtypes, GPU: 1 x 3, NPU: 1 x 3 = 15.
        assert_eq!(ExecConfig::enumerate().len(), 15);
    }

    #[test]
    fn dtype_sizes() {
        assert_eq!(DataType::Fp32.size(), 4);
        assert_eq!(DataType::Fp16.size(), 2);
        assert_eq!(DataType::Int8.size(), 1);
    }
}
