//! The model zoo: structural analogs of the paper's nine networks (Table 6).
//!
//! The paper evaluates MediaPipe Face/Selfie/Hand/Pose, TCMonoDepth,
//! Fast-SCNN, YOLOv8-nano, MOSAIC, and FastSAM-small. We cannot ship those
//! ONNX models, so each is rebuilt as a *structural analog* in the graph IR:
//! same topology class (branchy detector heads, encoder–decoder skips,
//! two-branch fusion), and MAC/param counts scaled down ~1000x with the
//! paper's **relative ordering preserved** (Face < Selfie < Hand < Pose <
//! TCMonoDepth ≈ FastSCNN < YOLOv8 < MOSAIC ≈ FastSAM). The GA only observes
//! topology and profiled subgraph cost, so this preserves the search
//! landscape (DESIGN.md §3).

mod zoo;

pub use zoo::{build_model, model_names, model_zoo, ModelSpec, MODEL_COUNT, SPECS};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_has_nine_models() {
        assert_eq!(MODEL_COUNT, 9);
        assert_eq!(model_zoo().len(), 9);
    }

    #[test]
    fn mac_ordering_matches_table6() {
        // Table 6 MAC ordering: 1 < 2 < 3 < 4 < 5 < 6 < 7 < 8 < 9 (with 5~6
        // and 8~9 close). Our analogs must preserve strict non-decreasing
        // order.
        let zoo = model_zoo();
        let macs: Vec<u64> = zoo.iter().map(|m| m.total_macs()).collect();
        for w in macs.windows(2) {
            assert!(w[0] <= w[1], "MAC ordering violated: {:?}", macs);
        }
        // Heaviest/lightest span roughly matches the paper's 39.2M..22325M
        // (~570x); require at least two orders of magnitude.
        assert!(macs[8] / macs[0] > 100, "span too small: {:?}", macs);
    }

    #[test]
    fn all_models_finalized_dags() {
        for m in model_zoo() {
            assert!(!m.topological_order().is_empty());
            assert!(!m.inputs().is_empty());
            assert!(!m.outputs().is_empty());
        }
    }

    #[test]
    fn branchy_models_have_joins() {
        // Every analog has at least one layer with >1 predecessor (mirrors
        // the branch/head structure the partition chromosome exploits).
        for m in model_zoo() {
            let has_join = (0..m.num_layers())
                .any(|l| m.predecessors(crate::graph::LayerId(l)).len() > 1);
            assert!(has_join, "{} has no join", m.name);
        }
    }

    #[test]
    fn names_are_stable() {
        let names = model_names();
        assert_eq!(names[0], "face_det");
        assert_eq!(names[8], "fastsam");
    }

    #[test]
    fn build_by_name_and_index_agree() {
        for (i, name) in model_names().iter().enumerate() {
            let a = build_model(i, i);
            assert_eq!(&a.name, name);
        }
    }
}
