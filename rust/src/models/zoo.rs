//! Construction of the nine model analogs.
//!
//! Spatial sizes are kept small (input 32x32) so AOT compilation and real
//! PJRT execution stay fast; MAC ratios across models track Table 6.

use crate::graph::{Layer, Network};

/// Number of models in the zoo (paper Table 6).
pub const MODEL_COUNT: usize = 9;

/// Static description of a zoo entry.
#[derive(Debug, Clone, Copy)]
pub struct ModelSpec {
    /// Stable snake_case name (used for artifact paths).
    pub name: &'static str,
    /// Human name from the paper.
    pub paper_name: &'static str,
    /// Paper MAC count (millions) — for documentation / ratio checks.
    pub paper_macs_m: f64,
}

/// Specs in Table 6 order.
pub const SPECS: [ModelSpec; MODEL_COUNT] = [
    ModelSpec { name: "face_det", paper_name: "MediaPipe Face Det.", paper_macs_m: 39.2 },
    ModelSpec { name: "selfie_seg", paper_name: "MediaPipe Selfie Seg.", paper_macs_m: 72.3 },
    ModelSpec { name: "hand_det", paper_name: "MediaPipe Hand Det.", paper_macs_m: 410.8 },
    ModelSpec { name: "pose_det", paper_name: "MediaPipe Pose Det.", paper_macs_m: 444.2 },
    ModelSpec { name: "tcmonodepth", paper_name: "TCMonoDepth", paper_macs_m: 2313.2 },
    ModelSpec { name: "fast_scnn", paper_name: "Fast-SCNN", paper_macs_m: 2358.9 },
    ModelSpec { name: "yolov8n", paper_name: "YOLO v8 nano", paper_macs_m: 4891.3 },
    ModelSpec { name: "mosaic", paper_name: "MOSAIC (Seg.)", paper_macs_m: 22055.1 },
    ModelSpec { name: "fastsam", paper_name: "FastSAM small (Seg.)", paper_macs_m: 22325.1 },
];

/// Names of all zoo models in Table 6 order.
pub fn model_names() -> Vec<&'static str> {
    SPECS.iter().map(|s| s.name).collect()
}

/// Build model `zoo_index` (0..9) with a given network id.
pub fn build_model(network_id: usize, zoo_index: usize) -> Network {
    match zoo_index {
        0 => face_det(network_id),
        1 => selfie_seg(network_id),
        2 => hand_det(network_id),
        3 => pose_det(network_id),
        4 => tcmonodepth(network_id),
        5 => fast_scnn(network_id),
        6 => yolov8n(network_id),
        7 => mosaic(network_id),
        8 => fastsam(network_id),
        _ => panic!("zoo index {zoo_index} out of range (0..{MODEL_COUNT})"),
    }
}

/// Build all nine models with network ids 0..9.
pub fn model_zoo() -> Vec<Network> {
    (0..MODEL_COUNT).map(|i| build_model(i, i)).collect()
}

/// Analog 1 — MediaPipe Face Det. (BlazeFace): small conv backbone, two
/// detection heads (boxes + scores). Lightest model.
fn face_det(id: usize) -> Network {
    let mut n = Network::new(id, "face_det");
    let stem = n.add_layer(Layer::conv("stem", 32, 3, 8, 3, 2)); // 16x16x8
    let b1 = n.add_layer(Layer::dwconv("b1_dw", 16, 8, 3, 1));
    let b1p = n.add_layer(Layer::pointwise("b1_pw", 16, 8, 12));
    let b2 = n.add_layer(Layer::dwconv("b2_dw", 16, 12, 3, 2)); // 8x8
    let b2p = n.add_layer(Layer::pointwise("b2_pw", 8, 12, 16));
    let trunk = n.add_layer(Layer::conv("trunk", 8, 16, 16, 3, 1));
    let head_box = n.add_layer(Layer::conv("head_box", 8, 16, 8, 3, 1));
    let head_cls = n.add_layer(Layer::conv("head_cls", 8, 16, 4, 3, 1));
    let join = n.add_layer(Layer::concat("out", 8, 12));
    n.connect(stem, b1);
    n.connect(b1, b1p);
    n.connect(b1p, b2);
    n.connect(b2, b2p);
    n.connect(b2p, trunk);
    n.connect(trunk, head_box);
    n.connect(trunk, head_cls);
    n.connect(head_box, join);
    n.connect(head_cls, join);
    n.finalize();
    n
}

/// Analog 2 — MediaPipe Selfie Seg.: encoder–decoder with a skip connection.
fn selfie_seg(id: usize) -> Network {
    let mut n = Network::new(id, "selfie_seg");
    let stem = n.add_layer(Layer::conv("stem", 32, 3, 8, 3, 1)); // 32x32x8
    let e1 = n.add_layer(Layer::conv("enc1", 32, 8, 12, 3, 2)); // 16x16x12
    let e2 = n.add_layer(Layer::conv("enc2", 16, 12, 16, 3, 2)); // 8x8x16
    let mid = n.add_layer(Layer::conv("mid", 8, 16, 16, 3, 1));
    let up1 = n.add_layer(Layer::upsample("up1", 8, 16)); // 16x16x16
    let d1 = n.add_layer(Layer::pointwise("dec1", 16, 16, 12));
    let skip = n.add_layer(Layer::add("skip", 16, 12)); // + enc1
    let up2 = n.add_layer(Layer::upsample("up2", 16, 12)); // 32x32x12
    let out = n.add_layer(Layer::pointwise("mask", 32, 12, 2));
    n.connect(stem, e1);
    n.connect(e1, e2);
    n.connect(e2, mid);
    n.connect(mid, up1);
    n.connect(up1, d1);
    n.connect(d1, skip);
    n.connect(e1, skip);
    n.connect(skip, up2);
    n.connect(up2, out);
    n.finalize();
    n
}

/// Analog 3 — MediaPipe Hand Det.: deeper backbone + palm/landmark heads.
fn hand_det(id: usize) -> Network {
    let mut n = Network::new(id, "hand_det");
    let stem = n.add_layer(Layer::conv("stem", 32, 3, 16, 3, 1)); // 32x32x16
    let c1 = n.add_layer(Layer::conv("c1", 32, 16, 24, 3, 2)); // 16x16x24
    let c2 = n.add_layer(Layer::conv("c2", 16, 24, 24, 3, 1));
    let r = n.add_layer(Layer::add("res", 16, 24)); // c1 + c2
    let c3 = n.add_layer(Layer::conv("c3", 16, 24, 32, 3, 2)); // 8x8x32
    let c4 = n.add_layer(Layer::conv("c4", 8, 32, 32, 3, 1));
    let trunk = n.add_layer(Layer::conv("trunk", 8, 32, 32, 3, 1));
    let head_palm = n.add_layer(Layer::conv("head_palm", 8, 32, 16, 3, 1));
    let head_lm = n.add_layer(Layer::conv("head_lm", 8, 32, 16, 3, 1));
    let join = n.add_layer(Layer::concat("out", 8, 32));
    n.connect(stem, c1);
    n.connect(c1, c2);
    n.connect(c2, r);
    n.connect(c1, r);
    n.connect(r, c3);
    n.connect(c3, c4);
    n.connect(c4, trunk);
    n.connect(trunk, head_palm);
    n.connect(trunk, head_lm);
    n.connect(head_palm, join);
    n.connect(head_lm, join);
    n.finalize();
    n
}

/// Analog 4 — MediaPipe Pose Det.: like hand but slightly heavier.
fn pose_det(id: usize) -> Network {
    let mut n = Network::new(id, "pose_det");
    let stem = n.add_layer(Layer::conv("stem", 32, 3, 16, 3, 1));
    let c1 = n.add_layer(Layer::conv("c1", 32, 16, 24, 3, 2)); // 16x16
    let c2 = n.add_layer(Layer::conv("c2", 16, 24, 32, 3, 1));
    let c3 = n.add_layer(Layer::conv("c3", 16, 32, 32, 3, 1));
    let r = n.add_layer(Layer::add("res", 16, 32));
    let c4 = n.add_layer(Layer::conv("c4", 16, 32, 40, 3, 2)); // 8x8x40
    let c5 = n.add_layer(Layer::conv("c5", 8, 40, 40, 3, 1));
    let trunk = n.add_layer(Layer::conv("trunk", 8, 40, 40, 3, 1));
    let head_box = n.add_layer(Layer::conv("head_box", 8, 40, 16, 3, 1));
    let head_kp = n.add_layer(Layer::conv("head_kp", 8, 40, 16, 3, 1));
    let join = n.add_layer(Layer::concat("out", 8, 32));
    n.connect(stem, c1);
    n.connect(c1, c2);
    n.connect(c2, c3);
    n.connect(c3, r);
    n.connect(c2, r);
    n.connect(r, c4);
    n.connect(c4, c5);
    n.connect(c5, trunk);
    n.connect(trunk, head_box);
    n.connect(trunk, head_kp);
    n.connect(head_box, join);
    n.connect(head_kp, join);
    n.finalize();
    n
}

/// Analog 5 — TCMonoDepth: encoder–decoder depth net, medium-heavy.
fn tcmonodepth(id: usize) -> Network {
    let mut n = Network::new(id, "tcmonodepth");
    let stem = n.add_layer(Layer::conv("stem", 32, 3, 32, 3, 1)); // 32x32x32
    let e1 = n.add_layer(Layer::conv("enc1", 32, 32, 32, 3, 2)); // 16x16x32
    let e2 = n.add_layer(Layer::conv("enc2", 16, 32, 48, 3, 1));
    let e3 = n.add_layer(Layer::conv("enc3", 16, 48, 64, 3, 2)); // 8x8x64
    let mid1 = n.add_layer(Layer::conv("mid1", 8, 64, 64, 3, 1));
    let mid2 = n.add_layer(Layer::conv("mid2", 8, 64, 64, 3, 1));
    let up1 = n.add_layer(Layer::upsample("up1", 8, 64)); // 16x16x64
    let d1 = n.add_layer(Layer::conv("dec1", 16, 64, 32, 3, 1));
    let skip1 = n.add_layer(Layer::add("skip1", 16, 32)); // + enc1
    let up2 = n.add_layer(Layer::upsample("up2", 16, 32)); // 32x32x32
    let d2 = n.add_layer(Layer::conv("dec2", 32, 32, 12, 3, 1));
    let out = n.add_layer(Layer::pointwise("depth", 32, 12, 1));
    n.connect(stem, e1);
    n.connect(e1, e2);
    n.connect(e2, e3);
    n.connect(e3, mid1);
    n.connect(mid1, mid2);
    n.connect(mid2, up1);
    n.connect(up1, d1);
    n.connect(d1, skip1);
    n.connect(e1, skip1);
    n.connect(skip1, up2);
    n.connect(up2, d2);
    n.connect(d2, out);
    n.finalize();
    n
}

/// Analog 6 — Fast-SCNN: learning-to-downsample + global branch + fusion.
fn fast_scnn(id: usize) -> Network {
    let mut n = Network::new(id, "fast_scnn");
    let lds1 = n.add_layer(Layer::conv("lds1", 32, 3, 32, 3, 2)); // 16x16x32
    let lds2 = n.add_layer(Layer::dwconv("lds2_dw", 16, 32, 3, 1));
    let lds3 = n.add_layer(Layer::pointwise("lds2_pw", 16, 32, 48));
    // Global feature branch (deeper, lower-res).
    let g1 = n.add_layer(Layer::conv("gfe1", 16, 48, 96, 3, 2)); // 8x8x96
    let g2 = n.add_layer(Layer::conv("gfe2", 8, 96, 96, 3, 1));
    let g3 = n.add_layer(Layer::conv("gfe3", 8, 96, 96, 3, 1));
    let gup = n.add_layer(Layer::upsample("gfe_up", 8, 96)); // 16x16x96
    let gproj = n.add_layer(Layer::pointwise("gfe_proj", 16, 96, 48));
    // Fusion of the two branches.
    let fuse = n.add_layer(Layer::add("fuse", 16, 48));
    let f1 = n.add_layer(Layer::conv("fusion_conv", 16, 48, 64, 3, 1));
    let up = n.add_layer(Layer::upsample("up", 16, 64)); // 32x32x64
    let cls = n.add_layer(Layer::pointwise("classifier", 32, 64, 4));
    n.connect(lds1, lds2);
    n.connect(lds2, lds3);
    n.connect(lds3, g1);
    n.connect(g1, g2);
    n.connect(g2, g3);
    n.connect(g3, gup);
    n.connect(gup, gproj);
    n.connect(gproj, fuse);
    n.connect(lds3, fuse); // high-res branch skips straight to fusion
    n.connect(fuse, f1);
    n.connect(f1, up);
    n.connect(up, cls);
    n.finalize();
    n
}

/// Analog 7 — YOLOv8-nano: CSP-ish backbone with three detection heads.
fn yolov8n(id: usize) -> Network {
    let mut n = Network::new(id, "yolov8n");
    let stem = n.add_layer(Layer::conv("stem", 32, 3, 32, 3, 1)); // 32x32x32
    let c1 = n.add_layer(Layer::conv("c1", 32, 32, 64, 3, 2)); // 16x16x64
    // CSP split: half goes through bottleneck, half bypasses.
    let csp_a = n.add_layer(Layer::pointwise("csp_a", 16, 64, 32));
    let csp_b = n.add_layer(Layer::pointwise("csp_b", 16, 64, 32));
    let bn1 = n.add_layer(Layer::conv("bneck1", 16, 32, 32, 3, 1));
    let bn2 = n.add_layer(Layer::conv("bneck2", 16, 32, 32, 3, 1));
    let csp_j = n.add_layer(Layer::concat("csp_join", 16, 64));
    let c2 = n.add_layer(Layer::conv("c2", 16, 64, 96, 3, 2)); // 8x8x96
    let c3 = n.add_layer(Layer::conv("c3", 8, 96, 96, 3, 1));
    let neck = n.add_layer(Layer::conv("neck", 8, 96, 96, 3, 1));
    // Three scale heads (P3 from csp_join, P4/P5 from the neck).
    let p3 = n.add_layer(Layer::conv("head_p3", 16, 64, 16, 3, 1));
    let p4 = n.add_layer(Layer::conv("head_p4", 8, 96, 32, 3, 1));
    let p5 = n.add_layer(Layer::conv("head_p5", 8, 96, 32, 3, 1));
    let out45 = n.add_layer(Layer::concat("out_p45", 8, 64));
    n.connect(stem, c1);
    n.connect(c1, csp_a);
    n.connect(c1, csp_b);
    n.connect(csp_a, bn1);
    n.connect(bn1, bn2);
    n.connect(bn2, csp_j);
    n.connect(csp_b, csp_j);
    n.connect(csp_j, c2);
    n.connect(c2, c3);
    n.connect(c3, neck);
    n.connect(csp_j, p3);
    n.connect(neck, p4);
    n.connect(neck, p5);
    n.connect(p4, out45);
    n.connect(p5, out45);
    n.finalize();
    n
}

/// Analog 8 — MOSAIC: heavy encoder–decoder with multi-scale aggregation.
fn mosaic(id: usize) -> Network {
    let mut n = Network::new(id, "mosaic");
    let stem = n.add_layer(Layer::conv("stem", 32, 3, 48, 3, 1)); // 32x32x48
    let e1 = n.add_layer(Layer::conv("enc1", 32, 48, 96, 3, 2)); // 16x16x96
    let e2 = n.add_layer(Layer::conv("enc2", 16, 96, 96, 3, 1));
    let e3 = n.add_layer(Layer::conv("enc3", 16, 96, 96, 3, 1));
    let r1 = n.add_layer(Layer::add("res1", 16, 96));
    let e4 = n.add_layer(Layer::conv("enc4", 16, 96, 128, 3, 2)); // 8x8x128
    let e5 = n.add_layer(Layer::conv("enc5", 8, 128, 128, 3, 1));
    let e6 = n.add_layer(Layer::conv("enc6", 8, 128, 128, 3, 1));
    let r2 = n.add_layer(Layer::add("res2", 8, 128));
    let up1 = n.add_layer(Layer::upsample("up1", 8, 128)); // 16x16x128
    let proj1 = n.add_layer(Layer::pointwise("proj1", 16, 128, 96));
    let agg = n.add_layer(Layer::add("agg", 16, 96)); // + res1
    let d1 = n.add_layer(Layer::conv("dec1", 16, 96, 64, 3, 1));
    let up2 = n.add_layer(Layer::upsample("up2", 16, 64)); // 32x32x64
    let d2 = n.add_layer(Layer::conv("dec2", 32, 64, 32, 3, 1));
    let out = n.add_layer(Layer::pointwise("seg", 32, 32, 8));
    n.connect(stem, e1);
    n.connect(e1, e2);
    n.connect(e2, e3);
    n.connect(e3, r1);
    n.connect(e2, r1);
    n.connect(r1, e4);
    n.connect(e4, e5);
    n.connect(e5, e6);
    n.connect(e6, r2);
    n.connect(e5, r2);
    n.connect(r2, up1);
    n.connect(up1, proj1);
    n.connect(proj1, agg);
    n.connect(r1, agg);
    n.connect(agg, d1);
    n.connect(d1, up2);
    n.connect(up2, d2);
    n.connect(d2, out);
    n.finalize();
    n
}

/// Analog 9 — FastSAM-small: heaviest; YOLO-style backbone + mask branch.
fn fastsam(id: usize) -> Network {
    let mut n = Network::new(id, "fastsam");
    let stem = n.add_layer(Layer::conv("stem", 32, 3, 48, 3, 1)); // 32x32x48
    let c1 = n.add_layer(Layer::conv("c1", 32, 48, 96, 3, 2)); // 16x16x96
    let csp_a = n.add_layer(Layer::pointwise("csp_a", 16, 96, 64));
    let csp_b = n.add_layer(Layer::pointwise("csp_b", 16, 96, 64));
    let bn1 = n.add_layer(Layer::conv("bneck1", 16, 64, 64, 3, 1));
    let bn2 = n.add_layer(Layer::conv("bneck2", 16, 64, 64, 3, 1));
    let bn3 = n.add_layer(Layer::conv("bneck3", 16, 64, 64, 3, 1));
    let csp_j = n.add_layer(Layer::concat("csp_join", 16, 128));
    let c2 = n.add_layer(Layer::conv("c2", 16, 128, 160, 3, 2)); // 8x8x160
    let c3 = n.add_layer(Layer::conv("c3", 8, 160, 160, 3, 1));
    let neck = n.add_layer(Layer::conv("neck", 8, 160, 160, 3, 1));
    // Detection heads + mask prototype branch.
    let det = n.add_layer(Layer::conv("head_det", 8, 160, 64, 3, 1));
    let mask_up = n.add_layer(Layer::upsample("mask_up", 8, 160)); // 16x16x160
    let mask1 = n.add_layer(Layer::conv("mask1", 16, 160, 64, 3, 1));
    let mask2 = n.add_layer(Layer::conv("mask2", 16, 64, 32, 3, 1));
    let join = n.add_layer(Layer::concat("out", 8, 96)); // det + pooled mask
    let mask_pool = n.add_layer(Layer::pool("mask_pool", 16, 32)); // 8x8x32
    n.connect(stem, c1);
    n.connect(c1, csp_a);
    n.connect(c1, csp_b);
    n.connect(csp_a, bn1);
    n.connect(bn1, bn2);
    n.connect(bn2, bn3);
    n.connect(bn3, csp_j);
    n.connect(csp_b, csp_j);
    n.connect(csp_j, c2);
    n.connect(c2, c3);
    n.connect(c3, neck);
    n.connect(neck, det);
    n.connect(neck, mask_up);
    n.connect(mask_up, mask1);
    n.connect(mask1, mask2);
    n.connect(mask2, mask_pool);
    n.connect(det, join);
    n.connect(mask_pool, join);
    n.finalize();
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_mac_ratio_spans() {
        // The analogs must preserve Table 6's rough magnitude ordering; check
        // a few spot ratios (paper: hand/face ~ 10.5x, fastsam/face ~ 570x).
        let zoo = model_zoo();
        let m: Vec<f64> = zoo.iter().map(|n| n.total_macs() as f64).collect();
        assert!(m[2] / m[0] > 3.0, "hand/face ratio too small");
        assert!(m[8] / m[0] > 100.0, "fastsam/face ratio too small");
        assert!(m[7] / m[6] > 1.5, "mosaic/yolo ratio too small");
    }

    #[test]
    fn spec_names_match_networks() {
        for (i, spec) in SPECS.iter().enumerate() {
            assert_eq!(build_model(0, i).name, spec.name);
        }
    }

    #[test]
    fn layer_shapes_consistent_along_edges() {
        // For conv-like layers the declared in_channels must equal the sum
        // (concat) or the value (others) of predecessor output channels.
        use crate::graph::LayerKind;
        for net in model_zoo() {
            for l in 0..net.num_layers() {
                let lid = LayerId(l);
                let preds = net.predecessors(lid);
                if preds.is_empty() {
                    continue;
                }
                let layer = net.layer(lid);
                match layer.kind {
                    LayerKind::Concat => {
                        let total: usize = preds.iter().map(|&p| net.layer(p).out_shape.c).sum();
                        assert_eq!(layer.in_channels, total, "{}:{}", net.name, layer.name);
                    }
                    LayerKind::Add => {
                        for &p in preds {
                            assert_eq!(
                                net.layer(p).out_shape, layer.out_shape,
                                "{}:{} add operand shape mismatch", net.name, layer.name
                            );
                        }
                    }
                    _ => {
                        assert_eq!(preds.len(), 1, "{}:{} non-join with {} preds", net.name, layer.name, preds.len());
                        assert_eq!(
                            layer.in_channels,
                            net.layer(preds[0]).out_shape.c,
                            "{}:{} channel mismatch", net.name, layer.name
                        );
                    }
                }
            }
        }
    }

    use crate::graph::LayerId;
}
