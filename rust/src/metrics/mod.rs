//! XRBench-style scoring (paper §6.2).
//!
//! * **Makespan** Θ — time from a group's request to its last model
//!   finishing (produced by the simulator / runtime).
//! * **QoE score** — fraction of requests meeting the deadline (= period).
//! * **Realtime score** — sigmoid sensitivity to the deadline, k = 15.
//! * **Score(α, S)** — mean over groups of (mean RtScore · QoE).
//! * **Saturation multiplier** α* — the smallest α with Score = 1.0; the
//!   paper's headline metric ("how much load each method can handle").
//!
//! Accuracy score is omitted (partitioning never alters the computation;
//! the paper assumes 1.0) and the energy score is out of scope, as in the
//! paper.

/// Sigmoid sensitivity constant (paper: k = 15, from XRBench).
pub const K_SENSITIVITY: f64 = 15.0;

/// QoE score: fraction of requests whose makespan meets the deadline.
pub fn qoe_score(makespans: &[f64], deadline: f64) -> f64 {
    if makespans.is_empty() {
        return 0.0;
    }
    let ok = makespans.iter().filter(|&&m| m <= deadline).count();
    ok as f64 / makespans.len() as f64
}

/// Per-request realtime score: `1 / (1 + e^{k (Θ - Φ)})`.
///
/// Θ and Φ are in **seconds**; the paper's k = 15 is tuned for makespans on
/// the order of the period, so we scale the argument by the deadline to stay
/// unit-consistent (XRBench normalizes per-request slack the same way).
pub fn rt_score(makespan: f64, deadline: f64) -> f64 {
    let slack = if deadline > 0.0 { (makespan - deadline) / deadline } else { f64::INFINITY };
    1.0 / (1.0 + (K_SENSITIVITY * slack).exp())
}

/// Mean realtime score over a request series.
pub fn mean_rt_score(makespans: &[f64], deadline: f64) -> f64 {
    if makespans.is_empty() {
        return 0.0;
    }
    makespans.iter().map(|&m| rt_score(m, deadline)).sum::<f64>() / makespans.len() as f64
}

/// Scenario score at one period setting:
/// `Score = (1/N) Σ_G [ mean_j RtScore^{(j)} · QoE(G) ]`.
pub fn scenario_score(group_makespans: &[Vec<f64>], deadlines: &[f64]) -> f64 {
    assert_eq!(group_makespans.len(), deadlines.len());
    if group_makespans.is_empty() {
        return 0.0;
    }
    let n = group_makespans.len() as f64;
    group_makespans
        .iter()
        .zip(deadlines)
        .map(|(ms, &d)| mean_rt_score(ms, d) * qoe_score(ms, d))
        .sum::<f64>()
        / n
}

/// Score threshold treated as "1.0" for saturation search. The sigmoid never
/// quite reaches 1; XRBench's own aggregation rounds at two decimals.
pub const SATURATION_THRESHOLD: f64 = 0.995;

/// Find the saturation multiplier α* = min { α : Score(α) ≥ threshold } by
/// scanning a caller-supplied evaluator over a grid and refining by
/// bisection. Returns `None` if even `alpha_max` fails.
pub fn saturation_multiplier(
    mut eval: impl FnMut(f64) -> f64,
    alpha_min: f64,
    alpha_max: f64,
    tolerance: f64,
) -> Option<f64> {
    if eval(alpha_max) < SATURATION_THRESHOLD {
        return None;
    }
    let (mut lo, mut hi) = (alpha_min, alpha_max);
    if eval(lo) >= SATURATION_THRESHOLD {
        return Some(lo);
    }
    while hi - lo > tolerance {
        let mid = 0.5 * (lo + hi);
        if eval(mid) >= SATURATION_THRESHOLD {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    Some(hi)
}

/// Mean ± population standard deviation of a sample (reported throughout the
/// paper's evaluation as `Mean±SD`).
pub fn mean_sd(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qoe_counts_deadline_hits() {
        assert_eq!(qoe_score(&[0.5, 1.0, 1.5, 2.0], 1.0), 0.5);
        assert_eq!(qoe_score(&[], 1.0), 0.0);
        assert_eq!(qoe_score(&[0.1], 1.0), 1.0);
    }

    #[test]
    fn rt_score_sigmoid_shape() {
        // At the deadline: exactly 0.5. Well under: ~1. Well over: ~0.
        assert!((rt_score(1.0, 1.0) - 0.5).abs() < 1e-12);
        assert!(rt_score(0.5, 1.0) > 0.99);
        assert!(rt_score(2.0, 1.0) < 0.01);
    }

    #[test]
    fn rt_score_monotone_decreasing() {
        let mut prev = 1.0;
        for i in 1..20 {
            let s = rt_score(i as f64 * 0.2, 1.0);
            assert!(s < prev);
            prev = s;
        }
    }

    #[test]
    fn scenario_score_perfect_and_zero() {
        let fast = vec![vec![0.1, 0.2, 0.1], vec![0.2, 0.1, 0.2]];
        let s = scenario_score(&fast, &[1.0, 1.0]);
        assert!(s > 0.99, "score {s}");
        let slow = vec![vec![5.0; 3], vec![5.0; 3]];
        assert!(scenario_score(&slow, &[1.0, 1.0]) < 0.01);
    }

    #[test]
    fn scenario_score_averages_groups() {
        let mixed = vec![vec![0.1; 4], vec![9.0; 4]];
        let s = scenario_score(&mixed, &[1.0, 1.0]);
        assert!((s - 0.5).abs() < 0.01, "score {s}");
    }

    #[test]
    fn saturation_bisection_finds_knee() {
        // Score = 1 when alpha >= 1.3, else 0.
        let f = |a: f64| if a >= 1.3 { 1.0 } else { 0.0 };
        let a = saturation_multiplier(f, 0.1, 3.0, 1e-3).unwrap();
        assert!((a - 1.3).abs() < 2e-3, "alpha {a}");
    }

    #[test]
    fn saturation_none_when_unreachable() {
        assert!(saturation_multiplier(|_| 0.5, 0.1, 3.0, 1e-3).is_none());
    }

    #[test]
    fn saturation_clamps_at_min() {
        let a = saturation_multiplier(|_| 1.0, 0.2, 3.0, 1e-3).unwrap();
        assert_eq!(a, 0.2);
    }

    #[test]
    fn mean_sd_basic() {
        let (m, s) = mean_sd(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-12);
        assert!((s - 2.0).abs() < 1e-12);
    }
}
