//! Analytic M/M/c-style queueing envelopes for the serving runtime.
//!
//! An [`Envelope`] is computed *before* a load runs, from the same inputs
//! the utilization certificate uses ([`offered_utilization`]): per-group
//! long-run arrival rates and the solution set's profiled per-request work.
//! It predicts a band `[lo, hi]` for the deadline-violation fraction the
//! runtime will measure:
//!
//! * **ρ and the infeasibility certificate** — per-processor utilization
//!   ρ_p = Σ_g λ_g · E\[work_p per group-g request\]. ρ > 1 on any
//!   processor certifies unbounded backlog (the saturation driver's
//!   certificate, restated here).
//! * **Heavy-traffic waiting time** — a Kingman/VUT approximation at the
//!   bottleneck processor: `E[W] ≈ (Ca² + Cs²)/2 · ρ/(1−ρ) · E[S]`, with
//!   the arrival SCV `Ca²` taken from the arrival-process mix (periodic 0,
//!   Poisson 1, bursty ≈ burst size, schedules peak/mean) and `Cs² = 1`
//!   (M/M/c-style service variability covering the engine noise model).
//! * **Violation band** — the lower edge counts requests that *cannot*
//!   meet their deadline (deadline below the profiled subgraph-time
//!   floor); the upper edge applies a Markov tail bound
//!   `P(W > t) ≤ E[W]/t` to each group's slack after service, charges each
//!   group's first arrival for the t = 0 startup herd, and degenerates to
//!   1 past [`HEAVY_TRAFFIC_RHO`] — or whenever the *peak instantaneous*
//!   rates (burst clumps, flash-crowd spikes) transiently exceed ρ = 1 —
//!   where stationary bounds stop being informative for short probes.
//!
//! The property the fuzz harness enforces ([`crate::scenario::fuzz`],
//! `tests/fuzz_envelope.rs`): every fuzzed scenario's *measured*
//! [`ServeReport`] lands inside its envelope — one test that catches both
//! simulator bugs (measured outside an honest band) and queueing-model
//! bugs ([`certificate_corroborated`] cross-checks `mean_rates` against
//! the empirical rate of the very arrival schedule it describes).
//!
//! The band assumes the envelope's own protocol: virtual clock, queue-all
//! admission ([`crate::coordinator::OverloadPolicy::Queue`]), no fault
//! plan. Capped or chaos-injected runs are outside its contract.

use crate::coordinator::NetworkSolution;
use crate::perf::PerfModel;

use super::{offered_utilization, ArrivalProcess, LoadError, LoadSpec, ServeReport};

/// ρ above which the stationary tail bound is treated as uninformative for
/// finite probes: the upper band edge saturates to 1 (honest near α*,
/// where backlog growth dominates any heavy-traffic approximation).
pub const HEAVY_TRAFFIC_RHO: f64 = 0.85;

/// Safety multiplier on the Kingman mean wait inside the Markov tail
/// bound — covers the approximation error of treating the three-processor
/// pipeline as one bottleneck queue.
const WAIT_MARGIN: f64 = 3.0;

/// Inflation on a group's profiled serial work when computing its
/// post-service deadline slack: covers execution noise, transfer staging,
/// and dispatch overheads the profile omits.
const SERVICE_MARGIN: f64 = 1.5;

/// Deflation on the serial-work makespan floor for the *sure-violation*
/// lower edge: execution noise can only shrink a request's makespan so
/// far, so deadlines below `floor × FLOOR_SAFETY` are violated with
/// certainty.
const FLOOR_SAFETY: f64 = 0.5;

/// Arrivals of the long prefix [`certificate_corroborated`] samples when
/// cross-checking analytic mean rates against the generated schedule.
const CORROBORATION_PREFIX: usize = 512;

/// A pre-run analytic envelope for one (solution set, load) pair.
#[derive(Debug, Clone)]
pub struct Envelope {
    /// Offered per-processor utilization ρ (lower bound on true load).
    pub rho: [f64; 3],
    /// Largest per-processor ρ — the bottleneck utilization.
    pub rho_max: f64,
    /// Bottleneck utilization at *peak instantaneous* arrival rates (burst
    /// clumps, flash-crowd spikes). Above 1, the load is transiently
    /// overloaded even when `rho_max` is comfortable: backlog grows for
    /// the length of the clump, so the upper band edge saturates to 1 —
    /// stationary tail bounds are not informative for such probes.
    pub peak_rho_max: f64,
    /// ρ > 1 on some processor: queueing-theoretic infeasibility (backlog
    /// grows without bound; the violation band is `[lo, 1]`).
    pub certified_infeasible: bool,
    /// Largest arrival squared-coefficient-of-variation over the groups
    /// (the `Ca²` of the Kingman term).
    pub arrival_scv: f64,
    /// Heavy-traffic mean waiting time at the bottleneck, seconds
    /// (infinite when certified infeasible).
    pub mean_wait: f64,
    /// Predicted band `[lo, hi]` for the measured violation fraction
    /// (violations / served).
    pub band: (f64, f64),
    /// Per-group profiled serial work (seconds of compute one group
    /// request schedules, summed over member networks).
    pub group_work: Vec<f64>,
}

/// A measured report that landed outside its envelope.
#[derive(Debug, Clone)]
pub struct EnvelopeBreach {
    /// Measured violation fraction (violations / served).
    pub measured: f64,
    /// The predicted band the measurement missed.
    pub band: (f64, f64),
    /// Human-readable description of the breach.
    pub detail: String,
}

impl std::fmt::Display for EnvelopeBreach {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "measured violation fraction {:.4} outside [{:.4}, {:.4}]: {}",
            self.measured, self.band.0, self.band.1, self.detail
        )
    }
}

/// Squared coefficient of variation of inter-arrival times, per process:
/// periodic is deterministic (0), Poisson is memoryless (1), bursty clumps
/// `burst` back-to-back arrivals (index of dispersion ≈ burst), and a
/// piecewise schedule is scored by its peak-to-mean rate ratio (≥ 1 when
/// genuinely time-varying) — all conservative from the envelope's side,
/// since a larger Ca² only widens the band.
fn arrival_scv(process: &ArrivalProcess) -> f64 {
    match process {
        ArrivalProcess::Periodic { .. } => 0.0,
        ArrivalProcess::Poisson { .. } => 1.0,
        ArrivalProcess::Bursty { burst, .. } => (*burst).max(1) as f64,
        ArrivalProcess::Schedule { segments, .. } => {
            let peak = segments
                .iter()
                .map(|s| if s.period > 0.0 { 1.0 / s.period } else { 0.0 })
                .fold(0.0f64, f64::max);
            let cycle: f64 = segments.iter().map(|s| s.duration).sum();
            let per_cycle: f64 = segments
                .iter()
                .map(|s| (s.duration / s.period.max(1e-12)).ceil().max(1.0))
                .sum();
            let mean = if cycle > 0.0 { per_cycle / cycle } else { 0.0 };
            if mean > 0.0 {
                (peak / mean).max(1.0)
            } else {
                1.0
            }
        }
    }
}

/// Peak *instantaneous* arrival rate of a process, as generated: the
/// tightest spacing its schedule actually emits. Periodic and Poisson peak
/// at their mean (Poisson bunching is priced by `Ca² = 1` instead); bursty
/// clumps arrivals at `period / 10` spacing; a piecewise schedule peaks at
/// its fastest segment. Feeding these through [`offered_utilization`]
/// yields the *transient* bottleneck load — above 1, backlog grows during
/// the clump/spike even when the long-run ρ is comfortable, and short
/// probes can legitimately violate en masse.
fn peak_rate(process: &ArrivalProcess) -> f64 {
    match process {
        ArrivalProcess::Periodic { period } => {
            if *period > 0.0 {
                1.0 / period
            } else {
                0.0
            }
        }
        ArrivalProcess::Poisson { mean, .. } => {
            if *mean > 0.0 {
                1.0 / mean
            } else {
                0.0
            }
        }
        ArrivalProcess::Bursty { period, burst } => {
            if *period > 0.0 && *burst > 1 {
                10.0 / period
            } else if *period > 0.0 {
                1.0 / period
            } else {
                0.0
            }
        }
        ArrivalProcess::Schedule { segments, .. } => segments
            .iter()
            .map(|s| if s.period > 0.0 { 1.0 / s.period } else { 0.0 })
            .fold(0.0f64, f64::max),
    }
}

/// Per-group profiled serial work: seconds of compute one group request
/// schedules, summed over the group's member networks' subgraphs (the
/// same per-request work the utilization certificate charges).
fn per_group_work(
    solutions: &[NetworkSolution],
    groups: &[Vec<usize>],
    perf: &PerfModel,
) -> Vec<f64> {
    groups
        .iter()
        .map(|members| {
            members
                .iter()
                .map(|&n| {
                    let sol = &solutions[n];
                    sol.partition
                        .subgraphs
                        .iter()
                        .zip(&sol.configs)
                        .map(|(sg, cfg)| perf.subgraph_time(&sol.network, &sg.layers, *cfg))
                        .sum::<f64>()
                })
                .sum()
        })
        .collect()
}

/// Per-group makespan floor: the largest *single subgraph* time over the
/// group's members, deflated by [`FLOOR_SAFETY`] for favorable execution
/// noise. Deliberately weak — subgraphs of a branchy member can overlap
/// across processors, so the member's serial sum is **not** a lower bound,
/// but its longest subgraph must still execute somewhere inside the
/// request's makespan.
fn per_group_floor(
    solutions: &[NetworkSolution],
    groups: &[Vec<usize>],
    perf: &PerfModel,
) -> Vec<f64> {
    groups
        .iter()
        .map(|members| {
            members
                .iter()
                .map(|&n| {
                    let sol = &solutions[n];
                    sol.partition
                        .subgraphs
                        .iter()
                        .zip(&sol.configs)
                        .map(|(sg, cfg)| perf.subgraph_time(&sol.network, &sg.layers, *cfg))
                        .fold(0.0f64, f64::max)
                })
                .fold(0.0f64, f64::max)
                * FLOOR_SAFETY
        })
        .collect()
}

/// Compute the analytic envelope for one (solution set, load) pair. The
/// spec is [`LoadSpec::validate`]d first — malformed loads surface as a
/// typed [`LoadError`] here rather than NaN bands.
pub fn envelope_for(
    solutions: &[NetworkSolution],
    groups: &[Vec<usize>],
    spec: &LoadSpec,
    perf: &PerfModel,
) -> Result<Envelope, LoadError> {
    spec.validate()?;
    let rates = spec.mean_rates();
    let rho = offered_utilization(solutions, groups, &rates, perf);
    let rho_max = rho.iter().fold(0.0f64, |a, &b| a.max(b));
    let certified_infeasible = rho.iter().any(|&r| r > 1.0);
    let peak_rates: Vec<f64> = spec.groups.iter().map(|g| peak_rate(&g.process)).collect();
    let peak_rho = offered_utilization(solutions, groups, &peak_rates, perf);
    let peak_rho_max = peak_rho.iter().fold(0.0f64, |a, &b| a.max(b));
    let lambda_tot: f64 = rates.iter().sum();
    let arrival_scv_max =
        spec.groups.iter().map(|g| arrival_scv(&g.process)).fold(0.0f64, f64::max);

    // Kingman/VUT at the bottleneck: E[S] is the bottleneck seconds one
    // *average* group request schedules (ρ_max / λ_total).
    let bottleneck_service = if lambda_tot > 0.0 { rho_max / lambda_tot } else { 0.0 };
    let mean_wait = if rho_max >= 1.0 {
        f64::INFINITY
    } else {
        (arrival_scv_max + 1.0) / 2.0 * rho_max / (1.0 - rho_max) * bottleneck_service
    };

    let group_work = per_group_work(solutions, groups, perf);
    let group_floor = per_group_floor(solutions, groups, perf);
    let total_requests: f64 = spec.groups.iter().map(|g| g.requests as f64).sum();

    // Startup herd: every group's schedule can open at (or near) t = 0, so
    // the first request of a group may queue behind one request of every
    // other group regardless of the long-run rates.
    let herd: f64 = group_work.iter().sum::<f64>() * SERVICE_MARGIN;

    let mut lo = 0.0f64;
    let mut hi = 0.0f64;
    for (g, load) in spec.groups.iter().enumerate() {
        let Some(deadline) = load.deadline else { continue };
        let weight = load.requests as f64 / total_requests.max(1.0);
        if deadline < group_floor[g] {
            // No execution can beat the subgraph-time floor: every request
            // of this group violates, whatever the queueing.
            lo += weight;
            hi += weight;
            continue;
        }
        let room = deadline - group_work[g] * SERVICE_MARGIN;
        let tail = if !mean_wait.is_finite() {
            1.0
        } else if room <= 0.0 {
            1.0
        } else {
            (WAIT_MARGIN * mean_wait / room).min(1.0)
        };
        hi += weight * tail;
        if deadline < herd {
            // The group's first arrival may ride the t = 0 herd even when
            // the stationary wait is negligible.
            hi += weight * (1.0 / load.requests.max(1) as f64).min(1.0);
        }
    }
    if rho_max > HEAVY_TRAFFIC_RHO || peak_rho_max > 1.0 {
        hi = 1.0;
    }
    let lo = lo.min(1.0);
    let band = (lo, hi.clamp(lo, 1.0));

    Ok(Envelope {
        rho,
        rho_max,
        peak_rho_max,
        certified_infeasible,
        arrival_scv: arrival_scv_max,
        mean_wait,
        band,
        group_work,
    })
}

impl Envelope {
    /// The measured violation fraction of a report: violations over served
    /// requests (the band's denominator).
    pub fn measured_fraction(report: &ServeReport) -> f64 {
        report.violations as f64 / report.served.max(1) as f64
    }

    /// Check a measured report against the band. The upper edge gets a
    /// finite-sample allowance (`max(3σ, 2/n)` around the predicted
    /// fraction) — the band predicts an expectation, the report measures
    /// `n = served` Bernoulli draws of it.
    pub fn check(&self, report: &ServeReport) -> Result<(), EnvelopeBreach> {
        let measured = Self::measured_fraction(report);
        let n = report.served.max(1) as f64;
        let (lo, hi) = self.band;
        let sigma = (hi * (1.0 - hi) / n).sqrt();
        let hi_allow = (hi + (3.0 * sigma).max(2.0 / n)).min(1.0);
        let lo_allow = (lo - 2.0 / n).max(0.0);
        if measured < lo_allow {
            return Err(EnvelopeBreach {
                measured,
                band: self.band,
                detail: format!(
                    "below the sure-violation floor (≥ {lo_allow:.4} after sampling allowance)"
                ),
            });
        }
        if measured > hi_allow {
            return Err(EnvelopeBreach {
                measured,
                band: self.band,
                detail: format!(
                    "above the tail bound ({hi_allow:.4} after sampling allowance), \
                     rho_max {:.3}, mean wait {:.4}s",
                    self.rho_max, self.mean_wait
                ),
            });
        }
        Ok(())
    }
}

/// Cross-check an infeasibility certificate against the arrival schedule
/// it claims to describe: for every group, the empirical rate of a long
/// generated prefix (`(n−1)/span` over [`CORROBORATION_PREFIX`] arrivals)
/// must agree with [`LoadSpec::mean_rates`] within 20 % — Poisson sample
/// noise over 512 draws stays well inside that, and a genuine mismatch
/// means the certificate's λ (hence its ρ > 1 verdict) was computed from a
/// rate the load never offers: a **false certificate**, exactly the
/// queueing-model bug class the fuzz property hunts.
pub fn certificate_corroborated(spec: &LoadSpec) -> bool {
    spec.groups.iter().zip(spec.mean_rates()).all(|(load, rate)| {
        let times = load.process.times(CORROBORATION_PREFIX);
        let n = times.len();
        if n < 2 || rate <= 0.0 {
            return true;
        }
        let span = times[n - 1] - times[0];
        if span <= 0.0 {
            return true;
        }
        let empirical = (n - 1) as f64 / span;
        (empirical - rate).abs() <= 0.20 * rate
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ga::Genome;
    use crate::scenario::Scenario;
    use crate::serve::materialize_solutions;
    use crate::Processor;
    use std::sync::Arc;

    fn fixture() -> (Scenario, Vec<NetworkSolution>, Vec<Vec<usize>>, Arc<PerfModel>) {
        let scenario = Scenario::from_groups("env", &[vec![0, 1]]);
        let perf = Arc::new(PerfModel::paper_calibrated());
        let genome = Genome::all_on(&scenario.networks, Processor::Npu);
        let sols = materialize_solutions(&scenario.networks, &genome, &perf);
        let groups: Vec<Vec<usize>> = scenario.groups.iter().map(|g| g.members.clone()).collect();
        (scenario, sols, groups, perf)
    }

    #[test]
    fn feasible_load_gets_a_narrow_band() {
        let (scenario, sols, groups, perf) = fixture();
        let spec = LoadSpec::for_scenario(&scenario, &perf, 4.0, 8);
        let env = envelope_for(&sols, &groups, &spec, &perf).expect("valid spec");
        assert!(!env.certified_infeasible);
        assert!(env.rho_max < 1.0);
        assert!(env.mean_wait.is_finite());
        assert_eq!(env.band.0, 0.0);
        assert!(env.band.1 < 1.0, "comfortable load must not predict certain violations");
    }

    #[test]
    fn overload_certifies_and_band_tops_out() {
        let (scenario, sols, groups, perf) = fixture();
        let spec = LoadSpec::for_scenario(&scenario, &perf, 0.01, 8);
        let env = envelope_for(&sols, &groups, &spec, &perf).expect("valid spec");
        assert!(env.certified_infeasible);
        assert!(env.mean_wait.is_infinite());
        assert_eq!(env.band.1, 1.0);
        assert!(certificate_corroborated(&spec), "periodic rates are exact");
    }

    #[test]
    fn invalid_spec_is_a_typed_error() {
        let (_, sols, groups, perf) = fixture();
        let spec = LoadSpec::periodic(&[f64::NAN, 1.0], 4);
        let err = envelope_for(&sols, &groups, &spec, &perf).unwrap_err();
        assert!(matches!(err, LoadError::BadRate { group: 0, .. }));
    }

    #[test]
    fn corroboration_rejects_a_lying_rate() {
        // A schedule whose generated arrivals are twice as fast as any
        // mean_rates claim would be caught — simulate by comparing the
        // empirical rate of a periodic load against a doctored spec: the
        // cross-check passes for honest specs and is exercised end-to-end
        // by the fuzz property; here we pin the arithmetic on the honest
        // side for every built-in process.
        let periods = [0.01, 0.025];
        for spec in [
            LoadSpec::periodic(&periods, 4),
            LoadSpec::poisson(&periods, 4, 7),
            LoadSpec::bursty(&periods, 3, 4),
        ] {
            assert!(certificate_corroborated(&spec), "honest process flagged false");
        }
    }
}
