//! Arrival-driven serving (paper §6.3–6.4): open-loop load generation,
//! deadline accounting, and runtime-measured saturation.
//!
//! The paper's headline metric is *request frequency* — how much sustained
//! load each method handles while meeting real-time requirements (Figs
//! 12–16). This module provides the harness that measures it **through the
//! actual runtime** instead of the analytic simulator:
//!
//! * [`Clock`] — pluggable time source: [`WallClock`] for real serving,
//!   [`VirtualClock`] for deterministic, fast load tests
//!   ([`Coordinator::run_virtual`] advances it along the event schedule);
//! * [`ArrivalProcess`] / [`GroupLoad`] / [`LoadSpec`] — open-loop arrival
//!   schedules per model group: periodic at the scenario's period (Fig 11
//!   semantics), Poisson (user-driven events), an on/off bursty variant,
//!   and piecewise time-varying [`ArrivalProcess::Schedule`]s (diurnal
//!   ramps, flash crowds, mid-run group joins). [`LoadSpec::validate`]
//!   rejects malformed loads with a typed [`LoadError`];
//! * [`envelope`] — M/M/c-style analytic envelopes: per-processor ρ,
//!   heavy-traffic waiting-time approximations, and a predicted
//!   violation-probability band that every *measured* [`ServeReport`] must
//!   land inside (property-tested over the scenario fuzzer's corpus,
//!   [`crate::scenario::fuzz`]);
//! * [`run_load`] / [`RuntimeHarness`] — push one load through a
//!   Coordinator (existing or freshly deployed) and summarize the
//!   [`ServedRequest`] log as a [`ServeReport`] (attainment, violations,
//!   drops, XRBench score);
//! * [`WarmDeployment`] — a **persistent** Coordinator/Worker stack for one
//!   solution set: deploy once ([`RuntimeHarness::deploy`]), then replay any
//!   number of loads against the warm runtime. Between probes the stack is
//!   [`Coordinator::reset`] and its engine noise re-seeded, so a reused
//!   probe is bit-identical to one on a fresh deployment (tested);
//! * [`saturation_via_runtime`] — the saturation driver: binary-search the
//!   smallest period multiplier α whose **runtime-measured** score clears
//!   the SLO-attainment threshold, replacing the analytic-only
//!   `experiments::saturation_of` path for the serving figures. The driver
//!   deploys **exactly once per solution set** and reuses that warm stack
//!   for every α-probe, seeds the bisection bracket at the queueing-
//!   theoretic ρ = 1 point ([`rho_bracket_floor`]), and can apply
//!   Little's-law admission control ([`Admission`], [`little_inflight_cap`])
//!   instead of unbounded queueing.
//!
//! Every method (Puzzle, Best Mapping, NPU Only) is measured through this
//! one harness — [`materialize_solutions`] turns any genome into runtime
//! [`NetworkSolution`]s — so the comparison is apples-to-apples.
#![warn(missing_docs)]

pub mod envelope;
mod fault;

pub use envelope::{envelope_for, Envelope, EnvelopeBreach};
pub use fault::{FaultEvent, FaultPlan, FaultyEngine, FLAP_TRANSIENT_PROB};

use std::ops::ControlFlow;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::comm::CommModel;
use crate::coordinator::{
    Coordinator, DropReason, NetworkSolution, OverloadPolicy, RecoveryOptions, RuntimeOptions,
    ServedRequest,
};
use crate::engine::{Engine, SimEngine};
use crate::ga::{decode_network, Genome};
use crate::graph::Network;
use crate::metrics;
use crate::perf::PerfModel;
use crate::scenario::Scenario;
use crate::telemetry::TelemetryRx;
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// Clocks

/// A monotonic time source for the runtime, in seconds. Wall time for real
/// serving; a virtual clock for reproducible, fast load tests.
pub trait Clock: Send + Sync {
    /// Current reading, seconds since the clock's epoch.
    fn now(&self) -> f64;
    /// True for clocks advanced by an event loop rather than the OS.
    fn is_virtual(&self) -> bool {
        false
    }
}

/// Wall time relative to the clock's creation instant.
pub struct WallClock {
    epoch: Instant,
}

impl WallClock {
    /// A wall clock whose epoch is *now*.
    pub fn new() -> WallClock {
        WallClock { epoch: Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        WallClock::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }
}

/// Deterministic virtual time, advanced explicitly by the event-driven run
/// ([`Coordinator::run_virtual`]). Readable from any thread.
pub struct VirtualClock {
    bits: AtomicU64,
}

impl VirtualClock {
    /// A virtual clock starting at t = 0.
    pub fn new() -> VirtualClock {
        VirtualClock { bits: AtomicU64::new(0f64.to_bits()) }
    }

    /// Move the clock to `t` seconds (monotonicity is the caller's event
    /// order, not enforced here).
    pub fn advance_to(&self, t: f64) {
        self.bits.store(t.to_bits(), Ordering::Relaxed);
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        VirtualClock::new()
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }

    fn is_virtual(&self) -> bool {
        true
    }
}

// ---------------------------------------------------------------------------
// Open-loop load generation

/// One open-loop group-request arrival (simulated seconds; the wall driver
/// scales to wall seconds at the engine's time scale).
#[derive(Debug, Clone)]
pub struct Arrival {
    /// Arrival timestamp, simulated seconds from the load's start.
    pub time: f64,
    /// Model group this request targets.
    pub group: usize,
    /// Relative SLO deadline of this request (= the group period under the
    /// paper's protocol).
    pub deadline: Option<f64>,
}

/// One piecewise-constant segment of an [`ArrivalProcess::Schedule`]:
/// arrivals spaced `period` apart for `duration` seconds, so one schedule
/// cycle through the segment contributes `ceil(duration / period)`
/// arrivals.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RateSegment {
    /// How long this segment lasts, simulated seconds.
    pub duration: f64,
    /// Inter-arrival time while the segment is active, simulated seconds.
    pub period: f64,
}

impl RateSegment {
    /// A segment of `duration` seconds at inter-arrival time `period`.
    pub fn new(duration: f64, period: f64) -> RateSegment {
        RateSegment { duration, period }
    }

    /// Arrivals this segment contributes per schedule cycle (the `j` with
    /// `j·period < duration`).
    fn arrivals_per_cycle(&self) -> f64 {
        (self.duration / self.period).ceil().max(1.0)
    }
}

/// How one group's requests arrive. All processes are open-loop: arrival
/// times never depend on service completions (no back-pressure), which is
/// what exposes backlog growth past saturation.
#[derive(Debug, Clone)]
pub enum ArrivalProcess {
    /// Fixed-rate arrivals every `period` seconds (camera / microphone —
    /// the paper's protocol).
    Periodic {
        /// Inter-arrival time, simulated seconds.
        period: f64,
    },
    /// Poisson arrivals with mean inter-arrival `mean` seconds (user-driven
    /// aperiodic events), deterministic per seed.
    Poisson {
        /// Mean inter-arrival time, simulated seconds.
        mean: f64,
        /// Seed of the deterministic exponential draws.
        seed: u64,
    },
    /// On/off bursts: `burst` requests spaced `period / 10` apart, bursts
    /// starting every `burst × period` seconds — the long-run rate matches
    /// `Periodic { period }` but queueing is adversarial.
    Bursty {
        /// Long-run mean inter-arrival time, simulated seconds.
        period: f64,
        /// Requests per burst.
        burst: usize,
    },
    /// Piecewise time-varying arrival rate (diurnal ramps, flash-crowd
    /// spikes): the process cycles through `segments` indefinitely, each
    /// contributing fixed-spacing arrivals at its own period for its
    /// duration. `offset` delays the whole schedule — a group *joining* a
    /// running scenario at a later time (model churn).
    Schedule {
        /// Piecewise-constant rate segments, cycled for as long as the
        /// load keeps generating arrivals. Must be non-empty with finite
        /// positive durations and periods ([`GroupLoad::validate`]).
        segments: Vec<RateSegment>,
        /// Lead-in delay before the first segment starts, simulated
        /// seconds (`0.0` = live from the load's start).
        offset: f64,
    },
}

impl ArrivalProcess {
    /// The first `n` arrival timestamps of this process.
    pub fn times(&self, n: usize) -> Vec<f64> {
        match *self {
            ArrivalProcess::Periodic { period } => {
                (0..n).map(|j| period * j as f64).collect()
            }
            ArrivalProcess::Poisson { mean, seed } => {
                let mut rng = Rng::seed_from_u64(seed);
                let mut t = 0.0;
                (0..n)
                    .map(|_| {
                        let u = rng.gen_f64().max(1e-12);
                        t += -mean * u.ln();
                        t
                    })
                    .collect()
            }
            ArrivalProcess::Bursty { period, burst } => {
                let burst = burst.max(1);
                (0..n)
                    .map(|j| {
                        let k = (j / burst) as f64;
                        let i = (j % burst) as f64;
                        k * burst as f64 * period + i * period * 0.1
                    })
                    .collect()
            }
            ArrivalProcess::Schedule { ref segments, offset } => {
                let mut out = Vec::with_capacity(n);
                if segments.is_empty() {
                    return out;
                }
                let mut seg_start = offset.max(0.0);
                while out.len() < n {
                    let before = out.len();
                    for seg in segments {
                        let period = seg.period.max(1e-12);
                        let mut j = 0usize;
                        while (j as f64) * period < seg.duration && out.len() < n {
                            out.push(seg_start + j as f64 * period);
                            j += 1;
                        }
                        seg_start += seg.duration.max(0.0);
                        if out.len() == n {
                            break;
                        }
                    }
                    if out.len() == before {
                        // A degenerate schedule (all durations non-positive)
                        // makes no progress; validation rejects it upstream.
                        break;
                    }
                }
                out
            }
        }
    }
}

/// The load offered to one model group.
#[derive(Debug, Clone)]
pub struct GroupLoad {
    /// Arrival process generating this group's request timestamps.
    pub process: ArrivalProcess,
    /// Relative SLO deadline stamped on each request (the group period in
    /// the paper's protocol; `None` disables deadline accounting).
    pub deadline: Option<f64>,
    /// Number of requests offered to this group.
    pub requests: usize,
}

/// Which clock drives the load.
#[derive(Debug, Clone, Copy)]
pub enum ClockMode {
    /// Deterministic event-driven run (fast: the engine never sleeps).
    Virtual,
    /// Real time: arrivals scheduled on the wall clock at the deployment's
    /// time scale; `timeout` bounds the post-arrival drain.
    Wall {
        /// Wall-clock bound on draining in-flight work after the last
        /// arrival.
        timeout: Duration,
    },
}

/// A complete load test description, consumed by [`run_load`] /
/// [`crate::api::Deployment::serve_load`].
///
/// Constructors cover the paper's protocol and its stress variants; the
/// builder-style methods ([`LoadSpec::wall`], [`LoadSpec::with_policy`])
/// adjust clocking and admission:
///
/// ```
/// use puzzle::serve::{ArrivalProcess, LoadSpec};
///
/// // Two groups at 10 ms / 25 ms periods, 100 requests each; each request
/// // carries its group period as the SLO deadline.
/// let spec = LoadSpec::periodic(&[0.010, 0.025], 100);
/// assert_eq!(spec.groups.len(), 2);
/// assert_eq!(spec.groups[0].deadline, Some(0.010));
///
/// // Long-run mean arrival rates feed the utilization certificate
/// // (ρ = λ·E[work]): 1/period per group.
/// let rates = spec.mean_rates();
/// assert!((rates[0] - 100.0).abs() < 1e-9 && (rates[1] - 40.0).abs() < 1e-9);
///
/// // Same mean rates, adversarial clumping.
/// let bursty = LoadSpec::bursty(&[0.010, 0.025], 4, 100);
/// assert_eq!(bursty.mean_rates(), rates);
/// assert!(matches!(bursty.groups[0].process, ArrivalProcess::Bursty { .. }));
/// ```
#[derive(Debug, Clone)]
pub struct LoadSpec {
    /// One entry per model group of the deployment.
    pub groups: Vec<GroupLoad>,
    /// Which clock drives the load (deterministic virtual replay, or real
    /// time).
    pub mode: ClockMode,
    /// Admission policy applied for the duration of the load.
    pub policy: OverloadPolicy,
    /// Prices cross-subgraph tensor transfers into virtual ready times
    /// (wall runs pay real staging cost instead).
    pub comm: CommModel,
}

impl LoadSpec {
    fn from_processes(groups: Vec<GroupLoad>) -> LoadSpec {
        LoadSpec {
            groups,
            mode: ClockMode::Virtual,
            policy: OverloadPolicy::Queue,
            comm: CommModel::paper_calibrated(),
        }
    }

    /// The paper's protocol: periodic arrivals at each group's period, the
    /// period doubling as the deadline.
    pub fn periodic(periods: &[f64], requests: usize) -> LoadSpec {
        LoadSpec::from_processes(
            periods
                .iter()
                .map(|&p| GroupLoad {
                    process: ArrivalProcess::Periodic { period: p },
                    deadline: Some(p),
                    requests,
                })
                .collect(),
        )
    }

    /// Poisson arrivals at the same mean rate (and deadline) as
    /// [`LoadSpec::periodic`].
    pub fn poisson(periods: &[f64], requests: usize, seed: u64) -> LoadSpec {
        LoadSpec::from_processes(
            periods
                .iter()
                .enumerate()
                .map(|(g, &p)| GroupLoad {
                    process: ArrivalProcess::Poisson { mean: p, seed: seed ^ ((g as u64) << 16) },
                    deadline: Some(p),
                    requests,
                })
                .collect(),
        )
    }

    /// Bursty arrivals at the same long-run rate (and deadline) as
    /// [`LoadSpec::periodic`].
    pub fn bursty(periods: &[f64], burst: usize, requests: usize) -> LoadSpec {
        LoadSpec::from_processes(
            periods
                .iter()
                .map(|&p| GroupLoad {
                    process: ArrivalProcess::Bursty { period: p, burst },
                    deadline: Some(p),
                    requests,
                })
                .collect(),
        )
    }

    /// Periodic load for a scenario at period multiplier `alpha` (Fig 11
    /// semantics: Φ(α, Gi) = α·φ̄).
    pub fn for_scenario(
        scenario: &Scenario,
        perf: &PerfModel,
        alpha: f64,
        requests: usize,
    ) -> LoadSpec {
        LoadSpec::periodic(&scenario.periods(alpha, perf), requests)
    }

    /// Switch to wall-clock mode with the given drain timeout.
    pub fn wall(mut self, timeout: Duration) -> LoadSpec {
        self.mode = ClockMode::Wall { timeout };
        self
    }

    /// Replace the admission policy (queue everything, or drop past an
    /// in-flight cap — see [`little_inflight_cap`] for a derived cap).
    pub fn with_policy(mut self, policy: OverloadPolicy) -> LoadSpec {
        self.policy = policy;
        self
    }

    /// Long-run mean arrival rate (requests per simulated second) per
    /// group: `1/period` for periodic, `1/mean` for Poisson, the burst
    /// long-run rate `1/period` for bursty, and arrivals-per-cycle over
    /// cycle length for piecewise schedules (the lead-in `offset` is a
    /// one-time transient and does not affect the long-run rate) — the λ
    /// of the utilization certificate ρ = λ · E[work].
    pub fn mean_rates(&self) -> Vec<f64> {
        self.groups
            .iter()
            .map(|g| match g.process {
                ArrivalProcess::Periodic { period } => 1.0 / period,
                ArrivalProcess::Poisson { mean, .. } => 1.0 / mean,
                ArrivalProcess::Bursty { period, .. } => 1.0 / period,
                ArrivalProcess::Schedule { ref segments, .. } => {
                    let cycle: f64 = segments.iter().map(|s| s.duration).sum();
                    let per_cycle: f64 =
                        segments.iter().map(RateSegment::arrivals_per_cycle).sum();
                    if cycle > 0.0 {
                        per_cycle / cycle
                    } else {
                        0.0
                    }
                }
            })
            .collect()
    }

    /// Validate every group's load ([`GroupLoad::validate`]); the error
    /// names the first offending group. An empty spec is rejected outright
    /// — downstream it would produce an empty arrival vector and NaN-free
    /// but vacuous reports.
    pub fn validate(&self) -> Result<(), LoadError> {
        if self.groups.is_empty() {
            return Err(LoadError::NoGroups);
        }
        for (g, load) in self.groups.iter().enumerate() {
            load.validate(g)?;
        }
        Ok(())
    }
}

/// Why a [`LoadSpec`] or [`GroupLoad`] failed validation: malformed loads
/// (non-finite or non-positive rates/periods/deadlines, zero-request
/// groups, empty schedules) are rejected with a typed error instead of
/// producing NaN ρ or empty arrival vectors downstream.
#[derive(Debug, Clone, PartialEq)]
pub enum LoadError {
    /// The spec has no groups at all.
    NoGroups,
    /// A group offers zero requests.
    ZeroRequests {
        /// Offending group index.
        group: usize,
    },
    /// A rate parameter (period, Poisson mean, segment duration or period,
    /// schedule offset) is non-finite or out of range.
    BadRate {
        /// Offending group index.
        group: usize,
        /// Which parameter was rejected.
        what: &'static str,
        /// The rejected value.
        value: f64,
    },
    /// A deadline is non-finite or non-positive.
    BadDeadline {
        /// Offending group index.
        group: usize,
        /// The rejected deadline.
        value: f64,
    },
    /// A bursty process with zero requests per burst.
    ZeroBurst {
        /// Offending group index.
        group: usize,
    },
    /// A schedule with no segments.
    EmptySchedule {
        /// Offending group index.
        group: usize,
    },
}

impl std::fmt::Display for LoadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LoadError::NoGroups => write!(f, "load spec has no groups"),
            LoadError::ZeroRequests { group } => {
                write!(f, "group {group} offers zero requests")
            }
            LoadError::BadRate { group, what, value } => {
                write!(f, "group {group}: {what} must be finite and positive, got {value}")
            }
            LoadError::BadDeadline { group, value } => {
                write!(f, "group {group}: deadline must be finite and positive, got {value}")
            }
            LoadError::ZeroBurst { group } => {
                write!(f, "group {group}: bursty process needs at least one request per burst")
            }
            LoadError::EmptySchedule { group } => {
                write!(f, "group {group}: schedule has no segments")
            }
        }
    }
}

impl std::error::Error for LoadError {}

impl GroupLoad {
    /// Validate this group's load parameters: requests > 0, a finite
    /// positive deadline (when set), and finite positive rate parameters
    /// for every arrival-process variant. `group` is the index reported in
    /// the error.
    pub fn validate(&self, group: usize) -> Result<(), LoadError> {
        fn positive(group: usize, what: &'static str, value: f64) -> Result<(), LoadError> {
            if value.is_finite() && value > 0.0 {
                Ok(())
            } else {
                Err(LoadError::BadRate { group, what, value })
            }
        }
        if self.requests == 0 {
            return Err(LoadError::ZeroRequests { group });
        }
        if let Some(d) = self.deadline {
            if !d.is_finite() || d <= 0.0 {
                return Err(LoadError::BadDeadline { group, value: d });
            }
        }
        match &self.process {
            ArrivalProcess::Periodic { period } => positive(group, "period", *period),
            ArrivalProcess::Poisson { mean, .. } => positive(group, "mean", *mean),
            ArrivalProcess::Bursty { period, burst } => {
                positive(group, "period", *period)?;
                if *burst == 0 {
                    return Err(LoadError::ZeroBurst { group });
                }
                Ok(())
            }
            ArrivalProcess::Schedule { segments, offset } => {
                if segments.is_empty() {
                    return Err(LoadError::EmptySchedule { group });
                }
                for seg in segments {
                    positive(group, "segment duration", seg.duration)?;
                    positive(group, "segment period", seg.period)?;
                }
                if !offset.is_finite() || *offset < 0.0 {
                    return Err(LoadError::BadRate { group, what: "offset", value: *offset });
                }
                Ok(())
            }
        }
    }
}

/// Offered per-processor utilization ρ of a solution set under per-group
/// mean arrival rates (requests/second): ρ_p = Σ_g rate_g × (seconds of
/// processor-`p` work one group-`g` request schedules). Transfer and
/// dispatch overheads are *not* counted, so this is a **lower bound** on
/// the true load — ρ > 1 on any processor is a queueing-theoretic
/// infeasibility certificate (backlog grows without bound), which lets
/// [`saturation_via_runtime`] reject an α-probe without deploying a
/// runtime.
pub fn offered_utilization(
    solutions: &[NetworkSolution],
    groups: &[Vec<usize>],
    rates: &[f64],
    perf: &PerfModel,
) -> [f64; 3] {
    let mut rho = [0.0f64; 3];
    for (members, &rate) in groups.iter().zip(rates) {
        for &n in members {
            let sol = &solutions[n];
            for (sg, cfg) in sol.partition.subgraphs.iter().zip(&sol.configs) {
                rho[sg.processor.index()] +=
                    rate * perf.subgraph_time(&sol.network, &sg.layers, *cfg);
            }
        }
    }
    rho
}

/// Little's-law admission cap for [`OverloadPolicy::DropAfter`]: the
/// expected in-flight group-request population is L = Σ_g λ_g · W_g
/// (mean arrival rate × profiled per-request service time, summed over the
/// request's member networks' subgraphs), and the cap admits `slack` times
/// that — headroom for transient queueing — with a floor of one in-flight
/// request per group so light loads are never strangled.
///
/// The estimate is only meaningful at feasible load (ρ ≤ 1; past it the
/// stationary population is unbounded and L tracks the *offered* work
/// instead). That pairs naturally with the saturation driver, whose
/// utilization certificate skips ρ > 1 probes before admission control
/// could matter.
pub fn little_inflight_cap(
    solutions: &[NetworkSolution],
    groups: &[Vec<usize>],
    rates: &[f64],
    perf: &PerfModel,
    slack: f64,
) -> usize {
    let mut expected_inflight = 0.0f64;
    for (members, &rate) in groups.iter().zip(rates) {
        let mut work = 0.0f64;
        for &n in members {
            let sol = &solutions[n];
            for (sg, cfg) in sol.partition.subgraphs.iter().zip(&sol.configs) {
                work += perf.subgraph_time(&sol.network, &sg.layers, *cfg);
            }
        }
        expected_inflight += rate * work;
    }
    ((slack * expected_inflight).ceil() as usize).max(groups.len()).max(1)
}

/// The α below which the utilization certificate alone forces the
/// saturation probe's **median** score to zero — a queueing-informed lower
/// bound for the bisection bracket of [`saturation_via_runtime`].
///
/// Periods scale linearly in α (Φ(α, Gi) = α·φ̄), so rates scale as 1/α and
/// each solution set's certificate boundary is exactly its maximum
/// per-processor utilization at α = 1. The driver passes a probe on the
/// *median* score over the sets, so the bracket floor is the
/// (⌊n/2⌋ + 1)-th largest of those boundaries: strictly below it, more than
/// half the sets are certified infeasible (ρ > 1 ⇒ score 0) and the median
/// cannot clear any positive threshold. The returned value is backed off by
/// one part in 10⁹ so float rounding in the per-probe ρ computation can
/// never flip the certificate at the boundary: every α strictly below the
/// floor is certified infeasible, and **no feasible α is ever excluded**
/// (property-tested).
pub fn rho_bracket_floor(
    solution_sets: &[Vec<NetworkSolution>],
    scenario: &Scenario,
    perf: &PerfModel,
) -> f64 {
    if solution_sets.is_empty() {
        return 0.0;
    }
    let rates: Vec<f64> = scenario.periods(1.0, perf).iter().map(|p| 1.0 / p).collect();
    let groups: Vec<Vec<usize>> = scenario.groups.iter().map(|g| g.members.clone()).collect();
    let mut maxes: Vec<f64> = solution_sets
        .iter()
        .map(|sols| {
            offered_utilization(sols, &groups, &rates, perf)
                .iter()
                .fold(0.0f64, |a, &b| a.max(b))
        })
        .collect();
    maxes.sort_by(|a, b| a.partial_cmp(b).expect("finite utilizations"));
    let n = maxes.len();
    maxes[n - 1 - n / 2] * (1.0 - 1e-9)
}

/// Merge every group's arrival process into one time-ordered open-loop
/// schedule (stable: simultaneous arrivals keep group order, then per-group
/// generation order).
pub fn generate_arrivals(groups: &[GroupLoad]) -> Vec<Arrival> {
    let mut out = Vec::new();
    for (g, load) in groups.iter().enumerate() {
        for t in load.process.times(load.requests) {
            out.push(Arrival { time: t, group: g, deadline: load.deadline });
        }
    }
    out.sort_by(|a, b| a.time.partial_cmp(&b.time).expect("finite arrival times"));
    out
}

// ---------------------------------------------------------------------------
// Reports

/// Delta of one memory-accounting counter set across a single load —
/// Table 5's columns, snapshotted per load by [`run_load`] so reused
/// deployments (whose coordinators deliberately accumulate pool/arena
/// statistics across loads) can still be attributed load-by-load. Counts
/// are deterministic under the virtual clock; the millisecond fields are
/// wall-measured and are **not** part of any bit-identity contract.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MemDelta {
    /// (De)allocation time spent, milliseconds.
    pub malloc_ms: f64,
    /// Buffer allocations performed.
    pub mallocs: u64,
    /// Marshalling memcpy time, milliseconds.
    pub memcpy_ms: f64,
    /// Free time, milliseconds.
    pub free_ms: f64,
}

impl MemDelta {
    fn between(before: (f64, u64, f64, f64), after: (f64, u64, f64, f64)) -> MemDelta {
        MemDelta {
            malloc_ms: after.0 - before.0,
            mallocs: after.1.saturating_sub(before.1),
            memcpy_ms: after.2 - before.2,
            free_ms: after.3 - before.3,
        }
    }
}

/// Per-load memory accounting: the tensor pool's and the shared arena's
/// counter deltas across one load.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LoadMemStats {
    /// Worker-side tensor-pool delta (staging buffers).
    pub pool: MemDelta,
    /// Coordinator-side shared-arena delta (published boundary tensors).
    pub arena: MemDelta,
}

/// Summary of one load pushed through the runtime.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Requests offered by the arrival schedule (= served + dropped +
    /// unfinished-at-timeout).
    pub submitted: usize,
    /// Requests served to completion during this load.
    pub served: usize,
    /// Requests rejected by the admission policy **or shed by fault
    /// recovery** during this load ([`ServeReport::fault_shed`] is the
    /// recovery subset).
    pub dropped: usize,
    /// Requests still in flight when a wall-mode drain timeout expired
    /// (always 0 under the virtual clock, which runs to completion).
    pub unfinished: usize,
    /// Served requests that missed their deadline.
    pub violations: usize,
    /// Makespans per group in **simulated seconds**, completion order.
    pub group_makespans: Vec<Vec<f64>>,
    /// XRBench scenario score of the served makespans against the declared
    /// deadlines (falls back to `attainment` when no group declared one).
    /// Dropped/unfinished requests are *not* in the makespan series — they
    /// show up in `attainment`, which counts them as misses.
    pub score: f64,
    /// Fraction of offered requests served within their deadline (dropped
    /// and unfinished requests count as misses).
    pub attainment: f64,
    /// Wall-clock duration of the run.
    pub wall_seconds: f64,
    /// Offered per-processor utilization ρ of this load against the served
    /// solutions ([`offered_utilization`]; overheads excluded, so a lower
    /// bound). Populated by [`RuntimeHarness`] runs; `None` when the caller
    /// pushed a load through an existing coordinator without solution
    /// context.
    pub rho: Option<[f64; 3]>,
    /// Failed task attempts retried in place across the served requests
    /// (0 unless recovery is enabled and faults occurred).
    pub retries: u64,
    /// Subgraph tasks remapped to another processor across the served
    /// requests.
    pub remaps: u64,
    /// Requests shed by recovery after retry and remap were exhausted
    /// (subset of `dropped`). Filled by [`run_load`]; 0 from
    /// [`ServeReport::from_log`] alone.
    pub fault_shed: usize,
    /// Processor-seconds lost to failed attempts and retry backoff across
    /// the served requests.
    pub degraded_time: f64,
    /// Pool/arena accounting deltas for this load (Table 5). Filled by
    /// [`run_load`]; default from [`ServeReport::from_log`] alone.
    pub mem: LoadMemStats,
}

impl ServeReport {
    /// Summarize a slice of the served log. `offered` is the arrival count
    /// of the load (requests neither served nor dropped were left
    /// unfinished by a drain timeout); `scale` converts recorded makespans
    /// back to simulated seconds (wall runs record wall seconds);
    /// `deadlines` are per group, in simulated seconds.
    pub fn from_log(
        served: &[ServedRequest],
        dropped: usize,
        offered: usize,
        deadlines: &[Option<f64>],
        scale: f64,
        wall_seconds: f64,
    ) -> ServeReport {
        let scale = if scale > 0.0 { scale } else { 1.0 };
        let n_groups = deadlines.len();
        let mut group_makespans = vec![Vec::new(); n_groups];
        let mut violations = 0usize;
        let mut met = 0usize;
        let mut retries = 0u64;
        let mut remaps = 0u64;
        let mut degraded_time = 0.0f64;
        for s in served {
            if s.group < n_groups {
                group_makespans[s.group].push(s.makespan / scale);
            }
            if s.violated {
                violations += 1;
            } else {
                met += 1;
            }
            retries += s.retries as u64;
            remaps += s.remaps as u64;
            degraded_time += s.degraded;
        }
        let submitted = offered.max(served.len() + dropped);
        let unfinished = submitted - served.len() - dropped;
        let attainment = if submitted == 0 { 1.0 } else { met as f64 / submitted as f64 };
        let (scored, dls): (Vec<Vec<f64>>, Vec<f64>) = group_makespans
            .iter()
            .zip(deadlines)
            .filter_map(|(m, d)| d.map(|d| (m.clone(), d)))
            .unzip();
        let score = if dls.is_empty() {
            attainment
        } else {
            metrics::scenario_score(&scored, &dls)
        };
        ServeReport {
            submitted,
            served: served.len(),
            dropped,
            unfinished,
            violations,
            group_makespans,
            score,
            attainment,
            wall_seconds,
            rho: None,
            retries,
            remaps,
            fault_shed: 0,
            degraded_time,
            mem: LoadMemStats::default(),
        }
    }

    /// p-th percentile makespan of one group, simulated seconds.
    pub fn percentile(&self, group: usize, p: f64) -> f64 {
        crate::sim::percentile(&self.group_makespans[group], p)
    }

    /// Mean makespan of one group, simulated seconds.
    pub fn avg_makespan(&self, group: usize) -> f64 {
        let m = &self.group_makespans[group];
        if m.is_empty() { 0.0 } else { m.iter().sum::<f64>() / m.len() as f64 }
    }
}

// ---------------------------------------------------------------------------
// Drivers

/// Push one open-loop load through an existing coordinator. `groups[g]` are
/// the member network indices of model group `g`; `time_scale` is the
/// backing engine's wall-seconds per simulated second (wall mode only —
/// virtual runs are unscaled). The report covers only this load, even on a
/// coordinator that served earlier traffic.
pub fn run_load(
    coord: &mut Coordinator,
    groups: &[Vec<usize>],
    spec: &LoadSpec,
    time_scale: f64,
) -> ServeReport {
    // Finish stragglers from earlier traffic BEFORE snapshotting the log:
    // a request still in flight from a timed-out pump must complete under
    // the previous clock/policy and stay out of this load's report.
    coord.settle(Duration::from_secs(30));
    let prev_policy = coord.overload_policy();
    coord.set_overload_policy(spec.policy);
    let served_before = coord.served().len();
    let dropped_before = coord.dropped().len();
    // Pool/arena counters accumulate across loads on a warm coordinator
    // (Coordinator::reset deliberately leaves them); snapshot-delta them
    // here — mirroring the served-log snapshot above — so the report's
    // Table-5 numbers cover exactly this load.
    let pool_before = coord.pool_stats();
    let arena_before = coord.arena.stats.snapshot();
    let arrivals = generate_arrivals(&spec.groups);
    let offered = arrivals.len();
    // New telemetry window: heartbeat schedule and ρ accumulators rewind to
    // this load's t = 0 (run_virtual re-begins its own window — idempotent).
    coord.begin_telemetry_window();
    let t0 = Instant::now();
    let scale = match spec.mode {
        ClockMode::Virtual => {
            coord.run_virtual(&arrivals, groups, &spec.comm);
            1.0
        }
        ClockMode::Wall { timeout } => {
            let scale = if time_scale > 0.0 { time_scale } else { 1.0 };
            drive_wall(coord, groups, &arrivals, scale, timeout);
            scale
        }
    };
    let wall_seconds = t0.elapsed().as_secs_f64();
    coord.set_overload_policy(prev_policy);
    let deadlines: Vec<Option<f64>> = spec.groups.iter().map(|g| g.deadline).collect();
    let new_drops = &coord.dropped()[dropped_before..];
    let mut report = ServeReport::from_log(
        &coord.served()[served_before..],
        new_drops.len(),
        offered,
        &deadlines,
        scale,
        wall_seconds,
    );
    report.fault_shed =
        new_drops.iter().filter(|d| d.reason == DropReason::FaultShed).count();
    report.mem = LoadMemStats {
        pool: MemDelta::between(pool_before, coord.pool_stats()),
        arena: MemDelta::between(arena_before, coord.arena.stats.snapshot()),
    };
    report
}

/// Wall-clock open-loop driver: release each arrival when the wall reaches
/// its (scaled) timestamp, polling completions in between; drain the tail
/// under `timeout`.
///
/// Release timing is a park/spin-tail precise sleeper: coarse waits go
/// through `std::thread::park_timeout` in ≤ 500 µs slices (so completions
/// keep being polled at the historical cadence), and the last
/// [`SPIN_TAIL`] before the target busy-spins — release error is bounded
/// by scheduler wakeup jitter *within* the spin tail instead of the ~0.5 ms
/// sleep granularity of the former `thread::sleep` loop (asserted in the
/// wall-mode release-error test).
fn drive_wall(
    coord: &mut Coordinator,
    groups: &[Vec<usize>],
    arrivals: &[Arrival],
    scale: f64,
    timeout: Duration,
) {
    /// Busy-spin window before each release target: long enough to absorb
    /// `park_timeout`'s wakeup overshoot, short enough to keep the burned
    /// CPU negligible at serving periods.
    const SPIN_TAIL: f64 = 300e-6;
    let t0 = Instant::now();
    for a in arrivals {
        let target = a.time * scale;
        loop {
            coord.poll();
            let remaining = target - t0.elapsed().as_secs_f64();
            if remaining <= SPIN_TAIL {
                break;
            }
            std::thread::park_timeout(Duration::from_secs_f64(
                (remaining - SPIN_TAIL).min(500e-6),
            ));
        }
        while t0.elapsed().as_secs_f64() < target {
            std::hint::spin_loop();
        }
        let now = coord.now();
        coord.submit_group_at(a.group, &groups[a.group], now, a.deadline.map(|d| d * scale));
        coord.poll();
    }
    coord.pump(timeout);
}

// ---------------------------------------------------------------------------
// Deploying a genome straight into runtime solutions

/// Materialize runtime [`NetworkSolution`]s for a genome: partitions from
/// the cut chromosome, per-subgraph exec configs from the device model,
/// priorities from the priority chromosome. This is how the baselines enter
/// the same serving harness as Puzzle's Pareto solutions.
pub fn materialize_solutions(
    networks: &[Network],
    genome: &Genome,
    perf: &PerfModel,
) -> Vec<NetworkSolution> {
    networks
        .iter()
        .zip(&genome.networks)
        .enumerate()
        .map(|(i, (net, genes))| {
            let part = decode_network(net, genes);
            let configs = part
                .subgraphs
                .iter()
                .map(|sg| perf.best_config_for(net, &sg.layers, sg.processor).0)
                .collect();
            NetworkSolution {
                network: Arc::new(net.clone()),
                partition: Arc::new(part),
                configs,
                priority: genome.priority[i],
            }
        })
        .collect()
}

/// Everything needed to push loads through the runtime: the solution set,
/// group membership, device model, and engine knobs. One-shot runs
/// ([`RuntimeHarness::run`]) deploy → probe → shut down; the saturation
/// driver and the figure sweeps instead [`RuntimeHarness::deploy`] once and
/// replay every probe against the resulting [`WarmDeployment`].
#[derive(Clone)]
pub struct RuntimeHarness {
    /// Runtime solutions, one per network of the scenario. Shared — a
    /// figure sweep deploying one harness per solution set per α-band
    /// bumps a refcount instead of copying every plan
    /// ([`RuntimeHarness::for_shared`]).
    pub solutions: Arc<Vec<NetworkSolution>>,
    /// Member network indices per model group (shared, like
    /// [`RuntimeHarness::solutions`]).
    pub groups: Arc<Vec<Vec<usize>>>,
    /// The calibrated device model backing the simulated engine.
    pub perf: Arc<PerfModel>,
    /// Runtime ablation switches (tensor pool, zero-copy).
    pub options: RuntimeOptions,
    /// Apply the calibrated execution-noise model (as on the real device).
    pub noisy: bool,
    /// Engine noise seed for one-shot [`RuntimeHarness::run`] probes
    /// (warm-deployment probes pass an explicit per-probe seed instead).
    pub seed: u64,
    /// Engine wall-seconds per simulated second for wall-mode runs (virtual
    /// runs always use a non-sleeping engine).
    pub time_scale: f64,
    /// Chaos scenario injected into every deployment of this harness:
    /// `Some(plan)` wraps the engine in a [`FaultyEngine`] and enables the
    /// coordinator's watchdog/retry/remap recovery (even for an *empty*
    /// plan, which is how the no-fault identity contract is tested).
    /// `None` (the default) deploys the plain engine with recovery off —
    /// bit-identical to the pre-fault-injection runtime.
    pub fault_plan: Option<FaultPlan>,
}

/// Deterministic per-probe seed: stable in (base seed, solution-set index,
/// period multiplier), so repeated probes of one α agree and the score
/// curves share the saturation driver's schedule.
pub fn probe_seed(base: u64, set_index: usize, alpha: f64) -> u64 {
    base ^ ((set_index as u64) << 32) ^ (alpha.to_bits() >> 20)
}

impl RuntimeHarness {
    /// Harness for one genome on a scenario (deterministic; noise on).
    pub fn for_genome(
        scenario: &Scenario,
        genome: &Genome,
        perf: &Arc<PerfModel>,
        seed: u64,
    ) -> RuntimeHarness {
        RuntimeHarness::for_solutions(
            materialize_solutions(&scenario.networks, genome, perf),
            scenario.groups.iter().map(|g| g.members.clone()).collect(),
            perf.clone(),
            seed,
        )
    }

    /// Harness over prepared runtime solutions (noise on, virtual-speed
    /// engine) — the probe shape the saturation driver and the serving
    /// figures share.
    pub fn for_solutions(
        solutions: Vec<NetworkSolution>,
        groups: Vec<Vec<usize>>,
        perf: Arc<PerfModel>,
        seed: u64,
    ) -> RuntimeHarness {
        RuntimeHarness::for_shared(Arc::new(solutions), Arc::new(groups), perf, seed)
    }

    /// [`RuntimeHarness::for_solutions`] over already-shared solutions:
    /// the harness holds the `Arc`s as-is, so callers deploying many
    /// harnesses over one solution set (the score-band sweeps, the probe
    /// fleet) never duplicate the plans.
    pub fn for_shared(
        solutions: Arc<Vec<NetworkSolution>>,
        groups: Arc<Vec<Vec<usize>>>,
        perf: Arc<PerfModel>,
        seed: u64,
    ) -> RuntimeHarness {
        RuntimeHarness {
            solutions,
            groups,
            perf,
            options: RuntimeOptions::default(),
            noisy: true,
            seed,
            time_scale: 0.0,
            fault_plan: None,
        }
    }

    /// Attach a chaos scenario (builder style): deployments get a
    /// [`FaultyEngine`] and self-healing recovery. See
    /// [`RuntimeHarness::fault_plan`].
    pub fn with_fault_plan(mut self, plan: FaultPlan) -> RuntimeHarness {
        self.fault_plan = Some(plan);
        self
    }

    /// Offered per-processor utilization of `spec` against this harness's
    /// solutions (see [`offered_utilization`]).
    pub fn utilization(&self, spec: &LoadSpec) -> [f64; 3] {
        offered_utilization(&self.solutions, &self.groups, &spec.mean_rates(), &self.perf)
    }

    /// Deploy the Coordinator/Worker stack **once** for reuse across
    /// probes. `mode` fixes the engine pacing at spawn time: virtual probes
    /// need a non-sleeping engine, wall probes sleep at the harness time
    /// scale — probe a [`WarmDeployment`] only with specs of the mode it
    /// was deployed for.
    ///
    /// Wall mode uses the same fallback scale as the wall driver's arrival
    /// pacing ([`run_load`]): with a never-sleeping engine under real-time
    /// arrivals, every makespan would be ~0 and the report would measure
    /// nothing.
    pub fn deploy(&self, mode: ClockMode) -> WarmDeployment {
        let engine_scale = match mode {
            ClockMode::Virtual => 0.0,
            ClockMode::Wall { .. } => {
                if self.time_scale > 0.0 {
                    self.time_scale
                } else {
                    1.0
                }
            }
        };
        let engine: Arc<dyn Engine> = match &self.fault_plan {
            Some(plan) => Arc::new(FaultyEngine::new(
                self.perf.clone(),
                engine_scale,
                self.noisy,
                self.seed,
                plan.clone(),
            )),
            None => {
                Arc::new(SimEngine::new(self.perf.clone(), engine_scale, self.noisy, self.seed))
            }
        };
        let mut coordinator =
            Coordinator::new((*self.solutions).clone(), engine, self.options.clone());
        if self.fault_plan.is_some() {
            coordinator.enable_recovery(self.perf.clone(), RecoveryOptions::default());
        }
        WarmDeployment {
            coordinator,
            groups: self.groups.clone(),
            perf: self.perf.clone(),
            time_scale: self.time_scale,
        }
    }

    /// Deploy a fresh Coordinator/Worker stack, run the load, shut down.
    pub fn run(&self, spec: &LoadSpec) -> ServeReport {
        let (report, _) = self.run_with_log(spec);
        report
    }

    /// [`RuntimeHarness::run`], also returning the raw [`ServedRequest`]
    /// log (for determinism checks and custom accounting).
    pub fn run_with_log(&self, spec: &LoadSpec) -> (ServeReport, Vec<ServedRequest>) {
        let mut deployment = self.deploy(spec.mode);
        let out = deployment.probe_with_log(spec, self.seed);
        deployment.shutdown();
        out
    }
}

/// A deployed, **reusable** Coordinator/Worker stack for one solution set.
///
/// Construction ([`RuntimeHarness::deploy`]) spawns the runtime's worker
/// threads once; [`WarmDeployment::probe`] then replays any number of loads
/// — different α multipliers, different arrival patterns — against the warm
/// stack. Between probes the coordinator is [`Coordinator::reset`] (drain
/// in-flight work, clear logs and sequence counters) and the engine's noise
/// stream re-seeded, so a reused probe produces a [`ServeReport`] and
/// served log **bit-identical** to the same probe on a fresh deployment
/// (tested under the virtual clock). This is what lets the saturation
/// search pay one deployment per solution set instead of one per α-probe.
pub struct WarmDeployment {
    coordinator: Coordinator,
    groups: Arc<Vec<Vec<usize>>>,
    perf: Arc<PerfModel>,
    time_scale: f64,
}

impl WarmDeployment {
    /// Read access to the live coordinator (inspection, tests).
    pub fn coordinator(&self) -> &Coordinator {
        &self.coordinator
    }

    /// Attach a telemetry subscriber to the warm stack: subsequent probes
    /// publish their [`crate::telemetry::TelemetryEvent`] stream to the
    /// returned handle (non-blocking drain, counted drop-on-full). Without
    /// a subscriber the telemetry plane is contractually invisible — see
    /// [`crate::telemetry`].
    pub fn subscribe(&self) -> TelemetryRx {
        self.coordinator.subscribe()
    }

    /// Reset the warm stack, re-seed engine noise to `seed`, and push one
    /// load through it. Equivalent to [`RuntimeHarness::run`] with that
    /// seed on a freshly deployed stack, minus the deploy.
    pub fn probe(&mut self, spec: &LoadSpec, seed: u64) -> ServeReport {
        self.coordinator.reset();
        self.coordinator.engine().reseed(seed);
        let mut report = run_load(&mut self.coordinator, &self.groups, spec, self.time_scale);
        report.rho = Some(offered_utilization(
            self.coordinator.solutions(),
            &self.groups,
            &spec.mean_rates(),
            &self.perf,
        ));
        report
    }

    /// [`WarmDeployment::probe`], also returning the raw [`ServedRequest`]
    /// log of this probe (the reset guarantees the coordinator log contains
    /// exactly this load).
    pub fn probe_with_log(
        &mut self,
        spec: &LoadSpec,
        seed: u64,
    ) -> (ServeReport, Vec<ServedRequest>) {
        let report = self.probe(spec, seed);
        let log = self.coordinator.served().to_vec();
        (report, log)
    }

    /// Shut the workers down and join their threads.
    pub fn shutdown(self) {
        self.coordinator.shutdown();
    }
}

// ---------------------------------------------------------------------------
// Saturation driver

/// How the saturation driver admits probe arrivals.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Admission {
    /// Queue every arrival — the paper's implicit protocol, and the
    /// default: past saturation the backlog grows and the score collapses.
    Queue,
    /// Admission control: drop arrivals beyond a per-solution-set
    /// [`little_inflight_cap`] of `slack ×` the Little's-law expected
    /// in-flight population. Bounds probe backlog without hand-picking a
    /// constant per scenario.
    LittleCap {
        /// Headroom multiplier over the Little's-law estimate
        /// ([`Admission::DEFAULT_SLACK`] unless tuned).
        slack: f64,
    },
}

impl Admission {
    /// Default headroom multiplier for [`Admission::LittleCap`],
    /// calibrated against the [`crate::experiments::calibrate_slack`]
    /// sweep over the periodic fuzz corpus (`fuzz --calibrate`;
    /// [`crate::scenario::fuzz::FuzzConfig::calibration`]). In the cap's
    /// design domain — the saturation driver's periodic probes — the
    /// per-group floor of [`little_inflight_cap`] already absorbs the
    /// t = 0 arrival herd and the stationary population stays near one
    /// request per group, so the slack only has to cover transient
    /// queueing excursions: 2× the Little's-law estimate does, and the
    /// previous uncalibrated 3× bought nothing. A regression test
    /// (`tests/fuzz_envelope.rs`) pins the calibrated property: at this
    /// slack the cap is invisible (zero drops, bit-identical log) on a
    /// feasible periodic load.
    pub const DEFAULT_SLACK: f64 = 2.0;

    /// [`Admission::LittleCap`] at the default slack.
    pub fn little() -> Admission {
        Admission::LittleCap { slack: Admission::DEFAULT_SLACK }
    }
}

/// Knobs of the runtime saturation search.
#[derive(Debug, Clone)]
pub struct SaturationOptions {
    /// Requests per group per probe.
    pub requests: usize,
    /// Lower edge of the bisection bracket. The driver may *raise* it to
    /// the ρ = 1 point ([`rho_bracket_floor`]) — everything below is
    /// certified infeasible, so passing probes are never spent there.
    pub alpha_min: f64,
    /// Upper edge of the bisection bracket; failing here means the
    /// solutions cannot saturate at any probed load.
    pub alpha_max: f64,
    /// Bisection convergence width on α.
    pub tolerance: f64,
    /// Score treated as "meets the SLO" (XRBench rounds at two decimals).
    pub threshold: f64,
    /// Base seed of the deterministic per-probe noise schedule
    /// ([`probe_seed`]).
    pub seed: u64,
    /// Execution noise on (the paper measures on the fluctuating device).
    pub noisy: bool,
    /// Runtime ablation switches applied to every probe deployment.
    pub options: RuntimeOptions,
    /// Probe admission policy ([`Admission::Queue`] by default — the
    /// paper's protocol).
    pub admission: Admission,
    /// Chaos scenario injected into every probe deployment: the search then
    /// measures **robust-α*** — the request rate sustainable *under* the
    /// fault scenario, with the coordinator's recovery active — instead of
    /// nominal α*. `None` (the default) measures on pristine processors.
    pub fault_plan: Option<FaultPlan>,
    /// Probe-fleet width: how many solution sets of one α to probe
    /// concurrently (`0` = all cores, clamped to the set count). Each
    /// fleet worker owns its sets' [`WarmDeployment`]s for the whole
    /// search and probes them with the serial path's [`probe_seed`]
    /// derivation, so results are **bit-identical to the serial path for
    /// any thread count** (determinism contract #6, property-tested).
    pub probe_threads: usize,
    /// Shared core budget for the probe fleet. When set, every α-probe
    /// leases its fleet width from the budget instead of `probe_threads`
    /// (the lease alone bounds the width — no double clamp), so the
    /// search dynamically reclaims cores freed by sibling searches and
    /// shrinks to the caller's own thread when the pool is dry. Pure
    /// scheduling: deployments stay pinned to set indices and seeds stay
    /// positional, so α* and the probe stream are bit-identical for any
    /// budget (contract #6, property-tested nominal and under chaos).
    pub core_budget: Option<crate::util::threads::CoreBudget>,
}

impl Default for SaturationOptions {
    fn default() -> Self {
        SaturationOptions {
            requests: 12,
            alpha_min: 0.2,
            alpha_max: 6.0,
            tolerance: 0.01,
            threshold: metrics::SATURATION_THRESHOLD,
            seed: 23,
            noisy: true,
            options: RuntimeOptions::default(),
            admission: Admission::Queue,
            fault_plan: None,
            probe_threads: 0,
            core_budget: None,
        }
    }
}

/// One probe of the saturation search, streamed to the observer.
#[derive(Debug, Clone)]
pub struct ProbeProgress {
    /// Period multiplier probed.
    pub alpha: f64,
    /// Median runtime-measured score across the solution sets at `alpha`.
    pub score: f64,
    /// Probes evaluated so far (including this one).
    pub probes: usize,
    /// Solution sets of this probe whose runtime run was skipped by the
    /// utilization certificate (ρ > 1 on some processor ⇒ score 0 without
    /// touching the runtime).
    pub certified_infeasible: usize,
    /// Runtime deployments performed so far across the whole search. The
    /// probe-reuse contract: at most one per solution set, however many
    /// α-probes the bisection takes.
    pub deploys: usize,
}

/// Runtime-measured saturation multiplier α* of a set of candidate
/// solutions on a scenario: the smallest α whose **median runtime score**
/// (over the solution sets, the paper's multi-solution rule) clears the
/// threshold. The driver deploys **one persistent virtual-clock runtime
/// per solution set** ([`WarmDeployment`], asserted in tests) and replays
/// every α-probe against that warm stack, pushing periodic open-loop load
/// at Φ(α) through the real Coordinator. Returns `None` when even
/// `alpha_max` fails.
///
/// Probes whose offered utilization exceeds 1 on any processor are
/// **certified infeasible** without touching the runtime
/// ([`offered_utilization`]): sustained ρ > 1 load is unservable
/// regardless of what a short finite probe run happens to score, so the
/// certificate both skips pointless runtime work *and* makes α* robust to
/// short-run measurement artifacts (a 12-request probe at ρ ≈ 1.02 can
/// fluke past the threshold that a longer run would fail). The same
/// certificate **seeds the bisection bracket**: `alpha_min` is raised to
/// the ρ = 1 point ([`rho_bracket_floor`]), below which the median score
/// is certified zero — passing probes are never spent on a certainly-
/// failing region. Consequence of both: α* can come out slightly larger —
/// never smaller — than a purely-measured search.
pub fn saturation_via_runtime(
    solution_sets: &[Vec<NetworkSolution>],
    scenario: &Scenario,
    perf: &Arc<PerfModel>,
    opts: &SaturationOptions,
) -> Option<f64> {
    saturation_via_runtime_observed(solution_sets, scenario, perf, opts, &mut |_| {
        ControlFlow::Continue(())
    })
}

/// Per-set outcome of one α-probe: the runtime score plus the bookkeeping
/// flags the driver folds after the fleet joins. Every field is a pure
/// function of (solution set, α, seed), which is what lets the fold be
/// order-independent.
struct SetProbe {
    score: f64,
    skipped: bool,
    deployed: bool,
}

/// Probe one solution set at one α, lazily deploying its warm stack into
/// `slot` on the set's first non-certified probe. The serial loop and the
/// fleet workers share this exact body — same [`probe_seed`] derivation,
/// same certificate, same admission policy — so the parallel path is
/// bit-identical to the serial one by construction.
#[allow(clippy::too_many_arguments)]
fn probe_set(
    i: usize,
    sols: &[NetworkSolution],
    slot: &mut Option<WarmDeployment>,
    alpha: f64,
    spec: &LoadSpec,
    rates: &[f64],
    groups: &Arc<Vec<Vec<usize>>>,
    perf: &Arc<PerfModel>,
    opts: &SaturationOptions,
) -> SetProbe {
    // Utilization certificate: ρ > 1 on any processor means the offered
    // work exceeds capacity before any overhead — sustained load is
    // unservable, so score 0 without touching the runtime.
    let rho = offered_utilization(sols, groups, rates, perf);
    if rho.iter().any(|&r| r > 1.0) {
        return SetProbe { score: 0.0, skipped: true, deployed: false };
    }
    let mut deployed = false;
    if slot.is_none() {
        deployed = true;
        let mut harness = RuntimeHarness::for_shared(
            Arc::new(sols.to_vec()),
            groups.clone(),
            perf.clone(),
            opts.seed,
        );
        harness.options = opts.options.clone();
        harness.noisy = opts.noisy;
        harness.fault_plan = opts.fault_plan.clone();
        *slot = Some(harness.deploy(ClockMode::Virtual));
    }
    let deployment = slot.as_mut().expect("deployed above");
    let spec_i = match opts.admission {
        Admission::Queue => spec.clone(),
        Admission::LittleCap { slack } => spec.clone().with_policy(OverloadPolicy::DropAfter {
            max_inflight: little_inflight_cap(sols, groups, rates, perf, slack),
        }),
    };
    SetProbe {
        score: deployment.probe(&spec_i, probe_seed(opts.seed, i, alpha)).score,
        skipped: false,
        deployed,
    }
}

/// [`saturation_via_runtime`] with a per-probe observer; returning
/// [`ControlFlow::Break`] cancels the search (→ `None`), which is how the
/// CLI keeps long load tests interruptible.
///
/// With [`SaturationOptions::probe_threads`] resolved above 1, the
/// solution sets of each α are probed by a scoped fleet of workers —
/// deployments stay pinned to their set index across probes, per-set
/// scores land at their set index before the median fold, and the
/// observer still fires exactly once per α on the calling thread, so the
/// streamed [`ProbeProgress`] sequence and the returned α* are
/// bit-identical to the serial path.
pub fn saturation_via_runtime_observed(
    solution_sets: &[Vec<NetworkSolution>],
    scenario: &Scenario,
    perf: &Arc<PerfModel>,
    opts: &SaturationOptions,
    on_probe: &mut dyn FnMut(&ProbeProgress) -> ControlFlow<()>,
) -> Option<f64> {
    if solution_sets.is_empty() {
        return None;
    }
    let groups: Arc<Vec<Vec<usize>>> =
        Arc::new(scenario.groups.iter().map(|g| g.members.clone()).collect());
    // ρ-seeded bracket: below this point the certificate alone forces the
    // median score to zero, so the bisection never probes there.
    let alpha_min = opts
        .alpha_min
        .max(rho_bracket_floor(solution_sets, scenario, perf))
        .min(opts.alpha_max);
    // One warm deployment per solution set, created lazily at the set's
    // first non-certified probe and reused for every probe after it. The
    // fleet keeps each deployment pinned to its set index, so a set's
    // engine-noise stream never depends on which worker probes it.
    let mut deployments: Vec<Option<WarmDeployment>> =
        solution_sets.iter().map(|_| None).collect();
    let mut probes = 0usize;
    let mut deploys = 0usize;

    let outcome = 'search: {
        // Median runtime score at one multiplier; None = cancelled.
        let mut score_at = |alpha: f64, deployments: &mut [Option<WarmDeployment>]| -> Option<f64> {
            let spec = LoadSpec::periodic(&scenario.periods(alpha, perf), opts.requests);
            let rates = spec.mean_rates();
            // Fleet width, re-resolved per α-probe: with a shared core
            // budget the lease tracks what is free *right now* (freed
            // sibling cores are reclaimed probe by probe) and is the sole
            // bound on the width; without one, the static probe_threads
            // rule. Either way the width changes scheduling only.
            let (threads, _lease) = crate::util::threads::leased_threads(
                opts.core_budget.as_ref(),
                opts.probe_threads,
                solution_sets.len(),
            );
            let results: Vec<SetProbe> = if threads <= 1 {
                solution_sets
                    .iter()
                    .zip(deployments.iter_mut())
                    .enumerate()
                    .map(|(i, (sols, slot))| {
                        probe_set(i, sols, slot, alpha, &spec, &rates, &groups, perf, opts)
                    })
                    .collect()
            } else {
                // Fleet: chunk the per-set deployment slots across a
                // scoped pool. Chunks carry their base index, so every
                // probe still derives `probe_seed(seed, i, alpha)` from
                // the set's global index and every outcome lands at its
                // set's position — the fold below cannot observe the
                // thread count.
                let chunk = solution_sets.len().div_ceil(threads);
                let mut out: Vec<Option<SetProbe>> = Vec::new();
                out.resize_with(solution_sets.len(), || None);
                std::thread::scope(|scope| {
                    for ((base, sets), (slots, outs)) in solution_sets
                        .chunks(chunk)
                        .enumerate()
                        .map(|(c, sets)| (c * chunk, sets))
                        .zip(deployments.chunks_mut(chunk).zip(out.chunks_mut(chunk)))
                    {
                        let (spec, rates, groups) = (&spec, &rates, &groups);
                        scope.spawn(move || {
                            for (j, (sols, (slot, o))) in
                                sets.iter().zip(slots.iter_mut().zip(outs.iter_mut())).enumerate()
                            {
                                *o = Some(probe_set(
                                    base + j,
                                    sols,
                                    slot,
                                    alpha,
                                    spec,
                                    rates,
                                    groups,
                                    perf,
                                    opts,
                                ));
                            }
                        });
                    }
                });
                out.into_iter().map(|r| r.expect("every set probed")).collect()
            };
            let mut skipped = 0usize;
            let mut scores: Vec<f64> = Vec::with_capacity(results.len());
            for r in &results {
                skipped += r.skipped as usize;
                deploys += r.deployed as usize;
                scores.push(r.score);
            }
            scores.sort_by(|a, b| a.partial_cmp(b).expect("finite scores"));
            let median = scores[scores.len() / 2];
            probes += 1;
            let progress = ProbeProgress {
                alpha,
                score: median,
                probes,
                certified_infeasible: skipped,
                deploys,
            };
            if on_probe(&progress).is_break() {
                return None;
            }
            Some(median)
        };

        // Same grid + bisection as `metrics::saturation_multiplier`, but
        // cancellable per probe and bracketed from the ρ-seeded floor.
        match score_at(opts.alpha_max, &mut deployments) {
            None => break 'search None,
            Some(s) if s < opts.threshold => break 'search None,
            Some(_) => {}
        }
        match score_at(alpha_min, &mut deployments) {
            None => break 'search None,
            Some(s) if s >= opts.threshold => break 'search Some(alpha_min),
            Some(_) => {}
        }
        let (mut lo, mut hi) = (alpha_min, opts.alpha_max);
        while hi - lo > opts.tolerance {
            let mid = 0.5 * (lo + hi);
            match score_at(mid, &mut deployments) {
                None => break 'search None,
                Some(s) if s >= opts.threshold => hi = mid,
                Some(_) => lo = mid,
            }
        }
        Some(hi)
    };

    for deployment in deployments.into_iter().flatten() {
        deployment.shutdown();
    }
    outcome
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::Processor;

    #[test]
    fn clocks_behave() {
        let w = WallClock::new();
        let a = w.now();
        let b = w.now();
        assert!(b >= a && !w.is_virtual());
        let v = VirtualClock::new();
        assert_eq!(v.now(), 0.0);
        v.advance_to(1.5);
        assert_eq!(v.now(), 1.5);
        assert!(v.is_virtual());
    }

    #[test]
    fn periodic_and_bursty_preserve_mean_rate() {
        let p = ArrivalProcess::Periodic { period: 0.01 }.times(10);
        assert_eq!(p[0], 0.0);
        assert!((p[9] - 0.09).abs() < 1e-12);
        // Bursty: same long-run rate, clumped.
        let b = ArrivalProcess::Bursty { period: 0.01, burst: 4 }.times(8);
        assert_eq!(b[0], 0.0);
        assert!((b[4] - 0.04).abs() < 1e-12, "second burst starts at 4·period: {b:?}");
        // Within a burst, spacing is period/10.
        assert!((b[1] - 0.001).abs() < 1e-12);
        assert!(b.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn poisson_times_deterministic_per_seed() {
        let a = ArrivalProcess::Poisson { mean: 0.01, seed: 9 }.times(100);
        let b = ArrivalProcess::Poisson { mean: 0.01, seed: 9 }.times(100);
        let c = ArrivalProcess::Poisson { mean: 0.01, seed: 10 }.times(100);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!(a.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn arrivals_merge_in_time_order() {
        let spec = LoadSpec::periodic(&[0.010, 0.004], 3);
        let arrivals = generate_arrivals(&spec.groups);
        assert_eq!(arrivals.len(), 6);
        assert!(arrivals.windows(2).all(|w| w[0].time <= w[1].time));
        // Simultaneous arrivals (t = 0) keep group order.
        assert_eq!((arrivals[0].group, arrivals[1].group), (0, 1));
        assert_eq!(arrivals[0].deadline, Some(0.010));
    }

    #[test]
    fn report_scores_and_counts() {
        let served = vec![
            ServedRequest {
                group: 0,
                request: 0,
                arrival: 0.0,
                completion: 0.005,
                makespan: 0.005,
                deadline: Some(0.01),
                violated: false,
                retries: 1,
                remaps: 0,
                degraded: 0.002,
            },
            ServedRequest {
                group: 0,
                request: 1,
                arrival: 0.01,
                completion: 0.05,
                makespan: 0.04,
                deadline: Some(0.01),
                violated: true,
                retries: 0,
                remaps: 1,
                degraded: 0.01,
            },
        ];
        let r = ServeReport::from_log(&served, 1, 3, &[Some(0.01)], 1.0, 0.1);
        assert_eq!(r.submitted, 3);
        assert_eq!(r.served, 2);
        assert_eq!(r.dropped, 1);
        assert_eq!(r.unfinished, 0);
        assert_eq!(r.violations, 1);
        assert!((r.attainment - 1.0 / 3.0).abs() < 1e-12);
        assert!(r.score > 0.0 && r.score < 1.0);
        assert_eq!(r.group_makespans[0].len(), 2);
        // Fault accounting folds across the served entries.
        assert_eq!((r.retries, r.remaps), (1, 1));
        assert!((r.degraded_time - 0.012).abs() < 1e-12);
        // Requests a wall-mode drain timeout never finished count as
        // misses, not as a smaller denominator.
        let r = ServeReport::from_log(&served, 1, 5, &[Some(0.01)], 1.0, 0.1);
        assert_eq!(r.submitted, 5);
        assert_eq!(r.unfinished, 2);
        assert!((r.attainment - 1.0 / 5.0).abs() < 1e-12);
    }

    #[test]
    fn harness_runs_virtual_load_end_to_end() {
        let scenario = Scenario::from_groups("serve-test", &[vec![0, 1]]);
        let perf = Arc::new(PerfModel::paper_calibrated());
        let genome = Genome::all_on(&scenario.networks, Processor::Npu);
        let harness = RuntimeHarness::for_genome(&scenario, &genome, &perf, 7);
        let spec = LoadSpec::for_scenario(&scenario, &perf, 2.0, 8);
        let report = harness.run(&spec);
        assert_eq!(report.served, 8);
        assert_eq!(report.dropped, 0);
        assert!(report.group_makespans[0].iter().all(|&m| m > 0.0));
        // A 2x period is comfortable for two light models on the NPU.
        assert_eq!(report.violations, 0, "{report:?}");
        assert!(report.score > 0.9, "score {}", report.score);
    }

    #[test]
    fn saturation_driver_finds_knee_on_tiny_scenario() {
        let scenario = Scenario::from_groups("sat-test", &[vec![0, 1]]);
        let perf = Arc::new(PerfModel::paper_calibrated());
        let genome = Genome::all_on(&scenario.networks, Processor::Npu);
        let sets = vec![materialize_solutions(&scenario.networks, &genome, &perf)];
        let opts = SaturationOptions { requests: 10, tolerance: 0.02, ..Default::default() };
        let alpha = saturation_via_runtime(&sets, &scenario, &perf, &opts);
        let a = alpha.expect("light scenario saturates");
        assert!(a >= opts.alpha_min && a < opts.alpha_max, "alpha {a}");
        // Reproducible: the same search lands on the same knee.
        let again = saturation_via_runtime(&sets, &scenario, &perf, &opts).unwrap();
        assert_eq!(a, again);
    }

    #[test]
    fn utilization_matches_hand_math_and_is_logged() {
        // One network, whole on the NPU: a periodic load at period 2T gives
        // exactly rho_NPU = 0.5 and zero on the other processors.
        let scenario = Scenario::from_groups("rho-test", &[vec![0]]);
        let perf = Arc::new(PerfModel::paper_calibrated());
        let genome = Genome::all_on(&scenario.networks, Processor::Npu);
        let sols = materialize_solutions(&scenario.networks, &genome, &perf);
        let groups: Vec<Vec<usize>> =
            scenario.groups.iter().map(|g| g.members.clone()).collect();
        let t = perf.subgraph_time(
            &sols[0].network,
            &sols[0].partition.subgraphs[0].layers,
            sols[0].configs[0],
        );
        let spec = LoadSpec::periodic(&[2.0 * t], 4);
        let rho = offered_utilization(&sols, &groups, &spec.mean_rates(), &perf);
        assert!((rho[Processor::Npu.index()] - 0.5).abs() < 1e-9, "{rho:?}");
        assert_eq!(rho[Processor::Cpu.index()], 0.0);
        assert_eq!(rho[Processor::Gpu.index()], 0.0);
        // Harness runs log the certificate input in the report.
        let harness = RuntimeHarness::for_solutions(sols, groups, perf.clone(), 7);
        let report = harness.run(&spec);
        let logged = report.rho.expect("harness logs utilization");
        assert!((logged[Processor::Npu.index()] - 0.5).abs() < 1e-9, "{logged:?}");
    }

    #[test]
    fn saturation_certificate_skips_overloaded_probes() {
        // alpha_max so tight that offered utilization exceeds 1: the driver
        // must certify infeasibility and bail out without deploying any
        // runtime (observer sees the skip count).
        let scenario = Scenario::from_groups("cert-test", &[vec![0, 1]]);
        let perf = Arc::new(PerfModel::paper_calibrated());
        let genome = Genome::all_on(&scenario.networks, Processor::Npu);
        let sets = vec![materialize_solutions(&scenario.networks, &genome, &perf)];
        let opts = SaturationOptions {
            requests: 4,
            alpha_min: 0.001,
            alpha_max: 0.002,
            ..Default::default()
        };
        let mut skips = 0usize;
        let mut probes = 0usize;
        let mut deploys = usize::MAX;
        let out = saturation_via_runtime_observed(&sets, &scenario, &perf, &opts, &mut |p| {
            skips += p.certified_infeasible;
            probes = p.probes;
            deploys = p.deploys;
            ControlFlow::Continue(())
        });
        assert!(out.is_none(), "overloaded scenario must not saturate");
        assert_eq!(probes, 1, "certificate still counts as one probe");
        assert_eq!(skips, 1, "the one probe must be certified infeasible");
        assert_eq!(deploys, 0, "a fully certified probe must not deploy a runtime");
    }

    #[test]
    fn little_cap_matches_hand_math() {
        // One network whole on the NPU at period 2T: L = λ·W = T/(2T) = 0.5
        // expected in-flight requests; slack 3 → ceil(1.5) = 2.
        let scenario = Scenario::from_groups("little-test", &[vec![0]]);
        let perf = Arc::new(PerfModel::paper_calibrated());
        let genome = Genome::all_on(&scenario.networks, Processor::Npu);
        let sols = materialize_solutions(&scenario.networks, &genome, &perf);
        let groups: Vec<Vec<usize>> =
            scenario.groups.iter().map(|g| g.members.clone()).collect();
        let t = perf.subgraph_time(
            &sols[0].network,
            &sols[0].partition.subgraphs[0].layers,
            sols[0].configs[0],
        );
        let rates = LoadSpec::periodic(&[2.0 * t], 4).mean_rates();
        assert_eq!(little_inflight_cap(&sols, &groups, &rates, &perf, 3.0), 2);
        // The per-group floor: a vanishing load still admits one in-flight
        // request per group.
        let idle = LoadSpec::periodic(&[1e6 * t], 4).mean_rates();
        assert_eq!(little_inflight_cap(&sols, &groups, &idle, &perf, 3.0), 1);
    }

    #[test]
    fn rho_floor_is_median_certificate_boundary() {
        // One set: the floor is (within the 1e-9 backoff) the set's maximum
        // per-processor utilization at α = 1.
        let scenario = Scenario::from_groups("floor-test", &[vec![0, 1]]);
        let perf = Arc::new(PerfModel::paper_calibrated());
        let genome = Genome::all_on(&scenario.networks, Processor::Npu);
        let sols = materialize_solutions(&scenario.networks, &genome, &perf);
        let groups: Vec<Vec<usize>> =
            scenario.groups.iter().map(|g| g.members.clone()).collect();
        let rates: Vec<f64> =
            scenario.periods(1.0, &perf).iter().map(|p| 1.0 / p).collect();
        let expect = offered_utilization(&sols, &groups, &rates, &perf)
            .iter()
            .fold(0.0f64, |a, &b| a.max(b));
        let sets = vec![sols];
        let floor = rho_bracket_floor(&sets, &scenario, &perf);
        assert!(floor > 0.0 && floor <= expect, "floor {floor} vs boundary {expect}");
        assert!((floor - expect).abs() < 1e-6 * expect, "floor {floor} vs boundary {expect}");
        // And the driver's result never lands below the floor.
        let opts = SaturationOptions { requests: 8, tolerance: 0.02, ..Default::default() };
        let alpha = saturation_via_runtime(&sets, &scenario, &perf, &opts)
            .expect("light scenario saturates");
        assert!(alpha >= floor, "alpha* {alpha} below the certified floor {floor}");
    }

    #[test]
    fn load_validation_names_the_offending_group_and_field() {
        // Typed rejection of malformed loads (satellite of the fuzz PR):
        // each degenerate field maps to its LoadError variant, with the
        // group index preserved.
        let good = GroupLoad {
            process: ArrivalProcess::Periodic { period: 0.01 },
            deadline: Some(0.01),
            requests: 4,
        };

        let empty = LoadSpec::from_processes(vec![]);
        assert!(matches!(empty.validate(), Err(LoadError::NoGroups)));

        let mut zero_req = LoadSpec::from_processes(vec![good.clone(), good.clone()]);
        zero_req.groups[1].requests = 0;
        assert!(matches!(zero_req.validate(), Err(LoadError::ZeroRequests { group: 1 })));

        for bad_period in [f64::NAN, f64::INFINITY, 0.0, -1.0] {
            let mut spec = LoadSpec::from_processes(vec![good.clone()]);
            spec.groups[0].process = ArrivalProcess::Periodic { period: bad_period };
            assert!(
                matches!(spec.validate(), Err(LoadError::BadRate { group: 0, what: "period", .. })),
                "period {bad_period} must be rejected"
            );
        }

        let mut bad_mean = LoadSpec::from_processes(vec![good.clone()]);
        bad_mean.groups[0].process = ArrivalProcess::Poisson { mean: -0.5, seed: 1 };
        assert!(matches!(
            bad_mean.validate(),
            Err(LoadError::BadRate { group: 0, what: "mean", .. })
        ));

        let mut bad_deadline = LoadSpec::from_processes(vec![good.clone()]);
        bad_deadline.groups[0].deadline = Some(0.0);
        assert!(matches!(bad_deadline.validate(), Err(LoadError::BadDeadline { group: 0, .. })));

        let mut zero_burst = LoadSpec::from_processes(vec![good.clone()]);
        zero_burst.groups[0].process = ArrivalProcess::Bursty { period: 0.01, burst: 0 };
        assert!(matches!(zero_burst.validate(), Err(LoadError::ZeroBurst { group: 0 })));

        let mut empty_sched = LoadSpec::from_processes(vec![good.clone()]);
        empty_sched.groups[0].process =
            ArrivalProcess::Schedule { segments: vec![], offset: 0.0 };
        assert!(matches!(empty_sched.validate(), Err(LoadError::EmptySchedule { group: 0 })));

        let mut bad_seg = LoadSpec::from_processes(vec![good.clone()]);
        bad_seg.groups[0].process = ArrivalProcess::Schedule {
            segments: vec![RateSegment::new(1.0, f64::NAN)],
            offset: 0.0,
        };
        assert!(matches!(bad_seg.validate(), Err(LoadError::BadRate { group: 0, .. })));

        let mut bad_offset = LoadSpec::from_processes(vec![good]);
        bad_offset.groups[0].process = ArrivalProcess::Schedule {
            segments: vec![RateSegment::new(1.0, 0.01)],
            offset: -2.0,
        };
        assert!(matches!(
            bad_offset.validate(),
            Err(LoadError::BadRate { group: 0, what: "offset", .. })
        ));

        // Errors render through Display (std::error::Error is implemented).
        let msg = zero_burst.validate().unwrap_err().to_string();
        assert!(msg.contains("group 0"), "unhelpful error message: {msg}");
    }

    #[test]
    fn schedule_times_match_their_mean_rate() {
        // The Schedule arm of times() and mean_rates() must agree: the
        // empirical rate of a long generated prefix converges to the
        // analytic long-run rate (the certificate corroboration relies on
        // exactly this identity).
        let process = ArrivalProcess::Schedule {
            segments: vec![
                RateSegment::new(0.40, 0.010),
                RateSegment::new(0.20, 0.004),
                RateSegment::new(0.40, 0.020),
            ],
            offset: 0.0,
        };
        let spec = LoadSpec::from_processes(vec![GroupLoad {
            process: process.clone(),
            deadline: None,
            requests: 4,
        }]);
        let analytic = spec.mean_rates()[0];
        assert!(analytic > 0.0);
        let times = process.times(600);
        assert_eq!(times.len(), 600);
        assert!(times.windows(2).all(|w| w[1] >= w[0]), "arrivals must be non-decreasing");
        let empirical = (times.len() - 1) as f64 / (times[599] - times[0]);
        let err = (empirical - analytic).abs() / analytic;
        assert!(err < 0.05, "schedule empirical rate {empirical} vs analytic {analytic}");

        // Offset delays the whole schedule without changing its shape.
        let shifted = ArrivalProcess::Schedule {
            segments: vec![RateSegment::new(0.40, 0.010)],
            offset: 1.5,
        };
        let first = shifted.times(1)[0];
        assert!((first - 1.5).abs() < 1e-12, "offset schedule starts at the offset: {first}");

        // Degenerate schedules terminate instead of spinning.
        let empty = ArrivalProcess::Schedule { segments: vec![], offset: 0.0 };
        assert!(empty.times(5).is_empty());
    }

    #[test]
    fn saturation_driver_is_cancellable() {
        let scenario = Scenario::from_groups("cancel-test", &[vec![0]]);
        let perf = Arc::new(PerfModel::paper_calibrated());
        let genome = Genome::all_on(&scenario.networks, Processor::Npu);
        let sets = vec![materialize_solutions(&scenario.networks, &genome, &perf)];
        let opts = SaturationOptions { requests: 4, ..Default::default() };
        let mut seen = 0usize;
        let out = saturation_via_runtime_observed(&sets, &scenario, &perf, &opts, &mut |p| {
            seen = p.probes;
            if p.probes >= 2 { ControlFlow::Break(()) } else { ControlFlow::Continue(()) }
        });
        assert!(out.is_none(), "cancelled search must not report a knee");
        assert_eq!(seen, 2);
    }
}
