//! Deterministic processor-fault injection (chaos testing for the serving
//! runtime).
//!
//! Real mobile SoCs violate the profiler's assumptions constantly: thermal
//! throttling and DVFS steps slow a processor for seconds at a time, driver
//! resets and co-runner preemption stall it outright, and transient
//! execution errors fail individual tasks. A [`FaultPlan`] describes such a
//! scenario as a seeded timeline of [`FaultEvent`]s, and [`FaultyEngine`]
//! prices it into the simulated engine's task durations — slowdowns and
//! stalls stretch `elapsed`, transient faults surface as fallible
//! [`EngineOutput`]s — so the Coordinator's watchdog/retry/remap machinery
//! (see [`crate::coordinator::RecoveryOptions`]) can be exercised
//! reproducibly.
//!
//! Determinism contract: the per-task transient draws come from the same
//! seeded-RNG discipline as the engine's execution noise
//! ([`crate::util::rng::Rng`]), and [`FaultyEngine::reseed`] re-derives the
//! fault stream from the probe seed. Same seed + same plan ⇒ bit-identical
//! served/dropped logs on the virtual clock, including every retry and
//! remap. Zero-overhead contract: an **empty** plan short-circuits to the
//! wrapped [`SimEngine`] before any pricing or draw, so the no-fault path
//! stays bit-identical to (and allocates exactly as much as) the plain
//! runtime.

use std::sync::Mutex;

use crate::engine::{Engine, EngineOutput, EngineTask, SimEngine};
use crate::perf::PerfModel;
use crate::util::error::Result;
use crate::util::rng::Rng;
use crate::{anyhow, Processor};

/// One injected fault on the processor timeline. Times are clock seconds
/// (virtual seconds under [`crate::serve::VirtualClock`], which restarts at
/// 0 for every load — so a plan replays identically across probes).
#[derive(Debug, Clone, PartialEq)]
pub enum FaultEvent {
    /// Thermal throttle / DVFS step: every task starting on `processor`
    /// inside `[from, until)` runs `factor` times slower.
    Slowdown {
        /// Degraded processor.
        processor: Processor,
        /// Duration multiplier (> 1 slows the processor down).
        factor: f64,
        /// Window start, clock seconds.
        from: f64,
        /// Window end (exclusive), clock seconds.
        until: f64,
    },
    /// Driver reset / co-runner preemption: a task starting on `processor`
    /// inside `[at, at + duration)` cannot begin executing until the stall
    /// clears — its elapsed time absorbs the remaining stall.
    Stall {
        /// Stalled processor.
        processor: Processor,
        /// Stall start, clock seconds.
        at: f64,
        /// Stall length, seconds.
        duration: f64,
    },
    /// Per-task transient execution failure (driver error, bad DMA): each
    /// task independently fails with probability `prob`, consuming its
    /// (priced) duration before the failure surfaces.
    Transient {
        /// Per-task failure probability in `[0, 1]`.
        prob: f64,
    },
    /// Correlated fault bursts: `processor` flaps between healthy and
    /// transient-prone windows. Each `period` seconds, the first
    /// `duty × period` seconds are faulty — tasks starting inside a faulty
    /// window fail with probability [`FLAP_TRANSIENT_PROB`], drawn from the
    /// same seeded fault stream as [`FaultEvent::Transient`]. Clustered
    /// failures on one processor drive recovery through retry exhaustion
    /// into the remap memo far harder than independent transients do.
    Flap {
        /// Flapping processor.
        processor: Processor,
        /// Full healthy + faulty cycle length, clock seconds.
        period: f64,
        /// Fraction of each period spent transient-prone, in `[0, 1]`.
        duty: f64,
    },
}

/// Per-task failure probability inside a [`FaultEvent::Flap`] faulty
/// window. A constant: the flap's knobs are *where* the bad windows fall
/// (`period`, `duty`), while the failure draws come from the plan's
/// existing seeded fault stream.
pub const FLAP_TRANSIENT_PROB: f64 = 0.5;

/// A seeded chaos scenario: a set of [`FaultEvent`]s plus the seed salt of
/// the transient-failure draw stream. [`FaultPlan::default`] (no events,
/// seed 0) is the **empty plan**: attached to a [`FaultyEngine`] it is
/// contractually invisible — bit-identical logs, zero extra steady-state
/// allocation (both tested).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// The injected faults (order is irrelevant; windows may overlap, in
    /// which case slowdown factors multiply and the longest stall wins).
    pub events: Vec<FaultEvent>,
    /// Seed salt of the transient draw stream, XOR-ed with the engine's
    /// probe seed so distinct probes draw distinct-but-reproducible faults.
    pub seed: u64,
}

impl FaultPlan {
    /// An empty plan with the given transient-stream seed salt.
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { events: Vec::new(), seed }
    }

    /// Add a [`FaultEvent::Slowdown`] window (builder style).
    pub fn slowdown(mut self, processor: Processor, factor: f64, from: f64, until: f64) -> Self {
        self.events.push(FaultEvent::Slowdown { processor, factor, from, until });
        self
    }

    /// Add a [`FaultEvent::Stall`] window (builder style).
    pub fn stall(mut self, processor: Processor, at: f64, duration: f64) -> Self {
        self.events.push(FaultEvent::Stall { processor, at, duration });
        self
    }

    /// Add a [`FaultEvent::Transient`] failure probability (builder style).
    pub fn transient(mut self, prob: f64) -> Self {
        self.events.push(FaultEvent::Transient { prob });
        self
    }

    /// Add a [`FaultEvent::Flap`] healthy/faulty cycle (builder style).
    pub fn flap(mut self, processor: Processor, period: f64, duty: f64) -> Self {
        self.events.push(FaultEvent::Flap { processor, period, duty });
        self
    }

    /// True when the plan injects nothing — the zero-overhead fast path.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Combined per-task transient failure probability: independent
    /// [`FaultEvent::Transient`] events compose as `1 − Π(1 − pᵢ)`.
    pub fn transient_prob(&self) -> f64 {
        let mut survive = 1.0f64;
        for ev in &self.events {
            if let FaultEvent::Transient { prob } = ev {
                survive *= 1.0 - prob.clamp(0.0, 1.0);
            }
        }
        1.0 - survive
    }

    /// Seconds a task starting on `p` at time `t` must wait before it can
    /// begin executing (the remainder of the longest active stall; 0 when
    /// no stall covers `t`).
    pub fn stall_wait(&self, p: Processor, t: f64) -> f64 {
        let mut wait = 0.0f64;
        for ev in &self.events {
            if let FaultEvent::Stall { processor, at, duration } = *ev {
                if processor == p && t >= at && t < at + duration {
                    wait = wait.max(at + duration - t);
                }
            }
        }
        wait
    }

    /// True when a task starting on `p` at time `t` falls inside a faulty
    /// window of some [`FaultEvent::Flap`] on that processor: the first
    /// `duty × period` seconds of each cycle are faulty.
    pub fn flap_active(&self, p: Processor, t: f64) -> bool {
        for ev in &self.events {
            if let FaultEvent::Flap { processor, period, duty } = *ev {
                if processor == p
                    && period > 0.0
                    && t.rem_euclid(period) < duty.clamp(0.0, 1.0) * period
                {
                    return true;
                }
            }
        }
        false
    }

    /// Duration multiplier for a task starting on `p` at time `t`: the
    /// product of all active [`FaultEvent::Slowdown`] factors (1.0 when
    /// none is active).
    pub fn slowdown_factor(&self, p: Processor, t: f64) -> f64 {
        let mut factor = 1.0f64;
        for ev in &self.events {
            if let FaultEvent::Slowdown { processor, factor: f, from, until } = *ev {
                if processor == p && t >= from && t < until {
                    factor *= f.max(0.0);
                }
            }
        }
        factor
    }

    /// Parse a CLI chaos spec: comma-separated events, each
    /// colon-separated —
    ///
    /// * `slowdown:<proc>:<factor>:<from>:<until>`
    /// * `stall:<proc>:<at>:<duration>`
    /// * `transient:<prob>`
    /// * `flap:<proc>:<period>:<duty>`
    ///
    /// with `<proc>` one of `cpu`/`gpu`/`npu` (case-insensitive) and times
    /// in simulated seconds. Example:
    /// `stall:npu:0.005:0.05,slowdown:gpu:1.5:0:1,transient:0.02`.
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan> {
        let mut plan = FaultPlan::new(seed);
        for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let fields: Vec<&str> = part.split(':').map(str::trim).collect();
            let num = |i: usize| -> Result<f64> {
                fields
                    .get(i)
                    .ok_or_else(|| anyhow!("chaos event `{part}` is missing field {i}"))?
                    .parse::<f64>()
                    .map_err(|e| anyhow!("chaos event `{part}` field {i}: {e}"))
            };
            match fields[0].to_ascii_lowercase().as_str() {
                "slowdown" => {
                    if fields.len() != 5 {
                        return Err(anyhow!(
                            "slowdown takes proc:factor:from:until, got `{part}`"
                        ));
                    }
                    let p = parse_processor(fields[1], part)?;
                    plan = plan.slowdown(p, num(2)?, num(3)?, num(4)?);
                }
                "stall" => {
                    if fields.len() != 4 {
                        return Err(anyhow!("stall takes proc:at:duration, got `{part}`"));
                    }
                    let p = parse_processor(fields[1], part)?;
                    plan = plan.stall(p, num(2)?, num(3)?);
                }
                "transient" => {
                    if fields.len() != 2 {
                        return Err(anyhow!("transient takes one probability, got `{part}`"));
                    }
                    plan = plan.transient(num(1)?);
                }
                "flap" => {
                    if fields.len() != 4 {
                        return Err(anyhow!("flap takes proc:period:duty, got `{part}`"));
                    }
                    let p = parse_processor(fields[1], part)?;
                    plan = plan.flap(p, num(2)?, num(3)?);
                }
                other => {
                    return Err(anyhow!(
                        "unknown chaos event `{other}` (expected slowdown/stall/transient/flap)"
                    ))
                }
            }
        }
        Ok(plan)
    }
}

fn parse_processor(s: &str, context: &str) -> Result<Processor> {
    match s.to_ascii_lowercase().as_str() {
        "cpu" => Ok(Processor::Cpu),
        "gpu" => Ok(Processor::Gpu),
        "npu" => Ok(Processor::Npu),
        other => Err(anyhow!("unknown processor `{other}` in chaos event `{context}`")),
    }
}

/// Seed of the fault-injection draw stream: derived from the probe seed and
/// the plan's salt, and deliberately decorrelated from the execution-noise
/// stream (which is seeded with the probe seed directly) so attaching a
/// plan never perturbs the noise draws themselves.
fn fault_stream_seed(seed: u64, plan_seed: u64) -> u64 {
    seed ^ plan_seed.rotate_left(17) ^ 0xFA11_7BAD_5EED_0001
}

/// [`Engine`] wrapper that injects a [`FaultPlan`] into a [`SimEngine`]:
/// slowdowns and stalls are priced into the reported task durations
/// (keyed on the task's dispatch timestamp, [`EngineTask::start`]), and
/// transient failures surface as [`EngineOutput`]s with
/// [`EngineOutput::error`] set after consuming their priced duration.
///
/// [`FaultyEngine::reseed`] re-derives **both** streams — the inner
/// engine's execution noise and the fault draws — from the probe seed, so
/// warm-deployment probes replay chaos scenarios bit-identically.
pub struct FaultyEngine {
    inner: SimEngine,
    plan: FaultPlan,
    /// Cached combined transient probability (events never change).
    transient: f64,
    /// Cached "plan has a flap event" flag: plans without one must not
    /// reach the flap check at all, so their fault-stream draw order stays
    /// exactly what it was before flaps existed (replay compatibility).
    has_flap: bool,
    rng: Mutex<Rng>,
}

impl FaultyEngine {
    /// Wrap a fresh [`SimEngine`] (same knobs as [`SimEngine::new`]) with a
    /// fault plan.
    pub fn new(
        perf: std::sync::Arc<PerfModel>,
        time_scale: f64,
        noisy: bool,
        seed: u64,
        plan: FaultPlan,
    ) -> FaultyEngine {
        let transient = plan.transient_prob();
        let has_flap = plan.events.iter().any(|e| matches!(e, FaultEvent::Flap { .. }));
        let rng = Mutex::new(Rng::seed_from_u64(fault_stream_seed(seed, plan.seed)));
        FaultyEngine {
            inner: SimEngine::new(perf, time_scale, noisy, seed),
            plan,
            transient,
            has_flap,
            rng,
        }
    }

    /// The attached plan.
    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }
}

impl Engine for FaultyEngine {
    fn execute(&self, task: &EngineTask<'_>) -> Result<EngineOutput> {
        // Zero-overhead contract: an empty plan is one branch, then the
        // plain engine — no pricing, no draw, no allocation.
        if self.plan.is_empty() {
            return self.inner.execute(task);
        }
        let mut out = self.inner.execute(task)?;
        let p = task.config.processor;
        // Stalls gate the task's start; slowdowns stretch what then runs.
        // Both key on the dispatch timestamp — an idle-worker dispatch, so
        // it coincides with the execution start under the virtual clock.
        let wait = self.plan.stall_wait(p, task.start);
        let factor = self.plan.slowdown_factor(p, task.start + wait);
        let base = out.elapsed;
        out.elapsed = wait + base * factor;
        if self.inner.time_scale > 0.0 && out.elapsed > base {
            // Wall mode: the inner engine already slept the nominal
            // duration; sleep the injected remainder so wall timestamps
            // track the degraded schedule.
            std::thread::sleep(std::time::Duration::from_secs_f64(
                (out.elapsed - base) * self.inner.time_scale,
            ));
        }
        if self.transient > 0.0 && self.rng.lock().unwrap().gen_bool(self.transient) {
            out.tensors.clear();
            out.error = Some(format!("transient fault on {}", p.name()));
        }
        // Flap windows draw from the same fault stream, but only when the
        // task actually starts inside one — and never for flap-less plans,
        // whose draw order must match the pre-flap fault stream exactly.
        if out.error.is_none()
            && self.has_flap
            && self.plan.flap_active(p, task.start)
            && self.rng.lock().unwrap().gen_bool(FLAP_TRANSIENT_PROB)
        {
            out.tensors.clear();
            out.error = Some(format!("flap fault on {}", p.name()));
        }
        Ok(out)
    }

    fn name(&self) -> &str {
        "faulty-sim"
    }

    fn reseed(&self, seed: u64) {
        self.inner.reseed(seed);
        *self.rng.lock().unwrap() =
            Rng::seed_from_u64(fault_stream_seed(seed, self.plan.seed));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::partition;
    use crate::models::build_model;
    use crate::{Backend, DataType, ExecConfig};
    use std::sync::Arc;

    fn run_at(
        engine: &dyn Engine,
        net: &crate::graph::Network,
        part: &crate::graph::Partition,
        start: f64,
    ) -> EngineOutput {
        let task = EngineTask {
            network: net,
            subgraph: &part.subgraphs[0],
            config: ExecConfig::new(Processor::Npu, Backend::Qnn, DataType::Fp16),
            inputs: vec![],
            start,
        };
        engine.execute(&task).unwrap()
    }

    fn fixture() -> (crate::graph::Network, crate::graph::Partition, Arc<PerfModel>) {
        let net = build_model(0, 0);
        let part = partition(
            &net,
            &vec![false; net.num_edges()],
            &vec![Processor::Npu; net.num_layers()],
        );
        (net, part, Arc::new(PerfModel::paper_calibrated()))
    }

    #[test]
    fn empty_plan_matches_plain_engine_bit_for_bit() {
        let (net, part, pm) = fixture();
        let plain = SimEngine::new(pm.clone(), 0.0, true, 7);
        let faulty = FaultyEngine::new(pm, 0.0, true, 7, FaultPlan::new(0));
        for i in 0..8 {
            let a = run_at(&plain, &net, &part, i as f64);
            let b = run_at(&faulty, &net, &part, i as f64);
            assert_eq!(a.elapsed.to_bits(), b.elapsed.to_bits(), "draw {i}");
            assert!(a.error.is_none() && b.error.is_none());
        }
    }

    #[test]
    fn slowdown_prices_only_inside_its_window() {
        let (net, part, pm) = fixture();
        let plan = FaultPlan::new(0).slowdown(Processor::Npu, 3.0, 1.0, 2.0);
        let eng = FaultyEngine::new(pm.clone(), 0.0, false, 7, plan);
        let nominal = run_at(&SimEngine::new(pm, 0.0, false, 7), &net, &part, 0.0).elapsed;
        let before = run_at(&eng, &net, &part, 0.5).elapsed;
        let inside = run_at(&eng, &net, &part, 1.5).elapsed;
        let after = run_at(&eng, &net, &part, 2.5).elapsed;
        assert_eq!(before.to_bits(), nominal.to_bits());
        assert_eq!(after.to_bits(), nominal.to_bits());
        assert!((inside - 3.0 * nominal).abs() < 1e-12, "{inside} vs 3x{nominal}");
    }

    #[test]
    fn stall_absorbs_the_remaining_window() {
        let (net, part, pm) = fixture();
        let plan = FaultPlan::new(0).stall(Processor::Npu, 1.0, 0.5);
        let eng = FaultyEngine::new(pm.clone(), 0.0, false, 7, plan);
        let nominal = run_at(&SimEngine::new(pm, 0.0, false, 7), &net, &part, 0.0).elapsed;
        // Task starting 0.2 s into the stall waits the remaining 0.3 s.
        let stalled = run_at(&eng, &net, &part, 1.2).elapsed;
        assert!((stalled - (0.3 + nominal)).abs() < 1e-12, "{stalled}");
        // Other processors are unaffected.
        assert_eq!(eng.plan().stall_wait(Processor::Gpu, 1.2), 0.0);
    }

    #[test]
    fn transient_draws_are_seed_deterministic_and_reseedable() {
        let (net, part, pm) = fixture();
        let mk = |seed| {
            FaultyEngine::new(pm.clone(), 0.0, true, seed, FaultPlan::new(9).transient(0.5))
        };
        let outcomes = |eng: &FaultyEngine| -> Vec<bool> {
            (0..32).map(|_| run_at(eng, &net, &part, 0.0).error.is_some()).collect()
        };
        let a = outcomes(&mk(7));
        let b = outcomes(&mk(7));
        assert_eq!(a, b, "same seed must replay the same failures");
        assert!(a.iter().any(|&f| f) && a.iter().any(|&f| !f), "p=0.5 should mix");
        // A warm engine reseeded to s matches a fresh engine seeded s.
        let warm = mk(3);
        let _burn = outcomes(&warm);
        warm.reseed(7);
        assert_eq!(outcomes(&warm), a);
    }

    #[test]
    fn spec_parsing_roundtrips_and_rejects_garbage() {
        let plan = FaultPlan::parse(
            "stall:npu:0.005:0.05, slowdown:gpu:1.5:0:1, transient:0.02, flap:npu:1.0:0.5",
            5,
        )
        .unwrap();
        assert_eq!(plan.events.len(), 4);
        assert_eq!(plan.seed, 5);
        assert!(plan.stall_wait(Processor::Npu, 0.01) > 0.0);
        assert!((plan.slowdown_factor(Processor::Gpu, 0.5) - 1.5).abs() < 1e-12);
        assert!((plan.transient_prob() - 0.02).abs() < 1e-12);
        assert_eq!(
            plan.events[3],
            FaultEvent::Flap { processor: Processor::Npu, period: 1.0, duty: 0.5 }
        );
        assert!(FaultPlan::parse("melt:npu:1", 0).is_err());
        assert!(FaultPlan::parse("stall:tpu:0:1", 0).is_err());
        assert!(FaultPlan::parse("slowdown:npu:2:0", 0).is_err());
        assert!(FaultPlan::parse("transient:lots", 0).is_err());
        assert!(FaultPlan::parse("flap:npu:1.0", 0).is_err());
        assert!(FaultPlan::parse("flap:dsp:1.0:0.5", 0).is_err());
    }

    #[test]
    fn flap_windows_gate_where_failures_can_happen() {
        let (net, part, pm) = fixture();
        // 1 s cycle, first half faulty.
        let plan = FaultPlan::new(0).flap(Processor::Npu, 1.0, 0.5);
        assert!(plan.flap_active(Processor::Npu, 0.2));
        assert!(!plan.flap_active(Processor::Npu, 0.7));
        assert!(plan.flap_active(Processor::Npu, 7.3), "windows repeat every period");
        assert!(!plan.flap_active(Processor::Gpu, 0.2), "other processors unaffected");
        let eng = FaultyEngine::new(pm, 0.0, false, 7, plan);
        // Outside the faulty window a task can never fail...
        for i in 0..32 {
            let healthy = run_at(&eng, &net, &part, 0.6 + (i as f64) * 1.0);
            assert!(healthy.error.is_none(), "healthy-window task {i} failed");
        }
        // ...inside it, failures occur at FLAP_TRANSIENT_PROB and mix.
        let faulty: Vec<bool> = (0..32)
            .map(|i| run_at(&eng, &net, &part, 0.1 + (i as f64) * 1.0).error.is_some())
            .collect();
        assert!(faulty.iter().any(|&f| f) && faulty.iter().any(|&f| !f), "{faulty:?}");
    }

    #[test]
    fn flap_draws_replay_bit_identically_across_reseeds() {
        let (net, part, pm) = fixture();
        let mk = |seed| {
            FaultyEngine::new(
                pm.clone(),
                0.0,
                true,
                seed,
                FaultPlan::new(11).flap(Processor::Npu, 0.01, 0.4).transient(0.1),
            )
        };
        let outcomes = |eng: &FaultyEngine| -> Vec<(u64, bool)> {
            (0..48)
                .map(|i| {
                    let out = run_at(eng, &net, &part, (i as f64) * 0.003);
                    (out.elapsed.to_bits(), out.error.is_some())
                })
                .collect()
        };
        let a = outcomes(&mk(7));
        assert_eq!(a, outcomes(&mk(7)), "same seed must replay the same flap stream");
        assert_ne!(a, outcomes(&mk(8)), "distinct seeds must draw distinct streams");
        // A warm engine reseeded to s matches a fresh engine seeded s.
        let warm = mk(3);
        let _burn = outcomes(&warm);
        warm.reseed(7);
        assert_eq!(outcomes(&warm), a);
    }

    #[test]
    fn overlapping_faults_compose() {
        let plan = FaultPlan::new(0)
            .slowdown(Processor::Cpu, 2.0, 0.0, 10.0)
            .slowdown(Processor::Cpu, 1.5, 5.0, 10.0)
            .transient(0.1)
            .transient(0.1);
        assert!((plan.slowdown_factor(Processor::Cpu, 6.0) - 3.0).abs() < 1e-12);
        assert!((plan.slowdown_factor(Processor::Cpu, 1.0) - 2.0).abs() < 1e-12);
        assert!((plan.transient_prob() - 0.19).abs() < 1e-12);
    }
}
