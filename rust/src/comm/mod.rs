//! Communication-cost modeling (paper §4.1, Fig 5).
//!
//! Cross-processor tensor transfer = **RPC overhead** (marshalling +
//! unmarshalling, proportional to data size with a knee at 1 MiB) + **data
//! transfer** bounded by main-memory bandwidth (~40 GB/s on the S23U per the
//! STREAM benchmark).
//!
//! We reproduce both halves: [`microbench`] actually serializes buffers and
//! measures host marshalling cost (and a STREAM-style bandwidth probe), and
//! [`PiecewiseLinear`] fits the paper's two-segment regression to those
//! samples. [`CommModel`] is the calibrated model the simulator and the
//! Static Analyzer consume.

pub mod microbench;
mod regression;

pub use microbench::{default_size_sweep, rpc_microbenchmark, stream_bandwidth, RpcSample};
pub use regression::PiecewiseLinear;

/// Knee between the two regression regions (paper: 1 MiB).
pub const KNEE_BYTES: f64 = 1024.0 * 1024.0;

/// Calibrated communication-cost model.
///
/// `cost(bytes) = rpc_overhead(bytes) + bytes / bandwidth`, with
/// `rpc_overhead` the piecewise-linear fit of the marshalling microbenchmark.
#[derive(Debug, Clone)]
pub struct CommModel {
    pub rpc: PiecewiseLinear,
    /// Main-memory bandwidth in bytes/second.
    pub bandwidth_bytes_per_s: f64,
    /// Fixed per-call latency floor in seconds (queue + wakeup), present even
    /// for tiny messages.
    pub base_latency_s: f64,
}

impl CommModel {
    /// The paper-calibrated model: knee at 1 MiB, ~40 GB/s memory bandwidth,
    /// RPC overhead slopes chosen to reproduce Fig 5's shape (sub-millisecond
    /// below the knee, growing steeply above it).
    pub fn paper_calibrated() -> CommModel {
        CommModel {
            rpc: PiecewiseLinear {
                knee: KNEE_BYTES,
                // seconds = intercept + slope * bytes, per region.
                below_intercept: 30e-6,          // 30 us fixed marshalling setup
                below_slope: 120e-12,            // ~0.12 us per KiB
                above_intercept: 80e-6,          // larger setup above the knee
                above_slope: 260e-12,            // steeper marshalling slope
            },
            bandwidth_bytes_per_s: 40.0e9,
            base_latency_s: 20e-6,
        }
    }

    /// Fit a model from microbenchmark samples plus a measured bandwidth.
    pub fn fit(samples: &[RpcSample], bandwidth_bytes_per_s: f64) -> CommModel {
        CommModel {
            rpc: PiecewiseLinear::fit(samples, KNEE_BYTES),
            bandwidth_bytes_per_s,
            base_latency_s: 20e-6,
        }
    }

    /// Predicted cross-processor transfer cost, in seconds, for `bytes`.
    /// Same-processor handoffs are free at this level (the runtime passes
    /// buffers by reference; see `mem::SharedArena`).
    pub fn transfer_cost(&self, bytes: usize, same_processor: bool) -> f64 {
        if same_processor || bytes == 0 {
            return 0.0;
        }
        self.base_latency_s + self.rpc.predict(bytes as f64) + bytes as f64 / self.bandwidth_bytes_per_s
    }

    /// Transfer cost when the zero-copy shared buffer is enabled: the
    /// marshalling term disappears and only the base latency + a small
    /// cache-coherence cost remains (paper §5.3).
    pub fn transfer_cost_zero_copy(&self, bytes: usize, same_processor: bool) -> f64 {
        if same_processor || bytes == 0 {
            return 0.0;
        }
        // Coherence/ownership transfer still touches the data once.
        self.base_latency_s + bytes as f64 / (2.0 * self.bandwidth_bytes_per_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_for_same_processor() {
        let m = CommModel::paper_calibrated();
        assert_eq!(m.transfer_cost(1 << 20, true), 0.0);
        assert_eq!(m.transfer_cost(0, false), 0.0);
    }

    #[test]
    fn monotone_in_size() {
        let m = CommModel::paper_calibrated();
        let mut prev = 0.0;
        for kib in [1, 4, 16, 64, 256, 1024, 4096, 16384] {
            let c = m.transfer_cost(kib * 1024, false);
            assert!(c > prev, "cost not monotone at {kib} KiB");
            prev = c;
        }
    }

    #[test]
    fn knee_changes_slope() {
        let m = CommModel::paper_calibrated();
        // Marginal cost per byte above the knee must exceed below it.
        let below = m.rpc.predict(512.0 * 1024.0) - m.rpc.predict(256.0 * 1024.0);
        let above = m.rpc.predict(4096.0 * 1024.0) - m.rpc.predict(3840.0 * 1024.0);
        let per_byte_below = below / (256.0 * 1024.0);
        let per_byte_above = above / (256.0 * 1024.0);
        assert!(per_byte_above > per_byte_below);
    }

    #[test]
    fn zero_copy_is_cheaper() {
        let m = CommModel::paper_calibrated();
        for kib in [8, 128, 2048, 16384] {
            let b = kib * 1024;
            assert!(
                m.transfer_cost_zero_copy(b, false) < m.transfer_cost(b, false),
                "zero-copy not cheaper at {kib} KiB"
            );
        }
    }

    #[test]
    fn magnitude_sanity_vs_paper_fig5() {
        // Fig 5 shows sub-ms RPC overhead below 1 MiB and a few ms at tens
        // of MiB on the S23U.
        let m = CommModel::paper_calibrated();
        assert!(m.transfer_cost(64 * 1024, false) < 1e-3);
        let big = m.transfer_cost(32 << 20, false);
        assert!(big > 1e-3 && big < 50e-3, "32 MiB cost {big}");
    }
}
