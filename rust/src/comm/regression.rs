//! Piecewise-linear regression for the RPC overhead (paper §4.1).
//!
//! The paper observes the size→overhead relationship differs below and above
//! 1 MiB, and fits one linear segment per region. We do the same with
//! ordinary least squares per region.

use super::microbench::RpcSample;

/// Two-segment linear model `seconds = intercept + slope * bytes`, split at
/// `knee` bytes.
#[derive(Debug, Clone)]
pub struct PiecewiseLinear {
    pub knee: f64,
    pub below_intercept: f64,
    pub below_slope: f64,
    pub above_intercept: f64,
    pub above_slope: f64,
}

impl PiecewiseLinear {
    /// Ordinary least squares on each side of the knee. Falls back to a flat
    /// fit when a region has <2 samples.
    pub fn fit(samples: &[RpcSample], knee: f64) -> PiecewiseLinear {
        let below: Vec<(f64, f64)> = samples
            .iter()
            .filter(|s| (s.bytes as f64) < knee)
            .map(|s| (s.bytes as f64, s.seconds))
            .collect();
        let above: Vec<(f64, f64)> = samples
            .iter()
            .filter(|s| (s.bytes as f64) >= knee)
            .map(|s| (s.bytes as f64, s.seconds))
            .collect();
        let (bi, bs) = ols(&below);
        let (ai, as_) = ols(&above);
        PiecewiseLinear {
            knee,
            below_intercept: bi,
            below_slope: bs,
            above_intercept: ai,
            above_slope: as_,
        }
    }

    /// Predicted RPC overhead (seconds) for a payload of `bytes`.
    /// Negative predictions (possible from a noisy fit near zero) clamp to 0.
    pub fn predict(&self, bytes: f64) -> f64 {
        let v = if bytes < self.knee {
            self.below_intercept + self.below_slope * bytes
        } else {
            self.above_intercept + self.above_slope * bytes
        };
        v.max(0.0)
    }

    /// Coefficient of determination (R²) of the fit over a sample set.
    pub fn r_squared(&self, samples: &[RpcSample]) -> f64 {
        if samples.is_empty() {
            return 0.0;
        }
        let mean = samples.iter().map(|s| s.seconds).sum::<f64>() / samples.len() as f64;
        let ss_tot: f64 = samples.iter().map(|s| (s.seconds - mean).powi(2)).sum();
        let ss_res: f64 = samples
            .iter()
            .map(|s| (s.seconds - self.predict(s.bytes as f64)).powi(2))
            .sum();
        if ss_tot == 0.0 {
            return 1.0;
        }
        1.0 - ss_res / ss_tot
    }
}

/// Least-squares `y = a + b x`; degenerate inputs fall back to the mean.
fn ols(points: &[(f64, f64)]) -> (f64, f64) {
    let n = points.len() as f64;
    if points.is_empty() {
        return (0.0, 0.0);
    }
    if points.len() == 1 {
        return (points[0].1, 0.0);
    }
    let sx: f64 = points.iter().map(|p| p.0).sum();
    let sy: f64 = points.iter().map(|p| p.1).sum();
    let sxx: f64 = points.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = points.iter().map(|p| p.0 * p.1).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() < f64::EPSILON {
        return (sy / n, 0.0);
    }
    let b = (n * sxy - sx * sy) / denom;
    let a = (sy - b * sx) / n;
    (a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth(knee: f64) -> Vec<RpcSample> {
        // Ground truth: below = 10us + 0.1ns/B, above = 50us + 0.3ns/B.
        let mut out = Vec::new();
        for i in 1..=40 {
            let bytes = (i * 64 * 1024) as f64; // 64 KiB .. 2.5 MiB
            let s = if bytes < knee {
                10e-6 + 0.1e-9 * bytes
            } else {
                50e-6 + 0.3e-9 * bytes
            };
            out.push(RpcSample { bytes: bytes as usize, seconds: s });
        }
        out
    }

    #[test]
    fn recovers_synthetic_coefficients() {
        let knee = 1024.0 * 1024.0;
        let fit = PiecewiseLinear::fit(&synth(knee), knee);
        assert!((fit.below_slope - 0.1e-9).abs() < 1e-12, "below slope {}", fit.below_slope);
        assert!((fit.above_slope - 0.3e-9).abs() < 1e-12, "above slope {}", fit.above_slope);
        assert!((fit.below_intercept - 10e-6).abs() < 1e-7);
        assert!((fit.above_intercept - 50e-6).abs() < 1e-7);
    }

    #[test]
    fn r_squared_near_one_for_clean_data() {
        let knee = 1024.0 * 1024.0;
        let s = synth(knee);
        let fit = PiecewiseLinear::fit(&s, knee);
        assert!(fit.r_squared(&s) > 0.999);
    }

    #[test]
    fn predict_clamps_negative() {
        let pl = PiecewiseLinear {
            knee: 100.0,
            below_intercept: -1.0,
            below_slope: 0.0,
            above_intercept: 0.0,
            above_slope: 0.0,
        };
        assert_eq!(pl.predict(10.0), 0.0);
    }

    #[test]
    fn ols_degenerate_inputs() {
        assert_eq!(ols(&[]), (0.0, 0.0));
        assert_eq!(ols(&[(5.0, 3.0)]), (3.0, 0.0));
        let (a, b) = ols(&[(2.0, 7.0), (2.0, 9.0)]); // vertical line
        assert_eq!(b, 0.0);
        assert!((a - 8.0).abs() < 1e-12);
    }
}
