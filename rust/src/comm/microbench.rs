//! Microbenchmarks backing the communication-cost model (paper §4.1).
//!
//! * [`rpc_microbenchmark`] — the marshalling probe: serialize + deserialize
//!   payloads of varying sizes through an actual byte-copy round trip
//!   (the mechanism ION-less Android RPC pays for), timing each size.
//! * [`stream_bandwidth`] — a STREAM-style copy-bandwidth probe, the analog
//!   of the paper's use of McCalpin's STREAM to find the S23U's ~40 GB/s.

use std::time::Instant;

/// One (payload size, measured seconds) observation.
#[derive(Debug, Clone, Copy)]
pub struct RpcSample {
    pub bytes: usize,
    pub seconds: f64,
}

/// Simulated RPC marshalling: length-prefix frame + payload copy out
/// (marshal), then parse + copy back in (unmarshal). This is deliberately a
/// real data movement, not a sleep — the measured cost scales with size the
/// same way the paper's Fig 5 microbenchmark does.
fn marshal_roundtrip(payload: &[u8], scratch: &mut Vec<u8>, out: &mut Vec<u8>) {
    scratch.clear();
    scratch.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    scratch.extend_from_slice(payload);
    // "Unmarshal": validate the frame and copy the body out.
    let len = u64::from_le_bytes(scratch[..8].try_into().unwrap()) as usize;
    out.clear();
    out.extend_from_slice(&scratch[8..8 + len]);
}

/// Run the RPC overhead microbenchmark over a log-spaced size sweep
/// (default 1 KiB .. 32 MiB), `reps` repetitions per size, keeping the
/// minimum (least-noise) observation, as microbenchmarks conventionally do.
pub fn rpc_microbenchmark(sizes: &[usize], reps: usize) -> Vec<RpcSample> {
    let max = sizes.iter().copied().max().unwrap_or(0);
    let payload = vec![0xa5u8; max];
    let mut scratch = Vec::with_capacity(max + 8);
    let mut out = Vec::with_capacity(max);
    let mut samples = Vec::with_capacity(sizes.len());
    for &size in sizes {
        // Warm-up to fault pages in.
        marshal_roundtrip(&payload[..size], &mut scratch, &mut out);
        let mut best = f64::INFINITY;
        for _ in 0..reps.max(1) {
            let t0 = Instant::now();
            marshal_roundtrip(&payload[..size], &mut scratch, &mut out);
            best = best.min(t0.elapsed().as_secs_f64());
        }
        // Defeat dead-code elimination.
        std::hint::black_box(&out);
        samples.push(RpcSample { bytes: size, seconds: best });
    }
    samples
}

/// Default log-spaced sweep 1 KiB..32 MiB (doubling), matching Fig 5's range.
pub fn default_size_sweep() -> Vec<usize> {
    let mut sizes = Vec::new();
    let mut s = 1024usize;
    while s <= 32 << 20 {
        sizes.push(s);
        s *= 2;
    }
    sizes
}

/// STREAM-style copy bandwidth probe: large-array copy, bytes moved per
/// second (counting read+write as 2x, as STREAM's Copy kernel does).
pub fn stream_bandwidth(array_bytes: usize, reps: usize) -> f64 {
    let n = array_bytes.max(1 << 20);
    let src = vec![1.0f64; n / 8];
    let mut dst = vec![0.0f64; n / 8];
    // Warm-up.
    dst.copy_from_slice(&src);
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        dst.copy_from_slice(&src);
        best = best.min(t0.elapsed().as_secs_f64());
    }
    std::hint::black_box(&dst);
    (2 * n) as f64 / best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn microbench_monotone_ish() {
        // 4 KiB should marshal faster than 4 MiB; exact monotonicity is not
        // guaranteed under noise, so compare endpoints with margin.
        let samples = rpc_microbenchmark(&[4 * 1024, 4 * 1024 * 1024], 5);
        assert!(samples[1].seconds > samples[0].seconds);
    }

    #[test]
    fn sweep_covers_knee() {
        let sweep = default_size_sweep();
        assert!(sweep.contains(&(1 << 20)), "sweep must straddle the 1 MiB knee");
        assert!(sweep.first().copied().unwrap() < 1 << 20);
        assert!(sweep.last().copied().unwrap() > 1 << 20);
    }

    #[test]
    fn bandwidth_positive_and_plausible() {
        let bw = stream_bandwidth(8 << 20, 3);
        // Any functioning host moves between 1 GB/s and 1 TB/s.
        assert!(bw > 1e9 && bw < 1e12, "bandwidth {bw}");
    }

    #[test]
    fn marshal_roundtrip_preserves_payload() {
        let payload: Vec<u8> = (0..=255u8).collect();
        let mut scratch = Vec::new();
        let mut out = Vec::new();
        marshal_roundtrip(&payload, &mut scratch, &mut out);
        assert_eq!(out, payload);
    }
}
