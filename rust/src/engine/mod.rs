//! Engine abstraction (paper §5.1): "a thin abstraction layer over DL
//! runtime frameworks … providing a unified interface that hides the details
//! of DL runtime frameworks".
//!
//! Two engines ship:
//! * [`SimEngine`] — the calibrated simulated device: spends the profiled
//!   duration (scaled by a configurable time factor so scenarios replay fast)
//!   with processor-dependent execution noise. Used by the Runtime Evaluator
//!   and the serving experiments.
//! * [`PjrtEngine`] — real execution: runs the model's AOT HLO artifacts on
//!   the PJRT CPU client (layer chains per subgraph). Used by the e2e
//!   example and hardware-mode tests.
//!
//! New backends (paper: QNN, ORT, TVM) slot in by implementing [`Engine`].

use crate::util::rng::Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::util::error::Result;
use std::sync::Mutex;

use crate::graph::{LayerId, Network, Subgraph};
use crate::perf::PerfModel;
use crate::runtime::{layer_artifact, PjrtRuntime};
use crate::ExecConfig;

/// A unit of engine work: one subgraph of one network, with input tensors.
pub struct EngineTask<'a> {
    pub network: &'a Network,
    pub subgraph: &'a Subgraph,
    pub config: ExecConfig,
    /// Flat f32 input tensors (one per network input feeding this subgraph;
    /// engines that only model time may ignore these).
    pub inputs: Vec<Vec<f32>>,
    /// Dispatch timestamp, clock seconds (the coordinator's clock at the
    /// moment the task was handed to the worker). Fault-injecting engines
    /// key timeline events on this; time-only engines ignore it.
    pub start: f64,
}

/// Result of one engine execution.
pub struct EngineOutput {
    /// Flat f32 outputs (empty for time-only engines).
    pub tensors: Vec<Vec<f32>>,
    /// Wall-clock duration of the execution, seconds (unscaled).
    pub elapsed: f64,
    /// `Some(reason)` when the execution *failed after consuming*
    /// `elapsed` seconds (a recoverable fault — e.g. an injected transient
    /// error), with `tensors` empty. `None` on success. Distinct from the
    /// `Err` return, which signals an engine-level failure with no time
    /// attributable to the task.
    pub error: Option<String>,
}

/// The unified engine interface.
pub trait Engine: Send + Sync {
    /// Execute a subgraph task synchronously on the calling worker thread.
    fn execute(&self, task: &EngineTask<'_>) -> Result<EngineOutput>;

    /// Engine name for logs/metrics.
    fn name(&self) -> &str;

    /// Re-seed the engine's stochastic state (execution-noise RNG) so a
    /// *warm* engine replays the same noise stream as one freshly
    /// constructed with `seed`. Together with
    /// [`crate::coordinator::Coordinator::reset`] this is what makes a
    /// reused deployment's probe bit-identical to a fresh one. Default:
    /// no-op (real hardware has no re-seedable noise).
    fn reseed(&self, _seed: u64) {}
}

/// Simulated engine: consumes simulated time according to the calibrated
/// performance model. `time_scale` compresses simulated seconds into wall
/// seconds (0.0 = don't sleep at all, just account).
pub struct SimEngine {
    perf: Arc<PerfModel>,
    pub time_scale: f64,
    /// Noise applied per execution (device fluctuation); deterministic rng.
    rng: Mutex<Rng>,
    noisy: bool,
    /// Accumulated simulated busy time, ns.
    sim_busy_ns: AtomicU64,
}

impl SimEngine {
    pub fn new(perf: Arc<PerfModel>, time_scale: f64, noisy: bool, seed: u64) -> SimEngine {
        SimEngine {
            perf,
            time_scale,
            rng: Mutex::new(Rng::seed_from_u64(seed)),
            noisy,
            sim_busy_ns: AtomicU64::new(0),
        }
    }

    /// Total simulated busy seconds this engine has executed.
    pub fn simulated_busy(&self) -> f64 {
        self.sim_busy_ns.load(Ordering::Relaxed) as f64 / 1e9
    }
}

impl Engine for SimEngine {
    fn execute(&self, task: &EngineTask<'_>) -> Result<EngineOutput> {
        let nominal = self
            .perf
            .subgraph_time(task.network, &task.subgraph.layers, task.config);
        let duration = if self.noisy {
            let mut rng = self.rng.lock().unwrap();
            self.perf.sample(nominal, task.config.processor, &mut rng)
        } else {
            nominal
        };
        self.sim_busy_ns
            .fetch_add((duration * 1e9) as u64, Ordering::Relaxed);
        if self.time_scale > 0.0 {
            let wall = duration * self.time_scale;
            // Hybrid sleep: OS sleep for the bulk, spin for the tail, so the
            // scaled schedule stays faithful at sub-millisecond scale.
            let t0 = Instant::now();
            if wall > 200e-6 {
                std::thread::sleep(std::time::Duration::from_secs_f64(wall - 100e-6));
            }
            while t0.elapsed().as_secs_f64() < wall {
                std::hint::spin_loop();
            }
        }
        Ok(EngineOutput { tensors: Vec::new(), elapsed: duration, error: None })
    }

    fn name(&self) -> &str {
        "sim"
    }

    fn reseed(&self, seed: u64) {
        *self.rng.lock().unwrap() = Rng::seed_from_u64(seed);
    }
}

/// Real-execution engine: runs each layer of the subgraph through its AOT
/// HLO artifact on the PJRT CPU client, chaining outputs to inputs.
///
/// Join layers (add/concat) consume multiple predecessor tensors; the
/// artifact for each layer was lowered with the right arity by `aot.py`.
/// Thread-safety: the `xla` crate's client/executable handles are `Rc`-based
/// and not `Send`. All PJRT state therefore lives behind one mutex and every
/// call — load, compile, execute — happens while holding it, so `Rc`
/// refcounts are only ever touched by one thread at a time and no handle
/// escapes the lock. That makes the `unsafe impl Send + Sync` below sound.
pub struct PjrtEngine {
    runtime: Mutex<PjrtRuntime>,
}

unsafe impl Send for PjrtEngine {}
unsafe impl Sync for PjrtEngine {}

impl PjrtEngine {
    pub fn new(runtime: PjrtRuntime) -> PjrtEngine {
        PjrtEngine { runtime: Mutex::new(runtime) }
    }

    /// Pre-compile all layer artifacts of a network (done at registration,
    /// paper §5.2 "Workers load the model libraries embedded in the
    /// solution").
    pub fn preload(&self, net: &Network) -> Result<()> {
        let runtime = self.runtime.lock().unwrap();
        for l in 0..net.num_layers() {
            runtime.load(&layer_artifact(&net.name, l))?;
        }
        Ok(())
    }

    /// Number of compiled executables held.
    pub fn cached_modules(&self) -> usize {
        self.runtime.lock().unwrap().cached_modules()
    }
}

impl Engine for PjrtEngine {
    fn execute(&self, task: &EngineTask<'_>) -> Result<EngineOutput> {
        let t0 = Instant::now();
        let runtime = self.runtime.lock().unwrap();
        let net = task.network;
        // Tensor store: layer id -> produced tensor, seeded with subgraph
        // inputs in predecessor order.
        let mut produced: std::collections::HashMap<usize, Vec<f32>> = std::collections::HashMap::new();
        let mut ext_inputs = task.inputs.iter();
        let mut outputs = Vec::new();
        for &l in &task.subgraph.layers {
            let module = runtime.load(&layer_artifact(&net.name, l.0))?;
            let preds = net.predecessors(l);
            // Gather inputs: internal predecessors from `produced`,
            // external ones from the task's input list.
            let mut in_tensors: Vec<Vec<f32>> = Vec::with_capacity(preds.len().max(1));
            if preds.is_empty() {
                let ext = ext_inputs
                    .next()
                    .cloned()
                    .unwrap_or_else(|| default_input(net, l));
                in_tensors.push(ext);
            } else {
                for &p in preds {
                    if let Some(t) = produced.get(&p.0) {
                        in_tensors.push(t.clone());
                    } else {
                        let ext = ext_inputs
                            .next()
                            .cloned()
                            .unwrap_or_else(|| default_pred_input(net, p));
                        in_tensors.push(ext);
                    }
                }
            }
            let shaped: Vec<(&[f32], Vec<usize>)> = in_tensors
                .iter()
                .enumerate()
                .map(|(i, t)| {
                    let shape = input_shape(net, l, preds.get(i).copied());
                    (t.as_slice(), shape)
                })
                .collect();
            let refs: Vec<(&[f32], &[usize])> =
                shaped.iter().map(|(d, s)| (*d, s.as_slice())).collect();
            let mut out = module.run_f32(&refs)?;
            let tensor = out.remove(0);
            // Boundary layer: a network output, or consumed by another
            // subgraph (even if also consumed internally).
            let succs = net.successors(l);
            let is_boundary =
                succs.is_empty() || succs.iter().any(|s| !task.subgraph.contains(*s));
            if is_boundary {
                outputs.push(tensor.clone());
            }
            produced.insert(l.0, tensor);
        }
        Ok(EngineOutput { tensors: outputs, elapsed: t0.elapsed().as_secs_f64(), error: None })
    }

    fn name(&self) -> &str {
        "pjrt"
    }
}

/// Input tensor shape for layer `l` coming from predecessor `p` (or the
/// network input when `p` is None): NHWC with N=1.
pub fn input_shape(net: &Network, l: LayerId, p: Option<LayerId>) -> Vec<usize> {
    match p {
        Some(pred) => {
            let s = net.layer(pred).out_shape;
            vec![1, s.h, s.w, s.c]
        }
        None => {
            // Network input: infer from the layer's declared input channels
            // and its output spatial size × stride.
            let layer = net.layer(l);
            let (h, w) = match layer.kind {
                crate::graph::LayerKind::Conv { stride, .. }
                | crate::graph::LayerKind::DepthwiseConv { stride, .. } => {
                    (layer.out_shape.h * stride, layer.out_shape.w * stride)
                }
                crate::graph::LayerKind::Pool => (layer.out_shape.h * 2, layer.out_shape.w * 2),
                crate::graph::LayerKind::Upsample => (layer.out_shape.h / 2, layer.out_shape.w / 2),
                _ => (layer.out_shape.h, layer.out_shape.w),
            };
            vec![1, h, w, layer.in_channels]
        }
    }
}

fn default_input(net: &Network, l: LayerId) -> Vec<f32> {
    let s = input_shape(net, l, None);
    vec![0.1f32; s.iter().product()]
}

fn default_pred_input(net: &Network, p: LayerId) -> Vec<f32> {
    let s = net.layer(p).out_shape;
    vec![0.1f32; s.elements()]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::partition;
    use crate::models::build_model;
    use crate::{Backend, DataType, Processor};

    fn npu_cfg() -> ExecConfig {
        ExecConfig::new(Processor::Npu, Backend::Qnn, DataType::Fp16)
    }

    #[test]
    fn sim_engine_accounts_time() {
        let pm = Arc::new(PerfModel::paper_calibrated());
        let engine = SimEngine::new(pm.clone(), 0.0, false, 1);
        let net = build_model(0, 0);
        let part = partition(&net, &vec![false; net.num_edges()], &vec![Processor::Npu; net.num_layers()]);
        let task = EngineTask {
            network: &net,
            subgraph: &part.subgraphs[0],
            config: npu_cfg(),
            inputs: vec![],
            start: 0.0,
        };
        let out = engine.execute(&task).unwrap();
        let expected = pm.subgraph_time(&net, &part.subgraphs[0].layers, npu_cfg());
        assert!((out.elapsed - expected).abs() < 1e-12);
        assert!((engine.simulated_busy() - expected).abs() < 1e-6);
    }

    #[test]
    fn sim_engine_noise_varies_but_deterministic_per_seed() {
        let pm = Arc::new(PerfModel::paper_calibrated());
        let net = build_model(0, 1);
        let part = partition(&net, &vec![false; net.num_edges()], &vec![Processor::Cpu; net.num_layers()]);
        let run = |seed: u64| -> Vec<f64> {
            let engine = SimEngine::new(pm.clone(), 0.0, true, seed);
            (0..5)
                .map(|_| {
                    let task = EngineTask {
                        network: &net,
                        subgraph: &part.subgraphs[0],
                        config: ExecConfig::new(Processor::Cpu, Backend::Xnnpack, DataType::Fp32),
                        inputs: vec![],
                        start: 0.0,
                    };
                    engine.execute(&task).unwrap().elapsed
                })
                .collect()
        };
        let a = run(7);
        let b = run(7);
        let c = run(8);
        assert_eq!(a, b);
        assert_ne!(a, c);
        // Noise actually varies across calls.
        assert!(a.windows(2).any(|w| (w[0] - w[1]).abs() > 1e-9));
    }

    #[test]
    fn reseed_replays_the_noise_stream() {
        // A warm engine reseeded to `s` must produce the same durations as
        // a fresh engine constructed with `s` — the probe-reuse contract.
        let pm = Arc::new(PerfModel::paper_calibrated());
        let net = build_model(0, 1);
        let part = partition(&net, &vec![false; net.num_edges()], &vec![Processor::Cpu; net.num_layers()]);
        let cfg = ExecConfig::new(Processor::Cpu, Backend::Xnnpack, DataType::Fp32);
        let sample = |engine: &SimEngine| -> Vec<f64> {
            (0..4)
                .map(|_| {
                    let task = EngineTask {
                        network: &net,
                        subgraph: &part.subgraphs[0],
                        config: cfg,
                        inputs: vec![],
                        start: 0.0,
                    };
                    engine.execute(&task).unwrap().elapsed
                })
                .collect()
        };
        let warm = SimEngine::new(pm.clone(), 0.0, true, 3);
        let _burn = sample(&warm); // advance the stream
        warm.reseed(41);
        let reused = sample(&warm);
        let fresh = sample(&SimEngine::new(pm, 0.0, true, 41));
        assert_eq!(reused, fresh);
    }

    #[test]
    fn sim_engine_time_scale_sleeps() {
        let pm = Arc::new(PerfModel::paper_calibrated());
        // face_det on NPU is 0.3 ms nominal; at scale 10 it must take ≥3 ms wall.
        let engine = SimEngine::new(pm, 10.0, false, 1);
        let net = build_model(0, 0);
        let part = partition(&net, &vec![false; net.num_edges()], &vec![Processor::Npu; net.num_layers()]);
        let task = EngineTask {
            network: &net,
            subgraph: &part.subgraphs[0],
            config: npu_cfg(),
            inputs: vec![],
            start: 0.0,
        };
        let t0 = Instant::now();
        engine.execute(&task).unwrap();
        assert!(t0.elapsed().as_secs_f64() >= 0.5 * 10.0 * 0.3e-3);
    }
}
