//! Minimal property-testing loop (proptest is unavailable offline):
//! run a closure over `n` seeded random cases; on failure, report the seed
//! so the case reproduces exactly.

use super::rng::Rng;

/// Run `cases` random test cases. The closure returns `Err(msg)` to fail;
/// the panic message includes the failing seed for reproduction.
pub fn check<F>(name: &str, cases: usize, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0x5eed_0000 + case as u64;
        let mut rng = Rng::seed_from_u64(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property '{name}' failed at seed {seed:#x}: {msg}");
        }
    }
}

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)+) => {
        if !$cond {
            return Err(format!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 25, |_rng| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        check("fails", 5, |rng| {
            let x = rng.gen_range(0, 10);
            if x < 100 {
                Err(format!("x = {x}"))
            } else {
                Ok(())
            }
        });
    }
}
