//! Minimal property-testing loop (proptest is unavailable offline):
//! run a closure over `n` seeded random cases; on failure, report the seed
//! so the case reproduces exactly. The `PUZZLE_PROP_CASES` environment
//! variable multiplies every property's case count — CI's elevated lane
//! runs the same properties deeper with no code changes.

use super::rng::Rng;

/// The effective case count for a property with base count `base`:
/// scaled by the integer `PUZZLE_PROP_CASES` multiplier when set (values
/// below 1 and unparsable values are ignored).
pub fn effective_cases(base: usize) -> usize {
    let multiplier = std::env::var("PUZZLE_PROP_CASES")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&m| m >= 1)
        .unwrap_or(1);
    base.saturating_mul(multiplier)
}

/// Run `cases` random test cases (scaled by [`effective_cases`]). The
/// closure returns `Err(msg)` to fail; the panic message includes the
/// failing seed for reproduction.
pub fn check<F>(name: &str, cases: usize, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let cases = effective_cases(cases);
    for case in 0..cases {
        let seed = 0x5eed_0000 + case as u64;
        let mut rng = Rng::seed_from_u64(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property '{name}' failed at seed {seed:#x}: {msg}");
        }
    }
}

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)+) => {
        if !$cond {
            return Err(format!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_cases_scales_by_at_least_one() {
        // Never sets the env var (other tests in this process read it
        // concurrently through `check`): with it unset the base count
        // passes through; with a CI multiplier it can only grow.
        assert!(effective_cases(5) >= 5);
        assert_eq!(effective_cases(0), 0);
    }

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 25, |_rng| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        check("fails", 5, |rng| {
            let x = rng.gen_range(0, 10);
            if x < 100 {
                Err(format!("x = {x}"))
            } else {
                Ok(())
            }
        });
    }
}
