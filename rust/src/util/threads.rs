//! Thread-count resolution shared by every fan-out substrate (the GA's
//! offspring batch evaluator, the saturation probe fleet, the figure
//! protocol shard), plus the process-shareable [`CoreBudget`] that lets
//! those substrates *reclaim* cores from each other dynamically.

use std::sync::{Arc, Condvar, Mutex};

/// Resolve a requested thread count against a job count.
///
/// `0` means "use the machine" ([`std::thread::available_parallelism`]);
/// the result is clamped to `1..=jobs.max(1)` so empty or tiny job lists
/// never spawn idle workers. Every caller holds the same contract: the
/// resolved count changes *scheduling only* — results are bit-identical
/// for any value.
pub fn effective_threads(requested: usize, jobs: usize) -> usize {
    let threads = if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    };
    threads.clamp(1, jobs.max(1))
}

/// A process-shareable counting semaphore of worker-core slots.
///
/// One budget is sized to the logical cores (or an explicit override) and
/// cloned into every nested fan-out substrate — the figure-protocol shard,
/// the GA offspring/eval fan-out, the saturation probe fleet. Each
/// substrate [`CoreBudget::acquire`]s a [`CoreLease`] of `1..=max` slots
/// sized to what is *currently free*, and the lease returns its slots on
/// drop. The effect is dynamic core reclamation: when early protocol jobs
/// finish and their workers retire, the freed slots are picked up by the
/// still-running jobs' inner fan-outs at their next lease (every GA
/// generation and every α-probe re-acquires) instead of staying pinned to
/// a static one-thread-per-inner-level rule.
///
/// The budget bounds *scheduling only*. Every substrate that leases from
/// it gathers results by job index with positionally-derived seeds, so
/// outputs are bit-identical for any capacity (determinism contract #6).
#[derive(Clone)]
pub struct CoreBudget {
    inner: Arc<BudgetInner>,
}

struct BudgetInner {
    capacity: usize,
    available: Mutex<usize>,
    freed: Condvar,
}

impl std::fmt::Debug for CoreBudget {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CoreBudget")
            .field("capacity", &self.capacity())
            .field("available", &self.available())
            .finish()
    }
}

impl CoreBudget {
    /// A budget of `capacity` worker slots; `0` sizes it to the machine
    /// ([`std::thread::available_parallelism`]). Capacity is at least 1.
    pub fn new(capacity: usize) -> CoreBudget {
        let capacity = if capacity == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            capacity
        }
        .max(1);
        CoreBudget {
            inner: Arc::new(BudgetInner {
                capacity,
                available: Mutex::new(capacity),
                freed: Condvar::new(),
            }),
        }
    }

    /// Total slots this budget was created with.
    pub fn capacity(&self) -> usize {
        self.inner.capacity
    }

    /// Slots currently unleased (a racy snapshot — informational only).
    pub fn available(&self) -> usize {
        *self.inner.available.lock().expect("core budget poisoned")
    }

    /// Lease between `min` and `max` worker slots, blocking until at least
    /// `min` are free. The lease takes *everything currently free* up to
    /// `max` and returns it on drop.
    ///
    /// `min = 0` never blocks — the non-blocking form every *nested*
    /// fan-out must use (its calling thread is already charged to the
    /// budget by an outer lease, so blocking here could deadlock the
    /// whole pool; running on the caller's own thread is always legal).
    /// Even a zero-slot grant resolves to one worker
    /// ([`CoreLease::workers`]): the caller's thread itself.
    pub fn acquire(&self, min: usize, max: usize) -> CoreLease {
        let max = max.clamp(1, self.inner.capacity);
        let min = min.min(max);
        let mut available = self.inner.available.lock().expect("core budget poisoned");
        while *available < min {
            available = self.inner.freed.wait(available).expect("core budget poisoned");
        }
        let granted = (*available).min(max);
        *available -= granted;
        drop(available);
        CoreLease { budget: Some(self.clone()), granted }
    }

    fn release(&self, slots: usize) {
        if slots == 0 {
            return;
        }
        let mut available = self.inner.available.lock().expect("core budget poisoned");
        *available = (*available + slots).min(self.inner.capacity);
        drop(available);
        self.inner.freed.notify_all();
    }
}

/// A granted allocation of worker slots, returned to its [`CoreBudget`]
/// on drop. Obtained from [`CoreBudget::acquire`].
#[derive(Debug)]
pub struct CoreLease {
    budget: Option<CoreBudget>,
    granted: usize,
}

impl CoreLease {
    /// How many workers this lease entitles the holder to run: the granted
    /// slots, but never less than 1 — a zero-slot grant still runs on the
    /// calling thread (which an outer lease already paid for).
    pub fn workers(&self) -> usize {
        self.granted.max(1)
    }

    /// Slots actually charged to the budget (0 when the pool was dry and
    /// the lease covers only the caller's own thread).
    pub fn granted(&self) -> usize {
        self.granted
    }

    /// Split this lease into one single-slot token per worker
    /// ([`CoreLease::workers`] of them). Each token releases its slot back
    /// to the budget *individually* when dropped — the mechanism that lets
    /// a retiring shard worker hand its core to still-running jobs' inner
    /// fan-outs while its siblings keep stealing. When the lease holds
    /// fewer granted slots than workers (the dry-pool case), the excess
    /// tokens own nothing and release nothing.
    pub fn split(mut self) -> Vec<CoreLease> {
        let budget = self.budget.take();
        let (granted, workers) = (self.granted, self.workers());
        (0..workers)
            .map(|i| CoreLease {
                budget: budget.clone(),
                granted: usize::from(i < granted),
            })
            .collect()
    }
}

impl Drop for CoreLease {
    fn drop(&mut self) {
        if let Some(budget) = self.budget.take() {
            budget.release(self.granted);
        }
    }
}

/// Resolve one fan-out's worker count, leasing from `budget` when present.
///
/// With a budget, the lease is the *sole* authority on width: the fan-out
/// asks for up to `jobs` slots (never blocking — `min = 0`) and runs with
/// exactly [`CoreLease::workers`], so the static `requested` knob is
/// superseded and never double-clamps the grant. Without a budget this is
/// [`effective_threads`] unchanged. Hold the returned lease for the
/// fan-out's lifetime; drop it to return the slots.
pub fn leased_threads(
    budget: Option<&CoreBudget>,
    requested: usize,
    jobs: usize,
) -> (usize, Option<CoreLease>) {
    match budget {
        Some(b) => {
            let lease = b.acquire(0, jobs.max(1));
            (lease.workers().min(jobs.max(1)), Some(lease))
        }
        None => (effective_threads(requested, jobs), None),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_resolves_to_machine_and_clamps_to_jobs() {
        assert_eq!(effective_threads(4, 2), 2);
        assert_eq!(effective_threads(4, 100), 4);
        assert_eq!(effective_threads(1, 0), 1);
        assert_eq!(effective_threads(0, 0), 1);
        assert!(effective_threads(0, 64) >= 1);
    }

    #[test]
    fn acquire_takes_whats_free_and_drop_returns_it() {
        let budget = CoreBudget::new(4);
        assert_eq!(budget.capacity(), 4);
        let a = budget.acquire(0, 3);
        assert_eq!((a.workers(), a.granted()), (3, 3));
        assert_eq!(budget.available(), 1);
        // Pool nearly dry: a second lease takes the remainder.
        let b = budget.acquire(0, 3);
        assert_eq!((b.workers(), b.granted()), (1, 1));
        assert_eq!(budget.available(), 0);
        // Fully dry: min = 0 never blocks, grant 0 → 1 caller-thread worker.
        let c = budget.acquire(0, 8);
        assert_eq!((c.workers(), c.granted()), (1, 0));
        drop(a);
        assert_eq!(budget.available(), 3);
        drop(b);
        drop(c);
        assert_eq!(budget.available(), 4);
    }

    #[test]
    fn blocking_acquire_wakes_on_release() {
        let budget = CoreBudget::new(2);
        let held = budget.acquire(0, 2);
        assert_eq!(budget.available(), 0);
        let waiter = {
            let budget = budget.clone();
            std::thread::spawn(move || budget.acquire(2, 2).granted())
        };
        // Give the waiter time to park, then free the slots it needs.
        std::thread::sleep(std::time::Duration::from_millis(20));
        drop(held);
        assert_eq!(waiter.join().expect("waiter survives"), 2);
    }

    #[test]
    fn split_releases_per_token() {
        let budget = CoreBudget::new(3);
        let tokens = budget.acquire(0, 3).split();
        assert_eq!(tokens.len(), 3);
        assert_eq!(budget.available(), 0);
        let mut tokens = tokens.into_iter();
        drop(tokens.next());
        assert_eq!(budget.available(), 1, "each dropped token frees one slot");
        drop(tokens.next());
        assert_eq!(budget.available(), 2);
        drop(tokens.next());
        assert_eq!(budget.available(), 3);

        // Dry-pool split: the excess tokens own nothing.
        let all = budget.acquire(0, 3);
        let dry = budget.acquire(0, 2);
        assert_eq!(dry.workers(), 1);
        let dry_tokens = dry.split();
        assert_eq!(dry_tokens.len(), 1);
        drop(dry_tokens);
        assert_eq!(budget.available(), 0, "a zero-granted token releases nothing");
        drop(all);
        assert_eq!(budget.available(), 3);
    }

    #[test]
    fn lease_of_k_resolves_to_exactly_k_workers_for_any_requested_knob() {
        // The no-double-clamp contract: with a budget present, the lease is
        // the sole authority — the `requested` knob (GA threads,
        // probe_threads, protocol_threads) must not re-clamp the grant.
        for requested in [0usize, 1, 2, 8, 64] {
            let budget = CoreBudget::new(3);
            let (workers, lease) = leased_threads(Some(&budget), requested, 10);
            assert_eq!(workers, 3, "requested={requested} must not affect the grant");
            assert_eq!(lease.expect("budget leases").granted(), 3);
        }
        // Still clamped by the job count (never spawn idle workers)…
        let budget = CoreBudget::new(8);
        let (workers, _lease) = leased_threads(Some(&budget), 0, 2);
        assert_eq!(workers, 2);
        // …and the unleased remainder stays available to siblings.
        assert!(budget.available() >= 6);
        // Without a budget, the static rule is unchanged.
        assert_eq!(leased_threads(None, 4, 100).0, 4);
        assert!(leased_threads(None, 4, 100).1.is_none());
    }

    #[test]
    fn machine_sized_budget_has_at_least_one_slot() {
        let budget = CoreBudget::new(0);
        assert!(budget.capacity() >= 1);
        assert_eq!(budget.available(), budget.capacity());
    }
}
