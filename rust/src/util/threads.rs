//! Thread-count resolution shared by every fan-out substrate (the GA's
//! offspring batch evaluator, the saturation probe fleet, the figure
//! protocol shard).

/// Resolve a requested thread count against a job count.
///
/// `0` means "use the machine" ([`std::thread::available_parallelism`]);
/// the result is clamped to `1..=jobs.max(1)` so empty or tiny job lists
/// never spawn idle workers. Every caller holds the same contract: the
/// resolved count changes *scheduling only* — results are bit-identical
/// for any value.
pub fn effective_threads(requested: usize, jobs: usize) -> usize {
    let threads = if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    };
    threads.clamp(1, jobs.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_resolves_to_machine_and_clamps_to_jobs() {
        assert_eq!(effective_threads(4, 2), 2);
        assert_eq!(effective_threads(4, 100), 4);
        assert_eq!(effective_threads(1, 0), 1);
        assert_eq!(effective_threads(0, 0), 1);
        assert!(effective_threads(0, 64) >= 1);
    }
}
