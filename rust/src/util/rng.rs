//! Deterministic PRNG (xoshiro256++ seeded via SplitMix64) — the in-tree
//! replacement for the `rand` crate. Used by the GA, the noise model, and
//! the property tests; determinism per seed is load-bearing (analyzer
//! results are reproducible, tests are stable).

/// xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically.
    pub fn seed_from_u64(seed: u64) -> Rng {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform usize in [lo, hi) — panics if the range is empty.
    pub fn gen_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        let span = (hi - lo) as u64;
        // Multiply-shift rejection-free mapping (Lemire); bias is < 2^-64
        // per draw, irrelevant at GA scale.
        let x = self.next_u64();
        lo + ((x as u128 * span as u128) >> 64) as usize
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn gen_range_inclusive(&mut self, lo: usize, hi: usize) -> usize {
        self.gen_range(lo, hi + 1)
    }

    /// Bernoulli draw.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        if p <= 0.0 {
            return false;
        }
        if p >= 1.0 {
            return true;
        }
        self.gen_f64() < p
    }

    /// Uniform f64 in [lo, hi).
    pub fn gen_f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.gen_f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(1e-300);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> Option<&'a T> {
        if xs.is_empty() {
            None
        } else {
            Some(&xs[self.gen_range(0, xs.len())])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(1);
        for _ in 0..10_000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds_respected() {
        let mut r = Rng::seed_from_u64(2);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.gen_range(3, 13);
            assert!((3..13).contains(&x));
            seen[x - 3] = true;
        }
        assert!(seen.iter().all(|&s| s), "not all values hit: {seen:?}");
    }

    #[test]
    fn bool_probability_roughly_honored() {
        let mut r = Rng::seed_from_u64(3);
        let n = 100_000;
        let hits = (0..n).filter(|_| r.gen_bool(0.3)).count();
        let p = hits as f64 / n as f64;
        assert!((p - 0.3).abs() < 0.01, "p = {p}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(4);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::seed_from_u64(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "shuffle did nothing");
    }
}
