//! Minimal `anyhow`-style error handling (the build environment is offline,
//! so the real crate is not vendored — see `util` module docs).
//!
//! Provides the small surface the crate actually uses:
//! * [`Error`] — an opaque, message-carrying error;
//! * [`Result`] — `Result<T, Error>` with a defaultable error type;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`;
//! * the crate-root [`crate::anyhow!`] and [`crate::bail!`] macros.
//!
//! Like `anyhow::Error`, [`Error`] deliberately does **not** implement
//! `std::error::Error`: that keeps the blanket `impl<E: std::error::Error>
//! From<E> for Error` coherent with core's reflexive `From<T> for T`, so `?`
//! converts any standard error automatically.

use std::fmt;

/// An opaque error with a human-readable message (and context prefixes
/// accumulated via [`Context`]).
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (the `anyhow!` macro's backend).
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error { msg: msg.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors, `anyhow`-style.
pub trait Context<T> {
    /// Wrap the error with a static-ish context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{ctx}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error { msg: ctx.to_string() })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error { msg: f().to_string() })
    }
}

/// Construct an [`Error`] from a format string (in-tree `anyhow::anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(, $arg:expr)* $(,)?) => {
        $crate::util::error::Error::msg(format!($fmt $(, $arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::util::error::Error::msg($err)
    };
}

/// Early-return with an error (in-tree `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<u32> {
        let n: u32 = s.parse()?; // std error converts via the blanket From
        Ok(n)
    }

    #[test]
    fn std_errors_convert() {
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn context_prefixes_message() {
        let e = "x".parse::<u32>().context("parsing count").unwrap_err();
        assert!(e.to_string().starts_with("parsing count: "), "{e}");
        let e: Error = None::<u32>.context("missing field").unwrap_err();
        assert_eq!(e.to_string(), "missing field");
    }

    #[test]
    fn macros_format() {
        let e = crate::anyhow!("bad value {} at {}", 7, "slot");
        assert_eq!(e.to_string(), "bad value 7 at slot");
        fn f() -> Result<()> {
            crate::bail!("boom {}", 1);
        }
        assert_eq!(f().unwrap_err().to_string(), "boom 1");
    }
}
