//! In-tree utility substrates.
//!
//! The build environment is offline, so the usual helper crates (rand,
//! criterion, proptest, clap, crossbeam, anyhow) are rebuilt here at the
//! size this project needs: a deterministic PRNG ([`rng`]), a micro bench
//! harness ([`bench`]), a tiny property-testing loop ([`prop`]), an
//! `anyhow`-style error type ([`error`]), a counting global allocator
//! ([`alloc`]) backing the simulator's zero-allocation guarantee, and the
//! shared thread-count resolution ([`threads`]) behind every fan-out.

pub mod alloc;
pub mod bench;
pub mod error;
pub mod prop;
pub mod rng;
pub mod threads;
