//! In-tree utility substrates.
//!
//! The build environment is offline, so the usual helper crates (rand,
//! criterion, proptest, clap, crossbeam) are rebuilt here at the size this
//! project needs: a deterministic PRNG ([`rng`]), a micro bench harness
//! ([`bench`]), and a tiny property-testing loop ([`prop`]).

pub mod bench;
pub mod prop;
pub mod rng;
