//! Global allocation counter — the instrument behind the "steady-state
//! simulation performs zero per-call heap allocation" guarantee (§Perf: the
//! GA's inner loop re-runs [`crate::sim::SimWorkspace`] tens of thousands of
//! times per search; a single stray allocation per event would dominate).
//!
//! [`CountingAllocator`] wraps the system allocator and bumps a **per-thread**
//! counter on every `alloc`/`realloc`/`alloc_zeroed`. Per-thread is doubly
//! deliberate: tests asserting "zero allocations" cannot be flaked by other
//! test threads allocating concurrently, and the hot multi-threaded batch
//! evaluator never touches a shared cacheline — the overhead is one
//! uncontended TLS `Cell` bump per allocation, negligible against the
//! allocation itself.
//!
//! The counter is installed as the crate's `#[global_allocator]` in
//! `lib.rs`, so it is active in every binary, bench, and test that links
//! `puzzle`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    // `const`-initialized: no lazy init, no allocation on first access, so
    // the allocator can touch it re-entrancy-free.
    static THREAD_ALLOCATIONS: Cell<u64> = const { Cell::new(0) };
}

/// System-allocator wrapper that counts allocation calls.
pub struct CountingAllocator;

#[inline]
fn record() {
    // `try_with`: TLS may be unavailable during thread teardown; dropping
    // the count there is fine (nothing asserts across teardown).
    let _ = THREAD_ALLOCATIONS.try_with(|c| c.set(c.get() + 1));
}

unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        record();
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        record();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        record();
        System.alloc_zeroed(layout)
    }
}

/// Allocation calls made by the **current thread** so far. Subtract two
/// readings to count allocations across a code region.
pub fn thread_allocations() -> u64 {
    THREAD_ALLOCATIONS.try_with(|c| c.get()).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_heap_allocations_on_this_thread() {
        let before = thread_allocations();
        let v: Vec<u64> = std::hint::black_box(Vec::with_capacity(1024));
        let after = thread_allocations();
        assert!(after > before, "Vec::with_capacity not counted");
        drop(v);
        // A no-allocation region really reads as zero.
        let a = thread_allocations();
        let x = std::hint::black_box(3u64) + 4;
        let b = thread_allocations();
        assert_eq!(a, b, "pure arithmetic allocated?");
        assert_eq!(x, 7);
    }
}
