//! Micro bench harness (criterion is unavailable offline). Each bench
//! binary (`harness = false`) builds a [`Harness`], registers closures, and
//! prints per-iteration statistics. Warm-up + trimmed timing keeps the
//! numbers stable enough for before/after comparisons in EXPERIMENTS.md.
//!
//! [`write_json`] additionally emits the collected stats as a
//! machine-readable `name → ns/iter` map; `benches/hotpaths.rs` writes it to
//! `BENCH_hotpaths.json` at the repo root so future PRs have a perf
//! trajectory to regress against.

use std::path::Path;
use std::time::Instant;

/// Timing result of one registered bench.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iterations: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub p50_s: f64,
    pub p90_s: f64,
}

/// Run a closure repeatedly and collect stats. `target_s` bounds the total
/// measuring time; at least `min_iters` iterations always run.
pub fn run_bench<F: FnMut()>(name: &str, target_s: f64, min_iters: usize, mut f: F) -> BenchStats {
    // Warm-up.
    f();
    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters || (start.elapsed().as_secs_f64() < target_s && samples.len() < 10_000) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    BenchStats {
        name: name.to_string(),
        iterations: n,
        mean_s: mean,
        min_s: samples[0],
        p50_s: samples[n / 2],
        p90_s: samples[(n * 9 / 10).min(n - 1)],
    }
}

/// Human-friendly duration.
pub fn fmt_duration(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}us", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// Print one stats row.
pub fn report(stats: &BenchStats) {
    println!(
        "{:<44} {:>10} iters  mean {:>12}  min {:>12}  p50 {:>12}  p90 {:>12}",
        stats.name,
        stats.iterations,
        fmt_duration(stats.mean_s),
        fmt_duration(stats.min_s),
        fmt_duration(stats.p50_s),
        fmt_duration(stats.p90_s),
    );
}

/// Convenience: run + report.
pub fn bench<F: FnMut()>(name: &str, target_s: f64, min_iters: usize, f: F) -> BenchStats {
    let stats = run_bench(name, target_s, min_iters, f);
    report(&stats);
    stats
}

/// Prevent the optimizer from discarding a value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Serialize bench stats as a JSON object: `name → {ns_per_iter, ...}`.
/// Hand-rolled (serde is unavailable offline); names are escaped, numbers
/// are plain decimals.
pub fn to_json(stats: &[BenchStats]) -> String {
    let mut out = String::from("{\n");
    for (i, st) in stats.iter().enumerate() {
        let sep = if i + 1 == stats.len() { "" } else { "," };
        out.push_str(&format!(
            "  \"{}\": {{\"ns_per_iter\": {:.1}, \"iterations\": {}, \"min_ns\": {:.1}, \"p50_ns\": {:.1}, \"p90_ns\": {:.1}}}{}\n",
            json_escape(&st.name),
            st.mean_s * 1e9,
            st.iterations,
            st.min_s * 1e9,
            st.p50_s * 1e9,
            st.p90_s * 1e9,
            sep,
        ));
    }
    out.push_str("}\n");
    out
}

/// Write bench stats as JSON to `path`.
pub fn write_json(path: &Path, stats: &[BenchStats]) -> std::io::Result<()> {
    std::fs::write(path, to_json(stats))
}

/// Numbers recovered from a [`to_json`] file (the subset the regression
/// guard compares).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BenchNumbers {
    pub ns_per_iter: f64,
    pub min_ns: f64,
}

/// Parse a [`to_json`]-format file back into `(name, numbers)` rows — the
/// inverse used by the `bench_guard` binary. Line-oriented and forgiving:
/// lines without a quoted name + `ns_per_iter`/`min_ns` fields are skipped.
pub fn parse_json(text: &str) -> Vec<(String, BenchNumbers)> {
    fn field(line: &str, key: &str) -> Option<f64> {
        let start = line.find(key)? + key.len();
        let rest = &line[start..];
        let rest = rest.trim_start_matches([':', ' ']);
        let end = rest
            .find(|c: char| !(c.is_ascii_digit() || c == '.' || c == '-' || c == 'e' || c == '+'))
            .unwrap_or(rest.len());
        rest[..end].parse().ok()
    }
    let mut out = Vec::new();
    for line in text.lines() {
        let trimmed = line.trim_start();
        if !trimmed.starts_with('"') {
            continue;
        }
        // Name: between the first quote and the next unescaped quote.
        let body = &trimmed[1..];
        let mut name = String::new();
        let mut escaped = false;
        let mut name_len = 0;
        for c in body.chars() {
            name_len += c.len_utf8();
            if escaped {
                name.push(c);
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                break;
            } else {
                name.push(c);
            }
        }
        let rest = &body[name_len..];
        if let (Some(ns_per_iter), Some(min_ns)) =
            (field(rest, "\"ns_per_iter\""), field(rest, "\"min_ns\""))
        {
            out.push((name, BenchNumbers { ns_per_iter, min_ns }));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_at_least_min_iters() {
        let s = run_bench("noop", 0.0, 7, || {});
        assert!(s.iterations >= 7);
        assert!(s.min_s <= s.p50_s && s.p50_s <= s.p90_s);
    }

    #[test]
    fn json_output_well_formed() {
        let stats = vec![
            run_bench("a/first", 0.0, 2, || {}),
            run_bench("b/\"quoted\"", 0.0, 2, || {}),
        ];
        let json = to_json(&stats);
        assert!(json.starts_with("{\n") && json.ends_with("}\n"), "{json}");
        assert!(json.contains("\"a/first\""));
        assert!(json.contains("\\\"quoted\\\""), "quotes not escaped: {json}");
        assert!(json.contains("ns_per_iter"));
        // Exactly one comma separator for two entries (each entry line ends
        // with a single closing brace).
        assert_eq!(json.matches("},\n").count(), 1, "{json}");
    }

    #[test]
    fn parse_json_roundtrips_write_json() {
        let stats = vec![
            run_bench("analyzer/serial_generation", 0.0, 2, || {}),
            run_bench("ga/decode_genome(cached profiles)", 0.0, 2, || {}),
        ];
        let parsed = parse_json(&to_json(&stats));
        assert_eq!(parsed.len(), 2);
        for (st, (name, nums)) in stats.iter().zip(&parsed) {
            assert_eq!(&st.name, name);
            assert!((nums.ns_per_iter - st.mean_s * 1e9).abs() <= 0.1);
            assert!((nums.min_ns - st.min_s * 1e9).abs() <= 0.1);
        }
        // Garbage lines are skipped, not fatal.
        assert!(parse_json("{\nnot json\n}\n").is_empty());
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(2.0), "2.000s");
        assert_eq!(fmt_duration(0.0025), "2.500ms");
        assert_eq!(fmt_duration(2.5e-6), "2.500us");
        assert!(fmt_duration(3e-9).ends_with("ns"));
    }
}
